//! Trace schema integration test (own binary: tracing flips the
//! process-wide `TRACE_ON` switch, so this must not share a process
//! with the library's exact-count tests).
//!
//! One traced plan-build + `spmv_multi` product, then the contract the
//! `csrc trace` CLI and CI rely on: events serialize to the
//! chrome://tracing format, survive a parse round-trip, carry only the
//! fixed phase names, keep globally monotone timestamps, and every
//! begin has a balancing, properly nested end.

use csrc_spmv::obs::{self, Phase};
use csrc_spmv::parallel::{build_engine, AccumMethod, EngineKind};
use csrc_spmv::plan::PlanBuilder;
use csrc_spmv::sparse::{Coo, Csrc, SpmvKernel};
use csrc_spmv::util::json::Json;
use csrc_spmv::util::Rng;
use std::sync::Arc;

#[test]
fn traced_spmv_multi_emits_a_valid_schema() {
    // One test fn only: concurrent #[test]s toggling the global trace
    // switch would interleave their spans.
    let mut rng = Rng::new(17);
    let coo = Coo::random_structurally_symmetric(400, 5, false, &mut rng);
    let a = Arc::new(Csrc::from_coo(&coo).unwrap());
    let n = a.n;
    let kernel: Arc<dyn SpmvKernel> = a.clone();
    let kind = EngineKind::LocalBuffers(AccumMethod::Effective);

    obs::reset_phases();
    obs::start_trace();
    let plan = Arc::new(PlanBuilder::for_kind(3, kind).build(kernel.as_ref()));
    let mut engine = build_engine(kind, kernel, plan);
    let k = 4;
    let x: Vec<f64> = (0..n * k).map(|i| (i as f64 * 0.001).sin()).collect();
    let mut y = vec![0.0; n * k];
    engine.spmv_multi(&x, &mut y, k);
    drop(engine); // pool threads park; every span is closed
    let events = obs::stop_trace();

    // Raw events: non-empty, balanced, monotone, fixed name set.
    assert!(!events.is_empty(), "a traced product must record spans");
    let begins = events.iter().filter(|e| e.begin).count();
    assert_eq!(begins * 2, events.len(), "begin/end events must pair up");
    let allowed: Vec<&str> = Phase::ALL.iter().map(|p| p.label()).collect();
    for e in &events {
        assert!(allowed.contains(&e.name), "unknown phase name {:?}", e.name);
    }
    for w in events.windows(2) {
        assert!(w[0].ts_us <= w[1].ts_us, "timestamps must be globally monotone");
    }
    assert_eq!(obs::trace_dropped(), 0, "small trace must fit the ring");

    // The run exercised the phases the CLI prints for this path.
    let seen: Vec<&str> = events.iter().filter(|e| e.begin).map(|e| e.name).collect();
    for phase in [Phase::PlanBuild, Phase::Zero, Phase::Sweep, Phase::Accumulate] {
        assert!(seen.contains(&phase.label()), "missing {:?} span", phase);
    }

    // Serialized form validates, and survives a dump → parse round-trip
    // (what `csrc trace --out` writes is what CI re-validates).
    let j = obs::trace_to_json(&events);
    let nevents = obs::validate_trace_json(&j).expect("schema valid");
    assert_eq!(nevents, events.len());
    let reparsed = Json::parse(&j.dump()).expect("round-trip parse");
    assert_eq!(obs::validate_trace_json(&reparsed).expect("still valid"), events.len());

    // Tampering is caught: swap one end event's name.
    if let Some(arr) = reparsed.get("traceEvents").and_then(|e| e.as_arr()) {
        let mut broken: Vec<Json> = arr.to_vec();
        for ev in broken.iter_mut().rev() {
            if ev.get("ph").and_then(|p| p.as_str()) == Some("E") {
                *ev = Json::obj(vec![
                    ("name", Json::Str("retune".to_string())),
                    ("cat", Json::Str("csrc".to_string())),
                    ("ph", Json::Str("E".to_string())),
                    ("ts", ev.get("ts").cloned().unwrap()),
                    ("pid", Json::Num(1.0)),
                    ("tid", ev.get("tid").cloned().unwrap()),
                ]);
                break;
            }
        }
        let tampered = Json::obj(vec![
            ("traceEvents", Json::Arr(broken)),
            ("displayTimeUnit", Json::Str("ms".to_string())),
        ]);
        assert!(obs::validate_trace_json(&tampered).is_err(), "mismatched end must fail");
    } else {
        panic!("traceEvents array missing after round-trip");
    }
}
