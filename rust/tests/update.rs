//! In-place value updates, end to end (ISSUE 10): the update path must
//! be numerically indistinguishable from rebuilding and re-registering
//! from scratch — across every engine, reorder policy, and shard count
//! — while keeping every pattern-derived artifact (tuned decision,
//! plan, RCM ordering) and never mixing values generations in a panel.

#![allow(clippy::field_reassign_with_default)]

use csrc_spmv::coordinator::{
    BatchPolicy, MatvecService, ServiceConfig, ShardConfig, ShardedMatvecService,
};
use csrc_spmv::gen::{assemble_coo, Assembler, Mesh2d};
use csrc_spmv::parallel::{AccumMethod, EngineKind};
use csrc_spmv::reorder::ReorderPolicy;
use csrc_spmv::sparse::{Csrc, LinOp};
use csrc_spmv::tuner::TrialBudget;
use std::sync::Arc;
use std::time::Duration;

fn close(got: &[f64], want: &[f64]) -> bool {
    got.len() == want.len()
        && got.iter().zip(want).all(|(g, w)| (g - w).abs() <= 1e-10 * (1.0 + w.abs()))
}

/// Rebuild-from-scratch reference: sequential Coo assembly at time `t`,
/// compacted and converted fresh — the path `update_values` replaces.
fn rebuilt(mesh: &csrc_spmv::gen::Mesh, convection: f64, t: f64) -> Csrc {
    Csrc::from_coo(&assemble_coo(mesh, convection, t)).unwrap()
}

#[test]
fn update_equals_rebuild_across_engines_reorder_and_shards() {
    let mesh = Mesh2d::quads(12, 12);
    let convection = 0.25;
    let asm = Assembler::new(mesh.clone(), convection).unwrap();
    let n = asm.matrix().n;
    let x: Vec<f64> = (0..n).map(|i| (i as f64 * 0.17).sin()).collect();
    let t = 1.3;
    let reference = rebuilt(&mesh, convection, t);
    let mut want = vec![0.0; n];
    reference.apply(&x, &mut want);
    // The in-place step the services will apply instead.
    let step = asm.assemble_sequential(t);
    assert_eq!(step.pattern_fingerprint(), reference.pattern_fingerprint());
    let engines = [
        EngineKind::Sequential,
        EngineKind::LocalBuffers(AccumMethod::Effective),
        EngineKind::Colorful,
        EngineKind::Atomic,
    ];
    for kind in engines {
        for reorder in [ReorderPolicy::Never, ReorderPolicy::Always] {
            for nshards in [1usize, 2, 4] {
                let mut service = ServiceConfig::default();
                service.workers = 1;
                service.route.parallel_kind = kind;
                service.route.threads = 2;
                service.route.min_parallel_n = 1;
                service.route.reorder = reorder;
                let svc = ShardedMatvecService::start(ShardConfig {
                    nshards,
                    service,
                    ..ShardConfig::default()
                });
                svc.register("m", Arc::new(asm.matrix().clone()));
                // Serve the t = 0 values first so plans, orderings and
                // engines all exist before the update hits them.
                let y0 = svc.spmv("m", &x).unwrap();
                assert_eq!(y0.len(), n);
                svc.update_values("m", &step).unwrap();
                let got = svc.spmv("m", &x).unwrap();
                assert!(
                    close(&got, &want),
                    "update != rebuild for kind={kind:?} reorder={reorder:?} \
                     nshards={nshards}"
                );
                svc.shutdown();
            }
        }
    }
}

#[test]
fn updates_keep_tuned_artifacts_across_many_steps() {
    // Auto-tuned, reordered serving: five update/serve steps must leave
    // `tunes`, `plan_builds`, and `rcm_builds` exactly where the first
    // serve put them — the whole point of the in-place path — while
    // every step's products match the from-scratch rebuild.
    let mesh = Mesh2d::quads(10, 10);
    let convection = 0.0;
    let mut asm = Assembler::new(mesh.clone(), convection).unwrap();
    let n = asm.matrix().n;
    let mut cfg = ServiceConfig::default();
    cfg.workers = 1;
    cfg.route.parallel_kind = EngineKind::Auto;
    cfg.route.threads = 2;
    cfg.route.sweep_threads = true;
    cfg.route.min_parallel_n = 1;
    cfg.route.reorder = ReorderPolicy::Always;
    cfg.tune_budget = TrialBudget::smoke();
    cfg.drift_fraction = 0.0;
    let svc = MatvecService::start(cfg);
    svc.register("m", Arc::new(asm.matrix().clone()));
    let x: Vec<f64> = (0..n).map(|i| 1.0 / (1.0 + i as f64)).collect();
    let y = svc.call("m", x.clone()).unwrap();
    assert_eq!(y.len(), n);
    let before = svc.stats();
    assert_eq!(before.tunes, 1, "registration tunes exactly once");
    for step in 1..=5u32 {
        let t = 0.3 * step as f64;
        let next = asm.assemble(t, 2);
        svc.update_values("m", &next).unwrap();
        let got = svc.call("m", x.clone()).unwrap();
        let reference = rebuilt(&mesh, convection, t);
        let mut want = vec![0.0; n];
        reference.apply(&x, &mut want);
        assert!(close(&got, &want), "step {step}: update != rebuild");
    }
    let after = svc.stats();
    assert_eq!(after.tunes, before.tunes, "updates must never re-tune");
    assert_eq!(after.plan_builds, before.plan_builds, "plans must survive updates");
    assert_eq!(after.rcm_builds, before.rcm_builds, "RCM orderings must survive updates");
    assert_eq!(after.value_updates, 5);
    assert_eq!(after.panics_caught, 0);
    assert_eq!(after.failed, 0);
    svc.shutdown();
}

#[test]
fn parallel_assembly_variants_serve_identically() {
    // Atomic scatter and colored batches must both agree with the
    // sequential Coo oracle *through the serving stack*, and the
    // assembly counters must record which variant ran.
    let mesh = Mesh2d::triangles(9, 9);
    let convection = 0.4;
    let asm = Assembler::new(mesh.clone(), convection).unwrap();
    let n = asm.matrix().n;
    let svc = MatvecService::start(ServiceConfig::default());
    svc.register("m", Arc::new(asm.matrix().clone()));
    let x: Vec<f64> = (0..n).map(|i| (i as f64 * 0.09).cos()).collect();
    for (step, t) in [0.8, 1.6].iter().enumerate() {
        let colored = step % 2 == 0;
        let next =
            if colored { asm.assemble_colored(*t, 2) } else { asm.assemble_atomic(*t, 2) };
        svc.update_values("m", &next).unwrap();
        svc.record_assembly(colored);
        let got = svc.call("m", x.clone()).unwrap();
        let reference = rebuilt(&mesh, convection, *t);
        let mut want = vec![0.0; n];
        reference.apply(&x, &mut want);
        assert!(close(&got, &want), "t={t}: served product != rebuilt oracle");
    }
    let s = svc.stats();
    assert_eq!(s.value_updates, 2);
    assert_eq!(s.assembly_colored, 1);
    assert_eq!(s.assembly_atomic, 1);
    svc.shutdown();
}

#[test]
fn interleaved_updates_never_lose_or_corrupt_requests() {
    // Satellite (ISSUE 10): requests submitted before and after an
    // update_values may never coalesce into one panel. Observable
    // contract: every request answers, post-update requests see the new
    // values, and pre-update requests see exactly the values they were
    // submitted against — the worker serves a stamped batch from the
    // retained snapshot its stamp names — never a mixture, never a
    // loss.
    let mesh = Mesh2d::quads(8, 8);
    let asm = Assembler::new(mesh.clone(), 0.0).unwrap();
    let n = asm.matrix().n;
    let mut cfg = ServiceConfig::default();
    cfg.workers = 1;
    cfg.batch = BatchPolicy { max_batch: 64, max_wait: Duration::from_millis(40) };
    let svc = MatvecService::start(cfg);
    let a0 = asm.matrix().clone();
    svc.register("m", Arc::new(a0.clone()));
    let a1 = asm.assemble_sequential(2.0);
    let x: Vec<f64> = (0..n).map(|i| (i as f64 * 0.05).cos()).collect();
    let (mut y0, mut y1) = (vec![0.0; n], vec![0.0; n]);
    a0.apply(&x, &mut y0);
    a1.apply(&x, &mut y1);
    assert!(!close(&y0, &y1), "the generations must be distinguishable");
    let pre: Vec<_> = (0..4).map(|_| svc.submit("m", x.clone())).collect();
    svc.update_values("m", &a1).unwrap();
    let post: Vec<_> = (0..4).map(|_| svc.submit("m", x.clone())).collect();
    for rx in pre {
        let y = rx.recv_timeout(Duration::from_secs(10)).unwrap().unwrap();
        assert!(close(&y, &y0), "pre-update replies must serve the values they observed");
    }
    for rx in post {
        let y = rx.recv_timeout(Duration::from_secs(10)).unwrap().unwrap();
        assert!(close(&y, &y1), "post-update replies must serve the new values");
    }
    let s = svc.stats();
    assert_eq!(s.completed, s.submitted);
    assert_eq!(s.failed, 0);
    assert_eq!(s.value_updates, 1);
    svc.shutdown();
}

#[test]
fn pre_update_submissions_serve_pre_update_values() {
    // Regression (review): the batcher keys panels on the submit-time
    // values generation, so the worker must honor that stamp — a batch
    // submitted before an `update_values` but dispatched after it
    // computes with the *pre-update* values, served from the registry's
    // retained snapshot. A long batching window makes the ordering
    // deterministic: the update always lands while the request is still
    // queued.
    let mesh = Mesh2d::quads(8, 8);
    let asm = Assembler::new(mesh.clone(), 0.0).unwrap();
    let n = asm.matrix().n;
    let mut cfg = ServiceConfig::default();
    cfg.workers = 1;
    cfg.batch = BatchPolicy { max_batch: 64, max_wait: Duration::from_millis(300) };
    let svc = MatvecService::start(cfg);
    let a0 = asm.matrix().clone();
    svc.register("m", Arc::new(a0.clone()));
    let a1 = asm.assemble_sequential(2.0);
    let x: Vec<f64> = (0..n).map(|i| (i as f64 * 0.07).sin()).collect();
    let (mut y0, mut y1) = (vec![0.0; n], vec![0.0; n]);
    a0.apply(&x, &mut y0);
    a1.apply(&x, &mut y1);
    assert!(!close(&y0, &y1), "the generations must be distinguishable");
    let pre = svc.submit("m", x.clone());
    std::thread::sleep(Duration::from_millis(50));
    svc.update_values("m", &a1).unwrap();
    let y = pre.recv_timeout(Duration::from_secs(10)).unwrap().unwrap();
    assert!(close(&y, &y0), "a pre-update submission must compute with the old values");
    let got = svc.call("m", x.clone()).unwrap();
    assert!(close(&got, &y1), "a post-update call must compute with the new values");
    assert_eq!(svc.stats().failed, 0);
    svc.shutdown();
}

#[test]
fn update_refuses_mismatched_patterns_with_typed_errors() {
    // The guard rails: wrong shape and wrong pattern are typed fatal
    // errors — the registered matrix keeps serving the old values.
    let mesh = Mesh2d::quads(6, 6);
    let asm = Assembler::new(mesh.clone(), 0.1).unwrap();
    let other = Assembler::new(Mesh2d::quads(7, 7), 0.1).unwrap();
    let n = asm.matrix().n;
    let svc = MatvecService::start(ServiceConfig::default());
    svc.register("m", Arc::new(asm.matrix().clone()));
    let e = svc.update_values("m", other.matrix()).unwrap_err();
    assert!(!e.is_retryable(), "pattern mismatch is a caller bug: {e}");
    let e = svc.update_values("ghost", asm.matrix()).unwrap_err();
    assert!(!e.is_retryable(), "unknown key is a caller bug: {e}");
    let x = vec![1.0; n];
    let mut want = vec![0.0; n];
    asm.matrix().apply(&x, &mut want);
    let got = svc.call("m", x).unwrap();
    assert!(close(&got, &want), "failed updates must leave the values untouched");
    assert_eq!(svc.stats().value_updates, 0);
    svc.shutdown();
}
