//! End-to-end chaos tests: the only place the process-global fault
//! switch (`csrc_spmv::faults`) is ever armed during `cargo test`.
//!
//! Chaos state is process-wide, so every test here serializes on one
//! mutex and disarms on drop (even when the test body panics) — the
//! tests in this binary may run on different threads, but never with
//! chaos armed concurrently. The library's own `faults::tests` exercise
//! only the pure schedule and parser and never flip the switch.

use csrc_spmv::coordinator::{
    BreakerState, MatvecService, ServiceConfig, ShardConfig, ShardedMatvecService,
};
use csrc_spmv::faults;
use csrc_spmv::harness::{self, figures};
use csrc_spmv::parallel::EngineKind;
use csrc_spmv::sparse::{Coo, Csrc};
use csrc_spmv::tuner;
use csrc_spmv::util::Rng;
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Duration;

static GATE: Mutex<()> = Mutex::new(());

/// Serializes the test and guarantees chaos is disarmed before and
/// after, even if the test body panics.
struct ChaosGuard {
    _gate: MutexGuard<'static, ()>,
}

impl Drop for ChaosGuard {
    fn drop(&mut self) {
        faults::reset();
    }
}

fn chaos_guard() -> ChaosGuard {
    // A previous test failing while holding the gate poisons it; the
    // protected state (the global chaos registry) is reset below, so
    // recovering the lock is sound.
    let gate = GATE.lock().unwrap_or_else(|e| e.into_inner());
    faults::reset();
    ChaosGuard { _gate: gate }
}

fn test_matrix(n: usize, seed: u64) -> Arc<Csrc> {
    let mut rng = Rng::new(seed);
    Arc::new(Csrc::from_coo(&Coo::random_structurally_symmetric(n, 3, false, &mut rng)).unwrap())
}

fn assert_close(got: &[f64], want: &[f64]) {
    assert_eq!(got.len(), want.len());
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert!((g - w).abs() <= 1e-9 * (1.0 + w.abs()), "index {i}: got {g}, want {w}");
    }
}

#[test]
fn worker_panic_is_caught_supervised_and_served_after_restart() {
    let _g = chaos_guard();
    let svc = MatvecService::start(ServiceConfig { workers: 1, ..ServiceConfig::default() });
    let a = test_matrix(60, 5);
    svc.register("a", a);
    let x = vec![1.0; 60];
    // Healthy product first: plan built, engine warm.
    svc.call("a", x.clone()).expect("healthy product");
    faults::configure("worker-panic:1").unwrap();
    faults::set_chaos_enabled(true);
    // The panicked batch fails over as a typed, retryable error — the
    // request is answered, not lost.
    let err = svc.call("a", x.clone()).expect_err("panicked batch must fail over");
    assert!(err.is_retryable(), "{err}");
    assert_eq!(err.reason().unwrap().label(), "worker-crashed");
    faults::reset();
    // The supervisor restarts the (only) worker; the next product is
    // served by the respawn — this call would hang forever if the
    // restart never happened.
    let y = svc.call("a", x).expect("served by the restarted worker");
    assert_eq!(y.len(), 60);
    let s = svc.stats();
    assert!(s.panics_caught >= 1, "panics_caught = {}", s.panics_caught);
    assert!(s.worker_restarts >= 1, "worker_restarts = {}", s.worker_restarts);
    // The supervision counters are on the scrape.
    let page = svc.metrics_registry().render_prometheus();
    assert!(page.contains("csrc_panics_caught_total"), "{page}");
    assert!(page.contains("csrc_worker_restarts_total"), "{page}");
    svc.shutdown();
}

#[test]
fn stalled_shard_trips_deadline_opens_breaker_serves_degraded_then_recovers() {
    let _g = chaos_guard();
    let svc = ShardedMatvecService::start(ShardConfig {
        nshards: 1,
        deadline: Duration::from_millis(40),
        breaker_threshold: 2,
        breaker_cooldown: Duration::from_millis(150),
        service: ServiceConfig { workers: 1, ..ServiceConfig::default() },
        ..ShardConfig::default()
    });
    let a = test_matrix(80, 6);
    svc.register("a", a.clone());
    let x: Vec<f64> = (0..80).map(|i| (i as f64 * 0.31).sin()).collect();
    let mut want = vec![0.0; 80];
    a.spmv_into_zeroed(&x, &mut want);
    // Healthy product: plan built, breaker closed.
    assert_close(&svc.spmv("a", &x).expect("healthy product"), &want);
    // Every batch now stalls 250ms — far past the 40ms gather deadline.
    faults::configure("shard-stall:1,stall-ms:250").unwrap();
    faults::set_chaos_enabled(true);
    // Two consecutive deadline misses open the breaker.
    for i in 0..2 {
        let e = svc.spmv("a", &x).expect_err("stalled shard must miss the deadline");
        assert_eq!(e.reason().unwrap().label(), "deadline-exceeded", "product {i}: {e}");
        assert!(e.is_retryable());
    }
    assert_eq!(svc.stats()[0].breaker, BreakerState::Open);
    // While open, the row block is served by the sequential fallback —
    // degraded, still exactly right, and no shard traffic.
    let y = svc.spmv("a", &x).expect("degraded product");
    assert_close(&y, &want);
    assert_eq!(svc.stats()[0].degraded, 1);
    // Heal the shard and wait out the cooldown (plus the tail of the
    // last 250ms stall): the half-open probe passes and the breaker
    // closes again.
    faults::reset();
    std::thread::sleep(Duration::from_millis(500));
    let y = svc.spmv("a", &x).expect("half-open probe product");
    assert_close(&y, &want);
    assert_eq!(svc.stats()[0].breaker, BreakerState::Closed);
    // Exact metric deltas for the whole scenario: 5 products = 1 healthy
    // + 2 deadline rejections + 1 degraded + 1 probe.
    let stats = svc.stats();
    let s = &stats[0];
    assert_eq!(s.deadline_exceeded, 2);
    assert_eq!(s.degraded, 1);
    assert_eq!(s.rejects, 0, "queue never filled");
    let f = svc.front_stats();
    assert_eq!(f.products, 5);
    assert_eq!(f.completed, 3);
    assert_eq!(f.rejected, 2);
    assert_eq!(f.degraded, 1);
    assert_eq!(f.retries, 0);
    // Breaker transitions and labeled rejections are on the scrape.
    let page = svc.render_prometheus();
    assert!(
        page.contains("csrc_shard_breaker_transitions_total{shard=\"0\",to=\"open\"} 1"),
        "{page}"
    );
    assert!(
        page.contains("csrc_shard_breaker_transitions_total{shard=\"0\",to=\"half-open\"} 1"),
        "{page}"
    );
    assert!(
        page.contains("csrc_shard_breaker_transitions_total{shard=\"0\",to=\"closed\"} 1"),
        "{page}"
    );
    assert!(
        page.contains("csrc_shard_rejections_total{reason=\"deadline-exceeded\",shard=\"0\"} 2"),
        "{page}"
    );
    assert!(page.contains("csrc_shard_degraded_products_total{shard=\"0\"} 1"), "{page}");
    assert!(page.contains("csrc_shard_breaker_state{shard=\"0\"} 0"), "{page}");
    svc.shutdown();
}

#[test]
fn chaos_equivalence_every_completed_product_matches_the_oracle() {
    let _g = chaos_guard();
    let a = test_matrix(120, 9);
    let x: Vec<f64> = (0..120).map(|i| (i as f64 * 0.17).cos()).collect();
    let mut want = vec![0.0; 120];
    a.spmv_into_zeroed(&x, &mut want);
    for nshards in [1usize, 2, 4] {
        faults::reset();
        let svc = ShardedMatvecService::start(ShardConfig {
            nshards,
            breaker_cooldown: Duration::from_millis(30),
            ..ShardConfig::default()
        });
        svc.register("a", a.clone());
        // Warm product before chaos: plans and engines built.
        assert_close(&svc.spmv("a", &x).expect("warm product"), &want);
        faults::configure("worker-panic:0.2,shard-stall:0.3,stall-ms:3,queue-full:0.15,seed:42")
            .unwrap();
        faults::set_chaos_enabled(true);
        let (mut completed, mut rejected) = (0u64, 0u64);
        for i in 0..40 {
            match svc.spmv("a", &x) {
                Ok(y) => {
                    completed += 1;
                    // Chaos may slow, reject, or degrade a product —
                    // never corrupt it.
                    assert_close(&y, &want);
                }
                Err(e) => {
                    rejected += 1;
                    assert!(e.is_retryable(), "shards={nshards} product {i}: fatal {e}");
                }
            }
        }
        faults::reset();
        // Conservation: every submitted product resolved, none lost.
        let f = svc.front_stats();
        assert_eq!(f.products, 41, "shards={nshards}");
        assert_eq!(f.completed + f.rejected, f.products, "shards={nshards}: lost requests");
        assert_eq!(f.completed, completed + 1, "shards={nshards}");
        assert_eq!(f.rejected, rejected, "shards={nshards}");
        assert!(completed > 0, "shards={nshards}: nothing completed under chaos");
        svc.shutdown();
    }
}

#[test]
fn cache_io_faults_degrade_reads_and_skip_writes_without_clobbering() {
    let _g = chaos_guard();
    let dir = std::env::temp_dir().join(format!("csrc_chaos_cache_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("decisions.json");
    // Healthy: one persisted decision.
    let cache = tuner::DecisionCache::open(&path);
    cache.put(fake_decision(7, 2));
    assert_eq!(tuner::DecisionCache::open(&path).len(), 1);
    // Armed: the open's read fails (injected) — the cache degrades to
    // empty instead of erroring, and a put under fault keeps the
    // in-memory entry but skips the file write, so the healthy file
    // survives untouched.
    faults::configure("cache-io:1").unwrap();
    faults::set_chaos_enabled(true);
    let faulted = tuner::DecisionCache::open(&path);
    assert!(faulted.is_empty(), "injected read fault must degrade to empty");
    faulted.put(fake_decision(8, 2));
    assert_eq!(faulted.len(), 1, "in-memory cache stays authoritative");
    faults::reset();
    let back = tuner::DecisionCache::open(&path);
    assert_eq!(back.len(), 1, "faulted write must not clobber the file");
    assert!(back.get(7, 2).is_some(), "original entry survives");
    assert!(back.get(8, 2).is_none(), "faulted put never reached disk");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn dropping_the_sharded_front_joins_every_thread() {
    let _g = chaos_guard();
    let svc = ShardedMatvecService::start(ShardConfig { nshards: 2, ..ShardConfig::default() });
    let a = test_matrix(50, 4);
    svc.register("a", a);
    let x = vec![1.0; 50];
    svc.spmv("a", &x).unwrap();
    // Drop (not shutdown): the front joins every shard's workers,
    // re-tuner, dispatcher, and supervisor — a detached thread would
    // leave this test passing but flaky under races; a join deadlock
    // would hang it.
    drop(svc);
}

#[test]
fn faults_figure_table_balances_the_books() {
    let _g = chaos_guard();
    let suite = harness::smoke_suite();
    let rows = figures::faults_table(&suite[..1], figures::FAULTS_SPEC);
    assert_eq!(rows.len(), 1);
    let headers = figures::faults_headers();
    assert_eq!(rows[0].len(), headers.len());
    // Column 7 is "lost": products not accounted as completed+rejected.
    assert_eq!(rows[0][7], "0", "lost requests: {rows:?}");
    assert_eq!(rows[0].last().unwrap(), "yes", "wrong answers: {rows:?}");
    assert!(!faults::chaos_enabled(), "the table must disarm chaos when done");
}

/// A minimal valid decision for the cache-io test (mirrors the shape the
/// tuner persists; the values are arbitrary).
fn fake_decision(fp: u64, nthreads: usize) -> tuner::Decision {
    tuner::Decision {
        kind: EngineKind::Sequential,
        reorder: false,
        mflops: 100.0,
        measured: true,
        provenance: tuner::Provenance::Measured,
        served_mflops: 0.0,
        tuned_s: 0.001,
        fingerprint: fp,
        nthreads,
        max_threads: nthreads,
        features: tuner::Features {
            n: 100,
            work_flops: 900,
            scatter_pairs: 200,
            scatter_ratio: 0.8,
            bandwidth: 17,
            window_rows: 260,
            window_shrink: 0.65,
            colors: 5,
            intervals: 9,
            balance: 1.06,
            nthreads,
        },
        trials: Vec::new(),
        sweep: vec![tuner::SweepPoint { nthreads: 1, trials: Vec::new() }],
        block_k: 1,
        block_rates: Vec::new(),
    }
}
