//! Integration: the rust runtime executes the python-AOT artifacts and
//! the numbers agree with the native CSRC engines — the proof that all
//! three layers compose.
//!
//! The whole file is gated behind the `xla` cargo feature (the PJRT
//! client needs the vendored `xla` crate and the xla_extension shared
//! library, neither of which exists on a bare machine); run with
//! `cargo test --features xla`. It additionally requires
//! `make artifacts` and skips cleanly if the artifact directory is
//! absent. The artifact-free cross-check lives in `end_to_end.rs`
//! (`native_engines_agree_with_ell_reference`).
#![cfg(feature = "xla")]

use csrc_spmv::runtime::XlaRuntime;
use csrc_spmv::sparse::{Coo, Csrc};
use csrc_spmv::util::Rng;
use std::path::Path;

fn artifacts_dir() -> Option<&'static Path> {
    let p = Path::new("artifacts");
    if p.join("manifest.json").exists() {
        Some(p)
    } else {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        None
    }
}

fn test_matrix(n: usize, w: usize, seed: u64) -> Csrc {
    let mut rng = Rng::new(seed);
    // Keep max row width <= w by using few nnz per row.
    let coo = Coo::random_structurally_symmetric(n, w.min(4), false, &mut rng);
    let a = Csrc::from_coo(&coo).unwrap();
    assert!(a.max_row_width() <= w);
    a
}

#[test]
fn xla_spmv_matches_native_csrc() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = XlaRuntime::open(dir).expect("open runtime");
    assert_eq!(rt.platform(), "cpu");
    let a = test_matrix(200, 8, 1);
    let ell = a.to_ell(256, 8).expect("pad to artifact shape");
    ell.validate().unwrap();
    let mut rng = Rng::new(2);
    let x64: Vec<f64> = (0..256).map(|i| if i < 200 { rng.normal() } else { 0.0 }).collect();
    let x32: Vec<f32> = x64.iter().map(|&v| v as f32).collect();

    let got = rt.spmv("spmv_n256_w8", &ell, &x32).expect("xla spmv");

    let mut want = vec![0.0f64; 200];
    a.spmv_into_zeroed(&x64[..200], &mut want);
    for i in 0..200 {
        let diff = (got[i] as f64 - want[i]).abs();
        assert!(diff < 1e-3 * (1.0 + want[i].abs()), "row {i}: {} vs {}", got[i], want[i]);
    }
    // Padding rows must stay zero.
    for i in 200..256 {
        assert_eq!(got[i], 0.0, "padding row {i} contaminated");
    }
}

#[test]
fn xla_transpose_artifact_swaps_triangles() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = XlaRuntime::open(dir).expect("open runtime");
    let a = test_matrix(180, 8, 3);
    let ell = a.to_ell(256, 8).unwrap();
    let mut rng = Rng::new(4);
    let x64: Vec<f64> = (0..256).map(|i| if i < 180 { rng.normal() } else { 0.0 }).collect();
    let x32: Vec<f32> = x64.iter().map(|&v| v as f32).collect();

    let got = rt.spmv("spmv_t_n256_w8", &ell, &x32).expect("xla spmv_t");

    let mut want = vec![0.0f64; 180];
    want.fill(0.0);
    a.spmv_t(&x64[..180], &mut want);
    for i in 0..180 {
        let diff = (got[i] as f64 - want[i]).abs();
        assert!(diff < 1e-3 * (1.0 + want[i].abs()), "row {i}");
    }
}

#[test]
fn xla_batched_spmv_matches_loop() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = XlaRuntime::open(dir).expect("open runtime");
    let a = test_matrix(100, 8, 5);
    let ell = a.to_ell(256, 8).unwrap();
    let mut rng = Rng::new(6);
    let batch = 8;
    let xs: Vec<f32> = (0..batch * 256)
        .map(|i| if i % 256 < 100 { rng.normal() as f32 } else { 0.0 })
        .collect();
    let ys = rt.spmv_batch("spmv_batch8_n256_w8", &ell, &xs, batch).expect("batched");
    assert_eq!(ys.len(), batch * 256);
    for b in 0..batch {
        let one = rt.spmv("spmv_n256_w8", &ell, &xs[b * 256..(b + 1) * 256]).unwrap();
        for i in 0..256 {
            assert!(
                (ys[b * 256 + i] - one[i]).abs() < 1e-4 * (1.0 + one[i].abs()),
                "batch {b} row {i}"
            );
        }
    }
}

#[test]
fn xla_cg_step_reduces_residual() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = XlaRuntime::open(dir).expect("open runtime");
    // Numerically symmetric SPD-ish matrix for CG.
    let mut rng = Rng::new(7);
    let coo = Coo::random_structurally_symmetric(150, 4, true, &mut rng);
    let a = Csrc::from_coo(&coo).unwrap();
    let ell = a.to_ell(256, 8).unwrap();
    let b32: Vec<f32> = (0..256).map(|i| if i < 150 { 1.0 } else { 0.0 }).collect();
    let x0 = vec![0.0f32; 256];
    let rs0: f32 = b32.iter().map(|v| v * v).sum();

    let args = vec![
        xla::Literal::vec1(&ell.ad),
        xla::Literal::vec1(&ell.al).reshape(&[256, 8]).unwrap(),
        xla::Literal::vec1(&ell.au).reshape(&[256, 8]).unwrap(),
        xla::Literal::vec1(&ell.ja).reshape(&[256, 8]).unwrap(),
        xla::Literal::vec1(&x0),
        xla::Literal::vec1(&b32),
        xla::Literal::vec1(&b32),
        xla::Literal::scalar(rs0),
    ];
    let out = rt.execute("cg_step_n256_w8", &args).expect("cg step");
    assert_eq!(out.len(), 4);
    let rs1 = out[3].to_vec::<f32>().unwrap()[0];
    assert!(rs1.is_finite());
    assert!(rs1 < rs0, "one CG step should reduce <r,r>: {rs1} vs {rs0}");
}

#[test]
fn xla_gradient_artifact_is_symmetrized_product() {
    // grad ½xᵀAx = ½(A+Aᵀ)x — the custom-VJP artifact exercising the
    // free-transpose path through jax.grad, executed from rust.
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = XlaRuntime::open(dir).expect("open runtime");
    let a = test_matrix(120, 8, 21);
    let ell = a.to_ell(256, 8).unwrap();
    let mut rng = Rng::new(22);
    let x64: Vec<f64> = (0..256).map(|i| if i < 120 { rng.normal() } else { 0.0 }).collect();
    let x32: Vec<f32> = x64.iter().map(|&v| v as f32).collect();
    let args = vec![
        xla::Literal::vec1(&ell.ad),
        xla::Literal::vec1(&ell.al).reshape(&[256, 8]).unwrap(),
        xla::Literal::vec1(&ell.au).reshape(&[256, 8]).unwrap(),
        xla::Literal::vec1(&ell.ja).reshape(&[256, 8]).unwrap(),
        xla::Literal::vec1(&x32),
    ];
    let out = rt.execute("grad_quadform_n256_w8", &args).expect("grad artifact");
    let g = out[0].to_vec::<f32>().unwrap();
    // Native check: ½(Ax + Aᵀx).
    let (mut ax, mut atx) = (vec![0.0f64; 120], vec![0.0f64; 120]);
    a.spmv_into_zeroed(&x64[..120], &mut ax);
    a.spmv_t(&x64[..120], &mut atx);
    for i in 0..120 {
        let want = 0.5 * (ax[i] + atx[i]);
        assert!(
            (g[i] as f64 - want).abs() < 1e-3 * (1.0 + want.abs()),
            "row {i}: {} vs {want}",
            g[i]
        );
    }
}
