//! End-to-end integration: FEM assembly → CSRC → parallel engines →
//! solver → coordinator → figure harness, all composed as a downstream
//! user would.

use csrc_spmv::coordinator::{MatvecService, ServiceConfig};
use csrc_spmv::gen;
use csrc_spmv::harness::{figures, smoke_suite, Report};
use csrc_spmv::parallel::{build_engine, build_engine_auto, AccumMethod, EngineKind};
use csrc_spmv::plan::PlanBuilder;
use csrc_spmv::solver::{self, Jacobi, ParallelLinOp};
use csrc_spmv::sparse::{mmio, Coo, Csrc, CsrcRect, LinOp, SpmvKernel};
use csrc_spmv::util::Rng;
use std::sync::Arc;

#[test]
fn fem_to_solver_pipeline() {
    // Assemble, compress, plan, solve with the parallel engine, verify.
    let coo = gen::poisson_3d_hex(12, 0.0, 3);
    let a = Arc::new(Csrc::from_coo(&coo).unwrap());
    let n = a.n;
    assert_eq!(n, 13 * 13 * 13);
    let mut rng = Rng::new(1);
    let xstar: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let mut b = vec![0.0; n];
    a.apply(&xstar, &mut b);
    // The plan/executor path: analysis once, executor borrows it.
    let kernel: Arc<dyn SpmvKernel> = a.clone();
    let plan = Arc::new(
        PlanBuilder::for_kind(3, EngineKind::LocalBuffers(AccumMethod::Effective))
            .build(kernel.as_ref()),
    );
    plan.validate(kernel.as_ref()).unwrap();
    let mut engine = build_engine(EngineKind::LocalBuffers(AccumMethod::Effective), kernel, plan);
    let jac = Jacobi::new(a.as_ref()).expect("CSRC exposes its diagonal");
    let op = ParallelLinOp::new(n, engine.as_mut());
    let r = solver::cg(&op, &b, Some(&jac), 1e-11, 3000);
    assert!(r.converged, "residual {}", r.residual);
    for (got, want) in r.x.iter().zip(&xstar) {
        assert!((got - want).abs() < 1e-6);
    }
}

#[test]
fn native_engines_agree_with_ell_reference() {
    // The rust-side ELL reference (same convention as the Pallas kernel)
    // agrees with the parallel engines — no artifacts needed, so this
    // runs without the `xla` feature.
    let mut rng = Rng::new(8);
    let coo = Coo::random_structurally_symmetric(150, 4, false, &mut rng);
    let a = Arc::new(Csrc::from_coo(&coo).unwrap());
    let w = a.max_row_width().max(1);
    let ell = a.to_ell(150, w).unwrap();
    let mut rng = Rng::new(9);
    let x64: Vec<f64> = (0..150).map(|_| rng.normal()).collect();
    let x32: Vec<f32> = x64.iter().map(|&v| v as f32).collect();
    let yref = ell.spmv_ref(&x32);
    let mut engine =
        build_engine_auto(EngineKind::LocalBuffers(AccumMethod::Effective), a.clone(), 3);
    let mut y = vec![0.0; 150];
    engine.spmv(&x64, &mut y);
    for i in 0..150 {
        assert!((yref[i] as f64 - y[i]).abs() < 1e-3 * (1.0 + y[i].abs()), "row {i}");
    }
}

#[test]
fn overlapping_decomposition_served_by_coordinator() {
    // Build a global FEM matrix, decompose it, serve the square parts
    // through the matvec service, scatter-gather back, compare to global.
    let global_coo = gen::poisson_2d_quad(20, 0.3, 5);
    let global = csrc_spmv::sparse::Csr::from_coo(&global_coo);
    let n = global.nrows;
    let x: Vec<f64> = (0..n).map(|i| (i as f64 * 0.1).sin()).collect();
    let mut want = vec![0.0; n];
    global.spmv(&x, &mut want);
    let got = gen::decomp::verify_overlapping_spmv(&global, 4, &x);
    for (g, w) in got.iter().zip(&want) {
        assert!((g - w).abs() < 1e-10);
    }
    // Also serve the locals' square parts via the coordinator.
    let svc = MatvecService::start(ServiceConfig::default());
    for s in 0..4 {
        let local = gen::overlapping_local(&global, 4, s);
        let rect = CsrcRect::from_coo(&local).unwrap();
        svc.register(&format!("sub{s}"), Arc::new(rect.square));
    }
    for s in 0..4 {
        let rows = gen::decomp::slab(n, 4, s);
        let xl: Vec<f64> = rows.clone().map(|i| x[i]).collect();
        let y = svc.call(&format!("sub{s}"), xl).unwrap();
        assert_eq!(y.len(), rows.len());
    }
    svc.shutdown();
}

#[test]
fn mmio_roundtrip_preserves_products() {
    let dir = std::env::temp_dir().join("csrc_e2e");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("fem.mtx");
    let coo = gen::poisson_2d_tri(10, 0.4, 9);
    mmio::write_matrix_market(&path, &coo, "e2e").unwrap();
    let back = mmio::read_matrix_market(&path).unwrap();
    let a1 = Csrc::from_coo(&coo).unwrap();
    let a2 = Csrc::from_coo(&back).unwrap();
    let x: Vec<f64> = (0..a1.n).map(|i| i as f64 * 0.01).collect();
    let (mut y1, mut y2) = (vec![0.0; a1.n], vec![0.0; a1.n]);
    a1.apply(&x, &mut y1);
    a2.apply(&x, &mut y2);
    for (p, q) in y1.iter().zip(&y2) {
        assert!((p - q).abs() < 1e-12);
    }
}

#[test]
fn figure_harness_writes_reports() {
    let dir = std::env::temp_dir().join("csrc_e2e_results");
    let _ = std::fs::remove_dir_all(&dir);
    let report = Report::new(Some(&dir)).unwrap();
    // Two cheap figures over the two smallest entries.
    let entries: Vec<_> = smoke_suite().into_iter().take(2).collect();
    report
        .table(
            "table1",
            "t1",
            &["matrix", "sym", "n", "nnz", "nnz/n", "ws"],
            &figures::table1(&entries),
        )
        .unwrap();
    report
        .table("fig4", "f4", &["m", "a", "b", "c", "d"], &figures::fig4(&entries))
        .unwrap();
    assert!(dir.join("table1.csv").exists());
    assert!(dir.join("fig4.md").exists());
    let csv = std::fs::read_to_string(dir.join("table1.csv")).unwrap();
    assert_eq!(csv.lines().count(), entries.len() + 1);
}

#[test]
fn autotuner_resolves_and_persists_across_instances() {
    // FEM assembly → full plan → measured tuning → winning engine
    // executes correctly → decision survives on disk, so a second cache
    // instance (a "restarted service") resolves with zero new trials.
    use csrc_spmv::tuner::{self, DecisionCache, TrialBudget};
    let coo = gen::poisson_2d_quad(20, 0.2, 5);
    let a = Arc::new(Csrc::from_coo(&coo).unwrap());
    let n = a.n;
    let kernel: Arc<dyn SpmvKernel> = a.clone();
    let plan = Arc::new(PlanBuilder::all(2).build(kernel.as_ref()));
    let dir = std::env::temp_dir().join(format!("csrc_e2e_tuner_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let path = dir.join("decisions.json");
    let cache = DecisionCache::open(&path);
    let (d, hit) = tuner::resolve(
        &kernel,
        &plan,
        &TrialBudget::smoke(),
        &cache,
        csrc_spmv::reorder::ReorderPolicy::Never,
    );
    assert!(!hit && d.measured);
    assert!(!d.trials.is_empty());
    // The winning engine really computes A·x.
    let x: Vec<f64> = (0..n).map(|i| (i as f64 * 0.01).sin()).collect();
    let mut want = vec![0.0; n];
    a.spmv_into_zeroed(&x, &mut want);
    let mut engine = build_engine(d.kind, kernel.clone(), plan.clone());
    let mut y = vec![f64::NAN; n];
    engine.spmv(&x, &mut y);
    for (g, w) in y.iter().zip(&want) {
        assert!((g - w).abs() < 1e-10 * (1.0 + w.abs()));
    }
    // Fresh cache instance on the same file: decision comes from disk.
    let cache2 = DecisionCache::open(&path);
    let (d2, hit2) = tuner::resolve(
        &kernel,
        &plan,
        &TrialBudget::zero(),
        &cache2,
        csrc_spmv::reorder::ReorderPolicy::Never,
    );
    assert!(hit2, "persisted decision must be found");
    assert_eq!(d2.kind, d.kind);
    assert!(d2.measured, "the persisted decision keeps its measured trials");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn transpose_consistency_across_stack() {
    // CSRC free transpose == CSR transpose == dense transpose, and BiCG
    // (which uses both A and Aᵀ) converges on the same operator.
    let mut rng = Rng::new(33);
    let coo = Coo::random_structurally_symmetric(60, 4, false, &mut rng);
    let a = Csrc::from_coo(&coo).unwrap();
    let csr = a.to_csr();
    let x: Vec<f64> = (0..60).map(|_| rng.normal()).collect();
    let (mut y1, mut y2) = (vec![0.0; 60], vec![0.0; 60]);
    a.apply_t(&x, &mut y1).unwrap();
    csr.apply_t(&x, &mut y2).unwrap();
    for (p, q) in y1.iter().zip(&y2) {
        assert!((p - q).abs() < 1e-11);
    }
    let b: Vec<f64> = (0..60).map(|_| rng.normal()).collect();
    let r = solver::bicg(&a, &b, 1e-9, 2000).unwrap();
    assert!(r.converged);
}

#[test]
fn coordinator_survives_bad_and_good_interleaved() {
    // Failure injection: unknown matrices and wrong-length vectors mixed
    // into a healthy stream must fail their own requests only.
    use csrc_spmv::coordinator::{MatvecService, ServiceConfig};
    let svc = MatvecService::start(ServiceConfig::default());
    let a = {
        let mut rng = Rng::new(99);
        Arc::new(Csrc::from_coo(&Coo::random_structurally_symmetric(40, 3, false, &mut rng)).unwrap())
    };
    svc.register("ok", a.clone());
    let mut good = 0;
    let mut bad = 0;
    let mut handles = Vec::new();
    for i in 0..30 {
        match i % 3 {
            0 => handles.push(("good", svc.submit("ok", vec![1.0; 40]))),
            1 => handles.push(("ghost", svc.submit("missing", vec![1.0; 40]))),
            _ => handles.push(("short", svc.submit("ok", vec![1.0; 7]))),
        }
    }
    for (kind, h) in handles {
        match h.recv().unwrap() {
            Ok(y) => {
                assert_eq!(kind, "good");
                assert_eq!(y.len(), 40);
                good += 1;
            }
            Err(e) => {
                assert_ne!(kind, "good", "good request failed: {e}");
                bad += 1;
            }
        }
    }
    assert_eq!(good, 10);
    assert_eq!(bad, 20);
    let s = svc.stats();
    assert_eq!(s.completed, 10);
    assert_eq!(s.failed, 20);
    svc.shutdown();
}

#[test]
fn rcm_improves_effective_ranges() {
    // Reordering shrinks the local-buffers effective ranges — the
    // structural reason reordered matrices parallelize better (§4.2).
    use csrc_spmv::graph::{permute, reverse_cuthill_mckee};
    use csrc_spmv::partition;
    let mut rng = Rng::new(44);
    let band = Csrc::from_coo(&Coo::banded(400, 2, true, &mut rng)).unwrap();
    let shuffled = permute(&band, &rng.permutation(400));
    let restored = permute(&shuffled, &reverse_cuthill_mckee(&shuffled));
    let span = |m: &Csrc| -> usize {
        let part = partition::nnz_balanced(m, 4);
        (0..4)
            .map(|t| {
                let er = partition::effective_range(m, part.block(t));
                er.end - er.start
            })
            .sum()
    };
    assert!(
        span(&restored) < span(&shuffled) / 2,
        "RCM should shrink effective ranges: {} vs {}",
        span(&restored),
        span(&shuffled)
    );
}

#[test]
fn property_reordered_engines_match_unpermuted_oracle() {
    // ISSUE 4 satellite: for random structurally-symmetric AND banded
    // patterns, every engine × every accumulation method executed on the
    // RCM-permuted matrix must — after un-permutation — match the
    // *unpermuted* sequential oracle. Seeds varied by propcheck.
    use csrc_spmv::reorder::{rcm, ReorderedEngine};
    use csrc_spmv::util::propcheck;
    propcheck::check(6, |rng| {
        let n = 20 + rng.below(100);
        let coo = if rng.below(2) == 0 {
            Coo::random_structurally_symmetric(n, 1 + rng.below(5), false, rng)
        } else {
            Coo::banded(n, 1 + rng.below(4), false, rng)
        };
        let a = Arc::new(Csrc::from_coo(&coo).map_err(|e| e.to_string())?);
        let perm = Arc::new(rcm(a.as_ref()));
        let permuted: Arc<Csrc> = Arc::new(a.permuted(&perm));
        let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mut want = vec![0.0; n];
        a.spmv_into_zeroed(&x, &mut want); // unpermuted oracle
        let p = 2 + rng.below(4);
        let plan = Arc::new(PlanBuilder::all(p).build(permuted.as_ref()));
        for kind in EngineKind::all() {
            let inner = build_engine(kind, permuted.clone(), plan.clone());
            let mut engine = ReorderedEngine::new(inner, perm.clone());
            let mut y = vec![f64::NAN; n];
            engine.spmv(&x, &mut y);
            propcheck::assert_close(&y, &want, 1e-10, 1e-10)
                .map_err(|e| format!("{} p={p}: {e}", kind.label()))?;
        }
        Ok(())
    });
}

#[test]
fn reordered_solver_pipeline_end_to_end() {
    // FEM matrix → RCM → permuted CSRC + windowed parallel engine →
    // ReorderedLinOp → Jacobi-CG converges to the solution of the
    // *original* system.
    use csrc_spmv::reorder::{rcm, ReorderedLinOp};
    use csrc_spmv::solver::EngineLinOp;
    let coo = gen::poisson_2d_quad(16, 0.0, 11);
    let a = Arc::new(Csrc::from_coo(&coo).unwrap());
    let n = a.n;
    let perm = rcm(a.as_ref());
    let permuted = Arc::new(a.permuted(&perm));
    let kernel: Arc<dyn SpmvKernel> = permuted.clone();
    let plan = Arc::new(
        PlanBuilder::for_kind(3, EngineKind::LocalBuffers(AccumMethod::Interval))
            .build(kernel.as_ref()),
    );
    let inner = EngineLinOp::new(
        EngineKind::LocalBuffers(AccumMethod::Interval),
        kernel.clone(),
        plan,
    );
    let op = ReorderedLinOp::new(inner, perm);
    let mut rng = Rng::new(45);
    let xstar: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let mut b = vec![0.0; n];
    a.apply(&xstar, &mut b);
    let jac = Jacobi::new(a.as_ref()).expect("diagonal available");
    let r = solver::cg(&op, &b, Some(&jac), 1e-11, 5000);
    assert!(r.converged, "residual {}", r.residual);
    for (got, want) in r.x.iter().zip(&xstar) {
        assert!((got - want).abs() < 1e-6);
    }
}
