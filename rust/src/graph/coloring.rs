//! Greedy sequential coloring (Coleman & Moré style, the paper's [9]) of
//! the conflict graph, producing the conflict-free row classes the
//! colorful engine executes in parallel, plus the paper's §5 future-work
//! idea — stride-capped colors — as an ablation.

use super::ConflictGraph;
use crate::sparse::SpmvKernel;

/// Vertex visit order for the greedy sweep.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Ordering {
    /// Row order 0..n (what a standard sequential coloring does).
    Natural,
    /// Largest combined-degree first (classic heuristic, fewer colors).
    LargestDegreeFirst,
}

/// Result of a coloring: `color[v]` per vertex plus the classes, each a
/// sorted list of member rows.
#[derive(Clone, Debug)]
pub struct ColorClasses {
    pub color: Vec<u32>,
    pub classes: Vec<Vec<u32>>,
}

impl ColorClasses {
    pub fn num_colors(&self) -> usize {
        self.classes.len()
    }

    /// Per color, per thread: the slice [lo, hi) of the class row list
    /// each thread processes, split by the kernel's per-row work (the
    /// nnz-balanced intra-class split the colorful executor consumes).
    /// Pure analysis — computed once per plan, reused by every product.
    pub fn class_shares(&self, a: &dyn SpmvKernel, p: usize) -> Vec<Vec<(usize, usize)>> {
        self.classes.iter().map(|class| split_class_by_work(a, class, p)).collect()
    }

    /// Validate: no two rows in a class may conflict (direct or indirect).
    pub fn validate(&self, g: &ConflictGraph) -> Result<(), String> {
        for (c, class) in self.classes.iter().enumerate() {
            for (p, &u) in class.iter().enumerate() {
                for &v in &class[p + 1..] {
                    if g.conflicts(u as usize, v as usize) {
                        return Err(format!("rows {u} and {v} conflict in color {c}"));
                    }
                }
            }
        }
        // Every vertex in exactly one class, color[] consistent.
        let mut seen = vec![false; g.n];
        for (c, class) in self.classes.iter().enumerate() {
            for &u in class {
                if seen[u as usize] {
                    return Err(format!("row {u} in two classes"));
                }
                seen[u as usize] = true;
                if self.color[u as usize] != c as u32 {
                    return Err(format!("color[{u}] inconsistent"));
                }
            }
        }
        if let Some(u) = seen.iter().position(|&s| !s) {
            return Err(format!("row {u} uncolored"));
        }
        Ok(())
    }
}

/// Split a class's row list into p contiguous chunks balanced by the
/// kernel's per-row work (for CSRC: 1 + 2·row_len).
fn split_class_by_work(a: &dyn SpmvKernel, class: &[u32], p: usize) -> Vec<(usize, usize)> {
    let work: Vec<usize> = class.iter().map(|&i| a.row_work(i as usize)).collect();
    let total: usize = work.iter().sum();
    let mut out = Vec::with_capacity(p);
    let mut pos = 0usize;
    let mut consumed = 0usize;
    for t in 0..p {
        let start = pos;
        if t + 1 == p {
            pos = class.len();
        } else {
            let target = (total - consumed) as f64 / (p - t) as f64;
            let mut blk = 0usize;
            while pos < class.len() {
                let w = work[pos];
                if blk > 0 && (blk + w) as f64 - target > target - blk as f64 {
                    break;
                }
                blk += w;
                pos += 1;
            }
            consumed += blk;
        }
        out.push((start, pos));
    }
    out
}

fn build_classes(color: Vec<u32>) -> ColorClasses {
    let k = color.iter().map(|&c| c + 1).max().unwrap_or(0) as usize;
    let mut classes = vec![Vec::new(); k];
    for (u, &c) in color.iter().enumerate() {
        classes[c as usize].push(u as u32);
    }
    ColorClasses { color, classes }
}

/// First-fit greedy coloring of the combined conflict graph.
pub fn greedy_coloring(g: &ConflictGraph, order: Ordering) -> ColorClasses {
    let n = g.n;
    let visit: Vec<usize> = match order {
        Ordering::Natural => (0..n).collect(),
        Ordering::LargestDegreeFirst => {
            let mut v: Vec<usize> = (0..n).collect();
            v.sort_by_key(|&u| std::cmp::Reverse(g.neighbors(u).len()));
            v
        }
    };
    let mut color = vec![u32::MAX; n];
    let mut forbidden: Vec<u32> = vec![u32::MAX; n.max(1)]; // color -> stamp
    for (stamp, &u) in visit.iter().enumerate() {
        for &v in g.neighbors(u) {
            let cv = color[v as usize];
            if cv != u32::MAX {
                forbidden[cv as usize] = stamp as u32;
            }
        }
        let mut c = 0u32;
        while forbidden[c as usize] == stamp as u32 {
            c += 1;
        }
        color[u] = c;
    }
    build_classes(color)
}

/// §5 future-work ablation: additionally require that consecutive members
/// of a color class are at most `max_stride` rows apart, bounding the
/// stride of the irregular y/x accesses inside a class at the cost of
/// more colors.
pub fn stride_capped_coloring(g: &ConflictGraph, max_stride: usize) -> ColorClasses {
    let n = g.n;
    let mut color = vec![u32::MAX; n];
    let mut forbidden: Vec<u32> = vec![u32::MAX; n.max(1)];
    let mut last_row: Vec<i64> = Vec::new(); // per color, last row added
    for u in 0..n {
        for &v in g.neighbors(u) {
            let cv = color[v as usize];
            if cv != u32::MAX {
                forbidden[cv as usize] = u as u32;
            }
        }
        let mut c = 0u32;
        loop {
            let used = (c as usize) < last_row.len();
            let conflict = used && forbidden[c as usize] == u as u32;
            let stride_ok =
                !used || (u as i64 - last_row[c as usize]) <= max_stride as i64;
            if !conflict && stride_ok {
                break;
            }
            c += 1;
        }
        if (c as usize) == last_row.len() {
            last_row.push(u as i64);
        } else {
            last_row[c as usize] = u as i64;
        }
        color[u] = c;
    }
    build_classes(color)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::{Coo, Csrc};
    use crate::util::{propcheck, Rng};

    fn random_graph(n: usize, npr: usize, rng: &mut Rng) -> (Csrc, ConflictGraph) {
        let coo = Coo::random_structurally_symmetric(n, npr, false, rng);
        let a = Csrc::from_coo(&coo).unwrap();
        let g = ConflictGraph::build(&a);
        (a, g)
    }

    #[test]
    fn coloring_valid_on_random_graphs() {
        let mut rng = Rng::new(30);
        for _ in 0..5 {
            let (_a, g) = random_graph(40, 3, &mut rng);
            for order in [Ordering::Natural, Ordering::LargestDegreeFirst] {
                let c = greedy_coloring(&g, order);
                c.validate(&g).unwrap();
                assert!(c.num_colors() <= g.max_degree() + 1, "greedy bound violated");
            }
        }
    }

    #[test]
    fn diagonal_matrix_single_color() {
        let mut coo = Coo::new(6, 6);
        for i in 0..6 {
            coo.push(i, i, 1.0);
        }
        let g = ConflictGraph::build(&Csrc::from_coo(&coo).unwrap());
        let c = greedy_coloring(&g, Ordering::Natural);
        assert_eq!(c.num_colors(), 1);
    }

    #[test]
    fn banded_matrix_needs_few_colors() {
        // hbw=1 tridiagonal: conflict graph is (distance<=2) path graph —
        // 3-colorable. The paper's torsion1/minsurfo/dixmaanl analogues.
        let mut rng = Rng::new(31);
        let coo = Coo::banded(50, 1, true, &mut rng);
        let g = ConflictGraph::build(&Csrc::from_coo(&coo).unwrap());
        let c = greedy_coloring(&g, Ordering::Natural);
        c.validate(&g).unwrap();
        assert!(c.num_colors() <= 3, "tridiagonal needed {} colors", c.num_colors());
    }

    #[test]
    fn stride_cap_bounds_intra_class_stride() {
        let mut rng = Rng::new(32);
        let (_a, g) = random_graph(60, 2, &mut rng);
        let cap = 10;
        let c = stride_capped_coloring(&g, cap);
        c.validate(&g).unwrap();
        for class in &c.classes {
            for w in class.windows(2) {
                assert!((w[1] - w[0]) as usize <= cap, "stride violated: {w:?}");
            }
        }
        // And it should never use fewer colors than the uncapped greedy.
        let free = greedy_coloring(&g, Ordering::Natural);
        assert!(c.num_colors() >= free.num_colors());
    }

    #[test]
    fn property_coloring_always_valid() {
        propcheck::check(12, |rng| {
            let n = 5 + rng.below(50);
            let npr = 1 + rng.below(5);
            let coo = Coo::random_structurally_symmetric(n, npr, false, rng);
            let a = Csrc::from_coo(&coo).map_err(|e| e.to_string())?;
            let g = ConflictGraph::build(&a);
            for order in [Ordering::Natural, Ordering::LargestDegreeFirst] {
                greedy_coloring(&g, order).validate(&g)?;
            }
            stride_capped_coloring(&g, 1 + rng.below(n)).validate(&g)?;
            Ok(())
        });
    }
}
