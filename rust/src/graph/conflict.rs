//! Conflict graph of a row-sweep kernel (§3.2, Fig. 3c).
//!
//! Vertices are rows. Two kinds of conflict:
//!
//! * **direct** — thread owning row j (j > i) writes y(i) because
//!   a_ji ≠ 0: the direct edges are exactly the kernel's scatter pairs
//!   {i, target} (for CSRC, the symmetric pattern adjacency {i, ja(k)}).
//! * **indirect** — rows u and v (neither adjacent) both scatter into some
//!   shared y position: their neighbourhoods in the direct graph
//!   intersect. Computed with the marker-array two-hop sweep over the
//!   induced subgraph G'[A], as the paper describes.
//!
//! Built from any [`SpmvKernel`] — scatter-free formats (CSR, BCSR)
//! yield the empty graph, so *every* row shares one color and the
//! colorful executor degenerates to a plain row split.
//!
//! The paper's Fig. 1 example yields 12 direct and 7 indirect conflicts —
//! reproduced in the tests below.

use crate::sparse::SpmvKernel;

#[derive(Clone, Debug)]
pub struct ConflictGraph {
    pub n: usize,
    /// CSR-style adjacency of the *combined* conflict graph (direct ∪
    /// indirect), symmetric, no self-loops.
    pub xadj: Vec<u32>,
    pub adj: Vec<u32>,
    /// Same for the direct-only subgraph G'[A].
    pub xadj_direct: Vec<u32>,
    pub adj_direct: Vec<u32>,
}

impl ConflictGraph {
    /// Build from a kernel's scatter pattern.
    pub fn build(a: &dyn SpmvKernel) -> ConflictGraph {
        let n = a.dim();
        // --- direct graph: symmetric closure of the scatter pairs.
        let mut deg = vec![0u32; n];
        for i in 0..n {
            a.scatter_targets(i, &mut |j| {
                deg[i] += 1;
                deg[j] += 1;
            });
        }
        let mut xadj_direct = vec![0u32; n + 1];
        for i in 0..n {
            xadj_direct[i + 1] = xadj_direct[i] + deg[i];
        }
        let mut cursor: Vec<u32> = xadj_direct[..n].to_vec();
        let mut adj_direct = vec![0u32; xadj_direct[n] as usize];
        for i in 0..n {
            a.scatter_targets(i, &mut |j| {
                adj_direct[cursor[i] as usize] = j as u32;
                cursor[i] += 1;
                adj_direct[cursor[j] as usize] = i as u32;
                cursor[j] += 1;
            });
        }
        for i in 0..n {
            adj_direct[xadj_direct[i] as usize..xadj_direct[i + 1] as usize].sort_unstable();
        }

        // --- combined graph: direct ∪ two-hop (indirect), marker sweep.
        let mut xadj = vec![0u32; n + 1];
        let mut adj: Vec<u32> = Vec::with_capacity(adj_direct.len() * 2);
        let mut marker = vec![u32::MAX; n];
        for u in 0..n {
            marker[u] = u as u32; // exclude self
            let start = adj.len();
            for &v in &adj_direct[xadj_direct[u] as usize..xadj_direct[u + 1] as usize] {
                if marker[v as usize] != u as u32 {
                    marker[v as usize] = u as u32;
                    adj.push(v);
                }
                // two-hop: neighbours of v share a scatter target with u.
                for &w in
                    &adj_direct[xadj_direct[v as usize] as usize..xadj_direct[v as usize + 1] as usize]
                {
                    if marker[w as usize] != u as u32 {
                        marker[w as usize] = u as u32;
                        adj.push(w);
                    }
                }
            }
            adj[start..].sort_unstable();
            xadj[u + 1] = adj.len() as u32;
        }
        ConflictGraph { n, xadj, adj, xadj_direct, adj_direct }
    }

    #[inline]
    pub fn neighbors(&self, u: usize) -> &[u32] {
        &self.adj[self.xadj[u] as usize..self.xadj[u + 1] as usize]
    }

    #[inline]
    pub fn direct_neighbors(&self, u: usize) -> &[u32] {
        &self.adj_direct[self.xadj_direct[u] as usize..self.xadj_direct[u + 1] as usize]
    }

    /// Number of direct conflict edges (each counted once).
    pub fn direct_edges(&self) -> usize {
        self.adj_direct.len() / 2
    }

    /// Number of indirect-only edges (in combined but not direct).
    pub fn indirect_edges(&self) -> usize {
        (self.adj.len() - self.adj_direct.len()) / 2
    }

    pub fn max_degree(&self) -> usize {
        (0..self.n).map(|u| self.neighbors(u).len()).max().unwrap_or(0)
    }

    /// Do u and v conflict (directly or indirectly)?
    pub fn conflicts(&self, u: usize, v: usize) -> bool {
        self.neighbors(u).binary_search(&(v as u32)).is_ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::{Coo, Csrc};
    use crate::util::{propcheck, Rng};

    /// The paper's Fig. 1 pattern (9×9, 33 nnz).
    fn fig1_csrc() -> Csrc {
        let mut coo = Coo::new(9, 9);
        for i in 0..9 {
            coo.push(i, i, 1.0);
        }
        let lower = [
            (1, 0), (3, 1), (4, 0), (4, 3), (5, 2), (6, 0), (6, 4),
            (7, 3), (7, 5), (8, 2), (8, 6), (8, 7),
        ];
        for &(i, j) in &lower {
            coo.push(i, j, 1.0);
            coo.push(j, i, 1.0);
        }
        coo.compact();
        Csrc::from_coo(&coo).unwrap()
    }

    #[test]
    fn fig3c_direct_and_indirect_counts() {
        // The paper's Fig. 1 matrix has 12 direct conflicts ((33-9)/2
        // off-diagonal pairs) and reports 7 indirect ones. The exact
        // off-diagonal placement is only available as a bitmap figure, so
        // our stand-in pattern reproduces the direct count exactly (it is
        // determined by n and nnz) and pins the indirect count computed
        // for *this* pattern (14) as a regression value.
        let g = ConflictGraph::build(&fig1_csrc());
        assert_eq!(g.direct_edges(), 12);
        assert_eq!(g.indirect_edges(), 14);
    }

    #[test]
    fn adjacency_is_symmetric_and_loop_free() {
        let g = ConflictGraph::build(&fig1_csrc());
        for u in 0..g.n {
            for &v in g.neighbors(u) {
                assert_ne!(u as u32, v, "self loop at {u}");
                assert!(g.conflicts(v as usize, u), "asymmetric edge {u}-{v}");
            }
        }
    }

    #[test]
    fn direct_subset_of_combined() {
        let g = ConflictGraph::build(&fig1_csrc());
        for u in 0..g.n {
            for &v in g.direct_neighbors(u) {
                assert!(g.conflicts(u, v as usize));
            }
        }
    }

    #[test]
    fn indirect_edges_are_two_hops() {
        let g = ConflictGraph::build(&fig1_csrc());
        // (1,0) direct; 1-(0)-4: rows 1 and 4 share neighbour 0 => indirect.
        assert!(g.conflicts(1, 4));
        assert!(!g.direct_neighbors(1).contains(&4));
    }

    #[test]
    fn diagonal_matrix_has_no_conflicts() {
        let mut coo = Coo::new(5, 5);
        for i in 0..5 {
            coo.push(i, i, 2.0);
        }
        let g = ConflictGraph::build(&Csrc::from_coo(&coo).unwrap());
        assert_eq!(g.direct_edges(), 0);
        assert_eq!(g.indirect_edges(), 0);
    }

    #[test]
    fn property_combined_closed_under_shared_neighbor() {
        propcheck::check(10, |rng| {
            let n = 6 + rng.below(30);
            let coo = Coo::random_structurally_symmetric(n, 3, false, rng);
            let a = Csrc::from_coo(&coo).map_err(|e| e.to_string())?;
            let g = ConflictGraph::build(&a);
            // For every pair of direct neighbours (v, w) of any u, v and w
            // must conflict in the combined graph.
            for u in 0..n {
                let nb = g.direct_neighbors(u);
                for (p, &v) in nb.iter().enumerate() {
                    for &w in &nb[p + 1..] {
                        if !g.conflicts(v as usize, w as usize) {
                            return Err(format!("{v} and {w} share {u} but no edge"));
                        }
                    }
                }
            }
            Ok(())
        });
    }
}
