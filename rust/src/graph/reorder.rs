//! Bandwidth-reducing reordering — reverse Cuthill–McKee.
//!
//! The paper's §1 lists reordering among the classical sequential SpMV
//! optimizations, and its §4.2 discussion ties performance to the band
//! structure ("the running time is influenced by the working set size and
//! the band structure"; cage15/F1 suffer from "the absence of a band
//! structure"). RCM is the standard remedy: it also *shrinks the
//! effective ranges* of the local-buffers method and the color count of
//! the colorful method — measured in the `ablations` bench.

use crate::reorder::Permutation;
use crate::sparse::Csrc;

/// Reverse Cuthill–McKee ordering of the symmetric pattern of `a`.
/// Returns `perm` with `perm[new] = old`.
///
/// Compatibility shim over [`crate::reorder::rcm`] — the full subsystem
/// (pseudo-peripheral seeds, [`Permutation`], permuted operators) lives
/// there; this keeps the original `Vec<usize>`-based call sites (and
/// their tests) exercising the same implementation.
pub fn reverse_cuthill_mckee(a: &Csrc) -> Vec<usize> {
    crate::reorder::rcm(a).as_new_to_old().to_vec()
}

/// Apply a permutation (`perm[new] = old`) symmetrically: B = P A Pᵀ.
/// Shim over [`Csrc::permuted`].
pub fn permute(a: &Csrc, perm: &[usize]) -> Csrc {
    let p = Permutation::from_new_to_old(perm.to_vec()).expect("perm must be a permutation");
    a.permuted(&p)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::{Coo, LinOp};
    use crate::util::{propcheck, Rng};

    fn random(n: usize, npr: usize, seed: u64) -> Csrc {
        let mut rng = Rng::new(seed);
        Csrc::from_coo(&Coo::random_structurally_symmetric(n, npr, false, &mut rng)).unwrap()
    }

    #[test]
    fn rcm_is_a_permutation() {
        let a = random(80, 4, 1);
        let p = reverse_cuthill_mckee(&a);
        let mut s = p.clone();
        s.sort_unstable();
        assert_eq!(s, (0..80).collect::<Vec<_>>());
    }

    #[test]
    fn rcm_reduces_bandwidth_of_shuffled_band_matrix() {
        // Start from a band matrix, shuffle it, RCM should mostly recover
        // a small bandwidth.
        let mut rng = Rng::new(2);
        let band = Csrc::from_coo(&Coo::banded(200, 2, true, &mut rng)).unwrap();
        let shuffle = rng.permutation(200);
        let shuffled = permute(&band, &shuffle);
        assert!(shuffled.half_bandwidth() > 20, "shuffle should destroy the band");
        let rcm = reverse_cuthill_mckee(&shuffled);
        let restored = permute(&shuffled, &rcm);
        assert!(
            restored.half_bandwidth() < shuffled.half_bandwidth() / 2,
            "RCM {} vs shuffled {}",
            restored.half_bandwidth(),
            shuffled.half_bandwidth()
        );
    }

    #[test]
    fn permute_preserves_spectrum_action() {
        // (P A Pᵀ)(P x) == P (A x).
        let a = random(50, 3, 3);
        let mut rng = Rng::new(4);
        let perm = rng.permutation(50);
        let b = permute(&a, &perm);
        let x: Vec<f64> = (0..50).map(|_| rng.normal()).collect();
        let mut ax = vec![0.0; 50];
        a.apply(&x, &mut ax);
        let mut inv = vec![0usize; 50];
        for (new, &old) in perm.iter().enumerate() {
            inv[old] = new;
        }
        let px: Vec<f64> = (0..50).map(|new| x[perm[new]]).collect();
        let mut bpx = vec![0.0; 50];
        b.apply(&px, &mut bpx);
        for new in 0..50 {
            assert!((bpx[new] - ax[perm[new]]).abs() < 1e-11, "row {new}");
        }
        let _ = inv;
    }

    #[test]
    fn property_rcm_never_increases_bandwidth_much() {
        propcheck::check(8, |rng| {
            let n = 20 + rng.below(80);
            let coo = Coo::banded(n, 1 + rng.below(3), false, rng);
            let a = Csrc::from_coo(&coo).map_err(|e| e.to_string())?;
            let p = reverse_cuthill_mckee(&a);
            let b = permute(&a, &p);
            // RCM on an already-banded matrix must stay within a small
            // constant of the original bandwidth.
            if b.half_bandwidth() > 4 * a.half_bandwidth().max(2) {
                return Err(format!("{} -> {}", a.half_bandwidth(), b.half_bandwidth()));
            }
            Ok(())
        });
    }
}
