//! Bandwidth-reducing reordering — reverse Cuthill–McKee.
//!
//! The paper's §1 lists reordering among the classical sequential SpMV
//! optimizations, and its §4.2 discussion ties performance to the band
//! structure ("the running time is influenced by the working set size and
//! the band structure"; cage15/F1 suffer from "the absence of a band
//! structure"). RCM is the standard remedy: it also *shrinks the
//! effective ranges* of the local-buffers method and the color count of
//! the colorful method — measured in the `ablations` bench.

use crate::sparse::{Coo, Csrc};

/// Reverse Cuthill–McKee ordering of the symmetric pattern of `a`.
/// Returns `perm` with `perm[new] = old`.
pub fn reverse_cuthill_mckee(a: &Csrc) -> Vec<usize> {
    let n = a.n;
    // Build symmetric adjacency (both triangles).
    let g = super::ConflictGraph::build(a);
    let mut order = Vec::with_capacity(n);
    let mut visited = vec![false; n];
    let mut frontier = std::collections::VecDeque::new();
    // Process every connected component; seed each from a minimum-degree
    // peripheral-ish vertex.
    loop {
        let seed = match (0..n).filter(|&v| !visited[v]).min_by_key(|&v| g.direct_neighbors(v).len())
        {
            Some(s) => s,
            None => break,
        };
        visited[seed] = true;
        frontier.push_back(seed);
        while let Some(v) = frontier.pop_front() {
            order.push(v);
            let mut nbrs: Vec<usize> = g
                .direct_neighbors(v)
                .iter()
                .map(|&u| u as usize)
                .filter(|&u| !visited[u])
                .collect();
            nbrs.sort_by_key(|&u| g.direct_neighbors(u).len());
            for u in nbrs {
                visited[u] = true;
                frontier.push_back(u);
            }
        }
    }
    order.reverse(); // the "reverse" in RCM
    order
}

/// Apply a permutation (`perm[new] = old`) symmetrically: B = P A Pᵀ.
pub fn permute(a: &Csrc, perm: &[usize]) -> Csrc {
    let n = a.n;
    assert_eq!(perm.len(), n);
    let mut inv = vec![0usize; n];
    for (new, &old) in perm.iter().enumerate() {
        inv[old] = new;
    }
    let csr = a.to_csr();
    let mut coo = Coo::with_capacity(n, n, a.nnz());
    for i in 0..n {
        for k in csr.row_range(i) {
            coo.push(inv[i], inv[csr.ja[k] as usize], csr.a[k]);
        }
    }
    coo.compact();
    Csrc::from_coo(&coo).expect("permutation preserves structural symmetry")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::LinOp;
    use crate::util::{propcheck, Rng};

    fn random(n: usize, npr: usize, seed: u64) -> Csrc {
        let mut rng = Rng::new(seed);
        Csrc::from_coo(&Coo::random_structurally_symmetric(n, npr, false, &mut rng)).unwrap()
    }

    #[test]
    fn rcm_is_a_permutation() {
        let a = random(80, 4, 1);
        let p = reverse_cuthill_mckee(&a);
        let mut s = p.clone();
        s.sort_unstable();
        assert_eq!(s, (0..80).collect::<Vec<_>>());
    }

    #[test]
    fn rcm_reduces_bandwidth_of_shuffled_band_matrix() {
        // Start from a band matrix, shuffle it, RCM should mostly recover
        // a small bandwidth.
        let mut rng = Rng::new(2);
        let band = Csrc::from_coo(&Coo::banded(200, 2, true, &mut rng)).unwrap();
        let shuffle = rng.permutation(200);
        let shuffled = permute(&band, &shuffle);
        assert!(shuffled.half_bandwidth() > 20, "shuffle should destroy the band");
        let rcm = reverse_cuthill_mckee(&shuffled);
        let restored = permute(&shuffled, &rcm);
        assert!(
            restored.half_bandwidth() < shuffled.half_bandwidth() / 2,
            "RCM {} vs shuffled {}",
            restored.half_bandwidth(),
            shuffled.half_bandwidth()
        );
    }

    #[test]
    fn permute_preserves_spectrum_action() {
        // (P A Pᵀ)(P x) == P (A x).
        let a = random(50, 3, 3);
        let mut rng = Rng::new(4);
        let perm = rng.permutation(50);
        let b = permute(&a, &perm);
        let x: Vec<f64> = (0..50).map(|_| rng.normal()).collect();
        let mut ax = vec![0.0; 50];
        a.apply(&x, &mut ax);
        let mut inv = vec![0usize; 50];
        for (new, &old) in perm.iter().enumerate() {
            inv[old] = new;
        }
        let px: Vec<f64> = (0..50).map(|new| x[perm[new]]).collect();
        let mut bpx = vec![0.0; 50];
        b.apply(&px, &mut bpx);
        for new in 0..50 {
            assert!((bpx[new] - ax[perm[new]]).abs() < 1e-11, "row {new}");
        }
        let _ = inv;
    }

    #[test]
    fn property_rcm_never_increases_bandwidth_much() {
        propcheck::check(8, |rng| {
            let n = 20 + rng.below(80);
            let coo = Coo::banded(n, 1 + rng.below(3), false, rng);
            let a = Csrc::from_coo(&coo).map_err(|e| e.to_string())?;
            let p = reverse_cuthill_mckee(&a);
            let b = permute(&a, &p);
            // RCM on an already-banded matrix must stay within a small
            // constant of the original bandwidth.
            if b.half_bandwidth() > 4 * a.half_bandwidth().max(2) {
                return Err(format!("{} -> {}", a.half_bandwidth(), b.half_bandwidth()));
            }
            Ok(())
        });
    }
}
