//! Conflict graphs and coloring for the *colorful* parallelization (§3.2).

pub mod coloring;
pub mod conflict;

pub use coloring::{greedy_coloring, stride_capped_coloring, ColorClasses, Ordering};
pub use conflict::ConflictGraph;

pub mod reorder;
pub use reorder::{permute, reverse_cuthill_mckee};
