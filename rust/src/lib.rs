//! # csrc-spmv
//!
//! Production-quality reproduction of *“Parallel structurally-symmetric
//! sparse matrix-vector products on multi-core processors”* (Batista,
//! Ainsworth Jr., Ribeiro, 2010) as a three-layer Rust + JAX + Pallas
//! stack:
//!
//! * **L3 (this crate)** — the CSRC storage format, the two parallel
//!   SpMV strategies (local buffers ×4 accumulation schemes, colorful)
//!   split into reusable *analysis* ([`plan::SpmvPlan`]) and
//!   format-generic *executors* ([`parallel`] over [`sparse::SpmvKernel`]),
//!   every substrate the evaluation needs (FEM generators, a multi-core
//!   machine simulator, iterative solvers, a matvec service coordinator
//!   that caches one plan per matrix across its workers), an autotuner
//!   ([`tuner`]) that resolves `EngineKind::Auto` per matrix through
//!   measured trials with a persistent decision cache, and the harness
//!   that regenerates each of the paper's tables/figures.
//! * **L2/L1 (python/, build-time only)** — the JAX model graphs and the
//!   Pallas CSRC-ELL kernel, AOT-lowered to HLO text artifacts executed
//!   from [`runtime`] via PJRT. Python is never on the request path.
//!
//! Quick start (`no_run` only because doctest binaries don't get the
//! xla_extension rpath; `cargo run --example quickstart` runs the same):
//!
//! ```no_run
//! use csrc_spmv::sparse::{Coo, Csrc};
//! use csrc_spmv::util::Rng;
//!
//! let mut rng = Rng::new(1);
//! let coo = Coo::random_structurally_symmetric(100, 4, false, &mut rng);
//! let a = Csrc::from_coo(&coo).unwrap();
//! let x = vec![1.0; 100];
//! let mut y = vec![0.0; 100];
//! a.spmv_into_zeroed(&x, &mut y);   // sequential, Fig. 2(a)
//! ```
//!
//! See `DESIGN.md` for the full system inventory (including the
//! plan/executor architecture and the layer map) and `EXPERIMENTS.md`
//! for paper-vs-measured results.

// Numeric sweeps index by row/column on purpose; builders construct
// their value then configure it. Keep clippy's style nits out of the
// way of the `-D warnings` CI gate.
#![allow(
    clippy::needless_range_loop,
    clippy::new_without_default,
    clippy::field_reassign_with_default,
    clippy::manual_memcpy,
    clippy::too_many_arguments,
    clippy::type_complexity
)]

pub mod coordinator;
pub mod faults;
pub mod gen;
pub mod graph;
pub mod harness;
pub mod metrics;
pub mod obs;
pub mod parallel;
pub mod partition;
pub mod plan;
pub mod reorder;
pub mod runtime;
pub mod simulator;
pub mod solver;
pub mod sparse;
pub mod tuner;
pub mod util;
