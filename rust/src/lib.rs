//! # csrc-spmv
//!
//! Production-quality reproduction of *“Parallel structurally-symmetric
//! sparse matrix-vector products on multi-core processors”* (Batista,
//! Ainsworth Jr., Ribeiro, 2010) as a three-layer Rust + JAX + Pallas
//! stack:
//!
//! * **L3 (this crate)** — the CSRC storage format, the two parallel
//!   SpMV strategies (local buffers ×4 accumulation schemes, colorful),
//!   every substrate the evaluation needs (FEM generators, a multi-core
//!   machine simulator, iterative solvers, a matvec service coordinator)
//!   and the harness that regenerates each of the paper's tables/figures.
//! * **L2/L1 (python/, build-time only)** — the JAX model graphs and the
//!   Pallas CSRC-ELL kernel, AOT-lowered to HLO text artifacts executed
//!   from [`runtime`] via PJRT. Python is never on the request path.
//!
//! Quick start (`no_run` only because doctest binaries don't get the
//! xla_extension rpath; `cargo run --example quickstart` runs the same):
//!
//! ```no_run
//! use csrc_spmv::sparse::{Coo, Csrc};
//! use csrc_spmv::util::Rng;
//!
//! let mut rng = Rng::new(1);
//! let coo = Coo::random_structurally_symmetric(100, 4, false, &mut rng);
//! let a = Csrc::from_coo(&coo).unwrap();
//! let x = vec![1.0; 100];
//! let mut y = vec![0.0; 100];
//! a.spmv_into_zeroed(&x, &mut y);   // sequential, Fig. 2(a)
//! ```
//!
//! See `DESIGN.md` for the full system inventory and `EXPERIMENTS.md` for
//! paper-vs-measured results.

pub mod coordinator;
pub mod gen;
pub mod graph;
pub mod harness;
pub mod metrics;
pub mod parallel;
pub mod partition;
pub mod runtime;
pub mod simulator;
pub mod solver;
pub mod sparse;
pub mod util;
