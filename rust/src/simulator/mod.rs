//! Multi-core machine simulator — the testbed substitute (DESIGN.md §2).
//!
//! The paper's evaluation ran on a 2-core Wolfdale and a 4-core
//! Bloomfield with PAPI counters; this container has one core and no
//! counters. The simulator executes the *actual* access traces of the
//! real schedules (same partition/coloring objects as `parallel/`)
//! through configurable cache/TLB/bandwidth models, producing
//! deterministic cycle counts, speedups and miss ratios for Figs. 4, 6–9
//! and Table 2.

pub mod cache;
pub mod exec;
pub mod machine;

pub use cache::{Cache, CacheConfig, Tlb};
pub use exec::{
    sim_colorful, sim_csr_sequential, sim_csrc_sequential, sim_local_buffers, CsrcLayout,
    SimResult,
};
pub use machine::{MachineConfig, MachineSim, MissStats};
