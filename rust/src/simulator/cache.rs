//! Set-associative LRU cache and TLB models.
//!
//! Deterministic, trace-driven; counts hits/misses. Used to reproduce the
//! paper's PAPI measurements (Fig. 4: % L2 and TLB misses) and as the
//! memory system of the multi-core machine model (Figs. 6–9, Table 2).

/// Geometry of one cache level.
#[derive(Clone, Copy, Debug)]
pub struct CacheConfig {
    pub size: usize,
    pub line: usize,
    pub assoc: usize,
}

impl CacheConfig {
    pub fn sets(&self) -> usize {
        (self.size / self.line / self.assoc).max(1)
    }
}

/// Set-associative cache with true-LRU replacement, stored as one flat
/// tag array (`sets × assoc`, MRU-first per set, `u64::MAX` = empty).
/// Flat storage + rotate keeps the per-access cost allocation-free and
/// cache-friendly — this is the innermost loop of the whole simulator
/// (EXPERIMENTS.md §Perf).
#[derive(Clone, Debug)]
pub struct Cache {
    cfg: CacheConfig,
    set_mask: usize,
    line_shift: u32,
    tags: Vec<u64>, // sets * assoc, MRU first within each set
    pub hits: u64,
    pub misses: u64,
}

const EMPTY: u64 = u64::MAX;

impl Cache {
    pub fn new(cfg: CacheConfig) -> Cache {
        assert!(cfg.line.is_power_of_two() && cfg.sets().is_power_of_two());
        Cache {
            cfg,
            set_mask: cfg.sets() - 1,
            line_shift: cfg.line.trailing_zeros(),
            tags: vec![EMPTY; cfg.sets() * cfg.assoc],
            hits: 0,
            misses: 0,
        }
    }

    pub fn cfg(&self) -> CacheConfig {
        self.cfg
    }

    /// Access the line containing `addr`; returns true on hit.
    #[inline]
    pub fn access(&mut self, addr: u64) -> bool {
        let line = addr >> self.line_shift;
        let set = (line as usize) & self.set_mask;
        let assoc = self.cfg.assoc;
        let ways = &mut self.tags[set * assoc..(set + 1) * assoc];
        // MRU fast path: repeated access to the same line is the common
        // case in the SpMV streams (unit-stride arrays).
        if ways[0] == line {
            self.hits += 1;
            return true;
        }
        if let Some(pos) = ways.iter().position(|&t| t == line) {
            ways[..=pos].rotate_right(1); // move to MRU, shift the rest
            ways[0] = line;
            self.hits += 1;
            true
        } else {
            ways.rotate_right(1); // evict LRU (last slot falls off)
            ways[0] = line;
            self.misses += 1;
            false
        }
    }

    pub fn accesses(&self) -> u64 {
        self.hits + self.misses
    }

    pub fn miss_ratio(&self) -> f64 {
        if self.accesses() == 0 {
            0.0
        } else {
            self.misses as f64 / self.accesses() as f64
        }
    }

    pub fn reset_counters(&mut self) {
        self.hits = 0;
        self.misses = 0;
    }
}

/// TLB modelled as a 4-way set-associative LRU translation cache (real
/// DTLBs are set-associative; a fully-associative linear scan over 256+
/// entries was the simulator's original bottleneck — EXPERIMENTS.md
/// §Perf).
#[derive(Clone, Debug)]
pub struct Tlb {
    cache: Cache,
    pub hits: u64,
    pub misses: u64,
}

impl Tlb {
    pub fn new(entries: usize, page: usize) -> Tlb {
        let assoc = 4.min(entries);
        Tlb {
            cache: Cache::new(CacheConfig { size: entries * page, line: page, assoc }),
            hits: 0,
            misses: 0,
        }
    }

    #[inline]
    pub fn access(&mut self, addr: u64) -> bool {
        if self.cache.access(addr) {
            self.hits += 1;
            true
        } else {
            self.misses += 1;
            false
        }
    }

    pub fn accesses(&self) -> u64 {
        self.hits + self.misses
    }

    pub fn miss_ratio(&self) -> f64 {
        if self.accesses() == 0 {
            0.0
        } else {
            self.misses as f64 / self.accesses() as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> Cache {
        Cache::new(CacheConfig { size: 1024, line: 64, assoc: 2 }) // 8 sets
    }

    #[test]
    fn sequential_scan_misses_once_per_line() {
        let mut c = small();
        for addr in (0..4096u64).step_by(8) {
            c.access(addr);
        }
        assert_eq!(c.misses, 4096 / 64);
        assert_eq!(c.accesses(), 512);
    }

    #[test]
    fn repeat_access_hits() {
        let mut c = small();
        assert!(!c.access(0));
        assert!(c.access(8)); // same line
        assert!(c.access(0));
    }

    #[test]
    fn lru_eviction_within_set() {
        let mut c = small();
        // Three lines mapping to the same set (stride = sets*line = 512).
        c.access(0);
        c.access(512);
        c.access(1024); // evicts line 0 (assoc 2)
        assert!(!c.access(0), "line 0 should have been evicted");
        assert!(c.access(1024));
    }

    #[test]
    fn working_set_fits_no_capacity_misses() {
        // 1KB cache, 512B working set: second pass must be all hits.
        let mut c = small();
        for _pass in 0..2 {
            for addr in (0..512u64).step_by(64) {
                c.access(addr);
            }
        }
        assert_eq!(c.misses, 8);
        assert_eq!(c.hits, 8);
    }

    #[test]
    fn miss_ratio_monotone_in_cache_size() {
        // Bigger cache, same trace => miss ratio must not increase.
        let trace: Vec<u64> = (0..20000u64).map(|i| (i * 2654435761) % 65536).collect();
        let mut small = Cache::new(CacheConfig { size: 2048, line: 64, assoc: 4 });
        let mut big = Cache::new(CacheConfig { size: 32768, line: 64, assoc: 4 });
        for &a in &trace {
            small.access(a);
            big.access(a);
        }
        assert!(big.miss_ratio() <= small.miss_ratio() + 1e-9);
    }

    #[test]
    fn tlb_basic() {
        let mut t = Tlb::new(4, 4096);
        assert!(!t.access(0));
        assert!(t.access(100)); // same page
        for p in 1..5u64 {
            t.access(p * 4096); // fills and evicts page 0
        }
        assert!(!t.access(0));
    }
}
