//! Schedule executors: run the SpMV algorithms *as memory-access traces*
//! through the machine model.
//!
//! Crucially these consume the **same plan objects** the real threaded
//! engines use — `partition::nnz_balanced`, `effective_range`,
//! `intervals`, `greedy_coloring` — so a planning bug shows up in both the
//! real engines' correctness tests and the simulated speedups.
//!
//! Multi-core interleaving: within each parallel phase, per-core work is
//! advanced in round-robin *row chunks*, which approximates co-scheduled
//! execution through the shared cache well enough for the paper's
//! in-cache/out-of-cache dichotomy.

use super::machine::{MachineSim, MissStats};
use crate::graph::ColorClasses;
use crate::parallel::AccumMethod;
use crate::partition::{self, RowPartition};
use crate::sparse::{Csr, Csrc};

/// Virtual address layout for the CSRC arrays (page-aligned bases, the
/// same "many parallel streams" picture the real arrays have).
pub struct CsrcLayout {
    pub ad: u64,
    pub al: u64,
    pub au: u64,
    pub ia: u64,
    pub ja: u64,
    pub x: u64,
    pub y: u64,
    /// Per-thread local buffers (local-buffers engines only).
    pub bufs: Vec<u64>,
}

fn page_up(a: u64) -> u64 {
    (a + 4095) & !4095
}

impl CsrcLayout {
    pub fn new(a: &Csrc, nbufs: usize) -> CsrcLayout {
        let n = a.n as u64;
        let k = a.k() as u64;
        let mut base = 0x10000u64;
        let mut take = |bytes: u64| {
            let b = base;
            base = page_up(base + bytes);
            b
        };
        CsrcLayout {
            ad: take(n * 8),
            al: take(k * 8),
            au: take(k * 8),
            ia: take((n + 1) * 4),
            ja: take(k * 4),
            x: take(n * 8),
            y: take(n * 8),
            bufs: (0..nbufs).map(|_| take(n * 8)).collect(),
        }
    }
}

/// Simulate the CSRC row sweep for rows [r0, r1) on `core`, scattering
/// into the buffer based at `buf` (use `layout.y` for direct-to-y).
fn sim_csrc_rows(
    sim: &mut MachineSim,
    l: &CsrcLayout,
    a: &Csrc,
    core: usize,
    r0: usize,
    r1: usize,
    buf: u64,
) {
    for i in r0..r1 {
        sim.access(core, l.x + 8 * i as u64); // xi
        sim.access(core, l.ad + 8 * i as u64);
        sim.access(core, l.ia + 4 * i as u64); // row bounds (ia[i], ia[i+1] same line usually)
        for k in a.row_range(i) {
            let j = a.ja[k] as usize;
            sim.access(core, l.ja + 4 * k as u64);
            sim.access(core, l.al + 8 * k as u64);
            sim.access(core, l.au + 8 * k as u64);
            sim.access(core, l.x + 8 * j as u64); // gather
            sim.access(core, buf + 8 * j as u64); // scatter read-modify-write
        }
        sim.access(core, buf + 8 * i as u64); // y_i / buf_i write
        sim.flops(core, 2 * a.row_range(i).len() as u64 + 1);
        sim.cycles(core, 2); // loop control
    }
}

/// Simulate the classical CSR sweep (baseline for Fig. 4 / Fig. 5).
fn sim_csr_rows(sim: &mut MachineSim, a: &Csr, core: usize, r0: usize, r1: usize) {
    // CSR layout: ia, ja, a, x, y.
    let n = a.nrows as u64;
    let nnz = a.nnz() as u64;
    let mut base = 0x10000u64;
    let mut take = |bytes: u64| {
        let b = base;
        base = page_up(base + bytes);
        b
    };
    let (bia, bja, ba, bx, by) = (
        take((n + 1) * 4),
        take(nnz * 4),
        take(nnz * 8),
        take(n * 8),
        take(n * 8),
    );
    for i in r0..r1 {
        sim.access(core, bia + 4 * i as u64);
        for k in a.row_range(i) {
            let j = a.ja[k] as usize;
            sim.access(core, bja + 4 * k as u64);
            sim.access(core, ba + 8 * k as u64);
            sim.access(core, bx + 8 * j as u64);
        }
        sim.access(core, by + 8 * i as u64);
        sim.flops(core, 2 * a.row_range(i).len() as u64);
        sim.cycles(core, 2);
    }
}

/// Result of one simulated product.
#[derive(Clone, Copy, Debug)]
pub struct SimResult {
    pub cycles: f64,
    pub misses: MissStats,
}

/// Sequential CSRC product (Figs. 4/5 and the speedup denominator).
pub fn sim_csrc_sequential(sim: &mut MachineSim, a: &Csrc) -> SimResult {
    let l = CsrcLayout::new(a, 0);
    sim.set_active(1);
    sim_csrc_rows(sim, &l, a, 0, 0, a.n, l.y);
    SimResult { cycles: sim.core_cycles(0), misses: sim.miss_stats() }
}

/// Sequential CSR product.
pub fn sim_csr_sequential(sim: &mut MachineSim, a: &Csr) -> SimResult {
    sim.set_active(1);
    sim_csr_rows(sim, a, 0, 0, a.nrows);
    SimResult { cycles: sim.core_cycles(0), misses: sim.miss_stats() }
}

/// Round-robin interleaved execution of per-core row ranges, in chunks.
fn interleave_rows(
    sim: &mut MachineSim,
    l: &CsrcLayout,
    a: &Csrc,
    part: &RowPartition,
    bufs: &[u64],
    chunk: usize,
) {
    let p = part.nthreads();
    let mut pos: Vec<usize> = (0..p).map(|t| part.block(t).start).collect();
    let mut live = true;
    while live {
        live = false;
        for t in 0..p {
            let end = part.block(t).end;
            if pos[t] < end {
                let hi = (pos[t] + chunk).min(end);
                sim_csrc_rows(sim, l, a, t, pos[t], hi, bufs[t]);
                pos[t] = hi;
                live = true;
            }
        }
    }
}

/// Simulated local-buffers product (§3.1) with the chosen accumulation
/// method; returns max-core cycles including init/accumulate phases.
pub fn sim_local_buffers(
    sim: &mut MachineSim,
    a: &Csrc,
    p: usize,
    method: AccumMethod,
) -> SimResult {
    assert!(p <= sim.cfg.cores, "{p} threads > {} cores", sim.cfg.cores);
    let n = a.n;
    let l = CsrcLayout::new(a, p);
    let part = partition::nnz_balanced(a, p);
    let eff: Vec<_> = (0..p).map(|t| partition::effective_range(a, part.block(t))).collect();
    let ints = partition::intervals(&eff);
    let assign = partition::assign_intervals(&ints, p);
    sim.set_active(p);
    sim.fork_join();

    // ---- init phase (writes are sequential streams; model as accesses).
    match method {
        AccumMethod::AllInOne => {
            let total = p * n;
            for t in 0..p {
                let (lo, hi) = (t * total / p, (t + 1) * total / p);
                for i in (lo..hi).step_by(8) {
                    let b = i / n;
                    let off = i % n;
                    sim.access(t, l.bufs[b] + 8 * off as u64);
                }
                sim.cycles(t, (hi - lo) as u64 / 4);
            }
        }
        AccumMethod::PerBuffer => {
            for b in 0..p {
                for t in 0..p {
                    let (lo, hi) = (t * n / p, (t + 1) * n / p);
                    for i in (lo..hi).step_by(8) {
                        sim.access(t, l.bufs[b] + 8 * i as u64);
                    }
                    sim.cycles(t, ((hi - lo) / 4) as u64);
                }
                sim.barrier();
            }
        }
        AccumMethod::Effective => {
            for t in 0..p {
                for i in eff[t].clone().step_by(8) {
                    sim.access(t, l.bufs[t] + 8 * i as u64);
                }
                sim.cycles(t, (eff[t].len() / 4) as u64);
            }
        }
        AccumMethod::Interval => {
            for (t, idxs) in assign.iter().enumerate() {
                for &ii in idxs {
                    let int = &ints[ii];
                    for &b in &int.covers {
                        for i in int.range.clone().step_by(8) {
                            sim.access(t, l.bufs[b] + 8 * i as u64);
                        }
                        sim.cycles(t, (int.range.len() / 4) as u64);
                    }
                }
            }
        }
    }
    sim.barrier();

    // ---- compute phase (interleaved through the shared cache).
    interleave_rows(sim, &l, a, &part, &l.bufs, 32);
    sim.barrier();

    // ---- accumulation phase.
    match method {
        AccumMethod::AllInOne => {
            for t in 0..p {
                let (lo, hi) = (t * n / p, (t + 1) * n / p);
                for i in lo..hi {
                    for b in 0..p {
                        sim.access(t, l.bufs[b] + 8 * i as u64);
                    }
                    sim.access(t, l.y + 8 * i as u64);
                    sim.flops(t, p as u64);
                }
            }
        }
        AccumMethod::PerBuffer => {
            for b in 0..p {
                for t in 0..p {
                    let (lo, hi) = (t * n / p, (t + 1) * n / p);
                    for i in lo..hi {
                        sim.access(t, l.bufs[b] + 8 * i as u64);
                        sim.access(t, l.y + 8 * i as u64);
                        sim.flops(t, 1);
                    }
                }
                sim.barrier();
            }
        }
        AccumMethod::Effective => {
            for t in 0..p {
                let own = part.block(t);
                for b in 0..p {
                    let from = own.start.max(eff[b].start);
                    let to = own.end.min(eff[b].end);
                    for i in from..to {
                        sim.access(t, l.bufs[b] + 8 * i as u64);
                        sim.access(t, l.y + 8 * i as u64);
                        sim.flops(t, 1);
                    }
                }
            }
        }
        AccumMethod::Interval => {
            for (t, idxs) in assign.iter().enumerate() {
                for &ii in idxs {
                    let int = &ints[ii];
                    for i in int.range.clone() {
                        for &b in &int.covers {
                            sim.access(t, l.bufs[b] + 8 * i as u64);
                        }
                        sim.access(t, l.y + 8 * i as u64);
                        sim.flops(t, int.covers.len() as u64);
                    }
                }
            }
        }
    }
    sim.barrier();
    SimResult { cycles: sim.max_cycles(), misses: sim.miss_stats() }
}

/// Simulated colorful product (§3.2).
pub fn sim_colorful(sim: &mut MachineSim, a: &Csrc, p: usize, colors: &ColorClasses) -> SimResult {
    assert!(p <= sim.cfg.cores);
    let l = CsrcLayout::new(a, 0);
    sim.set_active(p);
    sim.fork_join();
    // Zero y cooperatively.
    for t in 0..p {
        let (lo, hi) = (t * a.n / p, (t + 1) * a.n / p);
        for i in (lo..hi).step_by(8) {
            sim.access(t, l.y + 8 * i as u64);
        }
    }
    sim.barrier();
    for class in &colors.classes {
        // nnz-balanced split of the class, chunk-interleaved.
        let work: Vec<usize> = class.iter().map(|&i| 1 + a.row_range(i as usize).len()).collect();
        let total: usize = work.iter().sum();
        let mut cuts = vec![0usize];
        let mut acc = 0usize;
        let mut t = 1;
        for (idx, w) in work.iter().enumerate() {
            if t < p && acc * p >= total * t {
                cuts.push(idx);
                t += 1;
            }
            acc += w;
        }
        while cuts.len() < p + 1 {
            cuts.push(class.len());
        }
        cuts[p] = class.len();
        // Interleave per-core chunks of 32 rows.
        let mut pos: Vec<usize> = cuts[..p].to_vec();
        let mut live = true;
        while live {
            live = false;
            for t in 0..p {
                let end = cuts[t + 1];
                if pos[t] < end {
                    let hi = (pos[t] + 32).min(end);
                    for &row in &class[pos[t]..hi] {
                        let i = row as usize;
                        sim.access(t, l.x + 8 * i as u64);
                        sim.access(t, l.ad + 8 * i as u64);
                        sim.access(t, l.ia + 4 * i as u64);
                        for k in a.row_range(i) {
                            let j = a.ja[k] as usize;
                            sim.access(t, l.ja + 4 * k as u64);
                            sim.access(t, l.al + 8 * k as u64);
                            sim.access(t, l.au + 8 * k as u64);
                            sim.access(t, l.x + 8 * j as u64);
                            sim.access(t, l.y + 8 * j as u64);
                        }
                        sim.access(t, l.y + 8 * i as u64);
                        sim.flops(t, 2 * a.row_range(i).len() as u64 + 1);
                        sim.cycles(t, 2);
                    }
                    pos[t] = hi;
                    live = true;
                }
            }
        }
        sim.barrier();
    }
    SimResult { cycles: sim.max_cycles(), misses: sim.miss_stats() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{greedy_coloring, ConflictGraph, Ordering};
    use crate::simulator::machine::MachineConfig;
    use crate::sparse::Coo;
    use crate::util::Rng;

    fn mat(n: usize, npr: usize, seed: u64) -> Csrc {
        let mut rng = Rng::new(seed);
        Csrc::from_coo(&Coo::random_structurally_symmetric(n, npr, false, &mut rng)).unwrap()
    }

    fn banded(n: usize, hbw: usize, seed: u64) -> Csrc {
        let mut rng = Rng::new(seed);
        Csrc::from_coo(&Coo::banded(n, hbw, true, &mut rng)).unwrap()
    }

    #[test]
    fn sequential_cycles_scale_with_nnz() {
        let small = mat(200, 3, 1);
        let large = mat(200, 9, 1);
        let mut s1 = MachineSim::new(MachineConfig::wolfdale());
        let mut s2 = MachineSim::new(MachineConfig::wolfdale());
        let r1 = sim_csrc_sequential(&mut s1, &small);
        let r2 = sim_csrc_sequential(&mut s2, &large);
        assert!(r2.cycles > r1.cycles);
    }

    #[test]
    fn in_cache_local_buffers_speedup_near_linear() {
        // Small banded matrix fits every cache: effective method with 2
        // cores should approach 2x on a *warm* product (the paper's
        // in-cache finding; peaks 1.83-1.87 at 2 threads).
        let a = banded(20000, 4, 2);
        let cfg = MachineConfig::bloomfield();
        let mut seq = MachineSim::new(cfg.clone());
        sim_csrc_sequential(&mut seq, &a);
        seq.reset_counters();
        seq.reset_cycles();
        let base = sim_csrc_sequential(&mut seq, &a).cycles;
        let mut par = MachineSim::new(cfg);
        sim_local_buffers(&mut par, &a, 2, AccumMethod::Effective);
        par.reset_counters();
        par.reset_cycles();
        let got = sim_local_buffers(&mut par, &a, 2, AccumMethod::Effective).cycles;
        let speedup = base / got;
        assert!(speedup > 1.5, "in-cache warm speedup only {speedup:.2}");
        assert!(speedup < 2.2, "speedup {speedup:.2} impossibly high");
    }

    #[test]
    fn colorful_correct_shape_and_bounded() {
        let a = banded(5000, 1, 3);
        let g = ConflictGraph::build(&a);
        let colors = greedy_coloring(&g, Ordering::Natural);
        let mut seq = MachineSim::new(MachineConfig::wolfdale());
        let base = sim_csrc_sequential(&mut seq, &a).cycles;
        let mut par = MachineSim::new(MachineConfig::wolfdale());
        let got = sim_colorful(&mut par, &a, 2, &colors).cycles;
        let speedup = base / got;
        assert!(speedup > 0.5 && speedup < 2.2, "colorful speedup {speedup:.2}");
    }

    #[test]
    fn effective_cheaper_than_all_in_one() {
        // Table 2's key relation: effective init/accum < all-in-one.
        let a = banded(30000, 3, 4);
        let mut s1 = MachineSim::new(MachineConfig::bloomfield());
        let c1 = sim_local_buffers(&mut s1, &a, 4, AccumMethod::AllInOne).cycles;
        let mut s2 = MachineSim::new(MachineConfig::bloomfield());
        let c2 = sim_local_buffers(&mut s2, &a, 4, AccumMethod::Effective).cycles;
        assert!(c2 < c1, "effective {c2} should beat all-in-one {c1}");
    }

    #[test]
    fn csr_sequential_runs() {
        let a = mat(300, 5, 5);
        let csr = a.to_csr();
        let mut sim = MachineSim::new(MachineConfig::wolfdale());
        let r = sim_csr_sequential(&mut sim, &csr);
        assert!(r.cycles > 0.0);
        assert!(r.misses.outer_accesses > 0);
    }
}
