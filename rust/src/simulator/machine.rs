//! The multi-core machine model — the substitute testbed (DESIGN.md §2).
//!
//! Configured as the paper's two platforms:
//!
//! * **Wolfdale** (Intel Core 2 Duo E8200): 2 cores, private 32 KB L1d,
//!   one **shared 6 MB L2**, FSB memory path (strong contention),
//! * **Bloomfield** (Intel Core i7 940): 4 cores, private 32 KB L1d +
//!   256 KB L2, **shared 8 MB L3**, on-die memory controller + QuickPath
//!   (weak contention — the paper's §4.2 "63 % more efficient" finding).
//!
//! The model executes the *actual* access streams of the SpMV schedules
//! (see [`super::exec`]) through per-core L1/TLB, the private/shared
//! outer levels, and charges latency per hit level plus a bandwidth
//! contention penalty per concurrently-active memory-bound core.

use super::cache::{Cache, CacheConfig, Tlb};

#[derive(Clone, Debug)]
pub struct MachineConfig {
    pub name: &'static str,
    pub cores: usize,
    pub l1: CacheConfig,
    /// Second level; private per core or shared by all.
    pub l2: CacheConfig,
    pub l2_private: bool,
    /// Optional shared last level.
    pub l3: Option<CacheConfig>,
    pub tlb_entries: usize,
    pub page: usize,
    /// Latencies in cycles.
    pub lat_l1: u64,
    pub lat_l2: u64,
    pub lat_l3: u64,
    pub lat_mem: u64,
    pub lat_tlb_miss: u64,
    /// Cycles per floating-point op (superscalar FMA pipelines < 1).
    pub flop_cycles: f64,
    /// Extra memory latency per *other* active core on a memory fetch —
    /// the bandwidth-contention knob (FSB ≫ QPI).
    pub mem_contention: u64,
    /// Fork-join cost per parallel region and per in-region barrier.
    pub fork_join_cycles: u64,
    pub barrier_cycles: u64,
}

impl MachineConfig {
    /// Intel Core 2 Duo E8200 "Wolfdale", 2.66 GHz.
    pub fn wolfdale() -> MachineConfig {
        MachineConfig {
            name: "wolfdale",
            cores: 2,
            l1: CacheConfig { size: 32 << 10, line: 64, assoc: 8 },
            l2: CacheConfig { size: 6 << 20, line: 64, assoc: 24 }, // 24-way: 4096 sets
            l2_private: false, // the shared 6MB L2
            l3: None,
            tlb_entries: 256,
            page: 4096,
            lat_l1: 3,
            lat_l2: 15,
            lat_l3: 0,
            lat_mem: 230,
            lat_tlb_miss: 30,
            flop_cycles: 0.5,
            mem_contention: 120, // FSB: two cores nearly serialize on DRAM
            fork_join_cycles: 4000,
            barrier_cycles: 800,
        }
    }

    /// Intel Core i7 940 "Bloomfield", 2.93 GHz, HT disabled (§4).
    pub fn bloomfield() -> MachineConfig {
        MachineConfig {
            name: "bloomfield",
            cores: 4,
            l1: CacheConfig { size: 32 << 10, line: 64, assoc: 8 },
            l2: CacheConfig { size: 256 << 10, line: 64, assoc: 8 },
            l2_private: true,
            l3: Some(CacheConfig { size: 8 << 20, line: 64, assoc: 16 }),
            tlb_entries: 512,
            page: 4096,
            lat_l1: 4,
            lat_l2: 11,
            lat_l3: 40,
            lat_mem: 200,
            lat_tlb_miss: 30,
            flop_cycles: 0.5,
            mem_contention: 35, // integrated memory controller + QPI
            fork_join_cycles: 4000,
            barrier_cycles: 800,
        }
    }

    /// Outermost-cache capacity — the ws threshold Table 2 splits on
    /// (6 MB Wolfdale, 8 MB Bloomfield).
    pub fn last_level_bytes(&self) -> usize {
        self.l3.map(|c| c.size).unwrap_or(self.l2.size)
    }
}

/// Per-core private state.
struct Core {
    l1: Cache,
    l2: Option<Cache>, // private L2 (bloomfield)
    tlb: Tlb,
    cycles: f64,
    mem_accesses: u64,
}

/// Trace-driven multi-core simulator.
pub struct MachineSim {
    pub cfg: MachineConfig,
    cores: Vec<Core>,
    shared: Cache, // shared L2 (wolfdale) or L3 (bloomfield)
    /// Cores currently considered active (set per phase by the executor);
    /// memory fetches pay contention for each *other* active core.
    active_cores: usize,
}

/// Counters snapshot for Fig. 4-style reporting.
#[derive(Clone, Copy, Debug, Default)]
pub struct MissStats {
    /// All data accesses issued (the L1 access count) — the denominator
    /// for the Fig. 4 percentages, so "0 % misses" is meaningful for
    /// in-cache runs where the outer level is barely touched.
    pub total_accesses: u64,
    pub outer_accesses: u64,
    pub outer_misses: u64,
    pub tlb_accesses: u64,
    pub tlb_misses: u64,
}

impl MissStats {
    pub fn outer_miss_pct(&self) -> f64 {
        if self.total_accesses == 0 {
            0.0
        } else {
            100.0 * self.outer_misses as f64 / self.total_accesses as f64
        }
    }
    pub fn tlb_miss_pct(&self) -> f64 {
        if self.tlb_accesses == 0 {
            0.0
        } else {
            100.0 * self.tlb_misses as f64 / self.tlb_accesses as f64
        }
    }
}

impl MachineSim {
    pub fn new(cfg: MachineConfig) -> MachineSim {
        let cores = (0..cfg.cores)
            .map(|_| Core {
                l1: Cache::new(cfg.l1),
                l2: if cfg.l2_private { Some(Cache::new(cfg.l2)) } else { None },
                tlb: Tlb::new(cfg.tlb_entries, cfg.page),
                cycles: 0.0,
                mem_accesses: 0,
            })
            .collect();
        let shared = Cache::new(if cfg.l2_private {
            cfg.l3.expect("private L2 requires a shared L3")
        } else {
            cfg.l2
        });
        MachineSim { cfg, cores, shared, active_cores: 1 }
    }

    /// Declare how many cores run concurrently in the current phase.
    pub fn set_active(&mut self, n: usize) {
        self.active_cores = n.max(1);
    }

    /// One memory access by `core`; charges cycles by hit level.
    #[inline]
    pub fn access(&mut self, core: usize, addr: u64) {
        let cfg = &self.cfg;
        let c = &mut self.cores[core];
        if !c.tlb.access(addr) {
            c.cycles += cfg.lat_tlb_miss as f64;
        }
        if c.l1.access(addr) {
            c.cycles += cfg.lat_l1 as f64;
            return;
        }
        if let Some(l2) = &mut c.l2 {
            if l2.access(addr) {
                c.cycles += cfg.lat_l2 as f64;
                return;
            }
        }
        // Shared level (L2 on wolfdale, L3 on bloomfield).
        let shared_lat = if cfg.l2_private { cfg.lat_l3 } else { cfg.lat_l2 };
        if self.shared.access(addr) {
            c.cycles += shared_lat as f64;
            return;
        }
        // DRAM: base latency + contention for the other active cores.
        c.cycles += cfg.lat_mem as f64
            + cfg.mem_contention as f64 * (self.active_cores.saturating_sub(1)) as f64;
        c.mem_accesses += 1;
    }

    /// Charge `n` floating-point operations to `core`.
    #[inline]
    pub fn flops(&mut self, core: usize, n: u64) {
        self.cores[core].cycles += n as f64 * self.cfg.flop_cycles;
    }

    /// Charge raw cycles (loop control etc.).
    #[inline]
    pub fn cycles(&mut self, core: usize, n: u64) {
        self.cores[core].cycles += n as f64;
    }

    pub fn core_cycles(&self, core: usize) -> f64 {
        self.cores[core].cycles
    }

    pub fn max_cycles(&self) -> f64 {
        self.cores.iter().map(|c| c.cycles).fold(0.0, f64::max)
    }

    pub fn total_cycles(&self) -> f64 {
        self.cores.iter().map(|c| c.cycles).sum()
    }

    /// Align all cores to the slowest (a barrier) and charge its cost.
    pub fn barrier(&mut self) {
        let m = self.max_cycles() + self.cfg.barrier_cycles as f64;
        for c in &mut self.cores {
            c.cycles = m;
        }
    }

    /// Charge the fork-join entry cost to every core.
    pub fn fork_join(&mut self) {
        for c in &mut self.cores {
            c.cycles += self.cfg.fork_join_cycles as f64;
        }
    }

    /// Zero all hit/miss counters but keep cache/TLB contents — used to
    /// measure the *warm* (steady-state) product, like the paper's
    /// 1000-product runs (a single cold product overstates miss ratios).
    pub fn reset_counters(&mut self) {
        for c in &mut self.cores {
            c.l1.reset_counters();
            if let Some(l2) = &mut c.l2 {
                l2.reset_counters();
            }
            c.tlb.hits = 0;
            c.tlb.misses = 0;
        }
        self.shared.reset_counters();
    }

    /// Zero per-core cycle accounting (keep cache/TLB contents) — with
    /// `reset_counters`, lets callers measure a *warm* product: run once
    /// cold, reset, run again (the paper times 1000 warm products).
    pub fn reset_cycles(&mut self) {
        for c in &mut self.cores {
            c.cycles = 0.0;
            c.mem_accesses = 0;
        }
    }

    /// Fig. 4 counters: outer-level (= the level PAPI calls "L2" on both
    /// machines) and TLB, summed over cores.
    pub fn miss_stats(&self) -> MissStats {
        let mut s = MissStats::default();
        // Outer level: on wolfdale the shared L2; on bloomfield the
        // private L2s (PAPI L2 counters are per-core L2 there).
        if self.cfg.l2_private {
            for c in &self.cores {
                let l2 = c.l2.as_ref().unwrap();
                s.outer_accesses += l2.accesses();
                s.outer_misses += l2.misses;
            }
        } else {
            s.outer_accesses = self.shared.accesses();
            s.outer_misses = self.shared.misses;
        }
        for c in &self.cores {
            s.total_accesses += c.l1.accesses();
            s.tlb_accesses += c.tlb.accesses();
            s.tlb_misses += c.tlb.misses;
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_shape() {
        let w = MachineConfig::wolfdale();
        assert_eq!(w.cores, 2);
        assert!(!w.l2_private);
        assert_eq!(w.last_level_bytes(), 6 << 20);
        let b = MachineConfig::bloomfield();
        assert_eq!(b.cores, 4);
        assert!(b.l2_private);
        assert_eq!(b.last_level_bytes(), 8 << 20);
    }

    #[test]
    fn small_working_set_stays_cached() {
        let mut sim = MachineSim::new(MachineConfig::wolfdale());
        // Cold pass over 16KB to warm caches...
        for a in (0..16384u64).step_by(8) {
            sim.access(0, a);
        }
        let cold = sim.core_cycles(0);
        // ...then a warm pass must be all L1 hits (lat_l1 per access).
        for a in (0..16384u64).step_by(8) {
            sim.access(0, a);
        }
        let warm_per_access = (sim.core_cycles(0) - cold) / 2048.0;
        assert!(warm_per_access <= 4.0, "warm avg {warm_per_access} cycles/access");
    }

    #[test]
    fn contention_increases_memory_cost() {
        let cfg = MachineConfig::wolfdale();
        let mut alone = MachineSim::new(cfg.clone());
        alone.set_active(1);
        let mut contended = MachineSim::new(cfg);
        contended.set_active(2);
        // A streaming (all-miss) pattern >> caches.
        for a in (0..(32u64 << 20)).step_by(64) {
            alone.access(0, a);
        }
        for a in (0..(32u64 << 20)).step_by(64) {
            contended.access(0, a);
        }
        assert!(contended.core_cycles(0) > alone.core_cycles(0) * 1.2);
    }

    #[test]
    fn barrier_aligns_cores() {
        let mut sim = MachineSim::new(MachineConfig::bloomfield());
        sim.cycles(0, 100);
        sim.cycles(1, 5000);
        sim.barrier();
        for c in 0..4 {
            assert_eq!(sim.core_cycles(c), 5000.0 + 800.0);
        }
    }

    #[test]
    fn miss_stats_accumulate() {
        let mut sim = MachineSim::new(MachineConfig::bloomfield());
        for a in (0..(1u64 << 20)).step_by(64) {
            sim.access(0, a);
        }
        let s = sim.miss_stats();
        assert!(s.outer_accesses > 0);
        assert!(s.tlb_accesses > 0);
        assert!(s.outer_miss_pct() > 0.0);
    }
}
