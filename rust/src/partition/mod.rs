//! Row partitioning for the local-buffers strategy (§3.1).
//!
//! The paper found row-count partitioning load-imbalanced and used a
//! **non-zero guided** split: contiguous row blocks whose nnz (counting
//! both triangles, since each lower entry costs two updates) deviates
//! minimally from the average. [`effective_range`] and [`intervals`]
//! support the *effective* and *interval* accumulation methods.
//!
//! Everything here is pure *analysis* over the [`SpmvKernel`] abstraction
//! (per-row work, per-row write extents), so one partitioner serves
//! CSRC, CSR and BCSR alike; [`crate::plan::SpmvPlan`] packages the
//! results for reuse across engines and workers.

use crate::sparse::SpmvKernel;

/// Contiguous row blocks: thread t owns rows `starts[t]..starts[t+1]`.
#[derive(Clone, Debug, PartialEq)]
pub struct RowPartition {
    pub starts: Vec<usize>, // len = nthreads + 1; starts[0]=0, last = n
}

impl RowPartition {
    pub fn nthreads(&self) -> usize {
        self.starts.len() - 1
    }

    pub fn block(&self, t: usize) -> std::ops::Range<usize> {
        self.starts[t]..self.starts[t + 1]
    }

    /// Sanity: monotone, complete cover of 0..n.
    pub fn validate(&self, n: usize) -> Result<(), String> {
        if *self.starts.first().unwrap() != 0 || *self.starts.last().unwrap() != n {
            return Err(format!("partition does not cover 0..{n}: {:?}", self.starts));
        }
        if self.starts.windows(2).any(|w| w[0] > w[1]) {
            return Err(format!("partition not monotone: {:?}", self.starts));
        }
        Ok(())
    }
}

/// Even split by *row count* (the naive baseline the paper rejects).
pub fn rowwise_even(n: usize, p: usize) -> RowPartition {
    assert!(p > 0);
    let starts = (0..=p).map(|t| t * n / p).collect();
    RowPartition { starts }
}

/// Non-zero guided partition (§3.1): greedy sweep closing each block as
/// soon as its accumulated work reaches the remaining average, which
/// minimizes the deviation from the mean for contiguous blocks. Work is
/// the kernel's own per-row estimate (for CSRC: 1 + 2·row_len).
pub fn nnz_balanced(a: &dyn SpmvKernel, p: usize) -> RowPartition {
    assert!(p > 0);
    let n = a.dim();
    let total: usize = (0..n).map(|i| a.row_work(i)).sum();
    let mut starts = Vec::with_capacity(p + 1);
    starts.push(0);
    let mut consumed = 0usize;
    let mut row = 0usize;
    for t in 0..p - 1 {
        // Re-target on the *remaining* work so early rounding errors do
        // not starve the last thread.
        let target = (total - consumed) as f64 / (p - t) as f64;
        let mut block = 0usize;
        while row < n {
            let w = a.row_work(row);
            // Close the block when adding the row would overshoot the
            // target by more than stopping short undershoots it.
            if block > 0 && (block + w) as f64 - target > target - block as f64 {
                break;
            }
            block += w;
            row += 1;
        }
        consumed += block;
        starts.push(row);
    }
    starts.push(n); // last thread takes the tail
    RowPartition { starts }
}

/// The *effective range* of a thread (§3.1): the set of y rows it
/// actually touches. For a contiguous block [r0, r1) the writes are the
/// owned rows plus every scatter target below r0 — a prefix extension
/// [min write, r1). Formats without scatters (CSR, BCSR) collapse this
/// to the owned block itself.
pub fn effective_range(a: &dyn SpmvKernel, block: std::ops::Range<usize>) -> std::ops::Range<usize> {
    let mut lo = block.start;
    for i in block.clone() {
        lo = lo.min(a.row_write_lo(i));
    }
    lo..block.end
}

/// Interval decomposition (§3.1 method 4): the union of all effective
/// ranges cut at every boundary, each interval annotated with the buffers
/// (threads) covering it. Intervals are disjoint and sorted.
#[derive(Clone, Debug, PartialEq)]
pub struct Interval {
    pub range: std::ops::Range<usize>,
    pub covers: Vec<usize>, // thread ids whose effective range ⊇ range
}

pub fn intervals(effective: &[std::ops::Range<usize>]) -> Vec<Interval> {
    let mut cuts: Vec<usize> = effective
        .iter()
        .flat_map(|r| [r.start, r.end])
        .collect();
    cuts.sort_unstable();
    cuts.dedup();
    let mut out = Vec::new();
    for w in cuts.windows(2) {
        let (lo, hi) = (w[0], w[1]);
        if lo == hi {
            continue;
        }
        let covers: Vec<usize> = effective
            .iter()
            .enumerate()
            .filter(|(_, r)| r.start <= lo && hi <= r.end)
            .map(|(t, _)| t)
            .collect();
        if !covers.is_empty() {
            out.push(Interval { range: lo..hi, covers });
        }
    }
    out
}

/// Assign intervals to threads, balancing Σ len×covers (the accumulation
/// work) greedily — longest-work interval to the least-loaded thread.
pub fn assign_intervals(ints: &[Interval], p: usize) -> Vec<Vec<usize>> {
    let mut order: Vec<usize> = (0..ints.len()).collect();
    order.sort_by_key(|&i| std::cmp::Reverse(ints[i].range.len() * ints[i].covers.len()));
    let mut load = vec![0usize; p];
    let mut assign = vec![Vec::new(); p];
    for i in order {
        let t = (0..p).min_by_key(|&t| load[t]).unwrap();
        load[t] += ints[i].range.len() * ints[i].covers.len();
        assign[t].push(i);
    }
    for a in &mut assign {
        a.sort_unstable();
    }
    assign
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::{Coo, Csrc};
    use crate::util::{propcheck, Rng};

    fn mat(n: usize, npr: usize, seed: u64) -> Csrc {
        let mut rng = Rng::new(seed);
        Csrc::from_coo(&Coo::random_structurally_symmetric(n, npr, false, &mut rng)).unwrap()
    }

    #[test]
    fn rowwise_covers() {
        let p = rowwise_even(10, 3);
        p.validate(10).unwrap();
        assert_eq!(p.starts, vec![0, 3, 6, 10]);
    }

    #[test]
    fn nnz_balanced_covers_and_balances() {
        let a = mat(200, 6, 40);
        for p in [1, 2, 4, 7] {
            let part = nnz_balanced(&a, p);
            part.validate(a.n).unwrap();
            let works: Vec<usize> = (0..p)
                .map(|t| part.block(t).map(|i| 1 + 2 * a.row_range(i).len()).sum())
                .collect();
            let total: usize = works.iter().sum();
            let avg = total as f64 / p as f64;
            for (t, &w) in works.iter().enumerate() {
                // Deviation at most one max-row of work.
                let max_row = (0..a.n).map(|i| 1 + 2 * a.row_range(i).len()).max().unwrap();
                assert!(
                    (w as f64 - avg).abs() <= (max_row + 1) as f64,
                    "thread {t}: work {w} vs avg {avg} (max_row {max_row})"
                );
            }
        }
    }

    #[test]
    fn nnz_balanced_more_threads_than_rows() {
        let a = mat(3, 1, 41);
        let part = nnz_balanced(&a, 8);
        part.validate(3).unwrap(); // empty blocks are fine
    }

    #[test]
    fn effective_range_contains_block_and_scatters() {
        let a = mat(60, 4, 42);
        let part = nnz_balanced(&a, 3);
        for t in 0..3 {
            let block = part.block(t);
            let er = effective_range(&a, block.clone());
            assert!(er.start <= block.start && er.end == block.end);
            // Every write target of the block is inside er.
            for i in block {
                for k in a.row_range(i) {
                    let j = a.ja[k] as usize;
                    assert!(er.contains(&j), "scatter {j} outside {er:?}");
                }
            }
        }
    }

    #[test]
    fn intervals_partition_union_of_ranges() {
        let eff = vec![0..5, 3..9, 7..9];
        let ints = intervals(&eff);
        // Disjoint, sorted, cover exactly union = 0..9.
        let mut covered = vec![false; 9];
        for int in &ints {
            for i in int.range.clone() {
                assert!(!covered[i], "overlap at {i}");
                covered[i] = true;
            }
        }
        assert!(covered.iter().all(|&c| c));
        // Interval [3,5) must be covered by threads 0 and 1.
        let mid = ints.iter().find(|i| i.range == (3..5)).unwrap();
        assert_eq!(mid.covers, vec![0, 1]);
    }

    #[test]
    fn assign_intervals_covers_all() {
        let eff = vec![0..50, 25..100, 90..120];
        let ints = intervals(&eff);
        let assign = assign_intervals(&ints, 3);
        let mut seen = vec![false; ints.len()];
        for a in &assign {
            for &i in a {
                assert!(!seen[i]);
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn property_partition_invariants() {
        propcheck::check(15, |rng| {
            let n = 10 + rng.below(150);
            let a = {
                let coo = Coo::random_structurally_symmetric(n, 1 + rng.below(6), false, rng);
                Csrc::from_coo(&coo).map_err(|e| e.to_string())?
            };
            let p = 1 + rng.below(8);
            let part = nnz_balanced(&a, p);
            part.validate(n)?;
            let eff: Vec<_> = (0..p).map(|t| effective_range(&a, part.block(t))).collect();
            let ints = intervals(&eff);
            // Intervals must cover every row that any effective range covers.
            for (t, r) in eff.iter().enumerate() {
                for i in r.clone() {
                    if !ints.iter().any(|int| int.range.contains(&i) && int.covers.contains(&t)) {
                        return Err(format!("row {i} of thread {t} uncovered"));
                    }
                }
            }
            Ok(())
        });
    }
}
