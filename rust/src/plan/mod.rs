//! Reusable SpMV scheduling plans — the analysis half of the
//! analysis/execution split.
//!
//! The paper's two race-avoidance strategies are pure *analysis* over the
//! matrix pattern: the §3.1 local-buffers family needs an nnz-guided row
//! partition, per-thread effective ranges and (for the interval method)
//! an interval decomposition; the §3.2 colorful strategy needs conflict
//! coloring and per-class thread shares. None of it depends on the
//! values, on the buffers, or on which executor runs it — so it is
//! computed once per matrix × thread-count into an immutable
//! [`SpmvPlan`], held in an `Arc`, and *borrowed* by every engine
//! ([`crate::parallel::build_engine`]) instead of being recomputed in
//! each engine's constructor.
//!
//! * [`PlanBuilder`] computes only the pieces a strategy needs
//!   ([`PlanPieces`]); [`PlanBuilder::for_kind`] picks them per
//!   [`EngineKind`].
//! * [`PlanCache`] is the concurrent matrix-key → `Arc<SpmvPlan>` map the
//!   coordinator threads through its workers, with build count / build
//!   time counters surfaced in the service stats — a matrix registered
//!   once is analyzed once, not once per worker × engine.
//! * [`SpmvPlan::validate`] checks every invariant (partition covers and
//!   is monotone, effective ranges contain owned blocks, intervals tile
//!   the union, colors are conflict-free) and is property-tested below.

use crate::graph::{greedy_coloring, ColorClasses, ConflictGraph, Ordering as ColorOrdering};
use crate::metrics;
use crate::parallel::{AccumMethod, EngineKind};
use crate::partition::{self, Interval, RowPartition};
use crate::sparse::SpmvKernel;
use std::collections::HashMap;
use std::ops::Range;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Which analysis pieces a plan carries (the row partition is always
/// computed — every strategy but colorful consumes it and it is O(n)).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PlanPieces {
    /// Per-thread effective ranges + owned-block covering sets (§3.1
    /// *effective* accumulation; since the windowed-buffer change, every
    /// local-buffers method sizes its scatter buffers from these).
    pub ranges: bool,
    /// Interval decomposition + balanced assignment (§3.1 *interval*
    /// accumulation; implies `ranges`).
    pub intervals: bool,
    /// Conflict coloring + per-class thread shares (§3.2 colorful).
    pub coloring: bool,
    /// RCM reorder analysis ([`crate::reorder::rcm`]): the permutation
    /// plus half-bandwidth before/after. Opt-in — no engine *requires*
    /// it; the tuner's reordered candidates and the reorder figures
    /// consume it.
    pub reorder: bool,
}

impl PlanPieces {
    /// Every piece an engine might need. The reorder analysis is *not*
    /// included: it is policy-driven ([`crate::reorder::ReorderPolicy`]),
    /// not engine-driven — request it with [`PlanBuilder::reorder`].
    pub fn all() -> PlanPieces {
        PlanPieces { ranges: true, intervals: true, coloring: true, reorder: false }
    }

    /// The pieces one engine kind needs. Every local-buffers method now
    /// asks for `ranges`: the effective ranges are the buffer *windows*,
    /// so even all-in-one/per-buffer — which never consult them for
    /// scheduling — need them to allocate windowed buffers instead of
    /// full-length copies of y.
    pub fn for_kind(kind: EngineKind) -> PlanPieces {
        match kind {
            EngineKind::Sequential | EngineKind::Atomic => PlanPieces::default(),
            EngineKind::LocalBuffers(AccumMethod::AllInOne)
            | EngineKind::LocalBuffers(AccumMethod::PerBuffer)
            | EngineKind::LocalBuffers(AccumMethod::Effective) => {
                PlanPieces { ranges: true, ..Default::default() }
            }
            EngineKind::LocalBuffers(AccumMethod::Interval) => {
                PlanPieces { ranges: true, intervals: true, ..Default::default() }
            }
            EngineKind::Colorful => PlanPieces { coloring: true, ..Default::default() },
            // Auto is resolved by trialing every candidate engine, so its
            // plan must carry every piece.
            EngineKind::Auto => PlanPieces::all(),
        }
    }

    pub fn union(self, other: PlanPieces) -> PlanPieces {
        PlanPieces {
            ranges: self.ranges || other.ranges || self.intervals || other.intervals,
            intervals: self.intervals || other.intervals,
            coloring: self.coloring || other.coloring,
            reorder: self.reorder || other.reorder,
        }
    }

    /// Does `self` include everything `other` asks for?
    pub fn covers(self, other: PlanPieces) -> bool {
        (self.ranges || !other.ranges)
            && (self.intervals || !other.intervals)
            && (self.coloring || !other.coloring)
            && (self.reorder || !other.reorder)
    }
}

/// Wall-clock cost of the analysis phases (seconds) — surfaced through
/// the service metrics so plan reuse is observable.
#[derive(Clone, Copy, Debug, Default)]
pub struct PlanStats {
    pub partition_s: f64,
    pub ranges_s: f64,
    pub intervals_s: f64,
    pub coloring_s: f64,
    pub reorder_s: f64,
    pub total_s: f64,
}

/// The reorder stage's output (`pieces.reorder`): the RCM permutation
/// and the half-bandwidth it would achieve — recorded whether or not a
/// caller decides to execute through it, so reorder-on vs reorder-off
/// is an informed choice.
#[derive(Clone, Debug)]
pub struct ReorderPlan {
    pub perm: Arc<crate::reorder::Permutation>,
    /// Half-bandwidth of the kernel's symmetric pattern as given.
    pub hbw_before: usize,
    /// Half-bandwidth under the RCM permutation.
    pub hbw_after: usize,
}

impl ReorderPlan {
    /// Does the permutation actually tighten the band? (An already
    /// well-ordered matrix gains nothing and should skip the permute /
    /// un-permute cost.)
    pub fn improves(&self) -> bool {
        self.hbw_after < self.hbw_before
    }
}

/// An immutable, shareable scheduling plan for one matrix × thread-count.
#[derive(Clone, Debug)]
pub struct SpmvPlan {
    pub n: usize,
    pub nthreads: usize,
    pub kernel_name: &'static str,
    pub pieces: PlanPieces,
    /// nnz-guided contiguous row blocks (thread t owns `part.block(t)`).
    pub part: RowPartition,
    /// Per-thread effective range (`pieces.ranges`).
    pub eff: Option<Vec<Range<usize>>>,
    /// Buffers covering each thread's owned block (`pieces.ranges`).
    pub covering: Option<Vec<Vec<usize>>>,
    /// Interval decomposition + per-thread assignment (`pieces.intervals`).
    pub ints: Option<Vec<Interval>>,
    pub int_assign: Option<Vec<Vec<usize>>>,
    /// Conflict-free color classes + per-class thread shares
    /// (`pieces.coloring`).
    pub colors: Option<ColorClasses>,
    pub color_shares: Option<Vec<Vec<(usize, usize)>>>,
    /// RCM reorder analysis (`pieces.reorder`).
    pub reorder: Option<ReorderPlan>,
    pub stats: PlanStats,
}

impl SpmvPlan {
    /// Convenience: build the exact plan `kind` needs.
    pub fn for_engine(kind: EngineKind, kernel: &dyn SpmvKernel, nthreads: usize) -> Arc<SpmvPlan> {
        Arc::new(PlanBuilder::for_kind(nthreads, kind).build(kernel))
    }

    /// Scatter-buffer bytes a k-wide local-buffers product backs under
    /// this plan: `Σ_t |eff[t]| · k · 8` with windowed buffers (the
    /// effective ranges present), `p·n·k·8` for the full-length
    /// fallback, 0 when a single thread bypasses buffers entirely.
    pub fn windowed_buffer_bytes(&self, k: usize) -> usize {
        assert!(k >= 1);
        if self.nthreads <= 1 {
            return 0;
        }
        let slots = match &self.eff {
            Some(eff) => eff.iter().map(|r| r.len()).sum::<usize>(),
            None => self.nthreads * self.n,
        };
        slots * k * 8
    }

    /// Check every structural invariant against the kernel the plan was
    /// built for. Used by the property tests and by debug assertions.
    pub fn validate(&self, kernel: &dyn SpmvKernel) -> Result<(), String> {
        let n = kernel.dim();
        if n != self.n {
            return Err(format!("plan n {} != kernel n {}", self.n, n));
        }
        self.part.validate(n)?;
        if self.part.nthreads() != self.nthreads {
            return Err("partition thread count mismatch".into());
        }
        let p = self.nthreads;
        if let Some(eff) = &self.eff {
            for t in 0..p {
                let own = self.part.block(t);
                let er = &eff[t];
                if er.start > own.start || er.end != own.end {
                    return Err(format!("eff {er:?} does not extend block {own:?}"));
                }
                // Every write of the block must land inside the range.
                for i in own {
                    if kernel.row_write_lo(i) < er.start {
                        return Err(format!("row {i} writes below eff range {er:?}"));
                    }
                }
            }
            let covering = self.covering.as_ref().ok_or("ranges without covering")?;
            for t in 0..p {
                if !self.part.block(t).is_empty() && !covering[t].contains(&t) {
                    return Err(format!("covering[{t}] misses the owner"));
                }
            }
        }
        if let Some(ints) = &self.ints {
            let eff = self.eff.as_ref().ok_or("intervals without ranges")?;
            // Disjoint, sorted, and exactly tiling the union of ranges.
            let mut hits = vec![0usize; n];
            for int in ints {
                for i in int.range.clone() {
                    hits[i] += 1;
                }
            }
            for (t, er) in eff.iter().enumerate() {
                for i in er.clone() {
                    if hits[i] != 1 {
                        return Err(format!("row {i} (thread {t}) covered {}×", hits[i]));
                    }
                    if !ints
                        .iter()
                        .any(|int| int.range.contains(&i) && int.covers.contains(&t))
                    {
                        return Err(format!("row {i}: interval misses buffer {t}"));
                    }
                }
            }
            let assign = self.int_assign.as_ref().ok_or("intervals without assignment")?;
            let mut seen = vec![false; ints.len()];
            for owned in assign {
                for &idx in owned {
                    if seen[idx] {
                        return Err(format!("interval {idx} assigned twice"));
                    }
                    seen[idx] = true;
                }
            }
            if let Some(idx) = seen.iter().position(|&s| !s) {
                return Err(format!("interval {idx} unassigned"));
            }
        }
        if let Some(r) = &self.reorder {
            if r.perm.len() != n {
                return Err(format!("reorder perm length {} != n {n}", r.perm.len()));
            }
        }
        if let Some(colors) = &self.colors {
            let g = ConflictGraph::build(kernel);
            colors.validate(&g)?;
            let shares = self.color_shares.as_ref().ok_or("colors without shares")?;
            for (class, share) in colors.classes.iter().zip(shares) {
                if share.len() != p
                    || share[0].0 != 0
                    || share.last().unwrap().1 != class.len()
                    || share.windows(2).any(|w| w[0].1 != w[1].0)
                {
                    return Err(format!("class shares malformed: {share:?}"));
                }
            }
        }
        Ok(())
    }
}

/// Builds [`SpmvPlan`]s, computing only the requested pieces.
#[derive(Clone, Copy, Debug)]
pub struct PlanBuilder {
    nthreads: usize,
    pieces: PlanPieces,
}

impl PlanBuilder {
    /// Base plan: the nnz-guided row partition only.
    pub fn new(nthreads: usize) -> PlanBuilder {
        assert!(nthreads > 0);
        PlanBuilder { nthreads, pieces: PlanPieces::default() }
    }

    /// Everything — what the coordinator caches so any engine can share.
    pub fn all(nthreads: usize) -> PlanBuilder {
        PlanBuilder::new(nthreads).with_pieces(PlanPieces::all())
    }

    /// Exactly the pieces one engine kind needs.
    pub fn for_kind(nthreads: usize, kind: EngineKind) -> PlanBuilder {
        PlanBuilder::new(nthreads).with_pieces(PlanPieces::for_kind(kind))
    }

    pub fn with_pieces(mut self, pieces: PlanPieces) -> PlanBuilder {
        self.pieces = self.pieces.union(pieces);
        self
    }

    pub fn ranges(self) -> PlanBuilder {
        self.with_pieces(PlanPieces { ranges: true, ..Default::default() })
    }

    pub fn intervals(self) -> PlanBuilder {
        self.with_pieces(PlanPieces { intervals: true, ..Default::default() })
    }

    pub fn coloring(self) -> PlanBuilder {
        self.with_pieces(PlanPieces { coloring: true, ..Default::default() })
    }

    /// Request the RCM reorder analysis (permutation + half-bandwidth
    /// before/after in the plan and `reorder_s` in the stats).
    pub fn reorder(self) -> PlanBuilder {
        self.with_pieces(PlanPieces { reorder: true, ..Default::default() })
    }

    pub fn nthreads(&self) -> usize {
        self.nthreads
    }

    pub fn pieces(&self) -> PlanPieces {
        self.pieces
    }

    pub fn build(&self, kernel: &dyn SpmvKernel) -> SpmvPlan {
        let _span = crate::obs::phase(crate::obs::Phase::PlanBuild);
        let t_all = Instant::now();
        let p = self.nthreads;
        let n = kernel.dim();
        let mut stats = PlanStats::default();

        let (part, dt) = metrics::timed(|| partition::nnz_balanced(kernel, p));
        stats.partition_s = dt;

        let (mut eff, mut covering) = (None, None);
        if self.pieces.ranges {
            let ((ranges, cov), dt) = metrics::timed(|| {
                let ranges: Vec<Range<usize>> =
                    (0..p).map(|t| partition::effective_range(kernel, part.block(t))).collect();
                let cov: Vec<Vec<usize>> = (0..p)
                    .map(|t| {
                        let own = part.block(t);
                        (0..p)
                            .filter(|&b| ranges[b].start < own.end && own.start < ranges[b].end)
                            .collect()
                    })
                    .collect();
                (ranges, cov)
            });
            stats.ranges_s = dt;
            eff = Some(ranges);
            covering = Some(cov);
        }

        let (mut ints, mut int_assign) = (None, None);
        if self.pieces.intervals {
            let ((decomposition, assign), dt) = metrics::timed(|| {
                let decomposition = partition::intervals(eff.as_ref().unwrap());
                let assign = partition::assign_intervals(&decomposition, p);
                (decomposition, assign)
            });
            stats.intervals_s = dt;
            ints = Some(decomposition);
            int_assign = Some(assign);
        }

        let (mut colors, mut color_shares) = (None, None);
        if self.pieces.coloring {
            let ((classes, shares), dt) = metrics::timed(|| {
                let g = ConflictGraph::build(kernel);
                let classes = greedy_coloring(&g, ColorOrdering::Natural);
                let shares = classes.class_shares(kernel, p);
                (classes, shares)
            });
            stats.coloring_s = dt;
            colors = Some(classes);
            color_shares = Some(shares);
        }

        let mut reorder = None;
        if self.pieces.reorder {
            let (rp, dt) = metrics::timed(|| crate::reorder::analyze(kernel));
            stats.reorder_s = dt;
            reorder = Some(rp);
        }

        stats.total_s = t_all.elapsed().as_secs_f64();
        SpmvPlan {
            n,
            nthreads: p,
            kernel_name: kernel.kernel_name(),
            pieces: self.pieces,
            part,
            eff,
            covering,
            ints,
            int_assign,
            colors,
            color_shares,
            reorder,
            stats,
        }
    }

    /// Build with a caller-provided coloring (stride-capped ablations,
    /// tests) instead of the default greedy one.
    pub fn build_with_coloring(&self, kernel: &dyn SpmvKernel, colors: ColorClasses) -> SpmvPlan {
        let without = PlanBuilder {
            nthreads: self.nthreads,
            pieces: PlanPieces { coloring: false, ..self.pieces },
        };
        let mut plan = without.build(kernel);
        let t = Instant::now();
        plan.color_shares = Some(colors.class_shares(kernel, self.nthreads));
        plan.colors = Some(colors);
        plan.stats.coloring_s = t.elapsed().as_secs_f64();
        plan.stats.total_s += plan.stats.coloring_s;
        plan.pieces.coloring = true;
        plan
    }
}

/// Concurrent plan cache: matrix-key → shared plan, one build per
/// (matrix, thread-count) no matter how many workers or engines ask.
///
/// The map lock is held *across* the build on purpose: a cold key asked
/// for by many workers at once must still be analyzed exactly once (the
/// single-build guarantee the service test asserts); plan builds are rare
/// and bounded, so the coarse critical section is fine.
#[derive(Default)]
pub struct PlanCache {
    map: Mutex<HashMap<String, Arc<SpmvPlan>>>,
    builds: AtomicU64,
    build_ns: AtomicU64,
}

impl PlanCache {
    pub fn new() -> PlanCache {
        PlanCache::default()
    }

    /// Fetch the plan for `key` (a caller-chosen matrix identifier),
    /// building it on first use. A cached plan missing a newly requested
    /// piece is rebuilt with the union of pieces and replaced.
    pub fn get_or_build(
        &self,
        key: &str,
        kernel: &dyn SpmvKernel,
        builder: PlanBuilder,
    ) -> Arc<SpmvPlan> {
        let full_key = format!("{key}#p{}", builder.nthreads());
        let mut map = self.map.lock().unwrap();
        let mut want = builder;
        if let Some(plan) = map.get(&full_key) {
            if plan.pieces.covers(builder.pieces()) {
                return plan.clone();
            }
            want = want.with_pieces(plan.pieces);
        }
        let t = Instant::now();
        let plan = Arc::new(want.build(kernel));
        self.builds.fetch_add(1, Ordering::Relaxed);
        self.build_ns.fetch_add(t.elapsed().as_nanos() as u64, Ordering::Relaxed);
        map.insert(full_key, plan.clone());
        plan
    }

    /// Drop every plan cached for `key` (matrix replaced / unregistered).
    pub fn invalidate(&self, key: &str) {
        let prefix = format!("{key}#p");
        self.map.lock().unwrap().retain(|k, _| !k.starts_with(&prefix));
    }

    /// Drop every plan whose caller key starts with `prefix` — e.g. all
    /// generations of one matrix at once. Over-matching is safe (it only
    /// costs a rebuild), so callers may use a coarse prefix.
    pub fn invalidate_prefix(&self, prefix: &str) {
        self.map.lock().unwrap().retain(|k, _| !k.starts_with(prefix));
    }

    /// Drop every plan cached for `key` at a thread count other than
    /// `keep`. A thread-count sweep ([`crate::tuner::sweep`]) builds one
    /// plan per ladder rung; once the winning p is known the other
    /// rungs' analyses are dead weight — engines already holding an
    /// `Arc` to a dropped plan are unaffected.
    pub fn invalidate_other_threads(&self, key: &str, keep: usize) {
        let keep_key = format!("{key}#p{keep}");
        let prefix = format!("{key}#p");
        self.map.lock().unwrap().retain(|k, _| !k.starts_with(&prefix) || *k == keep_key);
    }

    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// How many plans were ever built (cache misses).
    pub fn builds(&self) -> u64 {
        self.builds.load(Ordering::Relaxed)
    }

    /// Total wall-clock seconds spent building plans.
    pub fn build_seconds(&self) -> f64 {
        self.build_ns.load(Ordering::Relaxed) as f64 * 1e-9
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::{Coo, Csr, Csrc};
    use crate::util::{propcheck, Rng};

    fn mat(n: usize, npr: usize, seed: u64) -> Csrc {
        let mut rng = Rng::new(seed);
        Csrc::from_coo(&Coo::random_structurally_symmetric(n, npr, false, &mut rng)).unwrap()
    }

    #[test]
    fn pieces_union_and_covers() {
        let base = PlanPieces::default();
        let ranged = PlanPieces { ranges: true, ..Default::default() };
        let interval = PlanPieces { intervals: true, ..Default::default() };
        assert!(PlanPieces::all().covers(ranged));
        assert!(!base.covers(ranged));
        // intervals imply ranges after union.
        assert!(base.union(interval).ranges);
        assert!(ranged.union(base).covers(ranged));
    }

    #[test]
    fn for_kind_requests_the_right_pieces() {
        use crate::parallel::{AccumMethod, EngineKind};
        assert_eq!(PlanPieces::for_kind(EngineKind::Sequential), PlanPieces::default());
        assert!(PlanPieces::for_kind(EngineKind::LocalBuffers(AccumMethod::Effective)).ranges);
        // Windowed buffers: even the methods that ignore effective
        // ranges for *scheduling* need them for buffer sizing.
        assert!(PlanPieces::for_kind(EngineKind::LocalBuffers(AccumMethod::AllInOne)).ranges);
        assert!(PlanPieces::for_kind(EngineKind::LocalBuffers(AccumMethod::PerBuffer)).ranges);
        let p = PlanPieces::for_kind(EngineKind::LocalBuffers(AccumMethod::Interval));
        assert!(p.ranges && p.intervals);
        assert!(PlanPieces::for_kind(EngineKind::Colorful).coloring);
        assert_eq!(PlanPieces::for_kind(EngineKind::Auto), PlanPieces::all());
        // Reorder is policy-driven, never engine-required.
        for kind in EngineKind::all() {
            assert!(!PlanPieces::for_kind(kind).reorder, "{}", kind.label());
        }
    }

    #[test]
    fn reorder_stage_records_permutation_and_bandwidth() {
        let mut rng = Rng::new(7);
        // A shuffled band: RCM must find a much tighter ordering.
        let band = Csrc::from_coo(&Coo::banded(150, 2, false, &mut rng)).unwrap();
        let shuffle =
            crate::reorder::Permutation::from_new_to_old(rng.permutation(150)).unwrap();
        let shuffled = band.permuted(&shuffle);
        let plan = PlanBuilder::new(3).reorder().build(&shuffled);
        plan.validate(&shuffled).unwrap();
        let r = plan.reorder.as_ref().expect("reorder piece requested");
        assert_eq!(r.hbw_before, shuffled.half_bandwidth());
        assert!(r.improves(), "{} -> {}", r.hbw_before, r.hbw_after);
        assert!(r.hbw_after <= r.hbw_before / 2);
        assert!(plan.stats.reorder_s >= 0.0);
        // The recorded bandwidth matches the actually permuted matrix.
        let restored = shuffled.permuted(&r.perm);
        assert_eq!(restored.half_bandwidth(), r.hbw_after);
        // Plans without the piece stay reorder-free.
        assert!(PlanBuilder::all(3).build(&shuffled).reorder.is_none());
    }

    #[test]
    fn full_plan_validates_on_csrc_and_csr() {
        let a = mat(150, 4, 1);
        let csr = a.to_csr();
        for p in [1usize, 2, 3, 5] {
            let plan = PlanBuilder::all(p).build(&a);
            plan.validate(&a).unwrap();
            assert_eq!(plan.kernel_name, "csrc");
            let plan = PlanBuilder::all(p).build(&csr);
            plan.validate(&csr).unwrap();
            // No scatters: every effective range is exactly the block.
            for t in 0..p {
                assert_eq!(plan.eff.as_ref().unwrap()[t], plan.part.block(t));
            }
            // No conflicts: a single color.
            assert_eq!(plan.colors.as_ref().unwrap().num_colors(), 1);
        }
    }

    #[test]
    fn partial_plans_omit_pieces() {
        let a = mat(80, 3, 2);
        let base = PlanBuilder::new(3).build(&a);
        assert!(base.eff.is_none() && base.ints.is_none() && base.colors.is_none());
        let ranged = PlanBuilder::new(3).ranges().build(&a);
        assert!(ranged.eff.is_some() && ranged.ints.is_none());
        let interval = PlanBuilder::new(3).intervals().build(&a);
        assert!(interval.eff.is_some() && interval.ints.is_some());
        interval.validate(&a).unwrap();
    }

    #[test]
    fn plan_records_build_time() {
        let a = mat(200, 5, 3);
        let plan = PlanBuilder::all(4).build(&a);
        assert!(plan.stats.total_s > 0.0);
        assert!(plan.stats.total_s >= plan.stats.coloring_s);
    }

    #[test]
    fn property_plan_invariants_hold() {
        propcheck::check(12, |rng| {
            let n = 10 + rng.below(150);
            let npr = 1 + rng.below(6);
            let coo = Coo::random_structurally_symmetric(n, npr, rng.below(2) == 0, rng);
            let a = Csrc::from_coo(&coo).map_err(|e| e.to_string())?;
            let p = 1 + rng.below(8);
            PlanBuilder::all(p).build(&a).validate(&a)?;
            let csr = Csr::from_coo(&coo);
            PlanBuilder::all(p).build(&csr).validate(&csr)?;
            Ok(())
        });
    }

    #[test]
    fn cache_builds_once_and_invalidates() {
        let a = mat(100, 3, 4);
        let cache = PlanCache::new();
        let p1 = cache.get_or_build("m", &a, PlanBuilder::for_kind(2, EngineKind::Atomic));
        let p2 = cache.get_or_build("m", &a, PlanBuilder::for_kind(2, EngineKind::Atomic));
        assert!(Arc::ptr_eq(&p1, &p2));
        assert_eq!(cache.builds(), 1);
        // A new piece forces one upgrade rebuild, which then covers both.
        let p3 = cache.get_or_build("m", &a, PlanBuilder::for_kind(2, EngineKind::Colorful));
        assert!(p3.colors.is_some());
        assert_eq!(cache.builds(), 2);
        let p4 = cache.get_or_build("m", &a, PlanBuilder::for_kind(2, EngineKind::Atomic));
        assert!(Arc::ptr_eq(&p3, &p4));
        // Different thread count = different plan.
        cache.get_or_build("m", &a, PlanBuilder::for_kind(3, EngineKind::Atomic));
        assert_eq!(cache.builds(), 3);
        assert_eq!(cache.len(), 2);
        assert!(cache.build_seconds() >= 0.0);
        cache.invalidate("m");
        assert!(cache.is_empty());
        // Prefix invalidation sweeps every related key at once.
        cache.get_or_build("k@0", &a, PlanBuilder::for_kind(2, EngineKind::Atomic));
        cache.get_or_build("k@1", &a, PlanBuilder::for_kind(2, EngineKind::Atomic));
        cache.invalidate_prefix("k@");
        assert!(cache.is_empty());
    }

    #[test]
    fn invalidate_other_threads_keeps_the_winner() {
        let a = mat(80, 3, 6);
        let cache = PlanCache::new();
        for p in [1usize, 2, 4] {
            cache.get_or_build("m@0", &a, PlanBuilder::new(p));
        }
        cache.get_or_build("other", &a, PlanBuilder::new(4));
        assert_eq!(cache.len(), 4);
        cache.invalidate_other_threads("m@0", 2);
        assert_eq!(cache.len(), 2, "only the winning rung and unrelated keys survive");
        // The kept plan is still served from cache, losers rebuild.
        cache.get_or_build("m@0", &a, PlanBuilder::new(2));
        assert_eq!(cache.builds(), 4);
        cache.get_or_build("m@0", &a, PlanBuilder::new(4));
        assert_eq!(cache.builds(), 5);
    }

    #[test]
    fn builder_coloring_override_is_used() {
        use crate::graph::{stride_capped_coloring, ConflictGraph};
        let a = mat(90, 3, 5);
        let g = ConflictGraph::build(&a);
        let capped = stride_capped_coloring(&g, 8);
        let k = capped.num_colors();
        let plan = PlanBuilder::new(3).build_with_coloring(&a, capped);
        assert_eq!(plan.colors.as_ref().unwrap().num_colors(), k);
        plan.validate(&a).unwrap();
    }
}
