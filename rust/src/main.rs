//! `csrc` — the command-line front end.
//!
//! Subcommands:
//!
//! * `info    --matrix <name|file.mtx>`              — format statistics
//! * `gen     --kind poisson3d --nx 40 --out a.mtx`  — generate a matrix
//! * `spmv    --matrix <..> --engine effective --threads 4 --products 100`
//! * `solve   --matrix <..> --solver cg|gmres|bicg|block-cg [--rhs K]`
//! * `serve   --requests 64 [--metrics-addr 127.0.0.1:9464] [--chaos <spec>]` — coordinator demo
//! * `trace   --matrix <..> [--rhs K] [--out trace.json]` — traced product
//! * `xla     --artifacts artifacts`                 — run the AOT path
//! * `tune train --corpus <dir> --model model.json`  — fit the cost model
//! * `figures <table1|fig4|fig5|fig6|fig7|fig8|fig9|table2|plan|spmm|model|all>`
//!            `[--suite quick|full|smoke] [--out results]`

use csrc_spmv::coordinator::{MatvecService, ServiceConfig, ShardConfig, ShardedMatvecService};
use csrc_spmv::faults;
use csrc_spmv::gen;
use csrc_spmv::harness::{self, figures, Report};
use csrc_spmv::metrics;
use csrc_spmv::obs;
use csrc_spmv::parallel::{build_engine, EngineKind};
use csrc_spmv::plan::{PlanBuilder, PlanCache};
use csrc_spmv::reorder::ReorderPolicy;
use csrc_spmv::runtime::XlaRuntime;
use csrc_spmv::simulator::MachineConfig;
use csrc_spmv::solver;
use csrc_spmv::sparse::{mmio, Coo, Csrc, LinOp, SpmvKernel};
use csrc_spmv::tuner;
use csrc_spmv::util::cli::Args;
use csrc_spmv::util::error::{msg, Result};
use csrc_spmv::util::Rng;
use std::path::Path;
use std::sync::Arc;

fn main() {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        usage_and_exit();
    }
    let cmd = argv.remove(0);
    let args = Args::parse(argv);
    let result = match cmd.as_str() {
        "info" => cmd_info(&args),
        "gen" => cmd_gen(&args),
        "spmv" => cmd_spmv(&args),
        "tune" => cmd_tune(&args),
        "reorder" => cmd_reorder(&args),
        "solve" => cmd_solve(&args),
        "serve" => cmd_serve(&args),
        "trace" => cmd_trace(&args),
        "xla" => cmd_xla(&args),
        "figures" => cmd_figures(&args),
        "help" | "--help" | "-h" => {
            usage_and_exit();
        }
        other => Err(msg(format!("unknown subcommand {other:?} (try `csrc help`)"))),
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn usage_and_exit() -> ! {
    eprintln!(
        "csrc — parallel structurally-symmetric SpMV (CSRC), Batista et al. 2010 reproduction\n\
         \n\
         usage: csrc <info|gen|spmv|tune|reorder|solve|serve|trace|xla|figures> [options]\n\
         \n\
         csrc info    --matrix <dataset-name|file.mtx>\n\
         csrc gen     --kind <poisson2d|poisson3d|elasticity|band|random|dense> --nx N --out a.mtx\n\
                      [--shuffle] (randomly renumber rows/cols — destroys band structure)\n\
         csrc spmv    --matrix <..> --engine <seq|all-in-one|per-buffer|effective|interval|colorful|atomic>\n\
                      --threads P --products K\n\
         csrc tune    --matrix <..> [--threads P] [--runs R] [--products K]\n\
                      [--cache decisions.json] [--sweep-threads] [--report sweep.json]\n\
                      [--reorder never|measure|always] [--model model.json]\n\
         csrc tune train --corpus <dir|decisions.json> --model model.json\n\
         csrc reorder --matrix <..> [--threads P] [--out rcm.mtx]\n\
         csrc solve   --matrix <..> --solver <cg|gmres|bicg|block-cg> [--tol 1e-10]\n\
                      [--rhs K] [--engine <kind>] [--threads P] (block-cg: K right-hand sides,\n\
                      one blocked spmv_multi product per iteration)\n\
         csrc serve   [--requests N] [--workers W] [--engine auto] [--min-parallel-n N]\n\
                      [--sweep-threads] [--reorder never|measure|always] [--model model.json]\n\
                      [--shards S] (row-block shard the service: one private service per shard\n\
                      behind a scatter/gather front with bounded per-shard queues)\n\
                      [--metrics-addr HOST:PORT] (Prometheus text endpoint; port 0 = pick free)\n\
                      [--linger-ms T] (keep serving scrapes T ms after the demo requests)\n\
                      [--chaos <point:rate,...>] (arm deterministic fault injection — points:\n\
                      worker-panic, shard-stall, queue-full, deadline-blow, cache-io; options\n\
                      stall-ms:N, seed:N — see DESIGN.md §14; sharded runs verify every\n\
                      completed answer against a sequential oracle and balance the books)\n\
                      [--deadline-ms T] (per-reply gather deadline for the sharded front)\n\
         csrc trace   --matrix <..> [--engine <kind>] [--threads P] [--rhs K] [--out trace.json]\n\
                      [--shards S] (trace one product through the sharded front instead:\n\
                      scatter/gather spans plus per-shard serve spans on distinct tids)\n\
                      [--chaos <spec>] [--deadline-ms T] (chaos-armed sharded trace: a few\n\
                      products so breaker/degraded/restart spans land in the dump)\n\
                      (run one traced product; prints the per-phase breakdown and writes a\n\
                      chrome://tracing JSON dump, validated against the event schema)\n\
         csrc xla     [--artifacts artifacts] [--name spmv_n256_w8]\n\
         csrc figures <table1|fig4|fig5|fig6|fig7|fig8|fig9|table2|plan|tune|sweep|reorder|spmm|model|obs|shard|faults|all>\n\
                      [--suite smoke|quick|full] [--out results] [--model model.json]\n\
                      [--chaos <spec>] (faults table: override the default chaos spec)"
    );
    std::process::exit(2);
}

/// Resolve `--matrix`: a dataset entry name or an .mtx path.
fn load_matrix(args: &Args) -> Result<(String, Csrc)> {
    let spec = args
        .opt("matrix")
        .ok_or_else(|| msg("--matrix <dataset-name|file.mtx> required"))?;
    if spec.ends_with(".mtx") {
        let coo = mmio::read_matrix_market(Path::new(spec))?;
        let m = Csrc::from_coo(&coo).map_err(msg)?;
        return Ok((spec.to_string(), m));
    }
    let entry = harness::full_suite()
        .into_iter()
        .find(|e| e.name == spec)
        .ok_or_else(|| msg(format!("unknown dataset matrix {spec:?} (see `csrc figures table1`)")))?;
    Ok((spec.to_string(), entry.build_csrc()))
}

fn cmd_info(args: &Args) -> Result<()> {
    let (name, m) = load_matrix(args)?;
    println!("matrix        : {name}");
    println!("n             : {}", m.n);
    println!("nnz           : {}", m.nnz());
    println!("nnz/n         : {:.1}", m.nnz() as f64 / m.n as f64);
    println!("k (pairs)     : {}", m.k());
    println!("numeric sym   : {}", m.numeric_symmetric);
    println!("half-bandwidth: {}", m.half_bandwidth());
    println!("max row width : {}", m.max_row_width());
    println!("working set   : {} KB", m.working_set_bytes() / 1024);
    println!("flops/product : {}", m.flops());
    println!(
        "loads/product : {}  (load:flop {:.3})",
        m.loads(),
        m.loads() as f64 / m.flops() as f64
    );
    let g = csrc_spmv::graph::ConflictGraph::build(&m);
    println!("conflicts     : {} direct, {} indirect", g.direct_edges(), g.indirect_edges());
    let colors = csrc_spmv::graph::greedy_coloring(&g, csrc_spmv::graph::Ordering::Natural);
    println!("colors        : {}", colors.num_colors());
    Ok(())
}

fn cmd_gen(args: &Args) -> Result<()> {
    let kind = args.opt_or("kind", "poisson2d");
    let nx = args.usize_or("nx", 40);
    let n = args.usize_or("n", 10000);
    let seed = args.usize_or("seed", 1) as u64;
    let conv = args.f64_or("convection", 0.0);
    let out = args.opt_or("out", "matrix.mtx");
    let mut coo = match kind {
        "poisson2d" => gen::poisson_2d_quad(nx, conv, seed),
        "poisson2d-tri" => gen::poisson_2d_tri(nx, conv, seed),
        "poisson3d" => gen::poisson_3d_hex(nx, conv, seed),
        "elasticity" => gen::elasticity_2d(nx, seed),
        "band" => {
            let mut rng = Rng::new(seed);
            Coo::banded(n, args.usize_or("hbw", 2), !args.has_flag("nonsym"), &mut rng)
        }
        "random" => {
            let mut rng = Rng::new(seed);
            Coo::random_structurally_symmetric(
                n,
                args.usize_or("nnz-per-row", 5),
                !args.has_flag("nonsym"),
                &mut rng,
            )
        }
        "dense" => {
            let mut rng = Rng::new(seed);
            Coo::dense_random(n.min(2048), &mut rng)
        }
        other => return Err(msg(format!("unknown kind {other:?}"))),
    };
    // `--shuffle`: renumber rows/columns with a random symmetric
    // permutation. Destroys the band structure on purpose — the input
    // the `reorder` command (RCM) is meant to repair.
    if args.has_flag("shuffle") {
        if coo.nrows != coo.ncols {
            return Err(msg("--shuffle needs a square matrix"));
        }
        let mut rng = Rng::new(seed.wrapping_add(0x9e37));
        let perm = rng.permutation(coo.nrows);
        let mut new_of = vec![0u32; coo.nrows];
        for (new, &old) in perm.iter().enumerate() {
            new_of[old] = new as u32;
        }
        for r in &mut coo.rows {
            *r = new_of[*r as usize];
        }
        for c in &mut coo.cols {
            *c = new_of[*c as usize];
        }
        coo.compact();
    }
    mmio::write_matrix_market(Path::new(out), &coo, &format!("csrc gen --kind {kind}"))?;
    println!("wrote {out}: {}x{}, {} nnz", coo.nrows, coo.ncols, coo.nnz());
    Ok(())
}

fn cmd_spmv(args: &Args) -> Result<()> {
    let (name, m) = load_matrix(args)?;
    let kind = EngineKind::parse(args.opt_or("engine", "effective"))
        .ok_or_else(|| msg("bad --engine"))?;
    let threads = args.usize_or("threads", 2);
    let products = args.usize_or("products", figures::products_for(m.nnz()));
    let n = m.n;
    let a = Arc::new(m);
    // Analysis/execution split: build the plan once (reported), then the
    // executor borrows it — the same path the coordinator caches.
    let kernel: Arc<dyn SpmvKernel> = a.clone();
    let plan = Arc::new(PlanBuilder::for_kind(threads, kind).build(kernel.as_ref()));
    println!(
        "plan: kernel={} pieces={:?} built in {:.3} ms",
        plan.kernel_name,
        plan.pieces,
        plan.stats.total_s * 1e3
    );
    let mut engine = build_engine(kind, kernel, plan);
    let x: Vec<f64> = (0..n).map(|i| (i as f64 * 0.001).sin()).collect();
    let mut y = vec![0.0; n];
    let per = metrics::median_of_runs(3, products, || engine.spmv(&x, &mut y));
    println!(
        "{name}: engine={} threads={threads} products={products} -> {:.3} ms/product, {:.1} Mflop/s",
        engine.name(),
        per * 1e3,
        metrics::mflops(a.flops(), per)
    );
    Ok(())
}

/// Autotune: trial every candidate engine on a matrix — with
/// `--sweep-threads`, at every thread count of the 1,2,4,… ladder up to
/// `--threads` — print the trial table(s) and the winner; `--cache`
/// persists the decision so a later `tune` (or a service pointed at the
/// same file) performs zero new trials; `--report` writes the decision
/// (including the sweep surface) as JSON; `--model` consults a trained
/// cost model ([`tuner::CostModel`]) for zero-budget (`--runs 0`)
/// cold starts before the heuristic. `csrc tune train` fits that model
/// from the persisted decision corpus.
fn cmd_tune(args: &Args) -> Result<()> {
    if args.positional.first().map(|s| s.as_str()) == Some("train") {
        return cmd_tune_train(args);
    }
    let (name, m) = load_matrix(args)?;
    let threads = args.usize_or("threads", 4);
    let budget = tuner::TrialBudget {
        runs: args.usize_or("runs", 3),
        products: args.usize_or("products", figures::products_for(m.nnz()).min(100)),
    };
    let flops = m.flops();
    let a = Arc::new(m);
    let kernel: Arc<dyn SpmvKernel> = a.clone();
    let cache = match args.opt("cache") {
        Some(p) => tuner::DecisionCache::open(Path::new(p)),
        None => tuner::DecisionCache::in_memory(),
    };
    let policy = match args.opt("reorder") {
        Some(s) => ReorderPolicy::parse(s)
            .ok_or_else(|| msg("bad --reorder (never|measure|always)"))?,
        None => ReorderPolicy::Never,
    };
    // An unreadable model file warns and degrades to the heuristic.
    let model = args.opt("model").and_then(|p| tuner::CostModel::load(Path::new(p)));
    let (d, hit) = if args.has_flag("sweep-threads") {
        let ladder = tuner::thread_ladder(threads);
        let plans = PlanCache::new();
        let mut plan_for = tuner::cached_plan_provider(&plans, &name, &kernel);
        tuner::resolve_swept_with_model(
            &kernel,
            &ladder,
            &budget,
            &cache,
            &mut plan_for,
            policy,
            model.as_ref(),
        )
    } else {
        let plan = Arc::new(PlanBuilder::all(threads).build(kernel.as_ref()));
        tuner::resolve_with_model(&kernel, &plan, &budget, &cache, policy, model.as_ref())
    };
    println!(
        "{name}: n={} colors={} intervals={} bandwidth={} scatter-ratio={:.3} balance={:.3}",
        d.features.n,
        d.features.colors,
        d.features.intervals,
        d.features.bandwidth,
        d.features.scatter_ratio,
        d.features.balance
    );
    let print_trial = |indent: &str, t: &tuner::TrialResult| {
        println!(
            "{indent}{:<28} {:>10.3} ms/product  {:>9.1} Mflop/s",
            t.label(),
            t.seconds_per_product * 1e3,
            metrics::mflops(flops, t.seconds_per_product)
        );
    };
    if d.sweep.is_empty() {
        for t in &d.trials {
            print_trial("  ", t);
        }
    } else {
        for pt in &d.sweep {
            println!("  p = {}:", pt.nthreads);
            for t in &pt.trials {
                print_trial("    ", t);
            }
        }
    }
    if !d.block_rates.is_empty() {
        println!("  block widths (per-vector rate at the winning engine):");
        for &(bk, rate) in &d.block_rates {
            println!(
                "    k = {bk}: {rate:>9.1} Mflop/s{}",
                if bk == d.block_k { "  <- winner" } else { "" }
            );
        }
    }
    let win = d.trials.iter().find(|t| t.kind == d.kind && t.reordered == d.reorder);
    println!(
        "winner: {} at {} threads, block width {} ({}; tuned in {:.1} ms{})",
        d.label(),
        d.nthreads,
        d.block_k,
        match win {
            Some(w) => format!("{:.1} Mflop/s", metrics::mflops(flops, w.seconds_per_product)),
            None => match d.provenance {
                tuner::Provenance::Model => "model prediction, no trials".to_string(),
                tuner::Provenance::Heuristic => "heuristic pick, no trials".to_string(),
                tuner::Provenance::Measured => "measured, no matching trial recorded".to_string(),
            },
        },
        d.tuned_s * 1e3,
        if hit { "; from decision cache, zero new trials" } else { "" }
    );
    if let Some(report) = args.opt("report") {
        let path = Path::new(report);
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(path, tuner::decision_json(&d).dump())?;
        println!("wrote decision report to {report}");
    }
    Ok(())
}

/// `csrc tune train --corpus <dir|decisions.json> --model <out.json>`:
/// flatten the persisted decision cache(s) — schema v1 and v2 both load
/// — into labeled rows and fit the learned cost model that `tune
/// --model`, `serve --model` and `figures model` consume.
fn cmd_tune_train(args: &Args) -> Result<()> {
    let corpus = args
        .opt("corpus")
        .ok_or_else(|| msg("--corpus <dir|decisions.json> required"))?;
    let out = args.opt_or("model", "model.json");
    let rows = tuner::model::load_corpus(Path::new(corpus))?;
    if rows.is_empty() {
        return Err(msg(format!(
            "corpus {corpus:?} holds no measured decisions (run `csrc tune --cache …` first)"
        )));
    }
    let m = tuner::CostModel::train(&rows)
        .ok_or_else(|| msg("model training failed on a non-empty corpus"))?;
    m.save(Path::new(out))?;
    println!("trained cost model ({}); wrote {out}", m.summary());
    Ok(())
}

/// RCM reorder report: half-bandwidth and working-set bytes before vs
/// after, with the windowed-buffer accounting at `--threads`. `--out`
/// writes the permuted matrix for downstream use.
fn cmd_reorder(args: &Args) -> Result<()> {
    let (name, m) = load_matrix(args)?;
    let threads = args.usize_or("threads", 4);
    let a = Arc::new(m);
    let kernel: Arc<dyn SpmvKernel> = a.clone();
    let plan = PlanBuilder::new(threads).ranges().reorder().build(kernel.as_ref());
    let r = plan.reorder.as_ref().expect("reorder piece requested");
    let permuted = a.permuted(&r.perm);
    let pplan = PlanBuilder::new(threads).ranges().build(&permuted);
    println!("matrix        : {name}");
    println!("n             : {}  nnz {}", a.n, a.nnz());
    println!("half-bandwidth: {} -> {}", r.hbw_before, r.hbw_after);
    println!("ws sequential : {} KB", a.working_set_bytes() / 1024);
    println!(
        "ws parallel   : {} KB -> {} KB ({threads} threads, windowed buffers)",
        a.working_set_bytes_parallel(&plan) / 1024,
        permuted.working_set_bytes_parallel(&pplan) / 1024,
    );
    println!(
        "full buffers  : {} KB (pre-windowing p*n layout)",
        a.working_set_bytes().saturating_add(threads * a.n * 8) / 1024
    );
    println!("rcm analysis  : {:.2} ms", plan.stats.reorder_s * 1e3);
    println!("hbw reduced   : {}", if r.improves() { "yes" } else { "no" });
    if let Some(out) = args.opt("out") {
        let coo = permuted.to_csr().to_coo();
        mmio::write_matrix_market(Path::new(out), &coo, "csrc reorder (RCM-permuted)")?;
        println!("wrote RCM-permuted matrix to {out}");
    }
    Ok(())
}

fn cmd_solve(args: &Args) -> Result<()> {
    let (name, m) = load_matrix(args)?;
    let tol = args.f64_or("tol", 1e-10);
    let which = args.opt_or("solver", "cg");
    let n = m.n;
    let m = Arc::new(m);
    let mut rng = Rng::new(7);
    let xstar: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let mut b = vec![0.0; n];
    m.apply(&xstar, &mut b);
    let t = std::time::Instant::now();
    let (its, res, ok) = match which {
        "cg" => {
            let r = solver::cg(m.as_ref(), &b, None, tol, 10 * n);
            (r.iterations, r.residual, r.converged)
        }
        "gmres" => {
            let r = solver::gmres(m.as_ref(), &b, 50, tol, 200);
            (r.iterations, r.residual, r.converged)
        }
        "bicg" => {
            let r = solver::bicg(m.as_ref(), &b, tol, 10 * n).map_err(msg)?;
            (r.iterations, r.residual, r.converged)
        }
        "block-cg" => {
            // Multi-RHS: k planted solutions, one row-major panel, one
            // blocked engine product per iteration.
            let k = args.usize_or("rhs", 4).max(1);
            let threads = args.usize_or("threads", 2);
            let kind = match args.opt("engine") {
                Some(s) => EngineKind::parse(s)
                    .ok_or_else(|| msg(format!("bad --engine {s:?}")))?,
                None => EngineKind::Colorful,
            };
            let mut xs = vec![0.0; n * k];
            for v in xs.iter_mut() {
                *v = rng.normal();
            }
            let mut bp = vec![0.0; n * k];
            m.apply_multi(&xs, &mut bp, k);
            let kernel: Arc<dyn SpmvKernel> = m.clone();
            let op = solver::EngineLinOp::auto(kind, kernel, threads);
            let r = solver::block_cg(&op, &bp, k, tol, 10 * n);
            println!(
                "{name}: block-cg over {} at {threads} threads, {k} right-hand sides \
                 (one blocked product per iteration)",
                kind.label()
            );
            let worst = r.residuals.iter().cloned().fold(0.0, f64::max);
            (r.iterations, worst, r.converged)
        }
        other => return Err(msg(format!("unknown solver {other:?}"))),
    };
    println!(
        "{name}: {which} {} in {} iterations, residual {res:.3e}, {:.2}s",
        if ok { "converged" } else { "did NOT converge" },
        its,
        t.elapsed().as_secs_f64()
    );
    Ok(())
}

/// `--chaos <spec>` arms the deterministic fault-injection registry
/// (grammar in DESIGN.md §14: `point:rate[,...][,stall-ms:N][,seed:N]`).
/// Returns whether chaos is now on.
fn arm_chaos(args: &Args) -> Result<bool> {
    let Some(spec) = args.opt("chaos") else { return Ok(false) };
    faults::configure(spec).map_err(msg)?;
    faults::set_chaos_enabled(true);
    println!("chaos armed: {}", faults::describe());
    Ok(true)
}

fn cmd_serve(args: &Args) -> Result<()> {
    let requests = args.usize_or("requests", 64);
    arm_chaos(args)?;
    let mut cfg = ServiceConfig { workers: args.usize_or("workers", 2), ..Default::default() };
    // `--engine auto` turns on autotuned routing: each registered matrix
    // is trialed once and served by its measured winner.
    if let Some(k) = args.opt("engine") {
        cfg.route.parallel_kind = EngineKind::parse(k).ok_or_else(|| msg("bad --engine"))?;
    }
    cfg.route.min_parallel_n = args.usize_or("min-parallel-n", cfg.route.min_parallel_n);
    // `--sweep-threads` lets Auto pick the thread count per matrix, too.
    cfg.route.sweep_threads = args.has_flag("sweep-threads");
    // `--reorder measure` lets the tuner race RCM-reordered candidates;
    // `always` serves every parallel request through the RCM ordering.
    if let Some(s) = args.opt("reorder") {
        cfg.route.reorder =
            ReorderPolicy::parse(s).ok_or_else(|| msg("bad --reorder (never|measure|always)"))?;
    }
    // `--model` arms the learned cost model for cold-start resolutions
    // (consulted after the decision cache, before the heuristic).
    if let Some(p) = args.opt("model") {
        cfg.model = Some(std::path::PathBuf::from(p));
    }
    // `--shards N` serves through the sharded front instead: each
    // registered matrix is row-block partitioned and every shard runs a
    // private service built from this same config.
    if let Some(nshards) = args.opt("shards") {
        let nshards: usize = nshards.parse().map_err(|_| msg("bad --shards"))?;
        return serve_sharded(args, nshards.max(1), cfg);
    }
    let svc = MatvecService::start(cfg);
    // `--metrics-addr` exposes the service registry as a Prometheus
    // text endpoint and turns on phase timing so scrapes carry the
    // per-phase totals too.
    if let Some(addr) = args.opt("metrics-addr") {
        obs::set_metrics_enabled(true);
        let bound = obs::serve_metrics(addr, svc.metrics_registry())?;
        println!("metrics: http://{bound}/metrics");
    }
    // Register a few dataset matrices once, remembering their sizes.
    let names = ["thermal", "torsion1", "poisson3Da"];
    let mut sizes = std::collections::HashMap::new();
    for name in names {
        let e = harness::full_suite().into_iter().find(|e| e.name == name).unwrap();
        let m = Arc::new(e.build_csrc());
        sizes.insert(name, m.n);
        svc.register(name, m);
    }
    let mut rng = Rng::new(11);
    let mut handles = Vec::new();
    let t = std::time::Instant::now();
    for i in 0..requests {
        let key = names[i % names.len()];
        let n = sizes[key];
        let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        handles.push(svc.submit(key, x));
    }
    let mut ok = 0;
    for h in handles {
        if h.recv().map(|r| r.is_ok()).unwrap_or(false) {
            ok += 1;
        }
    }
    let dt = t.elapsed().as_secs_f64();
    let s = svc.stats();
    println!(
        "served {ok}/{requests} in {:.3}s ({:.0} req/s); batches={} mean_latency={:.0}us \
         p99={:.0}us plan_builds={} ({:.2} ms analysis)",
        dt,
        requests as f64 / dt,
        s.batches,
        s.mean_latency_us,
        s.p99_latency_us,
        s.plan_builds,
        s.plan_build_seconds * 1e3
    );
    println!(
        "coalesced {} requests into {} blocked products; rcm_builds={}",
        s.coalesced_requests, s.coalesced_products, s.rcm_builds
    );
    if faults::chaos_enabled() {
        println!(
            "chaos: {} panics caught, {} worker restarts",
            s.panics_caught, s.worker_restarts
        );
    }
    if !s.auto_choices.is_empty() {
        println!(
            "autotuned {} matrices in {:.1} ms ({} cache hits, {} model hits, \
             {} heuristic fallbacks, {} drift events, {} re-tunes):",
            s.tunes,
            s.tune_seconds * 1e3,
            s.decision_hits,
            s.model_hits,
            s.model_fallbacks,
            s.drift_events,
            s.retunes
        );
        for ((key, label), (_, p)) in s.auto_choices.iter().zip(&s.chosen_threads) {
            println!("  {key} -> {label} @ {p} threads");
        }
    }
    // `--linger-ms` keeps the process (and the metrics endpoint) alive
    // so an external scraper can read the final counters — the CI obs
    // smoke job curls the endpoint inside this window.
    let linger = args.usize_or("linger-ms", 0);
    if linger > 0 {
        println!("lingering {linger} ms for scrapes");
        std::thread::sleep(std::time::Duration::from_millis(linger as u64));
    }
    svc.shutdown();
    Ok(())
}

/// `csrc serve --shards N`: the same demo through the sharded front —
/// row-block shards, each with a private service, behind the
/// scatter/gather router. The metrics endpoint serves one composed page:
/// front counters (halo gauge, per-shard request/reject/deadline/
/// degraded families, breaker gauges) plus every shard's registry
/// labeled `shard="<i>"`. With `--chaos` armed, every completed answer
/// is verified against a retained sequential oracle and the front's
/// books are balanced at the end — chaos may slow or degrade products,
/// never corrupt or lose them.
fn serve_sharded(args: &Args, nshards: usize, service: ServiceConfig) -> Result<()> {
    let requests = args.usize_or("requests", 64);
    let chaos = faults::chaos_enabled();
    let mut cfg = ShardConfig { nshards, service, ..ShardConfig::default() };
    if let Some(ms) = args.opt("deadline-ms") {
        let ms: u64 = ms.parse().map_err(|_| msg("bad --deadline-ms"))?;
        cfg.deadline = std::time::Duration::from_millis(ms.max(1));
    }
    let svc = ShardedMatvecService::start(cfg);
    if let Some(addr) = args.opt("metrics-addr") {
        obs::set_metrics_enabled(true);
        let bound = svc.serve_metrics(addr)?;
        println!("metrics: http://{bound}/metrics");
    }
    let names = ["thermal", "torsion1", "poisson3Da"];
    let mut sizes = std::collections::HashMap::new();
    let mut oracle = std::collections::HashMap::new();
    for name in names {
        let e = harness::full_suite().into_iter().find(|e| e.name == name).unwrap();
        let m = Arc::new(e.build_csrc());
        sizes.insert(name, m.n);
        svc.register(name, m.clone());
        oracle.insert(name, m);
    }
    let mut rng = Rng::new(11);
    let t = std::time::Instant::now();
    let (mut ok, mut failed, mut wrong) = (0u64, 0u64, 0u64);
    for i in 0..requests {
        let key = names[i % names.len()];
        let n = sizes[key];
        let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        // Retryable rejections (back-pressure, deadline, worker crash)
        // are retried with the error's suggested back-off: a fault that
        // fired must not lose the request. Fatal errors never retry.
        let mut attempts = 0;
        let got = loop {
            match svc.spmv(key, &x) {
                Ok(y) => break Some(y),
                Err(e) if e.is_retryable() && attempts < 10 => {
                    attempts += 1;
                    std::thread::sleep(e.retry_after().unwrap_or_default());
                }
                Err(_) => break None,
            }
        };
        match got {
            Some(y) => {
                ok += 1;
                if chaos {
                    let a = &oracle[key];
                    let mut want = vec![0.0; n];
                    a.apply(&x, &mut want);
                    let bad = y
                        .iter()
                        .zip(&want)
                        .any(|(g, w)| (g - w).abs() > 1e-9 * (1.0 + w.abs()));
                    if bad {
                        wrong += 1;
                    }
                }
            }
            None => failed += 1,
        }
    }
    let dt = t.elapsed().as_secs_f64();
    println!(
        "served {ok}/{requests} across {nshards} shards in {:.3}s ({:.0} req/s); \
         halo={} doubles/product",
        dt,
        requests as f64 / dt,
        svc.halo_doubles()
    );
    if chaos {
        let f = svc.front_stats();
        let lost = f.products.saturating_sub(f.completed + f.rejected);
        println!(
            "chaos: {wrong} wrong answers, {failed} requests failed after retries, \
             {lost} lost requests"
        );
        println!(
            "front: {} products = {} completed + {} rejected; {} degraded, {} retries",
            f.products, f.completed, f.rejected, f.degraded, f.retries
        );
        if wrong > 0 || lost > 0 {
            return Err(msg(format!(
                "chaos verification failed: {wrong} wrong answers, {lost} lost requests"
            )));
        }
    }
    for s in svc.stats() {
        println!(
            "  shard {}: {} col-requests, {} rejects, {} deadline misses, {} degraded \
             (breaker {}); completed={} batches={} plan_builds={} tunes={} \
             panics_caught={} restarts={}",
            s.shard,
            s.requests,
            s.rejects,
            s.deadline_exceeded,
            s.degraded,
            s.breaker.label(),
            s.service.completed,
            s.service.batches,
            s.service.plan_builds,
            s.service.tunes,
            s.service.panics_caught,
            s.service.worker_restarts
        );
    }
    let linger = args.usize_or("linger-ms", 0);
    if linger > 0 {
        println!("lingering {linger} ms for scrapes");
        std::thread::sleep(std::time::Duration::from_millis(linger as u64));
    }
    svc.shutdown();
    Ok(())
}

/// `csrc trace`: run one (multi-vector) product under full tracing,
/// print the per-phase wall-clock breakdown, and write the span events
/// as chrome://tracing JSON (load in `about:tracing` or
/// <https://ui.perfetto.dev>), self-validated against the event schema.
fn cmd_trace(args: &Args) -> Result<()> {
    let (name, m) = load_matrix(args)?;
    arm_chaos(args)?;
    if let Some(nshards) = args.opt("shards") {
        let nshards: usize = nshards.parse().map_err(|_| msg("bad --shards"))?;
        return trace_sharded(args, &name, m, nshards.max(1));
    }
    let kind = EngineKind::parse(args.opt_or("engine", "effective"))
        .ok_or_else(|| msg("bad --engine"))?;
    let threads = args.usize_or("threads", 2);
    let k = args.usize_or("rhs", 4).max(1);
    let n = m.n;
    let a = Arc::new(m);
    let kernel: Arc<dyn SpmvKernel> = a.clone();
    // Trace everything from analysis to the product: plan build (with
    // any reorder stage), then the engine's zero/sweep/accumulate
    // phases across all pool threads.
    obs::reset_phases();
    obs::set_metrics_enabled(true);
    obs::start_trace();
    let plan = Arc::new(PlanBuilder::for_kind(threads, kind).build(kernel.as_ref()));
    let mut engine = build_engine(kind, kernel, plan);
    let x: Vec<f64> = (0..n * k).map(|i| (i as f64 * 0.001).sin()).collect();
    let mut y = vec![0.0; n * k];
    engine.spmv_multi(&x, &mut y, k);
    let engine_name = engine.name();
    drop(engine); // pool threads park; every span is closed
    let events = obs::stop_trace();
    obs::set_metrics_enabled(false);
    println!("{name}: engine={engine_name} threads={threads} k={k}");
    let totals = obs::phase_totals();
    let total_ns: u64 = totals.iter().map(|t| t.ns).sum();
    println!("phase breakdown (plan build + one spmv_multi product):");
    for t in &totals {
        if t.calls == 0 {
            continue;
        }
        println!(
            "  {:<16} {:>5} spans  {:>10.3} ms  {:>5.1}%",
            t.phase.label(),
            t.calls,
            t.ns as f64 / 1e6,
            100.0 * t.ns as f64 / total_ns.max(1) as f64
        );
    }
    let j = obs::trace_to_json(&events);
    let nevents = obs::validate_trace_json(&j).map_err(msg)?;
    let out = args.opt_or("out", "trace.json");
    std::fs::write(Path::new(out), j.dump())?;
    println!(
        "trace valid: {nevents} events ({} begin events dropped at the ring cap); wrote {out}",
        obs::trace_dropped()
    );
    Ok(())
}

/// `csrc trace --shards N`: one traced panel product through the
/// sharded front. The dump carries the front's scatter/gather spans on
/// the caller's thread plus every shard's serve/sweep spans on its own
/// worker tids — the per-shard concurrency is visible in the timeline.
fn trace_sharded(args: &Args, name: &str, m: Csrc, nshards: usize) -> Result<()> {
    let k = args.usize_or("rhs", 4).max(1);
    let n = m.n;
    let a = Arc::new(m);
    let chaos = faults::chaos_enabled();
    let mut cfg = ShardConfig { nshards, ..ShardConfig::default() };
    if let Some(ms) = args.opt("deadline-ms") {
        let ms: u64 = ms.parse().map_err(|_| msg("bad --deadline-ms"))?;
        cfg.deadline = std::time::Duration::from_millis(ms.max(1));
    }
    if chaos {
        // Trip on the first failure so a short traced run shows the
        // breaker transition and a degraded product in its spans.
        cfg.breaker_threshold = 1;
    }
    obs::reset_phases();
    obs::set_metrics_enabled(true);
    obs::start_trace();
    let svc = ShardedMatvecService::start(cfg);
    svc.register(name, a);
    let x: Vec<f64> = (0..n * k).map(|i| (i as f64 * 0.001).sin()).collect();
    // Under chaos a product may be rejected (that is the point) — run a
    // few so the dump also carries the breaker/degraded recovery spans.
    let products = if chaos { 3 } else { 1 };
    let mut served = 0usize;
    for _ in 0..products {
        match svc.spmv_multi(name, &x, k) {
            Ok(_) => served += 1,
            Err(e) if chaos && e.is_retryable() => {
                println!("chaos rejection (expected): {e}");
            }
            Err(e) => return Err(msg(e)),
        }
    }
    if chaos {
        println!("served {served}/{products} products under chaos");
    }
    // Shut the shards down *before* closing the trace: worker and
    // retuner threads exit, so every span they opened is closed.
    svc.shutdown();
    let events = obs::stop_trace();
    obs::set_metrics_enabled(false);
    println!("{name}: sharded front, {nshards} shards, k={k}");
    let totals = obs::phase_totals();
    let total_ns: u64 = totals.iter().map(|t| t.ns).sum();
    println!("phase breakdown (register + one sharded spmv_multi product):");
    for t in &totals {
        if t.calls == 0 {
            continue;
        }
        println!(
            "  {:<16} {:>5} spans  {:>10.3} ms  {:>5.1}%",
            t.phase.label(),
            t.calls,
            t.ns as f64 / 1e6,
            100.0 * t.ns as f64 / total_ns.max(1) as f64
        );
    }
    let j = obs::trace_to_json(&events);
    let nevents = obs::validate_trace_json(&j).map_err(msg)?;
    let out = args.opt_or("out", "trace.json");
    std::fs::write(Path::new(out), j.dump())?;
    println!(
        "trace valid: {nevents} events ({} begin events dropped at the ring cap); wrote {out}",
        obs::trace_dropped()
    );
    Ok(())
}

fn cmd_xla(args: &Args) -> Result<()> {
    let dir = args.opt_or("artifacts", "artifacts");
    let name = args.opt_or("name", "spmv_n256_w8");
    // Without the `xla` cargo feature this returns a clean "rebuild with
    // --features xla" error instead of failing to link.
    let mut rt = XlaRuntime::open(Path::new(dir))?;
    println!("platform: {}", rt.platform());
    let entry = rt
        .manifest
        .find(name)
        .ok_or_else(|| msg(format!("artifact {name:?} not found")))?
        .clone();
    println!("artifact {} (n={}, w={})", entry.name, entry.n, entry.w);
    // Build a matching matrix, run both paths, cross-check.
    let mut rng = Rng::new(3);
    let coo =
        Coo::random_structurally_symmetric(entry.n * 3 / 4, 4.min(entry.w), false, &mut rng);
    let m = Csrc::from_coo(&coo).map_err(msg)?;
    let ell = m
        .to_ell(entry.n, entry.w)
        .ok_or_else(|| msg("matrix does not fit artifact shape"))?;
    let x64: Vec<f64> = (0..entry.n).map(|i| if i < m.n { rng.normal() } else { 0.0 }).collect();
    let x32: Vec<f32> = x64.iter().map(|&v| v as f32).collect();
    let t = std::time::Instant::now();
    let got = rt.spmv(name, &ell, &x32)?;
    let xla_time = t.elapsed().as_secs_f64();
    let mut want = vec![0.0; m.n];
    m.spmv_into_zeroed(&x64[..m.n], &mut want);
    let max_err = (0..m.n)
        .map(|i| (got[i] as f64 - want[i]).abs() / (1.0 + want[i].abs()))
        .fold(0.0, f64::max);
    println!(
        "xla spmv: {:.3} ms (incl. first-call compile), max rel err vs native = {max_err:.2e}",
        xla_time * 1e3
    );
    if max_err >= 1e-3 {
        return Err(msg("XLA/native mismatch"));
    }
    println!("cross-check OK");
    Ok(())
}

fn cmd_figures(args: &Args) -> Result<()> {
    let what = args.positional.first().map(|s| s.as_str()).unwrap_or("all");
    let suite = match args.opt_or("suite", "quick") {
        "smoke" => harness::smoke_suite(),
        "full" => harness::full_suite(),
        _ => harness::quick_suite(),
    };
    let out = args.opt_or("out", "results");
    let report = Report::new(Some(Path::new(out)))?;
    let run_all = what == "all";
    if run_all || what == "table1" {
        // Table 1 always lists the complete 60-entry dataset.
        report.table(
            "table1",
            "Table 1 — dataset",
            &["matrix", "sym", "n", "nnz", "nnz/n", "ws (KB)"],
            &figures::table1(&harness::full_suite()),
        )?;
    }
    if run_all || what == "fig4" {
        report.table(
            "fig4",
            "Fig. 4 — % L2 / TLB misses, CSRC vs CSR (Wolfdale model)",
            &["matrix", "csrc L2 miss%", "csr L2 miss%", "csrc TLB miss%", "csr TLB miss%"],
            &figures::fig4(&suite),
        )?;
    }
    if run_all || what == "fig5" {
        report.table(
            "fig5",
            "Fig. 5 — sequential Mflop/s, CSRC vs CSR (measured on this host)",
            &["matrix", "csrc Mflop/s", "csr Mflop/s", "csrc/csr time ratio"],
            &figures::fig5(&suite),
        )?;
    }
    if run_all || what == "fig6" {
        report.table(
            "fig6",
            "Fig. 6 — colorful vs best local-buffers (simulated speedups)",
            &[
                "matrix",
                "colorful wolf(2t)",
                "best-lb wolf(2t)",
                "colorful bloom(4t)",
                "best-lb bloom(4t)",
                "winner",
            ],
            &figures::fig6(&suite),
        )?;
    }
    if run_all || what == "fig7" {
        report.table(
            "fig7",
            "Fig. 7 — colorful speedups",
            &["matrix", "colors", "wolfdale 2t", "bloomfield 2t", "bloomfield 4t"],
            &figures::fig7(&suite),
        )?;
    }
    if run_all || what == "fig8" {
        let cfg = MachineConfig::wolfdale();
        let headers = figures::fig89_headers(&cfg);
        let h: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
        report.table(
            "fig8",
            "Fig. 8 — local-buffers speedups (Wolfdale model)",
            &h,
            &figures::fig89(&suite, &cfg),
        )?;
    }
    if run_all || what == "fig9" {
        let cfg = MachineConfig::bloomfield();
        let headers = figures::fig89_headers(&cfg);
        let h: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
        report.table(
            "fig9",
            "Fig. 9 — local-buffers speedups (Bloomfield model)",
            &h,
            &figures::fig89(&suite, &cfg),
        )?;
    }
    if run_all || what == "table2" {
        let headers = figures::table2_headers();
        let h: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
        report.table(
            "table2",
            "Table 2 — avg max per-thread init+accumulation overhead",
            &h,
            &figures::table2(&suite),
        )?;
    }
    if run_all || what == "plan" {
        let headers = figures::plan_overview_headers();
        let h: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
        report.table(
            "plan",
            "Plan analysis — shared SpmvPlan cost and shape (4 threads)",
            &h,
            &figures::plan_overview(&suite, 4),
        )?;
    }
    // Trial budget for the tuner-backed tables (`tune`, `sweep`), scaled
    // with the suite so `--suite smoke` stays CI-cheap while `full` gets
    // stable medians.
    let trial_budget = match args.opt_or("suite", "quick") {
        "smoke" => tuner::TrialBudget::smoke(),
        "full" => tuner::TrialBudget::default(),
        _ => tuner::TrialBudget { runs: 2, products: 4 },
    };
    if run_all || what == "tune" {
        let headers = figures::tune_headers();
        let h: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
        report.table(
            "tune",
            "Autotuner — measured per-matrix winner vs the fixed default (4 threads)",
            &h,
            &figures::tune_table(&suite, args.usize_or("threads", 4), &trial_budget),
        )?;
    }
    if run_all || what == "sweep" {
        let p = args.usize_or("threads", 4);
        let headers = figures::sweep_headers(p);
        let h: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
        report.table(
            "sweep",
            "Thread sweep — best rate per thread count and the swept (engine × p) winner",
            &h,
            &figures::sweep_table(&suite, p, &trial_budget),
        )?;
    }
    if run_all || what == "reorder" {
        let p = args.usize_or("threads", 4);
        let headers = figures::reorder_headers();
        let h: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
        report.table(
            "reorder",
            "RCM reordering — half-bandwidth, windowed working set, Mflop/s before/after",
            &h,
            &figures::reorder_table(&suite, p),
        )?;
    }
    if run_all || what == "spmm" {
        let p = args.usize_or("threads", 4);
        let headers = figures::spmm_headers();
        let h: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
        report.table(
            "spmm",
            "SpMM — blocked multi-vector panels vs k serial products (per-vector Mflop/s)",
            &h,
            &figures::spmm_table(&suite, p),
        )?;
    }
    if run_all || what == "model" {
        // With `--model` the supplied file predicts for every matrix;
        // without it each row trains leave-one-out on the rest of the
        // suite's measured decisions — a genuine cross-matrix test.
        let model = args.opt("model").and_then(|p| tuner::CostModel::load(Path::new(p)));
        let headers = figures::model_headers();
        let h: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
        let p = args.usize_or("threads", 4);
        report.table(
            "model",
            "Learned cost model — measured winner vs model/heuristic cold-start picks and regret",
            &h,
            &figures::model_table(&suite, p, &trial_budget, model.as_ref()),
        )?;
    }
    if run_all || what == "shard" {
        let headers = figures::shard_headers();
        let h: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
        report.table(
            "shard",
            "Sharded serving — end-to-end rate and halo volume vs shard count",
            &h,
            &figures::shard_table(&suite),
        )?;
    }
    if run_all || what == "faults" {
        // Chaos is process-wide; the figures binary owns the process,
        // so arming it here races nothing. `--chaos` overrides the
        // default spec.
        let spec = args.opt_or("chaos", figures::FAULTS_SPEC);
        let headers = figures::faults_headers();
        let h: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
        report.table(
            "faults",
            "Fault tolerance — chaos-armed sharded serving: accounting, supervision, correctness",
            &h,
            &figures::faults_table(&suite, spec),
        )?;
    }
    if run_all || what == "obs" {
        // Phase timing must be on for spans to attribute; the table
        // helper itself never toggles the process-wide switch (lib
        // tests call it with instrumentation off).
        let p = args.usize_or("threads", 4);
        let headers = figures::obs_headers();
        let h: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
        obs::set_metrics_enabled(true);
        let rows = figures::obs_table(&suite, p);
        obs::set_metrics_enabled(false);
        report.table(
            "obs",
            "Observability — per-phase time share of one instrumented product run per matrix",
            &h,
            &rows,
        )?;
    }
    println!("wrote results under {out}/");
    Ok(())
}
