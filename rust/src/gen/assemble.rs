//! Parallel FEM re-assembly straight into CSRC value storage.
//!
//! Time-stepping wants the same mesh and pattern with new values every
//! step. Rebuilding through `Coo::compact` + `Csrc::from_coo` costs a
//! sort per step and re-derives an index structure that never changes;
//! here the destination slot of every element contribution is resolved
//! *once* (binary search in the CSRC index arrays) and each step is a
//! pure value scatter, parallelized two ways and raced:
//!
//! * **atomic scatter** — threads take strided element ranges and
//!   CAS-add f64 bit patterns into shared accumulators; no coordination,
//!   contended slots retry.
//! * **colored batches** — elements sharing a node get different colors
//!   (the same greedy machinery the colorful SpMV engines use, §3.2 of
//!   the paper, applied to the element conflict graph); within a class
//!   writes are provably disjoint, so plain stores suffice.
//!
//! The faster variant is measured once ([`Assembler::race`]) and
//! replayed every subsequent step — entered like every other tuned
//! choice in this repo.
//!
//! The element kernel is deterministic and time-parameterized (no RNG,
//! unlike [`super::fem`]): a smooth per-element diffusion coefficient
//! κ(centroid, t) scales inverse-distance weights, so the sequential
//! [`assemble_coo`] oracle, the atomic scatter, and the colored batches
//! all sum exactly the same contribution sets and agree to rounding.

use super::mesh::Mesh;
use crate::graph::{greedy_coloring, ColorClasses, ConflictGraph, Ordering};
use crate::obs::{self, Phase};
use crate::parallel::share::SyncSlice;
use crate::sparse::{Coo, Csrc};
use crate::util::Timer;
use std::sync::atomic::{AtomicU64, Ordering as MemOrder};

/// Destination of one element contribution in CSRC storage, resolved at
/// build time. Slot indices address `al`/`au` (an off-diagonal pair
/// (i, j), j < i lives at one slot: `al[s]` holds A(i,j), `au[s]` holds
/// the mirror A(j,i)).
#[derive(Clone, Copy, Debug)]
enum Slot {
    Diag(u32),
    Lower(u32),
    Upper(u32),
}

/// Which raced variant won.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AssemblyKind {
    Atomic,
    Colored,
}

impl AssemblyKind {
    pub fn label(&self) -> &'static str {
        match self {
            AssemblyKind::Atomic => "atomic",
            AssemblyKind::Colored => "colored",
        }
    }
}

/// One race outcome: both variants timed on the same step.
#[derive(Clone, Copy, Debug)]
pub struct AssemblyRace {
    pub atomic_s: f64,
    pub colored_s: f64,
    pub chosen: AssemblyKind,
    /// Colors the element conflict graph needed (sequential sync points
    /// per colored assembly).
    pub colors: usize,
}

/// Smooth, positive, per-element diffusion coefficient κ(centroid, t) —
/// the time dependence of a transient diffusion problem, deterministic
/// in (mesh, t).
fn kappa(mesh: &Mesh, e: usize, t: f64) -> f64 {
    let el = mesh.elem(e);
    let phase: f64 = el
        .iter()
        .flat_map(|&v| mesh.node_coord(v as usize))
        .sum::<f64>()
        / el.len() as f64;
    1.0 + 0.5 * (0.7 * t + 3.0 * phase).sin()
}

/// Append element `e`'s contributions to `out` in the canonical order
/// the slot table uses: for each local node `a`, its `npe - 1`
/// off-diagonal couplings (in local order), then its diagonal.
fn element_contribs(mesh: &Mesh, e: usize, convection: f64, t: f64, out: &mut Vec<f64>) {
    let el = mesh.elem(e);
    let kap = kappa(mesh, e, t);
    for (a, &va) in el.iter().enumerate() {
        let pa = mesh.node_coord(va as usize);
        let mut diag = 0.0;
        for (b, &vb) in el.iter().enumerate() {
            if a == b {
                continue;
            }
            let pb = mesh.node_coord(vb as usize);
            let d2: f64 = pa.iter().zip(pb).map(|(x, y)| (x - y) * (x - y)).sum();
            let w = 1.0 / d2.sqrt().max(1e-12);
            diag += w;
            // Upwind-biased antisymmetric part, as in `fem::assemble_scalar`.
            let skew = convection * w * if va < vb { 1.0 } else { -1.0 };
            out.push(kap * (-w + skew));
        }
        // +1.0 per element-node incidence keeps the diagonal dominant.
        out.push(kap * diag + 1.0);
    }
}

/// Sequential assembly into a [`Coo`] — the sum oracle both parallel
/// variants are tested against, and the pattern source for
/// [`Assembler::new`]. Same contribution set and order as the scatter
/// paths.
pub fn assemble_coo(mesh: &Mesh, convection: f64, t: f64) -> Coo {
    let n = mesh.num_nodes();
    let npe = mesh.nodes_per_elem;
    let mut coo = Coo::with_capacity(n, n, mesh.num_elems() * npe * npe);
    let mut vals = Vec::with_capacity(npe * npe);
    for e in 0..mesh.num_elems() {
        vals.clear();
        element_contribs(mesh, e, convection, t, &mut vals);
        let el = mesh.elem(e);
        let mut k = 0;
        for (a, &va) in el.iter().enumerate() {
            for (b, &vb) in el.iter().enumerate() {
                if a == b {
                    continue;
                }
                coo.push(va as usize, vb as usize, vals[k]);
                k += 1;
            }
            coo.push(va as usize, va as usize, vals[k]);
            k += 1;
        }
    }
    coo.compact();
    coo
}

/// CAS-add a f64 stored as bits. Relaxed suffices: only the final sums
/// are read, after the `thread::scope` join synchronizes everything.
#[inline]
fn atomic_add(cell: &AtomicU64, v: f64) {
    let mut cur = cell.load(MemOrder::Relaxed);
    loop {
        let next = (f64::from_bits(cur) + v).to_bits();
        match cell.compare_exchange_weak(cur, next, MemOrder::Relaxed, MemOrder::Relaxed) {
            Ok(_) => return,
            Err(now) => cur = now,
        }
    }
}

/// Element conflict graph + coloring: elements conflict iff they share a
/// node (sharing a destination slot — a diagonal or an off-diagonal pair
/// — implies sharing a node, so same-color elements have disjoint write
/// sets).
fn color_elements(mesh: &Mesh) -> ColorClasses {
    let ne = mesh.num_elems();
    let nn = mesh.num_nodes();
    // node -> incident elements, CSR.
    let mut start = vec![0u32; nn + 1];
    for e in 0..ne {
        for &v in mesh.elem(e) {
            start[v as usize + 1] += 1;
        }
    }
    for i in 0..nn {
        start[i + 1] += start[i];
    }
    let mut fill = start.clone();
    let mut node_elems = vec![0u32; start[nn] as usize];
    for e in 0..ne {
        for &v in mesh.elem(e) {
            node_elems[fill[v as usize] as usize] = e as u32;
            fill[v as usize] += 1;
        }
    }
    // element -> conflicting elements, CSR (sorted, deduped).
    let mut xadj = Vec::with_capacity(ne + 1);
    let mut adj: Vec<u32> = Vec::new();
    xadj.push(0u32);
    let mut nbr: Vec<u32> = Vec::new();
    for e in 0..ne {
        nbr.clear();
        for &v in mesh.elem(e) {
            let r = start[v as usize] as usize..start[v as usize + 1] as usize;
            nbr.extend(node_elems[r].iter().filter(|&&f| f as usize != e));
        }
        nbr.sort_unstable();
        nbr.dedup();
        adj.extend_from_slice(&nbr);
        xadj.push(adj.len() as u32);
    }
    // The coloring only walks `n` + `neighbors()`; the direct/indirect
    // split is an SpMV-side notion with no analog here, so leave it
    // empty.
    let g = ConflictGraph {
        n: ne,
        xadj,
        adj,
        xadj_direct: vec![0; ne + 1],
        adj_direct: Vec::new(),
    };
    greedy_coloring(&g, Ordering::Natural)
}

/// Re-assembles FEM values for one fixed (mesh, pattern) into fresh
/// [`Csrc`] matrices, one per time step. Build once, call
/// [`Assembler::assemble`] per step, feed the result to
/// `MatvecService::update_values` — the pattern fingerprint is preserved
/// by construction.
pub struct Assembler {
    mesh: Mesh,
    convection: f64,
    /// The t = 0 assembly; index structure shared by every later step.
    matrix: Csrc,
    /// Destination slot per contribution, element-major, in
    /// [`element_contribs`] order: `npe * npe` entries per element.
    targets: Vec<Slot>,
    colors: ColorClasses,
    choice: Option<AssemblyKind>,
}

impl Assembler {
    /// Assemble the t = 0 matrix (via the sequential oracle), resolve
    /// every contribution's destination slot, and color the element
    /// conflict graph. Fails — typed, no panic — when the mesh is
    /// malformed or its pattern is not CSRC-representable.
    pub fn new(mesh: Mesh, convection: f64) -> Result<Assembler, String> {
        mesh.validate()?;
        let coo = assemble_coo(&mesh, convection, 0.0);
        let matrix = Csrc::from_coo(&coo).map_err(|e| e.to_string())?;
        let npe = mesh.nodes_per_elem;
        let mut targets = Vec::with_capacity(mesh.num_elems() * npe * npe);
        for e in 0..mesh.num_elems() {
            let el = mesh.elem(e);
            for (a, &va) in el.iter().enumerate() {
                for (b, &vb) in el.iter().enumerate() {
                    if a == b {
                        continue;
                    }
                    targets.push(slot_for(&matrix, va as usize, vb as usize)?);
                }
                targets.push(Slot::Diag(va));
            }
        }
        let colors = color_elements(&mesh);
        Ok(Assembler { mesh, convection, matrix, targets, colors, choice: None })
    }

    /// The t = 0 assembly — register this, then `update_values` with
    /// each later step's output.
    pub fn matrix(&self) -> &Csrc {
        &self.matrix
    }

    pub fn num_colors(&self) -> usize {
        self.colors.num_colors()
    }

    /// The raced winner, once [`Assembler::race`] has run.
    pub fn choice(&self) -> Option<AssemblyKind> {
        self.choice
    }

    /// Assemble values at time `t` with the tuned variant, racing both
    /// on first use (like every other tuned choice: measure once, replay
    /// thereafter).
    pub fn assemble(&mut self, t: f64, nthreads: usize) -> Csrc {
        let kind = match self.choice {
            Some(k) => k,
            None => self.race(nthreads).chosen,
        };
        match kind {
            AssemblyKind::Atomic => self.assemble_atomic(t, nthreads),
            AssemblyKind::Colored => self.assemble_colored(t, nthreads),
        }
    }

    /// Time both variants on one representative step and fix the choice.
    pub fn race(&mut self, nthreads: usize) -> AssemblyRace {
        let timer = Timer::start();
        let _ = self.assemble_atomic(0.0, nthreads);
        let atomic_s = timer.elapsed_s();
        let timer = Timer::start();
        let _ = self.assemble_colored(0.0, nthreads);
        let colored_s = timer.elapsed_s();
        let chosen =
            if colored_s < atomic_s { AssemblyKind::Colored } else { AssemblyKind::Atomic };
        self.choice = Some(chosen);
        AssemblyRace { atomic_s, colored_s, chosen, colors: self.colors.num_colors() }
    }

    /// Atomic-scatter variant: strided element ranges per thread,
    /// f64-bit CAS adds into shared accumulators.
    pub fn assemble_atomic(&self, t: f64, nthreads: usize) -> Csrc {
        let _assemble_span = obs::phase(Phase::Assemble);
        let (n, k) = (self.matrix.n, self.matrix.k());
        let ad: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        let al: Vec<AtomicU64> = (0..k).map(|_| AtomicU64::new(0)).collect();
        let au: Vec<AtomicU64> = (0..k).map(|_| AtomicU64::new(0)).collect();
        let ne = self.mesh.num_elems();
        let stride = self.mesh.nodes_per_elem * self.mesh.nodes_per_elem;
        let p = nthreads.clamp(1, ne.max(1));
        std::thread::scope(|scope| {
            for tid in 0..p {
                let (ad, al, au) = (&ad, &al, &au);
                scope.spawn(move || {
                    let mut vals = Vec::with_capacity(stride);
                    for e in (tid..ne).step_by(p) {
                        vals.clear();
                        element_contribs(&self.mesh, e, self.convection, t, &mut vals);
                        let slots = &self.targets[e * stride..(e + 1) * stride];
                        for (s, &v) in slots.iter().zip(&vals) {
                            match *s {
                                Slot::Diag(i) => atomic_add(&ad[i as usize], v),
                                Slot::Lower(s) => atomic_add(&al[s as usize], v),
                                Slot::Upper(s) => atomic_add(&au[s as usize], v),
                            }
                        }
                    }
                });
            }
        });
        let unbits = |v: Vec<AtomicU64>| -> Vec<f64> {
            v.into_iter().map(|c| f64::from_bits(c.into_inner())).collect()
        };
        self.fresh(&unbits(ad), &unbits(al), &unbits(au))
    }

    /// Colored-batches variant: one `thread::scope` per color class;
    /// within a class, elements share no node, hence no destination
    /// slot, so plain read-modify-write stores are race-free.
    pub fn assemble_colored(&self, t: f64, nthreads: usize) -> Csrc {
        let _assemble_span = obs::phase(Phase::Assemble);
        let (n, k) = (self.matrix.n, self.matrix.k());
        let mut ad = vec![0.0; n];
        let mut al = vec![0.0; k];
        let mut au = vec![0.0; k];
        let stride = self.mesh.nodes_per_elem * self.mesh.nodes_per_elem;
        {
            let sad = SyncSlice::new(&mut ad);
            let sal = SyncSlice::new(&mut al);
            let sau = SyncSlice::new(&mut au);
            for class in &self.colors.classes {
                let p = nthreads.clamp(1, class.len().max(1));
                std::thread::scope(|scope| {
                    for tid in 0..p {
                        let (sad, sal, sau) = (&sad, &sal, &sau);
                        let class = class.as_slice();
                        scope.spawn(move || {
                            let mut vals = Vec::with_capacity(stride);
                            for idx in (tid..class.len()).step_by(p) {
                                let e = class[idx] as usize;
                                vals.clear();
                                element_contribs(&self.mesh, e, self.convection, t, &mut vals);
                                let slots = &self.targets[e * stride..(e + 1) * stride];
                                for (s, &v) in slots.iter().zip(&vals) {
                                    // Safety: same-color elements have
                                    // disjoint slot sets (shared slot ⇒
                                    // shared node ⇒ conflict edge), and
                                    // classes are separated by the scope
                                    // join.
                                    unsafe {
                                        match *s {
                                            Slot::Diag(i) => {
                                                *sad.as_mut_ptr().add(i as usize) += v
                                            }
                                            Slot::Lower(s) => {
                                                *sal.as_mut_ptr().add(s as usize) += v
                                            }
                                            Slot::Upper(s) => {
                                                *sau.as_mut_ptr().add(s as usize) += v
                                            }
                                        }
                                    }
                                }
                            }
                        });
                    }
                });
            }
        }
        self.fresh(&ad, &al, &au)
    }

    /// Sequential scatter through the slot table — used by tests to
    /// separate slot-resolution bugs from parallelism bugs.
    pub fn assemble_sequential(&self, t: f64) -> Csrc {
        let (n, k) = (self.matrix.n, self.matrix.k());
        let mut ad = vec![0.0; n];
        let mut al = vec![0.0; k];
        let mut au = vec![0.0; k];
        let stride = self.mesh.nodes_per_elem * self.mesh.nodes_per_elem;
        let mut vals = Vec::with_capacity(stride);
        for e in 0..self.mesh.num_elems() {
            vals.clear();
            element_contribs(&self.mesh, e, self.convection, t, &mut vals);
            let slots = &self.targets[e * stride..(e + 1) * stride];
            for (s, &v) in slots.iter().zip(&vals) {
                match *s {
                    Slot::Diag(i) => ad[i as usize] += v,
                    Slot::Lower(s) => al[s as usize] += v,
                    Slot::Upper(s) => au[s as usize] += v,
                }
            }
        }
        self.fresh(&ad, &al, &au)
    }

    /// Pattern clone + value swap: the output shares the index structure
    /// (and hence the pattern fingerprint) with the t = 0 matrix.
    fn fresh(&self, ad: &[f64], al: &[f64], au: &[f64]) -> Csrc {
        let mut out = self.matrix.clone();
        out.update_values(ad, al, au)
            .expect("assembler accumulators are sized from the pattern");
        out
    }
}

/// Resolve the CSRC slot holding entry (r, c): the off-diagonal pair
/// lives in the *higher* row's index range (`ja` is column-sorted per
/// row, so binary search).
fn slot_for(m: &Csrc, r: usize, c: usize) -> Result<Slot, String> {
    if r == c {
        return Ok(Slot::Diag(r as u32));
    }
    let (owner, other) = if r > c { (r, c) } else { (c, r) };
    let range = m.row_range(owner);
    let row = &m.ja[range.clone()];
    let s = range.start
        + row
            .binary_search(&(other as u32))
            .map_err(|_| format!("pattern misses pair ({r}, {c})"))?;
    Ok(if c < r { Slot::Lower(s as u32) } else { Slot::Upper(s as u32) })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::mesh::{Mesh2d, Mesh3d};

    fn assert_close(a: &[f64], b: &[f64], what: &str) {
        assert_eq!(a.len(), b.len(), "{what}: length");
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            let tol = 1e-11 * x.abs().max(y.abs()).max(1.0);
            assert!((x - y).abs() <= tol, "{what}[{i}]: {x} vs {y}");
        }
    }

    fn oracle(mesh: &Mesh, convection: f64, t: f64) -> Csrc {
        Csrc::from_coo(&assemble_coo(mesh, convection, t)).unwrap()
    }

    #[test]
    fn atomic_matches_sequential_coo_oracle() {
        let mesh = Mesh2d::quads(9, 9);
        let asm = Assembler::new(mesh.clone(), 0.3).unwrap();
        for &t in &[0.0, 0.7, 2.3] {
            let want = oracle(&mesh, 0.3, t);
            let got = asm.assemble_atomic(t, 4);
            assert_close(&got.ad, &want.ad, "ad");
            assert_close(&got.al, &want.al, "al");
            assert_close(&got.au, &want.au, "au");
        }
    }

    #[test]
    fn colored_matches_sequential_coo_oracle() {
        let mesh = Mesh3d::hexes(4, 4, 4);
        let asm = Assembler::new(mesh.clone(), 0.0).unwrap();
        for &t in &[0.0, 1.1] {
            let want = oracle(&mesh, 0.0, t);
            let got = asm.assemble_colored(t, 4);
            assert_close(&got.ad, &want.ad, "ad");
            assert_close(&got.al, &want.al, "al");
            assert_close(&got.au, &want.au, "au");
            assert!(got.numeric_symmetric, "pure diffusion stays symmetric");
        }
    }

    #[test]
    fn slot_table_matches_oracle_sequentially() {
        let mesh = Mesh2d::triangles(7, 7);
        let asm = Assembler::new(mesh.clone(), 0.5).unwrap();
        let want = oracle(&mesh, 0.5, 1.9);
        let got = asm.assemble_sequential(1.9);
        assert_close(&got.ad, &want.ad, "ad");
        assert_close(&got.al, &want.al, "al");
        assert_close(&got.au, &want.au, "au");
    }

    #[test]
    fn coloring_classes_share_no_node() {
        let mesh = Mesh2d::quads(6, 6);
        let colors = color_elements(&mesh);
        assert!(colors.num_colors() >= 2);
        for class in &colors.classes {
            let mut seen = std::collections::HashSet::new();
            for &e in class {
                for &v in mesh.elem(e as usize) {
                    assert!(seen.insert(v), "node {v} in two same-color elements");
                }
            }
        }
    }

    #[test]
    fn race_fixes_choice_and_preserves_fingerprint() {
        let mesh = Mesh2d::quads(8, 8);
        let mut asm = Assembler::new(mesh, 0.2).unwrap();
        assert!(asm.choice().is_none());
        let fp = asm.matrix().pattern_fingerprint();
        let out = asm.assemble(1.0, 2);
        let chosen = asm.choice().expect("first assemble races");
        assert_eq!(out.pattern_fingerprint(), fp);
        // Replay uses the fixed choice; values move with t, pattern not.
        let out2 = asm.assemble(2.0, 2);
        assert_eq!(asm.choice(), Some(chosen));
        assert_eq!(out2.pattern_fingerprint(), fp);
        assert_ne!(out.ad, out2.ad, "time dependence must show in values");
        // And the step output feeds the in-place update path.
        let mut m = asm.matrix().clone();
        m.update_values_from(&out2).unwrap();
        assert_eq!(m.ad, out2.ad);
    }
}
