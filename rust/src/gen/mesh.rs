//! Structured finite-element meshes.
//!
//! Minimal but real: nodes with coordinates and element connectivity,
//! enough for the assembly in [`super::fem`] to produce the global-matrix
//! patterns the paper's dataset exhibits (narrow band, nnz/row 2–130).

/// A generic mesh: nodes + homogeneous elements of `nodes_per_elem` nodes.
#[derive(Clone, Debug)]
pub struct Mesh {
    /// Node coordinates, `dim` values per node, row-major.
    pub coords: Vec<f64>,
    pub dim: usize,
    /// Element connectivity, `nodes_per_elem` node ids per element.
    pub elems: Vec<u32>,
    pub nodes_per_elem: usize,
}

impl Mesh {
    pub fn num_nodes(&self) -> usize {
        self.coords.len() / self.dim
    }

    pub fn num_elems(&self) -> usize {
        self.elems.len() / self.nodes_per_elem
    }

    pub fn elem(&self, e: usize) -> &[u32] {
        &self.elems[e * self.nodes_per_elem..(e + 1) * self.nodes_per_elem]
    }

    pub fn node_coord(&self, v: usize) -> &[f64] {
        &self.coords[v * self.dim..(v + 1) * self.dim]
    }

    /// Structural sanity for generated meshes.
    pub fn validate(&self) -> Result<(), String> {
        let n = self.num_nodes();
        if self.coords.len() % self.dim != 0 {
            return Err("coords not a multiple of dim".into());
        }
        if self.elems.len() % self.nodes_per_elem != 0 {
            return Err("elems not a multiple of nodes_per_elem".into());
        }
        for (k, &v) in self.elems.iter().enumerate() {
            if v as usize >= n {
                return Err(format!("elem slot {k} references node {v} >= {n}"));
            }
        }
        for e in 0..self.num_elems() {
            let el = self.elem(e);
            let mut s = el.to_vec();
            s.sort_unstable();
            s.dedup();
            if s.len() != el.len() {
                return Err(format!("element {e} has repeated nodes: {el:?}"));
            }
        }
        Ok(())
    }
}

/// 2-D structured grid on [0,1]²: (nx+1)×(ny+1) nodes.
pub struct Mesh2d;

impl Mesh2d {
    /// Quadrilateral elements (4 nodes each).
    pub fn quads(nx: usize, ny: usize) -> Mesh {
        let (mx, my) = (nx + 1, ny + 1);
        let mut coords = Vec::with_capacity(mx * my * 2);
        for j in 0..my {
            for i in 0..mx {
                coords.push(i as f64 / nx as f64);
                coords.push(j as f64 / ny as f64);
            }
        }
        let id = |i: usize, j: usize| (j * mx + i) as u32;
        let mut elems = Vec::with_capacity(nx * ny * 4);
        for j in 0..ny {
            for i in 0..nx {
                elems.extend_from_slice(&[id(i, j), id(i + 1, j), id(i + 1, j + 1), id(i, j + 1)]);
            }
        }
        Mesh { coords, dim: 2, elems, nodes_per_elem: 4 }
    }

    /// Triangles: each grid cell split along its diagonal (2 per cell).
    pub fn triangles(nx: usize, ny: usize) -> Mesh {
        let quad = Mesh2d::quads(nx, ny);
        let mut elems = Vec::with_capacity(nx * ny * 6);
        for e in 0..quad.num_elems() {
            let q = quad.elem(e);
            elems.extend_from_slice(&[q[0], q[1], q[2]]);
            elems.extend_from_slice(&[q[0], q[2], q[3]]);
        }
        Mesh { coords: quad.coords, dim: 2, elems, nodes_per_elem: 3 }
    }
}

/// 3-D structured hexahedral grid on [0,1]³.
pub struct Mesh3d;

impl Mesh3d {
    pub fn hexes(nx: usize, ny: usize, nz: usize) -> Mesh {
        let (mx, my, mz) = (nx + 1, ny + 1, nz + 1);
        let mut coords = Vec::with_capacity(mx * my * mz * 3);
        for k in 0..mz {
            for j in 0..my {
                for i in 0..mx {
                    coords.push(i as f64 / nx as f64);
                    coords.push(j as f64 / ny as f64);
                    coords.push(k as f64 / nz as f64);
                }
            }
        }
        let id = |i: usize, j: usize, k: usize| (k * my * mx + j * mx + i) as u32;
        let mut elems = Vec::with_capacity(nx * ny * nz * 8);
        for k in 0..nz {
            for j in 0..ny {
                for i in 0..nx {
                    elems.extend_from_slice(&[
                        id(i, j, k),
                        id(i + 1, j, k),
                        id(i + 1, j + 1, k),
                        id(i, j + 1, k),
                        id(i, j, k + 1),
                        id(i + 1, j, k + 1),
                        id(i + 1, j + 1, k + 1),
                        id(i, j + 1, k + 1),
                    ]);
                }
            }
        }
        Mesh { coords, dim: 3, elems, nodes_per_elem: 8 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quad_mesh_counts() {
        let m = Mesh2d::quads(3, 2);
        assert_eq!(m.num_nodes(), 4 * 3);
        assert_eq!(m.num_elems(), 6);
        m.validate().unwrap();
    }

    #[test]
    fn tri_mesh_counts() {
        let m = Mesh2d::triangles(3, 3);
        assert_eq!(m.num_nodes(), 16);
        assert_eq!(m.num_elems(), 18);
        m.validate().unwrap();
    }

    #[test]
    fn hex_mesh_counts() {
        let m = Mesh3d::hexes(2, 3, 4);
        assert_eq!(m.num_nodes(), 3 * 4 * 5);
        assert_eq!(m.num_elems(), 24);
        m.validate().unwrap();
    }

    #[test]
    fn coords_in_unit_box() {
        let m = Mesh3d::hexes(2, 2, 2);
        assert!(m.coords.iter().all(|&c| (0.0..=1.0).contains(&c)));
    }
}
