//! Global finite-element matrix assembly.
//!
//! The paper's target matrices are FEM global matrices: structurally
//! symmetric by construction (element connectivity is undirected), often
//! numerically non-symmetric (convection terms). We assemble:
//!
//! * Poisson/diffusion stiffness on tri/quad/hex meshes — SPD, the
//!   symmetric entries of Table 1,
//! * a convection-perturbed variant (`convection > 0`) — structurally
//!   symmetric, numerically *non*-symmetric, like `tracer_o32`,
//! * 2-D elasticity (2 dof/node) — block patterns with higher nnz/row,
//!   like the crankseg/bmw entries.
//!
//! Element matrices are simple but physically shaped (graph-Laplacian-like
//! stiffness with positive diagonal); what the SpMV evaluation cares about
//! is the *pattern and size spectrum*, which matches real assemblies.

use super::mesh::Mesh;
use crate::sparse::Coo;
use crate::util::Rng;

/// Assemble a scalar (1 dof/node) global matrix: for each element, couple
/// all node pairs. `convection` adds an antisymmetric perturbation making
/// the matrix numerically non-symmetric while preserving the pattern.
pub fn assemble_scalar(mesh: &Mesh, convection: f64, rng: &mut Rng) -> Coo {
    let n = mesh.num_nodes();
    let npe = mesh.nodes_per_elem;
    let mut coo = Coo::with_capacity(n, n, mesh.num_elems() * npe * npe);
    for e in 0..mesh.num_elems() {
        let el = mesh.elem(e);
        // Element stiffness: k_local[a][b] = -w_ab (a≠b), diag = Σ w.
        // Weights from inverse distance — positive, mesh-dependent.
        for (a, &va) in el.iter().enumerate() {
            let pa = mesh.node_coord(va as usize);
            let mut diag = 0.0;
            for (b, &vb) in el.iter().enumerate() {
                if a == b {
                    continue;
                }
                let pb = mesh.node_coord(vb as usize);
                let d2: f64 = pa.iter().zip(pb).map(|(x, y)| (x - y) * (x - y)).sum();
                let w = 1.0 / d2.sqrt().max(1e-12);
                diag += w;
                // Convection: upwind-biased antisymmetric part. No jitter
                // on off-diagonals so convection == 0 stays numerically
                // symmetric (mirror entries must match exactly).
                let skew = convection * w * if va < vb { 1.0 } else { -1.0 };
                coo.push(va as usize, vb as usize, -w + skew);
            }
            coo.push(va as usize, va as usize, diag * (1.0 + 0.01 * rng.normal().abs()) + 1.0);
        }
    }
    coo.compact();
    coo
}

/// Assemble a vector-valued (ndof per node) global matrix: each node pair
/// couples as a dense ndof×ndof block (elasticity-style).
pub fn assemble_vector(mesh: &Mesh, ndof: usize, rng: &mut Rng) -> Coo {
    let n = mesh.num_nodes() * ndof;
    let npe = mesh.nodes_per_elem;
    let mut coo = Coo::with_capacity(n, n, mesh.num_elems() * npe * npe * ndof * ndof);
    for e in 0..mesh.num_elems() {
        let el = mesh.elem(e);
        for (a, &va) in el.iter().enumerate() {
            let pa = mesh.node_coord(va as usize);
            for (b, &vb) in el.iter().enumerate() {
                let pb = mesh.node_coord(vb as usize);
                let d2: f64 = pa.iter().zip(pb).map(|(x, y)| (x - y) * (x - y)).sum();
                let w = if a == b { 1.0 } else { -0.5 / d2.sqrt().max(1e-12) };
                for di in 0..ndof {
                    for dj in 0..ndof {
                        let coupling = if di == dj { w } else { 0.25 * w };
                        let v = coupling * (1.0 + 0.01 * rng.normal());
                        let (gi, gj) = (va as usize * ndof + di, vb as usize * ndof + dj);
                        // Keep block symmetric in *pattern* by pushing both
                        // (i,j) and (j,i) coordinates for off-diag blocks.
                        coo.push(gi, gj, v);
                    }
                }
            }
            // Diagonal dominance for solvability.
            for di in 0..ndof {
                let gi = va as usize * ndof + di;
                coo.push(gi, gi, 8.0 * npe as f64);
            }
        }
    }
    coo.compact();
    coo
}

use super::mesh::{Mesh2d, Mesh3d};

/// 2-D Poisson on triangles: `poisson_2d_tri(nx, convection, seed)`.
pub fn poisson_2d_tri(nx: usize, convection: f64, seed: u64) -> Coo {
    let mut rng = Rng::new(seed);
    assemble_scalar(&Mesh2d::triangles(nx, nx), convection, &mut rng)
}

/// 2-D Poisson on quads.
pub fn poisson_2d_quad(nx: usize, convection: f64, seed: u64) -> Coo {
    let mut rng = Rng::new(seed);
    assemble_scalar(&Mesh2d::quads(nx, nx), convection, &mut rng)
}

/// 3-D Poisson on hexes (27-point-like stencil, nnz/row ≈ 27).
pub fn poisson_3d_hex(nx: usize, convection: f64, seed: u64) -> Coo {
    let mut rng = Rng::new(seed);
    assemble_scalar(&Mesh3d::hexes(nx, nx, nx), convection, &mut rng)
}

/// 2-D elasticity (2 dof/node) on quads.
pub fn elasticity_2d(nx: usize, seed: u64) -> Coo {
    let mut rng = Rng::new(seed);
    assemble_vector(&Mesh2d::quads(nx, nx), 2, &mut rng)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::{Csr, Csrc};

    #[test]
    fn poisson_2d_is_structurally_symmetric() {
        let coo = poisson_2d_tri(8, 0.0, 1);
        assert!(coo.is_structurally_symmetric());
        let m = Csrc::from_coo(&coo).unwrap();
        assert_eq!(m.n, 81);
        assert!(m.numeric_symmetric, "pure diffusion should be symmetric");
    }

    #[test]
    fn convection_breaks_numeric_symmetry_only() {
        let coo = poisson_2d_quad(8, 0.5, 2);
        assert!(coo.is_structurally_symmetric());
        let m = Csrc::from_coo(&coo).unwrap();
        assert!(!m.numeric_symmetric);
    }

    #[test]
    fn poisson_3d_has_hex_stencil() {
        let coo = poisson_3d_hex(4, 0.0, 3);
        let m = Csrc::from_coo(&coo).unwrap();
        assert_eq!(m.n, 125);
        let csr = m.to_csr();
        // An interior node of a hex mesh touches 27 nodes incl. itself.
        let widths: Vec<usize> = (0..125).map(|i| csr.row_range(i).len()).collect();
        assert_eq!(*widths.iter().max().unwrap(), 27);
    }

    #[test]
    fn elasticity_block_pattern() {
        let coo = elasticity_2d(5, 4);
        assert!(coo.is_structurally_symmetric());
        let m = Csrc::from_coo(&coo).unwrap();
        assert_eq!(m.n, 36 * 2);
        // 2 dof/node doubles nnz/row vs scalar quad assembly (~9 -> ~18).
        let nnz_per_row = m.nnz() as f64 / m.n as f64;
        assert!(nnz_per_row > 12.0, "nnz/row = {nnz_per_row}");
    }

    #[test]
    fn assembly_is_deterministic_per_seed() {
        let a = poisson_2d_tri(6, 0.3, 42);
        let b = poisson_2d_tri(6, 0.3, 42);
        assert_eq!(a.vals, b.vals);
        let c = poisson_2d_tri(6, 0.3, 43);
        assert_ne!(a.vals, c.vals);
    }

    #[test]
    fn narrow_band_structure() {
        // Structured grids give banded global matrices — the property the
        // paper's effective-range analysis leans on (§3.1).
        let coo = poisson_2d_quad(10, 0.0, 5);
        let m = Csrc::from_coo(&coo).unwrap();
        assert!(m.half_bandwidth() <= 12, "hbw = {}", m.half_bandwidth());
        let _ = Csr::from_coo(&coo);
    }
}
