//! Workload generators — the substrate replacing the paper's matrix
//! sources (Table 1: 50 UF-collection matrices, 9 in-house FEM matrices,
//! one dense). See DESIGN.md §2 for the substitution argument.
//!
//! * [`mesh`] — structured 2-D (tri/quad) and 3-D (hex) meshes,
//! * [`fem`] — global matrix assembly (Poisson stiffness and
//!   convection-perturbed variants; 2-D/3-D elasticity with 2/3 dof per
//!   node), producing exactly the structurally symmetric patterns the
//!   paper targets,
//! * [`decomp`] — subdomain-by-subdomain splitting: non-overlapping
//!   (square local matrices, the `_n32` entries) and overlapping
//!   (rectangular n×m locals, the `_o32` entries, §2.1).

pub mod assemble;
pub mod decomp;
pub mod fem;
pub mod mesh;

pub use assemble::{assemble_coo, Assembler, AssemblyKind, AssemblyRace};
pub use decomp::{nonoverlapping_local, overlapping_local};
pub use fem::{elasticity_2d, poisson_2d_quad, poisson_2d_tri, poisson_3d_hex};
pub use mesh::{Mesh, Mesh2d, Mesh3d};
