//! Subdomain-by-subdomain domain decomposition (the paper's `_n32` /
//! `_o32` dataset entries, §2.1 and ref [27]).
//!
//! The global mesh's rows are split into contiguous slabs (structured
//! meshes make slabs geometric). For each subdomain:
//!
//! * **non-overlapping** — the local matrix is the square restriction of
//!   the global matrix to the slab's rows *and* columns: structurally
//!   symmetric, stored as plain CSRC,
//! * **overlapping** — the local matrix keeps every column its rows touch;
//!   ghost (overlap) columns are renumbered after the internal ones,
//!   giving the n×m (m > n) rectangle whose square part is structurally
//!   symmetric — exactly what [`crate::sparse::CsrcRect`] stores.

use crate::sparse::{Coo, Csr};

/// Rows of subdomain `s` out of `nsub` (contiguous slab split).
pub fn slab(n: usize, nsub: usize, s: usize) -> std::ops::Range<usize> {
    (s * n / nsub)..((s + 1) * n / nsub)
}

/// Non-overlapping local matrix: square restriction to the slab.
pub fn nonoverlapping_local(global: &Csr, nsub: usize, s: usize) -> Coo {
    let rows = slab(global.nrows, nsub, s);
    let nl = rows.len();
    let mut coo = Coo::new(nl, nl);
    for i in rows.clone() {
        for k in global.row_range(i) {
            let j = global.ja[k] as usize;
            if rows.contains(&j) {
                coo.push(i - rows.start, j - rows.start, global.a[k]);
            }
        }
    }
    coo.compact();
    coo
}

/// Overlapping local matrix: slab rows with ghost columns appended, as an
/// n×m COO (internal columns first, ghosts renumbered to n..m in first-
/// appearance order).
pub fn overlapping_local(global: &Csr, nsub: usize, s: usize) -> Coo {
    let rows = slab(global.nrows, nsub, s);
    let nl = rows.len();
    let mut ghost_id = std::collections::HashMap::new();
    let mut next_ghost = 0usize;
    let mut entries = Vec::new();
    for i in rows.clone() {
        for k in global.row_range(i) {
            let j = global.ja[k] as usize;
            let jl = if rows.contains(&j) {
                j - rows.start
            } else {
                let g = *ghost_id.entry(j).or_insert_with(|| {
                    let g = next_ghost;
                    next_ghost += 1;
                    g
                });
                nl + g
            };
            entries.push((i - rows.start, jl, global.a[k]));
        }
    }
    let m = nl + next_ghost;
    let mut coo = Coo::with_capacity(nl, m, entries.len());
    for (i, j, v) in entries {
        coo.push(i, j, v);
    }
    coo.compact();
    coo
}

/// Verify a decomposition reproduces the global product: scatter each
/// subdomain's local y back and compare (used by tests and the harness's
/// sanity pass). Overlapping locals consume the global x restricted to
/// their column map; this helper recomputes that map.
pub fn verify_overlapping_spmv(global: &Csr, nsub: usize, x: &[f64]) -> Vec<f64> {
    use crate::sparse::CsrcRect;
    let mut y = vec![0.0; global.nrows];
    for s in 0..nsub {
        let rows = slab(global.nrows, nsub, s);
        let local = overlapping_local(global, nsub, s);
        let rect = CsrcRect::from_coo(&local).expect("overlap local must be CSRC-compatible");
        // Rebuild the ghost map in the same first-appearance order.
        let mut ghost_cols = Vec::new();
        let mut seen = std::collections::HashSet::new();
        for i in rows.clone() {
            for k in global.row_range(i) {
                let j = global.ja[k] as usize;
                if !rows.contains(&j) && seen.insert(j) {
                    ghost_cols.push(j);
                }
            }
        }
        let mut xl = Vec::with_capacity(local.ncols);
        xl.extend(rows.clone().map(|i| x[i]));
        xl.extend(ghost_cols.iter().map(|&j| x[j]));
        let mut yl = vec![0.0; rows.len()];
        rect.spmv(&xl, &mut yl);
        for (off, i) in rows.enumerate() {
            y[i] = yl[off];
        }
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::fem::poisson_2d_quad;
    use crate::sparse::{Csr, Csrc};
    use crate::util::propcheck;

    fn global() -> Csr {
        Csr::from_coo(&poisson_2d_quad(12, 0.2, 7))
    }

    #[test]
    fn slabs_partition_rows() {
        let n = 169;
        let mut covered = 0;
        for s in 0..8 {
            covered += slab(n, 8, s).len();
        }
        assert_eq!(covered, n);
    }

    #[test]
    fn nonoverlapping_locals_are_csrc_compatible() {
        let g = global();
        for s in 0..4 {
            let local = nonoverlapping_local(&g, 4, s);
            assert!(local.is_structurally_symmetric(), "subdomain {s}");
            let m = Csrc::from_coo(&local).unwrap();
            assert_eq!(m.n, slab(g.nrows, 4, s).len());
        }
    }

    #[test]
    fn overlapping_locals_are_rectangular() {
        let g = global();
        for s in 0..4 {
            let local = overlapping_local(&g, 4, s);
            let nl = slab(g.nrows, 4, s).len();
            assert_eq!(local.nrows, nl);
            // Interior subdomains must have ghosts.
            if s == 1 || s == 2 {
                assert!(local.ncols > nl, "subdomain {s} should have ghosts");
            }
        }
    }

    #[test]
    fn overlapping_product_reproduces_global() {
        let g = global();
        let x: Vec<f64> = (0..g.nrows).map(|i| (i as f64 * 0.37).sin()).collect();
        let mut want = vec![0.0; g.nrows];
        g.spmv(&x, &mut want);
        let got = verify_overlapping_spmv(&g, 4, &x);
        propcheck::assert_close(&got, &want, 1e-10, 1e-10).unwrap();
    }

    #[test]
    fn decomposition_scales_with_subdomain_count() {
        let g = global();
        for nsub in [2, 4, 8] {
            let got = verify_overlapping_spmv(&g, nsub, &vec![1.0; g.nrows]);
            let mut want = vec![0.0; g.nrows];
            g.spmv(&vec![1.0; g.nrows], &mut want);
            propcheck::assert_close(&got, &want, 1e-10, 1e-10).unwrap();
        }
    }
}
