//! Observability: span-based phase tracing, a process-wide phase timer,
//! and a metrics registry with Prometheus text exposition (DESIGN.md
//! §12).
//!
//! Three layers, cheapest first:
//!
//! 1. **Phase spans** — [`phase`] returns a guard that attributes the
//!    enclosed wall-clock time to one of the fixed [`Phase`]s (zero,
//!    sweep, accumulate, permute-scatter, …). When observability is off
//!    the call is a single relaxed atomic load returning `None`, so the
//!    instrumentation stays compiled into the hot paths at a cost the
//!    `instrumentation-overhead` ablation bounds below 2%.
//! 2. **Trace ring** — with [`start_trace`] active, every span also
//!    pushes begin/end events (timestamped under one lock, so the event
//!    sequence is globally monotone) into a bounded buffer that
//!    serializes to the `chrome://tracing` JSON event format.
//! 3. **[`MetricsRegistry`]** — named counters, gauges, labeled counter
//!    families (matrix × engine × k), and mergeable latency histograms.
//!    The coordinator keeps one registry per service; [`serve_metrics`]
//!    exposes any registry over HTTP in the Prometheus text format,
//!    folding in the process-wide phase totals.
//!
//! Phase timers and the trace ring are process-wide (engines are shared
//! executors with no service handle); registries are per-owner so unit
//! tests with exact counter expectations never observe each other.

use crate::metrics::LatencyHistogram;
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::Ordering::Relaxed;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// The fixed set of instrumented phases. Trace event names and the
/// `phase` label of `csrc_phase_seconds_total` are drawn from
/// [`Phase::label`]; the trace validator rejects anything else.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Plan construction (partition/ranges/intervals/coloring).
    PlanBuild = 0,
    /// RCM analysis + permutation construction.
    Reorder = 1,
    /// One measured candidate inside `tuner::{tune,sweep}`.
    TuneTrial = 2,
    /// Zeroing y / local buffers / atomic slots before a product.
    Zero = 3,
    /// The symmetric row sweep itself.
    Sweep = 4,
    /// Buffer accumulation (local-buffers) or atomic copy-out.
    Accumulate = 5,
    /// Permute x in / scatter y out around a reordered engine.
    PermuteScatter = 6,
    /// Packing/unpacking coalesced SpMM panels in the service worker.
    Coalesce = 7,
    /// One worker batch, end to end.
    Serve = 8,
    /// One background re-tune triggered by drift.
    Retune = 9,
    /// Sharded front: split x into per-shard local rows + gathered halo.
    Scatter = 10,
    /// Sharded front: collect per-shard results + coupling back into y.
    Gather = 11,
    /// Supervisor respawning a crashed worker/retuner thread.
    Restart = 12,
    /// A circuit-breaker state transition (open/half-open/closed).
    Breaker = 13,
    /// Sequential fallback product for a shard whose breaker is open.
    Degraded = 14,
    /// Parallel FEM re-assembly (element contributions → CSRC values).
    Assemble = 15,
    /// In-place value update: registry swap + artifact value patch.
    Update = 16,
}

/// Number of phases (length of [`Phase::ALL`]).
pub const NPHASES: usize = 17;

impl Phase {
    pub const ALL: [Phase; NPHASES] = [
        Phase::PlanBuild,
        Phase::Reorder,
        Phase::TuneTrial,
        Phase::Zero,
        Phase::Sweep,
        Phase::Accumulate,
        Phase::PermuteScatter,
        Phase::Coalesce,
        Phase::Serve,
        Phase::Retune,
        Phase::Scatter,
        Phase::Gather,
        Phase::Restart,
        Phase::Breaker,
        Phase::Degraded,
        Phase::Assemble,
        Phase::Update,
    ];

    pub fn label(self) -> &'static str {
        match self {
            Phase::PlanBuild => "plan_build",
            Phase::Reorder => "reorder",
            Phase::TuneTrial => "tune_trial",
            Phase::Zero => "zero",
            Phase::Sweep => "sweep",
            Phase::Accumulate => "accumulate",
            Phase::PermuteScatter => "permute_scatter",
            Phase::Coalesce => "coalesce",
            Phase::Serve => "serve",
            Phase::Retune => "retune",
            Phase::Scatter => "scatter",
            Phase::Gather => "gather",
            Phase::Restart => "restart",
            Phase::Breaker => "breaker",
            Phase::Degraded => "degraded",
            Phase::Assemble => "assemble",
            Phase::Update => "update",
        }
    }

    fn index(self) -> usize {
        self as usize
    }
}

static METRICS_ON: AtomicBool = AtomicBool::new(false);
static TRACE_ON: AtomicBool = AtomicBool::new(false);

struct PhaseCell {
    ns: AtomicU64,
    calls: AtomicU64,
}

#[allow(clippy::declare_interior_mutable_const)]
const PHASE_CELL_ZERO: PhaseCell = PhaseCell { ns: AtomicU64::new(0), calls: AtomicU64::new(0) };
static PHASE_CELLS: [PhaseCell; NPHASES] = [PHASE_CELL_ZERO; NPHASES];

/// Enable/disable phase timing globally. Tracing has its own switch
/// ([`start_trace`]); either one makes [`phase`] return a live guard.
pub fn set_metrics_enabled(on: bool) {
    METRICS_ON.store(on, Relaxed);
}

pub fn metrics_enabled() -> bool {
    METRICS_ON.load(Relaxed)
}

pub fn trace_enabled() -> bool {
    TRACE_ON.load(Relaxed)
}

/// Begin a phase span; the guard attributes elapsed time on drop. When
/// both metrics and tracing are off this is one relaxed load and a
/// branch — the near-free disabled path the overhead ablation asserts.
#[inline]
pub fn phase(p: Phase) -> Option<PhaseGuard> {
    if !METRICS_ON.load(Relaxed) && !TRACE_ON.load(Relaxed) {
        return None;
    }
    Some(PhaseGuard::begin(p))
}

pub struct PhaseGuard {
    phase: Phase,
    start: Instant,
    traced: bool,
}

impl PhaseGuard {
    fn begin(phase: Phase) -> Self {
        let traced = TRACE_ON.load(Relaxed) && push_event(phase.label(), true);
        PhaseGuard { phase, start: Instant::now(), traced }
    }
}

impl Drop for PhaseGuard {
    fn drop(&mut self) {
        let cell = &PHASE_CELLS[self.phase.index()];
        cell.ns.fetch_add(self.start.elapsed().as_nanos() as u64, Relaxed);
        cell.calls.fetch_add(1, Relaxed);
        if self.traced {
            push_event(self.phase.label(), false);
        }
    }
}

/// One row of the process-wide phase accounting.
#[derive(Clone, Copy, Debug)]
pub struct PhaseTotal {
    pub phase: Phase,
    pub calls: u64,
    pub ns: u64,
}

impl PhaseTotal {
    pub fn seconds(&self) -> f64 {
        self.ns as f64 / 1e9
    }
}

/// Snapshot of the per-phase totals, in [`Phase::ALL`] order.
pub fn phase_totals() -> Vec<PhaseTotal> {
    Phase::ALL
        .iter()
        .map(|&p| {
            let cell = &PHASE_CELLS[p.index()];
            PhaseTotal { phase: p, calls: cell.calls.load(Relaxed), ns: cell.ns.load(Relaxed) }
        })
        .collect()
}

/// Zero the per-phase totals (figure harnesses isolate per-matrix runs).
pub fn reset_phases() {
    for cell in &PHASE_CELLS {
        cell.ns.store(0, Relaxed);
        cell.calls.store(0, Relaxed);
    }
}

// ---------------------------------------------------------------------
// Trace ring
// ---------------------------------------------------------------------

/// Begin events past this many buffered events are dropped (end events
/// of already-begun spans still land, keeping the trace balanced).
pub const TRACE_CAP: usize = 1 << 16;

#[derive(Clone, Debug)]
pub struct TraceEvent {
    /// A [`Phase::label`].
    pub name: &'static str,
    /// `true` = span begin (`"B"`), `false` = span end (`"E"`).
    pub begin: bool,
    /// Microseconds since [`start_trace`].
    pub ts_us: f64,
    /// Small dense thread id (assigned on first event per thread).
    pub tid: u32,
}

struct TraceBuf {
    epoch: Option<Instant>,
    events: Vec<TraceEvent>,
    dropped: u64,
}

static TRACE_BUF: Mutex<TraceBuf> =
    Mutex::new(TraceBuf { epoch: None, events: Vec::new(), dropped: 0 });

static NEXT_TID: AtomicU32 = AtomicU32::new(1);

thread_local! {
    static TID: std::cell::Cell<u32> = std::cell::Cell::new(0);
}

fn current_tid() -> u32 {
    TID.with(|c| {
        let mut t = c.get();
        if t == 0 {
            t = NEXT_TID.fetch_add(1, Relaxed);
            c.set(t);
        }
        t
    })
}

fn push_event(name: &'static str, begin: bool) -> bool {
    let mut buf = TRACE_BUF.lock().unwrap();
    let ts_us = match buf.epoch {
        Some(e) => e.elapsed().as_secs_f64() * 1e6,
        None => return false,
    };
    if begin && buf.events.len() >= TRACE_CAP {
        buf.dropped += 1;
        return false;
    }
    buf.events.push(TraceEvent { name, begin, ts_us, tid: current_tid() });
    true
}

/// Start recording trace events (clears any previous trace). Spans that
/// begin while tracing is active push begin/end pairs; stop with
/// [`stop_trace`] only after the traced work has fully completed, or
/// the in-flight spans' end events are lost and the trace unbalances.
pub fn start_trace() {
    let mut buf = TRACE_BUF.lock().unwrap();
    buf.epoch = Some(Instant::now());
    buf.events.clear();
    buf.dropped = 0;
    TRACE_ON.store(true, Relaxed);
}

/// Stop tracing and drain the recorded events.
pub fn stop_trace() -> Vec<TraceEvent> {
    TRACE_ON.store(false, Relaxed);
    let mut buf = TRACE_BUF.lock().unwrap();
    buf.epoch = None;
    std::mem::take(&mut buf.events)
}

/// Begin events dropped by the ring cap during the last/current trace.
pub fn trace_dropped() -> u64 {
    TRACE_BUF.lock().unwrap().dropped
}

/// Serialize events to the `chrome://tracing` JSON event format
/// (`about:tracing` → Load, or https://ui.perfetto.dev).
pub fn trace_to_json(events: &[TraceEvent]) -> Json {
    let list = events
        .iter()
        .map(|e| {
            Json::obj(vec![
                ("name", Json::Str(e.name.to_string())),
                ("cat", Json::Str("csrc".to_string())),
                ("ph", Json::Str(if e.begin { "B" } else { "E" }.to_string())),
                ("ts", Json::Num(e.ts_us)),
                ("pid", Json::Num(1.0)),
                ("tid", Json::Num(e.tid as f64)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("traceEvents", Json::Arr(list)),
        ("displayTimeUnit", Json::Str("ms".to_string())),
    ])
}

/// Validate a serialized trace against the event schema: a
/// `traceEvents` array whose events carry name/ph/ts/pid/tid, names
/// drawn from [`Phase::ALL`], globally monotone timestamps (they are
/// assigned under one lock), and balanced, properly nested begin/end
/// per thread. Returns the number of events.
pub fn validate_trace_json(j: &Json) -> Result<usize, String> {
    let events = j
        .get("traceEvents")
        .and_then(|e| e.as_arr())
        .ok_or_else(|| "missing traceEvents array".to_string())?;
    let allowed: Vec<&str> = Phase::ALL.iter().map(|p| p.label()).collect();
    let mut stacks: BTreeMap<u64, Vec<String>> = BTreeMap::new();
    let mut last_ts = f64::NEG_INFINITY;
    for (i, ev) in events.iter().enumerate() {
        let name = ev
            .get("name")
            .and_then(|v| v.as_str())
            .ok_or_else(|| format!("event {i}: missing name"))?;
        if !allowed.contains(&name) {
            return Err(format!("event {i}: unknown phase name {name:?}"));
        }
        let ph = ev
            .get("ph")
            .and_then(|v| v.as_str())
            .ok_or_else(|| format!("event {i}: missing ph"))?;
        let ts = ev
            .get("ts")
            .and_then(|v| v.as_f64())
            .ok_or_else(|| format!("event {i}: missing ts"))?;
        let tid = ev
            .get("tid")
            .and_then(|v| v.as_f64())
            .ok_or_else(|| format!("event {i}: missing tid"))? as u64;
        if ev.get("pid").and_then(|v| v.as_f64()).is_none() {
            return Err(format!("event {i}: missing pid"));
        }
        if ts < last_ts {
            return Err(format!("event {i}: timestamp {ts} < {last_ts} (not monotone)"));
        }
        last_ts = ts;
        let stack = stacks.entry(tid).or_default();
        match ph {
            "B" => stack.push(name.to_string()),
            "E" => match stack.pop() {
                Some(open) if open == name => {}
                Some(open) => return Err(format!("event {i}: end {name:?} closes {open:?}")),
                None => return Err(format!("event {i}: end {name:?} with no open span")),
            },
            other => return Err(format!("event {i}: ph must be B or E, got {other:?}")),
        }
    }
    for (tid, stack) in &stacks {
        if !stack.is_empty() {
            return Err(format!("tid {tid}: {} unclosed span(s) {stack:?}", stack.len()));
        }
    }
    Ok(events.len())
}

// ---------------------------------------------------------------------
// Metrics registry
// ---------------------------------------------------------------------

/// Monotone counter handle; clones share one atomic, so hot paths keep
/// a clone and bump lock-free.
#[derive(Clone)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Relaxed)
    }
}

/// f64 gauge handle (bits in one atomic; `add` is a CAS loop).
#[derive(Clone)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Relaxed);
    }

    pub fn add(&self, v: f64) {
        let mut cur = self.0.load(Relaxed);
        loop {
            let next = (f64::from_bits(cur) + v).to_bits();
            match self.0.compare_exchange_weak(cur, next, Relaxed, Relaxed) {
                Ok(_) => return,
                Err(seen) => cur = seen,
            }
        }
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Relaxed))
    }
}

/// Handle to one registered latency histogram (e.g. one worker's).
#[derive(Clone)]
pub struct HistogramHandle(Arc<Mutex<LatencyHistogram>>);

impl HistogramHandle {
    pub fn record(&self, seconds: f64) {
        self.0.lock().unwrap().record(seconds);
    }

    pub fn snapshot(&self) -> LatencyHistogram {
        self.0.lock().unwrap().clone()
    }
}

/// Named counters/gauges, labeled counter families, and mergeable
/// latency histograms. One registry per owner (the coordinator creates
/// one per `MatvecService`); rendering folds in the process-wide phase
/// totals so a single scrape shows both layers.
pub struct MetricsRegistry {
    counters: Mutex<Vec<(String, Arc<AtomicU64>)>>,
    gauges: Mutex<Vec<(String, Arc<AtomicU64>)>>,
    families: Mutex<BTreeMap<String, BTreeMap<String, Arc<AtomicU64>>>>,
    gauge_families: Mutex<BTreeMap<String, BTreeMap<String, Arc<AtomicU64>>>>,
    histograms: Mutex<Vec<(String, Arc<Mutex<LatencyHistogram>>)>>,
}

impl MetricsRegistry {
    pub fn new() -> Self {
        MetricsRegistry {
            counters: Mutex::new(Vec::new()),
            gauges: Mutex::new(Vec::new()),
            families: Mutex::new(BTreeMap::new()),
            gauge_families: Mutex::new(BTreeMap::new()),
            histograms: Mutex::new(Vec::new()),
        }
    }

    /// Get or create the counter `name`; handles share one atomic.
    pub fn counter(&self, name: &str) -> Counter {
        let mut v = self.counters.lock().unwrap();
        if let Some((_, a)) = v.iter().find(|(n, _)| n == name) {
            return Counter(a.clone());
        }
        let a = Arc::new(AtomicU64::new(0));
        v.push((name.to_string(), a.clone()));
        Counter(a)
    }

    /// Get or create the gauge `name`.
    pub fn gauge(&self, name: &str) -> Gauge {
        let mut v = self.gauges.lock().unwrap();
        if let Some((_, a)) = v.iter().find(|(n, _)| n == name) {
            return Gauge(a.clone());
        }
        let a = Arc::new(AtomicU64::new(0));
        v.push((name.to_string(), a.clone()));
        Gauge(a)
    }

    /// Get or create one series of a labeled counter family, e.g.
    /// `csrc_engine_products_total{matrix=…,engine=…,k=…}`. Labels are
    /// sorted by key so the same set always maps to the same series.
    pub fn family_counter(&self, name: &str, labels: &[(&str, &str)]) -> Counter {
        let blob = label_blob(labels);
        let mut fam = self.families.lock().unwrap();
        let series = fam.entry(name.to_string()).or_default();
        Counter(series.entry(blob).or_insert_with(|| Arc::new(AtomicU64::new(0))).clone())
    }

    /// Get or create one series of a labeled **gauge** family, e.g.
    /// `csrc_shard_breaker_state{shard=…}`. Same label canonicalization
    /// as [`Self::family_counter`], rendered with `# TYPE … gauge`.
    pub fn family_gauge(&self, name: &str, labels: &[(&str, &str)]) -> Gauge {
        let blob = label_blob(labels);
        let mut fam = self.gauge_families.lock().unwrap();
        let series = fam.entry(name.to_string()).or_default();
        Gauge(series.entry(blob).or_insert_with(|| Arc::new(AtomicU64::new(0))).clone())
    }

    /// Register a **new** histogram under `name`. Several handles may
    /// share a name (one per worker); [`Self::merged_histogram`] folds
    /// them into one distribution at snapshot time.
    pub fn histogram(&self, name: &str) -> HistogramHandle {
        let h = Arc::new(Mutex::new(LatencyHistogram::new()));
        self.histograms.lock().unwrap().push((name.to_string(), h.clone()));
        HistogramHandle(h)
    }

    /// Merge every histogram registered under `name`
    /// ([`LatencyHistogram::merge`] is exact: shared bucket layout).
    pub fn merged_histogram(&self, name: &str) -> LatencyHistogram {
        let mut out = LatencyHistogram::new();
        for (n, h) in self.histograms.lock().unwrap().iter() {
            if n == name {
                out.merge(&h.lock().unwrap());
            }
        }
        out
    }

    /// Render the Prometheus text exposition format (version 0.0.4):
    /// counters, labeled families, gauges, histograms (as summaries
    /// with q50/q90/q99 + `_sum`/`_count`), then the process-wide phase
    /// totals as `csrc_phase_seconds_total{phase=…}` / `_calls_total`.
    pub fn render_prometheus(&self) -> String {
        self.render_prometheus_with(&[], true)
    }

    /// [`Self::render_prometheus`] with `extra` label pairs injected
    /// into every sample — the sharded front tags each shard's registry
    /// with `shard="i"` — and optionally without the process-wide phase
    /// totals: those are global, so a front that concatenates N shard
    /// renderings must emit them once, not N times.
    pub fn render_prometheus_with(&self, extra: &[(&str, &str)], include_phases: bool) -> String {
        // `inner` goes inside an existing label block ('k="v",' ...),
        // `bare` is the complete block for otherwise-unlabeled samples.
        let inner: String =
            extra.iter().map(|(k, v)| format!("{k}=\"{}\",", escape_label(v))).collect();
        let bare = if inner.is_empty() {
            String::new()
        } else {
            format!("{{{}}}", inner.trim_end_matches(','))
        };
        let mut out = String::new();
        for (name, a) in self.counters.lock().unwrap().iter() {
            out.push_str(&format!("# TYPE {name} counter\n"));
            out.push_str(&format!("{name}{bare} {}\n", a.load(Relaxed)));
        }
        for (name, series) in self.families.lock().unwrap().iter() {
            out.push_str(&format!("# TYPE {name} counter\n"));
            for (labels, a) in series {
                out.push_str(&format!("{name}{{{inner}{labels}}} {}\n", a.load(Relaxed)));
            }
        }
        for (name, a) in self.gauges.lock().unwrap().iter() {
            out.push_str(&format!("# TYPE {name} gauge\n"));
            out.push_str(&format!("{name}{bare} {}\n", f64::from_bits(a.load(Relaxed))));
        }
        for (name, series) in self.gauge_families.lock().unwrap().iter() {
            out.push_str(&format!("# TYPE {name} gauge\n"));
            for (labels, a) in series {
                out.push_str(&format!(
                    "{name}{{{inner}{labels}}} {}\n",
                    f64::from_bits(a.load(Relaxed))
                ));
            }
        }
        let mut names: Vec<String> = Vec::new();
        for (n, _) in self.histograms.lock().unwrap().iter() {
            if !names.contains(n) {
                names.push(n.clone());
            }
        }
        for name in &names {
            let h = self.merged_histogram(name);
            out.push_str(&format!("# TYPE {name} summary\n"));
            for q in [0.5, 0.9, 0.99] {
                out.push_str(&format!("{name}{{{inner}quantile=\"{q}\"}} {}\n", h.quantile_us(q)));
            }
            out.push_str(&format!("{name}_sum{bare} {}\n", h.sum_us()));
            out.push_str(&format!("{name}_count{bare} {}\n", h.count()));
        }
        if include_phases {
            out.push_str("# TYPE csrc_phase_seconds_total counter\n");
            for t in phase_totals() {
                let label = t.phase.label();
                out.push_str(&format!("csrc_phase_seconds_total{{{inner}phase=\"{label}\"}} "));
                out.push_str(&format!("{}\n", t.seconds()));
            }
            out.push_str("# TYPE csrc_phase_calls_total counter\n");
            for t in phase_totals() {
                let label = t.phase.label();
                out.push_str(&format!(
                    "csrc_phase_calls_total{{{inner}phase=\"{label}\"}} {}\n",
                    t.calls
                ));
            }
        }
        out
    }
}

impl Default for MetricsRegistry {
    fn default() -> Self {
        Self::new()
    }
}

fn escape_label(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

/// Canonical label blob for family series: sorted by key so the same
/// label set always maps to the same series.
fn label_blob(labels: &[(&str, &str)]) -> String {
    let mut sorted: Vec<(&str, &str)> = labels.to_vec();
    sorted.sort();
    sorted
        .iter()
        .map(|(k, v)| format!("{k}=\"{}\"", escape_label(v)))
        .collect::<Vec<_>>()
        .join(",")
}

// ---------------------------------------------------------------------
// Exposition endpoint
// ---------------------------------------------------------------------

/// Serve `GET /metrics` scrapes of `registry` on `addr` from a detached
/// thread; returns the bound address (port 0 picks a free one). The
/// listener lives for the process — it is an exposition endpoint, not a
/// general web server.
pub fn serve_metrics(addr: &str, registry: Arc<MetricsRegistry>) -> std::io::Result<SocketAddr> {
    serve_rendered(addr, move || registry.render_prometheus())
}

/// [`serve_metrics`] generalized to a closure that produces the scrape
/// body — the sharded front composes one exposition per scrape from its
/// own registry plus every shard's (labeled `shard="i"`).
pub fn serve_rendered<F>(addr: &str, render: F) -> std::io::Result<SocketAddr>
where
    F: Fn() -> String + Send + 'static,
{
    let listener = TcpListener::bind(addr)?;
    let local = listener.local_addr()?;
    std::thread::Builder::new().name("csrc-metrics".into()).spawn(move || {
        for mut stream in listener.incoming().flatten() {
            let _ = answer_scrape(&mut stream, &render());
        }
    })?;
    Ok(local)
}

fn answer_scrape(s: &mut TcpStream, body: &str) -> std::io::Result<()> {
    // Best-effort read of the request head; every path gets the same
    // body, so a short or slow request cannot wedge the thread.
    let _ = s.set_read_timeout(Some(std::time::Duration::from_millis(500)));
    let mut head = [0u8; 1024];
    let _ = s.read(&mut head);
    let mut resp = String::new();
    resp.push_str("HTTP/1.1 200 OK\r\n");
    resp.push_str("Content-Type: text/plain; version=0.0.4\r\n");
    resp.push_str(&format!("Content-Length: {}\r\n", body.len()));
    resp.push_str("Connection: close\r\n\r\n");
    resp.push_str(body);
    s.write_all(resp.as_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Tests that toggle the process-wide switches serialize here so
    /// the lib test binary's parallel runner can't interleave them.
    static TEST_LOCK: Mutex<()> = Mutex::new(());

    fn serial() -> std::sync::MutexGuard<'static, ()> {
        TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn disabled_phase_returns_none() {
        let _g = serial();
        set_metrics_enabled(false);
        assert!(!trace_enabled());
        assert!(phase(Phase::Sweep).is_none());
    }

    #[test]
    fn phase_guard_accumulates_time_and_calls() {
        let _g = serial();
        set_metrics_enabled(true);
        let before = phase_totals()[Phase::Accumulate.index()];
        {
            let _p = phase(Phase::Accumulate);
            std::hint::black_box(0u64);
        }
        let after = phase_totals()[Phase::Accumulate.index()];
        set_metrics_enabled(false);
        assert!(after.calls >= before.calls + 1);
        assert!(after.ns >= before.ns);
    }

    #[test]
    fn registry_counters_and_families_render() {
        let reg = MetricsRegistry::new();
        let c = reg.counter("csrc_requests_submitted_total");
        c.add(3);
        // Same name → same atomic.
        assert_eq!(reg.counter("csrc_requests_submitted_total").get(), 3);
        let f = reg.family_counter(
            "csrc_engine_products_total",
            &[("matrix", "thermal"), ("engine", "atomic"), ("k", "4")],
        );
        f.inc();
        // Label order must not mint a second series.
        let f2 = reg.family_counter(
            "csrc_engine_products_total",
            &[("k", "4"), ("engine", "atomic"), ("matrix", "thermal")],
        );
        assert_eq!(f2.get(), 1);
        let g = reg.gauge("csrc_served_mflops");
        g.set(123.5);
        g.add(0.5);
        assert_eq!(g.get(), 124.0);
        let text = reg.render_prometheus();
        assert!(text.contains("csrc_requests_submitted_total 3"));
        assert!(text.contains("# TYPE csrc_engine_products_total counter"));
        assert!(text
            .contains("csrc_engine_products_total{engine=\"atomic\",k=\"4\",matrix=\"thermal\"} 1"));
        assert!(text.contains("csrc_served_mflops 124"));
        assert!(text.contains("csrc_phase_seconds_total{phase=\"sweep\"}"));
    }

    #[test]
    fn registry_histograms_merge_across_handles() {
        let reg = MetricsRegistry::new();
        let a = reg.histogram("csrc_request_latency_us");
        let b = reg.histogram("csrc_request_latency_us");
        a.record(100e-6);
        b.record(200e-6);
        let merged = reg.merged_histogram("csrc_request_latency_us");
        assert_eq!(merged.count(), 2);
        let text = reg.render_prometheus();
        assert!(text.contains("# TYPE csrc_request_latency_us summary"));
        assert!(text.contains("csrc_request_latency_us_count 2"));
    }

    #[test]
    fn validator_accepts_wellformed_and_rejects_malformed() {
        let ok = r#"{"traceEvents":[
            {"name":"serve","cat":"csrc","ph":"B","ts":1.0,"pid":1,"tid":1},
            {"name":"sweep","cat":"csrc","ph":"B","ts":2.0,"pid":1,"tid":1},
            {"name":"sweep","cat":"csrc","ph":"E","ts":3.0,"pid":1,"tid":1},
            {"name":"serve","cat":"csrc","ph":"E","ts":4.0,"pid":1,"tid":1}
        ]}"#;
        assert_eq!(validate_trace_json(&Json::parse(ok).unwrap()).unwrap(), 4);
        // Unknown phase name.
        let bad_name = ok.replace("\"sweep\"", "\"mystery\"");
        assert!(validate_trace_json(&Json::parse(&bad_name).unwrap()).is_err());
        // Non-monotone timestamps.
        let bad_ts = ok.replace("\"ts\":3.0", "\"ts\":0.5");
        assert!(validate_trace_json(&Json::parse(&bad_ts).unwrap()).is_err());
        // Unbalanced: drop the last end event.
        let unbalanced = r#"{"traceEvents":[
            {"name":"serve","cat":"csrc","ph":"B","ts":1.0,"pid":1,"tid":1}
        ]}"#;
        assert!(validate_trace_json(&Json::parse(unbalanced).unwrap()).is_err());
        // Interleaved (not nested) spans on one thread.
        let crossed = r#"{"traceEvents":[
            {"name":"serve","cat":"csrc","ph":"B","ts":1.0,"pid":1,"tid":1},
            {"name":"sweep","cat":"csrc","ph":"B","ts":2.0,"pid":1,"tid":1},
            {"name":"serve","cat":"csrc","ph":"E","ts":3.0,"pid":1,"tid":1},
            {"name":"sweep","cat":"csrc","ph":"E","ts":4.0,"pid":1,"tid":1}
        ]}"#;
        assert!(validate_trace_json(&Json::parse(crossed).unwrap()).is_err());
    }

    #[test]
    fn metrics_endpoint_serves_scrapes() {
        let reg = Arc::new(MetricsRegistry::new());
        reg.counter("csrc_requests_submitted_total").add(7);
        let addr = serve_metrics("127.0.0.1:0", reg).expect("bind loopback");
        let mut conn = TcpStream::connect(addr).expect("connect");
        conn.write_all(b"GET /metrics HTTP/1.1\r\nHost: localhost\r\n\r\n").unwrap();
        let mut resp = String::new();
        conn.read_to_string(&mut resp).unwrap();
        assert!(resp.starts_with("HTTP/1.1 200 OK"));
        assert!(resp.contains("text/plain"));
        assert!(resp.contains("csrc_requests_submitted_total 7"));
    }
}
