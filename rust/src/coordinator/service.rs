//! The matvec service: registry + request queue + batcher + workers.
//!
//! Flow: `submit()` enqueues (matrix-key, x, reply-channel) → the
//! dispatcher thread drains the queue, forms per-matrix batches
//! ([`super::batcher`]), and hands each batch to a worker → the worker
//! resolves the backend via the [`super::router`] policy, runs the
//! products on its cached engine, and replies through each request's
//! channel. Metrics (counts + latency histogram) are sampled on the
//! worker side.

use super::batcher::{form_batches, BatchPolicy};
use super::router::{Backend, RoutePolicy, Router};
use crate::metrics::LatencyHistogram;
use crate::parallel::{build_engine, ParallelSpmv};
use crate::sparse::Csrc;
use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Service configuration.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    pub workers: usize,
    pub batch: BatchPolicy,
    pub route: RoutePolicy,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig { workers: 2, batch: BatchPolicy::default(), route: RoutePolicy::default() }
    }
}

struct Request {
    matrix: String,
    x: Vec<f64>,
    enqueued: Instant,
    reply: Sender<Result<Vec<f64>, String>>,
}

struct WorkerBatch {
    matrix: String,
    requests: Vec<Request>,
}

/// Shared mutable service state.
#[derive(Default)]
struct Stats {
    submitted: u64,
    completed: u64,
    failed: u64,
    batches: u64,
    latency: Option<LatencyHistogram>,
}

/// Observable service counters.
#[derive(Clone, Debug)]
pub struct ServiceStats {
    pub submitted: u64,
    pub completed: u64,
    pub failed: u64,
    pub batches: u64,
    pub mean_latency_us: f64,
    pub p99_latency_us: f64,
}

pub struct MatvecService {
    registry: Arc<Mutex<HashMap<String, Arc<Csrc>>>>,
    queue_tx: Option<Sender<Request>>,
    dispatcher: Option<std::thread::JoinHandle<()>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    stats: Arc<Mutex<Stats>>,
}

impl MatvecService {
    pub fn start(cfg: ServiceConfig) -> MatvecService {
        let registry: Arc<Mutex<HashMap<String, Arc<Csrc>>>> = Arc::new(Mutex::new(HashMap::new()));
        let stats = Arc::new(Mutex::new(Stats { latency: Some(LatencyHistogram::new()), ..Default::default() }));
        let (queue_tx, queue_rx) = channel::<Request>();

        // Worker channels.
        let mut worker_txs: Vec<Sender<WorkerBatch>> = Vec::new();
        let mut workers = Vec::new();
        for wid in 0..cfg.workers.max(1) {
            let (tx, rx) = channel::<WorkerBatch>();
            worker_txs.push(tx);
            let registry = registry.clone();
            let stats = stats.clone();
            let route = cfg.route.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("matvec-worker-{wid}"))
                    .spawn(move || worker_loop(rx, registry, route, stats))
                    .expect("spawn worker"),
            );
        }

        // Dispatcher: drain queue -> batches -> round-robin workers.
        let batch_policy = cfg.batch;
        let stats_d = stats.clone();
        let dispatcher = std::thread::Builder::new()
            .name("matvec-dispatcher".into())
            .spawn(move || dispatcher_loop(queue_rx, worker_txs, batch_policy, stats_d))
            .expect("spawn dispatcher");

        MatvecService {
            registry,
            queue_tx: Some(queue_tx),
            dispatcher: Some(dispatcher),
            workers,
            stats,
        }
    }

    /// Register (or replace) a matrix under a key.
    pub fn register(&self, key: &str, a: Arc<Csrc>) {
        self.registry.lock().unwrap().insert(key.to_string(), a);
    }

    /// Submit y = A·x; returns the reply channel.
    pub fn submit(&self, key: &str, x: Vec<f64>) -> Receiver<Result<Vec<f64>, String>> {
        let (tx, rx) = channel();
        {
            let mut s = self.stats.lock().unwrap();
            s.submitted += 1;
        }
        let req = Request { matrix: key.to_string(), x, enqueued: Instant::now(), reply: tx };
        // If the service is shutting down the reply channel will just
        // return a disconnect error to the caller.
        if let Some(q) = &self.queue_tx {
            let _ = q.send(req);
        }
        rx
    }

    /// Convenience: submit and wait.
    pub fn call(&self, key: &str, x: Vec<f64>) -> Result<Vec<f64>, String> {
        self.submit(key, x)
            .recv()
            .map_err(|_| "service shut down before reply".to_string())?
    }

    pub fn stats(&self) -> ServiceStats {
        let s = self.stats.lock().unwrap();
        let lat = s.latency.as_ref().unwrap();
        ServiceStats {
            submitted: s.submitted,
            completed: s.completed,
            failed: s.failed,
            batches: s.batches,
            mean_latency_us: lat.mean_us(),
            p99_latency_us: lat.quantile_us(0.99),
        }
    }

    /// Graceful shutdown: drain, stop threads.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        self.queue_tx.take(); // closes the queue; dispatcher drains & exits
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for MatvecService {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

fn dispatcher_loop(
    queue: Receiver<Request>,
    worker_txs: Vec<Sender<WorkerBatch>>,
    policy: BatchPolicy,
    stats: Arc<Mutex<Stats>>,
) {
    let mut next_worker = 0usize;
    loop {
        // Block for the first request; then greedily drain within the
        // batching window.
        let first = match queue.recv() {
            Ok(r) => r,
            Err(_) => return, // queue closed: done (workers closed by drop of txs)
        };
        let mut pending = vec![first];
        let deadline = Instant::now() + policy.max_wait;
        while pending.len() < policy.max_batch * worker_txs.len() {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match queue.recv_timeout(deadline - now) {
                Ok(r) => pending.push(r),
                Err(_) => break,
            }
        }
        // Form per-matrix batches and ship them.
        let keys: Vec<String> = pending.iter().map(|r| r.matrix.clone()).collect();
        let batches = form_batches(&keys, &policy);
        {
            let mut s = stats.lock().unwrap();
            s.batches += batches.len() as u64;
        }
        // Move requests out of `pending` into their batches (descending
        // index take keeps indices valid).
        let mut slots: Vec<Option<Request>> = pending.into_iter().map(Some).collect();
        for b in batches {
            let reqs: Vec<Request> =
                b.requests.iter().map(|&i| slots[i].take().expect("batch index")).collect();
            let wb = WorkerBatch { matrix: b.matrix, requests: reqs };
            let _ = worker_txs[next_worker % worker_txs.len()].send(wb);
            next_worker += 1;
        }
    }
}

fn worker_loop(
    rx: Receiver<WorkerBatch>,
    registry: Arc<Mutex<HashMap<String, Arc<Csrc>>>>,
    route: RoutePolicy,
    stats: Arc<Mutex<Stats>>,
) {
    let router = Router::new(route);
    // Engine cache per (matrix, backend) — engines are not Sync, each
    // worker owns its own.
    let mut engines: HashMap<String, Box<dyn ParallelSpmv>> = HashMap::new();
    while let Ok(batch) = rx.recv() {
        let a = registry.lock().unwrap().get(&batch.matrix).cloned();
        let Some(a) = a else {
            let mut s = stats.lock().unwrap();
            for r in batch.requests {
                s.failed += 1;
                let _ = r.reply.send(Err(format!("unknown matrix {:?}", batch.matrix)));
            }
            continue;
        };
        let backend = router.route(&a);
        for req in batch.requests {
            if req.x.len() != a.n {
                let mut s = stats.lock().unwrap();
                s.failed += 1;
                let _ = req
                    .reply
                    .send(Err(format!("x length {} != n {}", req.x.len(), a.n)));
                continue;
            }
            let mut y = vec![0.0; a.n];
            match &backend {
                Backend::NativeSequential => a.spmv_into_zeroed(&req.x, &mut y),
                Backend::NativeParallel { kind, threads } => {
                    let engine = engines.entry(format!("{}/{}", batch.matrix, kind.label()))
                        .or_insert_with(|| build_engine(*kind, a.clone(), *threads));
                    engine.spmv(&req.x, &mut y);
                }
                Backend::Xla { artifact } => {
                    // The XLA path is exercised via examples/ and the CLI
                    // (XlaRuntime is heavyweight); in-service we fall back
                    // to sequential to keep the worker self-contained.
                    let _ = artifact;
                    a.spmv_into_zeroed(&req.x, &mut y);
                }
            }
            let mut s = stats.lock().unwrap();
            s.completed += 1;
            s.latency.as_mut().unwrap().record(req.enqueued.elapsed().as_secs_f64());
            let _ = req.reply.send(Ok(std::mem::take(&mut y)));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::Coo;
    use crate::util::Rng;

    fn mat(n: usize, seed: u64) -> Arc<Csrc> {
        let mut rng = Rng::new(seed);
        Arc::new(Csrc::from_coo(&Coo::random_structurally_symmetric(n, 3, false, &mut rng)).unwrap())
    }

    #[test]
    fn serves_correct_products() {
        let svc = MatvecService::start(ServiceConfig::default());
        let a = mat(80, 80);
        svc.register("a", a.clone());
        let x: Vec<f64> = (0..80).map(|i| i as f64 * 0.01).collect();
        let y = svc.call("a", x.clone()).unwrap();
        let mut want = vec![0.0; 80];
        a.spmv_into_zeroed(&x, &mut want);
        crate::util::propcheck::assert_close(&y, &want, 1e-12, 1e-12).unwrap();
        let s = svc.stats();
        assert_eq!(s.completed, 1);
        svc.shutdown();
    }

    #[test]
    fn unknown_matrix_fails_cleanly() {
        let svc = MatvecService::start(ServiceConfig::default());
        let err = svc.call("ghost", vec![1.0; 4]).unwrap_err();
        assert!(err.contains("unknown matrix"), "{err}");
        assert_eq!(svc.stats().failed, 1);
    }

    #[test]
    fn wrong_length_fails_cleanly() {
        let svc = MatvecService::start(ServiceConfig::default());
        svc.register("a", mat(50, 81));
        let err = svc.call("a", vec![1.0; 3]).unwrap_err();
        assert!(err.contains("length"), "{err}");
    }

    #[test]
    fn many_concurrent_requests_all_served() {
        let svc = MatvecService::start(ServiceConfig::default());
        let a = mat(60, 82);
        let b = mat(40, 83);
        svc.register("a", a.clone());
        svc.register("b", b.clone());
        let mut rxs = Vec::new();
        for i in 0..40 {
            let key = if i % 3 == 0 { "b" } else { "a" };
            let n = if key == "a" { 60 } else { 40 };
            let x: Vec<f64> = (0..n).map(|j| (i * j) as f64 * 1e-3).collect();
            rxs.push((key, x.clone(), svc.submit(key, x)));
        }
        for (key, x, rx) in rxs {
            let y = rx.recv().unwrap().unwrap();
            let m = if key == "a" { &a } else { &b };
            let mut want = vec![0.0; m.n];
            m.spmv_into_zeroed(&x, &mut want);
            crate::util::propcheck::assert_close(&y, &want, 1e-12, 1e-12).unwrap();
        }
        let s = svc.stats();
        assert_eq!(s.completed, 40);
        assert!(s.batches >= 2, "should have formed multiple batches");
        assert!(s.mean_latency_us > 0.0);
        svc.shutdown();
    }

    #[test]
    fn parallel_backend_used_for_large_matrices() {
        let mut cfg = ServiceConfig::default();
        cfg.route.min_parallel_n = 32; // force the parallel path
        cfg.route.threads = 2;
        let svc = MatvecService::start(cfg);
        let a = mat(200, 84);
        svc.register("big", a.clone());
        let x = vec![1.0; 200];
        let y = svc.call("big", x.clone()).unwrap();
        let mut want = vec![0.0; 200];
        a.spmv_into_zeroed(&x, &mut want);
        crate::util::propcheck::assert_close(&y, &want, 1e-11, 1e-11).unwrap();
        svc.shutdown();
    }

    #[test]
    fn property_service_matches_sequential() {
        crate::util::propcheck::check(5, |rng| {
            let n = 20 + rng.below(80);
            let a = {
                let coo = Coo::random_structurally_symmetric(n, 2, false, rng);
                Arc::new(Csrc::from_coo(&coo).map_err(|e| e.to_string())?)
            };
            let svc = MatvecService::start(ServiceConfig::default());
            svc.register("m", a.clone());
            for _ in 0..3 {
                let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
                let y = svc.call("m", x.clone())?;
                let mut want = vec![0.0; n];
                a.spmv_into_zeroed(&x, &mut want);
                crate::util::propcheck::assert_close(&y, &want, 1e-11, 1e-11)?;
            }
            svc.shutdown();
            Ok(())
        });
    }
}
