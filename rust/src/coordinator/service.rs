//! The matvec service: registry + plan cache + request queue + batcher +
//! workers.
//!
//! Flow: `submit()` enqueues (matrix-key, x, reply-channel) → the
//! dispatcher thread drains the queue, forms per-matrix batches
//! ([`super::batcher`]), and hands each batch to a worker → the worker
//! resolves the backend via the [`super::router`] policy, runs the
//! products on its cached engine, and replies through each request's
//! channel. Metrics (counts + latency histogram) are sampled on the
//! worker side into the service's [`MetricsRegistry`] —
//! [`ServiceStats`] is a typed snapshot over those registry atomics,
//! and the same registry serves Prometheus scrapes
//! ([`crate::obs::serve_metrics`]), so the CLI endpoint and `stats()`
//! can never disagree.
//!
//! Engines hold execution state (pools, buffers) and stay per-worker,
//! but the *analysis* they run — the [`crate::plan::SpmvPlan`] — is
//! shared: one [`PlanCache`] maps matrix-key × thread-count to a single
//! `Arc<SpmvPlan>` that every worker and engine borrows, so a matrix
//! registered once is analyzed once, not once per worker × engine. Plan
//! build count and time are surfaced in [`ServiceStats`].
//!
//! Autotuned routing is *self-correcting*: workers fold each batch's
//! measured rate into a per-key EWMA, and when it drifts below
//! [`ServiceConfig::drift_fraction`] of the decision's recorded rate the
//! key is queued to a background re-tuner thread — the decision cache
//! entry is upgraded off the request path, never on it.

use super::batcher::{form_batches, summarize, BatchPolicy};
use super::router::{Backend, RoutePolicy, Router};
use crate::metrics;
use crate::obs::{self, Counter, HistogramHandle, MetricsRegistry, Phase};
use crate::parallel::{build_engine, EngineKind, ParallelSpmv};
use crate::plan::{PlanBuilder, PlanCache};
use crate::reorder::{self, Permutation, ReorderedEngine};
use crate::sparse::{Csrc, SpmvKernel};
use crate::tuner::{self, DecisionCache, TrialBudget};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Weight of the newest batch in the drift EWMA (higher = jumpier).
const EWMA_ALPHA: f64 = 0.3;

/// Panel width used to coalesce same-matrix requests on routes without
/// a tuned block pick (explicit engine routes, and requests racing an
/// Auto resolution). Matches the top of the tuner's block ladder.
const DEFAULT_PANEL_WIDTH: usize = 8;

/// Service configuration.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    pub workers: usize,
    pub batch: BatchPolicy,
    pub route: RoutePolicy,
    /// Trial budget used when `route.parallel_kind` is
    /// [`EngineKind::Auto`]; a zero budget answers from the cost model.
    pub tune_budget: TrialBudget,
    /// Persist autotuner decisions here (`None` = in-memory only). A
    /// restarted service pointed at the same file re-tunes nothing it
    /// has already measured.
    pub decision_cache: Option<PathBuf>,
    /// Learned cost-model file ([`tuner::CostModel`], written by
    /// `csrc tune train`) consulted for zero-budget/cold-start Auto
    /// resolutions *before* the hand-written heuristic. `None` — or an
    /// unreadable file — means heuristic only. Fallback order per
    /// registration: decision-cache hit → model → heuristic
    /// (`ServiceStats::{model_hits, model_fallbacks}`).
    pub model: Option<PathBuf>,
    /// Max engines one worker keeps cached (LRU by last-served batch).
    /// Each cached engine pins a thread pool, so abandoned keys must not
    /// park pools forever.
    pub engine_cache_capacity: usize,
    /// Queue a background re-tune when a served matrix's measured rate
    /// (per-key EWMA over batches) drops below this fraction of its
    /// decision's recorded rate. `0.0` disables drift detection.
    pub drift_fraction: f64,
    /// Batches observed for a key before drift is judged — the EWMA
    /// needs a few samples before it means anything.
    pub drift_min_batches: u64,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            workers: 2,
            batch: BatchPolicy::default(),
            route: RoutePolicy::default(),
            tune_budget: TrialBudget::default(),
            decision_cache: None,
            model: None,
            engine_cache_capacity: 32,
            drift_fraction: 0.5,
            drift_min_batches: 8,
        }
    }
}

struct Request {
    matrix: String,
    x: Vec<f64>,
    enqueued: Instant,
    reply: Sender<Result<Vec<f64>, String>>,
}

struct WorkerBatch {
    matrix: String,
    requests: Vec<Request>,
}

/// What an Auto registration resolved to — everything a worker needs to
/// build the engine and to judge rate drift.
#[derive(Clone, Copy, Debug)]
struct ResolvedAuto {
    kind: EngineKind,
    /// The winner ran through the RCM ordering: serve via the permuted
    /// matrix with per-request permute/un-permute.
    reorder: bool,
    /// The decision's thread count (the swept pick, not necessarily
    /// `RoutePolicy::threads`).
    nthreads: usize,
    /// The decision's recorded rate (0 when unmeasured).
    mflops: f64,
    /// Served-rate baseline ([`tuner::Decision::served_mflops`]): the
    /// per-request EWMA recorded after a drift re-tune. When > 0, drift
    /// is judged against it instead of the optimistic trial rate.
    served_mflops: f64,
    /// The work units the decision's rate was normalized by
    /// (`Features::work_flops`). The drift EWMA must use the *same*
    /// normalization — `Csrc::flops()` counts the symmetric kernel's
    /// flops differently, which would skew the comparison by up to 2×.
    work_flops: usize,
    measured: bool,
    /// The decision-cache key, so a worker can write the served
    /// baseline back into the persisted entry.
    fingerprint: u64,
    max_threads: usize,
    /// The decision's tuned panel width: same-matrix requests in one
    /// batch coalesce into `spmv_multi` panels this wide (1 = the
    /// blocked product lost its own tuning race, serve serially).
    block_k: usize,
}

impl ResolvedAuto {
    fn from_decision(d: &tuner::Decision) -> ResolvedAuto {
        ResolvedAuto {
            kind: d.kind,
            reorder: d.reorder,
            nthreads: d.nthreads,
            mflops: d.mflops,
            served_mflops: d.served_mflops,
            work_flops: d.features.work_flops,
            measured: d.measured,
            fingerprint: d.fingerprint,
            max_threads: d.max_threads,
            block_k: d.block_k.max(1),
        }
    }
}

/// Per-key drift tracking state (keyed by `key@generation`).
#[derive(Clone, Copy, Debug, Default)]
struct DriftState {
    ewma_mflops: f64,
    batches: u64,
    /// A re-tune has been queued and not yet completed — don't queue
    /// another for the same key × generation.
    retune_pending: bool,
    /// Set by the re-tuner when it publishes an upgraded decision: the
    /// next `drift_min_batches` batches *calibrate* — their EWMA is
    /// recorded as the entry's served baseline instead of being judged
    /// against the fresh (warm, optimistic) trial rate. Without this a
    /// decision whose trial rate sits far above serving reality would
    /// re-trigger after every re-tune: a storm.
    calibrating: bool,
    /// The baseline the calibration window recorded (0 = none yet).
    /// Judgement reads it here, under the same lock, rather than from
    /// the batch's `ResolvedAuto` snapshot: a second worker whose
    /// snapshot predates the calibration write must not re-judge
    /// against the optimistic trial rate and queue a spurious re-tune.
    served_baseline: f64,
}

/// A drift-triggered re-tune request, handled off the request path.
struct RetuneJob {
    matrix: String,
    cache_key: String,
    generation: u64,
}

/// Work for the `matvec-retuner` thread — everything that must stay off
/// the request path.
enum RetunerMsg {
    /// Re-run the measured trials and upgrade the decision entry.
    Retune(RetuneJob),
    /// Persist a calibration window's served-EWMA baseline into the
    /// cache entry. `DecisionCache::set_served_rate` rewrites the whole
    /// file, so a worker must not pay for it inside a batch.
    RecordServedRate { fingerprint: u64, max_threads: usize, mflops: f64 },
}

/// Auto-route choice log. Genuinely structured (ordered key/value
/// pairs), so it lives behind a small mutex next to the registry's
/// scalar atomics — nothing on the request path touches it.
#[derive(Default)]
struct ChoiceLog {
    auto_choices: Vec<(String, String)>,
    chosen_threads: Vec<(String, usize)>,
}

/// Shared mutable service state: typed handles into the service's
/// [`MetricsRegistry`]. Every scalar [`ServiceStats`] reports lives in
/// a registry atomic, so a `stats()` snapshot and a Prometheus scrape
/// read the *same* cells — the old `Mutex<Stats>` could not serve a
/// scrape without cloning, and a lock-free copy of it could tear.
struct Counters {
    obs: Arc<MetricsRegistry>,
    submitted: Counter,
    completed: Counter,
    failed: Counter,
    batches: Counter,
    tunes: Counter,
    /// Nanoseconds — registry counters are integers; `stats()` converts
    /// back to seconds.
    tune_ns: Counter,
    engines_evicted: Counter,
    retunes: Counter,
    drift_events: Counter,
    model_hits: Counter,
    model_fallbacks: Counter,
    coalesced_products: Counter,
    coalesced_requests: Counter,
    rcm_builds: Counter,
    choices: Mutex<ChoiceLog>,
}

impl Counters {
    fn new(obs: Arc<MetricsRegistry>) -> Counters {
        Counters {
            submitted: obs.counter("csrc_requests_submitted_total"),
            completed: obs.counter("csrc_requests_completed_total"),
            failed: obs.counter("csrc_requests_failed_total"),
            batches: obs.counter("csrc_batches_total"),
            tunes: obs.counter("csrc_tunes_total"),
            tune_ns: obs.counter("csrc_tune_ns_total"),
            engines_evicted: obs.counter("csrc_engines_evicted_total"),
            retunes: obs.counter("csrc_retunes_total"),
            drift_events: obs.counter("csrc_drift_events_total"),
            model_hits: obs.counter("csrc_model_hits_total"),
            model_fallbacks: obs.counter("csrc_model_fallbacks_total"),
            coalesced_products: obs.counter("csrc_coalesced_products_total"),
            coalesced_requests: obs.counter("csrc_coalesced_requests_total"),
            rcm_builds: obs.counter("csrc_rcm_builds_total"),
            choices: Mutex::new(ChoiceLog::default()),
            obs,
        }
    }

    fn add_tune_seconds(&self, s: f64) {
        self.tune_ns.add((s * 1e9) as u64);
    }
}

/// Observable service counters: a typed snapshot over the service's
/// [`MetricsRegistry`] atomics, taken in an order that preserves
/// `completed + failed <= submitted` even while workers are mid-batch.
#[derive(Clone, Debug)]
pub struct ServiceStats {
    pub submitted: u64,
    pub completed: u64,
    pub failed: u64,
    pub batches: u64,
    pub mean_latency_us: f64,
    pub p99_latency_us: f64,
    /// How many scheduling plans were built (cache misses) — with N
    /// workers all serving one matrix this stays 1, not N.
    pub plan_builds: u64,
    /// Total wall-clock seconds spent in plan analysis.
    pub plan_build_seconds: f64,
    /// Measured tuning runs performed for `EngineKind::Auto`
    /// registrations (decision-cache hits do not count).
    pub tunes: u64,
    /// Wall-clock seconds spent inside those tuning runs.
    pub tune_seconds: f64,
    /// Autotuner decisions answered from the (possibly persisted)
    /// decision cache with zero new trials.
    pub decision_hits: u64,
    /// Engines dropped from worker caches by the LRU eviction policy.
    pub engines_evicted: u64,
    /// (matrix key, resolved engine label) per Auto registration, in
    /// registration order.
    pub auto_choices: Vec<(String, String)>,
    /// (matrix key, decision thread count) per Auto registration — with
    /// `RoutePolicy::sweep_threads` this is the swept pick, which may
    /// sit below `RoutePolicy::threads`.
    pub chosen_threads: Vec<(String, usize)>,
    /// Background re-tunes completed after drift detection.
    pub retunes: u64,
    /// Batches whose rate EWMA sat below the drift threshold.
    pub drift_events: u64,
    /// Cold-start Auto registrations answered by the learned cost model
    /// (zero-budget predictions; decision-cache hits count in
    /// `decision_hits`, not here).
    pub model_hits: u64,
    /// Cold-start Auto registrations that fell back to the hand-written
    /// heuristic — no model configured, or it declined to predict.
    pub model_fallbacks: u64,
    /// Blocked (`spmv_multi`) products run in place of serial per-request
    /// products — one per coalesced panel.
    pub coalesced_products: u64,
    /// Requests served through those panels (`Σ` panel widths).
    pub coalesced_requests: u64,
    /// RCM orderings computed for reordered serving. With N workers all
    /// serving one key through the shared registry this stays 1, not N.
    pub rcm_builds: u64,
}

/// Registry value: the matrix plus a per-key generation counter.
/// Worker-side caches (engines, plans) key on `key@generation`, so a
/// replaced matrix can never be served by state built for its
/// predecessor — stale engines become unreachable instead of unsound.
type Registry = HashMap<String, (Arc<Csrc>, u64)>;

/// Shared RCM artifacts for reordered serving, keyed by
/// `key@generation`: the permutation and the permuted matrix. Shared
/// across workers (like the plan cache) so a matrix served reordered by
/// N workers is permuted once, not once per worker; entries of retired
/// generations are collected by `register()` on replacement.
type RcmRegistry = HashMap<String, (Arc<Csrc>, Arc<Permutation>)>;

pub struct MatvecService {
    registry: Arc<Mutex<Registry>>,
    plans: Arc<PlanCache>,
    queue_tx: Option<Sender<Request>>,
    dispatcher: Option<std::thread::JoinHandle<()>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    stats: Arc<Counters>,
    route: RoutePolicy,
    tune_budget: TrialBudget,
    decisions: Arc<DecisionCache>,
    /// Learned cost model for cold-start resolutions (loaded once at
    /// start; shared with the workers for the racing-request fallback).
    model: Option<Arc<tuner::CostModel>>,
    /// `key@generation` → engine + thread count resolved for an Auto route.
    resolved: Arc<Mutex<HashMap<String, ResolvedAuto>>>,
    /// `key@generation` → RCM artifacts shared by all workers.
    rcm: Arc<Mutex<RcmRegistry>>,
    /// `key@generation` → served-rate EWMA for drift detection.
    drift: Arc<Mutex<HashMap<String, DriftState>>>,
    retune_tx: Option<Sender<RetunerMsg>>,
    retuner: Option<std::thread::JoinHandle<()>>,
}

impl MatvecService {
    pub fn start(cfg: ServiceConfig) -> MatvecService {
        let registry: Arc<Mutex<Registry>> = Arc::new(Mutex::new(HashMap::new()));
        let plans = Arc::new(PlanCache::new());
        let stats = Arc::new(Counters::new(Arc::new(MetricsRegistry::new())));
        let decisions = Arc::new(match &cfg.decision_cache {
            Some(path) => DecisionCache::open(path),
            None => DecisionCache::in_memory(),
        });
        // A missing/invalid model file degrades (with a warning from
        // `load`) to the heuristic — never a startup failure.
        let model = cfg.model.as_ref().and_then(|p| tuner::CostModel::load(p)).map(Arc::new);
        let resolved: Arc<Mutex<HashMap<String, ResolvedAuto>>> =
            Arc::new(Mutex::new(HashMap::new()));
        let rcm: Arc<Mutex<RcmRegistry>> = Arc::new(Mutex::new(HashMap::new()));
        let drift: Arc<Mutex<HashMap<String, DriftState>>> = Arc::new(Mutex::new(HashMap::new()));
        let (queue_tx, queue_rx) = channel::<Request>();
        let (retune_tx, retune_rx) = channel::<RetunerMsg>();

        // Background re-tuner: drains drift-triggered jobs off the
        // request path, upgrades the decision cache in place.
        let retuner_ctx = RetunerCtx {
            registry: registry.clone(),
            plans: plans.clone(),
            route: cfg.route.clone(),
            budget: cfg.tune_budget,
            decisions: decisions.clone(),
            resolved: resolved.clone(),
            drift: drift.clone(),
            stats: stats.clone(),
        };
        let retuner = std::thread::Builder::new()
            .name("matvec-retuner".into())
            .spawn(move || retuner_loop(retune_rx, retuner_ctx))
            .expect("spawn retuner");

        // Worker channels.
        let mut worker_txs: Vec<Sender<WorkerBatch>> = Vec::new();
        let mut workers = Vec::new();
        for wid in 0..cfg.workers.max(1) {
            let (tx, rx) = channel::<WorkerBatch>();
            worker_txs.push(tx);
            let ctx = WorkerCtx {
                registry: registry.clone(),
                plans: plans.clone(),
                route: cfg.route.clone(),
                stats: stats.clone(),
                latency: stats.obs.histogram("csrc_request_latency_us"),
                resolved: resolved.clone(),
                rcm: rcm.clone(),
                drift: drift.clone(),
                model: model.clone(),
                retune_tx: retune_tx.clone(),
                engine_capacity: cfg.engine_cache_capacity.max(1),
                drift_fraction: cfg.drift_fraction,
                drift_min_batches: cfg.drift_min_batches,
            };
            workers.push(
                std::thread::Builder::new()
                    .name(format!("matvec-worker-{wid}"))
                    .spawn(move || worker_loop(rx, ctx))
                    .expect("spawn worker"),
            );
        }

        // Dispatcher: drain queue -> batches -> round-robin workers.
        let batch_policy = cfg.batch;
        let stats_d = stats.clone();
        let dispatcher = std::thread::Builder::new()
            .name("matvec-dispatcher".into())
            .spawn(move || dispatcher_loop(queue_rx, worker_txs, batch_policy, stats_d))
            .expect("spawn dispatcher");

        MatvecService {
            registry,
            plans,
            queue_tx: Some(queue_tx),
            dispatcher: Some(dispatcher),
            workers,
            stats,
            route: cfg.route,
            tune_budget: cfg.tune_budget,
            decisions,
            model,
            resolved,
            rcm,
            drift,
            retune_tx: Some(retune_tx),
            retuner: Some(retuner),
        }
    }

    /// Register (or replace) a matrix under a key. Replacement bumps the
    /// key's generation: workers' engine caches and the plan cache are
    /// keyed by generation, so state built for the old matrix is never
    /// consulted again. All prior generations' plans are swept here
    /// (prefix match, so a plan raced in by a worker mid-replace is
    /// collected by the next replacement at the latest); workers evict a
    /// key's retired engines the next time they serve that key, and the
    /// per-worker LRU cap (`ServiceConfig::engine_cache_capacity`)
    /// bounds how long an abandoned key's last engine can stay parked.
    pub fn register(&self, key: &str, a: Arc<Csrc>) {
        // Drop the registry lock before sweeping plans: plan builds hold
        // the cache lock for their whole (possibly long) analysis, and
        // every worker batch starts with a registry read — invalidating
        // under the registry lock would stall all workers behind an
        // unrelated build.
        let (generation, replaced) = {
            let mut reg = self.registry.lock().unwrap();
            let generation = reg.get(key).map(|(_, g)| g + 1).unwrap_or(0);
            let replaced = reg.insert(key.to_string(), (a.clone(), generation)).is_some();
            (generation, replaced)
        };
        if replaced {
            let prefix = format!("{key}@");
            // Plans may over-match (a user key containing '@' aliases the
            // prefix) — that only costs a rebuild. Resolved Auto entries
            // are repopulated by register() alone, so they must match
            // exactly: `key@<generation>` with an all-digit suffix, never
            // another live key like `key@other@0`.
            self.plans.invalidate_prefix(&prefix);
            // RCM artifacts follow the plans' lifecycle: purged here by
            // prefix (over-matching only costs a rebuild; an artifact a
            // worker races in mid-replace is collected by the next
            // replacement at the latest).
            self.rcm.lock().unwrap().retain(|k, _| !k.starts_with(&prefix));
            self.resolved.lock().unwrap().retain(|k, _| !is_generation_of(k, &prefix));
            self.drift.lock().unwrap().retain(|k, _| !is_generation_of(k, &prefix));
        }
        // Auto routing: resolve the concrete engine — and, with
        // `sweep_threads`, the thread count — now, off the request path.
        // The decision cache is keyed by structure fingerprint × thread
        // budget, so a re-registered matrix — or one registered with a
        // service restarted onto the same persisted cache — resolves
        // with zero new trials. (A request racing this resolution falls
        // back to the model/heuristic inside the worker; it never
        // blocks.)
        if self.route.parallel_kind == EngineKind::Auto && a.n >= self.route.min_parallel_n {
            let cache_key = format!("{key}@{generation}");
            let kernel: Arc<dyn SpmvKernel> = a.clone();
            let threads = self.route.threads.max(1);
            let (d, hit) = if self.route.sweep_threads {
                let ladder = tuner::thread_ladder(threads);
                let mut plan_for = tuner::cached_plan_provider(&self.plans, &cache_key, &kernel);
                let r = tuner::resolve_swept_with_model(
                    &kernel,
                    &ladder,
                    &self.tune_budget,
                    &self.decisions,
                    &mut plan_for,
                    self.route.reorder,
                    self.model.as_deref(),
                );
                // Only the winning rung's analysis stays alive — for
                // the plain plans and any reordered (`#rcm`) plans the
                // workers may have built at losing thread counts.
                self.plans.invalidate_other_threads(&cache_key, r.0.nthreads);
                self.plans
                    .invalidate_other_threads(&format!("{cache_key}#rcm"), r.0.nthreads);
                r
            } else {
                let plan = self.plans.get_or_build(
                    &cache_key,
                    kernel.as_ref(),
                    PlanBuilder::new(threads).with_pieces(tuner::required_pieces(threads)),
                );
                tuner::resolve_with_model(
                    &kernel,
                    &plan,
                    &self.tune_budget,
                    &self.decisions,
                    self.route.reorder,
                    self.model.as_deref(),
                )
            };
            self.resolved
                .lock()
                .unwrap()
                .insert(cache_key.clone(), ResolvedAuto::from_decision(&d));
            // Fresh drift baseline for the new decision/generation.
            self.drift.lock().unwrap().insert(cache_key, DriftState::default());
            if !hit {
                self.stats.tunes.inc();
                self.stats.add_tune_seconds(d.tuned_s);
                // Cold-start provenance: who answered when no cached
                // decision satisfied the caller.
                match d.provenance {
                    tuner::Provenance::Model => self.stats.model_hits.inc(),
                    tuner::Provenance::Heuristic => self.stats.model_fallbacks.inc(),
                    tuner::Provenance::Measured => {}
                }
            }
            // Reordered winners are visible in the choice log (the plain
            // label still parses as an EngineKind for plain winners).
            let mut log = self.stats.choices.lock().unwrap();
            log.auto_choices.push((key.to_string(), d.label()));
            log.chosen_threads.push((key.to_string(), d.nthreads));
        }
    }

    /// Submit y = A·x; returns the reply channel.
    pub fn submit(&self, key: &str, x: Vec<f64>) -> Receiver<Result<Vec<f64>, String>> {
        let (tx, rx) = channel();
        self.stats.submitted.inc();
        let req = Request { matrix: key.to_string(), x, enqueued: Instant::now(), reply: tx };
        // If the service is shutting down the reply channel will just
        // return a disconnect error to the caller.
        if let Some(q) = &self.queue_tx {
            let _ = q.send(req);
        }
        rx
    }

    /// Convenience: submit and wait.
    pub fn call(&self, key: &str, x: Vec<f64>) -> Result<Vec<f64>, String> {
        self.submit(key, x)
            .recv()
            .map_err(|_| "service shut down before reply".to_string())?
    }

    /// Snapshot the registry into a [`ServiceStats`]. Read order matters
    /// for consistency without a global lock: `completed`/`failed` are
    /// read *before* `submitted` — a request is counted submitted before
    /// it can possibly complete, so anything finishing between the two
    /// reads only widens `submitted` and the snapshot invariant
    /// `completed + failed <= submitted` holds in every interleaving.
    /// (The old `Mutex<Stats>` version held the same lock the workers
    /// bumped counters under; this one never blocks a worker.)
    pub fn stats(&self) -> ServiceStats {
        let c = &self.stats;
        let completed = c.completed.get();
        let failed = c.failed.get();
        let lat = c.obs.merged_histogram("csrc_request_latency_us");
        let log = c.choices.lock().unwrap();
        let auto_choices = log.auto_choices.clone();
        let chosen_threads = log.chosen_threads.clone();
        drop(log);
        let submitted = c.submitted.get();
        ServiceStats {
            submitted,
            completed,
            failed,
            batches: c.batches.get(),
            mean_latency_us: lat.mean_us(),
            p99_latency_us: lat.quantile_us(0.99),
            plan_builds: self.plans.builds(),
            plan_build_seconds: self.plans.build_seconds(),
            tunes: c.tunes.get(),
            tune_seconds: c.tune_ns.get() as f64 / 1e9,
            decision_hits: self.decisions.hits(),
            engines_evicted: c.engines_evicted.get(),
            auto_choices,
            chosen_threads,
            retunes: c.retunes.get(),
            drift_events: c.drift_events.get(),
            model_hits: c.model_hits.get(),
            model_fallbacks: c.model_fallbacks.get(),
            coalesced_products: c.coalesced_products.get(),
            coalesced_requests: c.coalesced_requests.get(),
            rcm_builds: c.rcm_builds.get(),
        }
    }

    /// The service's metrics registry — render it directly or expose it
    /// with [`crate::obs::serve_metrics`] (`csrc serve --metrics-addr`).
    pub fn metrics_registry(&self) -> Arc<MetricsRegistry> {
        self.stats.obs.clone()
    }

    /// Graceful shutdown: drain, stop threads.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        self.queue_tx.take(); // closes the queue; dispatcher drains & exits
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        // Workers (the other senders) are gone: dropping ours closes the
        // re-tune queue, and the re-tuner drains what is pending first.
        self.retune_tx.take();
        if let Some(r) = self.retuner.take() {
            let _ = r.join();
        }
    }
}

impl Drop for MatvecService {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

/// Does `k` name a generation of exactly the key whose prefix is
/// `"key@"` — i.e. `key@<digits>`? An all-digit suffix can only be a
/// generation stamped by `register()`; anything else (e.g. `key@b@0`)
/// belongs to a *different* user key that happens to contain '@'.
fn is_generation_of(k: &str, prefix: &str) -> bool {
    k.starts_with(prefix)
        && k.len() > prefix.len()
        && k[prefix.len()..].bytes().all(|b| b.is_ascii_digit())
}

fn dispatcher_loop(
    queue: Receiver<Request>,
    worker_txs: Vec<Sender<WorkerBatch>>,
    policy: BatchPolicy,
    stats: Arc<Counters>,
) {
    let mut next_worker = 0usize;
    loop {
        // Block for the first request; then greedily drain within the
        // batching window.
        let first = match queue.recv() {
            Ok(r) => r,
            Err(_) => return, // queue closed: done (workers closed by drop of txs)
        };
        let mut pending = vec![first];
        let deadline = Instant::now() + policy.max_wait;
        while pending.len() < policy.max_batch * worker_txs.len() {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match queue.recv_timeout(deadline - now) {
                Ok(r) => pending.push(r),
                Err(_) => break,
            }
        }
        // Form per-matrix batches and ship them.
        let coalesce_span = obs::phase(Phase::Coalesce);
        let keys: Vec<String> = pending.iter().map(|r| r.matrix.clone()).collect();
        let batches = form_batches(&keys, &policy);
        drop(coalesce_span);
        stats.batches.add(summarize(&batches).batches as u64);
        // Move requests out of `pending` into their batches (descending
        // index take keeps indices valid).
        let mut slots: Vec<Option<Request>> = pending.into_iter().map(Some).collect();
        for b in batches {
            let reqs: Vec<Request> =
                b.requests.iter().map(|&i| slots[i].take().expect("batch index")).collect();
            let wb = WorkerBatch { matrix: b.matrix, requests: reqs };
            let _ = worker_txs[next_worker % worker_txs.len()].send(wb);
            next_worker += 1;
        }
    }
}

/// Everything one worker thread shares with the service.
struct WorkerCtx {
    registry: Arc<Mutex<Registry>>,
    plans: Arc<PlanCache>,
    route: RoutePolicy,
    stats: Arc<Counters>,
    /// This worker's slice of the `csrc_request_latency_us` summary —
    /// recorded lock-free of other workers, merged at snapshot/scrape
    /// time ([`MetricsRegistry::merged_histogram`]).
    latency: HistogramHandle,
    resolved: Arc<Mutex<HashMap<String, ResolvedAuto>>>,
    /// Shared RCM artifacts — one permutation + permuted matrix per
    /// served `key@generation`, built by whichever worker gets there
    /// first (under the lock, so never twice).
    rcm: Arc<Mutex<RcmRegistry>>,
    drift: Arc<Mutex<HashMap<String, DriftState>>>,
    /// Cold-start model, consulted by the racing-request fallback so the
    /// fallback order (cache → model → heuristic) holds on the worker
    /// side too.
    model: Option<Arc<tuner::CostModel>>,
    /// Re-tunes *and* served-baseline write-backs go here — both touch
    /// the persisted decision cache, which must stay off the request
    /// path.
    retune_tx: Sender<RetunerMsg>,
    engine_capacity: usize,
    drift_fraction: f64,
    drift_min_batches: u64,
}

/// Worker engine-cache key: (matrix, generation, engine label, threads,
/// reordered). The thread count is part of the key because a re-tune
/// may move a key to a different p; the reorder flag because a re-tune
/// may flip the ordering.
type EngineKey = (String, u64, String, usize, bool);

fn worker_loop(rx: Receiver<WorkerBatch>, ctx: WorkerCtx) {
    let router = Router::new(ctx.route.clone());
    // Engine cache per [`EngineKey`] — engines hold execution state
    // (pool, buffers) and are not Sync, so each worker owns its own; the
    // *plan* inside every engine comes from the shared service cache.
    // Structural keys so user keys containing '@' cannot alias
    // generations. Values carry the last-served batch tick for the LRU
    // eviction below.
    let mut engines: HashMap<EngineKey, (Box<dyn ParallelSpmv>, u64)> = HashMap::new();
    let mut serve_tick: u64 = 0;
    while let Ok(batch) = rx.recv() {
        let _serve_span = obs::phase(Phase::Serve);
        let hit = ctx.registry.lock().unwrap().get(&batch.matrix).cloned();
        let Some((a, generation)) = hit else {
            for r in batch.requests {
                ctx.stats.failed.inc();
                let _ = r.reply.send(Err(format!("unknown matrix {:?}", batch.matrix)));
            }
            continue;
        };
        // Generation-qualified key: caches can never mix state across a
        // register() replacement (the matrix and its engines/plans stay
        // a consistent snapshot even if the registry changes mid-batch).
        let cache_key = format!("{}@{generation}", batch.matrix);
        // Evict engines built for retired generations of this matrix —
        // each pins a ThreadPool (live OS threads), the old matrix, and
        // its plan. (Retired RCM artifacts live in the shared registry
        // and are collected by `register()` on replacement.)
        engines.retain(|k, _| k.0 != batch.matrix || k.1 == generation);
        serve_tick += 1;
        let mut used_key: Option<EngineKey> = None;
        // Resolve Auto once per batch (it is batch-invariant): through
        // the registration-time decision — which carries the swept
        // thread count, not `RoutePolicy::threads` blindly — or, for a
        // request racing that resolution, the model/heuristic (features
        // only, no trials), rather than blocking or tuning on the
        // request path.
        let mut auto_decision: Option<ResolvedAuto> = None;
        let backend = match router.route(&a) {
            Backend::NativeParallel { kind: EngineKind::Auto, threads, reorder } => {
                let known = ctx.resolved.lock().unwrap().get(&cache_key).copied();
                match known {
                    Some(r) => {
                        auto_decision = Some(r);
                        Backend::NativeParallel {
                            kind: r.kind,
                            threads: r.nthreads,
                            reorder: r.reorder,
                        }
                    }
                    None => {
                        let plan = ctx.plans.get_or_build(
                            &cache_key,
                            a.as_ref(),
                            PlanBuilder::new(threads).with_pieces(tuner::required_pieces(threads)),
                        );
                        // Same fallback order as registration (model,
                        // then heuristic). The batch executes with the
                        // route's reorder flag either way (an Always
                        // route builds the RCM engine regardless), so
                        // the model must score classes for the ordering
                        // that will actually run — predicting plain for
                        // a reordered execution would pick from the
                        // wrong class space.
                        let features = tuner::Features::extract(a.as_ref(), &plan);
                        let policy = if reorder {
                            crate::reorder::ReorderPolicy::Always
                        } else {
                            crate::reorder::ReorderPolicy::Never
                        };
                        let kind = ctx
                            .model
                            .as_deref()
                            .and_then(|m| m.predict(&features, policy))
                            .map(|p| p.kind)
                            .unwrap_or_else(|| tuner::cost_model(&features));
                        Backend::NativeParallel { kind, threads, reorder }
                    }
                }
            }
            other => other,
        };
        // Per-batch rate sample for drift detection: seconds spent in
        // engine products and how many vector products ran (a k-wide
        // panel counts k — the EWMA stays per-vector-normalized).
        let mut batch_secs = 0.0f64;
        let mut batch_products = 0usize;
        // Validate lengths up front: a malformed request fails on its
        // own and never joins a panel.
        let mut valid: Vec<Request> = Vec::with_capacity(batch.requests.len());
        for req in batch.requests {
            if req.x.len() != a.n {
                ctx.stats.failed.inc();
                let _ = req
                    .reply
                    .send(Err(format!("x length {} != n {}", req.x.len(), a.n)));
            } else {
                valid.push(req);
            }
        }
        match &backend {
            Backend::NativeSequential => {
                for req in &valid {
                    let mut y = vec![0.0; a.n];
                    a.spmv_into_zeroed(&req.x, &mut y);
                    finish_request(&ctx, req, y);
                }
                count_products(&ctx, &batch.matrix, "sequential", 1, valid.len() as u64);
            }
            Backend::Xla { artifact } => {
                // The XLA path is exercised via examples/ and the CLI
                // (XlaRuntime is heavyweight); in-service we fall back
                // to sequential to keep the worker self-contained.
                let _ = artifact;
                for req in &valid {
                    let mut y = vec![0.0; a.n];
                    a.spmv_into_zeroed(&req.x, &mut y);
                    finish_request(&ctx, req, y);
                }
                count_products(&ctx, &batch.matrix, "sequential", 1, valid.len() as u64);
            }
            Backend::NativeParallel { kind, threads, reorder } if !valid.is_empty() => {
                let ekey =
                    (batch.matrix.clone(), generation, kind.label(), *threads, *reorder);
                let slot = engines.entry(ekey.clone()).or_insert_with(|| {
                    let engine: Box<dyn ParallelSpmv> = if *reorder {
                        // Serve through the RCM ordering: the permuted
                        // matrix and its permutation come from the
                        // *shared* registry — whichever worker arrives
                        // first builds them under the lock, every other
                        // worker (and engine kind) reuses the Arcs. The
                        // wrapper permutes x in / un-permutes y out per
                        // product.
                        let (pa, perm) = {
                            let mut rcm = ctx.rcm.lock().unwrap();
                            rcm.entry(cache_key.clone())
                                .or_insert_with(|| {
                                    ctx.stats.rcm_builds.inc();
                                    let perm = Arc::new(reorder::rcm(a.as_ref()));
                                    let pa = Arc::new(a.permuted(&perm));
                                    (pa, perm)
                                })
                                .clone()
                        };
                        let plan = ctx.plans.get_or_build(
                            &format!("{cache_key}#rcm"),
                            pa.as_ref(),
                            PlanBuilder::for_kind(*threads, *kind),
                        );
                        Box::new(ReorderedEngine::new(
                            build_engine(*kind, pa, plan),
                            perm,
                        ))
                    } else {
                        let plan = ctx.plans.get_or_build(
                            &cache_key,
                            a.as_ref(),
                            PlanBuilder::for_kind(*threads, *kind),
                        );
                        build_engine(*kind, a.clone(), plan)
                    };
                    (engine, 0)
                });
                slot.1 = serve_tick;
                used_key = Some(ekey);
                // Coalesce the batch into k-wide panels: the tuned
                // width for resolved Auto routes (block_k = 1 means the
                // blocked product lost its own race — serve serially),
                // the ladder cap for explicit routes.
                let cap = auto_decision
                    .map(|r| r.block_k.max(1))
                    .unwrap_or(DEFAULT_PANEL_WIDTH);
                let engine_label = kind.label();
                let mut i = 0usize;
                while i < valid.len() {
                    let g = cap.min(valid.len() - i);
                    if g <= 1 {
                        let req = &valid[i];
                        let mut y = vec![0.0; a.n];
                        let t = Instant::now();
                        slot.0.spmv(&req.x, &mut y);
                        batch_secs += t.elapsed().as_secs_f64();
                        batch_products += 1;
                        count_products(&ctx, &batch.matrix, &engine_label, 1, 1);
                        finish_request(&ctx, req, y);
                        i += 1;
                    } else {
                        // Pack the g request vectors into one row-major
                        // panel (x[j*g + c] = request c's x[j]), run a
                        // single blocked product, unpack per request.
                        let pack_span = obs::phase(Phase::Coalesce);
                        let mut xp = vec![0.0; a.n * g];
                        for (c, req) in valid[i..i + g].iter().enumerate() {
                            for (j, &v) in req.x.iter().enumerate() {
                                xp[j * g + c] = v;
                            }
                        }
                        drop(pack_span);
                        let mut yp = vec![0.0; a.n * g];
                        let t = Instant::now();
                        slot.0.spmv_multi(&xp, &mut yp, g);
                        batch_secs += t.elapsed().as_secs_f64();
                        batch_products += g;
                        ctx.stats.coalesced_products.inc();
                        ctx.stats.coalesced_requests.add(g as u64);
                        count_products(&ctx, &batch.matrix, &engine_label, g, 1);
                        let unpack_span = obs::phase(Phase::Coalesce);
                        for (c, req) in valid[i..i + g].iter().enumerate() {
                            let mut y = vec![0.0; a.n];
                            for (j, yj) in y.iter_mut().enumerate() {
                                *yj = yp[j * g + c];
                            }
                            finish_request(&ctx, req, y);
                        }
                        drop(unpack_span);
                        i += g;
                    }
                }
            }
            Backend::NativeParallel { .. } => {} // every request failed validation
        }
        if let Some(r) = auto_decision {
            let job = RetuneJob {
                matrix: batch.matrix.clone(),
                cache_key: cache_key.clone(),
                generation,
            };
            maybe_flag_drift(&ctx, job, r, batch_products, batch_secs);
        }
        // LRU eviction (ROADMAP item): a worker that has served many
        // distinct keys must not park one thread pool per key forever.
        // Evict the least-recently-served engines above capacity, never
        // the one this batch just used.
        if engines.len() > ctx.engine_capacity {
            let mut evicted = 0u64;
            while engines.len() > ctx.engine_capacity {
                let victim = engines
                    .iter()
                    .filter(|&(k, _)| used_key.as_ref() != Some(k))
                    .min_by_key(|&(_, &(_, tick))| tick)
                    .map(|(k, _)| k.clone());
                let Some(v) = victim else { break };
                engines.remove(&v);
                evicted += 1;
            }
            if evicted > 0 {
                ctx.stats.engines_evicted.add(evicted);
            }
        }
    }
}

/// Reply to one served request and record its completion + latency.
/// `completed` is bumped *before* the reply is sent, so a caller whose
/// `call()` has returned is always visible in the next snapshot.
fn finish_request(ctx: &WorkerCtx, req: &Request, y: Vec<f64>) {
    ctx.stats.completed.inc();
    ctx.latency.record(req.enqueued.elapsed().as_secs_f64());
    let _ = req.reply.send(Ok(y));
}

/// Bump the per-engine product family
/// (`csrc_engine_products_total{matrix,engine,k}`) for `products`
/// products served at panel width `k`.
fn count_products(ctx: &WorkerCtx, matrix: &str, engine: &str, k: usize, products: u64) {
    let width = k.to_string();
    ctx.stats
        .obs
        .family_counter(
            "csrc_engine_products_total",
            &[("matrix", matrix), ("engine", engine), ("k", &width)],
        )
        .add(products);
}

/// Fold one batch's measured rate into the key's EWMA and queue a
/// background re-tune — once per key × generation — when it has drifted
/// below `drift_fraction` of the decision's *baseline* rate. The rate
/// is normalized by the decision's own `work_flops`, so the EWMA and
/// the baseline are in the same units. Unmeasured (model/heuristic)
/// decisions record no rate and are never drift-checked.
///
/// The baseline is the entry's **served** rate when one has been
/// recorded, else the trial rate. Trials are warm back-to-back products
/// and therefore optimistic relative to per-request serving — judging
/// serving against them forever re-triggers (a re-tune storm). So the
/// first `drift_min_batches` batches after a re-tune *calibrate*
/// (`DriftState::calibrating`): their EWMA is written back into the
/// resolved entry and the persisted cache entry as the served baseline,
/// and only later batches are judged, against that baseline.
fn maybe_flag_drift(ctx: &WorkerCtx, job: RetuneJob, r: ResolvedAuto, products: usize, secs: f64) {
    if products == 0
        || secs <= 0.0
        || ctx.drift_fraction <= 0.0
        || !r.measured
        || r.mflops <= 0.0
        || r.work_flops == 0
    {
        return;
    }
    let rate = metrics::mflops(r.work_flops * products, secs);
    let mut drift = ctx.drift.lock().unwrap();
    let st = drift.entry(job.cache_key.clone()).or_default();
    st.ewma_mflops = if st.batches == 0 {
        rate
    } else {
        EWMA_ALPHA * rate + (1.0 - EWMA_ALPHA) * st.ewma_mflops
    };
    st.batches += 1;
    if st.batches < ctx.drift_min_batches {
        return;
    }
    if st.calibrating {
        // Enough post-re-tune batches: the EWMA *is* serving reality
        // now. (The first sample can straddle the old engine for one
        // batch — the EWMA shrugs that off.) Record it as the judging
        // baseline under this lock, publish it to the resolved entry
        // (cheap, in-memory) and hand the persisted write-back — a full
        // cache-file rewrite — to the re-tuner thread; judgement
        // restarts next batch.
        st.calibrating = false;
        st.served_baseline = st.ewma_mflops;
        let ewma = st.ewma_mflops;
        drop(drift);
        if let Some(e) = ctx.resolved.lock().unwrap().get_mut(&job.cache_key) {
            e.served_mflops = ewma;
        }
        let _ = ctx.retune_tx.send(RetunerMsg::RecordServedRate {
            fingerprint: r.fingerprint,
            max_threads: r.max_threads,
            mflops: ewma,
        });
        return;
    }
    // Baseline preference: the lock-protected calibration record, then
    // the decision's persisted served rate (a restarted service), then
    // — for never-calibrated decisions — the trial rate.
    let baseline = if st.served_baseline > 0.0 {
        st.served_baseline
    } else if r.served_mflops > 0.0 {
        r.served_mflops
    } else {
        r.mflops
    };
    if st.ewma_mflops >= ctx.drift_fraction * baseline {
        return;
    }
    let already_pending = st.retune_pending;
    st.retune_pending = true;
    drop(drift);
    ctx.stats.drift_events.inc();
    if !already_pending {
        let _ = ctx.retune_tx.send(RetunerMsg::Retune(job));
    }
}

/// Everything the background re-tuner shares with the service.
struct RetunerCtx {
    registry: Arc<Mutex<Registry>>,
    plans: Arc<PlanCache>,
    route: RoutePolicy,
    budget: TrialBudget,
    decisions: Arc<DecisionCache>,
    resolved: Arc<Mutex<HashMap<String, ResolvedAuto>>>,
    drift: Arc<Mutex<HashMap<String, DriftState>>>,
    stats: Arc<Counters>,
}

/// Drain re-tuner work: drift-triggered re-tunes (re-run the measured
/// trials — the sweep when `route.sweep_threads` — against the
/// *current* machine state, upgrade the decision-cache entry in place,
/// republish the resolution for workers, and reset the key's drift
/// state into calibration) and served-baseline write-backs the workers
/// hand off (a full cache-file rewrite each — request-path poison).
fn retuner_loop(rx: Receiver<RetunerMsg>, ctx: RetunerCtx) {
    while let Ok(msg) = rx.recv() {
        let job = match msg {
            RetunerMsg::Retune(job) => job,
            RetunerMsg::RecordServedRate { fingerprint, max_threads, mflops } => {
                ctx.decisions.set_served_rate(fingerprint, max_threads, mflops);
                continue;
            }
        };
        let hit = ctx.registry.lock().unwrap().get(&job.matrix).cloned();
        let Some((a, generation)) = hit else { continue };
        if generation != job.generation {
            continue; // replaced since the drift was observed
        }
        let _retune_span = obs::phase(Phase::Retune);
        let kernel: Arc<dyn SpmvKernel> = a.clone();
        // A zero budget cannot produce the measured decision a drift
        // repair needs; degrade to the cheapest measuring budget.
        let budget = if ctx.budget.is_zero() { TrialBudget::smoke() } else { ctx.budget };
        let threads = ctx.route.threads.max(1);
        let d = if ctx.route.sweep_threads {
            let ladder = tuner::thread_ladder(threads);
            let mut plan_for = tuner::cached_plan_provider(&ctx.plans, &job.cache_key, &kernel);
            let d = tuner::sweep_reordered(
                &kernel,
                &ladder,
                &budget,
                &mut plan_for,
                ctx.route.reorder,
            );
            ctx.plans.invalidate_other_threads(&job.cache_key, d.nthreads);
            // Reordered (`#rcm`) plans workers built at the losing
            // thread counts are dead weight too.
            ctx.plans
                .invalidate_other_threads(&format!("{}#rcm", job.cache_key), d.nthreads);
            d
        } else {
            let plan = ctx.plans.get_or_build(
                &job.cache_key,
                kernel.as_ref(),
                PlanBuilder::new(threads).with_pieces(tuner::required_pieces(threads)),
            );
            tuner::tune_reordered(&kernel, &plan, &budget, ctx.route.reorder)
        };
        // The fresh measurement is keyed by structure fingerprint, so it
        // is worth persisting even if the registration changed under us.
        ctx.decisions.put(d.clone());
        // Publish to the workers only if the generation is still
        // current: register() may have replaced the matrix while we
        // measured, and it already purged this generation's entries —
        // re-inserting would resurrect dead keys. The registry check
        // happens *under* the map locks, so a concurrent replacement
        // either purges after our insert or we observe its generation
        // bump and skip.
        {
            let mut resolved = ctx.resolved.lock().unwrap();
            let mut drift = ctx.drift.lock().unwrap();
            let current = ctx
                .registry
                .lock()
                .unwrap()
                .get(&job.matrix)
                .map(|(_, g)| *g)
                == Some(job.generation);
            if !current {
                continue;
            }
            resolved.insert(job.cache_key.clone(), ResolvedAuto::from_decision(&d));
            // Fresh state (`retune_pending` cleared) in *calibration*
            // mode: the next drift_min_batches batches record the
            // served EWMA as the new entry's baseline instead of being
            // judged against its warm trial rate — see maybe_flag_drift
            // (this is what stops the re-tune storm).
            drift.insert(job.cache_key, DriftState { calibrating: true, ..Default::default() });
        }
        ctx.stats.retunes.inc();
        ctx.stats.add_tune_seconds(d.tuned_s);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::Coo;
    use crate::util::Rng;

    fn mat(n: usize, seed: u64) -> Arc<Csrc> {
        let mut rng = Rng::new(seed);
        Arc::new(Csrc::from_coo(&Coo::random_structurally_symmetric(n, 3, false, &mut rng)).unwrap())
    }

    #[test]
    fn serves_correct_products() {
        let svc = MatvecService::start(ServiceConfig::default());
        let a = mat(80, 80);
        svc.register("a", a.clone());
        let x: Vec<f64> = (0..80).map(|i| i as f64 * 0.01).collect();
        let y = svc.call("a", x.clone()).unwrap();
        let mut want = vec![0.0; 80];
        a.spmv_into_zeroed(&x, &mut want);
        crate::util::propcheck::assert_close(&y, &want, 1e-12, 1e-12).unwrap();
        let s = svc.stats();
        assert_eq!(s.completed, 1);
        svc.shutdown();
    }

    #[test]
    fn unknown_matrix_fails_cleanly() {
        let svc = MatvecService::start(ServiceConfig::default());
        let err = svc.call("ghost", vec![1.0; 4]).unwrap_err();
        assert!(err.contains("unknown matrix"), "{err}");
        assert_eq!(svc.stats().failed, 1);
    }

    #[test]
    fn wrong_length_fails_cleanly() {
        let svc = MatvecService::start(ServiceConfig::default());
        svc.register("a", mat(50, 81));
        let err = svc.call("a", vec![1.0; 3]).unwrap_err();
        assert!(err.contains("length"), "{err}");
    }

    #[test]
    fn many_concurrent_requests_all_served() {
        let svc = MatvecService::start(ServiceConfig::default());
        let a = mat(60, 82);
        let b = mat(40, 83);
        svc.register("a", a.clone());
        svc.register("b", b.clone());
        let mut rxs = Vec::new();
        for i in 0..40 {
            let key = if i % 3 == 0 { "b" } else { "a" };
            let n = if key == "a" { 60 } else { 40 };
            let x: Vec<f64> = (0..n).map(|j| (i * j) as f64 * 1e-3).collect();
            rxs.push((key, x.clone(), svc.submit(key, x)));
        }
        for (key, x, rx) in rxs {
            let y = rx.recv().unwrap().unwrap();
            let m = if key == "a" { &a } else { &b };
            let mut want = vec![0.0; m.n];
            m.spmv_into_zeroed(&x, &mut want);
            crate::util::propcheck::assert_close(&y, &want, 1e-12, 1e-12).unwrap();
        }
        let s = svc.stats();
        assert_eq!(s.completed, 40);
        assert!(s.batches >= 2, "should have formed multiple batches");
        assert!(s.mean_latency_us > 0.0);
        svc.shutdown();
    }

    #[test]
    fn parallel_backend_used_for_large_matrices() {
        let mut cfg = ServiceConfig::default();
        cfg.route.min_parallel_n = 32; // force the parallel path
        cfg.route.threads = 2;
        let svc = MatvecService::start(cfg);
        let a = mat(200, 84);
        svc.register("big", a.clone());
        let x = vec![1.0; 200];
        let y = svc.call("big", x.clone()).unwrap();
        let mut want = vec![0.0; 200];
        a.spmv_into_zeroed(&x, &mut want);
        crate::util::propcheck::assert_close(&y, &want, 1e-11, 1e-11).unwrap();
        svc.shutdown();
    }

    #[test]
    fn plan_built_once_across_workers_and_requests() {
        // Four workers hammering one matrix over the parallel backend
        // must share a single cached plan — the registry analyzes a
        // matrix once, not once per worker × engine.
        let mut cfg = ServiceConfig::default();
        cfg.workers = 4;
        cfg.route.min_parallel_n = 1; // force the parallel path
        cfg.route.threads = 2;
        let svc = MatvecService::start(cfg);
        let a = mat(120, 85);
        svc.register("shared", a.clone());
        let mut want = vec![0.0; 120];
        let x: Vec<f64> = (0..120).map(|i| (i as f64 * 0.01).sin()).collect();
        a.spmv_into_zeroed(&x, &mut want);
        let rxs: Vec<_> = (0..32).map(|_| svc.submit("shared", x.clone())).collect();
        for rx in rxs {
            let y = rx.recv().unwrap().unwrap();
            crate::util::propcheck::assert_close(&y, &want, 1e-11, 1e-11).unwrap();
        }
        let s = svc.stats();
        assert_eq!(s.completed, 32);
        assert_eq!(s.plan_builds, 1, "one matrix must be analyzed exactly once");
        assert!(s.plan_build_seconds >= 0.0);
        // A second matrix costs exactly one more analysis.
        let b = mat(90, 86);
        svc.register("other", b.clone());
        let x2 = vec![1.0; 90];
        let _ = svc.call("other", x2).unwrap();
        assert_eq!(svc.stats().plan_builds, 2);
        svc.shutdown();
    }

    #[test]
    fn replacing_a_matrix_retires_its_engines_and_plans() {
        // After register() overwrites a key — even with a different size
        // — requests must run against the new matrix, not a worker's
        // cached engine for the old one.
        let mut cfg = ServiceConfig::default();
        cfg.workers = 1; // one worker so the engine cache is definitely warm
        cfg.route.min_parallel_n = 1;
        cfg.route.threads = 2;
        let svc = MatvecService::start(cfg);
        let a1 = mat(60, 87);
        svc.register("m", a1.clone());
        let x1 = vec![1.0; 60];
        let y1 = svc.call("m", x1.clone()).unwrap();
        let mut want1 = vec![0.0; 60];
        a1.spmv_into_zeroed(&x1, &mut want1);
        crate::util::propcheck::assert_close(&y1, &want1, 1e-11, 1e-11).unwrap();
        // Replace with a smaller matrix (the dangerous direction for a
        // stale engine) and serve again.
        let a2 = mat(40, 88);
        svc.register("m", a2.clone());
        let x2 = vec![1.0; 40];
        let y2 = svc.call("m", x2.clone()).unwrap();
        let mut want2 = vec![0.0; 40];
        a2.spmv_into_zeroed(&x2, &mut want2);
        crate::util::propcheck::assert_close(&y2, &want2, 1e-11, 1e-11).unwrap();
        let s = svc.stats();
        assert_eq!(s.completed, 2);
        assert_eq!(s.plan_builds, 2, "replacement must build a fresh plan");
        svc.shutdown();
    }

    #[test]
    fn auto_routing_tunes_once_and_persists_decisions() {
        let dir = std::env::temp_dir().join(format!("csrc_auto_svc_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut cfg = ServiceConfig::default();
        cfg.route.parallel_kind = EngineKind::Auto;
        cfg.route.min_parallel_n = 1; // force the parallel (Auto) path
        cfg.route.threads = 2;
        cfg.tune_budget = TrialBudget::smoke();
        cfg.decision_cache = Some(dir.join("decisions.json"));
        let a = mat(150, 89);
        let x: Vec<f64> = (0..150).map(|i| (i as f64 * 0.01).sin()).collect();
        let mut want = vec![0.0; 150];
        a.spmv_into_zeroed(&x, &mut want);

        let svc = MatvecService::start(cfg.clone());
        svc.register("m", a.clone());
        let y = svc.call("m", x.clone()).unwrap();
        crate::util::propcheck::assert_close(&y, &want, 1e-11, 1e-11).unwrap();
        let s = svc.stats();
        assert_eq!(s.tunes, 1, "first Auto registration runs measured trials");
        assert!(s.tune_seconds > 0.0);
        assert_eq!(s.auto_choices.len(), 1);
        let (key, label) = &s.auto_choices[0];
        assert_eq!(key, "m");
        let resolved = EngineKind::parse(label).expect("resolved label parses");
        assert_ne!(resolved, EngineKind::Auto, "Auto must resolve to a concrete engine");
        // Registering the same structure under another key: decision
        // cache hit, zero new trials.
        svc.register("m-again", a.clone());
        let s = svc.stats();
        assert_eq!(s.tunes, 1, "same structure must not re-tune");
        assert!(s.decision_hits >= 1);
        svc.shutdown();

        // A restarted service on the same persisted cache re-tunes
        // nothing: zero trials, decision read from disk.
        let svc2 = MatvecService::start(cfg);
        svc2.register("m", a.clone());
        let y2 = svc2.call("m", x).unwrap();
        crate::util::propcheck::assert_close(&y2, &want, 1e-11, 1e-11).unwrap();
        let s2 = svc2.stats();
        assert_eq!(s2.tunes, 0, "restart must hit the persisted decision cache");
        assert!(s2.decision_hits >= 1);
        assert_eq!(s2.auto_choices[0].1, *label, "persisted decision picks the same engine");
        svc2.shutdown();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn sweep_threads_resolves_engine_and_thread_count() {
        let mut cfg = ServiceConfig::default();
        cfg.route.parallel_kind = EngineKind::Auto;
        cfg.route.min_parallel_n = 1; // force the parallel (Auto) path
        cfg.route.threads = 2;
        cfg.route.sweep_threads = true;
        cfg.tune_budget = TrialBudget::smoke();
        let svc = MatvecService::start(cfg);
        let a = mat(150, 94);
        svc.register("m", a.clone());
        let s = svc.stats();
        assert_eq!(s.tunes, 1, "first Auto registration runs the sweep");
        assert_eq!(s.chosen_threads.len(), 1);
        let (key, p) = &s.chosen_threads[0];
        assert_eq!(key, "m");
        assert!(*p == 1 || *p == 2, "thread count must come from the ladder, got {p}");
        // Serving works at the swept thread count.
        let x: Vec<f64> = (0..150).map(|i| (i as f64 * 0.01).sin()).collect();
        let y = svc.call("m", x.clone()).unwrap();
        let mut want = vec![0.0; 150];
        a.spmv_into_zeroed(&x, &mut want);
        crate::util::propcheck::assert_close(&y, &want, 1e-11, 1e-11).unwrap();
        // Same structure under a new key: the swept decision is served
        // from the cache — no second sweep, same thread pick.
        svc.register("m2", a.clone());
        let s = svc.stats();
        assert_eq!(s.tunes, 1, "same structure must not re-sweep");
        assert!(s.decision_hits >= 1);
        assert_eq!(s.chosen_threads[1].1, s.chosen_threads[0].1);
        svc.shutdown();
    }

    /// A doctored swept decision: sequential at 1 thread (deliberately
    /// *not* `RoutePolicy::threads`) with an impossibly high recorded
    /// rate, so the served EWMA must sit below any drift threshold.
    fn doctored_decision(fp: u64, mflops: f64) -> tuner::Decision {
        tuner::Decision {
            kind: EngineKind::Sequential,
            reorder: false,
            mflops,
            measured: true,
            provenance: tuner::Provenance::Measured,
            served_mflops: 0.0,
            tuned_s: 0.001,
            fingerprint: fp,
            nthreads: 1,
            max_threads: 2,
            features: tuner::Features {
                n: 200,
                work_flops: 2000,
                scatter_pairs: 300,
                scatter_ratio: 0.75,
                bandwidth: 20,
                window_rows: 320,
                window_shrink: 0.8,
                colors: 4,
                intervals: 6,
                balance: 1.1,
                nthreads: 2,
            },
            trials: Vec::new(),
            sweep: vec![tuner::SweepPoint { nthreads: 1, trials: Vec::new() }],
            block_k: 1,
            block_rates: Vec::new(),
        }
    }

    #[test]
    fn drift_triggers_background_retune() {
        let dir = std::env::temp_dir().join(format!("csrc_drift_svc_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("decisions.json");
        let a = mat(200, 95);
        let kernel: Arc<dyn SpmvKernel> = a.clone();
        let fp = tuner::fingerprint(kernel.as_ref());
        // Pre-seed the persistent cache with the doctored decision under
        // this service's (fingerprint × thread budget) key.
        {
            let cache = DecisionCache::open(&path);
            cache.put(doctored_decision(fp, 1e9));
        }
        let mut cfg = ServiceConfig::default();
        cfg.workers = 1;
        cfg.route.parallel_kind = EngineKind::Auto;
        cfg.route.min_parallel_n = 1;
        cfg.route.threads = 2;
        cfg.route.sweep_threads = true;
        cfg.tune_budget = TrialBudget::smoke();
        cfg.decision_cache = Some(path.clone());
        cfg.drift_fraction = 0.5;
        cfg.drift_min_batches = 2;
        let svc = MatvecService::start(cfg);
        svc.register("m", a.clone());
        let s = svc.stats();
        assert_eq!(s.tunes, 0, "the doctored decision must be a cache hit");
        assert_eq!(
            s.chosen_threads,
            vec![("m".to_string(), 1)],
            "the service must consume the swept thread count, not RoutePolicy::threads"
        );
        let x: Vec<f64> = (0..200).map(|i| (i as f64 * 0.01).sin()).collect();
        let mut want = vec![0.0; 200];
        a.spmv_into_zeroed(&x, &mut want);
        // Serve batches until the background re-tune lands. Drift is
        // certain — no real engine reaches 1e9 "Mflop/s" — so this loop
        // only bounds how long we wait for the background thread.
        let mut retuned = false;
        for _ in 0..400 {
            let y = svc.call("m", x.clone()).unwrap();
            crate::util::propcheck::assert_close(&y, &want, 1e-11, 1e-11).unwrap();
            if svc.stats().retunes >= 1 {
                retuned = true;
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        let s = svc.stats();
        assert!(retuned, "drift must queue a background re-tune (drift_events={})", s.drift_events);
        assert!(s.drift_events >= 1);
        // Serving still works against the upgraded decision.
        let y = svc.call("m", x.clone()).unwrap();
        crate::util::propcheck::assert_close(&y, &want, 1e-11, 1e-11).unwrap();
        svc.shutdown();
        // The re-tune upgraded the persisted entry in place: realistic
        // measured rate, fresh sweep surface, same (fp × budget) key.
        let back = DecisionCache::open(&path);
        let d = back.get(fp, 2).expect("upgraded decision persisted");
        assert!(d.measured && !d.sweep.is_empty());
        assert!(d.mflops < 1e8, "recorded rate must be re-measured, got {}", d.mflops);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn retuned_decision_uses_served_baseline_not_trial_rate() {
        // Satellite (ISSUE 5): a doctored optimistic trial rate must
        // trigger exactly ONE re-tune, not a storm. After the re-tune
        // the worker's calibration window records the served EWMA into
        // the entry, and later drift judgements run against that
        // serving baseline — which the serving rate trivially meets.
        let dir = std::env::temp_dir().join(format!("csrc_storm_svc_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("decisions.json");
        let a = mat(200, 195);
        let kernel: Arc<dyn SpmvKernel> = a.clone();
        let fp = tuner::fingerprint(kernel.as_ref());
        {
            let cache = DecisionCache::open(&path);
            cache.put(doctored_decision(fp, 1e9));
        }
        let mut cfg = ServiceConfig::default();
        cfg.workers = 1;
        cfg.route.parallel_kind = EngineKind::Auto;
        cfg.route.min_parallel_n = 1;
        cfg.route.threads = 2;
        cfg.route.sweep_threads = true;
        cfg.tune_budget = TrialBudget::smoke();
        cfg.decision_cache = Some(path.clone());
        cfg.drift_fraction = 0.25;
        cfg.drift_min_batches = 2;
        let svc = MatvecService::start(cfg);
        svc.register("m", a.clone());
        let x: Vec<f64> = (0..200).map(|i| (i as f64 * 0.01).sin()).collect();
        let mut want = vec![0.0; 200];
        a.spmv_into_zeroed(&x, &mut want);
        // Serve until the (certain) first re-tune lands.
        let mut retuned = false;
        for _ in 0..400 {
            let y = svc.call("m", x.clone()).unwrap();
            crate::util::propcheck::assert_close(&y, &want, 1e-11, 1e-11).unwrap();
            if svc.stats().retunes >= 1 {
                retuned = true;
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        assert!(retuned, "the doctored rate must trigger the first re-tune");
        // Plenty of post-re-tune batches: calibration (2 batches) plus
        // many judged ones. Without the served baseline every judged
        // batch would re-flag drift against the fresh warm trial rate.
        for _ in 0..40 {
            let y = svc.call("m", x.clone()).unwrap();
            crate::util::propcheck::assert_close(&y, &want, 1e-11, 1e-11).unwrap();
        }
        // Give any (wrongly) queued re-tune time to complete.
        std::thread::sleep(std::time::Duration::from_millis(100));
        let s = svc.stats();
        assert_eq!(s.retunes, 1, "served-EWMA baseline must stop the re-tune storm");
        svc.shutdown();
        // The baseline was persisted with the upgraded entry.
        let back = DecisionCache::open(&path);
        let d = back.get(fp, 2).expect("upgraded decision persisted");
        assert!(d.measured);
        assert!(d.mflops < 1e8, "trial rate was re-measured, got {}", d.mflops);
        assert!(d.served_mflops > 0.0, "calibration must record the served baseline");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn concurrent_register_serve_retune_stress() {
        // Satellite (ISSUE 5): concurrent register/serve/retune must
        // lose no cache upgrades — every doctored entry ends up
        // re-measured in place — and the retune counter must reflect
        // the observed upgrades (one per key, no storms), even with a
        // key being re-registered mid-flight.
        let dir = std::env::temp_dir().join(format!("csrc_stress_svc_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("decisions.json");
        let mats: Vec<Arc<Csrc>> = (0..3).map(|i| mat(200, 300 + i)).collect();
        let fps: Vec<u64> = mats
            .iter()
            .map(|m| {
                let k: Arc<dyn SpmvKernel> = m.clone();
                tuner::fingerprint(k.as_ref())
            })
            .collect();
        {
            let cache = DecisionCache::open(&path);
            for fp in &fps {
                cache.put(doctored_decision(*fp, 1e9));
            }
        }
        let mut cfg = ServiceConfig::default();
        cfg.workers = 2;
        cfg.route.parallel_kind = EngineKind::Auto;
        cfg.route.min_parallel_n = 1;
        cfg.route.threads = 2;
        cfg.route.sweep_threads = true;
        cfg.tune_budget = TrialBudget::smoke();
        cfg.decision_cache = Some(path.clone());
        cfg.drift_fraction = 0.25;
        cfg.drift_min_batches = 2;
        let svc = MatvecService::start(cfg);
        for (i, m) in mats.iter().enumerate() {
            svc.register(&format!("m{i}"), m.clone());
        }
        assert_eq!(svc.stats().tunes, 0, "all three doctored entries must be cache hits");
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        std::thread::scope(|scope| {
            for c in 0..3usize {
                let svc = &svc;
                let mats = &mats;
                let stop = stop.clone();
                scope.spawn(move || {
                    let mut i = c;
                    while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                        let k = i % 3;
                        let m = &mats[k];
                        let x: Vec<f64> =
                            (0..m.n).map(|j| ((i + j) as f64 * 0.01).sin()).collect();
                        let mut want = vec![0.0; m.n];
                        m.spmv_into_zeroed(&x, &mut want);
                        let y = svc.call(&format!("m{k}"), x).unwrap();
                        crate::util::propcheck::assert_close(&y, &want, 1e-11, 1e-11).unwrap();
                        i += 1;
                    }
                });
            }
            // Meanwhile: wait for all three re-tunes, poking a
            // concurrent replacement of m0 (same matrix, so in-flight
            // x vectors stay valid) into the middle of the run.
            let mut ok = false;
            for round in 0..1200 {
                if svc.stats().retunes >= 3 {
                    ok = true;
                    break;
                }
                if round == 30 || round == 90 {
                    svc.register("m0", mats[0].clone());
                }
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
            stop.store(true, std::sync::atomic::Ordering::Relaxed);
            assert!(ok, "all drifted keys must re-tune (retunes={})", svc.stats().retunes);
        });
        let s = svc.stats();
        assert_eq!(s.failed, 0, "every request must serve cleanly through the churn");
        assert_eq!(s.completed, s.submitted);
        svc.shutdown();
        // No lost upgrades: every doctored entry was re-measured in
        // place despite the concurrent replacements…
        let back = DecisionCache::open(&path);
        for fp in &fps {
            let d = back.get(*fp, 2).expect("entry survives");
            assert!(d.measured, "upgrade must keep the entry measured");
            assert!(d.mflops < 1e8, "trial rate must be re-measured, got {}", d.mflops);
        }
        // …and the retune counter matches the observed upgrades: one
        // per key (the served-EWMA baseline forbids storms), plus at
        // most one extra per m0 re-registration that raced its own
        // upgrade (a replaced generation re-drifts once).
        assert!(
            (3..=5).contains(&s.retunes),
            "retunes {} must match the 3 observed upgrades (± racing re-registrations)",
            s.retunes
        );
    }

    #[test]
    fn zero_budget_auto_answers_from_model_when_supplied() {
        // ISSUE 5 acceptance at the service level: with an empty
        // decision cache and a zero trial budget, registration answers
        // from the supplied model (ServiceStats::model_hits), and from
        // the heuristic only when none is configured (model_fallbacks).
        let dir = std::env::temp_dir().join(format!("csrc_model_svc_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let model_path = dir.join("model.json");
        let a = mat(200, 400);
        // Train a tiny constant model that crowns `colorful` — a pick
        // the registration must echo verbatim if it consulted the model
        // (the heuristic would choose a local-buffers engine here).
        {
            let kernel: Arc<dyn SpmvKernel> = a.clone();
            let plan = crate::plan::PlanBuilder::all(2).build(kernel.as_ref());
            let features = tuner::Features::extract(kernel.as_ref(), &plan);
            let rows: Vec<tuner::CorpusRow> = (0..3u64)
                .map(|i| tuner::CorpusRow {
                    fingerprint: i,
                    max_threads: 2,
                    features: features.clone(),
                    kind: EngineKind::Colorful,
                    reordered: false,
                    nthreads: 2,
                    rung_rates: vec![(2, 500.0)],
                    block_rates: Vec::new(),
                })
                .collect();
            tuner::CostModel::train(&rows).unwrap().save(&model_path).unwrap();
        }
        let mut cfg = ServiceConfig::default();
        cfg.workers = 1;
        cfg.route.parallel_kind = EngineKind::Auto;
        cfg.route.min_parallel_n = 1;
        cfg.route.threads = 2;
        cfg.tune_budget = TrialBudget::zero();
        cfg.model = Some(model_path);
        let svc = MatvecService::start(cfg.clone());
        svc.register("m", a.clone());
        let s = svc.stats();
        assert_eq!(s.model_hits, 1, "the model must answer the cold start");
        assert_eq!(s.model_fallbacks, 0);
        assert_eq!(s.auto_choices[0].1, "colorful", "the planted model pick");
        // Serving runs correctly on the predicted engine.
        let x: Vec<f64> = (0..200).map(|i| (i as f64 * 0.01).sin()).collect();
        let mut want = vec![0.0; 200];
        a.spmv_into_zeroed(&x, &mut want);
        let y = svc.call("m", x.clone()).unwrap();
        crate::util::propcheck::assert_close(&y, &want, 1e-11, 1e-11).unwrap();
        svc.shutdown();
        // The same config without a model falls back to the heuristic.
        cfg.model = None;
        let svc2 = MatvecService::start(cfg);
        svc2.register("m", a.clone());
        let s2 = svc2.stats();
        assert_eq!(s2.model_hits, 0);
        assert_eq!(s2.model_fallbacks, 1, "no model: the heuristic answers");
        assert_ne!(s2.auto_choices[0].1, "colorful", "the heuristic picks differently here");
        svc2.shutdown();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn reorder_always_serves_correct_products() {
        // Policy Always: every parallel request runs through the RCM
        // ordering (permuted engine + per-request permute/un-permute) —
        // answers must be bit-identical in meaning to the plain path.
        let mut rng = Rng::new(97);
        let band = Csrc::from_coo(&Coo::banded(300, 2, false, &mut rng)).unwrap();
        let shuffle =
            Permutation::from_new_to_old(rng.permutation(300)).unwrap();
        let a = Arc::new(band.permuted(&shuffle)); // shuffled: RCM has room
        let mut cfg = ServiceConfig::default();
        cfg.workers = 1;
        cfg.route.min_parallel_n = 1;
        cfg.route.threads = 2;
        cfg.route.reorder = reorder::ReorderPolicy::Always;
        let svc = MatvecService::start(cfg);
        svc.register("m", a.clone());
        let x: Vec<f64> = (0..300).map(|i| (i as f64 * 0.01).sin()).collect();
        let mut want = vec![0.0; 300];
        a.spmv_into_zeroed(&x, &mut want);
        for _ in 0..3 {
            let y = svc.call("m", x.clone()).unwrap();
            crate::util::propcheck::assert_close(&y, &want, 1e-11, 1e-11).unwrap();
        }
        assert_eq!(svc.stats().completed, 3);
        svc.shutdown();
    }

    #[test]
    fn rcm_built_once_across_workers() {
        // Satellite (ISSUE 6): four workers all serving one key through
        // the RCM ordering must share a single permutation build — the
        // artifact registry is service-wide, like the plan cache.
        let mut rng = Rng::new(99);
        let band = Csrc::from_coo(&Coo::banded(300, 2, false, &mut rng)).unwrap();
        let shuffle = Permutation::from_new_to_old(rng.permutation(300)).unwrap();
        let a = Arc::new(band.permuted(&shuffle));
        let mut cfg = ServiceConfig::default();
        cfg.workers = 4;
        cfg.route.min_parallel_n = 1;
        cfg.route.threads = 2;
        cfg.route.reorder = reorder::ReorderPolicy::Always;
        let svc = MatvecService::start(cfg);
        svc.register("m", a.clone());
        let x: Vec<f64> = (0..300).map(|i| (i as f64 * 0.01).sin()).collect();
        let mut want = vec![0.0; 300];
        a.spmv_into_zeroed(&x, &mut want);
        let rxs: Vec<_> = (0..24).map(|_| svc.submit("m", x.clone())).collect();
        for rx in rxs {
            let y = rx.recv().unwrap().unwrap();
            crate::util::propcheck::assert_close(&y, &want, 1e-11, 1e-11).unwrap();
        }
        let s = svc.stats();
        assert_eq!(s.completed, 24);
        assert_eq!(s.rcm_builds, 1, "N workers must share one RCM build, got {}", s.rcm_builds);
        svc.shutdown();
    }

    #[test]
    fn coalesced_batches_replay_the_tuned_block_width() {
        // Tentpole acceptance (ISSUE 6): a persisted k>1 decision,
        // replayed by a cold-cache service, makes the worker coalesce
        // same-matrix requests into blocked products — and the answers
        // stay exact per request.
        let dir = std::env::temp_dir().join(format!("csrc_spmm_svc_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("decisions.json");
        let a = mat(200, 500);
        let kernel: Arc<dyn SpmvKernel> = a.clone();
        let fp = tuner::fingerprint(kernel.as_ref());
        {
            let cache = DecisionCache::open(&path);
            let mut d = doctored_decision(fp, 100.0);
            d.block_k = 4;
            d.block_rates = vec![(1, 100.0), (2, 110.0), (4, 130.0), (8, 120.0)];
            cache.put(d);
        }
        let mut cfg = ServiceConfig::default();
        cfg.workers = 1;
        cfg.batch = BatchPolicy {
            max_batch: 8,
            max_wait: std::time::Duration::from_millis(50),
        };
        cfg.route.parallel_kind = EngineKind::Auto;
        cfg.route.min_parallel_n = 1;
        cfg.route.threads = 2;
        cfg.route.sweep_threads = true;
        cfg.tune_budget = TrialBudget::smoke();
        cfg.decision_cache = Some(path.clone());
        cfg.drift_fraction = 0.0; // isolate coalescing from drift re-tunes
        let svc = MatvecService::start(cfg);
        svc.register("m", a.clone());
        assert_eq!(svc.stats().tunes, 0, "the persisted k>1 decision must be a cache hit");
        // A burst within the batching window forms one multi-request
        // batch, which the worker serves as two width-4 panels.
        let xs: Vec<Vec<f64>> = (0..8)
            .map(|r| (0..200).map(|i| ((r * 200 + i) as f64 * 0.01).sin()).collect())
            .collect();
        let rxs: Vec<_> = xs.iter().map(|x| svc.submit("m", x.clone())).collect();
        for (x, rx) in xs.iter().zip(rxs) {
            let y = rx.recv().unwrap().unwrap();
            let mut want = vec![0.0; 200];
            a.spmv_into_zeroed(x, &mut want);
            crate::util::propcheck::assert_close(&y, &want, 1e-11, 1e-11).unwrap();
        }
        let s = svc.stats();
        assert_eq!(s.completed, 8);
        assert!(
            s.coalesced_products >= 1 && s.coalesced_requests >= 2,
            "a burst against a k=4 decision must coalesce (products={}, requests={})",
            s.coalesced_products,
            s.coalesced_requests
        );
        svc.shutdown();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn auto_with_reorder_measure_resolves_and_serves() {
        // Auto + Measure: the tuner races reordered candidates against
        // plain ones; whatever wins, serving stays correct and the
        // choice log records the ordering.
        let mut rng = Rng::new(98);
        let band = Csrc::from_coo(&Coo::banded(250, 2, false, &mut rng)).unwrap();
        let shuffle =
            Permutation::from_new_to_old(rng.permutation(250)).unwrap();
        let a = Arc::new(band.permuted(&shuffle));
        let mut cfg = ServiceConfig::default();
        cfg.workers = 1;
        cfg.route.parallel_kind = EngineKind::Auto;
        cfg.route.min_parallel_n = 1;
        cfg.route.threads = 2;
        cfg.route.reorder = reorder::ReorderPolicy::Measure;
        cfg.tune_budget = TrialBudget::smoke();
        let svc = MatvecService::start(cfg);
        svc.register("m", a.clone());
        let s = svc.stats();
        assert_eq!(s.tunes, 1);
        assert_eq!(s.auto_choices.len(), 1);
        let label = &s.auto_choices[0].1;
        // Either a plain EngineKind label or the reordered/ prefix.
        let plain = label.strip_prefix("reordered/").unwrap_or(label);
        assert!(EngineKind::parse(plain).is_some(), "{label}");
        let x: Vec<f64> = (0..250).map(|i| (i as f64 * 0.02).cos()).collect();
        let mut want = vec![0.0; 250];
        a.spmv_into_zeroed(&x, &mut want);
        let y = svc.call("m", x.clone()).unwrap();
        crate::util::propcheck::assert_close(&y, &want, 1e-11, 1e-11).unwrap();
        svc.shutdown();
    }

    #[test]
    fn partial_batch_flushes_at_the_deadline() {
        // BatchPolicy::max_wait is a *release* deadline: one lone
        // request (far below max_batch) must still be served once the
        // batching window closes — not held until the batch fills.
        let mut cfg = ServiceConfig::default();
        cfg.workers = 1;
        cfg.batch = BatchPolicy {
            max_batch: 64,
            max_wait: std::time::Duration::from_millis(40),
        };
        let svc = MatvecService::start(cfg);
        let a = mat(40, 96);
        svc.register("a", a.clone());
        let x = vec![1.0; 40];
        let t0 = Instant::now();
        let rx = svc.submit("a", x.clone());
        let y = rx
            .recv_timeout(std::time::Duration::from_secs(10))
            .expect("partial batch must be released at the deadline, not held for max_batch")
            .unwrap();
        let waited = t0.elapsed();
        let mut want = vec![0.0; 40];
        a.spmv_into_zeroed(&x, &mut want);
        crate::util::propcheck::assert_close(&y, &want, 1e-12, 1e-12).unwrap();
        assert!(
            waited >= std::time::Duration::from_millis(25),
            "the dispatcher should wait out most of max_wait before releasing, waited {waited:?}"
        );
        let s = svc.stats();
        assert_eq!(s.completed, 1);
        assert_eq!(s.batches, 1, "one partial batch, released by the deadline");
        svc.shutdown();
    }

    #[test]
    fn resolved_sweep_matches_generations_exactly() {
        // Re-registering "a" must not drop the Auto decision of a
        // different live key that merely starts with "a@".
        assert!(is_generation_of("a@0", "a@"));
        assert!(is_generation_of("a@12", "a@"));
        assert!(!is_generation_of("a@b@0", "a@"));
        assert!(!is_generation_of("a@", "a@"));
        assert!(!is_generation_of("ab@0", "a@"));
    }

    #[test]
    fn worker_engine_cache_evicts_lru() {
        // Capacity-1 worker cache serving two matrices must release the
        // older engine (and its parked pool) instead of hoarding both.
        let mut cfg = ServiceConfig::default();
        cfg.workers = 1;
        cfg.route.min_parallel_n = 1;
        cfg.route.threads = 2;
        cfg.engine_cache_capacity = 1;
        let svc = MatvecService::start(cfg);
        let a = mat(60, 91);
        let b = mat(50, 92);
        svc.register("a", a.clone());
        svc.register("b", b.clone());
        for (key, m) in [("a", &a), ("b", &b), ("a", &a)] {
            let x = vec![1.0; m.n];
            let y = svc.call(key, x.clone()).unwrap();
            let mut want = vec![0.0; m.n];
            m.spmv_into_zeroed(&x, &mut want);
            crate::util::propcheck::assert_close(&y, &want, 1e-11, 1e-11).unwrap();
        }
        let s = svc.stats();
        assert_eq!(s.completed, 3);
        assert!(
            s.engines_evicted >= 1,
            "capacity-1 cache must evict between matrices, evicted {}",
            s.engines_evicted
        );
        svc.shutdown();
    }

    #[test]
    fn stats_snapshot_stays_consistent_under_concurrent_serving() {
        // Satellite (ISSUE 7): ServiceStats is now a snapshot over the
        // registry's atomics. Snapshots taken while callers hammer the
        // service must never tear — `completed + failed > submitted`
        // was possible when the scrape-side copy raced the worker-side
        // multi-field update — and must be monotone between reads.
        let svc = MatvecService::start(ServiceConfig::default());
        let a = mat(60, 93);
        svc.register("m", a.clone());
        let x: Vec<f64> = (0..60).map(|i| (i as f64 * 0.05).sin()).collect();
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        std::thread::scope(|scope| {
            for _ in 0..2 {
                let svc = &svc;
                let x = x.clone();
                let stop = stop.clone();
                scope.spawn(move || {
                    while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                        svc.call("m", x.clone()).unwrap();
                    }
                });
            }
            let mut last_completed = 0u64;
            for _ in 0..300 {
                let s = svc.stats();
                assert!(
                    s.completed + s.failed <= s.submitted,
                    "torn snapshot: completed {} + failed {} > submitted {}",
                    s.completed,
                    s.failed,
                    s.submitted
                );
                assert!(s.completed >= last_completed, "completed went backwards");
                last_completed = s.completed;
            }
            stop.store(true, std::sync::atomic::Ordering::Relaxed);
        });
        // Quiesced (every call() returned): the books balance exactly.
        let s = svc.stats();
        assert_eq!(s.completed + s.failed, s.submitted);
        assert!(s.completed > 0);
        assert!(s.mean_latency_us > 0.0);
        svc.shutdown();
    }

    #[test]
    fn metrics_registry_scrape_matches_service_stats() {
        // Tentpole acceptance (ISSUE 7): the Prometheus rendering and
        // stats() read the same registry cells — the scrape must show
        // the per-engine product family and the same request counts.
        let mut cfg = ServiceConfig::default();
        cfg.workers = 1;
        cfg.route.min_parallel_n = 1; // force the parallel path
        cfg.route.threads = 2;
        let svc = MatvecService::start(cfg);
        let a = mat(80, 94);
        svc.register("m", a.clone());
        let x = vec![1.0; 80];
        for _ in 0..3 {
            svc.call("m", x.clone()).unwrap();
        }
        let s = svc.stats();
        assert_eq!(s.completed, 3);
        let text = svc.metrics_registry().render_prometheus();
        assert!(text.contains("csrc_requests_submitted_total 3"), "{text}");
        assert!(text.contains("csrc_requests_completed_total 3"), "{text}");
        assert!(
            text.contains("csrc_engine_products_total{engine="),
            "per-engine family must be exposed:\n{text}"
        );
        assert!(text.contains("matrix=\"m\""), "{text}");
        assert!(text.contains("csrc_request_latency_us_count 3"), "{text}");
        // The scrape folds in the process-wide phase totals.
        assert!(text.contains("csrc_phase_seconds_total{phase=\"serve\"}"), "{text}");
        svc.shutdown();
    }

    #[test]
    fn property_service_matches_sequential() {
        crate::util::propcheck::check(5, |rng| {
            let n = 20 + rng.below(80);
            let a = {
                let coo = Coo::random_structurally_symmetric(n, 2, false, rng);
                Arc::new(Csrc::from_coo(&coo).map_err(|e| e.to_string())?)
            };
            let svc = MatvecService::start(ServiceConfig::default());
            svc.register("m", a.clone());
            for _ in 0..3 {
                let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
                let y = svc.call("m", x.clone())?;
                let mut want = vec![0.0; n];
                a.spmv_into_zeroed(&x, &mut want);
                crate::util::propcheck::assert_close(&y, &want, 1e-11, 1e-11)?;
            }
            svc.shutdown();
            Ok(())
        });
    }
}
