//! The matvec service: registry + plan cache + request queue + batcher +
//! workers.
//!
//! Flow: `submit()` enqueues (matrix-key, x, reply-channel) → the
//! dispatcher thread drains the queue, forms per-matrix batches
//! ([`super::batcher`]), and hands each batch to a worker → the worker
//! resolves the backend via the [`super::router`] policy, runs the
//! products on its cached engine, and replies through each request's
//! channel. Metrics (counts + latency histogram) are sampled on the
//! worker side.
//!
//! Engines hold execution state (pools, buffers) and stay per-worker,
//! but the *analysis* they run — the [`crate::plan::SpmvPlan`] — is
//! shared: one [`PlanCache`] maps matrix-key × thread-count to a single
//! `Arc<SpmvPlan>` that every worker and engine borrows, so a matrix
//! registered once is analyzed once, not once per worker × engine. Plan
//! build count and time are surfaced in [`ServiceStats`].

use super::batcher::{form_batches, BatchPolicy};
use super::router::{Backend, RoutePolicy, Router};
use crate::metrics::LatencyHistogram;
use crate::parallel::{build_engine, EngineKind, ParallelSpmv};
use crate::plan::{PlanBuilder, PlanCache};
use crate::sparse::{Csrc, SpmvKernel};
use crate::tuner::{self, DecisionCache, TrialBudget};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Service configuration.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    pub workers: usize,
    pub batch: BatchPolicy,
    pub route: RoutePolicy,
    /// Trial budget used when `route.parallel_kind` is
    /// [`EngineKind::Auto`]; a zero budget answers from the cost model.
    pub tune_budget: TrialBudget,
    /// Persist autotuner decisions here (`None` = in-memory only). A
    /// restarted service pointed at the same file re-tunes nothing it
    /// has already measured.
    pub decision_cache: Option<PathBuf>,
    /// Max engines one worker keeps cached (LRU by last-served batch).
    /// Each cached engine pins a thread pool, so abandoned keys must not
    /// park pools forever.
    pub engine_cache_capacity: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            workers: 2,
            batch: BatchPolicy::default(),
            route: RoutePolicy::default(),
            tune_budget: TrialBudget::default(),
            decision_cache: None,
            engine_cache_capacity: 32,
        }
    }
}

struct Request {
    matrix: String,
    x: Vec<f64>,
    enqueued: Instant,
    reply: Sender<Result<Vec<f64>, String>>,
}

struct WorkerBatch {
    matrix: String,
    requests: Vec<Request>,
}

/// Shared mutable service state.
#[derive(Default)]
struct Stats {
    submitted: u64,
    completed: u64,
    failed: u64,
    batches: u64,
    latency: Option<LatencyHistogram>,
    tunes: u64,
    tune_seconds: f64,
    engines_evicted: u64,
    auto_choices: Vec<(String, String)>,
}

/// Observable service counters.
#[derive(Clone, Debug)]
pub struct ServiceStats {
    pub submitted: u64,
    pub completed: u64,
    pub failed: u64,
    pub batches: u64,
    pub mean_latency_us: f64,
    pub p99_latency_us: f64,
    /// How many scheduling plans were built (cache misses) — with N
    /// workers all serving one matrix this stays 1, not N.
    pub plan_builds: u64,
    /// Total wall-clock seconds spent in plan analysis.
    pub plan_build_seconds: f64,
    /// Measured tuning runs performed for `EngineKind::Auto`
    /// registrations (decision-cache hits do not count).
    pub tunes: u64,
    /// Wall-clock seconds spent inside those tuning runs.
    pub tune_seconds: f64,
    /// Autotuner decisions answered from the (possibly persisted)
    /// decision cache with zero new trials.
    pub decision_hits: u64,
    /// Engines dropped from worker caches by the LRU eviction policy.
    pub engines_evicted: u64,
    /// (matrix key, resolved engine label) per Auto registration, in
    /// registration order.
    pub auto_choices: Vec<(String, String)>,
}

/// Registry value: the matrix plus a per-key generation counter.
/// Worker-side caches (engines, plans) key on `key@generation`, so a
/// replaced matrix can never be served by state built for its
/// predecessor — stale engines become unreachable instead of unsound.
type Registry = HashMap<String, (Arc<Csrc>, u64)>;

pub struct MatvecService {
    registry: Arc<Mutex<Registry>>,
    plans: Arc<PlanCache>,
    queue_tx: Option<Sender<Request>>,
    dispatcher: Option<std::thread::JoinHandle<()>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    stats: Arc<Mutex<Stats>>,
    route: RoutePolicy,
    tune_budget: TrialBudget,
    decisions: Arc<DecisionCache>,
    /// `key@generation` → concrete engine resolved for an Auto route.
    resolved: Arc<Mutex<HashMap<String, EngineKind>>>,
}

impl MatvecService {
    pub fn start(cfg: ServiceConfig) -> MatvecService {
        let registry: Arc<Mutex<Registry>> = Arc::new(Mutex::new(HashMap::new()));
        let plans = Arc::new(PlanCache::new());
        let stats = Arc::new(Mutex::new(Stats { latency: Some(LatencyHistogram::new()), ..Default::default() }));
        let decisions = Arc::new(match &cfg.decision_cache {
            Some(path) => DecisionCache::open(path),
            None => DecisionCache::in_memory(),
        });
        let resolved: Arc<Mutex<HashMap<String, EngineKind>>> =
            Arc::new(Mutex::new(HashMap::new()));
        let (queue_tx, queue_rx) = channel::<Request>();

        // Worker channels.
        let mut worker_txs: Vec<Sender<WorkerBatch>> = Vec::new();
        let mut workers = Vec::new();
        for wid in 0..cfg.workers.max(1) {
            let (tx, rx) = channel::<WorkerBatch>();
            worker_txs.push(tx);
            let registry = registry.clone();
            let plans = plans.clone();
            let stats = stats.clone();
            let route = cfg.route.clone();
            let resolved = resolved.clone();
            let capacity = cfg.engine_cache_capacity.max(1);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("matvec-worker-{wid}"))
                    .spawn(move || {
                        worker_loop(rx, registry, plans, route, stats, resolved, capacity)
                    })
                    .expect("spawn worker"),
            );
        }

        // Dispatcher: drain queue -> batches -> round-robin workers.
        let batch_policy = cfg.batch;
        let stats_d = stats.clone();
        let dispatcher = std::thread::Builder::new()
            .name("matvec-dispatcher".into())
            .spawn(move || dispatcher_loop(queue_rx, worker_txs, batch_policy, stats_d))
            .expect("spawn dispatcher");

        MatvecService {
            registry,
            plans,
            queue_tx: Some(queue_tx),
            dispatcher: Some(dispatcher),
            workers,
            stats,
            route: cfg.route,
            tune_budget: cfg.tune_budget,
            decisions,
            resolved,
        }
    }

    /// Register (or replace) a matrix under a key. Replacement bumps the
    /// key's generation: workers' engine caches and the plan cache are
    /// keyed by generation, so state built for the old matrix is never
    /// consulted again. All prior generations' plans are swept here
    /// (prefix match, so a plan raced in by a worker mid-replace is
    /// collected by the next replacement at the latest); workers evict a
    /// key's retired engines the next time they serve that key, and the
    /// per-worker LRU cap (`ServiceConfig::engine_cache_capacity`)
    /// bounds how long an abandoned key's last engine can stay parked.
    pub fn register(&self, key: &str, a: Arc<Csrc>) {
        // Drop the registry lock before sweeping plans: plan builds hold
        // the cache lock for their whole (possibly long) analysis, and
        // every worker batch starts with a registry read — invalidating
        // under the registry lock would stall all workers behind an
        // unrelated build.
        let (generation, replaced) = {
            let mut reg = self.registry.lock().unwrap();
            let generation = reg.get(key).map(|(_, g)| g + 1).unwrap_or(0);
            let replaced = reg.insert(key.to_string(), (a.clone(), generation)).is_some();
            (generation, replaced)
        };
        if replaced {
            let prefix = format!("{key}@");
            // Plans may over-match (a user key containing '@' aliases the
            // prefix) — that only costs a rebuild. Resolved Auto entries
            // are repopulated by register() alone, so they must match
            // exactly: `key@<generation>` with an all-digit suffix, never
            // another live key like `key@other@0`.
            self.plans.invalidate_prefix(&prefix);
            self.resolved.lock().unwrap().retain(|k, _| !is_generation_of(k, &prefix));
        }
        // Auto routing: resolve the concrete engine now, off the request
        // path. The decision cache is keyed by structure fingerprint ×
        // threads, so a re-registered matrix — or one registered with a
        // service restarted onto the same persisted cache — resolves
        // with zero new trials. (A request racing this resolution falls
        // back to the cost model inside the worker; it never blocks.)
        if self.route.parallel_kind == EngineKind::Auto && a.n >= self.route.min_parallel_n {
            let cache_key = format!("{key}@{generation}");
            let kernel: Arc<dyn SpmvKernel> = a.clone();
            let threads = self.route.threads;
            let plan = self.plans.get_or_build(
                &cache_key,
                kernel.as_ref(),
                PlanBuilder::new(threads).with_pieces(tuner::required_pieces(threads)),
            );
            let (d, hit) = tuner::resolve(&kernel, &plan, &self.tune_budget, &self.decisions);
            self.resolved.lock().unwrap().insert(cache_key, d.kind);
            let mut s = self.stats.lock().unwrap();
            if !hit {
                s.tunes += 1;
                s.tune_seconds += d.tuned_s;
            }
            s.auto_choices.push((key.to_string(), d.kind.label()));
        }
    }

    /// Submit y = A·x; returns the reply channel.
    pub fn submit(&self, key: &str, x: Vec<f64>) -> Receiver<Result<Vec<f64>, String>> {
        let (tx, rx) = channel();
        {
            let mut s = self.stats.lock().unwrap();
            s.submitted += 1;
        }
        let req = Request { matrix: key.to_string(), x, enqueued: Instant::now(), reply: tx };
        // If the service is shutting down the reply channel will just
        // return a disconnect error to the caller.
        if let Some(q) = &self.queue_tx {
            let _ = q.send(req);
        }
        rx
    }

    /// Convenience: submit and wait.
    pub fn call(&self, key: &str, x: Vec<f64>) -> Result<Vec<f64>, String> {
        self.submit(key, x)
            .recv()
            .map_err(|_| "service shut down before reply".to_string())?
    }

    pub fn stats(&self) -> ServiceStats {
        let s = self.stats.lock().unwrap();
        let lat = s.latency.as_ref().unwrap();
        ServiceStats {
            submitted: s.submitted,
            completed: s.completed,
            failed: s.failed,
            batches: s.batches,
            mean_latency_us: lat.mean_us(),
            p99_latency_us: lat.quantile_us(0.99),
            plan_builds: self.plans.builds(),
            plan_build_seconds: self.plans.build_seconds(),
            tunes: s.tunes,
            tune_seconds: s.tune_seconds,
            decision_hits: self.decisions.hits(),
            engines_evicted: s.engines_evicted,
            auto_choices: s.auto_choices.clone(),
        }
    }

    /// Graceful shutdown: drain, stop threads.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        self.queue_tx.take(); // closes the queue; dispatcher drains & exits
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for MatvecService {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

/// Does `k` name a generation of exactly the key whose prefix is
/// `"key@"` — i.e. `key@<digits>`? An all-digit suffix can only be a
/// generation stamped by `register()`; anything else (e.g. `key@b@0`)
/// belongs to a *different* user key that happens to contain '@'.
fn is_generation_of(k: &str, prefix: &str) -> bool {
    k.starts_with(prefix)
        && k.len() > prefix.len()
        && k[prefix.len()..].bytes().all(|b| b.is_ascii_digit())
}

fn dispatcher_loop(
    queue: Receiver<Request>,
    worker_txs: Vec<Sender<WorkerBatch>>,
    policy: BatchPolicy,
    stats: Arc<Mutex<Stats>>,
) {
    let mut next_worker = 0usize;
    loop {
        // Block for the first request; then greedily drain within the
        // batching window.
        let first = match queue.recv() {
            Ok(r) => r,
            Err(_) => return, // queue closed: done (workers closed by drop of txs)
        };
        let mut pending = vec![first];
        let deadline = Instant::now() + policy.max_wait;
        while pending.len() < policy.max_batch * worker_txs.len() {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match queue.recv_timeout(deadline - now) {
                Ok(r) => pending.push(r),
                Err(_) => break,
            }
        }
        // Form per-matrix batches and ship them.
        let keys: Vec<String> = pending.iter().map(|r| r.matrix.clone()).collect();
        let batches = form_batches(&keys, &policy);
        {
            let mut s = stats.lock().unwrap();
            s.batches += batches.len() as u64;
        }
        // Move requests out of `pending` into their batches (descending
        // index take keeps indices valid).
        let mut slots: Vec<Option<Request>> = pending.into_iter().map(Some).collect();
        for b in batches {
            let reqs: Vec<Request> =
                b.requests.iter().map(|&i| slots[i].take().expect("batch index")).collect();
            let wb = WorkerBatch { matrix: b.matrix, requests: reqs };
            let _ = worker_txs[next_worker % worker_txs.len()].send(wb);
            next_worker += 1;
        }
    }
}

fn worker_loop(
    rx: Receiver<WorkerBatch>,
    registry: Arc<Mutex<Registry>>,
    plans: Arc<PlanCache>,
    route: RoutePolicy,
    stats: Arc<Mutex<Stats>>,
    resolved: Arc<Mutex<HashMap<String, EngineKind>>>,
    engine_capacity: usize,
) {
    let router = Router::new(route);
    // Engine cache per (matrix, generation, backend) — engines hold
    // execution state (pool, buffers) and are not Sync, so each worker
    // owns its own; the *plan* inside every engine comes from the shared
    // service cache. Structural keys so user keys containing '@' cannot
    // alias generations. Values carry the last-served batch tick for the
    // LRU eviction below.
    let mut engines: HashMap<(String, u64, String), (Box<dyn ParallelSpmv>, u64)> = HashMap::new();
    let mut serve_tick: u64 = 0;
    while let Ok(batch) = rx.recv() {
        let hit = registry.lock().unwrap().get(&batch.matrix).cloned();
        let Some((a, generation)) = hit else {
            let mut s = stats.lock().unwrap();
            for r in batch.requests {
                s.failed += 1;
                let _ = r.reply.send(Err(format!("unknown matrix {:?}", batch.matrix)));
            }
            continue;
        };
        // Generation-qualified key: caches can never mix state across a
        // register() replacement (the matrix and its engines/plans stay
        // a consistent snapshot even if the registry changes mid-batch).
        let cache_key = format!("{}@{generation}", batch.matrix);
        // Evict engines built for retired generations of this matrix —
        // each pins a ThreadPool (live OS threads), the old matrix, and
        // its plan.
        engines.retain(|k, _| k.0 != batch.matrix || k.1 == generation);
        serve_tick += 1;
        let mut used_key: Option<(String, u64, String)> = None;
        // Resolve Auto once per batch (it is batch-invariant): through
        // the registration-time tuning decision, or — for a request
        // racing that resolution — the cost model (features only, no
        // trials), rather than blocking or tuning on the request path.
        let backend = match router.route(&a) {
            Backend::NativeParallel { kind: EngineKind::Auto, threads } => {
                let known = resolved.lock().unwrap().get(&cache_key).copied();
                let kind = known.unwrap_or_else(|| {
                    let plan = plans.get_or_build(
                        &cache_key,
                        a.as_ref(),
                        PlanBuilder::new(threads).with_pieces(tuner::required_pieces(threads)),
                    );
                    tuner::cost_model(&tuner::Features::extract(a.as_ref(), &plan))
                });
                Backend::NativeParallel { kind, threads }
            }
            other => other,
        };
        for req in batch.requests {
            if req.x.len() != a.n {
                let mut s = stats.lock().unwrap();
                s.failed += 1;
                let _ = req
                    .reply
                    .send(Err(format!("x length {} != n {}", req.x.len(), a.n)));
                continue;
            }
            let mut y = vec![0.0; a.n];
            match &backend {
                Backend::NativeSequential => a.spmv_into_zeroed(&req.x, &mut y),
                Backend::NativeParallel { kind, threads } => {
                    let ekey = (batch.matrix.clone(), generation, kind.label());
                    let slot = engines.entry(ekey.clone()).or_insert_with(|| {
                        let plan = plans.get_or_build(
                            &cache_key,
                            a.as_ref(),
                            PlanBuilder::for_kind(*threads, *kind),
                        );
                        (build_engine(*kind, a.clone(), plan), 0)
                    });
                    slot.1 = serve_tick;
                    slot.0.spmv(&req.x, &mut y);
                    used_key = Some(ekey);
                }
                Backend::Xla { artifact } => {
                    // The XLA path is exercised via examples/ and the CLI
                    // (XlaRuntime is heavyweight); in-service we fall back
                    // to sequential to keep the worker self-contained.
                    let _ = artifact;
                    a.spmv_into_zeroed(&req.x, &mut y);
                }
            }
            let mut s = stats.lock().unwrap();
            s.completed += 1;
            s.latency.as_mut().unwrap().record(req.enqueued.elapsed().as_secs_f64());
            let _ = req.reply.send(Ok(std::mem::take(&mut y)));
        }
        // LRU eviction (ROADMAP item): a worker that has served many
        // distinct keys must not park one thread pool per key forever.
        // Evict the least-recently-served engines above capacity, never
        // the one this batch just used.
        if engines.len() > engine_capacity {
            let mut evicted = 0u64;
            while engines.len() > engine_capacity {
                let victim = engines
                    .iter()
                    .filter(|&(k, _)| used_key.as_ref() != Some(k))
                    .min_by_key(|&(_, &(_, tick))| tick)
                    .map(|(k, _)| k.clone());
                let Some(v) = victim else { break };
                engines.remove(&v);
                evicted += 1;
            }
            if evicted > 0 {
                stats.lock().unwrap().engines_evicted += evicted;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::Coo;
    use crate::util::Rng;

    fn mat(n: usize, seed: u64) -> Arc<Csrc> {
        let mut rng = Rng::new(seed);
        Arc::new(Csrc::from_coo(&Coo::random_structurally_symmetric(n, 3, false, &mut rng)).unwrap())
    }

    #[test]
    fn serves_correct_products() {
        let svc = MatvecService::start(ServiceConfig::default());
        let a = mat(80, 80);
        svc.register("a", a.clone());
        let x: Vec<f64> = (0..80).map(|i| i as f64 * 0.01).collect();
        let y = svc.call("a", x.clone()).unwrap();
        let mut want = vec![0.0; 80];
        a.spmv_into_zeroed(&x, &mut want);
        crate::util::propcheck::assert_close(&y, &want, 1e-12, 1e-12).unwrap();
        let s = svc.stats();
        assert_eq!(s.completed, 1);
        svc.shutdown();
    }

    #[test]
    fn unknown_matrix_fails_cleanly() {
        let svc = MatvecService::start(ServiceConfig::default());
        let err = svc.call("ghost", vec![1.0; 4]).unwrap_err();
        assert!(err.contains("unknown matrix"), "{err}");
        assert_eq!(svc.stats().failed, 1);
    }

    #[test]
    fn wrong_length_fails_cleanly() {
        let svc = MatvecService::start(ServiceConfig::default());
        svc.register("a", mat(50, 81));
        let err = svc.call("a", vec![1.0; 3]).unwrap_err();
        assert!(err.contains("length"), "{err}");
    }

    #[test]
    fn many_concurrent_requests_all_served() {
        let svc = MatvecService::start(ServiceConfig::default());
        let a = mat(60, 82);
        let b = mat(40, 83);
        svc.register("a", a.clone());
        svc.register("b", b.clone());
        let mut rxs = Vec::new();
        for i in 0..40 {
            let key = if i % 3 == 0 { "b" } else { "a" };
            let n = if key == "a" { 60 } else { 40 };
            let x: Vec<f64> = (0..n).map(|j| (i * j) as f64 * 1e-3).collect();
            rxs.push((key, x.clone(), svc.submit(key, x)));
        }
        for (key, x, rx) in rxs {
            let y = rx.recv().unwrap().unwrap();
            let m = if key == "a" { &a } else { &b };
            let mut want = vec![0.0; m.n];
            m.spmv_into_zeroed(&x, &mut want);
            crate::util::propcheck::assert_close(&y, &want, 1e-12, 1e-12).unwrap();
        }
        let s = svc.stats();
        assert_eq!(s.completed, 40);
        assert!(s.batches >= 2, "should have formed multiple batches");
        assert!(s.mean_latency_us > 0.0);
        svc.shutdown();
    }

    #[test]
    fn parallel_backend_used_for_large_matrices() {
        let mut cfg = ServiceConfig::default();
        cfg.route.min_parallel_n = 32; // force the parallel path
        cfg.route.threads = 2;
        let svc = MatvecService::start(cfg);
        let a = mat(200, 84);
        svc.register("big", a.clone());
        let x = vec![1.0; 200];
        let y = svc.call("big", x.clone()).unwrap();
        let mut want = vec![0.0; 200];
        a.spmv_into_zeroed(&x, &mut want);
        crate::util::propcheck::assert_close(&y, &want, 1e-11, 1e-11).unwrap();
        svc.shutdown();
    }

    #[test]
    fn plan_built_once_across_workers_and_requests() {
        // Four workers hammering one matrix over the parallel backend
        // must share a single cached plan — the registry analyzes a
        // matrix once, not once per worker × engine.
        let mut cfg = ServiceConfig::default();
        cfg.workers = 4;
        cfg.route.min_parallel_n = 1; // force the parallel path
        cfg.route.threads = 2;
        let svc = MatvecService::start(cfg);
        let a = mat(120, 85);
        svc.register("shared", a.clone());
        let mut want = vec![0.0; 120];
        let x: Vec<f64> = (0..120).map(|i| (i as f64 * 0.01).sin()).collect();
        a.spmv_into_zeroed(&x, &mut want);
        let rxs: Vec<_> = (0..32).map(|_| svc.submit("shared", x.clone())).collect();
        for rx in rxs {
            let y = rx.recv().unwrap().unwrap();
            crate::util::propcheck::assert_close(&y, &want, 1e-11, 1e-11).unwrap();
        }
        let s = svc.stats();
        assert_eq!(s.completed, 32);
        assert_eq!(s.plan_builds, 1, "one matrix must be analyzed exactly once");
        assert!(s.plan_build_seconds >= 0.0);
        // A second matrix costs exactly one more analysis.
        let b = mat(90, 86);
        svc.register("other", b.clone());
        let x2 = vec![1.0; 90];
        let _ = svc.call("other", x2).unwrap();
        assert_eq!(svc.stats().plan_builds, 2);
        svc.shutdown();
    }

    #[test]
    fn replacing_a_matrix_retires_its_engines_and_plans() {
        // After register() overwrites a key — even with a different size
        // — requests must run against the new matrix, not a worker's
        // cached engine for the old one.
        let mut cfg = ServiceConfig::default();
        cfg.workers = 1; // one worker so the engine cache is definitely warm
        cfg.route.min_parallel_n = 1;
        cfg.route.threads = 2;
        let svc = MatvecService::start(cfg);
        let a1 = mat(60, 87);
        svc.register("m", a1.clone());
        let x1 = vec![1.0; 60];
        let y1 = svc.call("m", x1.clone()).unwrap();
        let mut want1 = vec![0.0; 60];
        a1.spmv_into_zeroed(&x1, &mut want1);
        crate::util::propcheck::assert_close(&y1, &want1, 1e-11, 1e-11).unwrap();
        // Replace with a smaller matrix (the dangerous direction for a
        // stale engine) and serve again.
        let a2 = mat(40, 88);
        svc.register("m", a2.clone());
        let x2 = vec![1.0; 40];
        let y2 = svc.call("m", x2.clone()).unwrap();
        let mut want2 = vec![0.0; 40];
        a2.spmv_into_zeroed(&x2, &mut want2);
        crate::util::propcheck::assert_close(&y2, &want2, 1e-11, 1e-11).unwrap();
        let s = svc.stats();
        assert_eq!(s.completed, 2);
        assert_eq!(s.plan_builds, 2, "replacement must build a fresh plan");
        svc.shutdown();
    }

    #[test]
    fn auto_routing_tunes_once_and_persists_decisions() {
        let dir = std::env::temp_dir().join(format!("csrc_auto_svc_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut cfg = ServiceConfig::default();
        cfg.route.parallel_kind = EngineKind::Auto;
        cfg.route.min_parallel_n = 1; // force the parallel (Auto) path
        cfg.route.threads = 2;
        cfg.tune_budget = TrialBudget::smoke();
        cfg.decision_cache = Some(dir.join("decisions.json"));
        let a = mat(150, 89);
        let x: Vec<f64> = (0..150).map(|i| (i as f64 * 0.01).sin()).collect();
        let mut want = vec![0.0; 150];
        a.spmv_into_zeroed(&x, &mut want);

        let svc = MatvecService::start(cfg.clone());
        svc.register("m", a.clone());
        let y = svc.call("m", x.clone()).unwrap();
        crate::util::propcheck::assert_close(&y, &want, 1e-11, 1e-11).unwrap();
        let s = svc.stats();
        assert_eq!(s.tunes, 1, "first Auto registration runs measured trials");
        assert!(s.tune_seconds > 0.0);
        assert_eq!(s.auto_choices.len(), 1);
        let (key, label) = &s.auto_choices[0];
        assert_eq!(key, "m");
        let resolved = EngineKind::parse(label).expect("resolved label parses");
        assert_ne!(resolved, EngineKind::Auto, "Auto must resolve to a concrete engine");
        // Registering the same structure under another key: decision
        // cache hit, zero new trials.
        svc.register("m-again", a.clone());
        let s = svc.stats();
        assert_eq!(s.tunes, 1, "same structure must not re-tune");
        assert!(s.decision_hits >= 1);
        svc.shutdown();

        // A restarted service on the same persisted cache re-tunes
        // nothing: zero trials, decision read from disk.
        let svc2 = MatvecService::start(cfg);
        svc2.register("m", a.clone());
        let y2 = svc2.call("m", x).unwrap();
        crate::util::propcheck::assert_close(&y2, &want, 1e-11, 1e-11).unwrap();
        let s2 = svc2.stats();
        assert_eq!(s2.tunes, 0, "restart must hit the persisted decision cache");
        assert!(s2.decision_hits >= 1);
        assert_eq!(s2.auto_choices[0].1, *label, "persisted decision picks the same engine");
        svc2.shutdown();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn resolved_sweep_matches_generations_exactly() {
        // Re-registering "a" must not drop the Auto decision of a
        // different live key that merely starts with "a@".
        assert!(is_generation_of("a@0", "a@"));
        assert!(is_generation_of("a@12", "a@"));
        assert!(!is_generation_of("a@b@0", "a@"));
        assert!(!is_generation_of("a@", "a@"));
        assert!(!is_generation_of("ab@0", "a@"));
    }

    #[test]
    fn worker_engine_cache_evicts_lru() {
        // Capacity-1 worker cache serving two matrices must release the
        // older engine (and its parked pool) instead of hoarding both.
        let mut cfg = ServiceConfig::default();
        cfg.workers = 1;
        cfg.route.min_parallel_n = 1;
        cfg.route.threads = 2;
        cfg.engine_cache_capacity = 1;
        let svc = MatvecService::start(cfg);
        let a = mat(60, 91);
        let b = mat(50, 92);
        svc.register("a", a.clone());
        svc.register("b", b.clone());
        for (key, m) in [("a", &a), ("b", &b), ("a", &a)] {
            let x = vec![1.0; m.n];
            let y = svc.call(key, x.clone()).unwrap();
            let mut want = vec![0.0; m.n];
            m.spmv_into_zeroed(&x, &mut want);
            crate::util::propcheck::assert_close(&y, &want, 1e-11, 1e-11).unwrap();
        }
        let s = svc.stats();
        assert_eq!(s.completed, 3);
        assert!(
            s.engines_evicted >= 1,
            "capacity-1 cache must evict between matrices, evicted {}",
            s.engines_evicted
        );
        svc.shutdown();
    }

    #[test]
    fn property_service_matches_sequential() {
        crate::util::propcheck::check(5, |rng| {
            let n = 20 + rng.below(80);
            let a = {
                let coo = Coo::random_structurally_symmetric(n, 2, false, rng);
                Arc::new(Csrc::from_coo(&coo).map_err(|e| e.to_string())?)
            };
            let svc = MatvecService::start(ServiceConfig::default());
            svc.register("m", a.clone());
            for _ in 0..3 {
                let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
                let y = svc.call("m", x.clone())?;
                let mut want = vec![0.0; n];
                a.spmv_into_zeroed(&x, &mut want);
                crate::util::propcheck::assert_close(&y, &want, 1e-11, 1e-11)?;
            }
            svc.shutdown();
            Ok(())
        });
    }
}
