//! The matvec service: registry + plan cache + request queue + batcher +
//! workers.
//!
//! Flow: `submit()` enqueues (matrix-key, x, reply-channel) → the
//! dispatcher thread drains the queue, forms per-matrix batches
//! ([`super::batcher`]), and hands each batch to a worker
//! ([`super::worker`]) → the worker resolves the backend via the
//! [`super::router`] policy, runs the products on its cached engine, and
//! replies through each request's channel. Metrics (counts + latency
//! histogram) are sampled on the worker side into the service's
//! [`MetricsRegistry`] — [`ServiceStats`] ([`super::stats`]) is a typed
//! snapshot over those registry atomics, and the same registry serves
//! Prometheus scrapes ([`crate::obs::serve_metrics`]), so the CLI
//! endpoint and `stats()` can never disagree.
//!
//! Engines hold execution state (pools, buffers) and stay per-worker,
//! but the *analysis* they run — the [`crate::plan::SpmvPlan`] — is
//! shared: one [`PlanCache`] maps matrix-key × thread-count to a single
//! `Arc<SpmvPlan>` that every worker and engine borrows, so a matrix
//! registered once is analyzed once, not once per worker × engine. Plan
//! build count and time are surfaced in [`ServiceStats`].
//!
//! Autotuned routing is *self-correcting*: workers fold each batch's
//! measured rate into a per-key EWMA, and when it drifts below
//! [`ServiceConfig::drift_fraction`] of the decision's recorded rate the
//! key is queued to a background re-tuner thread ([`super::retuner`]) —
//! the decision cache entry is upgraded off the request path, never on
//! it.
//!
//! This file owns only the service *shell*: configuration, lifecycle
//! (thread spawn/join), registration, and the dispatcher. The serving
//! internals live in shard-local sibling modules —
//! [`super::registration`] (registry types, Auto resolution),
//! [`super::worker`] (engine cache + batch execution + drift),
//! [`super::retuner`] (background re-measurement), and [`super::stats`]
//! (counters + snapshot) — so a [`super::ShardedMatvecService`] can own
//! one complete, private instance of all of it per shard.

use super::batcher::{form_batches, summarize, BatchPolicy};
use super::error::ServiceError;
use super::registration::{
    self, is_generation_of, DriftState, RcmRegistry, Registry, ResolvedAuto, ResolverCtx,
};
use super::retuner::{retuner_loop, RetunerCtx, RetunerMsg, SharedRetuneRx};
use super::router::RoutePolicy;
use super::stats::{Counters, ServiceStats};
use super::worker::{worker_loop, ReplySlot, Request, SharedBatchRx, WorkerBatch, WorkerCtx};
use crate::obs::{self, MetricsRegistry, Phase};
use crate::parallel::EngineKind;
use crate::plan::PlanCache;
use crate::sparse::{Csrc, SpmvKernel};
use crate::tuner::{self, DecisionCache, TrialBudget};
use crate::util::lock_unpoisoned;
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// First supervisor respawn delay after a thread crash; doubles per
/// consecutive crash of the same slot, capped at
/// [`RESTART_BACKOFF_CAP`] so a hard-crashing worker cannot spin the
/// supervisor, and a one-off panic costs ~10ms of extra latency.
pub(crate) const RESTART_BACKOFF_BASE: Duration = Duration::from_millis(10);
pub(crate) const RESTART_BACKOFF_CAP: Duration = Duration::from_secs(1);

/// Service configuration.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    pub workers: usize,
    pub batch: BatchPolicy,
    pub route: RoutePolicy,
    /// Trial budget used when `route.parallel_kind` is
    /// [`EngineKind::Auto`]; a zero budget answers from the cost model.
    pub tune_budget: TrialBudget,
    /// Persist autotuner decisions here (`None` = in-memory only). A
    /// restarted service pointed at the same file re-tunes nothing it
    /// has already measured.
    pub decision_cache: Option<PathBuf>,
    /// Learned cost-model file ([`tuner::CostModel`], written by
    /// `csrc tune train`) consulted for zero-budget/cold-start Auto
    /// resolutions *before* the hand-written heuristic. `None` — or an
    /// unreadable file — means heuristic only. Fallback order per
    /// registration: decision-cache hit → model → heuristic
    /// (`ServiceStats::{model_hits, model_fallbacks}`).
    pub model: Option<PathBuf>,
    /// Max engines one worker keeps cached (LRU by last-served batch).
    /// Each cached engine pins a thread pool, so abandoned keys must not
    /// park pools forever.
    pub engine_cache_capacity: usize,
    /// Queue a background re-tune when a served matrix's measured rate
    /// (per-key EWMA over batches) drops below this fraction of its
    /// decision's recorded rate. `0.0` disables drift detection.
    pub drift_fraction: f64,
    /// Batches observed for a key before drift is judged — the EWMA
    /// needs a few samples before it means anything.
    pub drift_min_batches: u64,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            workers: 2,
            batch: BatchPolicy::default(),
            route: RoutePolicy::default(),
            tune_budget: TrialBudget::default(),
            decision_cache: None,
            model: None,
            engine_cache_capacity: 32,
            drift_fraction: 0.5,
            drift_min_batches: 8,
        }
    }
}

pub struct MatvecService {
    registry: Arc<Mutex<Registry>>,
    plans: Arc<PlanCache>,
    queue_tx: Option<Sender<Request>>,
    dispatcher: Option<std::thread::JoinHandle<()>>,
    /// Owns and joins every worker + the retuner; respawns crashes.
    supervisor: Option<std::thread::JoinHandle<()>>,
    stats: Arc<Counters>,
    route: RoutePolicy,
    tune_budget: TrialBudget,
    decisions: Arc<DecisionCache>,
    /// Learned cost model for cold-start resolutions (loaded once at
    /// start; shared with the workers for the racing-request fallback).
    model: Option<Arc<tuner::CostModel>>,
    /// `key@generation` → engine + thread count resolved for an Auto route.
    resolved: Arc<Mutex<HashMap<String, ResolvedAuto>>>,
    /// `key@generation` → RCM artifacts shared by all workers.
    rcm: Arc<Mutex<RcmRegistry>>,
    /// `key@generation` → served-rate EWMA for drift detection.
    drift: Arc<Mutex<HashMap<String, DriftState>>>,
    retune_tx: Option<Sender<RetunerMsg>>,
}

/// Which supervised thread an [`ExitReport`] is about.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Role {
    Worker(usize),
    Retuner,
}

/// Sent by every supervised thread as its last act: which slot finished
/// and whether it crashed (batch panic) or exited cleanly (shutdown).
struct ExitReport {
    role: Role,
    crashed: bool,
}

fn spawn_worker(
    slot: usize,
    rx: SharedBatchRx,
    ctx: WorkerCtx,
    exit_tx: Sender<ExitReport>,
) -> JoinHandle<()> {
    std::thread::Builder::new()
        .name(format!("matvec-worker-{slot}"))
        .spawn(move || {
            let crashed = worker_loop(rx, ctx);
            let _ = exit_tx.send(ExitReport { role: Role::Worker(slot), crashed });
        })
        .expect("spawn worker")
}

fn spawn_retuner(
    rx: SharedRetuneRx,
    ctx: RetunerCtx,
    exit_tx: Sender<ExitReport>,
) -> JoinHandle<()> {
    std::thread::Builder::new()
        .name("matvec-retuner".into())
        .spawn(move || {
            let crashed = retuner_loop(rx, ctx);
            let _ = exit_tx.send(ExitReport { role: Role::Retuner, crashed });
        })
        .expect("spawn retuner")
}

/// Everything the supervisor needs to respawn a crashed thread: the
/// shared queue receivers (so a replacement resumes the dead thread's
/// queue) and a context template per slot.
struct Supervision {
    exit_rx: Receiver<ExitReport>,
    /// The supervisor's own sender clone — handed to every respawn, and
    /// keeps `exit_rx.recv()` from erroring while threads are down.
    exit_tx: Sender<ExitReport>,
    worker_rxs: Vec<SharedBatchRx>,
    worker_ctxs: Vec<WorkerCtx>,
    worker_handles: Vec<Option<JoinHandle<()>>>,
    retune_rx: SharedRetuneRx,
    retuner_ctx: Option<RetunerCtx>,
    retuner_handle: Option<JoinHandle<()>>,
    stats: Arc<Counters>,
}

/// Supervision tree root: join every exiting thread, respawn crashes
/// with capped exponential backoff, stop respawning once shutdown is
/// observed, and return only when every supervised thread is gone.
fn supervisor_loop(mut sup: Supervision) {
    let nworkers = sup.worker_handles.len();
    let mut live = nworkers + 1; // workers + retuner
    let mut backoff = vec![RESTART_BACKOFF_BASE; nworkers + 1]; // last = retuner
    let mut shutting_down = false;
    while live > 0 {
        let report = match sup.exit_rx.recv() {
            Ok(r) => r,
            Err(_) => break, // unreachable: sup.exit_tx keeps the channel open
        };
        let handle = match report.role {
            Role::Worker(i) => sup.worker_handles[i].take(),
            Role::Retuner => sup.retuner_handle.take(),
        };
        if let Some(h) = handle {
            let _ = h.join();
        }
        live -= 1;
        if !report.crashed {
            // Clean exits only happen at shutdown (a worker's queue
            // closes only once the dispatcher is gone). Stop respawning
            // and release the templates: each worker template holds a
            // retune sender, and the retuner cannot drain and exit
            // until every sender is dropped.
            if !shutting_down {
                shutting_down = true;
                sup.worker_ctxs.clear();
                sup.retuner_ctx = None;
            }
            continue;
        }
        if shutting_down {
            continue; // tearing down: let crashed slots stay down
        }
        // Crashed mid-service: respawn with capped exponential backoff.
        // The shared queue receiver survives the dead thread, so any
        // batches it had not pulled are served by the replacement.
        let bi = match report.role {
            Role::Worker(i) => i,
            Role::Retuner => nworkers,
        };
        std::thread::sleep(backoff[bi]);
        backoff[bi] = (backoff[bi] * 2).min(RESTART_BACKOFF_CAP);
        let _restart_span = obs::phase(Phase::Restart);
        sup.stats.worker_restarts.inc();
        match report.role {
            Role::Worker(i) => {
                sup.worker_handles[i] = Some(spawn_worker(
                    i,
                    sup.worker_rxs[i].clone(),
                    sup.worker_ctxs[i].clone(),
                    sup.exit_tx.clone(),
                ));
            }
            Role::Retuner => {
                if let Some(ctx) = sup.retuner_ctx.clone() {
                    sup.retuner_handle =
                        Some(spawn_retuner(sup.retune_rx.clone(), ctx, sup.exit_tx.clone()));
                }
            }
        }
        live += 1;
    }
}

impl MatvecService {
    pub fn start(cfg: ServiceConfig) -> MatvecService {
        let registry: Arc<Mutex<Registry>> = Arc::new(Mutex::new(HashMap::new()));
        let plans = Arc::new(PlanCache::new());
        let stats = Arc::new(Counters::new(Arc::new(MetricsRegistry::new())));
        let decisions = Arc::new(match &cfg.decision_cache {
            Some(path) => DecisionCache::open(path),
            None => DecisionCache::in_memory(),
        });
        // A missing/invalid model file degrades (with a warning from
        // `load`) to the heuristic — never a startup failure.
        let model = cfg.model.as_ref().and_then(|p| tuner::CostModel::load(p)).map(Arc::new);
        let resolved: Arc<Mutex<HashMap<String, ResolvedAuto>>> =
            Arc::new(Mutex::new(HashMap::new()));
        let rcm: Arc<Mutex<RcmRegistry>> = Arc::new(Mutex::new(HashMap::new()));
        let drift: Arc<Mutex<HashMap<String, DriftState>>> = Arc::new(Mutex::new(HashMap::new()));
        let (queue_tx, queue_rx) = channel::<Request>();
        let (retune_tx, retune_rx) = channel::<RetunerMsg>();
        let (exit_tx, exit_rx) = channel::<ExitReport>();

        // Background re-tuner: drains drift-triggered jobs off the
        // request path, upgrades the decision cache in place. Its queue
        // receiver is shared so a respawn after a crash resumes it.
        let retune_rx: SharedRetuneRx = Arc::new(Mutex::new(retune_rx));
        let retuner_ctx = RetunerCtx {
            registry: registry.clone(),
            plans: plans.clone(),
            route: cfg.route.clone(),
            budget: cfg.tune_budget,
            decisions: decisions.clone(),
            resolved: resolved.clone(),
            drift: drift.clone(),
            stats: stats.clone(),
        };
        let retuner_handle =
            spawn_retuner(retune_rx.clone(), retuner_ctx.clone(), exit_tx.clone());

        // Worker channels: the send side goes to the dispatcher, the
        // receive side is shared with the supervisor so a respawned
        // worker resumes the dead one's queue.
        let mut worker_txs: Vec<Sender<WorkerBatch>> = Vec::new();
        let mut worker_rxs: Vec<SharedBatchRx> = Vec::new();
        let mut worker_ctxs: Vec<WorkerCtx> = Vec::new();
        let mut worker_handles: Vec<Option<JoinHandle<()>>> = Vec::new();
        for wid in 0..cfg.workers.max(1) {
            let (tx, rx) = channel::<WorkerBatch>();
            worker_txs.push(tx);
            let rx: SharedBatchRx = Arc::new(Mutex::new(rx));
            let ctx = WorkerCtx {
                registry: registry.clone(),
                plans: plans.clone(),
                route: cfg.route.clone(),
                stats: stats.clone(),
                latency: stats.obs.histogram("csrc_request_latency_us"),
                resolved: resolved.clone(),
                rcm: rcm.clone(),
                drift: drift.clone(),
                model: model.clone(),
                retune_tx: retune_tx.clone(),
                engine_capacity: cfg.engine_cache_capacity.max(1),
                drift_fraction: cfg.drift_fraction,
                drift_min_batches: cfg.drift_min_batches,
            };
            worker_handles.push(Some(spawn_worker(wid, rx.clone(), ctx.clone(), exit_tx.clone())));
            worker_rxs.push(rx);
            worker_ctxs.push(ctx);
        }

        // Dispatcher: drain queue -> batches -> round-robin workers.
        let batch_policy = cfg.batch;
        let stats_d = stats.clone();
        let dispatcher = std::thread::Builder::new()
            .name("matvec-dispatcher".into())
            .spawn(move || dispatcher_loop(queue_rx, worker_txs, batch_policy, stats_d))
            .expect("spawn dispatcher");

        // Supervisor: owns every worker/retuner handle, joins exits,
        // respawns crashes (capped backoff), and itself exits only once
        // every supervised thread is down — so joining the supervisor
        // joins the whole tree.
        let sup = Supervision {
            exit_rx,
            exit_tx,
            worker_rxs,
            worker_ctxs,
            worker_handles,
            retune_rx,
            retuner_ctx: Some(retuner_ctx),
            retuner_handle: Some(retuner_handle),
            stats: stats.clone(),
        };
        let supervisor = std::thread::Builder::new()
            .name("matvec-supervisor".into())
            .spawn(move || supervisor_loop(sup))
            .expect("spawn supervisor");

        MatvecService {
            registry,
            plans,
            queue_tx: Some(queue_tx),
            dispatcher: Some(dispatcher),
            supervisor: Some(supervisor),
            stats,
            route: cfg.route,
            tune_budget: cfg.tune_budget,
            decisions,
            model,
            resolved,
            rcm,
            drift,
            retune_tx: Some(retune_tx),
        }
    }

    /// Register (or replace) a matrix under a key. Replacement bumps the
    /// key's generation: workers' engine caches and the plan cache are
    /// keyed by generation, so state built for the old matrix is never
    /// consulted again. All prior generations' plans are swept here
    /// (prefix match, so a plan raced in by a worker mid-replace is
    /// collected by the next replacement at the latest); workers evict a
    /// key's retired engines the next time they serve that key, and the
    /// per-worker LRU cap (`ServiceConfig::engine_cache_capacity`)
    /// bounds how long an abandoned key's last engine can stay parked.
    pub fn register(&self, key: &str, a: Arc<Csrc>) {
        // Drop the registry lock before sweeping plans: plan builds hold
        // the cache lock for their whole (possibly long) analysis, and
        // every worker batch starts with a registry read — invalidating
        // under the registry lock would stall all workers behind an
        // unrelated build.
        let (generation, replaced) = {
            let mut reg = lock_unpoisoned(&self.registry);
            let generation = reg.get(key).map(|e| e.generation + 1).unwrap_or(0);
            let replaced =
                reg.insert(key.to_string(), registration::RegEntry::new(a.clone(), generation)).is_some();
            (generation, replaced)
        };
        if replaced {
            let prefix = format!("{key}@");
            // Plans may over-match (a user key containing '@' aliases the
            // prefix) — that only costs a rebuild. Resolved Auto entries
            // are repopulated by register() alone, so they must match
            // exactly: `key@<generation>` with an all-digit suffix, never
            // another live key like `key@other@0`.
            self.plans.invalidate_prefix(&prefix);
            // RCM artifacts follow the plans' lifecycle: purged here by
            // prefix (over-matching only costs a rebuild; an artifact a
            // worker races in mid-replace is collected by the next
            // replacement at the latest).
            lock_unpoisoned(&self.rcm).retain(|k, _| !k.starts_with(&prefix));
            lock_unpoisoned(&self.resolved).retain(|k, _| !is_generation_of(k, &prefix));
            lock_unpoisoned(&self.drift).retain(|k, _| !is_generation_of(k, &prefix));
        }
        // Auto routing: resolve the concrete engine — and, with
        // `sweep_threads`, the thread count — now, off the request path
        // ([`registration::resolve_auto`]). The decision cache is keyed
        // by structure fingerprint × thread budget, so a re-registered
        // matrix — or one registered with a service restarted onto the
        // same persisted cache — resolves with zero new trials. (A
        // request racing this resolution falls back to the
        // model/heuristic inside the worker; it never blocks.)
        if self.route.parallel_kind == EngineKind::Auto && a.n >= self.route.min_parallel_n {
            let cache_key = format!("{key}@{generation}");
            let kernel: Arc<dyn SpmvKernel> = a.clone();
            let ctx = ResolverCtx {
                plans: &self.plans,
                route: &self.route,
                budget: &self.tune_budget,
                decisions: &self.decisions,
                model: self.model.as_deref(),
            };
            let (mut d, hit) = registration::resolve_auto(&ctx, &cache_key, &kernel);
            if replaced && d.served_mflops > 0.0 {
                // The cached served-rate baseline was calibrated against
                // the *replaced* key's serving. Decisions are keyed by
                // structure, so a same-pattern replacement with new
                // values would inherit it — and judge the new values
                // against the old rate, triggering or suppressing a
                // re-tune for the wrong reason. Drop it here and in the
                // persisted entry; the next calibration window records a
                // fresh one.
                d.served_mflops = 0.0;
                self.decisions.clear_served_rate(d.fingerprint, d.max_threads);
            }
            lock_unpoisoned(&self.resolved)
                .insert(cache_key.clone(), ResolvedAuto::from_decision(&d));
            // Fresh drift baseline for the new decision/generation.
            lock_unpoisoned(&self.drift).insert(cache_key, DriftState::default());
            if !hit {
                self.stats.tunes.inc();
                self.stats.add_tune_seconds(d.tuned_s);
                // Cold-start provenance: who answered when no cached
                // decision satisfied the caller.
                match d.provenance {
                    tuner::Provenance::Model => self.stats.model_hits.inc(),
                    tuner::Provenance::Heuristic => self.stats.model_fallbacks.inc(),
                    tuner::Provenance::Measured => {}
                }
            }
            // Reordered winners are visible in the choice log (the plain
            // label still parses as an EngineKind for plain winners).
            let mut log = lock_unpoisoned(&self.stats.choices);
            log.auto_choices.push((key.to_string(), d.label()));
            log.chosen_threads.push((key.to_string(), d.nthreads));
        }
    }

    /// Swap a registered matrix's *values* in place — same pattern, new
    /// numbers, the dominant update of FEM time-stepping. Everything
    /// derived from the pattern survives: the scheduling plan
    /// (`plan_builds` unchanged), the conflict coloring, the RCM
    /// ordering (`rcm_builds` unchanged — the cached permuted matrix is
    /// re-permuted in place), and the tuned decision (`tunes`
    /// unchanged). What restarts: the key's values generation (workers
    /// rebuild their engines against the new values from the cached
    /// plan; panels never mix requests across the boundary), the drift
    /// EWMA, and the served-rate baseline — all of which were measured
    /// against the old values.
    ///
    /// `values` must carry the registered pattern: a fingerprint or
    /// shape mismatch is a typed error ([`crate::sparse::CsrcError`]
    /// stringified into a fatal [`ServiceError`]), never a panic, and
    /// leaves the registered matrix untouched.
    pub fn update_values(&self, key: &str, values: &Csrc) -> Result<(), ServiceError> {
        let _update_span = obs::phase(Phase::Update);
        let cache_key = loop {
            // Snapshot under the lock, build outside it: the clone and
            // value copy are O(nnz), and every submit() stamp and worker
            // registry read takes this mutex — holding it across the
            // copy would stall the whole request path per update.
            let (cur, generation, vgen) = {
                let reg = lock_unpoisoned(&self.registry);
                let Some(e) = reg.get(key) else {
                    return Err(ServiceError::fatal(format!("unknown matrix {key:?}")));
                };
                (e.a.clone(), e.generation, e.vgen)
            };
            let mut next = (*cur).clone();
            next.update_values_from(values)
                .map_err(|e| ServiceError::fatal(format!("update_values({key:?}): {e}")))?;
            let next = Arc::new(next);
            let cache_key = format!("{key}@{generation}");
            // Re-permute the cached RCM artifact from the new values,
            // also outside the locks (no new RCM computation —
            // `rcm_builds` stays put).
            let permuted = lock_unpoisoned(&self.rcm)
                .get(&cache_key)
                .map(|e| e.perm.clone())
                .map(|perm| (Arc::new(next.permuted(&perm)), perm));
            // Publish only if nothing raced the build: a concurrent
            // register()/update_values() moved the key on, so redo the
            // update against the new state rather than clobber it.
            {
                let mut reg = lock_unpoisoned(&self.registry);
                let Some(e) = reg.get_mut(key) else {
                    return Err(ServiceError::fatal(format!("unknown matrix {key:?}")));
                };
                if e.generation != generation || e.vgen != vgen {
                    continue;
                }
                e.retire(next);
            }
            // Patch the shared RCM artifact. A worker can observe the
            // new registry entry before this lands — that is safe:
            // entries are stamped with the values generation their
            // permuted matrix was built from, and a worker re-permutes
            // on a stamp mismatch ([`super::worker`]), so the stale
            // artifact can never serve under the new generation. The
            // stamp guard also keeps us from clobbering a newer
            // update's (or a worker's) fresher patch.
            if let Some((pa, perm)) = permuted {
                let mut rcm = lock_unpoisoned(&self.rcm);
                if let Some(entry) = rcm.get_mut(&cache_key) {
                    if Arc::ptr_eq(&entry.perm, &perm) && entry.vgen <= vgen {
                        entry.pa = pa;
                        entry.vgen = vgen + 1;
                    }
                }
            }
            break cache_key;
        };
        // Drift tracking restarts: the EWMA aggregated rates measured
        // against the old values.
        lock_unpoisoned(&self.drift).insert(cache_key.clone(), DriftState::default());
        // So does the served-rate baseline — in the live resolution and
        // the persisted decision entry — while the decision itself
        // (engine, threads, block width) is kept: the pattern that
        // earned it is unchanged.
        if let Some(r) = lock_unpoisoned(&self.resolved).get_mut(&cache_key) {
            r.served_mflops = 0.0;
            self.decisions.clear_served_rate(r.fingerprint, r.max_threads);
        }
        self.stats.value_updates.inc();
        Ok(())
    }

    /// Drop the served-rate baseline calibrated for `key`'s *current*
    /// generation — live resolution and persisted decision entry both.
    /// The sharded front calls this on every shard of an outgoing
    /// decomposition when a key is replaced: the per-shard decision
    /// files (`….shard<i>`) are keyed by the shard-local pattern, so a
    /// baseline measured against a retired partition would otherwise
    /// survive to mis-calibrate a future registration that happens to
    /// resolve to the same entry. No-op for unknown keys.
    pub fn invalidate_served_baseline(&self, key: &str) {
        let Some(generation) = lock_unpoisoned(&self.registry).get(key).map(|e| e.generation)
        else {
            return;
        };
        let cache_key = format!("{key}@{generation}");
        if let Some(r) = lock_unpoisoned(&self.resolved).get_mut(&cache_key) {
            r.served_mflops = 0.0;
            self.decisions.clear_served_rate(r.fingerprint, r.max_threads);
        }
    }

    /// Submit y = A·x; returns the reply channel. A request resolves to
    /// `Ok(y)`, a typed [`ServiceError`] (retryable worker crash, fatal
    /// caller bug), or a channel disconnect if the service shuts down
    /// before answering — never silence.
    pub fn submit(&self, key: &str, x: Vec<f64>) -> Receiver<Result<Vec<f64>, ServiceError>> {
        let (tx, rx) = channel();
        self.stats.submitted.inc();
        // Stamp the key's current values generation: the batcher keys
        // panels on it, so requests submitted before an `update_values`
        // never share a blocked product with requests submitted after.
        let values_generation =
            lock_unpoisoned(&self.registry).get(key).map(|e| e.vgen).unwrap_or(0);
        let req = Request {
            matrix: key.to_string(),
            values_generation,
            x,
            enqueued: Instant::now(),
            reply: ReplySlot::new(tx),
        };
        // If the service is shutting down the reply channel will just
        // return a disconnect error to the caller.
        if let Some(q) = &self.queue_tx {
            let _ = q.send(req);
        }
        rx
    }

    /// Convenience: submit and wait.
    pub fn call(&self, key: &str, x: Vec<f64>) -> Result<Vec<f64>, ServiceError> {
        self.submit(key, x)
            .recv()
            .map_err(|_| ServiceError::fatal("service shut down before reply"))?
    }

    /// Requests currently submitted but not yet answered. The sharded
    /// front reads this as its per-shard queue depth for back-pressure;
    /// the read order (completed/failed first) keeps the depth an
    /// over-estimate, never an under-estimate — a full queue can only
    /// look fuller, so back-pressure stays conservative.
    pub fn in_flight(&self) -> u64 {
        let c = &self.stats;
        let done = c.completed.get() + c.failed.get();
        c.submitted.get().saturating_sub(done)
    }

    /// Snapshot the registry into a [`ServiceStats`]. Read order matters
    /// for consistency without a global lock: `completed`/`failed` are
    /// read *before* `submitted` — a request is counted submitted before
    /// it can possibly complete, so anything finishing between the two
    /// reads only widens `submitted` and the snapshot invariant
    /// `completed + failed <= submitted` holds in every interleaving.
    /// (The old `Mutex<Stats>` version held the same lock the workers
    /// bumped counters under; this one never blocks a worker.)
    pub fn stats(&self) -> ServiceStats {
        let c = &self.stats;
        let completed = c.completed.get();
        let failed = c.failed.get();
        let lat = c.obs.merged_histogram("csrc_request_latency_us");
        let log = lock_unpoisoned(&c.choices);
        let auto_choices = log.auto_choices.clone();
        let chosen_threads = log.chosen_threads.clone();
        drop(log);
        let submitted = c.submitted.get();
        ServiceStats {
            submitted,
            completed,
            failed,
            batches: c.batches.get(),
            mean_latency_us: lat.mean_us(),
            p99_latency_us: lat.quantile_us(0.99),
            plan_builds: self.plans.builds(),
            plan_build_seconds: self.plans.build_seconds(),
            tunes: c.tunes.get(),
            tune_seconds: c.tune_ns.get() as f64 / 1e9,
            decision_hits: self.decisions.hits(),
            engines_evicted: c.engines_evicted.get(),
            auto_choices,
            chosen_threads,
            retunes: c.retunes.get(),
            drift_events: c.drift_events.get(),
            model_hits: c.model_hits.get(),
            model_fallbacks: c.model_fallbacks.get(),
            coalesced_products: c.coalesced_products.get(),
            coalesced_requests: c.coalesced_requests.get(),
            rcm_builds: c.rcm_builds.get(),
            panics_caught: c.panics_caught.get(),
            worker_restarts: c.worker_restarts.get(),
            value_updates: c.value_updates.get(),
            assembly_atomic: c.assembly_atomic.get(),
            assembly_colored: c.assembly_colored.get(),
        }
    }

    /// Record one parallel re-assembly run against this service's
    /// counters (`csrc_assembly_*_total`) — called by the time-stepping
    /// path after [`crate::gen::Assembler::assemble`] so the Prometheus
    /// page shows which variant is producing the served values.
    pub fn record_assembly(&self, colored: bool) {
        if colored {
            self.stats.assembly_colored.inc();
        } else {
            self.stats.assembly_atomic.inc();
        }
    }

    /// The service's metrics registry — render it directly or expose it
    /// with [`crate::obs::serve_metrics`] (`csrc serve --metrics-addr`).
    pub fn metrics_registry(&self) -> Arc<MetricsRegistry> {
        self.stats.obs.clone()
    }

    /// Graceful shutdown: drain, stop threads.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        self.queue_tx.take(); // closes the queue; dispatcher drains & exits
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
        // With the dispatcher gone the worker queues close, so workers
        // drain and exit cleanly; the first clean exit tells the
        // supervisor to stop respawning and drop its context templates
        // (whose retune senders, with ours below, are what keep the
        // retuner alive). Joining the supervisor therefore joins every
        // worker *and* the retuner — nothing detaches.
        self.retune_tx.take();
        if let Some(s) = self.supervisor.take() {
            let _ = s.join();
        }
    }
}

impl Drop for MatvecService {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

fn dispatcher_loop(
    queue: Receiver<Request>,
    worker_txs: Vec<Sender<WorkerBatch>>,
    policy: BatchPolicy,
    stats: Arc<Counters>,
) {
    let mut next_worker = 0usize;
    loop {
        // Block for the first request; then greedily drain within the
        // batching window.
        let first = match queue.recv() {
            Ok(r) => r,
            Err(_) => return, // queue closed: done (workers closed by drop of txs)
        };
        let mut pending = vec![first];
        let deadline = Instant::now() + policy.max_wait;
        while pending.len() < policy.max_batch * worker_txs.len() {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match queue.recv_timeout(deadline - now) {
                Ok(r) => pending.push(r),
                Err(_) => break,
            }
        }
        // Form per-matrix batches and ship them.
        let coalesce_span = obs::phase(Phase::Coalesce);
        let keys: Vec<(String, u64)> =
            pending.iter().map(|r| (r.matrix.clone(), r.values_generation)).collect();
        let batches = form_batches(&keys, &policy);
        drop(coalesce_span);
        stats.batches.add(summarize(&batches).batches as u64);
        // Move requests out of `pending` into their batches (descending
        // index take keeps indices valid).
        let mut slots: Vec<Option<Request>> = pending.into_iter().map(Some).collect();
        for b in batches {
            let reqs: Vec<Request> =
                b.requests.iter().map(|&i| slots[i].take().expect("batch index")).collect();
            let wb = WorkerBatch {
                matrix: b.matrix,
                values_generation: b.values_generation,
                requests: reqs,
            };
            let _ = worker_txs[next_worker % worker_txs.len()].send(wb);
            next_worker += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::test_support::mat;
    use super::*;
    use crate::sparse::Coo;

    #[test]
    fn serves_correct_products() {
        let svc = MatvecService::start(ServiceConfig::default());
        let a = mat(80, 80);
        svc.register("a", a.clone());
        let x: Vec<f64> = (0..80).map(|i| i as f64 * 0.01).collect();
        let y = svc.call("a", x.clone()).unwrap();
        let mut want = vec![0.0; 80];
        a.spmv_into_zeroed(&x, &mut want);
        crate::util::propcheck::assert_close(&y, &want, 1e-12, 1e-12).unwrap();
        let s = svc.stats();
        assert_eq!(s.completed, 1);
        svc.shutdown();
    }

    #[test]
    fn unknown_matrix_fails_cleanly() {
        let svc = MatvecService::start(ServiceConfig::default());
        let err = svc.call("ghost", vec![1.0; 4]).unwrap_err();
        assert!(!err.is_retryable(), "an unknown matrix is a caller bug, not transient");
        assert!(err.to_string().contains("unknown matrix"), "{err}");
        assert_eq!(svc.stats().failed, 1);
    }

    #[test]
    fn wrong_length_fails_cleanly() {
        let svc = MatvecService::start(ServiceConfig::default());
        svc.register("a", mat(50, 81));
        let err = svc.call("a", vec![1.0; 3]).unwrap_err();
        assert!(!err.is_retryable(), "a wrong-length operand is a caller bug, not transient");
        assert!(err.to_string().contains("length"), "{err}");
    }

    #[test]
    fn many_concurrent_requests_all_served() {
        let svc = MatvecService::start(ServiceConfig::default());
        let a = mat(60, 82);
        let b = mat(40, 83);
        svc.register("a", a.clone());
        svc.register("b", b.clone());
        let mut rxs = Vec::new();
        for i in 0..40 {
            let key = if i % 3 == 0 { "b" } else { "a" };
            let n = if key == "a" { 60 } else { 40 };
            let x: Vec<f64> = (0..n).map(|j| (i * j) as f64 * 1e-3).collect();
            rxs.push((key, x.clone(), svc.submit(key, x)));
        }
        for (key, x, rx) in rxs {
            let y = rx.recv().unwrap().unwrap();
            let m = if key == "a" { &a } else { &b };
            let mut want = vec![0.0; m.n];
            m.spmv_into_zeroed(&x, &mut want);
            crate::util::propcheck::assert_close(&y, &want, 1e-12, 1e-12).unwrap();
        }
        let s = svc.stats();
        assert_eq!(s.completed, 40);
        assert!(s.batches >= 2, "should have formed multiple batches");
        assert!(s.mean_latency_us > 0.0);
        svc.shutdown();
    }

    #[test]
    fn plan_built_once_across_workers_and_requests() {
        // Four workers hammering one matrix over the parallel backend
        // must share a single cached plan — the registry analyzes a
        // matrix once, not once per worker × engine.
        let mut cfg = ServiceConfig::default();
        cfg.workers = 4;
        cfg.route.min_parallel_n = 1; // force the parallel path
        cfg.route.threads = 2;
        let svc = MatvecService::start(cfg);
        let a = mat(120, 85);
        svc.register("shared", a.clone());
        let mut want = vec![0.0; 120];
        let x: Vec<f64> = (0..120).map(|i| (i as f64 * 0.01).sin()).collect();
        a.spmv_into_zeroed(&x, &mut want);
        let rxs: Vec<_> = (0..32).map(|_| svc.submit("shared", x.clone())).collect();
        for rx in rxs {
            let y = rx.recv().unwrap().unwrap();
            crate::util::propcheck::assert_close(&y, &want, 1e-11, 1e-11).unwrap();
        }
        let s = svc.stats();
        assert_eq!(s.completed, 32);
        assert_eq!(s.plan_builds, 1, "one matrix must be analyzed exactly once");
        assert!(s.plan_build_seconds >= 0.0);
        // A second matrix costs exactly one more analysis.
        let b = mat(90, 86);
        svc.register("other", b.clone());
        let x2 = vec![1.0; 90];
        let _ = svc.call("other", x2).unwrap();
        assert_eq!(svc.stats().plan_builds, 2);
        svc.shutdown();
    }

    #[test]
    fn partial_batch_flushes_at_the_deadline() {
        // BatchPolicy::max_wait is a *release* deadline: one lone
        // request (far below max_batch) must still be served once the
        // batching window closes — not held until the batch fills.
        let mut cfg = ServiceConfig::default();
        cfg.workers = 1;
        cfg.batch = BatchPolicy {
            max_batch: 64,
            max_wait: std::time::Duration::from_millis(40),
        };
        let svc = MatvecService::start(cfg);
        let a = mat(40, 96);
        svc.register("a", a.clone());
        let x = vec![1.0; 40];
        let t0 = Instant::now();
        let rx = svc.submit("a", x.clone());
        let y = rx
            .recv_timeout(std::time::Duration::from_secs(10))
            .expect("partial batch must be released at the deadline, not held for max_batch")
            .unwrap();
        let waited = t0.elapsed();
        let mut want = vec![0.0; 40];
        a.spmv_into_zeroed(&x, &mut want);
        crate::util::propcheck::assert_close(&y, &want, 1e-12, 1e-12).unwrap();
        assert!(
            waited >= std::time::Duration::from_millis(25),
            "the dispatcher should wait out most of max_wait before releasing, waited {waited:?}"
        );
        let s = svc.stats();
        assert_eq!(s.completed, 1);
        assert_eq!(s.batches, 1, "one partial batch, released by the deadline");
        svc.shutdown();
    }

    #[test]
    fn reordered_serving_recovers_from_a_stale_rcm_artifact() {
        // Regression (review): `update_values` publishes the bumped
        // values generation to the registry *before* re-permuting the
        // shared RCM artifact. A worker that reads the registry in that
        // window must not build — and cache under the new generation —
        // a reordered engine from the stale permuted matrix. The
        // artifact's values-generation stamp is the guard: on mismatch
        // the worker re-permutes its own registry snapshot through the
        // cached ordering. The window is recreated deterministically
        // here by restoring the pre-update artifact after the update
        // has patched it.
        let mut cfg = ServiceConfig::default();
        cfg.workers = 1;
        cfg.route.parallel_kind = EngineKind::Atomic;
        cfg.route.threads = 2;
        cfg.route.min_parallel_n = 1;
        cfg.route.reorder = crate::reorder::ReorderPolicy::Always;
        let svc = MatvecService::start(cfg);
        let a = mat(90, 87);
        svc.register("m", a.clone());
        let x: Vec<f64> = (0..90).map(|i| (i as f64 * 0.11).sin()).collect();
        let _ = svc.call("m", x.clone()).unwrap();
        assert_eq!(svc.stats().rcm_builds, 1, "first reordered serve builds the artifact");
        let stale = lock_unpoisoned(&svc.rcm).get("m@0").cloned().expect("artifact cached");
        assert_eq!(stale.vgen, 0);
        let mut a2 = (*a).clone();
        for v in a2.ad.iter_mut().chain(a2.al.iter_mut()).chain(a2.au.iter_mut()) {
            *v *= 3.0;
        }
        svc.update_values("m", &a2).unwrap();
        // Simulate the lost patch: the registry already carries the new
        // values generation, the artifact still carries the old values.
        lock_unpoisoned(&svc.rcm).insert("m@0".to_string(), stale);
        let y = svc.call("m", x.clone()).unwrap();
        let mut want = vec![0.0; 90];
        a2.spmv_into_zeroed(&x, &mut want);
        crate::util::propcheck::assert_close(&y, &want, 1e-11, 1e-11)
            .expect("the stale artifact must never serve under the new values generation");
        let s = svc.stats();
        assert_eq!(s.rcm_builds, 1, "recovery re-permutes; it never re-runs RCM");
        assert_eq!(
            lock_unpoisoned(&svc.rcm).get("m@0").unwrap().vgen,
            1,
            "the worker publishes the repaired artifact back"
        );
        svc.shutdown();
    }

    #[test]
    fn property_service_matches_sequential() {
        crate::util::propcheck::check(5, |rng| {
            let n = 20 + rng.below(80);
            let a = {
                let coo = Coo::random_structurally_symmetric(n, 2, false, rng);
                Arc::new(Csrc::from_coo(&coo).map_err(|e| e.to_string())?)
            };
            let svc = MatvecService::start(ServiceConfig::default());
            svc.register("m", a.clone());
            for _ in 0..3 {
                let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
                let y = svc.call("m", x.clone())?;
                let mut want = vec![0.0; n];
                a.spmv_into_zeroed(&x, &mut want);
                crate::util::propcheck::assert_close(&y, &want, 1e-11, 1e-11)?;
            }
            svc.shutdown();
            Ok(())
        });
    }
}
