//! Backend routing: which execution engine serves a given matrix.
//!
//! Routing is a pure policy over matrix properties (size, working set,
//! whether an ELL/XLA artifact shape fits) — mirroring the paper's own
//! findings: small matrices don't amortize parallel overhead (§4.2's
//! one-thread shortcut), large ones want the parallel engines; the XLA
//! backend serves the fixed shapes the AOT artifacts were lowered for.

use crate::parallel::{AccumMethod, EngineKind};
use crate::reorder::ReorderPolicy;
use crate::sparse::Csrc;

/// Execution backend for one request.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Backend {
    NativeSequential,
    NativeParallel {
        kind: EngineKind,
        threads: usize,
        /// Serve through the RCM ordering: the worker builds the engine
        /// over the permuted matrix and permutes/un-permutes per
        /// request. Set by policy (`RoutePolicy::reorder == Always`) or
        /// by a tuned decision whose winner was a reordered candidate.
        reorder: bool,
    },
    /// AOT-compiled artifact (by manifest name).
    Xla { artifact: String },
}

/// Routing policy knobs.
#[derive(Clone, Debug)]
pub struct RoutePolicy {
    /// Below this row count the sequential sweep wins (fork-join cost).
    pub min_parallel_n: usize,
    /// Concrete engine for the parallel path, or [`EngineKind::Auto`]
    /// to let the tuner resolve it per matrix at registration time.
    /// Auto's fallback order is: persisted decision-cache hit → learned
    /// cost model (`ServiceConfig::model`, when configured) →
    /// hand-written heuristic — with measured trials replacing all
    /// three whenever the registration brings a non-zero
    /// `ServiceConfig::tune_budget`.
    pub parallel_kind: EngineKind,
    /// Thread *budget*. With a concrete `parallel_kind` this is the
    /// thread count engines run at; with [`EngineKind::Auto`] plus
    /// `sweep_threads` it caps the tuner's ladder and the decision picks
    /// the actual count per matrix.
    pub threads: usize,
    /// With `parallel_kind == Auto`: also sweep the thread-count ladder
    /// (1, 2, 4, … up to `threads`, [`crate::tuner::thread_ladder`]) so
    /// the decision picks `nthreads` per matrix instead of inheriting
    /// `threads` blindly — the paper's §4 curves show several matrices
    /// peak below the core count.
    pub sweep_threads: bool,
    /// Prefer the XLA backend when an artifact shape fits.
    pub prefer_xla: bool,
    /// Artifact shapes available: (name, n_pad, w).
    pub xla_shapes: Vec<(String, usize, usize)>,
    /// Bandwidth-aware RCM reordering ([`crate::reorder`]):
    /// `Never` serves matrices as given; `Measure` (with
    /// `parallel_kind == Auto`) lets the tuner race reordered candidates
    /// against plain ones per matrix; `Always` serves every parallel
    /// request through the RCM ordering.
    pub reorder: ReorderPolicy,
}

impl Default for RoutePolicy {
    fn default() -> Self {
        RoutePolicy {
            min_parallel_n: 4096,
            parallel_kind: EngineKind::LocalBuffers(AccumMethod::Effective),
            threads: 4,
            sweep_threads: false,
            prefer_xla: false,
            xla_shapes: Vec::new(),
            reorder: ReorderPolicy::Never,
        }
    }
}

pub struct Router {
    pub policy: RoutePolicy,
}

impl Router {
    pub fn new(policy: RoutePolicy) -> Router {
        Router { policy }
    }

    /// Choose the backend for a matrix.
    pub fn route(&self, a: &Csrc) -> Backend {
        if self.policy.prefer_xla {
            if let Some((name, _, _)) = self
                .policy
                .xla_shapes
                .iter()
                .find(|(_, n_pad, w)| a.n <= *n_pad && a.max_row_width() <= *w)
            {
                return Backend::Xla { artifact: name.clone() };
            }
        }
        if a.n < self.policy.min_parallel_n {
            Backend::NativeSequential
        } else {
            Backend::NativeParallel {
                kind: self.policy.parallel_kind,
                threads: self.policy.threads,
                // `Measure` is meaningful only through the tuner (Auto),
                // where the worker substitutes the decision's flag.
                reorder: self.policy.reorder == ReorderPolicy::Always,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::Coo;
    use crate::util::Rng;

    fn mat(n: usize) -> Csrc {
        let mut rng = Rng::new(70);
        Csrc::from_coo(&Coo::random_structurally_symmetric(n, 3, false, &mut rng)).unwrap()
    }

    #[test]
    fn small_matrices_run_sequential() {
        let r = Router::new(RoutePolicy::default());
        assert_eq!(r.route(&mat(100)), Backend::NativeSequential);
    }

    #[test]
    fn large_matrices_run_parallel() {
        let r = Router::new(RoutePolicy { min_parallel_n: 50, ..Default::default() });
        match r.route(&mat(100)) {
            Backend::NativeParallel { threads, .. } => assert_eq!(threads, 4),
            other => panic!("expected parallel, got {other:?}"),
        }
    }

    #[test]
    fn xla_routes_only_fitting_shapes() {
        let policy = RoutePolicy {
            prefer_xla: true,
            xla_shapes: vec![("spmv_n256_w8".into(), 256, 8)],
            ..Default::default()
        };
        let r = Router::new(policy);
        // n=100 with npr<=3 fits 256x8.
        assert_eq!(r.route(&mat(100)), Backend::Xla { artifact: "spmv_n256_w8".into() });
        // n=500 does not fit the 256-row artifact.
        assert_eq!(r.route(&mat(500)), Backend::NativeSequential);
    }
}
