//! Shard-local service statistics: typed counter handles over a
//! [`MetricsRegistry`] plus the [`ServiceStats`] snapshot.
//!
//! Every [`MatvecService`](super::MatvecService) — and therefore every
//! shard of a [`ShardedMatvecService`](super::ShardedMatvecService) —
//! owns one [`Counters`]: its own registry, its own atomics, its own
//! latency histogram. Nothing here is process-global, which is what
//! makes per-shard metrics labeling possible (the sharded front renders
//! each shard's registry with an injected `shard="i"` label).

use crate::obs::{Counter, MetricsRegistry};
use std::sync::{Arc, Mutex};

/// Auto-route choice log. Genuinely structured (ordered key/value
/// pairs), so it lives behind a small mutex next to the registry's
/// scalar atomics — nothing on the request path touches it.
#[derive(Default)]
pub(crate) struct ChoiceLog {
    pub(crate) auto_choices: Vec<(String, String)>,
    pub(crate) chosen_threads: Vec<(String, usize)>,
}

/// Shared mutable service state: typed handles into the service's
/// [`MetricsRegistry`]. Every scalar [`ServiceStats`] reports lives in
/// a registry atomic, so a `stats()` snapshot and a Prometheus scrape
/// read the *same* cells — the old `Mutex<Stats>` could not serve a
/// scrape without cloning, and a lock-free copy of it could tear.
pub(crate) struct Counters {
    pub(crate) obs: Arc<MetricsRegistry>,
    pub(crate) submitted: Counter,
    pub(crate) completed: Counter,
    pub(crate) failed: Counter,
    pub(crate) batches: Counter,
    pub(crate) tunes: Counter,
    /// Nanoseconds — registry counters are integers; `stats()` converts
    /// back to seconds.
    pub(crate) tune_ns: Counter,
    pub(crate) engines_evicted: Counter,
    pub(crate) retunes: Counter,
    pub(crate) drift_events: Counter,
    pub(crate) model_hits: Counter,
    pub(crate) model_fallbacks: Counter,
    pub(crate) coalesced_products: Counter,
    pub(crate) coalesced_requests: Counter,
    pub(crate) rcm_builds: Counter,
    pub(crate) panics_caught: Counter,
    pub(crate) worker_restarts: Counter,
    pub(crate) value_updates: Counter,
    pub(crate) assembly_atomic: Counter,
    pub(crate) assembly_colored: Counter,
    pub(crate) choices: Mutex<ChoiceLog>,
}

impl Counters {
    pub(crate) fn new(obs: Arc<MetricsRegistry>) -> Counters {
        Counters {
            submitted: obs.counter("csrc_requests_submitted_total"),
            completed: obs.counter("csrc_requests_completed_total"),
            failed: obs.counter("csrc_requests_failed_total"),
            batches: obs.counter("csrc_batches_total"),
            tunes: obs.counter("csrc_tunes_total"),
            tune_ns: obs.counter("csrc_tune_ns_total"),
            engines_evicted: obs.counter("csrc_engines_evicted_total"),
            retunes: obs.counter("csrc_retunes_total"),
            drift_events: obs.counter("csrc_drift_events_total"),
            model_hits: obs.counter("csrc_model_hits_total"),
            model_fallbacks: obs.counter("csrc_model_fallbacks_total"),
            coalesced_products: obs.counter("csrc_coalesced_products_total"),
            coalesced_requests: obs.counter("csrc_coalesced_requests_total"),
            rcm_builds: obs.counter("csrc_rcm_builds_total"),
            panics_caught: obs.counter("csrc_panics_caught_total"),
            worker_restarts: obs.counter("csrc_worker_restarts_total"),
            value_updates: obs.counter("csrc_value_updates_total"),
            assembly_atomic: obs.counter("csrc_assembly_atomic_total"),
            assembly_colored: obs.counter("csrc_assembly_colored_total"),
            choices: Mutex::new(ChoiceLog::default()),
            obs,
        }
    }

    pub(crate) fn add_tune_seconds(&self, s: f64) {
        self.tune_ns.add((s * 1e9) as u64);
    }
}

/// Observable service counters: a typed snapshot over the service's
/// [`MetricsRegistry`] atomics, taken in an order that preserves
/// `completed + failed <= submitted` even while workers are mid-batch.
#[derive(Clone, Debug)]
pub struct ServiceStats {
    pub submitted: u64,
    pub completed: u64,
    pub failed: u64,
    pub batches: u64,
    pub mean_latency_us: f64,
    pub p99_latency_us: f64,
    /// How many scheduling plans were built (cache misses) — with N
    /// workers all serving one matrix this stays 1, not N.
    pub plan_builds: u64,
    /// Total wall-clock seconds spent in plan analysis.
    pub plan_build_seconds: f64,
    /// Measured tuning runs performed for `EngineKind::Auto`
    /// registrations (decision-cache hits do not count).
    pub tunes: u64,
    /// Wall-clock seconds spent inside those tuning runs.
    pub tune_seconds: f64,
    /// Autotuner decisions answered from the (possibly persisted)
    /// decision cache with zero new trials.
    pub decision_hits: u64,
    /// Engines dropped from worker caches by the LRU eviction policy.
    pub engines_evicted: u64,
    /// (matrix key, resolved engine label) per Auto registration, in
    /// registration order.
    pub auto_choices: Vec<(String, String)>,
    /// (matrix key, decision thread count) per Auto registration — with
    /// `RoutePolicy::sweep_threads` this is the swept pick, which may
    /// sit below `RoutePolicy::threads`.
    pub chosen_threads: Vec<(String, usize)>,
    /// Background re-tunes completed after drift detection.
    pub retunes: u64,
    /// Batches whose rate EWMA sat below the drift threshold.
    pub drift_events: u64,
    /// Cold-start Auto registrations answered by the learned cost model
    /// (zero-budget predictions; decision-cache hits count in
    /// `decision_hits`, not here).
    pub model_hits: u64,
    /// Cold-start Auto registrations that fell back to the hand-written
    /// heuristic — no model configured, or it declined to predict.
    pub model_fallbacks: u64,
    /// Blocked (`spmv_multi`) products run in place of serial per-request
    /// products — one per coalesced panel.
    pub coalesced_products: u64,
    /// Requests served through those panels (`Σ` panel widths).
    pub coalesced_requests: u64,
    /// RCM orderings computed for reordered serving. With N workers all
    /// serving one key through the shared registry this stays 1, not N.
    pub rcm_builds: u64,
    /// Worker/retuner panics caught by the per-batch `catch_unwind`
    /// isolation — each one failed over its batch instead of killing the
    /// thread silently.
    pub panics_caught: u64,
    /// Crashed worker/retuner threads the supervisor respawned (capped
    /// exponential backoff between attempts).
    pub worker_restarts: u64,
    /// In-place `update_values` calls applied: same pattern, new values,
    /// every pattern-derived artifact (plan, RCM, decision) kept.
    pub value_updates: u64,
    /// Parallel re-assemblies recorded against this service, by variant
    /// (atomic scatter vs. colored element batches).
    pub assembly_atomic: u64,
    pub assembly_colored: u64,
}

#[cfg(test)]
mod tests {
    use super::super::test_support::mat;
    use super::super::{MatvecService, ServiceConfig};
    use std::sync::Arc;

    #[test]
    fn stats_snapshot_stays_consistent_under_concurrent_serving() {
        // Satellite (ISSUE 7): ServiceStats is now a snapshot over the
        // registry's atomics. Snapshots taken while callers hammer the
        // service must never tear — `completed + failed > submitted`
        // was possible when the scrape-side copy raced the worker-side
        // multi-field update — and must be monotone between reads.
        let svc = MatvecService::start(ServiceConfig::default());
        let a = mat(60, 93);
        svc.register("m", a.clone());
        let x: Vec<f64> = (0..60).map(|i| (i as f64 * 0.05).sin()).collect();
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        std::thread::scope(|scope| {
            for _ in 0..2 {
                let svc = &svc;
                let x = x.clone();
                let stop = stop.clone();
                scope.spawn(move || {
                    while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                        svc.call("m", x.clone()).unwrap();
                    }
                });
            }
            let mut last_completed = 0u64;
            for _ in 0..300 {
                let s = svc.stats();
                assert!(
                    s.completed + s.failed <= s.submitted,
                    "torn snapshot: completed {} + failed {} > submitted {}",
                    s.completed,
                    s.failed,
                    s.submitted
                );
                assert!(s.completed >= last_completed, "completed went backwards");
                last_completed = s.completed;
            }
            stop.store(true, std::sync::atomic::Ordering::Relaxed);
        });
        // Quiesced (every call() returned): the books balance exactly.
        let s = svc.stats();
        assert_eq!(s.completed + s.failed, s.submitted);
        assert!(s.completed > 0);
        assert!(s.mean_latency_us > 0.0);
        svc.shutdown();
    }

    #[test]
    fn metrics_registry_scrape_matches_service_stats() {
        // Tentpole acceptance (ISSUE 7): the Prometheus rendering and
        // stats() read the same registry cells — the scrape must show
        // the per-engine product family and the same request counts.
        let mut cfg = ServiceConfig::default();
        cfg.workers = 1;
        cfg.route.min_parallel_n = 1; // force the parallel path
        cfg.route.threads = 2;
        let svc = MatvecService::start(cfg);
        let a = mat(80, 94);
        svc.register("m", a.clone());
        let x = vec![1.0; 80];
        for _ in 0..3 {
            svc.call("m", x.clone()).unwrap();
        }
        let s = svc.stats();
        assert_eq!(s.completed, 3);
        let text = svc.metrics_registry().render_prometheus();
        assert!(text.contains("csrc_requests_submitted_total 3"), "{text}");
        assert!(text.contains("csrc_requests_completed_total 3"), "{text}");
        assert!(
            text.contains("csrc_engine_products_total{engine="),
            "per-engine family must be exposed:\n{text}"
        );
        assert!(text.contains("matrix=\"m\""), "{text}");
        assert!(text.contains("csrc_request_latency_us_count 3"), "{text}");
        // The scrape folds in the process-wide phase totals.
        assert!(text.contains("csrc_phase_seconds_total{phase=\"serve\"}"), "{text}");
        svc.shutdown();
    }
}
