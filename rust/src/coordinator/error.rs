//! Typed error taxonomy for the serving stack.
//!
//! Every failure a caller can see from [`MatvecService::call`] or the
//! sharded front is either [`ServiceError::Retryable`] — a transient
//! condition (full queue, missed deadline, crashed worker) carrying a
//! suggested back-off — or [`ServiceError::Fatal`] — a caller bug
//! (unknown matrix, wrong operand length) or shutdown, where retrying
//! can never help. The front's retry loop, the circuit breakers, and
//! the CLI chaos workload all branch on this split instead of string
//! matching.
//!
//! [`MatvecService::call`]: super::MatvecService::call

use std::fmt;
use std::time::Duration;

/// Why a retryable rejection happened — carried inside
/// [`ServiceError::Retryable`] and used as the `reason` label of the
/// `csrc_shard_rejections_total{shard,reason}` counter family.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RejectReason {
    /// Back-pressure: the shard's bounded queue could not absorb the
    /// product even after the front's jittered retries.
    QueueFull { shard: usize, depth: usize, capacity: usize },
    /// The shard failed to answer within the configured deadline.
    DeadlineExceeded { shard: usize, deadline: Duration },
    /// A worker thread panicked mid-batch; the panic was caught, the
    /// request failed over, and the supervisor is restarting the worker.
    WorkerCrashed { shard: Option<usize> },
    /// The product kept racing `register`/`update_values` mutations of
    /// its key: every attempt observed the decomposition mid-swap (the
    /// front retries internally before giving up). Retry once the
    /// mutation storm subsides.
    ConcurrentUpdate,
}

impl RejectReason {
    /// Stable label for the `reason` dimension of rejection counters.
    pub fn label(&self) -> &'static str {
        match self {
            RejectReason::QueueFull { .. } => "queue-full",
            RejectReason::DeadlineExceeded { .. } => "deadline-exceeded",
            RejectReason::WorkerCrashed { .. } => "worker-crashed",
            RejectReason::ConcurrentUpdate => "concurrent-update",
        }
    }

    /// Which shard rejected, when known.
    pub fn shard(&self) -> Option<usize> {
        match self {
            RejectReason::QueueFull { shard, .. } => Some(*shard),
            RejectReason::DeadlineExceeded { shard, .. } => Some(*shard),
            RejectReason::WorkerCrashed { shard } => *shard,
            RejectReason::ConcurrentUpdate => None,
        }
    }
}

/// What a serving call can fail with.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServiceError {
    /// Transient: back off for `after` and retry the same call.
    Retryable { reason: RejectReason, after: Duration },
    /// Permanent: retrying can never succeed (caller bug or shutdown).
    Fatal(String),
}

impl ServiceError {
    /// Shorthand for a permanent error.
    pub fn fatal(msg: impl Into<String>) -> ServiceError {
        ServiceError::Fatal(msg.into())
    }

    /// Is retrying this call worthwhile?
    pub fn is_retryable(&self) -> bool {
        matches!(self, ServiceError::Retryable { .. })
    }

    /// Suggested back-off before retrying, if retryable.
    pub fn retry_after(&self) -> Option<Duration> {
        match self {
            ServiceError::Retryable { after, .. } => Some(*after),
            ServiceError::Fatal(_) => None,
        }
    }

    /// The rejection reason, if retryable.
    pub fn reason(&self) -> Option<&RejectReason> {
        match self {
            ServiceError::Retryable { reason, .. } => Some(reason),
            ServiceError::Fatal(_) => None,
        }
    }
}

impl fmt::Display for ServiceError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServiceError::Retryable { reason, after } => match reason {
                RejectReason::QueueFull { shard, depth, capacity } => write!(
                    f,
                    "shard {shard} queue full ({depth} in flight, capacity {capacity}); \
                     retry after {after:?}"
                ),
                RejectReason::DeadlineExceeded { shard, deadline } => write!(
                    f,
                    "shard {shard} missed the {deadline:?} deadline; retry after {after:?}"
                ),
                RejectReason::WorkerCrashed { shard: Some(s) } => write!(
                    f,
                    "shard {s}: worker crashed mid-batch (panic caught); retry after {after:?}"
                ),
                RejectReason::WorkerCrashed { shard: None } => {
                    write!(f, "worker crashed mid-batch (panic caught); retry after {after:?}")
                }
                RejectReason::ConcurrentUpdate => write!(
                    f,
                    "product raced concurrent register/update_values mutations; \
                     retry after {after:?}"
                ),
            },
            ServiceError::Fatal(msg) => f.write_str(msg),
        }
    }
}

impl std::error::Error for ServiceError {}

impl From<ServiceError> for String {
    fn from(e: ServiceError) -> String {
        e.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_keeps_the_grep_surface() {
        let qf = ServiceError::Retryable {
            reason: RejectReason::QueueFull { shard: 2, depth: 9, capacity: 8 },
            after: Duration::from_millis(1),
        };
        assert!(qf.to_string().contains("queue full"));
        assert!(qf.to_string().contains("shard 2"));
        assert!(qf.to_string().contains("capacity 8"));
        let dl = ServiceError::Retryable {
            reason: RejectReason::DeadlineExceeded {
                shard: 0,
                deadline: Duration::from_millis(40),
            },
            after: Duration::from_millis(250),
        };
        assert!(dl.to_string().contains("missed the"));
        assert!(dl.to_string().contains("deadline"));
        let fatal = ServiceError::fatal("unknown matrix \"a\"");
        assert!(fatal.to_string().contains("unknown matrix"));
    }

    #[test]
    fn taxonomy_helpers() {
        let e = ServiceError::Retryable {
            reason: RejectReason::WorkerCrashed { shard: None },
            after: Duration::from_millis(10),
        };
        assert!(e.is_retryable());
        assert_eq!(e.retry_after(), Some(Duration::from_millis(10)));
        assert_eq!(e.reason().unwrap().label(), "worker-crashed");
        assert_eq!(e.reason().unwrap().shard(), None);
        let f = ServiceError::fatal("nope");
        assert!(!f.is_retryable());
        assert_eq!(f.retry_after(), None);
        assert!(f.reason().is_none());
    }
}
