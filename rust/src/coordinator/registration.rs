//! Shard-local registration state: the matrix registry, the resolved
//! Auto-route table, drift-tracking state, the shared RCM artifact
//! registry, and the registration-time Auto resolution itself.
//!
//! Everything here is owned per [`MatvecService`](super::MatvecService)
//! — i.e. per shard when serving through a
//! [`ShardedMatvecService`](super::ShardedMatvecService): each shard
//! resolves, caches, and drift-tracks its own row-block independently.

use crate::parallel::EngineKind;
use crate::plan::{PlanBuilder, PlanCache};
use crate::reorder::Permutation;
use crate::sparse::{Csrc, SpmvKernel};
use crate::tuner::{self, DecisionCache, TrialBudget};
use std::collections::HashMap;
use std::sync::Arc;

use super::router::RoutePolicy;

/// Superseded value generations retained per registry entry. Requests
/// are stamped with the values generation at submit time; a batch whose
/// stamp predates an `update_values` is served from the retained
/// snapshot it observed, so the batcher's generation split has teeth:
/// pre-update submissions compute with pre-update values. The bound
/// keeps replaced values from accumulating — in-flight requests live
/// for one dispatch window (milliseconds), so four generations is
/// already generous; anything older falls back to the current values.
pub(crate) const VALUES_HISTORY_CAP: usize = 4;

/// Registry value: the matrix plus a per-key *structural* generation
/// counter and a *values* generation counter. Worker-side caches
/// (engines, plans) key on `key@generation`, so a replaced matrix can
/// never be served by state built for its predecessor — stale engines
/// become unreachable instead of unsound. The values generation bumps
/// on [`super::MatvecService::update_values`] (same pattern, new
/// values): pattern-derived artifacts (plans, coloring, RCM ordering,
/// tuned decision) survive it, while engines — which bake the values
/// into their buffers — and batch panels key on it. Superseded values
/// stay reachable through `history` ([`VALUES_HISTORY_CAP`]) so a
/// batch stamped before an update serves the values its requests saw.
#[derive(Clone)]
pub(crate) struct RegEntry {
    pub(crate) a: Arc<Csrc>,
    pub(crate) generation: u64,
    pub(crate) vgen: u64,
    /// Retired `(values_generation, matrix)` snapshots, oldest first.
    pub(crate) history: Vec<(u64, Arc<Csrc>)>,
}

impl RegEntry {
    pub(crate) fn new(a: Arc<Csrc>, generation: u64) -> RegEntry {
        RegEntry { a, generation, vgen: 0, history: Vec::new() }
    }

    /// Swap in `next` as the current values, retiring the old matrix
    /// into the bounded history under the outgoing values generation.
    pub(crate) fn retire(&mut self, next: Arc<Csrc>) {
        let old = std::mem::replace(&mut self.a, next);
        self.history.push((self.vgen, old));
        if self.history.len() > VALUES_HISTORY_CAP {
            self.history.remove(0);
        }
        self.vgen += 1;
    }

    /// The matrix carrying values generation `vgen`, if still retained.
    pub(crate) fn values_at(&self, vgen: u64) -> Option<Arc<Csrc>> {
        if vgen == self.vgen {
            return Some(self.a.clone());
        }
        self.history.iter().rev().find(|(v, _)| *v == vgen).map(|(_, a)| a.clone())
    }
}

pub(crate) type Registry = HashMap<String, RegEntry>;

/// One shared RCM artifact for reordered serving: the permutation, the
/// permuted matrix, and the values generation the permuted matrix was
/// built from. The stamp is what makes `update_values` safe against
/// racing workers: an update publishes the new registry entry first and
/// patches this artifact after, so a worker that observes the new
/// values generation but the old artifact sees a stamp mismatch and
/// re-permutes from its own registry snapshot (`a.permuted(&perm)` —
/// no new RCM computation, `rcm_builds` stays put) instead of caching
/// an engine with stale values under the new generation.
#[derive(Clone)]
pub(crate) struct RcmEntry {
    pub(crate) pa: Arc<Csrc>,
    pub(crate) perm: Arc<Permutation>,
    pub(crate) vgen: u64,
}

/// Shared RCM artifacts for reordered serving, keyed by
/// `key@generation`. Shared across workers (like the plan cache) so a
/// matrix served reordered by N workers is permuted once, not once per
/// worker; entries of retired generations are collected by `register()`
/// on replacement.
pub(crate) type RcmRegistry = HashMap<String, RcmEntry>;

/// What an Auto registration resolved to — everything a worker needs to
/// build the engine and to judge rate drift.
#[derive(Clone, Copy, Debug)]
pub(crate) struct ResolvedAuto {
    pub(crate) kind: EngineKind,
    /// The winner ran through the RCM ordering: serve via the permuted
    /// matrix with per-request permute/un-permute.
    pub(crate) reorder: bool,
    /// The decision's thread count (the swept pick, not necessarily
    /// `RoutePolicy::threads`).
    pub(crate) nthreads: usize,
    /// The decision's recorded rate (0 when unmeasured).
    pub(crate) mflops: f64,
    /// Served-rate baseline ([`tuner::Decision::served_mflops`]): the
    /// per-request EWMA recorded after a drift re-tune. When > 0, drift
    /// is judged against it instead of the optimistic trial rate.
    pub(crate) served_mflops: f64,
    /// The work units the decision's rate was normalized by
    /// (`Features::work_flops`). The drift EWMA must use the *same*
    /// normalization — `Csrc::flops()` counts the symmetric kernel's
    /// flops differently, which would skew the comparison by up to 2×.
    pub(crate) work_flops: usize,
    pub(crate) measured: bool,
    /// The decision-cache key, so a worker can write the served
    /// baseline back into the persisted entry.
    pub(crate) fingerprint: u64,
    pub(crate) max_threads: usize,
    /// The decision's tuned panel width: same-matrix requests in one
    /// batch coalesce into `spmv_multi` panels this wide (1 = the
    /// blocked product lost its own tuning race, serve serially).
    pub(crate) block_k: usize,
}

impl ResolvedAuto {
    pub(crate) fn from_decision(d: &tuner::Decision) -> ResolvedAuto {
        ResolvedAuto {
            kind: d.kind,
            reorder: d.reorder,
            nthreads: d.nthreads,
            mflops: d.mflops,
            served_mflops: d.served_mflops,
            work_flops: d.features.work_flops,
            measured: d.measured,
            fingerprint: d.fingerprint,
            max_threads: d.max_threads,
            block_k: d.block_k.max(1),
        }
    }
}

/// Per-key drift tracking state (keyed by `key@generation`).
#[derive(Clone, Copy, Debug, Default)]
pub(crate) struct DriftState {
    pub(crate) ewma_mflops: f64,
    pub(crate) batches: u64,
    /// A re-tune has been queued and not yet completed — don't queue
    /// another for the same key × generation.
    pub(crate) retune_pending: bool,
    /// Set by the re-tuner when it publishes an upgraded decision: the
    /// next `drift_min_batches` batches *calibrate* — their EWMA is
    /// recorded as the entry's served baseline instead of being judged
    /// against the fresh (warm, optimistic) trial rate. Without this a
    /// decision whose trial rate sits far above serving reality would
    /// re-trigger after every re-tune: a storm.
    pub(crate) calibrating: bool,
    /// The baseline the calibration window recorded (0 = none yet).
    /// Judgement reads it here, under the same lock, rather than from
    /// the batch's `ResolvedAuto` snapshot: a second worker whose
    /// snapshot predates the calibration write must not re-judge
    /// against the optimistic trial rate and queue a spurious re-tune.
    pub(crate) served_baseline: f64,
}

/// Does `k` name a generation of exactly the key whose prefix is
/// `"key@"` — i.e. `key@<digits>`? An all-digit suffix can only be a
/// generation stamped by `register()`; anything else (e.g. `key@b@0`)
/// belongs to a *different* user key that happens to contain '@'.
pub(crate) fn is_generation_of(k: &str, prefix: &str) -> bool {
    k.starts_with(prefix)
        && k.len() > prefix.len()
        && k[prefix.len()..].bytes().all(|b| b.is_ascii_digit())
}

/// Everything registration-time Auto resolution reads — borrowed from
/// the service so the resolution logic lives here, shard-local, instead
/// of inside the service monolith.
pub(crate) struct ResolverCtx<'a> {
    pub(crate) plans: &'a PlanCache,
    pub(crate) route: &'a RoutePolicy,
    pub(crate) budget: &'a TrialBudget,
    pub(crate) decisions: &'a DecisionCache,
    pub(crate) model: Option<&'a tuner::CostModel>,
}

/// Resolve an Auto registration to a concrete decision, off the request
/// path. With `route.sweep_threads` the tuner races the thread ladder
/// (and losing rungs' plans — plain and `#rcm` — are invalidated);
/// otherwise it tunes at the route's fixed thread count. Returns the
/// decision and whether it was a decision-cache hit.
pub(crate) fn resolve_auto(
    ctx: &ResolverCtx<'_>,
    cache_key: &str,
    kernel: &Arc<dyn SpmvKernel>,
) -> (tuner::Decision, bool) {
    let threads = ctx.route.threads.max(1);
    if ctx.route.sweep_threads {
        let ladder = tuner::thread_ladder(threads);
        let mut plan_for = tuner::cached_plan_provider(ctx.plans, cache_key, kernel);
        let r = tuner::resolve_swept_with_model(
            kernel,
            &ladder,
            ctx.budget,
            ctx.decisions,
            &mut plan_for,
            ctx.route.reorder,
            ctx.model,
        );
        // Only the winning rung's analysis stays alive — for the plain
        // plans and any reordered (`#rcm`) plans the workers may have
        // built at losing thread counts.
        ctx.plans.invalidate_other_threads(cache_key, r.0.nthreads);
        ctx.plans.invalidate_other_threads(&format!("{cache_key}#rcm"), r.0.nthreads);
        r
    } else {
        let plan = ctx.plans.get_or_build(
            cache_key,
            kernel.as_ref(),
            PlanBuilder::new(threads).with_pieces(tuner::required_pieces(threads)),
        );
        tuner::resolve_with_model(
            kernel,
            &plan,
            ctx.budget,
            ctx.decisions,
            ctx.route.reorder,
            ctx.model,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::super::test_support::mat;
    use super::super::{MatvecService, ServiceConfig};
    use super::*;
    use crate::reorder;
    use crate::sparse::Coo;
    use crate::util::Rng;

    #[test]
    fn resolved_sweep_matches_generations_exactly() {
        // Re-registering "a" must not drop the Auto decision of a
        // different live key that merely starts with "a@".
        assert!(is_generation_of("a@0", "a@"));
        assert!(is_generation_of("a@12", "a@"));
        assert!(!is_generation_of("a@b@0", "a@"));
        assert!(!is_generation_of("a@", "a@"));
        assert!(!is_generation_of("ab@0", "a@"));
    }

    #[test]
    fn auto_routing_tunes_once_and_persists_decisions() {
        let dir = std::env::temp_dir().join(format!("csrc_auto_svc_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut cfg = ServiceConfig::default();
        cfg.route.parallel_kind = EngineKind::Auto;
        cfg.route.min_parallel_n = 1; // force the parallel (Auto) path
        cfg.route.threads = 2;
        cfg.tune_budget = TrialBudget::smoke();
        cfg.decision_cache = Some(dir.join("decisions.json"));
        let a = mat(150, 89);
        let x: Vec<f64> = (0..150).map(|i| (i as f64 * 0.01).sin()).collect();
        let mut want = vec![0.0; 150];
        a.spmv_into_zeroed(&x, &mut want);

        let svc = MatvecService::start(cfg.clone());
        svc.register("m", a.clone());
        let y = svc.call("m", x.clone()).unwrap();
        crate::util::propcheck::assert_close(&y, &want, 1e-11, 1e-11).unwrap();
        let s = svc.stats();
        assert_eq!(s.tunes, 1, "first Auto registration runs measured trials");
        assert!(s.tune_seconds > 0.0);
        assert_eq!(s.auto_choices.len(), 1);
        let (key, label) = &s.auto_choices[0];
        assert_eq!(key, "m");
        let resolved = EngineKind::parse(label).expect("resolved label parses");
        assert_ne!(resolved, EngineKind::Auto, "Auto must resolve to a concrete engine");
        // Registering the same structure under another key: decision
        // cache hit, zero new trials.
        svc.register("m-again", a.clone());
        let s = svc.stats();
        assert_eq!(s.tunes, 1, "same structure must not re-tune");
        assert!(s.decision_hits >= 1);
        svc.shutdown();

        // A restarted service on the same persisted cache re-tunes
        // nothing: zero trials, decision read from disk.
        let svc2 = MatvecService::start(cfg);
        svc2.register("m", a.clone());
        let y2 = svc2.call("m", x).unwrap();
        crate::util::propcheck::assert_close(&y2, &want, 1e-11, 1e-11).unwrap();
        let s2 = svc2.stats();
        assert_eq!(s2.tunes, 0, "restart must hit the persisted decision cache");
        assert!(s2.decision_hits >= 1);
        assert_eq!(s2.auto_choices[0].1, *label, "persisted decision picks the same engine");
        svc2.shutdown();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn sweep_threads_resolves_engine_and_thread_count() {
        let mut cfg = ServiceConfig::default();
        cfg.route.parallel_kind = EngineKind::Auto;
        cfg.route.min_parallel_n = 1; // force the parallel (Auto) path
        cfg.route.threads = 2;
        cfg.route.sweep_threads = true;
        cfg.tune_budget = TrialBudget::smoke();
        let svc = MatvecService::start(cfg);
        let a = mat(150, 94);
        svc.register("m", a.clone());
        let s = svc.stats();
        assert_eq!(s.tunes, 1, "first Auto registration runs the sweep");
        assert_eq!(s.chosen_threads.len(), 1);
        let (key, p) = &s.chosen_threads[0];
        assert_eq!(key, "m");
        assert!(*p == 1 || *p == 2, "thread count must come from the ladder, got {p}");
        // Serving works at the swept thread count.
        let x: Vec<f64> = (0..150).map(|i| (i as f64 * 0.01).sin()).collect();
        let y = svc.call("m", x.clone()).unwrap();
        let mut want = vec![0.0; 150];
        a.spmv_into_zeroed(&x, &mut want);
        crate::util::propcheck::assert_close(&y, &want, 1e-11, 1e-11).unwrap();
        // Same structure under a new key: the swept decision is served
        // from the cache — no second sweep, same thread pick.
        svc.register("m2", a.clone());
        let s = svc.stats();
        assert_eq!(s.tunes, 1, "same structure must not re-sweep");
        assert!(s.decision_hits >= 1);
        assert_eq!(s.chosen_threads[1].1, s.chosen_threads[0].1);
        svc.shutdown();
    }

    #[test]
    fn zero_budget_auto_answers_from_model_when_supplied() {
        // ISSUE 5 acceptance at the service level: with an empty
        // decision cache and a zero trial budget, registration answers
        // from the supplied model (ServiceStats::model_hits), and from
        // the heuristic only when none is configured (model_fallbacks).
        let dir = std::env::temp_dir().join(format!("csrc_model_svc_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let model_path = dir.join("model.json");
        let a = mat(200, 400);
        // Train a tiny constant model that crowns `colorful` — a pick
        // the registration must echo verbatim if it consulted the model
        // (the heuristic would choose a local-buffers engine here).
        {
            let kernel: Arc<dyn SpmvKernel> = a.clone();
            let plan = crate::plan::PlanBuilder::all(2).build(kernel.as_ref());
            let features = tuner::Features::extract(kernel.as_ref(), &plan);
            let rows: Vec<tuner::CorpusRow> = (0..3u64)
                .map(|i| tuner::CorpusRow {
                    fingerprint: i,
                    max_threads: 2,
                    features: features.clone(),
                    kind: EngineKind::Colorful,
                    reordered: false,
                    nthreads: 2,
                    rung_rates: vec![(2, 500.0)],
                    block_rates: Vec::new(),
                })
                .collect();
            tuner::CostModel::train(&rows).unwrap().save(&model_path).unwrap();
        }
        let mut cfg = ServiceConfig::default();
        cfg.workers = 1;
        cfg.route.parallel_kind = EngineKind::Auto;
        cfg.route.min_parallel_n = 1;
        cfg.route.threads = 2;
        cfg.tune_budget = TrialBudget::zero();
        cfg.model = Some(model_path);
        let svc = MatvecService::start(cfg.clone());
        svc.register("m", a.clone());
        let s = svc.stats();
        assert_eq!(s.model_hits, 1, "the model must answer the cold start");
        assert_eq!(s.model_fallbacks, 0);
        assert_eq!(s.auto_choices[0].1, "colorful", "the planted model pick");
        // Serving runs correctly on the predicted engine.
        let x: Vec<f64> = (0..200).map(|i| (i as f64 * 0.01).sin()).collect();
        let mut want = vec![0.0; 200];
        a.spmv_into_zeroed(&x, &mut want);
        let y = svc.call("m", x.clone()).unwrap();
        crate::util::propcheck::assert_close(&y, &want, 1e-11, 1e-11).unwrap();
        svc.shutdown();
        // The same config without a model falls back to the heuristic.
        cfg.model = None;
        let svc2 = MatvecService::start(cfg);
        svc2.register("m", a.clone());
        let s2 = svc2.stats();
        assert_eq!(s2.model_hits, 0);
        assert_eq!(s2.model_fallbacks, 1, "no model: the heuristic answers");
        assert_ne!(s2.auto_choices[0].1, "colorful", "the heuristic picks differently here");
        svc2.shutdown();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn auto_with_reorder_measure_resolves_and_serves() {
        // Auto + Measure: the tuner races reordered candidates against
        // plain ones; whatever wins, serving stays correct and the
        // choice log records the ordering.
        let mut rng = Rng::new(98);
        let band = Csrc::from_coo(&Coo::banded(250, 2, false, &mut rng)).unwrap();
        let shuffle = Permutation::from_new_to_old(rng.permutation(250)).unwrap();
        let a = Arc::new(band.permuted(&shuffle));
        let mut cfg = ServiceConfig::default();
        cfg.workers = 1;
        cfg.route.parallel_kind = EngineKind::Auto;
        cfg.route.min_parallel_n = 1;
        cfg.route.threads = 2;
        cfg.route.reorder = reorder::ReorderPolicy::Measure;
        cfg.tune_budget = TrialBudget::smoke();
        let svc = MatvecService::start(cfg);
        svc.register("m", a.clone());
        let s = svc.stats();
        assert_eq!(s.tunes, 1);
        assert_eq!(s.auto_choices.len(), 1);
        let label = &s.auto_choices[0].1;
        // Either a plain EngineKind label or the reordered/ prefix.
        let plain = label.strip_prefix("reordered/").unwrap_or(label);
        assert!(EngineKind::parse(plain).is_some(), "{label}");
        let x: Vec<f64> = (0..250).map(|i| (i as f64 * 0.02).cos()).collect();
        let mut want = vec![0.0; 250];
        a.spmv_into_zeroed(&x, &mut want);
        let y = svc.call("m", x.clone()).unwrap();
        crate::util::propcheck::assert_close(&y, &want, 1e-11, 1e-11).unwrap();
        svc.shutdown();
    }
}
