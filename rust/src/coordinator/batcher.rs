//! Request batching: group queued requests by matrix so a worker runs
//! them back-to-back against a warm engine (and, on the XLA backend, as
//! one batched artifact call). Pure logic — fully unit-testable without
//! threads.

/// Batching knobs.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Max requests per batch.
    pub max_batch: usize,
    /// Max queue-dwell before a partial batch is released.
    pub max_wait: std::time::Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8, max_wait: std::time::Duration::from_millis(2) }
    }
}

/// A formed batch: matrix key + the values generation its requests were
/// stamped with + indices into the pending queue.
#[derive(Clone, Debug, PartialEq)]
pub struct Batch {
    pub matrix: String,
    pub values_generation: u64,
    pub requests: Vec<usize>,
}

/// Greedy batching preserving arrival order per matrix: walk the queue,
/// open a batch per matrix, close at `max_batch`. Order across batches
/// follows first member arrival (FIFO fairness).
///
/// Each queue entry carries the values generation stamped at submit
/// time. A request whose generation differs from the open batch's
/// *closes* that batch and opens a new one: requests that straddle an
/// `update_values` boundary must never coalesce into one panel — a
/// mixed-generation panel would serve pre-update submissions and
/// post-update submissions in a single blocked product, erasing the
/// ordering the caller observed between its submit and the update.
pub fn form_batches(queue: &[(String, u64)], policy: &BatchPolicy) -> Vec<Batch> {
    let mut batches: Vec<Batch> = Vec::new();
    // matrix -> index of currently open batch
    let mut open: std::collections::HashMap<&str, usize> = std::collections::HashMap::new();
    for (idx, (m, vgen)) in queue.iter().enumerate() {
        match open.get(m.as_str()) {
            Some(&b)
                if batches[b].requests.len() < policy.max_batch
                    && batches[b].values_generation == *vgen =>
            {
                batches[b].requests.push(idx);
            }
            _ => {
                batches.push(Batch {
                    matrix: m.clone(),
                    values_generation: *vgen,
                    requests: vec![idx],
                });
                open.insert(m.as_str(), batches.len() - 1);
            }
        }
    }
    batches
}

/// Coalescing accounting for one dispatch round, reported by the
/// dispatcher into the service's metrics registry
/// (`csrc_batches_total`; the `coalesce` phase span covers the
/// formation itself).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BatchStats {
    /// Requests drained from the queue this round.
    pub requests: usize,
    /// Batches they were grouped into (`<= requests`).
    pub batches: usize,
    /// Largest batch formed (0 when the round was empty).
    pub widest: usize,
}

/// Summarize one round's batches for the coalescing counters.
pub fn summarize(batches: &[Batch]) -> BatchStats {
    BatchStats {
        requests: batches.iter().map(|b| b.requests.len()).sum(),
        batches: batches.len(),
        widest: batches.iter().map(|b| b.requests.len()).max().unwrap_or(0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn q(v: &[&str]) -> Vec<(String, u64)> {
        v.iter().map(|s| (s.to_string(), 0)).collect()
    }

    #[test]
    fn groups_by_matrix_preserving_order() {
        let batches = form_batches(&q(&["a", "b", "a", "a", "b"]), &BatchPolicy::default());
        assert_eq!(batches.len(), 2);
        assert_eq!(batches[0].matrix, "a");
        assert_eq!(batches[0].requests, vec![0, 2, 3]);
        assert_eq!(batches[1].requests, vec![1, 4]);
    }

    #[test]
    fn respects_max_batch() {
        let policy = BatchPolicy { max_batch: 2, ..Default::default() };
        let batches = form_batches(&q(&["a", "a", "a", "a", "a"]), &policy);
        assert_eq!(batches.len(), 3);
        assert_eq!(batches[0].requests, vec![0, 1]);
        assert_eq!(batches[1].requests, vec![2, 3]);
        assert_eq!(batches[2].requests, vec![4]);
    }

    #[test]
    fn empty_queue_no_batches() {
        assert!(form_batches(&[], &BatchPolicy::default()).is_empty());
    }

    #[test]
    fn values_generation_boundary_splits_batches() {
        // Satellite (ISSUE 10): requests stamped before and after an
        // update_values must never share a panel, even for one matrix
        // well under max_batch — and a boundary *closes* the open batch,
        // so a later old-generation straggler cannot rejoin it either.
        let queue: Vec<(String, u64)> = vec![
            ("a".into(), 0),
            ("a".into(), 0),
            ("a".into(), 1),
            ("a".into(), 0), // straggler stamped pre-update, dispatched late
            ("a".into(), 1),
        ];
        let batches = form_batches(&queue, &BatchPolicy::default());
        assert_eq!(batches.len(), 3, "{batches:?}");
        assert_eq!(batches[0].values_generation, 0);
        assert_eq!(batches[0].requests, vec![0, 1]);
        assert_eq!(batches[1].values_generation, 1);
        assert_eq!(batches[1].requests, vec![2]);
        assert_eq!(batches[2].values_generation, 0);
        assert_eq!(batches[2].requests, vec![3]);
        // ...and the final new-generation request opened yet another
        // batch rather than crossing back over the straggler.
        assert!(batches.iter().all(|b| {
            b.requests.iter().all(|&i| queue[i].1 == b.values_generation)
        }));
    }

    #[test]
    fn summarize_counts_requests_batches_and_width() {
        let batches = form_batches(&q(&["a", "b", "a", "a", "b"]), &BatchPolicy::default());
        let s = summarize(&batches);
        assert_eq!(s, BatchStats { requests: 5, batches: 2, widest: 3 });
        assert_eq!(summarize(&[]), BatchStats { requests: 0, batches: 0, widest: 0 });
    }

    #[test]
    fn every_request_in_exactly_one_batch() {
        let queue = q(&["x", "y", "x", "z", "z", "x", "y", "x", "x"]);
        let policy = BatchPolicy { max_batch: 3, ..Default::default() };
        let batches = form_batches(&queue, &policy);
        let mut seen = vec![false; queue.len()];
        for b in &batches {
            for &r in &b.requests {
                assert!(!seen[r], "request {r} in two batches");
                seen[r] = true;
                assert_eq!(queue[r].0, b.matrix);
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn property_batches_fifo_by_first_arrival() {
        // Batch order follows each batch's first request: the dispatcher
        // must never starve an early matrix behind a later one.
        crate::util::propcheck::check(20, |rng| {
            let names = ["a", "b", "c", "d", "e"];
            let queue: Vec<(String, u64)> = (0..rng.below(60))
                .map(|_| (names[rng.below(5)].to_string(), rng.below(2) as u64))
                .collect();
            let policy = BatchPolicy { max_batch: 1 + rng.below(5), ..Default::default() };
            let batches = form_batches(&queue, &policy);
            for w in batches.windows(2) {
                if w[0].requests[0] >= w[1].requests[0] {
                    return Err(format!(
                        "batch first-arrivals out of FIFO order: {} before {}",
                        w[0].requests[0], w[1].requests[0]
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn property_batching_invariants() {
        crate::util::propcheck::check(20, |rng| {
            let names = ["a", "b", "c", "d"];
            let queue: Vec<(String, u64)> = (0..rng.below(40))
                .map(|_| (names[rng.below(4)].to_string(), rng.below(3) as u64))
                .collect();
            let policy = BatchPolicy { max_batch: 1 + rng.below(6), ..Default::default() };
            let batches = form_batches(&queue, &policy);
            let total: usize = batches.iter().map(|b| b.requests.len()).sum();
            if total != queue.len() {
                return Err(format!("{total} batched != {} queued", queue.len()));
            }
            for b in &batches {
                if b.requests.len() > policy.max_batch {
                    return Err("batch over max".into());
                }
                if !b.requests.windows(2).all(|w| w[0] < w[1]) {
                    return Err("batch not in arrival order".into());
                }
                if !b.requests.iter().all(|&i| queue[i].1 == b.values_generation) {
                    return Err("mixed values generations in one batch".into());
                }
            }
            Ok(())
        });
    }
}
