//! Shard-local worker: engine cache, batch execution, coalescing into
//! blocked products, and drift detection.
//!
//! Each worker thread owns its engines (pools, buffers — not Sync) and
//! shares the plan cache, RCM registry, resolved-Auto table, and drift
//! map with its sibling workers of the *same* service. Under a
//! [`ShardedMatvecService`](super::ShardedMatvecService) every shard
//! spawns its own workers over its own state — nothing in this module
//! is shared across shards.

use super::error::{RejectReason, ServiceError};
use super::registration::{self, DriftState, RcmRegistry, Registry, ResolvedAuto};
use super::retuner::{RetuneJob, RetunerMsg};
use super::router::{Backend, RoutePolicy, Router};
use super::service::RESTART_BACKOFF_BASE;
use super::stats::Counters;
use crate::faults::{self, InjectionPoint};
use crate::metrics;
use crate::obs::{self, HistogramHandle, Phase};
use crate::parallel::{build_engine, EngineKind, ParallelSpmv};
use crate::plan::{PlanBuilder, PlanCache};
use crate::reorder::{self, ReorderedEngine};
use crate::tuner;
use crate::util::lock_unpoisoned;
use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Weight of the newest batch in the drift EWMA (higher = jumpier).
pub(crate) const EWMA_ALPHA: f64 = 0.3;

/// Panel width used to coalesce same-matrix requests on routes without
/// a tuned block pick (explicit engine routes, and requests racing an
/// Auto resolution). Matches the top of the tuner's block ladder.
pub(crate) const DEFAULT_PANEL_WIDTH: usize = 8;

/// Send-once reply handle. A request is normally answered exactly once
/// by the serving path, but when a worker panics mid-batch the
/// `catch_unwind` sweep must fail over every request the batch had not
/// answered yet — and only those. `send` claims the slot atomically and
/// reports whether *this* call delivered; the winner also owns the
/// completed/failed accounting, so `completed + failed == submitted`
/// holds even across crashes.
#[derive(Clone)]
pub(crate) struct ReplySlot {
    tx: Sender<Result<Vec<f64>, ServiceError>>,
    sent: Arc<AtomicBool>,
}

impl ReplySlot {
    pub(crate) fn new(tx: Sender<Result<Vec<f64>, ServiceError>>) -> ReplySlot {
        ReplySlot { tx, sent: Arc::new(AtomicBool::new(false)) }
    }

    /// Mark the slot answered; true if this caller won the claim.
    fn claim(&self) -> bool {
        !self.sent.swap(true, Ordering::SeqCst)
    }

    /// Deliver `r` unless a reply was already sent; true if delivered.
    pub(crate) fn send(&self, r: Result<Vec<f64>, ServiceError>) -> bool {
        if !self.claim() {
            return false;
        }
        let _ = self.tx.send(r);
        true
    }
}

pub(crate) struct Request {
    pub(crate) matrix: String,
    /// The key's values generation at submit time — the batcher never
    /// coalesces requests with different stamps into one panel.
    pub(crate) values_generation: u64,
    pub(crate) x: Vec<f64>,
    pub(crate) enqueued: Instant,
    pub(crate) reply: ReplySlot,
}

pub(crate) struct WorkerBatch {
    pub(crate) matrix: String,
    /// The values generation every request in this batch was stamped
    /// with (the batcher never mixes stamps). When it predates the
    /// registry's current generation, the batch is served from the
    /// retained snapshot its requests observed at submit time.
    pub(crate) values_generation: u64,
    pub(crate) requests: Vec<Request>,
}

/// Everything one worker thread shares with the service. `Clone` so the
/// supervisor can keep a template per worker slot and hand a fresh copy
/// to each respawn (every field is a shared handle or a scalar).
#[derive(Clone)]
pub(crate) struct WorkerCtx {
    pub(crate) registry: Arc<Mutex<Registry>>,
    pub(crate) plans: Arc<PlanCache>,
    pub(crate) route: RoutePolicy,
    pub(crate) stats: Arc<Counters>,
    /// This worker's slice of the `csrc_request_latency_us` summary —
    /// recorded lock-free of other workers, merged at snapshot/scrape
    /// time ([`crate::obs::MetricsRegistry::merged_histogram`]).
    pub(crate) latency: HistogramHandle,
    pub(crate) resolved: Arc<Mutex<HashMap<String, ResolvedAuto>>>,
    /// Shared RCM artifacts — one permutation + permuted matrix per
    /// served `key@generation`, built by whichever worker gets there
    /// first (under the lock, so never twice).
    pub(crate) rcm: Arc<Mutex<RcmRegistry>>,
    pub(crate) drift: Arc<Mutex<HashMap<String, DriftState>>>,
    /// Cold-start model, consulted by the racing-request fallback so the
    /// fallback order (cache → model → heuristic) holds on the worker
    /// side too.
    pub(crate) model: Option<Arc<tuner::CostModel>>,
    /// Re-tunes *and* served-baseline write-backs go here — both touch
    /// the persisted decision cache, which must stay off the request
    /// path.
    pub(crate) retune_tx: Sender<RetunerMsg>,
    pub(crate) engine_capacity: usize,
    pub(crate) drift_fraction: f64,
    pub(crate) drift_min_batches: u64,
}

/// Worker engine-cache key: (matrix, generation, values generation,
/// engine label, threads, reordered). The thread count is part of the
/// key because a re-tune may move a key to a different p; the reorder
/// flag because a re-tune may flip the ordering; the values generation
/// because an engine bakes the matrix values into its buffers — after
/// `update_values` the engine rebuilds (cheap: the plan, coloring, and
/// RCM ordering are all cached) against the new values.
type EngineKey = (String, u64, u64, String, usize, bool);

/// One worker's batch-queue receiver. Workers of a service each pull
/// from their own channel, but the receiver sits behind `Arc<Mutex<…>>`
/// so a respawned worker resumes the *same* queue — batches dispatched
/// to a worker that later crashed are served by its replacement, never
/// lost with the dead thread.
pub(crate) type SharedBatchRx = Arc<Mutex<Receiver<WorkerBatch>>>;

/// Per-thread worker state: the engine cache holds execution state
/// (pools, buffers — not Sync), so it dies with a crashed thread and
/// its replacement rebuilds from the shared plan cache.
struct WorkerState {
    router: Router,
    // Engine cache per [`EngineKey`] — structural keys so user keys
    // containing '@' cannot alias generations. Values carry the
    // last-served batch tick for LRU eviction.
    engines: HashMap<EngineKey, (Box<dyn ParallelSpmv>, u64)>,
    serve_tick: u64,
}

/// Serve batches until the dispatcher hangs up (returns `false`) or a
/// batch panics (returns `true` after failing over its unanswered
/// requests — the supervisor respawns the thread with backoff).
pub(crate) fn worker_loop(rx: SharedBatchRx, ctx: WorkerCtx) -> bool {
    let mut state = WorkerState {
        router: Router::new(ctx.route.clone()),
        engines: HashMap::new(),
        serve_tick: 0,
    };
    loop {
        // The queue lock is held only for the recv — no sibling shares
        // this channel, only this worker's future replacement does.
        let batch = match lock_unpoisoned(&rx).recv() {
            Ok(b) => b,
            Err(_) => return false, // dispatcher gone: clean shutdown
        };
        // Snapshot the reply slots, then serve under `catch_unwind`: a
        // panic mid-batch (chaos injection, a bug in an engine) must
        // fail over whatever the batch had not answered and hand the
        // thread back to the supervisor instead of dropping replies.
        let slots: Vec<ReplySlot> = batch.requests.iter().map(|r| r.reply.clone()).collect();
        let served = catch_unwind(AssertUnwindSafe(|| serve_batch(&mut state, &ctx, batch)));
        if served.is_err() {
            ctx.stats.panics_caught.inc();
            let crashed = ServiceError::Retryable {
                reason: RejectReason::WorkerCrashed { shard: None },
                after: RESTART_BACKOFF_BASE,
            };
            for slot in slots {
                if !slot.claim() {
                    continue; // answered before the panic
                }
                ctx.stats.failed.inc();
                let _ = slot.tx.send(Err(crashed.clone()));
            }
            return true;
        }
    }
}

fn serve_batch(state: &mut WorkerState, ctx: &WorkerCtx, batch: WorkerBatch) {
    let _serve_span = obs::phase(Phase::Serve);
    if faults::fire(InjectionPoint::WorkerPanic) {
        panic!("chaos: injected worker panic");
    }
    if faults::fire(InjectionPoint::ShardStall) {
        std::thread::sleep(faults::stall_duration());
    }
    let WorkerState { router, engines, serve_tick } = state;
    {
        let hit = lock_unpoisoned(&ctx.registry).get(&batch.matrix).cloned();
        let Some(entry) = hit else {
            for r in batch.requests {
                ctx.stats.failed.inc();
                let _ = r
                    .reply
                    .send(Err(ServiceError::fatal(format!("unknown matrix {:?}", batch.matrix))));
            }
            return;
        };
        let (generation, values_generation) = (entry.generation, entry.vgen);
        // A batch stamped before an `update_values` must compute with
        // the values its requests observed at submit time — that is the
        // ordering the batcher's generation split promises. Serve it
        // sequentially from the retained snapshot: straddling requests
        // only exist for one dispatch window around an update, so a
        // cached engine is not worth building for them. A stamp no
        // longer retained (structural replacement, or history overflow)
        // falls through to the current matrix — the values it named are
        // gone wholesale.
        if batch.values_generation != values_generation {
            if let Some(old) = entry.values_at(batch.values_generation) {
                for req in batch.requests {
                    if req.x.len() != old.n {
                        ctx.stats.failed.inc();
                        let _ = req.reply.send(Err(ServiceError::fatal(format!(
                            "x length {} != n {}",
                            req.x.len(),
                            old.n
                        ))));
                        continue;
                    }
                    let mut y = vec![0.0; old.n];
                    old.spmv_into_zeroed(&req.x, &mut y);
                    count_products(&ctx, &batch.matrix, "sequential", 1, 1);
                    finish_request(&ctx, &req, y);
                }
                return;
            }
        }
        let a = entry.a;
        // Generation-qualified key: caches can never mix state across a
        // register() replacement (the matrix and its engines/plans stay
        // a consistent snapshot even if the registry changes mid-batch).
        let cache_key = format!("{}@{generation}", batch.matrix);
        // Evict engines built for retired generations — structural or
        // values — of this matrix: each pins a ThreadPool (live OS
        // threads), the old matrix, and its plan. (Retired RCM artifacts
        // live in the shared registry and are collected by `register()`
        // on replacement; `update_values` re-permutes them in place.)
        engines
            .retain(|k, _| k.0 != batch.matrix || (k.1 == generation && k.2 == values_generation));
        *serve_tick += 1;
        let mut used_key: Option<EngineKey> = None;
        // Resolve Auto once per batch (it is batch-invariant): through
        // the registration-time decision — which carries the swept
        // thread count, not `RoutePolicy::threads` blindly — or, for a
        // request racing that resolution, the model/heuristic (features
        // only, no trials), rather than blocking or tuning on the
        // request path.
        let mut auto_decision: Option<ResolvedAuto> = None;
        let backend = match router.route(&a) {
            Backend::NativeParallel { kind: EngineKind::Auto, threads, reorder } => {
                let known = lock_unpoisoned(&ctx.resolved).get(&cache_key).copied();
                match known {
                    Some(r) => {
                        auto_decision = Some(r);
                        Backend::NativeParallel {
                            kind: r.kind,
                            threads: r.nthreads,
                            reorder: r.reorder,
                        }
                    }
                    None => {
                        let plan = ctx.plans.get_or_build(
                            &cache_key,
                            a.as_ref(),
                            PlanBuilder::new(threads).with_pieces(tuner::required_pieces(threads)),
                        );
                        // Same fallback order as registration (model,
                        // then heuristic). The batch executes with the
                        // route's reorder flag either way (an Always
                        // route builds the RCM engine regardless), so
                        // the model must score classes for the ordering
                        // that will actually run — predicting plain for
                        // a reordered execution would pick from the
                        // wrong class space.
                        let features = tuner::Features::extract(a.as_ref(), &plan);
                        let policy = if reorder {
                            crate::reorder::ReorderPolicy::Always
                        } else {
                            crate::reorder::ReorderPolicy::Never
                        };
                        let kind = ctx
                            .model
                            .as_deref()
                            .and_then(|m| m.predict(&features, policy))
                            .map(|p| p.kind)
                            .unwrap_or_else(|| tuner::cost_model(&features));
                        Backend::NativeParallel { kind, threads, reorder }
                    }
                }
            }
            other => other,
        };
        // Per-batch rate sample for drift detection: seconds spent in
        // engine products and how many vector products ran (a k-wide
        // panel counts k — the EWMA stays per-vector-normalized).
        let mut batch_secs = 0.0f64;
        let mut batch_products = 0usize;
        // Validate lengths up front: a malformed request fails on its
        // own and never joins a panel.
        let mut valid: Vec<Request> = Vec::with_capacity(batch.requests.len());
        for req in batch.requests {
            if req.x.len() != a.n {
                ctx.stats.failed.inc();
                let _ = req.reply.send(Err(ServiceError::fatal(format!(
                    "x length {} != n {}",
                    req.x.len(),
                    a.n
                ))));
            } else {
                valid.push(req);
            }
        }
        match &backend {
            Backend::NativeSequential => {
                for req in &valid {
                    let mut y = vec![0.0; a.n];
                    a.spmv_into_zeroed(&req.x, &mut y);
                    finish_request(&ctx, req, y);
                }
                count_products(&ctx, &batch.matrix, "sequential", 1, valid.len() as u64);
            }
            Backend::Xla { artifact } => {
                // The XLA path is exercised via examples/ and the CLI
                // (XlaRuntime is heavyweight); in-service we fall back
                // to sequential to keep the worker self-contained.
                let _ = artifact;
                for req in &valid {
                    let mut y = vec![0.0; a.n];
                    a.spmv_into_zeroed(&req.x, &mut y);
                    finish_request(&ctx, req, y);
                }
                count_products(&ctx, &batch.matrix, "sequential", 1, valid.len() as u64);
            }
            Backend::NativeParallel { kind, threads, reorder } if !valid.is_empty() => {
                let ekey = (
                    batch.matrix.clone(),
                    generation,
                    values_generation,
                    kind.label(),
                    *threads,
                    *reorder,
                );
                let slot = engines.entry(ekey.clone()).or_insert_with(|| {
                    let engine: Box<dyn ParallelSpmv> = if *reorder {
                        // Serve through the RCM ordering: the permuted
                        // matrix and its permutation come from the
                        // *shared* registry — whichever worker arrives
                        // first builds them under the lock, every other
                        // worker (and engine kind) reuses the Arcs. The
                        // wrapper permutes x in / un-permutes y out per
                        // product.
                        let (pa, perm) = {
                            let mut rcm = lock_unpoisoned(&ctx.rcm);
                            let e = rcm.entry(cache_key.clone()).or_insert_with(|| {
                                ctx.stats.rcm_builds.inc();
                                let perm = Arc::new(reorder::rcm(a.as_ref()));
                                let pa = Arc::new(a.permuted(&perm));
                                registration::RcmEntry {
                                    pa,
                                    perm,
                                    vgen: values_generation,
                                }
                            });
                            if e.vgen == values_generation {
                                (e.pa.clone(), e.perm.clone())
                            } else {
                                // The artifact's values lag (or lead)
                                // this batch's registry snapshot — an
                                // `update_values` raced us between its
                                // registry publish and its artifact
                                // patch. Re-permute our own snapshot
                                // through the cached ordering (no new
                                // RCM computation), and only publish it
                                // back when it advances the shared
                                // entry.
                                let pa = Arc::new(a.permuted(&e.perm));
                                if e.vgen < values_generation {
                                    e.pa = pa.clone();
                                    e.vgen = values_generation;
                                }
                                (pa, e.perm.clone())
                            }
                        };
                        let plan = ctx.plans.get_or_build(
                            &format!("{cache_key}#rcm"),
                            pa.as_ref(),
                            PlanBuilder::for_kind(*threads, *kind),
                        );
                        Box::new(ReorderedEngine::new(
                            build_engine(*kind, pa, plan),
                            perm,
                        ))
                    } else {
                        let plan = ctx.plans.get_or_build(
                            &cache_key,
                            a.as_ref(),
                            PlanBuilder::for_kind(*threads, *kind),
                        );
                        build_engine(*kind, a.clone(), plan)
                    };
                    (engine, 0)
                });
                slot.1 = *serve_tick;
                used_key = Some(ekey);
                // Coalesce the batch into k-wide panels: the tuned
                // width for resolved Auto routes (block_k = 1 means the
                // blocked product lost its own race — serve serially),
                // the ladder cap for explicit routes.
                let cap = auto_decision
                    .map(|r| r.block_k.max(1))
                    .unwrap_or(DEFAULT_PANEL_WIDTH);
                let engine_label = kind.label();
                let mut i = 0usize;
                while i < valid.len() {
                    let g = cap.min(valid.len() - i);
                    if g <= 1 {
                        let req = &valid[i];
                        let mut y = vec![0.0; a.n];
                        let t = Instant::now();
                        slot.0.spmv(&req.x, &mut y);
                        batch_secs += t.elapsed().as_secs_f64();
                        batch_products += 1;
                        count_products(&ctx, &batch.matrix, &engine_label, 1, 1);
                        finish_request(&ctx, req, y);
                        i += 1;
                    } else {
                        // Pack the g request vectors into one row-major
                        // panel (x[j*g + c] = request c's x[j]), run a
                        // single blocked product, unpack per request.
                        let pack_span = obs::phase(Phase::Coalesce);
                        let mut xp = vec![0.0; a.n * g];
                        for (c, req) in valid[i..i + g].iter().enumerate() {
                            for (j, &v) in req.x.iter().enumerate() {
                                xp[j * g + c] = v;
                            }
                        }
                        drop(pack_span);
                        let mut yp = vec![0.0; a.n * g];
                        let t = Instant::now();
                        slot.0.spmv_multi(&xp, &mut yp, g);
                        batch_secs += t.elapsed().as_secs_f64();
                        batch_products += g;
                        ctx.stats.coalesced_products.inc();
                        ctx.stats.coalesced_requests.add(g as u64);
                        count_products(&ctx, &batch.matrix, &engine_label, g, 1);
                        let unpack_span = obs::phase(Phase::Coalesce);
                        for (c, req) in valid[i..i + g].iter().enumerate() {
                            let mut y = vec![0.0; a.n];
                            for (j, yj) in y.iter_mut().enumerate() {
                                *yj = yp[j * g + c];
                            }
                            finish_request(&ctx, req, y);
                        }
                        drop(unpack_span);
                        i += g;
                    }
                }
            }
            Backend::NativeParallel { .. } => {} // every request failed validation
        }
        if let Some(r) = auto_decision {
            let job = RetuneJob {
                matrix: batch.matrix.clone(),
                cache_key: cache_key.clone(),
                generation,
            };
            maybe_flag_drift(&ctx, job, r, batch_products, batch_secs);
        }
        // LRU eviction (ROADMAP item): a worker that has served many
        // distinct keys must not park one thread pool per key forever.
        // Evict the least-recently-served engines above capacity, never
        // the one this batch just used.
        if engines.len() > ctx.engine_capacity {
            let mut evicted = 0u64;
            while engines.len() > ctx.engine_capacity {
                let victim = engines
                    .iter()
                    .filter(|&(k, _)| used_key.as_ref() != Some(k))
                    .min_by_key(|&(_, &(_, tick))| tick)
                    .map(|(k, _)| k.clone());
                let Some(v) = victim else { break };
                engines.remove(&v);
                evicted += 1;
            }
            if evicted > 0 {
                ctx.stats.engines_evicted.add(evicted);
            }
        }
    }
}

/// Reply to one served request and record its completion + latency.
/// `completed` is bumped *before* the reply is sent, so a caller whose
/// `call()` has returned is always visible in the next snapshot.
fn finish_request(ctx: &WorkerCtx, req: &Request, y: Vec<f64>) {
    ctx.stats.completed.inc();
    ctx.latency.record(req.enqueued.elapsed().as_secs_f64());
    let _ = req.reply.send(Ok(y));
}

/// Bump the per-engine product family
/// (`csrc_engine_products_total{matrix,engine,k}`) for `products`
/// products served at panel width `k`.
fn count_products(ctx: &WorkerCtx, matrix: &str, engine: &str, k: usize, products: u64) {
    let width = k.to_string();
    ctx.stats
        .obs
        .family_counter(
            "csrc_engine_products_total",
            &[("matrix", matrix), ("engine", engine), ("k", &width)],
        )
        .add(products);
}

/// Fold one batch's measured rate into the key's EWMA and queue a
/// background re-tune — once per key × generation — when it has drifted
/// below `drift_fraction` of the decision's *baseline* rate. The rate
/// is normalized by the decision's own `work_flops`, so the EWMA and
/// the baseline are in the same units. Unmeasured (model/heuristic)
/// decisions record no rate and are never drift-checked.
///
/// The baseline is the entry's **served** rate when one has been
/// recorded, else the trial rate. Trials are warm back-to-back products
/// and therefore optimistic relative to per-request serving — judging
/// serving against them forever re-triggers (a re-tune storm). So the
/// first `drift_min_batches` batches after a re-tune *calibrate*
/// (`DriftState::calibrating`): their EWMA is written back into the
/// resolved entry and the persisted cache entry as the served baseline,
/// and only later batches are judged, against that baseline.
fn maybe_flag_drift(ctx: &WorkerCtx, job: RetuneJob, r: ResolvedAuto, products: usize, secs: f64) {
    if products == 0
        || secs <= 0.0
        || ctx.drift_fraction <= 0.0
        || !r.measured
        || r.mflops <= 0.0
        || r.work_flops == 0
    {
        return;
    }
    let rate = metrics::mflops(r.work_flops * products, secs);
    let mut drift = lock_unpoisoned(&ctx.drift);
    let st = drift.entry(job.cache_key.clone()).or_default();
    st.ewma_mflops = if st.batches == 0 {
        rate
    } else {
        EWMA_ALPHA * rate + (1.0 - EWMA_ALPHA) * st.ewma_mflops
    };
    st.batches += 1;
    if st.batches < ctx.drift_min_batches {
        return;
    }
    if st.calibrating {
        // Enough post-re-tune batches: the EWMA *is* serving reality
        // now. (The first sample can straddle the old engine for one
        // batch — the EWMA shrugs that off.) Record it as the judging
        // baseline under this lock, publish it to the resolved entry
        // (cheap, in-memory) and hand the persisted write-back — a full
        // cache-file rewrite — to the re-tuner thread; judgement
        // restarts next batch.
        st.calibrating = false;
        st.served_baseline = st.ewma_mflops;
        let ewma = st.ewma_mflops;
        drop(drift);
        if let Some(e) = lock_unpoisoned(&ctx.resolved).get_mut(&job.cache_key) {
            e.served_mflops = ewma;
        }
        let _ = ctx.retune_tx.send(RetunerMsg::RecordServedRate {
            fingerprint: r.fingerprint,
            max_threads: r.max_threads,
            mflops: ewma,
        });
        return;
    }
    // Baseline preference: the lock-protected calibration record, then
    // the decision's persisted served rate (a restarted service), then
    // — for never-calibrated decisions — the trial rate.
    let baseline = if st.served_baseline > 0.0 {
        st.served_baseline
    } else if r.served_mflops > 0.0 {
        r.served_mflops
    } else {
        r.mflops
    };
    if st.ewma_mflops >= ctx.drift_fraction * baseline {
        return;
    }
    let already_pending = st.retune_pending;
    st.retune_pending = true;
    drop(drift);
    ctx.stats.drift_events.inc();
    if !already_pending {
        let _ = ctx.retune_tx.send(RetunerMsg::Retune(job));
    }
}

#[cfg(test)]
mod tests {
    use super::super::batcher::BatchPolicy;
    use super::super::test_support::{doctored_decision, mat};
    use super::super::{MatvecService, ServiceConfig};
    use super::*;
    use crate::reorder::Permutation;
    use crate::sparse::{Coo, Csrc};
    use crate::tuner::{DecisionCache, TrialBudget};
    use crate::util::Rng;

    #[test]
    fn parallel_backend_used_for_large_matrices() {
        let mut cfg = ServiceConfig::default();
        cfg.route.min_parallel_n = 32; // force the parallel path
        cfg.route.threads = 2;
        let svc = MatvecService::start(cfg);
        let a = mat(200, 84);
        svc.register("big", a.clone());
        let x = vec![1.0; 200];
        let y = svc.call("big", x.clone()).unwrap();
        let mut want = vec![0.0; 200];
        a.spmv_into_zeroed(&x, &mut want);
        crate::util::propcheck::assert_close(&y, &want, 1e-11, 1e-11).unwrap();
        svc.shutdown();
    }

    #[test]
    fn replacing_a_matrix_retires_its_engines_and_plans() {
        // After register() overwrites a key — even with a different size
        // — requests must run against the new matrix, not a worker's
        // cached engine for the old one.
        let mut cfg = ServiceConfig::default();
        cfg.workers = 1; // one worker so the engine cache is definitely warm
        cfg.route.min_parallel_n = 1;
        cfg.route.threads = 2;
        let svc = MatvecService::start(cfg);
        let a1 = mat(60, 87);
        svc.register("m", a1.clone());
        let x1 = vec![1.0; 60];
        let y1 = svc.call("m", x1.clone()).unwrap();
        let mut want1 = vec![0.0; 60];
        a1.spmv_into_zeroed(&x1, &mut want1);
        crate::util::propcheck::assert_close(&y1, &want1, 1e-11, 1e-11).unwrap();
        // Replace with a smaller matrix (the dangerous direction for a
        // stale engine) and serve again.
        let a2 = mat(40, 88);
        svc.register("m", a2.clone());
        let x2 = vec![1.0; 40];
        let y2 = svc.call("m", x2.clone()).unwrap();
        let mut want2 = vec![0.0; 40];
        a2.spmv_into_zeroed(&x2, &mut want2);
        crate::util::propcheck::assert_close(&y2, &want2, 1e-11, 1e-11).unwrap();
        let s = svc.stats();
        assert_eq!(s.completed, 2);
        assert_eq!(s.plan_builds, 2, "replacement must build a fresh plan");
        svc.shutdown();
    }

    #[test]
    fn reorder_always_serves_correct_products() {
        // Policy Always: every parallel request runs through the RCM
        // ordering (permuted engine + per-request permute/un-permute) —
        // answers must be bit-identical in meaning to the plain path.
        let mut rng = Rng::new(97);
        let band = Csrc::from_coo(&Coo::banded(300, 2, false, &mut rng)).unwrap();
        let shuffle = Permutation::from_new_to_old(rng.permutation(300)).unwrap();
        let a = Arc::new(band.permuted(&shuffle)); // shuffled: RCM has room
        let mut cfg = ServiceConfig::default();
        cfg.workers = 1;
        cfg.route.min_parallel_n = 1;
        cfg.route.threads = 2;
        cfg.route.reorder = reorder::ReorderPolicy::Always;
        let svc = MatvecService::start(cfg);
        svc.register("m", a.clone());
        let x: Vec<f64> = (0..300).map(|i| (i as f64 * 0.01).sin()).collect();
        let mut want = vec![0.0; 300];
        a.spmv_into_zeroed(&x, &mut want);
        for _ in 0..3 {
            let y = svc.call("m", x.clone()).unwrap();
            crate::util::propcheck::assert_close(&y, &want, 1e-11, 1e-11).unwrap();
        }
        assert_eq!(svc.stats().completed, 3);
        svc.shutdown();
    }

    #[test]
    fn rcm_built_once_across_workers() {
        // Satellite (ISSUE 6): four workers all serving one key through
        // the RCM ordering must share a single permutation build — the
        // artifact registry is service-wide, like the plan cache.
        let mut rng = Rng::new(99);
        let band = Csrc::from_coo(&Coo::banded(300, 2, false, &mut rng)).unwrap();
        let shuffle = Permutation::from_new_to_old(rng.permutation(300)).unwrap();
        let a = Arc::new(band.permuted(&shuffle));
        let mut cfg = ServiceConfig::default();
        cfg.workers = 4;
        cfg.route.min_parallel_n = 1;
        cfg.route.threads = 2;
        cfg.route.reorder = reorder::ReorderPolicy::Always;
        let svc = MatvecService::start(cfg);
        svc.register("m", a.clone());
        let x: Vec<f64> = (0..300).map(|i| (i as f64 * 0.01).sin()).collect();
        let mut want = vec![0.0; 300];
        a.spmv_into_zeroed(&x, &mut want);
        let rxs: Vec<_> = (0..24).map(|_| svc.submit("m", x.clone())).collect();
        for rx in rxs {
            let y = rx.recv().unwrap().unwrap();
            crate::util::propcheck::assert_close(&y, &want, 1e-11, 1e-11).unwrap();
        }
        let s = svc.stats();
        assert_eq!(s.completed, 24);
        assert_eq!(s.rcm_builds, 1, "N workers must share one RCM build, got {}", s.rcm_builds);
        svc.shutdown();
    }

    #[test]
    fn coalesced_batches_replay_the_tuned_block_width() {
        // Tentpole acceptance (ISSUE 6): a persisted k>1 decision,
        // replayed by a cold-cache service, makes the worker coalesce
        // same-matrix requests into blocked products — and the answers
        // stay exact per request.
        let dir = std::env::temp_dir().join(format!("csrc_spmm_svc_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("decisions.json");
        let a = mat(200, 500);
        let kernel: Arc<dyn crate::sparse::SpmvKernel> = a.clone();
        let fp = tuner::fingerprint(kernel.as_ref());
        {
            let cache = DecisionCache::open(&path);
            let mut d = doctored_decision(fp, 100.0);
            d.block_k = 4;
            d.block_rates = vec![(1, 100.0), (2, 110.0), (4, 130.0), (8, 120.0)];
            cache.put(d);
        }
        let mut cfg = ServiceConfig::default();
        cfg.workers = 1;
        cfg.batch = BatchPolicy {
            max_batch: 8,
            max_wait: std::time::Duration::from_millis(50),
        };
        cfg.route.parallel_kind = EngineKind::Auto;
        cfg.route.min_parallel_n = 1;
        cfg.route.threads = 2;
        cfg.route.sweep_threads = true;
        cfg.tune_budget = TrialBudget::smoke();
        cfg.decision_cache = Some(path.clone());
        cfg.drift_fraction = 0.0; // isolate coalescing from drift re-tunes
        let svc = MatvecService::start(cfg);
        svc.register("m", a.clone());
        assert_eq!(svc.stats().tunes, 0, "the persisted k>1 decision must be a cache hit");
        // A burst within the batching window forms one multi-request
        // batch, which the worker serves as two width-4 panels.
        let xs: Vec<Vec<f64>> = (0..8)
            .map(|r| (0..200).map(|i| ((r * 200 + i) as f64 * 0.01).sin()).collect())
            .collect();
        let rxs: Vec<_> = xs.iter().map(|x| svc.submit("m", x.clone())).collect();
        for (x, rx) in xs.iter().zip(rxs) {
            let y = rx.recv().unwrap().unwrap();
            let mut want = vec![0.0; 200];
            a.spmv_into_zeroed(x, &mut want);
            crate::util::propcheck::assert_close(&y, &want, 1e-11, 1e-11).unwrap();
        }
        let s = svc.stats();
        assert_eq!(s.completed, 8);
        assert!(
            s.coalesced_products >= 1 && s.coalesced_requests >= 2,
            "a burst against a k=4 decision must coalesce (products={}, requests={})",
            s.coalesced_products,
            s.coalesced_requests
        );
        svc.shutdown();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn worker_engine_cache_evicts_lru() {
        // Capacity-1 worker cache serving two matrices must release the
        // older engine (and its parked pool) instead of hoarding both.
        let mut cfg = ServiceConfig::default();
        cfg.workers = 1;
        cfg.route.min_parallel_n = 1;
        cfg.route.threads = 2;
        cfg.engine_cache_capacity = 1;
        let svc = MatvecService::start(cfg);
        let a = mat(60, 91);
        let b = mat(50, 92);
        svc.register("a", a.clone());
        svc.register("b", b.clone());
        for (key, m) in [("a", &a), ("b", &b), ("a", &a)] {
            let x = vec![1.0; m.n];
            let y = svc.call(key, x.clone()).unwrap();
            let mut want = vec![0.0; m.n];
            m.spmv_into_zeroed(&x, &mut want);
            crate::util::propcheck::assert_close(&y, &want, 1e-11, 1e-11).unwrap();
        }
        let s = svc.stats();
        assert_eq!(s.completed, 3);
        assert!(
            s.engines_evicted >= 1,
            "capacity-1 cache must evict between matrices, evicted {}",
            s.engines_evicted
        );
        svc.shutdown();
    }
}
