//! Sharded serving: row-block shards behind a scatter/gather front.
//!
//! A [`ShardedMatvecService`] scales the single-process service out the
//! way the paper's §5 scales the kernel out: each registered matrix is
//! row-block partitioned into `nshards` overlapping subdomains (the
//! [`super::distributed`] decomposition — square CSRC part per owned
//! row slab plus a rectangular coupling to the ghost columns), and each
//! shard owns a *complete, private* [`MatvecService`]: its own worker
//! pool, plan cache, decision cache, RCM registry, and
//! [`crate::obs::MetricsRegistry`]. Tuning, drift detection, re-tuning,
//! and metrics are therefore shard-local — one hot shard re-tunes
//! without touching its neighbours, exactly the isolation a NUMA-domain
//! or per-process deployment needs.
//!
//! The front router is thin and synchronous: `spmv`/`spmv_multi`
//! *scatter* x (owned rows per shard, plus a gathered halo of ghost
//! values), submit the k panel columns to every shard (each shard's
//! batcher re-coalesces them into one blocked product), overlap the
//! serial coupling sweep `A_R · halo` with the shards' square products,
//! then *gather* per-shard replies back into y. Scatter and gather are
//! traced as their own phases ([`crate::obs::Phase::Scatter`] /
//! [`crate::obs::Phase::Gather`]).
//!
//! Two service-shaped guardrails live at the front, not in the shards:
//! *back-pressure* — a shard whose in-flight depth would exceed
//! [`ShardConfig::queue_capacity`] rejects the product instead of
//! growing its queue — and a per-shard *deadline* on the gather side, so
//! a wedged shard turns into an error, not a hang.

use super::distributed::DistributedMatrix;
use super::service::{MatvecService, ServiceConfig};
use super::stats::ServiceStats;
use crate::obs::{self, Counter, Gauge, MetricsRegistry, Phase};
use crate::sparse::{Csrc, CsrcRect};
use std::collections::HashMap;
use std::net::SocketAddr;
use std::ops::Range;
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Sharded-front configuration. `service` is the template every shard's
/// private [`MatvecService`] is started from; a file-backed
/// [`ServiceConfig::decision_cache`] is suffixed `.shard<i>` per shard
/// so persisted tuning decisions stay shard-local too.
#[derive(Clone, Debug)]
pub struct ShardConfig {
    pub nshards: usize,
    /// Max requests in flight per shard (submitted, not yet answered).
    /// A product whose k columns would push a shard past this is
    /// rejected up front — bounded queues, not unbounded growth.
    pub queue_capacity: usize,
    /// Gather-side wait per reply; a shard that misses it fails the
    /// product (and bumps `csrc_shard_deadline_exceeded_total`).
    pub deadline: Duration,
    pub service: ServiceConfig,
}

impl Default for ShardConfig {
    fn default() -> Self {
        ShardConfig {
            nshards: 2,
            queue_capacity: 1024,
            deadline: Duration::from_secs(30),
            service: ServiceConfig::default(),
        }
    }
}

/// One shard's slice of a registered matrix, kept by the front for
/// scatter/gather: the owned row slab, the global ids of the ghost
/// columns, and the rectangular coupling (the shard's service serves
/// only the square part — the front applies `A_R · halo` itself).
struct ShardPart {
    rows: Range<usize>,
    ghosts: Vec<usize>,
    rect: CsrcRect,
}

/// A registered matrix's full decomposition. `parts.len()` may sit
/// below `nshards` for tiny matrices (never more slabs than rows).
struct ShardedParts {
    n: usize,
    parts: Vec<ShardPart>,
}

/// Per-shard front counters + the shard's own service snapshot.
#[derive(Clone, Debug)]
pub struct ShardStats {
    pub shard: usize,
    /// Column requests this shard was handed by the front.
    pub requests: u64,
    /// Products rejected at the front because this shard's queue was
    /// full (counted once per product, not per column).
    pub rejects: u64,
    /// Gather-side deadline misses charged to this shard.
    pub deadline_exceeded: u64,
    pub service: ServiceStats,
}

pub struct ShardedMatvecService {
    cfg: ShardConfig,
    services: Vec<MatvecService>,
    registry: Mutex<HashMap<String, Arc<ShardedParts>>>,
    /// Front-side registry: scatter/gather counters live here; each
    /// shard's serving metrics stay in its service's own registry.
    obs: Arc<MetricsRegistry>,
    requests: Vec<Counter>,
    rejects: Vec<Counter>,
    deadline_exceeded: Vec<Counter>,
    /// Total ghost values gathered per single-vector product, summed
    /// over every registered matrix — the halo-volume cost of the
    /// current shard count, scraped by the CI smoke.
    halo: Gauge,
}

impl ShardedMatvecService {
    pub fn start(cfg: ShardConfig) -> ShardedMatvecService {
        assert!(cfg.nshards >= 1, "need at least one shard");
        let obs_reg = Arc::new(MetricsRegistry::new());
        let mut services = Vec::with_capacity(cfg.nshards);
        let mut requests = Vec::with_capacity(cfg.nshards);
        let mut rejects = Vec::with_capacity(cfg.nshards);
        let mut deadline_exceeded = Vec::with_capacity(cfg.nshards);
        for i in 0..cfg.nshards {
            let mut sc = cfg.service.clone();
            if let Some(path) = &mut sc.decision_cache {
                let name = path
                    .file_name()
                    .map(|f| f.to_string_lossy().into_owned())
                    .unwrap_or_else(|| "decisions.json".into());
                path.set_file_name(format!("{name}.shard{i}"));
            }
            services.push(MatvecService::start(sc));
            let l = i.to_string();
            requests.push(obs_reg.family_counter("csrc_shard_requests_total", &[("shard", &l)]));
            rejects.push(obs_reg.family_counter("csrc_shard_rejects_total", &[("shard", &l)]));
            deadline_exceeded
                .push(obs_reg.family_counter("csrc_shard_deadline_exceeded_total", &[("shard", &l)]));
        }
        let halo = obs_reg.gauge("csrc_shard_halo_doubles");
        ShardedMatvecService {
            cfg,
            services,
            registry: Mutex::new(HashMap::new()),
            obs: obs_reg,
            requests,
            rejects,
            deadline_exceeded,
            halo,
        }
    }

    pub fn nshards(&self) -> usize {
        self.cfg.nshards
    }

    /// Register (or replace) a matrix under a key: decompose it into
    /// row-block subdomains and register each shard's square part with
    /// that shard's private service (which tunes it like any matrix —
    /// every shard is tuner-raced independently). The front keeps the
    /// row slabs, ghost maps, and coupling rectangles for scatter/gather.
    pub fn register(&self, key: &str, a: Arc<Csrc>) {
        let global = a.to_csr();
        let nsub = self.cfg.nshards.min(global.nrows.max(1));
        let dm = DistributedMatrix::from_global(&global, nsub);
        let mut parts = Vec::with_capacity(nsub);
        for sub in dm.subs {
            let rank = sub.rank;
            let local = sub.local;
            self.services[rank].register(key, Arc::new(local.square.clone()));
            parts.push(ShardPart { rows: sub.rows, ghosts: sub.ghosts, rect: local });
        }
        let mut reg = self.registry.lock().unwrap();
        reg.insert(key.to_string(), Arc::new(ShardedParts { n: global.nrows, parts }));
        let total: usize =
            reg.values().map(|p| p.parts.iter().map(|s| s.ghosts.len()).sum::<usize>()).sum();
        self.halo.set(total as f64);
    }

    /// y = A·x through the sharded front.
    pub fn spmv(&self, key: &str, x: &[f64]) -> Result<Vec<f64>, String> {
        self.spmv_multi(key, x, 1)
    }

    /// Y = A·X for a row-major n×k panel. Scatter → k column requests
    /// per shard (each shard's batcher re-coalesces them into a blocked
    /// product) → coupling sweep on the front thread while the shards
    /// run → gather with per-shard deadlines.
    pub fn spmv_multi(&self, key: &str, x: &[f64], k: usize) -> Result<Vec<f64>, String> {
        assert!(k >= 1);
        let parts = self
            .registry
            .lock()
            .unwrap()
            .get(key)
            .cloned()
            .ok_or_else(|| format!("unknown matrix {key:?}"))?;
        if x.len() != parts.n * k {
            return Err(format!(
                "x has length {} but {key:?} is {}x{} with k={k}",
                x.len(),
                parts.n,
                parts.n
            ));
        }
        // Back-pressure: refuse the whole product before submitting any
        // column if some shard's queue cannot take k more requests.
        // `in_flight` over-estimates depth (completed is read first), so
        // a full queue can only look fuller — rejection is conservative.
        for (i, svc) in self.services[..parts.parts.len()].iter().enumerate() {
            if svc.in_flight() + k as u64 > self.cfg.queue_capacity as u64 {
                self.rejects[i].inc();
                return Err(format!(
                    "shard {i} queue full ({} in flight, capacity {})",
                    svc.in_flight(),
                    self.cfg.queue_capacity
                ));
            }
        }
        // Scatter: per shard, slice the owned rows out of each panel
        // column and gather the ghost values into a halo panel.
        let mut pending = Vec::with_capacity(parts.parts.len());
        let mut halos = Vec::with_capacity(parts.parts.len());
        {
            let _span = obs::phase(Phase::Scatter);
            for (i, part) in parts.parts.iter().enumerate() {
                let mut halo = vec![0.0; part.ghosts.len() * k];
                for (g, &gj) in part.ghosts.iter().enumerate() {
                    halo[g * k..g * k + k].copy_from_slice(&x[gj * k..gj * k + k]);
                }
                let mut cols = Vec::with_capacity(k);
                for c in 0..k {
                    let xs: Vec<f64> = part.rows.clone().map(|r| x[r * k + c]).collect();
                    cols.push(self.services[i].submit(key, xs));
                }
                self.requests[i].add(k as u64);
                pending.push(cols);
                halos.push(halo);
            }
        }
        // Coupling sweeps run here, overlapped with the shards' square
        // products: y_shard = service(A_S · x_owned) + A_R · halo.
        let coups: Vec<Vec<f64>> = parts
            .parts
            .iter()
            .zip(&halos)
            .map(|(part, halo)| {
                let mut coup = vec![0.0; part.rows.len() * k];
                part.rect.coupling_spmv_multi_into(halo, &mut coup, k);
                coup
            })
            .collect();
        // Gather: collect every shard's columns (deadline per reply) and
        // add the coupling contribution back into the global panel.
        let mut y = vec![0.0; parts.n * k];
        {
            let _span = obs::phase(Phase::Gather);
            for (i, (part, cols)) in parts.parts.iter().zip(pending).enumerate() {
                let coup = &coups[i];
                for (c, rx) in cols.into_iter().enumerate() {
                    let yc = match rx.recv_timeout(self.cfg.deadline) {
                        Ok(reply) => reply?,
                        Err(_) => {
                            self.deadline_exceeded[i].inc();
                            return Err(format!(
                                "shard {i} missed the {:?} deadline",
                                self.cfg.deadline
                            ));
                        }
                    };
                    for (r, v) in yc.into_iter().enumerate() {
                        y[(part.rows.start + r) * k + c] = v + coup[r * k + c];
                    }
                }
            }
        }
        Ok(y)
    }

    /// Per-shard stats: front counters + each shard's service snapshot.
    pub fn stats(&self) -> Vec<ShardStats> {
        self.services
            .iter()
            .enumerate()
            .map(|(i, svc)| ShardStats {
                shard: i,
                requests: self.requests[i].get(),
                rejects: self.rejects[i].get(),
                deadline_exceeded: self.deadline_exceeded[i].get(),
                service: svc.stats(),
            })
            .collect()
    }

    /// Current halo volume (ghost doubles gathered per single-vector
    /// product, summed over registered matrices).
    pub fn halo_doubles(&self) -> f64 {
        self.halo.get()
    }

    /// One Prometheus page for the whole deployment: the front's
    /// registry (with the process-wide phase totals, emitted once) plus
    /// every shard's registry with a `shard="<i>"` label injected into
    /// each sample.
    pub fn render_prometheus(&self) -> String {
        let mut out = self.obs.render_prometheus();
        for (i, svc) in self.services.iter().enumerate() {
            let label = i.to_string();
            out.push_str(
                &svc.metrics_registry().render_prometheus_with(&[("shard", &label)], false),
            );
        }
        out
    }

    /// Serve the composed page on a scrape endpoint
    /// (`csrc serve --shards N --metrics-addr`).
    pub fn serve_metrics(&self, addr: &str) -> std::io::Result<SocketAddr> {
        let front = self.obs.clone();
        let shards: Vec<Arc<MetricsRegistry>> =
            self.services.iter().map(|s| s.metrics_registry()).collect();
        obs::serve_rendered(addr, move || {
            let mut out = front.render_prometheus();
            for (i, r) in shards.iter().enumerate() {
                let label = i.to_string();
                out.push_str(&r.render_prometheus_with(&[("shard", &label)], false));
            }
            out
        })
    }

    /// Graceful shutdown: every shard drains and joins.
    pub fn shutdown(mut self) {
        for svc in self.services.drain(..) {
            svc.shutdown();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::batcher::BatchPolicy;
    use super::super::test_support::mat;
    use super::*;
    use crate::sparse::LinOp;

    fn assert_close(got: &[f64], want: &[f64]) {
        assert_eq!(got.len(), want.len());
        for (i, (g, w)) in got.iter().zip(want).enumerate() {
            // Summation order differs across the shard boundary — bit
            // equality is not expected, 1e-11 relative is.
            assert!(
                (g - w).abs() <= 1e-11 * (1.0 + w.abs()),
                "index {i}: got {g}, want {w}"
            );
        }
    }

    #[test]
    fn sharded_spmv_matches_unsharded_for_every_shard_count() {
        let a = mat(120, 71);
        let x: Vec<f64> = (0..120).map(|i| (i as f64 * 0.37).sin()).collect();
        let mut want = vec![0.0; 120];
        a.apply(&x, &mut want);
        for nshards in [1usize, 2, 4, 7] {
            let svc = ShardedMatvecService::start(ShardConfig {
                nshards,
                ..ShardConfig::default()
            });
            svc.register("a", a.clone());
            let got = svc.spmv("a", &x).unwrap();
            assert_close(&got, &want);
            if nshards > 1 {
                assert!(svc.halo_doubles() > 0.0, "overlap decomposition must have ghosts");
            }
            let stats = svc.stats();
            assert_eq!(stats.len(), nshards);
            assert!(stats.iter().all(|s| s.rejects == 0 && s.deadline_exceeded == 0));
            svc.shutdown();
        }
    }

    #[test]
    fn sharded_spmv_multi_matches_unsharded_for_every_shard_count() {
        let n = 96;
        let k = 4;
        let a = mat(n, 72);
        let x: Vec<f64> = (0..n * k).map(|i| (i as f64 * 0.13).cos()).collect();
        let mut want = vec![0.0; n * k];
        a.apply_multi(&x, &mut want, k);
        for nshards in [1usize, 2, 4, 7] {
            let svc = ShardedMatvecService::start(ShardConfig {
                nshards,
                ..ShardConfig::default()
            });
            svc.register("a", a.clone());
            let got = svc.spmv_multi("a", &x, k).unwrap();
            assert_close(&got, &want);
            // Every shard served k column requests.
            for s in svc.stats() {
                assert_eq!(s.requests, k as u64, "shard {}", s.shard);
            }
            svc.shutdown();
        }
    }

    #[test]
    fn replacing_a_matrix_reshards_it() {
        let svc =
            ShardedMatvecService::start(ShardConfig { nshards: 3, ..ShardConfig::default() });
        let a = mat(80, 73);
        let b = mat(64, 74);
        svc.register("m", a);
        let halo_a = svc.halo_doubles();
        svc.register("m", b.clone());
        assert_ne!(svc.halo_doubles(), halo_a, "replacement must re-decompose");
        let x: Vec<f64> = (0..64).map(|i| 1.0 / (1.0 + i as f64)).collect();
        let mut want = vec![0.0; 64];
        b.apply(&x, &mut want);
        assert_close(&svc.spmv("m", &x).unwrap(), &want);
        svc.shutdown();
    }

    #[test]
    fn unknown_key_and_wrong_length_fail_cleanly() {
        let svc =
            ShardedMatvecService::start(ShardConfig { nshards: 2, ..ShardConfig::default() });
        assert!(svc.spmv("nope", &[1.0, 2.0]).is_err());
        svc.register("a", mat(40, 75));
        let short = vec![0.0; 39];
        assert!(svc.spmv("a", &short).is_err());
        svc.shutdown();
    }

    #[test]
    fn full_shard_queue_rejects_instead_of_deadlocking() {
        // One shard whose dispatcher parks partial batches for 200ms: a
        // submitted product sits in flight for the whole window, so a
        // second product arriving mid-window must bounce off the
        // capacity-1 queue — rejection, not unbounded growth or a hang.
        let cfg = ShardConfig {
            nshards: 1,
            queue_capacity: 1,
            service: ServiceConfig {
                workers: 1,
                batch: BatchPolicy {
                    max_batch: 64,
                    max_wait: std::time::Duration::from_millis(200),
                },
                ..ServiceConfig::default()
            },
            ..ShardConfig::default()
        };
        let svc = Arc::new(ShardedMatvecService::start(cfg));
        let n = 60;
        let a = mat(n, 76);
        svc.register("a", a);
        let x: Vec<f64> = (0..n).map(|i| i as f64).collect();
        let first = {
            let svc = svc.clone();
            let x = x.clone();
            std::thread::spawn(move || svc.spmv("a", &x))
        };
        // Land inside the 200ms batching window with a wide margin.
        std::thread::sleep(std::time::Duration::from_millis(50));
        let second = svc.spmv("a", &x);
        assert!(second.is_err(), "saturated shard must reject");
        assert!(second.unwrap_err().contains("queue full"));
        assert!(first.join().unwrap().is_ok(), "parked product still completes");
        assert_eq!(svc.stats()[0].rejects, 1);
        // Capacity frees up once the first product drains.
        assert!(svc.spmv("a", &x).is_ok());
    }

    #[test]
    fn composed_scrape_carries_shard_labels_and_halo_gauge() {
        let svc =
            ShardedMatvecService::start(ShardConfig { nshards: 2, ..ShardConfig::default() });
        svc.register("a", mat(70, 77));
        let x = vec![1.0; 70];
        svc.spmv("a", &x).unwrap();
        let page = svc.render_prometheus();
        assert!(page.contains("csrc_shard_halo_doubles"));
        assert!(page.contains("csrc_shard_requests_total{shard=\"0\"}"));
        assert!(page.contains("csrc_shard_requests_total{shard=\"1\"}"));
        // Shard service counters carry the injected label.
        assert!(page.contains("csrc_requests_submitted_total{shard=\"0\"}"));
        assert!(page.contains("csrc_requests_submitted_total{shard=\"1\"}"));
        svc.shutdown();
    }
}
