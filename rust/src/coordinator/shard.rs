//! Sharded serving: row-block shards behind a scatter/gather front.
//!
//! A [`ShardedMatvecService`] scales the single-process service out the
//! way the paper's §5 scales the kernel out: each registered matrix is
//! row-block partitioned into `nshards` overlapping subdomains (the
//! [`super::distributed`] decomposition — square CSRC part per owned
//! row slab plus a rectangular coupling to the ghost columns), and each
//! shard owns a *complete, private* [`MatvecService`]: its own worker
//! pool, plan cache, decision cache, RCM registry, and
//! [`crate::obs::MetricsRegistry`]. Tuning, drift detection, re-tuning,
//! and metrics are therefore shard-local — one hot shard re-tunes
//! without touching its neighbours, exactly the isolation a NUMA-domain
//! or per-process deployment needs.
//!
//! The front router is thin and synchronous: `spmv`/`spmv_multi`
//! *scatter* x (owned rows per shard, plus a gathered halo of ghost
//! values), submit the k panel columns to every shard (each shard's
//! batcher re-coalesces them into one blocked product), overlap the
//! serial coupling sweep `A_R · halo` with the shards' square products,
//! then *gather* per-shard replies back into y. Scatter and gather are
//! traced as their own phases ([`crate::obs::Phase::Scatter`] /
//! [`crate::obs::Phase::Gather`]).
//!
//! Fault tolerance lives at the front (DESIGN.md §14). Failures are
//! **typed** ([`ServiceError`]): back-pressure and deadline misses are
//! `Retryable` with a suggested back-off, caller bugs are `Fatal`.
//! Queue-full submits are retried a few times with jittered exponential
//! back-off before the product is rejected. Each shard has a **circuit
//! breaker**: `breaker_threshold` consecutive product failures
//! (deadline misses and worker-crash replies — queue-full is healthy
//! back-pressure and does not count) open it; an open breaker routes
//! the shard's row block through the **sequential fallback** — the
//! front computes `A_S · x_owned` itself on the retained square part
//! (slower, never wrong, counted in
//! `csrc_shard_degraded_products_total`) — until the cooldown expires
//! and a half-open probe is admitted. Breaker state is a Prometheus
//! gauge (`csrc_shard_breaker_state`: 0 closed / 1 open / 2 half-open)
//! and every transition bumps
//! `csrc_shard_breaker_transitions_total{shard,to}`.

use super::distributed::DistributedMatrix;
use super::error::{RejectReason, ServiceError};
use super::service::{MatvecService, ServiceConfig};
use super::stats::ServiceStats;
use crate::faults::{self, InjectionPoint};
use crate::obs::{self, Counter, Gauge, MetricsRegistry, Phase};
use crate::sparse::{Csrc, CsrcRect};
use crate::util::{lock_unpoisoned, Rng};
use std::collections::HashMap;
use std::net::SocketAddr;
use std::ops::Range;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::Receiver;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Ceiling on the front's jittered retry back-off between queue-full
/// submit attempts.
const RETRY_BACKOFF_CAP: Duration = Duration::from_millis(20);

/// How many times a product recomputes after observing a concurrent
/// `register`/`update_values` of its key mid-flight before giving up
/// with a typed retryable error.
const MUTATION_RETRY_ATTEMPTS: u32 = 8;

/// Pause between those recomputes — mutations are short (a value
/// memcpy per shard plus a registry swap), so a brief yield suffices.
const MUTATION_RETRY_PAUSE: Duration = Duration::from_micros(200);

/// Sharded-front configuration. `service` is the template every shard's
/// private [`MatvecService`] is started from; a file-backed
/// [`ServiceConfig::decision_cache`] is suffixed `.shard<i>` per shard
/// so persisted tuning decisions stay shard-local too.
#[derive(Clone, Debug)]
pub struct ShardConfig {
    pub nshards: usize,
    /// Max requests in flight per shard (submitted, not yet answered).
    /// A product whose k columns would push a shard past this is
    /// rejected up front — bounded queues, not unbounded growth.
    pub queue_capacity: usize,
    /// Gather-side wait per reply; a shard that misses it fails the
    /// product (and bumps `csrc_shard_deadline_exceeded_total`).
    pub deadline: Duration,
    /// Consecutive product failures (deadline misses, worker-crash
    /// replies) that open a shard's circuit breaker. Queue-full
    /// rejections never count — back-pressure is the system working.
    pub breaker_threshold: u32,
    /// How long an open breaker serves degraded before admitting one
    /// half-open probe product.
    pub breaker_cooldown: Duration,
    /// Submit attempts per shard per product while its queue is full
    /// (the first attempt counts; `1` disables retrying).
    pub retry_attempts: u32,
    /// Base of the jittered exponential back-off between those
    /// attempts (doubled per attempt, capped at 20ms).
    pub retry_backoff: Duration,
    pub service: ServiceConfig,
}

impl Default for ShardConfig {
    fn default() -> Self {
        ShardConfig {
            nshards: 2,
            queue_capacity: 1024,
            deadline: Duration::from_secs(30),
            breaker_threshold: 3,
            breaker_cooldown: Duration::from_millis(250),
            retry_attempts: 3,
            retry_backoff: Duration::from_millis(1),
            service: ServiceConfig::default(),
        }
    }
}

/// Circuit-breaker states, exported so callers can read
/// [`ShardStats::breaker`]. The numeric value is what the
/// `csrc_shard_breaker_state` gauge reports.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BreakerState {
    /// Healthy: products flow to the shard's service.
    Closed = 0,
    /// Tripped: the shard's row block is served by the sequential
    /// fallback until the cooldown expires.
    Open = 1,
    /// Cooldown expired: exactly one probe product is in flight against
    /// the shard; everyone else still degrades.
    HalfOpen = 2,
}

impl BreakerState {
    /// `to` label of `csrc_shard_breaker_transitions_total`.
    pub fn label(self) -> &'static str {
        match self {
            BreakerState::Closed => "closed",
            BreakerState::Open => "open",
            BreakerState::HalfOpen => "half-open",
        }
    }
}

/// What the breaker decided for one product.
enum Admission {
    /// Send the shard's columns to its service; `probe` marks the one
    /// half-open trial product.
    Live { probe: bool },
    /// Serve this shard's row block through the sequential fallback.
    Degraded,
}

struct BreakerInner {
    state: BreakerState,
    /// Consecutive product failures while closed.
    failures: u32,
    /// When the breaker last opened; half-open is admitted once
    /// `cooldown` has elapsed since then.
    opened_at: Option<Instant>,
}

/// Per-shard circuit breaker: closed → (threshold consecutive failures)
/// → open → (cooldown) → half-open probe → closed on success, re-open
/// on failure. All transitions are counted and mirrored into a gauge.
struct Breaker {
    shard: usize,
    threshold: u32,
    cooldown: Duration,
    inner: Mutex<BreakerInner>,
    state_gauge: Gauge,
    obs: Arc<MetricsRegistry>,
}

impl Breaker {
    fn new(
        shard: usize,
        threshold: u32,
        cooldown: Duration,
        obs: &Arc<MetricsRegistry>,
    ) -> Breaker {
        let label = shard.to_string();
        let state_gauge = obs.family_gauge("csrc_shard_breaker_state", &[("shard", &label)]);
        state_gauge.set(BreakerState::Closed as u8 as f64);
        Breaker {
            shard,
            threshold: threshold.max(1),
            cooldown,
            inner: Mutex::new(BreakerInner {
                state: BreakerState::Closed,
                failures: 0,
                opened_at: None,
            }),
            state_gauge,
            obs: obs.clone(),
        }
    }

    /// Move to `to`, mirror the gauge, count the transition. Caller
    /// holds the inner lock (passed as `g`).
    fn transition(&self, g: &mut BreakerInner, to: BreakerState) {
        if g.state == to {
            return;
        }
        let _span = obs::phase(Phase::Breaker);
        g.state = to;
        self.state_gauge.set(to as u8 as f64);
        let label = self.shard.to_string();
        self.obs
            .family_counter(
                "csrc_shard_breaker_transitions_total",
                &[("shard", &label), ("to", to.label())],
            )
            .inc();
    }

    /// Admission decision for one product, advancing open → half-open
    /// when the cooldown has expired.
    fn admit(&self) -> Admission {
        let mut g = lock_unpoisoned(&self.inner);
        match g.state {
            BreakerState::Closed => Admission::Live { probe: false },
            BreakerState::Open => {
                let cooled = match g.opened_at {
                    Some(t) => t.elapsed() >= self.cooldown,
                    None => true,
                };
                if cooled {
                    self.transition(&mut g, BreakerState::HalfOpen);
                    Admission::Live { probe: true }
                } else {
                    Admission::Degraded
                }
            }
            // Someone else's probe is in flight; don't pile on.
            BreakerState::HalfOpen => Admission::Degraded,
        }
    }

    /// The shard answered a whole product: reset the failure streak and
    /// close a half-open breaker (the probe passed).
    fn record_success(&self) {
        let mut g = lock_unpoisoned(&self.inner);
        g.failures = 0;
        if g.state != BreakerState::Closed {
            self.transition(&mut g, BreakerState::Closed);
            g.opened_at = None;
        }
    }

    /// The shard failed a product (deadline miss or worker-crash
    /// reply): trip at the threshold; a failed probe re-opens with a
    /// fresh cooldown.
    fn record_failure(&self) {
        let mut g = lock_unpoisoned(&self.inner);
        match g.state {
            BreakerState::Closed => {
                g.failures += 1;
                if g.failures >= self.threshold {
                    g.opened_at = Some(Instant::now());
                    self.transition(&mut g, BreakerState::Open);
                }
            }
            BreakerState::HalfOpen => {
                g.opened_at = Some(Instant::now());
                self.transition(&mut g, BreakerState::Open);
            }
            BreakerState::Open => {}
        }
    }

    /// The product carrying this shard's probe aborted before the shard
    /// could answer (some *other* shard failed first). Return to open
    /// WITHOUT refreshing `opened_at`: the shard proved nothing either
    /// way, so the next product may probe again immediately.
    fn abort_probe(&self) {
        let mut g = lock_unpoisoned(&self.inner);
        if g.state == BreakerState::HalfOpen {
            self.transition(&mut g, BreakerState::Open);
        }
    }

    fn state(&self) -> BreakerState {
        lock_unpoisoned(&self.inner).state
    }
}

/// One shard's slice of a registered matrix, kept by the front for
/// scatter/gather: the owned row slab, the global ids of the ghost
/// columns, and the rectangular coupling. `rect.square` doubles as the
/// retained sequential fallback — with the shard's breaker open the
/// front runs `A_S · x_owned` itself (slower, never wrong).
struct ShardPart {
    rows: Range<usize>,
    ghosts: Vec<usize>,
    rect: CsrcRect,
}

/// A registered matrix's full decomposition. `parts.len()` may sit
/// below `nshards` for tiny matrices (never more slabs than rows).
struct ShardedParts {
    n: usize,
    parts: Vec<ShardPart>,
}

/// One key's front-side registration: the decomposition plus the
/// seqlock word readers use to detect mutations. The word is odd while
/// a `register`/`update_values` is swapping the decomposition (front
/// parts *and* inner services — they cannot change as one atomic step)
/// and even when stable; a product snapshots it before scattering and
/// re-checks after gathering, recomputing on any change. The handle is
/// shared (`Arc`) so in-flight readers see the bump even across a
/// whole-entry replacement.
struct ShardEntry {
    parts: Arc<ShardedParts>,
    seq: Arc<AtomicU64>,
}

/// Per-shard front counters + the shard's own service snapshot.
#[derive(Clone, Debug)]
pub struct ShardStats {
    pub shard: usize,
    /// Column requests this shard was handed by the front.
    pub requests: u64,
    /// Products rejected at the front because this shard's queue was
    /// full (counted once per product, not per column).
    pub rejects: u64,
    /// Gather-side deadline misses charged to this shard.
    pub deadline_exceeded: u64,
    /// Products whose row block was served by the sequential fallback
    /// because this shard's breaker was open.
    pub degraded: u64,
    /// Current circuit-breaker state.
    pub breaker: BreakerState,
    pub service: ServiceStats,
}

/// Front-side product accounting: every product the front was asked
/// for resolves to completed or rejected — `products == completed +
/// rejected` once the front is quiesced, so no request is ever lost.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FrontStats {
    /// Products submitted to the front (`spmv`/`spmv_multi` calls).
    pub products: u64,
    /// Products that returned `Ok` (including degraded ones).
    pub completed: u64,
    /// Products that returned an error (typed retryable or fatal).
    pub rejected: u64,
    /// Completed products that served ≥1 shard through the fallback.
    pub degraded: u64,
    /// Queue-full submit attempts that were retried after back-off.
    pub retries: u64,
}

pub struct ShardedMatvecService {
    cfg: ShardConfig,
    services: Vec<MatvecService>,
    registry: Mutex<HashMap<String, ShardEntry>>,
    /// Serializes `register` and `update_values` front-wide. With one
    /// mutation in flight at a time, `update_values`' validate→patch
    /// sequence is all-or-nothing: nothing can re-register or re-patch
    /// a shard between the fingerprint validation and the inner
    /// updates, so a post-validation inner failure is unreachable.
    mutation: Mutex<()>,
    /// Front-side registry: scatter/gather counters live here; each
    /// shard's serving metrics stay in its service's own registry.
    obs: Arc<MetricsRegistry>,
    requests: Vec<Counter>,
    rejects: Vec<Counter>,
    deadline_exceeded: Vec<Counter>,
    /// Per-shard `csrc_shard_degraded_products_total`.
    degraded: Vec<Counter>,
    breakers: Vec<Breaker>,
    front_products: Counter,
    front_completed: Counter,
    front_rejected: Counter,
    front_degraded: Counter,
    front_retries: Counter,
    /// Jitter source for the retry back-off (seeded: reproducible).
    rng: Mutex<Rng>,
    /// Total ghost values gathered per single-vector product, summed
    /// over every registered matrix — the halo-volume cost of the
    /// current shard count, scraped by the CI smoke.
    halo: Gauge,
}

impl ShardedMatvecService {
    pub fn start(cfg: ShardConfig) -> ShardedMatvecService {
        assert!(cfg.nshards >= 1, "need at least one shard");
        let obs_reg = Arc::new(MetricsRegistry::new());
        let mut services = Vec::with_capacity(cfg.nshards);
        let mut requests = Vec::with_capacity(cfg.nshards);
        let mut rejects = Vec::with_capacity(cfg.nshards);
        let mut deadline_exceeded = Vec::with_capacity(cfg.nshards);
        let mut degraded = Vec::with_capacity(cfg.nshards);
        let mut breakers = Vec::with_capacity(cfg.nshards);
        for i in 0..cfg.nshards {
            let mut sc = cfg.service.clone();
            if let Some(path) = &mut sc.decision_cache {
                let name = path
                    .file_name()
                    .map(|f| f.to_string_lossy().into_owned())
                    .unwrap_or_else(|| "decisions.json".into());
                path.set_file_name(format!("{name}.shard{i}"));
            }
            services.push(MatvecService::start(sc));
            let l = i.to_string();
            requests.push(obs_reg.family_counter("csrc_shard_requests_total", &[("shard", &l)]));
            rejects.push(obs_reg.family_counter("csrc_shard_rejects_total", &[("shard", &l)]));
            deadline_exceeded.push(
                obs_reg.family_counter("csrc_shard_deadline_exceeded_total", &[("shard", &l)]),
            );
            degraded.push(
                obs_reg.family_counter("csrc_shard_degraded_products_total", &[("shard", &l)]),
            );
            breakers.push(Breaker::new(i, cfg.breaker_threshold, cfg.breaker_cooldown, &obs_reg));
        }
        let halo = obs_reg.gauge("csrc_shard_halo_doubles");
        let front_products = obs_reg.counter("csrc_front_products_total");
        let front_completed = obs_reg.counter("csrc_front_completed_total");
        let front_rejected = obs_reg.counter("csrc_front_rejected_total");
        let front_degraded = obs_reg.counter("csrc_front_degraded_products_total");
        let front_retries = obs_reg.counter("csrc_front_retries_total");
        ShardedMatvecService {
            cfg,
            services,
            registry: Mutex::new(HashMap::new()),
            mutation: Mutex::new(()),
            obs: obs_reg,
            requests,
            rejects,
            deadline_exceeded,
            degraded,
            breakers,
            front_products,
            front_completed,
            front_rejected,
            front_degraded,
            front_retries,
            rng: Mutex::new(Rng::new(0x5eed_f417)),
            halo,
        }
    }

    pub fn nshards(&self) -> usize {
        self.cfg.nshards
    }

    /// Register (or replace) a matrix under a key: decompose it into
    /// row-block subdomains and register each shard's square part with
    /// that shard's private service (which tunes it like any matrix —
    /// every shard is tuner-raced independently). The front keeps the
    /// row slabs, ghost maps, and coupling rectangles for scatter/gather.
    pub fn register(&self, key: &str, a: Arc<Csrc>) {
        let _mutation = lock_unpoisoned(&self.mutation);
        let global = a.to_csr();
        let nsub = self.cfg.nshards.min(global.nrows.max(1));
        // Replacement: the outgoing decomposition's per-shard decisions
        // live on in the `….shard<i>` cache files, keyed by each retired
        // square part's pattern. Their served-rate baselines were
        // calibrated against the old partition and generation — clear
        // them now, or a later registration resolving to the same entry
        // (same shard-local pattern, new values) would judge its serving
        // against a dead generation's rate.
        let seq = {
            let reg = lock_unpoisoned(&self.registry);
            reg.get(key).map(|old| {
                for rank in 0..old.parts.parts.len() {
                    self.services[rank].invalidate_served_baseline(key);
                }
                old.seq.clone()
            })
        };
        // Replacing a live key: mark the entry mid-mutation (odd) so a
        // product in flight — whose snapshotted coupling rectangles are
        // about to stop matching the inner services — recomputes
        // instead of returning a torn answer.
        if let Some(seq) = &seq {
            seq.fetch_add(1, Ordering::AcqRel);
        }
        let dm = DistributedMatrix::from_global(&global, nsub);
        let mut parts = Vec::with_capacity(nsub);
        for sub in dm.subs {
            let rank = sub.rank;
            let local = sub.local;
            self.services[rank].register(key, Arc::new(local.square.clone()));
            parts.push(ShardPart { rows: sub.rows, ghosts: sub.ghosts, rect: local });
        }
        let mut reg = lock_unpoisoned(&self.registry);
        let parts = Arc::new(ShardedParts { n: global.nrows, parts });
        match seq {
            Some(seq) => {
                reg.insert(key.to_string(), ShardEntry { parts, seq: seq.clone() });
                seq.fetch_add(1, Ordering::Release); // even again: stable
            }
            None => {
                reg.insert(
                    key.to_string(),
                    ShardEntry { parts, seq: Arc::new(AtomicU64::new(0)) },
                );
            }
        }
        let total: usize = reg
            .values()
            .map(|e| e.parts.parts.iter().map(|s| s.ghosts.len()).sum::<usize>())
            .sum();
        self.halo.set(total as f64);
    }

    /// In-place value update across the shards: re-decompose the new
    /// values with the SAME slab count (identical pattern ⇒ identical
    /// row slabs and ghost maps), patch each shard's square part
    /// through that shard's [`MatvecService::update_values`] (plans,
    /// colorings, RCM artifacts, and tuned decisions all survive —
    /// only the per-shard values generation, drift EWMA, and served
    /// baselines restart), and swap the front's coupling rectangles.
    ///
    /// Every shard's fingerprint is checked *before* any shard is
    /// patched, so a mismatch is a typed fatal error with no partial
    /// update — the serving state stays the old generation throughout.
    /// Mutations are serialized front-wide (one `register`/
    /// `update_values` at a time), which is what keeps that validation
    /// true while the shards are patched; concurrent *products* that
    /// overlap the patch window observe the entry's seqlock and
    /// recompute rather than mixing generations across shards.
    pub fn update_values(&self, key: &str, values: &Csrc) -> Result<(), ServiceError> {
        let _update_span = obs::phase(Phase::Update);
        let _mutation = lock_unpoisoned(&self.mutation);
        let (old, seq) = lock_unpoisoned(&self.registry)
            .get(key)
            .map(|e| (e.parts.clone(), e.seq.clone()))
            .ok_or_else(|| ServiceError::fatal(format!("unknown matrix {key:?}")))?;
        if values.n != old.n {
            return Err(ServiceError::fatal(format!(
                "update_values({key:?}): got {} rows but {key:?} has {} (re-register instead)",
                values.n, old.n
            )));
        }
        let dm = DistributedMatrix::from_global(&values.to_csr(), old.parts.len());
        // Validation pass: the row-block decomposition is deterministic
        // in (n, nsub), so an unchanged global pattern yields exactly
        // the registered shard patterns — anything else is a caller
        // trying to smuggle a re-registration through the update path.
        // Nothing has been touched yet, so failing here is clean.
        for (sub, part) in dm.subs.iter().zip(&old.parts) {
            if sub.local.square.pattern_fingerprint() != part.rect.square.pattern_fingerprint() {
                return Err(ServiceError::fatal(format!(
                    "update_values({key:?}): shard {} pattern changed (re-register instead)",
                    sub.rank
                )));
            }
        }
        // All shards validated — patch. The entry goes odd first: a
        // product overlapping this window would otherwise snapshot the
        // old coupling rectangles while some inner services already
        // serve the new square values, a torn answer matching neither
        // generation.
        seq.fetch_add(1, Ordering::AcqRel);
        let mut parts = Vec::with_capacity(dm.subs.len());
        for sub in dm.subs {
            let rank = sub.rank;
            let local = sub.local;
            if let Err(e) = self.services[rank].update_values(key, &local.square) {
                // Unreachable after validation with mutations
                // serialized — but never leave the seq odd, or every
                // reader of this key retries until exhaustion.
                seq.fetch_add(1, Ordering::AcqRel);
                return Err(e);
            }
            parts.push(ShardPart { rows: sub.rows, ghosts: sub.ghosts, rect: local });
        }
        if let Some(e) = lock_unpoisoned(&self.registry).get_mut(key) {
            e.parts = Arc::new(ShardedParts { n: old.n, parts });
        }
        seq.fetch_add(1, Ordering::Release); // even again: stable
        Ok(())
    }

    /// y = A·x through the sharded front.
    pub fn spmv(&self, key: &str, x: &[f64]) -> Result<Vec<f64>, ServiceError> {
        self.spmv_multi(key, x, 1)
    }

    /// Y = A·X for a row-major n×k panel. Scatter → k column requests
    /// per live shard (each shard's batcher re-coalesces them into a
    /// blocked product; open-breaker shards fall back to the sequential
    /// path) → coupling sweep on the front thread while the shards run
    /// → gather with per-shard deadlines.
    pub fn spmv_multi(&self, key: &str, x: &[f64], k: usize) -> Result<Vec<f64>, ServiceError> {
        assert!(k >= 1);
        self.front_products.inc();
        match self.spmv_multi_inner(key, x, k) {
            Ok(y) => {
                self.front_completed.inc();
                Ok(y)
            }
            Err(e) => {
                self.front_rejected.inc();
                Err(e)
            }
        }
    }

    /// Snapshot-consistent product: the decomposition snapshot is only
    /// trusted if the entry's seqlock was even (no mutation in flight)
    /// before the product started *and* unchanged after it finished.
    /// Otherwise the answer may mix values generations across shards
    /// (old coupling rectangles against new square parts) and is
    /// discarded and recomputed. A product that keeps losing the race
    /// surfaces as a typed retryable [`RejectReason::ConcurrentUpdate`].
    fn spmv_multi_inner(&self, key: &str, x: &[f64], k: usize) -> Result<Vec<f64>, ServiceError> {
        let mut attempts = 0u32;
        loop {
            // The seq word is sampled inside the same critical section
            // that clones the snapshot: a mutation's parts-swap also
            // takes this lock (with its odd bump ordered before the
            // acquisition), so any swap landing after our release is
            // guaranteed to move the word past `s0` — it can never
            // complete invisibly between the clone and the sample.
            let (parts, seq, s0) = {
                let reg = lock_unpoisoned(&self.registry);
                let e = reg
                    .get(key)
                    .ok_or_else(|| ServiceError::fatal(format!("unknown matrix {key:?}")))?;
                (e.parts.clone(), e.seq.clone(), e.seq.load(Ordering::Acquire))
            };
            if s0 % 2 == 0 {
                let r = self.spmv_once(key, &parts, x, k);
                if seq.load(Ordering::Acquire) == s0 {
                    return r;
                }
                // The seq moved under the product: even an Ok result
                // may be torn across generations — recompute.
            }
            attempts += 1;
            if attempts >= MUTATION_RETRY_ATTEMPTS {
                return Err(ServiceError::Retryable {
                    reason: RejectReason::ConcurrentUpdate,
                    after: self.cfg.retry_backoff.max(Duration::from_millis(1)),
                });
            }
            std::thread::sleep(MUTATION_RETRY_PAUSE);
        }
    }

    /// One scatter → compute → gather pass over a fixed decomposition
    /// snapshot. Only meaningful under [`Self::spmv_multi_inner`]'s
    /// seqlock validation.
    fn spmv_once(
        &self,
        key: &str,
        parts: &ShardedParts,
        x: &[f64],
        k: usize,
    ) -> Result<Vec<f64>, ServiceError> {
        if x.len() != parts.n * k {
            return Err(ServiceError::fatal(format!(
                "x has length {} but {key:?} is {}x{} with k={k}",
                x.len(),
                parts.n,
                parts.n
            )));
        }
        let nparts = parts.parts.len();
        // Breaker admission, before anything is submitted: open-breaker
        // shards are carved out for the sequential fallback; a cooled
        // open breaker admits this product as its half-open probe.
        let mut degraded = vec![false; nparts];
        let mut probing = vec![false; nparts];
        for i in 0..nparts {
            match self.breakers[i].admit() {
                Admission::Live { probe } => probing[i] = probe,
                Admission::Degraded => degraded[i] = true,
            }
        }
        // Back-pressure with bounded retry: a live shard whose queue
        // cannot take k more requests is retried behind a jittered
        // exponential back-off; if it is still full after
        // `retry_attempts` the whole product is rejected with a typed,
        // retryable error before any column is submitted anywhere.
        // `in_flight` over-estimates depth (completed is read first),
        // so a full queue can only look fuller — rejection stays
        // conservative.
        for i in 0..nparts {
            if degraded[i] {
                continue;
            }
            let svc = &self.services[i];
            let mut attempt = 0u32;
            loop {
                let injected = faults::fire(InjectionPoint::QueueFull);
                let depth = svc.in_flight();
                if !injected && depth + k as u64 <= self.cfg.queue_capacity as u64 {
                    break;
                }
                attempt += 1;
                if attempt >= self.cfg.retry_attempts.max(1) {
                    self.rejects[i].inc();
                    self.count_rejection(i, "queue-full");
                    self.abort_probes(&mut probing);
                    return Err(ServiceError::Retryable {
                        reason: RejectReason::QueueFull {
                            shard: i,
                            depth: depth as usize,
                            capacity: self.cfg.queue_capacity,
                        },
                        after: self.retry_delay(attempt),
                    });
                }
                self.front_retries.inc();
                std::thread::sleep(self.retry_delay(attempt - 1));
            }
        }
        // Scatter: per shard, slice the owned rows out of each panel
        // column and gather the ghost values into a halo panel.
        // Degraded shards get a halo (the coupling sweep still needs
        // it) but no submits.
        let mut pending: Vec<Option<Vec<Receiver<Result<Vec<f64>, ServiceError>>>>> =
            Vec::with_capacity(nparts);
        let mut halos = Vec::with_capacity(nparts);
        {
            let _span = obs::phase(Phase::Scatter);
            for (i, part) in parts.parts.iter().enumerate() {
                let mut halo = vec![0.0; part.ghosts.len() * k];
                for (g, &gj) in part.ghosts.iter().enumerate() {
                    halo[g * k..g * k + k].copy_from_slice(&x[gj * k..gj * k + k]);
                }
                halos.push(halo);
                if degraded[i] {
                    pending.push(None);
                    continue;
                }
                let mut cols = Vec::with_capacity(k);
                for c in 0..k {
                    let xs: Vec<f64> = part.rows.clone().map(|r| x[r * k + c]).collect();
                    cols.push(self.services[i].submit(key, xs));
                }
                self.requests[i].add(k as u64);
                pending.push(Some(cols));
            }
        }
        // Coupling sweeps run here, overlapped with the shards' square
        // products: y_shard = service(A_S · x_owned) + A_R · halo.
        let coups: Vec<Vec<f64>> = parts
            .parts
            .iter()
            .zip(&halos)
            .map(|(part, halo)| {
                let mut coup = vec![0.0; part.rows.len() * k];
                part.rect.coupling_spmv_multi_into(halo, &mut coup, k);
                coup
            })
            .collect();
        // Gather: collect every live shard's columns (deadline per
        // reply), run the sequential fallback for degraded shards, and
        // add the coupling contribution back into the global panel.
        let mut served_degraded = false;
        let mut y = vec![0.0; parts.n * k];
        {
            let _span = obs::phase(Phase::Gather);
            for (i, (part, cols)) in parts.parts.iter().zip(pending).enumerate() {
                let coup = &coups[i];
                let Some(cols) = cols else {
                    // Open breaker: the front computes this row block
                    // itself on the retained square part — degraded
                    // (sequential, no batching) but never wrong.
                    let _deg = obs::phase(Phase::Degraded);
                    self.degraded[i].inc();
                    served_degraded = true;
                    for c in 0..k {
                        let xs: Vec<f64> = part.rows.clone().map(|r| x[r * k + c]).collect();
                        let mut yc = vec![0.0; part.rows.len()];
                        part.rect.square.spmv_into_zeroed(&xs, &mut yc);
                        for (r, v) in yc.into_iter().enumerate() {
                            y[(part.rows.start + r) * k + c] = v + coup[r * k + c];
                        }
                    }
                    continue;
                };
                for (c, rx) in cols.into_iter().enumerate() {
                    let blown = faults::fire(InjectionPoint::DeadlineBlow);
                    let reply = if blown {
                        Err(())
                    } else {
                        rx.recv_timeout(self.cfg.deadline).map_err(|_| ())
                    };
                    let yc = match reply {
                        Ok(Ok(yc)) => yc,
                        Ok(Err(e)) => {
                            return Err(self.shard_reply_failed(i, e, &mut probing));
                        }
                        Err(()) => {
                            self.deadline_exceeded[i].inc();
                            self.count_rejection(i, "deadline-exceeded");
                            probing[i] = false;
                            self.breakers[i].record_failure();
                            self.abort_probes(&mut probing);
                            return Err(ServiceError::Retryable {
                                reason: RejectReason::DeadlineExceeded {
                                    shard: i,
                                    deadline: self.cfg.deadline,
                                },
                                after: self.cfg.breaker_cooldown,
                            });
                        }
                    };
                    for (r, v) in yc.into_iter().enumerate() {
                        y[(part.rows.start + r) * k + c] = v + coup[r * k + c];
                    }
                }
                // Every column of this shard answered in time: one
                // product-level success (closes a half-open probe).
                probing[i] = false;
                self.breakers[i].record_success();
            }
        }
        if served_degraded {
            self.front_degraded.inc();
        }
        Ok(y)
    }

    /// A shard's service replied with an error mid-gather: charge the
    /// breaker for transient failures, fill in the shard index, release
    /// any other shard's probe, and hand the typed error up.
    fn shard_reply_failed(
        &self,
        shard: usize,
        e: ServiceError,
        probing: &mut [bool],
    ) -> ServiceError {
        let out = match e {
            ServiceError::Retryable { reason, after } => {
                let reason = match reason {
                    RejectReason::WorkerCrashed { .. } => {
                        RejectReason::WorkerCrashed { shard: Some(shard) }
                    }
                    other => other,
                };
                self.count_rejection(shard, reason.label());
                probing[shard] = false;
                self.breakers[shard].record_failure();
                ServiceError::Retryable { reason, after }
            }
            // Caller bugs (unknown key, wrong length) are not shard
            // health signals: no breaker charge.
            ServiceError::Fatal(msg) => ServiceError::fatal(format!("shard {shard}: {msg}")),
        };
        self.abort_probes(probing);
        out
    }

    /// Release every probe this product was carrying (early return: the
    /// probed shards proved nothing).
    fn abort_probes(&self, probing: &mut [bool]) {
        for (i, p) in probing.iter_mut().enumerate() {
            if *p {
                self.breakers[i].abort_probe();
                *p = false;
            }
        }
    }

    /// Jittered exponential back-off for queue-full retries: `base ·
    /// 2^attempt` capped at [`RETRY_BACKOFF_CAP`], plus up to 50%
    /// seeded jitter so synchronized callers de-correlate.
    fn retry_delay(&self, attempt: u32) -> Duration {
        let base = self.cfg.retry_backoff.max(Duration::from_micros(50));
        let exp = base.saturating_mul(1u32 << attempt.min(10));
        let capped = exp.min(RETRY_BACKOFF_CAP);
        let span = (capped.as_micros() as usize / 2).max(1);
        let jitter = lock_unpoisoned(&self.rng).below(span) as u64;
        capped + Duration::from_micros(jitter)
    }

    /// Bump `csrc_shard_rejections_total{shard,reason}`.
    fn count_rejection(&self, shard: usize, reason: &str) {
        let l = shard.to_string();
        self.obs
            .family_counter("csrc_shard_rejections_total", &[("shard", &l), ("reason", reason)])
            .inc();
    }

    /// Per-shard stats: front counters + each shard's service snapshot.
    pub fn stats(&self) -> Vec<ShardStats> {
        self.services
            .iter()
            .enumerate()
            .map(|(i, svc)| ShardStats {
                shard: i,
                requests: self.requests[i].get(),
                rejects: self.rejects[i].get(),
                deadline_exceeded: self.deadline_exceeded[i].get(),
                degraded: self.degraded[i].get(),
                breaker: self.breakers[i].state(),
                service: svc.stats(),
            })
            .collect()
    }

    /// Front-side product accounting (products/completed/rejected/
    /// degraded/retries) — the chaos harness asserts
    /// `products == completed + rejected` so no request is ever lost.
    pub fn front_stats(&self) -> FrontStats {
        FrontStats {
            products: self.front_products.get(),
            completed: self.front_completed.get(),
            rejected: self.front_rejected.get(),
            degraded: self.front_degraded.get(),
            retries: self.front_retries.get(),
        }
    }

    /// Current halo volume (ghost doubles gathered per single-vector
    /// product, summed over registered matrices).
    pub fn halo_doubles(&self) -> f64 {
        self.halo.get()
    }

    /// One Prometheus page for the whole deployment: the front's
    /// registry (with the process-wide phase totals, emitted once) plus
    /// every shard's registry with a `shard="<i>"` label injected into
    /// each sample.
    pub fn render_prometheus(&self) -> String {
        let mut out = self.obs.render_prometheus();
        for (i, svc) in self.services.iter().enumerate() {
            let label = i.to_string();
            out.push_str(
                &svc.metrics_registry().render_prometheus_with(&[("shard", &label)], false),
            );
        }
        out
    }

    /// Serve the composed page on a scrape endpoint
    /// (`csrc serve --shards N --metrics-addr`).
    pub fn serve_metrics(&self, addr: &str) -> std::io::Result<SocketAddr> {
        let front = self.obs.clone();
        let shards: Vec<Arc<MetricsRegistry>> =
            self.services.iter().map(|s| s.metrics_registry()).collect();
        obs::serve_rendered(addr, move || {
            let mut out = front.render_prometheus();
            for (i, r) in shards.iter().enumerate() {
                let label = i.to_string();
                out.push_str(&r.render_prometheus_with(&[("shard", &label)], false));
            }
            out
        })
    }

    /// Graceful shutdown: every shard drains and joins.
    pub fn shutdown(mut self) {
        for svc in self.services.drain(..) {
            svc.shutdown();
        }
    }
}

impl Drop for ShardedMatvecService {
    fn drop(&mut self) {
        // Each MatvecService joins its entire supervision tree (workers,
        // retuner, dispatcher, supervisor) in its own Drop, so dropping
        // the front never detaches a thread. Drain explicitly so the
        // shards come down in order even if a panic is unwinding.
        for svc in self.services.drain(..) {
            drop(svc);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::batcher::BatchPolicy;
    use super::super::test_support::{doctored_decision, mat};
    use super::*;
    use crate::parallel::EngineKind;
    use crate::sparse::LinOp;
    use crate::tuner::{self, DecisionCache, TrialBudget};

    fn assert_close(got: &[f64], want: &[f64]) {
        assert_eq!(got.len(), want.len());
        for (i, (g, w)) in got.iter().zip(want).enumerate() {
            // Summation order differs across the shard boundary — bit
            // equality is not expected, 1e-11 relative is.
            assert!(
                (g - w).abs() <= 1e-11 * (1.0 + w.abs()),
                "index {i}: got {g}, want {w}"
            );
        }
    }

    #[test]
    fn sharded_spmv_matches_unsharded_for_every_shard_count() {
        let a = mat(120, 71);
        let x: Vec<f64> = (0..120).map(|i| (i as f64 * 0.37).sin()).collect();
        let mut want = vec![0.0; 120];
        a.apply(&x, &mut want);
        for nshards in [1usize, 2, 4, 7] {
            let svc = ShardedMatvecService::start(ShardConfig {
                nshards,
                ..ShardConfig::default()
            });
            svc.register("a", a.clone());
            let got = svc.spmv("a", &x).unwrap();
            assert_close(&got, &want);
            if nshards > 1 {
                assert!(svc.halo_doubles() > 0.0, "overlap decomposition must have ghosts");
            }
            let stats = svc.stats();
            assert_eq!(stats.len(), nshards);
            assert!(stats.iter().all(|s| s.rejects == 0 && s.deadline_exceeded == 0));
            assert!(stats.iter().all(|s| s.degraded == 0 && s.breaker == BreakerState::Closed));
            let f = svc.front_stats();
            assert_eq!(f.products, 1);
            assert_eq!(f.completed, 1);
            assert_eq!(f.rejected, 0);
            svc.shutdown();
        }
    }

    #[test]
    fn sharded_spmv_multi_matches_unsharded_for_every_shard_count() {
        let n = 96;
        let k = 4;
        let a = mat(n, 72);
        let x: Vec<f64> = (0..n * k).map(|i| (i as f64 * 0.13).cos()).collect();
        let mut want = vec![0.0; n * k];
        a.apply_multi(&x, &mut want, k);
        for nshards in [1usize, 2, 4, 7] {
            let svc = ShardedMatvecService::start(ShardConfig {
                nshards,
                ..ShardConfig::default()
            });
            svc.register("a", a.clone());
            let got = svc.spmv_multi("a", &x, k).unwrap();
            assert_close(&got, &want);
            // Every shard served k column requests.
            for s in svc.stats() {
                assert_eq!(s.requests, k as u64, "shard {}", s.shard);
            }
            svc.shutdown();
        }
    }

    #[test]
    fn replacing_a_matrix_reshards_it() {
        let svc =
            ShardedMatvecService::start(ShardConfig { nshards: 3, ..ShardConfig::default() });
        let a = mat(80, 73);
        let b = mat(64, 74);
        svc.register("m", a);
        let halo_a = svc.halo_doubles();
        svc.register("m", b.clone());
        assert_ne!(svc.halo_doubles(), halo_a, "replacement must re-decompose");
        let x: Vec<f64> = (0..64).map(|i| 1.0 / (1.0 + i as f64)).collect();
        let mut want = vec![0.0; 64];
        b.apply(&x, &mut want);
        assert_close(&svc.spmv("m", &x).unwrap(), &want);
        svc.shutdown();
    }

    #[test]
    fn update_values_patches_every_shard_without_retuning() {
        let a = mat(90, 203);
        let svc =
            ShardedMatvecService::start(ShardConfig { nshards: 3, ..ShardConfig::default() });
        svc.register("a", a.clone());
        let x: Vec<f64> = (0..90).map(|i| (i as f64 * 0.1).cos()).collect();
        let mut want = vec![0.0; 90];
        a.apply(&x, &mut want);
        assert_close(&svc.spmv("a", &x).unwrap(), &want);
        let before = svc.stats();
        // Same pattern, values × 2 — one time step's worth of change.
        let mut b = (*a).clone();
        for v in b.ad.iter_mut().chain(b.al.iter_mut()).chain(b.au.iter_mut()) {
            *v *= 2.0;
        }
        svc.update_values("a", &b).unwrap();
        let mut want2 = vec![0.0; 90];
        b.apply(&x, &mut want2);
        assert_close(&svc.spmv("a", &x).unwrap(), &want2);
        let after = svc.stats();
        for (b4, af) in before.iter().zip(&after) {
            assert_eq!(
                af.service.tunes, b4.service.tunes,
                "shard {}: an in-place update must not re-tune",
                af.shard
            );
            assert_eq!(
                af.service.plan_builds, b4.service.plan_builds,
                "shard {}: plans survive a value update",
                af.shard
            );
            assert_eq!(af.service.value_updates, b4.service.value_updates + 1);
        }
        // The update path refuses a changed pattern or an unknown key —
        // typed fatal errors, and no shard is left half-patched.
        let c = mat(90, 204);
        assert!(!svc.update_values("a", &c).unwrap_err().is_retryable());
        assert_close(&svc.spmv("a", &x).unwrap(), &want2);
        assert!(!svc.update_values("nope", &b).unwrap_err().is_retryable());
        svc.shutdown();
    }

    #[test]
    fn concurrent_updates_never_tear_sharded_products() {
        // Regression (review): a product overlapping a sharded
        // `update_values` must never gather a torn answer — snapshotted
        // coupling rectangles of one values generation against inner
        // services already serving another. Values are scaled by
        // power-of-two factors so every *consistent* product matches
        // exactly one factor's reference; a torn one mixes factors
        // across row blocks (or between the square and coupling
        // contributions of a single block) and matches none.
        const FACTORS: [f64; 4] = [1.0, 2.0, 4.0, 8.0];
        let n = 96;
        let a = mat(n, 205);
        let x: Vec<f64> = (0..n).map(|i| (i as f64 * 0.23).sin() + 1.5).collect();
        let mut base = vec![0.0; n];
        a.apply(&x, &mut base);
        let refs: Vec<Vec<f64>> =
            FACTORS.iter().map(|f| base.iter().map(|w| w * f).collect()).collect();
        let close = |got: &[f64], want: &[f64]| {
            got.iter().zip(want).all(|(g, w)| (g - w).abs() <= 1e-10 * (1.0 + w.abs()))
        };
        let svc =
            ShardedMatvecService::start(ShardConfig { nshards: 2, ..ShardConfig::default() });
        svc.register("m", a.clone());
        let steps = 24u32;
        std::thread::scope(|s| {
            let (svc, a, x, refs, close) = (&svc, &a, &x, &refs, &close);
            let done = &std::sync::atomic::AtomicBool::new(false);
            s.spawn(move || {
                for step in 0..steps {
                    let f = FACTORS[step as usize % FACTORS.len()];
                    let mut b = (**a).clone();
                    for v in b.ad.iter_mut().chain(b.al.iter_mut()).chain(b.au.iter_mut()) {
                        *v *= f;
                    }
                    svc.update_values("m", &b).unwrap();
                    std::thread::sleep(Duration::from_micros(200));
                }
                done.store(true, Ordering::Release);
            });
            for _ in 0..3 {
                s.spawn(move || {
                    let mut served = 0u32;
                    // Generous attempt bound: readers must observe at
                    // least one product but never hang if every attempt
                    // keeps losing the race (they should not — updates
                    // stop once the updater finishes).
                    for _ in 0..20_000 {
                        match svc.spmv("m", x) {
                            Ok(y) => {
                                served += 1;
                                assert!(
                                    refs.iter().any(|r| close(&y, r)),
                                    "torn product: matches no single values generation"
                                );
                            }
                            Err(e) if e.is_retryable() => {
                                std::thread::sleep(Duration::from_micros(200));
                            }
                            Err(e) => panic!("fatal error under concurrent updates: {e}"),
                        }
                        if done.load(Ordering::Acquire) && served > 0 {
                            break;
                        }
                    }
                    assert!(served > 0, "reader never completed a product");
                });
            }
        });
        // Quiesced: the final serve must carry the last update's values.
        let last = &refs[(steps as usize - 1) % FACTORS.len()];
        let y = svc.spmv("m", &x).unwrap();
        assert!(close(&y, last), "settled product must serve the final values generation");
        svc.shutdown();
    }

    #[test]
    fn replacing_a_key_clears_stale_shard_cache_baselines() {
        // Satellite (ISSUE 10): the per-shard decision caches
        // (`….shard<i>` files) key entries by the *shard-local*
        // pattern, so a replaced matrix's old partition lives on in
        // them, served baselines included. Replacement must clear those
        // baselines: a later registration resolving to the same shard
        // pattern would otherwise be calibrated against the serving
        // rate of a dead partition generation.
        let dir = std::env::temp_dir().join(format!("csrc_shard_stale_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("decisions.json");
        let a = mat(80, 201);
        // The old partition's square-part fingerprints, shard by shard.
        let dm = DistributedMatrix::from_global(&a.to_csr(), 2);
        let fps: Vec<u64> =
            dm.subs.iter().map(|s| tuner::fingerprint(&s.local.square)).collect();
        for (i, fp) in fps.iter().enumerate() {
            let cache = DecisionCache::open(&dir.join(format!("decisions.json.shard{i}")));
            cache.put(doctored_decision(*fp, 1.0));
            cache.set_served_rate(*fp, 2, 1e9);
        }
        let mut service = ServiceConfig::default();
        service.route.parallel_kind = EngineKind::Auto;
        service.route.min_parallel_n = 1;
        service.route.threads = 2;
        service.route.sweep_threads = true;
        service.tune_budget = TrialBudget::smoke();
        service.decision_cache = Some(path);
        let svc = ShardedMatvecService::start(ShardConfig {
            nshards: 2,
            service,
            ..ShardConfig::default()
        });
        svc.register("m", a.clone());
        assert!(
            svc.stats().iter().all(|s| s.service.tunes == 0),
            "both shards' doctored entries must be cache hits"
        );
        // Replace the key with a different matrix: the old partition's
        // entries are orphaned, and their baselines must die with it.
        svc.register("m", mat(64, 202));
        svc.shutdown();
        for (i, fp) in fps.iter().enumerate() {
            let back = DecisionCache::open(&dir.join(format!("decisions.json.shard{i}")));
            let d = back.get(*fp, 2).expect("old partition's decision entry survives");
            assert_eq!(
                d.served_mflops, 0.0,
                "shard {i}: replaced partition's served baseline must be cleared"
            );
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn unknown_key_and_wrong_length_fail_cleanly() {
        let svc =
            ShardedMatvecService::start(ShardConfig { nshards: 2, ..ShardConfig::default() });
        let e = svc.spmv("nope", &[1.0, 2.0]).unwrap_err();
        assert!(!e.is_retryable(), "unknown key is a caller bug");
        svc.register("a", mat(40, 75));
        let short = vec![0.0; 39];
        let e = svc.spmv("a", &short).unwrap_err();
        assert!(!e.is_retryable(), "wrong length is a caller bug");
        // Fatal rejections still balance the front's books.
        let f = svc.front_stats();
        assert_eq!(f.products, 2);
        assert_eq!(f.rejected, 2);
        assert_eq!(f.completed, 0);
        svc.shutdown();
    }

    #[test]
    fn full_shard_queue_rejects_with_a_typed_retryable_error() {
        // One shard whose dispatcher parks partial batches for 300ms: a
        // submitted product sits in flight for the whole window, so a
        // second product arriving mid-window must bounce off the
        // capacity-1 queue — typed rejection after bounded retries, not
        // unbounded growth or a hang.
        let cfg = ShardConfig {
            nshards: 1,
            queue_capacity: 1,
            service: ServiceConfig {
                workers: 1,
                batch: BatchPolicy {
                    max_batch: 64,
                    max_wait: std::time::Duration::from_millis(300),
                },
                ..ServiceConfig::default()
            },
            ..ShardConfig::default()
        };
        let svc = Arc::new(ShardedMatvecService::start(cfg));
        let n = 60;
        let a = mat(n, 76);
        svc.register("a", a);
        let x: Vec<f64> = (0..n).map(|i| i as f64).collect();
        let first = {
            let svc = svc.clone();
            let x = x.clone();
            std::thread::spawn(move || svc.spmv("a", &x))
        };
        // Land inside the 300ms batching window with a wide margin.
        std::thread::sleep(std::time::Duration::from_millis(50));
        let second = svc.spmv("a", &x);
        let err = second.expect_err("saturated shard must reject");
        assert!(err.is_retryable(), "back-pressure must be retryable: {err}");
        assert!(err.retry_after().is_some());
        assert_eq!(err.reason().unwrap().label(), "queue-full");
        assert_eq!(err.reason().unwrap().shard(), Some(0));
        assert!(err.to_string().contains("queue full"), "{err}");
        assert!(first.join().unwrap().is_ok(), "parked product still completes");
        assert_eq!(svc.stats()[0].rejects, 1);
        let f = svc.front_stats();
        assert!(f.retries >= 1, "the front must retry before rejecting");
        // The labeled rejection family carries the reason.
        let page = svc.render_prometheus();
        assert!(
            page.contains("csrc_shard_rejections_total{reason=\"queue-full\",shard=\"0\"}"),
            "{page}"
        );
        // Capacity frees up once the first product drains; queue-full
        // rejections must NOT have tripped the breaker.
        assert!(svc.spmv("a", &x).is_ok());
        assert_eq!(svc.stats()[0].breaker, BreakerState::Closed);
    }

    #[test]
    fn breaker_state_machine_opens_probes_and_recovers() {
        let obs = Arc::new(MetricsRegistry::new());
        let b = Breaker::new(0, 2, Duration::from_millis(30), &obs);
        assert_eq!(b.state(), BreakerState::Closed);
        // One failure stays closed (threshold 2); success resets the
        // streak, so two non-consecutive failures don't trip it.
        b.record_failure();
        b.record_success();
        b.record_failure();
        assert_eq!(b.state(), BreakerState::Closed);
        b.record_failure();
        assert_eq!(b.state(), BreakerState::Open);
        // Open degrades until the cooldown expires…
        assert!(matches!(b.admit(), Admission::Degraded));
        std::thread::sleep(Duration::from_millis(45));
        // …then admits exactly one half-open probe; a concurrent
        // product still degrades.
        assert!(matches!(b.admit(), Admission::Live { probe: true }));
        assert!(matches!(b.admit(), Admission::Degraded));
        // Probe failure re-opens (fresh cooldown).
        b.record_failure();
        assert_eq!(b.state(), BreakerState::Open);
        std::thread::sleep(Duration::from_millis(45));
        assert!(matches!(b.admit(), Admission::Live { probe: true }));
        // Probe success closes.
        b.record_success();
        assert_eq!(b.state(), BreakerState::Closed);
        // An abandoned probe (early return elsewhere) restores Open
        // WITHOUT refreshing the cooldown clock: the very next product
        // may probe again.
        b.record_failure();
        b.record_failure();
        assert_eq!(b.state(), BreakerState::Open);
        std::thread::sleep(Duration::from_millis(45));
        assert!(matches!(b.admit(), Admission::Live { probe: true }));
        b.abort_probe();
        assert_eq!(b.state(), BreakerState::Open);
        assert!(matches!(b.admit(), Admission::Live { probe: true }));
        b.record_success();
        // Transitions were counted and the gauge mirrors the state.
        let page = obs.render_prometheus();
        assert!(page.contains("csrc_shard_breaker_state{shard=\"0\"} 0"), "{page}");
        assert!(
            page.contains("csrc_shard_breaker_transitions_total{shard=\"0\",to=\"open\"}"),
            "{page}"
        );
        assert!(
            page.contains("csrc_shard_breaker_transitions_total{shard=\"0\",to=\"half-open\"}"),
            "{page}"
        );
        assert!(
            page.contains("csrc_shard_breaker_transitions_total{shard=\"0\",to=\"closed\"}"),
            "{page}"
        );
    }

    #[test]
    fn open_breaker_serves_the_row_block_degraded_and_correct() {
        // Force shard 1's breaker open by hand, then serve: the product
        // must still be exactly right (sequential fallback + coupling)
        // and the degraded counters must show it.
        let a = mat(100, 78);
        let x: Vec<f64> = (0..100).map(|i| (i as f64 * 0.21).sin()).collect();
        let mut want = vec![0.0; 100];
        a.apply(&x, &mut want);
        let svc = ShardedMatvecService::start(ShardConfig {
            nshards: 2,
            breaker_threshold: 1,
            breaker_cooldown: Duration::from_secs(3600), // stays open
            ..ShardConfig::default()
        });
        svc.register("a", a.clone());
        svc.breakers[1].record_failure();
        assert_eq!(svc.stats()[1].breaker, BreakerState::Open);
        for _ in 0..2 {
            let got = svc.spmv("a", &x).unwrap();
            assert_close(&got, &want);
        }
        let stats = svc.stats();
        assert_eq!(stats[1].degraded, 2, "both products served shard 1 degraded");
        assert_eq!(stats[0].degraded, 0);
        // Shard 1's service saw no requests while degraded.
        assert_eq!(stats[1].requests, 0);
        assert_eq!(stats[0].requests, 2);
        let f = svc.front_stats();
        assert_eq!(f.completed, 2);
        assert_eq!(f.degraded, 2);
        let page = svc.render_prometheus();
        assert!(page.contains("csrc_shard_degraded_products_total{shard=\"1\"} 2"), "{page}");
        assert!(page.contains("csrc_shard_breaker_state{shard=\"1\"} 1"), "{page}");
        svc.shutdown();
    }

    #[test]
    fn composed_scrape_carries_shard_labels_and_halo_gauge() {
        let svc =
            ShardedMatvecService::start(ShardConfig { nshards: 2, ..ShardConfig::default() });
        svc.register("a", mat(70, 77));
        let x = vec![1.0; 70];
        svc.spmv("a", &x).unwrap();
        let page = svc.render_prometheus();
        assert!(page.contains("csrc_shard_halo_doubles"));
        assert!(page.contains("csrc_shard_requests_total{shard=\"0\"}"));
        assert!(page.contains("csrc_shard_requests_total{shard=\"1\"}"));
        // Shard service counters carry the injected label.
        assert!(page.contains("csrc_requests_submitted_total{shard=\"0\"}"));
        assert!(page.contains("csrc_requests_submitted_total{shard=\"1\"}"));
        // Breaker gauges for both shards start closed.
        assert!(page.contains("csrc_shard_breaker_state{shard=\"0\"} 0"));
        assert!(page.contains("csrc_shard_breaker_state{shard=\"1\"} 0"));
        svc.shutdown();
    }
}
