//! Shard-local background re-tuner: drift-triggered re-measurement and
//! decision-cache write-backs, off the request path.
//!
//! One `matvec-retuner` thread per [`MatvecService`](super::MatvecService)
//! — so under a sharded front every shard re-tunes against its *own*
//! row-block independently, with its own decision cache and drift
//! state.

use super::registration::{DriftState, Registry, ResolvedAuto};
use super::router::RoutePolicy;
use super::stats::Counters;
use crate::obs::{self, Phase};
use crate::plan::{PlanBuilder, PlanCache};
use crate::sparse::SpmvKernel;
use crate::tuner::{self, DecisionCache, TrialBudget};
use crate::util::lock_unpoisoned;
use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::Receiver;
use std::sync::{Arc, Mutex};

/// The re-tune channel, shared so the supervisor can hand the *same*
/// receiver to a respawned re-tuner: queued jobs survive a crash.
pub(crate) type SharedRetuneRx = Arc<Mutex<Receiver<RetunerMsg>>>;

/// A drift-triggered re-tune request, handled off the request path.
pub(crate) struct RetuneJob {
    pub(crate) matrix: String,
    pub(crate) cache_key: String,
    pub(crate) generation: u64,
}

/// Work for the `matvec-retuner` thread — everything that must stay off
/// the request path.
pub(crate) enum RetunerMsg {
    /// Re-run the measured trials and upgrade the decision entry.
    Retune(RetuneJob),
    /// Persist a calibration window's served-EWMA baseline into the
    /// cache entry. `DecisionCache::set_served_rate` rewrites the whole
    /// file, so a worker must not pay for it inside a batch.
    RecordServedRate { fingerprint: u64, max_threads: usize, mflops: f64 },
}

/// Everything the background re-tuner shares with the service. `Clone`
/// so the supervisor can keep a respawn template.
#[derive(Clone)]
pub(crate) struct RetunerCtx {
    pub(crate) registry: Arc<Mutex<Registry>>,
    pub(crate) plans: Arc<PlanCache>,
    pub(crate) route: RoutePolicy,
    pub(crate) budget: TrialBudget,
    pub(crate) decisions: Arc<DecisionCache>,
    pub(crate) resolved: Arc<Mutex<HashMap<String, ResolvedAuto>>>,
    pub(crate) drift: Arc<Mutex<HashMap<String, DriftState>>>,
    pub(crate) stats: Arc<Counters>,
}

/// Drain re-tuner work: drift-triggered re-tunes (re-run the measured
/// trials — the sweep when `route.sweep_threads` — against the
/// *current* machine state, upgrade the decision-cache entry in place,
/// republish the resolution for workers, and reset the key's drift
/// state into calibration) and served-baseline write-backs the workers
/// hand off (a full cache-file rewrite each — request-path poison).
///
/// Each message is handled under `catch_unwind`, so a panicking re-tune
/// loses *that job only*; the loop reports `true` ("crashed") so the
/// supervisor respawns a fresh re-tuner against the same shared
/// receiver. Returns `false` on a clean channel close.
pub(crate) fn retuner_loop(rx: SharedRetuneRx, ctx: RetunerCtx) -> bool {
    loop {
        let msg = match lock_unpoisoned(&rx).recv() {
            Ok(msg) => msg,
            Err(_) => return false, // every sender dropped: clean shutdown
        };
        if catch_unwind(AssertUnwindSafe(|| handle_retuner_msg(&ctx, msg))).is_err() {
            // The job is lost (drift will re-flag it), but the thread
            // must not die silently: report the crash for respawn.
            ctx.stats.panics_caught.inc();
            return true;
        }
    }
}

fn handle_retuner_msg(ctx: &RetunerCtx, msg: RetunerMsg) {
    {
        let job = match msg {
            RetunerMsg::Retune(job) => job,
            RetunerMsg::RecordServedRate { fingerprint, max_threads, mflops } => {
                ctx.decisions.set_served_rate(fingerprint, max_threads, mflops);
                return;
            }
        };
        let hit = lock_unpoisoned(&ctx.registry).get(&job.matrix).cloned();
        let Some(entry) = hit else { return };
        if entry.generation != job.generation {
            return; // replaced since the drift was observed
        }
        let a = entry.a;
        let _retune_span = obs::phase(Phase::Retune);
        let kernel: Arc<dyn SpmvKernel> = a.clone();
        // A zero budget cannot produce the measured decision a drift
        // repair needs; degrade to the cheapest measuring budget.
        let budget = if ctx.budget.is_zero() { TrialBudget::smoke() } else { ctx.budget };
        let threads = ctx.route.threads.max(1);
        let d = if ctx.route.sweep_threads {
            let ladder = tuner::thread_ladder(threads);
            let mut plan_for = tuner::cached_plan_provider(&ctx.plans, &job.cache_key, &kernel);
            let d = tuner::sweep_reordered(
                &kernel,
                &ladder,
                &budget,
                &mut plan_for,
                ctx.route.reorder,
            );
            ctx.plans.invalidate_other_threads(&job.cache_key, d.nthreads);
            // Reordered (`#rcm`) plans workers built at the losing
            // thread counts are dead weight too.
            ctx.plans
                .invalidate_other_threads(&format!("{}#rcm", job.cache_key), d.nthreads);
            d
        } else {
            let plan = ctx.plans.get_or_build(
                &job.cache_key,
                kernel.as_ref(),
                PlanBuilder::new(threads).with_pieces(tuner::required_pieces(threads)),
            );
            tuner::tune_reordered(&kernel, &plan, &budget, ctx.route.reorder)
        };
        // The fresh measurement is keyed by structure fingerprint, so it
        // is worth persisting even if the registration changed under us.
        ctx.decisions.put(d.clone());
        // Publish to the workers only if the generation is still
        // current: register() may have replaced the matrix while we
        // measured, and it already purged this generation's entries —
        // re-inserting would resurrect dead keys. The registry check
        // happens *under* the map locks, so a concurrent replacement
        // either purges after our insert or we observe its generation
        // bump and skip.
        {
            let mut resolved = lock_unpoisoned(&ctx.resolved);
            let mut drift = lock_unpoisoned(&ctx.drift);
            let current = lock_unpoisoned(&ctx.registry).get(&job.matrix).map(|e| e.generation)
                == Some(job.generation);
            if !current {
                return;
            }
            resolved.insert(job.cache_key.clone(), ResolvedAuto::from_decision(&d));
            // Fresh state (`retune_pending` cleared) in *calibration*
            // mode: the next drift_min_batches batches record the
            // served EWMA as the new entry's baseline instead of being
            // judged against its warm trial rate — see maybe_flag_drift
            // (this is what stops the re-tune storm).
            drift.insert(job.cache_key, DriftState { calibrating: true, ..Default::default() });
        }
        ctx.stats.retunes.inc();
        ctx.stats.add_tune_seconds(d.tuned_s);
    }
}

#[cfg(test)]
mod tests {
    use super::super::test_support::{doctored_decision, mat};
    use super::super::{MatvecService, ServiceConfig};
    use super::*;
    use crate::parallel::EngineKind;
    use crate::sparse::Csrc;

    #[test]
    fn drift_triggers_background_retune() {
        let dir = std::env::temp_dir().join(format!("csrc_drift_svc_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("decisions.json");
        let a = mat(200, 95);
        let kernel: Arc<dyn SpmvKernel> = a.clone();
        let fp = tuner::fingerprint(kernel.as_ref());
        // Pre-seed the persistent cache with the doctored decision under
        // this service's (fingerprint × thread budget) key.
        {
            let cache = DecisionCache::open(&path);
            cache.put(doctored_decision(fp, 1e9));
        }
        let mut cfg = ServiceConfig::default();
        cfg.workers = 1;
        cfg.route.parallel_kind = EngineKind::Auto;
        cfg.route.min_parallel_n = 1;
        cfg.route.threads = 2;
        cfg.route.sweep_threads = true;
        cfg.tune_budget = TrialBudget::smoke();
        cfg.decision_cache = Some(path.clone());
        cfg.drift_fraction = 0.5;
        cfg.drift_min_batches = 2;
        let svc = MatvecService::start(cfg);
        svc.register("m", a.clone());
        let s = svc.stats();
        assert_eq!(s.tunes, 0, "the doctored decision must be a cache hit");
        assert_eq!(
            s.chosen_threads,
            vec![("m".to_string(), 1)],
            "the service must consume the swept thread count, not RoutePolicy::threads"
        );
        let x: Vec<f64> = (0..200).map(|i| (i as f64 * 0.01).sin()).collect();
        let mut want = vec![0.0; 200];
        a.spmv_into_zeroed(&x, &mut want);
        // Serve batches until the background re-tune lands. Drift is
        // certain — no real engine reaches 1e9 "Mflop/s" — so this loop
        // only bounds how long we wait for the background thread.
        let mut retuned = false;
        for _ in 0..400 {
            let y = svc.call("m", x.clone()).unwrap();
            crate::util::propcheck::assert_close(&y, &want, 1e-11, 1e-11).unwrap();
            if svc.stats().retunes >= 1 {
                retuned = true;
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        let s = svc.stats();
        assert!(retuned, "drift must queue a background re-tune (drift_events={})", s.drift_events);
        assert!(s.drift_events >= 1);
        // Serving still works against the upgraded decision.
        let y = svc.call("m", x.clone()).unwrap();
        crate::util::propcheck::assert_close(&y, &want, 1e-11, 1e-11).unwrap();
        svc.shutdown();
        // The re-tune upgraded the persisted entry in place: realistic
        // measured rate, fresh sweep surface, same (fp × budget) key.
        let back = DecisionCache::open(&path);
        let d = back.get(fp, 2).expect("upgraded decision persisted");
        assert!(d.measured && !d.sweep.is_empty());
        assert!(d.mflops < 1e8, "recorded rate must be re-measured, got {}", d.mflops);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn retuned_decision_uses_served_baseline_not_trial_rate() {
        // Satellite (ISSUE 5): a doctored optimistic trial rate must
        // trigger exactly ONE re-tune, not a storm. After the re-tune
        // the worker's calibration window records the served EWMA into
        // the entry, and later drift judgements run against that
        // serving baseline — which the serving rate trivially meets.
        let dir = std::env::temp_dir().join(format!("csrc_storm_svc_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("decisions.json");
        let a = mat(200, 195);
        let kernel: Arc<dyn SpmvKernel> = a.clone();
        let fp = tuner::fingerprint(kernel.as_ref());
        {
            let cache = DecisionCache::open(&path);
            cache.put(doctored_decision(fp, 1e9));
        }
        let mut cfg = ServiceConfig::default();
        cfg.workers = 1;
        cfg.route.parallel_kind = EngineKind::Auto;
        cfg.route.min_parallel_n = 1;
        cfg.route.threads = 2;
        cfg.route.sweep_threads = true;
        cfg.tune_budget = TrialBudget::smoke();
        cfg.decision_cache = Some(path.clone());
        cfg.drift_fraction = 0.25;
        cfg.drift_min_batches = 2;
        let svc = MatvecService::start(cfg);
        svc.register("m", a.clone());
        let x: Vec<f64> = (0..200).map(|i| (i as f64 * 0.01).sin()).collect();
        let mut want = vec![0.0; 200];
        a.spmv_into_zeroed(&x, &mut want);
        // Serve until the (certain) first re-tune lands.
        let mut retuned = false;
        for _ in 0..400 {
            let y = svc.call("m", x.clone()).unwrap();
            crate::util::propcheck::assert_close(&y, &want, 1e-11, 1e-11).unwrap();
            if svc.stats().retunes >= 1 {
                retuned = true;
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        assert!(retuned, "the doctored rate must trigger the first re-tune");
        // Plenty of post-re-tune batches: calibration (2 batches) plus
        // many judged ones. Without the served baseline every judged
        // batch would re-flag drift against the fresh warm trial rate.
        for _ in 0..40 {
            let y = svc.call("m", x.clone()).unwrap();
            crate::util::propcheck::assert_close(&y, &want, 1e-11, 1e-11).unwrap();
        }
        // Give any (wrongly) queued re-tune time to complete.
        std::thread::sleep(std::time::Duration::from_millis(100));
        let s = svc.stats();
        assert_eq!(s.retunes, 1, "served-EWMA baseline must stop the re-tune storm");
        svc.shutdown();
        // The baseline was persisted with the upgraded entry.
        let back = DecisionCache::open(&path);
        let d = back.get(fp, 2).expect("upgraded decision persisted");
        assert!(d.measured);
        assert!(d.mflops < 1e8, "trial rate was re-measured, got {}", d.mflops);
        assert!(d.served_mflops > 0.0, "calibration must record the served baseline");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn replacing_a_matrix_drops_the_stale_served_baseline() {
        // Satellite (ISSUE 10): a served-rate baseline calibrated
        // against a key's OLD values must neither trigger nor suppress
        // a re-tune once the key is re-registered with new values.
        // Pre-seed a persisted entry whose trial rate is tiny (never
        // drifts by itself) but whose served baseline is impossibly
        // high — what a previous serving generation would leave behind
        // — then replace and serve: without the replace-time clear,
        // every judged batch flags drift against the dead baseline.
        let dir =
            std::env::temp_dir().join(format!("csrc_stale_baseline_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("decisions.json");
        let a = mat(200, 97);
        let kernel: Arc<dyn SpmvKernel> = a.clone();
        let fp = tuner::fingerprint(kernel.as_ref());
        {
            let cache = DecisionCache::open(&path);
            cache.put(doctored_decision(fp, 1.0));
            cache.set_served_rate(fp, 2, 1e9);
        }
        let mut cfg = ServiceConfig::default();
        cfg.workers = 1;
        cfg.route.parallel_kind = EngineKind::Auto;
        cfg.route.min_parallel_n = 1;
        cfg.route.threads = 2;
        cfg.route.sweep_threads = true;
        cfg.tune_budget = TrialBudget::smoke();
        cfg.decision_cache = Some(path.clone());
        cfg.drift_fraction = 0.5;
        cfg.drift_min_batches = 2;
        let svc = MatvecService::start(cfg);
        svc.register("m", a.clone());
        assert_eq!(svc.stats().tunes, 0, "the doctored decision must be a cache hit");
        // Same pattern, new values: re-registration under an existing
        // key (the path a caller takes instead of `update_values`).
        let mut scaled = (*a).clone();
        for v in scaled.ad.iter_mut().chain(scaled.al.iter_mut()).chain(scaled.au.iter_mut())
        {
            *v *= 3.0;
        }
        let scaled = Arc::new(scaled);
        svc.register("m", scaled.clone());
        let x: Vec<f64> = (0..200).map(|i| (i as f64 * 0.01).sin()).collect();
        let mut want = vec![0.0; 200];
        scaled.spmv_into_zeroed(&x, &mut want);
        for _ in 0..30 {
            let y = svc.call("m", x.clone()).unwrap();
            crate::util::propcheck::assert_close(&y, &want, 1e-11, 1e-11).unwrap();
        }
        // Give any (wrongly) queued re-tune time to land in the stats.
        std::thread::sleep(std::time::Duration::from_millis(100));
        let s = svc.stats();
        assert_eq!(s.drift_events, 0, "stale baseline must not judge the new values");
        assert_eq!(s.retunes, 0, "no spurious re-tune after an in-place replacement");
        svc.shutdown();
        // The persisted baseline is gone too: a restarted service
        // cannot resurrect the dead generation's calibration.
        let back = DecisionCache::open(&path);
        let d = back.get(fp, 2).expect("replaced entry survives, decision intact");
        assert_eq!(d.served_mflops, 0.0, "persisted served baseline must be dropped");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn concurrent_register_serve_retune_stress() {
        // Satellite (ISSUE 5): concurrent register/serve/retune must
        // lose no cache upgrades — every doctored entry ends up
        // re-measured in place — and the retune counter must reflect
        // the observed upgrades (one per key, no storms), even with a
        // key being re-registered mid-flight.
        let dir = std::env::temp_dir().join(format!("csrc_stress_svc_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("decisions.json");
        let mats: Vec<Arc<Csrc>> = (0..3).map(|i| mat(200, 300 + i)).collect();
        let fps: Vec<u64> = mats
            .iter()
            .map(|m| {
                let k: Arc<dyn SpmvKernel> = m.clone();
                tuner::fingerprint(k.as_ref())
            })
            .collect();
        {
            let cache = DecisionCache::open(&path);
            for fp in &fps {
                cache.put(doctored_decision(*fp, 1e9));
            }
        }
        let mut cfg = ServiceConfig::default();
        cfg.workers = 2;
        cfg.route.parallel_kind = EngineKind::Auto;
        cfg.route.min_parallel_n = 1;
        cfg.route.threads = 2;
        cfg.route.sweep_threads = true;
        cfg.tune_budget = TrialBudget::smoke();
        cfg.decision_cache = Some(path.clone());
        cfg.drift_fraction = 0.25;
        cfg.drift_min_batches = 2;
        let svc = MatvecService::start(cfg);
        for (i, m) in mats.iter().enumerate() {
            svc.register(&format!("m{i}"), m.clone());
        }
        assert_eq!(svc.stats().tunes, 0, "all three doctored entries must be cache hits");
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        std::thread::scope(|scope| {
            for c in 0..3usize {
                let svc = &svc;
                let mats = &mats;
                let stop = stop.clone();
                scope.spawn(move || {
                    let mut i = c;
                    while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                        let k = i % 3;
                        let m = &mats[k];
                        let x: Vec<f64> =
                            (0..m.n).map(|j| ((i + j) as f64 * 0.01).sin()).collect();
                        let mut want = vec![0.0; m.n];
                        m.spmv_into_zeroed(&x, &mut want);
                        let y = svc.call(&format!("m{k}"), x).unwrap();
                        crate::util::propcheck::assert_close(&y, &want, 1e-11, 1e-11).unwrap();
                        i += 1;
                    }
                });
            }
            // Meanwhile: wait for all three re-tunes, poking a
            // concurrent replacement of m0 (same matrix, so in-flight
            // x vectors stay valid) into the middle of the run.
            let mut ok = false;
            for round in 0..1200 {
                if svc.stats().retunes >= 3 {
                    ok = true;
                    break;
                }
                if round == 30 || round == 90 {
                    svc.register("m0", mats[0].clone());
                }
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
            stop.store(true, std::sync::atomic::Ordering::Relaxed);
            assert!(ok, "all drifted keys must re-tune (retunes={})", svc.stats().retunes);
        });
        let s = svc.stats();
        assert_eq!(s.failed, 0, "every request must serve cleanly through the churn");
        assert_eq!(s.completed, s.submitted);
        svc.shutdown();
        // No lost upgrades: every doctored entry was re-measured in
        // place despite the concurrent replacements…
        let back = DecisionCache::open(&path);
        for fp in &fps {
            let d = back.get(*fp, 2).expect("entry survives");
            assert!(d.measured, "upgrade must keep the entry measured");
            assert!(d.mflops < 1e8, "trial rate must be re-measured, got {}", d.mflops);
        }
        // …and the retune counter matches the observed upgrades: one
        // per key (the served-EWMA baseline forbids storms), plus at
        // most one extra per m0 re-registration that raced its own
        // upgrade (a replaced generation re-drifts once).
        assert!(
            (3..=5).contains(&s.retunes),
            "retunes {} must match the 3 observed upgrades (± racing re-registrations)",
            s.retunes
        );
    }
}
