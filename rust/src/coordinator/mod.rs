//! L3 coordinator: a matvec *service*.
//!
//! The paper's algorithms end up inside long-running solver services (its
//! §5: "now part of a distributed-memory implementation of the finite
//! element method"). This module packages the engines behind a
//! production-shaped front: a registry of matrices, an async request
//! queue, a batcher that groups requests per matrix, a router that picks
//! a backend per request (sequential / parallel engine / the XLA
//! artifact runtime), worker threads, and service metrics.
//!
//! The service itself is built from shard-local modules — registration
//! state ([`registration`]), batch-serving workers ([`worker`]), the
//! background re-tuner ([`retuner`]), and counters ([`stats`]) — with
//! [`service`] as the shell that wires them together. [`shard`] scales
//! that out: a [`ShardedMatvecService`] row-block-partitions each
//! registered matrix (the paper's §5 overlapping decomposition, via
//! [`distributed`]'s machinery) and runs one complete private
//! [`MatvecService`] per shard behind a scatter/gather front router.
//!
//! Everything is std-only (threads + mpsc): tokio is not in the offline
//! vendor tree, and the request path must never touch python.

pub mod batcher;
pub mod error;
pub(crate) mod registration;
pub(crate) mod retuner;
pub mod router;
pub mod service;
pub mod shard;
pub(crate) mod stats;
pub(crate) mod worker;

pub use batcher::{form_batches, Batch, BatchPolicy};
pub use error::{RejectReason, ServiceError};
pub use router::{Backend, RoutePolicy, Router};
pub use service::{MatvecService, ServiceConfig};
pub use shard::{BreakerState, FrontStats, ShardConfig, ShardStats, ShardedMatvecService};
pub use stats::ServiceStats;

pub mod distributed;
pub use distributed::{distributed_cg, DistributedMatrix, Subdomain};

/// Shared fixtures for the coordinator's module tests.
#[cfg(test)]
pub(crate) mod test_support {
    use crate::parallel::EngineKind;
    use crate::sparse::{Coo, Csrc};
    use crate::tuner;
    use crate::util::Rng;
    use std::sync::Arc;

    pub(crate) fn mat(n: usize, seed: u64) -> Arc<Csrc> {
        let mut rng = Rng::new(seed);
        Arc::new(Csrc::from_coo(&Coo::random_structurally_symmetric(n, 3, false, &mut rng)).unwrap())
    }

    /// A doctored swept decision: sequential at 1 thread (deliberately
    /// *not* `RoutePolicy::threads`) with an arbitrary recorded rate —
    /// pass an impossibly high one to force drift below any threshold.
    pub(crate) fn doctored_decision(fp: u64, mflops: f64) -> tuner::Decision {
        tuner::Decision {
            kind: EngineKind::Sequential,
            reorder: false,
            mflops,
            measured: true,
            provenance: tuner::Provenance::Measured,
            served_mflops: 0.0,
            tuned_s: 0.001,
            fingerprint: fp,
            nthreads: 1,
            max_threads: 2,
            features: tuner::Features {
                n: 200,
                work_flops: 2000,
                scatter_pairs: 300,
                scatter_ratio: 0.75,
                bandwidth: 20,
                window_rows: 320,
                window_shrink: 0.8,
                colors: 4,
                intervals: 6,
                balance: 1.1,
                nthreads: 2,
            },
            trials: Vec::new(),
            sweep: vec![tuner::SweepPoint { nthreads: 1, trials: Vec::new() }],
            block_k: 1,
            block_rates: Vec::new(),
        }
    }
}
