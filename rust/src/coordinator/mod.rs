//! L3 coordinator: a matvec *service*.
//!
//! The paper's algorithms end up inside long-running solver services (its
//! §5: "now part of a distributed-memory implementation of the finite
//! element method"). This module packages the engines behind a
//! production-shaped front: a registry of matrices, an async request
//! queue, a batcher that groups requests per matrix, a router that picks
//! a backend per request (sequential / parallel engine / the XLA
//! artifact runtime), worker threads, and service metrics.
//!
//! Everything is std-only (threads + mpsc): tokio is not in the offline
//! vendor tree, and the request path must never touch python.

pub mod batcher;
pub mod router;
pub mod service;

pub use batcher::{form_batches, Batch, BatchPolicy};
pub use router::{Backend, RoutePolicy, Router};
pub use service::{MatvecService, ServiceConfig, ServiceStats};

pub mod distributed;
pub use distributed::{distributed_cg, DistributedMatrix, Subdomain};
