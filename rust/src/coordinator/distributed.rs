//! Distributed-memory layer (the paper's §2.1 / §5 context).
//!
//! The CSRC algorithms "are now part of a distributed-memory
//! implementation of the finite element method" using a
//! subdomain-by-subdomain approach with overlapping decomposition — the
//! very source of the rectangular matrices §2.1 extends CSRC for. This
//! module reproduces that substrate in-process: each subdomain owns the
//! rectangular local matrix (square CSRC part + CSR overlap couplings), a
//! ghost-exchange step plays the role of the MPI halo swap, and a
//! distributed CG couples the coarse (subdomain) and fine (thread)
//! parallelism — the paper's closing "currently, we conduct experiments
//! on the effect of coupling both coarse- and fine-grained parallelisms".
//!
//! Subdomain products run either the serial Fig. 2(b) sweep or — after
//! [`DistributedMatrix::attach_engines`] — a tuner-raced [`ParallelSpmv`]
//! engine over the square CSRC part plus the CSR coupling sweep, so each
//! subdomain is tuned like any registered matrix. All per-product scratch
//! (local x gather, local y, halo) lives in the [`Subdomain`] and is
//! reused across products; [`DistributedMatrix::scratch_reallocs`] counts
//! (re)allocations the same way `ReorderedEngine::scratch_reallocs` does.

use std::sync::Arc;

use crate::gen::decomp;
use crate::parallel::{build_engine, EngineKind, ParallelSpmv};
use crate::plan::PlanBuilder;
use crate::sparse::{Csr, Csrc, CsrcRect, SpmvKernel};
use crate::tuner::{self, TrialBudget};

/// One subdomain: local rectangular matrix + the global ids its ghost
/// columns refer to + reusable product scratch.
pub struct Subdomain {
    pub rank: usize,
    pub rows: std::ops::Range<usize>,
    pub local: CsrcRect,
    /// Global row ids of ghost columns (local columns n..m, in order).
    pub ghosts: Vec<usize>,
    /// Local x: owned rows followed by gathered halo values (len m·k).
    xl: Vec<f64>,
    /// Local y (len n_l·k).
    yl: Vec<f64>,
    /// Optional parallel engine over the square CSRC part; the coupling
    /// sweep is applied on top of its output. `None` → serial Fig. 2(b).
    engine: Option<Box<dyn ParallelSpmv>>,
}

impl Subdomain {
    /// One local product into `self.yl` for panel width `k`, using the
    /// attached engine when present (square sweep + coupling add) and the
    /// serial rectangular kernel otherwise. `self.xl` holds the local
    /// vector (owned rows then halo) on entry.
    fn product(&mut self, k: usize) {
        let nl = self.rows.len();
        match &mut self.engine {
            Some(eng) => {
                eng.spmv_multi(&self.xl[..nl * k], &mut self.yl, k);
                self.local.coupling_spmv_multi_into(&self.xl[nl * k..], &mut self.yl, k);
            }
            None => self.local.spmv_multi(&self.xl, &mut self.yl, k),
        }
    }
}

/// A process-group stand-in: all subdomains of one global matrix.
pub struct DistributedMatrix {
    pub n: usize,
    pub subs: Vec<Subdomain>,
    /// How many times any subdomain's scratch was (re)allocated. Starts
    /// at 0; the first product costs one allocation per buffer class and
    /// steady-state products cost none (only widening a panel grows it).
    scratch_reallocs: usize,
}

impl DistributedMatrix {
    /// Overlapping decomposition of a global CSR into `nsub` subdomains.
    pub fn from_global(global: &Csr, nsub: usize) -> DistributedMatrix {
        assert!(global.is_structurally_symmetric());
        let n = global.nrows;
        let subs = (0..nsub)
            .map(|s| {
                let rows = decomp::slab(n, nsub, s);
                let coo = decomp::overlapping_local(global, nsub, s);
                let local = CsrcRect::from_coo(&coo)
                    .expect("overlap local must have a CSRC square part");
                // Ghost map in first-appearance order (same construction
                // as decomp::overlapping_local).
                let mut ghosts = Vec::new();
                let mut seen = std::collections::HashSet::new();
                for i in rows.clone() {
                    for k in global.row_range(i) {
                        let j = global.ja[k] as usize;
                        if !rows.contains(&j) && seen.insert(j) {
                            ghosts.push(j);
                        }
                    }
                }
                Subdomain { rank: s, rows, local, ghosts, xl: Vec::new(), yl: Vec::new(), engine: None }
            })
            .collect();
        DistributedMatrix { n, subs, scratch_reallocs: 0 }
    }

    /// Attach a parallel engine to every subdomain's square part. With
    /// [`EngineKind::Auto`] each square part is tuner-raced under
    /// `budget` — the subdomain is tuned like any registered matrix;
    /// concrete kinds skip the race.
    pub fn attach_engines(&mut self, kind: EngineKind, nthreads: usize, budget: &TrialBudget) {
        for s in &mut self.subs {
            let kernel: Arc<dyn SpmvKernel> = Arc::new(s.local.square.clone());
            let plan = Arc::new(PlanBuilder::all(nthreads).build(kernel.as_ref()));
            let concrete = if kind == EngineKind::Auto {
                tuner::tune(&kernel, &plan, budget).kind
            } else {
                kind
            };
            s.engine = Some(build_engine(concrete, kernel, plan));
        }
    }

    /// Borrow each subdomain's square CSRC part (e.g. to register the
    /// shards with a serving front).
    pub fn square_parts(&self) -> Vec<Arc<Csrc>> {
        self.subs.iter().map(|s| Arc::new(s.local.square.clone())).collect()
    }

    /// Grow a scratch vector to exactly `len`, counting reallocations.
    fn ensure(buf: &mut Vec<f64>, len: usize, reallocs: &mut usize) {
        if buf.capacity() < len {
            *buf = vec![0.0; len];
            *reallocs += 1;
        } else {
            buf.resize(len, 0.0);
        }
    }

    /// The halo exchange: gather each subdomain's ghost values from the
    /// (conceptually remote) owners into the tail of its local-x scratch.
    /// In-process this is a gather from the global vector; the
    /// communication volume per rank is reported by [`halo_volume`] so
    /// benches can chart it.
    ///
    /// [`halo_volume`]: DistributedMatrix::halo_volume
    pub fn exchange_ghosts(&mut self, x: &[f64]) {
        self.scatter_multi(x, 1)
    }

    /// Scatter the global panel (n×k row-major) into each subdomain's
    /// local-x scratch: owned rows first, then the gathered halo.
    fn scatter_multi(&mut self, x: &[f64], k: usize) {
        for s in &mut self.subs {
            let nl = s.rows.len();
            Self::ensure(&mut s.xl, s.local.m * k, &mut self.scratch_reallocs);
            for (off, i) in s.rows.clone().enumerate() {
                s.xl[off * k..off * k + k].copy_from_slice(&x[i * k..i * k + k]);
            }
            for (off, &g) in s.ghosts.iter().enumerate() {
                s.xl[(nl + off) * k..(nl + off) * k + k].copy_from_slice(&x[g * k..g * k + k]);
            }
        }
    }

    /// Distributed y = A x: per-subdomain rectangular CSRC products (the
    /// Fig. 2b kernel, or the attached engine + coupling sweep) + ghost
    /// exchange, scattered back to global ids. No per-product heap
    /// traffic after the first call.
    pub fn spmv(&mut self, x: &[f64], y: &mut [f64]) {
        self.spmv_multi(x, y, 1)
    }

    /// Panel form: Y (n×k row-major) = A X.
    pub fn spmv_multi(&mut self, x: &[f64], y: &mut [f64], k: usize) {
        assert_eq!(x.len(), self.n * k);
        assert_eq!(y.len(), self.n * k);
        self.scatter_multi(x, k);
        for s in &mut self.subs {
            let nl = s.rows.len();
            Self::ensure(&mut s.yl, nl * k, &mut self.scratch_reallocs);
            s.product(k);
            for (off, i) in s.rows.clone().enumerate() {
                y[i * k..i * k + k].copy_from_slice(&s.yl[off * k..off * k + k]);
            }
        }
    }

    /// Total halo doubles moved per product (communication volume).
    pub fn halo_volume(&self) -> usize {
        self.subs.iter().map(|s| s.ghosts.len()).sum()
    }

    /// How many scratch (re)allocations all products so far have cost.
    pub fn scratch_reallocs(&self) -> usize {
        self.scratch_reallocs
    }
}

/// Distributed (block-row) conjugate gradients on the subdomain matvec —
/// coarse-grained parallelism over subdomains with the CSRC kernel inside
/// each, exactly the paper's deployment shape. Returns (x, iterations,
/// relative residual).
pub fn distributed_cg(
    dm: &mut DistributedMatrix,
    b: &[f64],
    tol: f64,
    max_iter: usize,
) -> (Vec<f64>, usize, f64) {
    let n = dm.n;
    let mut x = vec![0.0; n];
    let mut r = b.to_vec();
    let mut p = r.clone();
    let mut ap = vec![0.0; n];
    let dot = |a: &[f64], b: &[f64]| a.iter().zip(b).map(|(u, v)| u * v).sum::<f64>();
    let bnorm = dot(b, b).sqrt().max(1e-300);
    let mut rs = dot(&r, &r);
    for it in 0..max_iter {
        if rs.sqrt() / bnorm < tol {
            return (x, it, rs.sqrt() / bnorm);
        }
        dm.spmv(&p, &mut ap);
        let alpha = rs / dot(&p, &ap);
        for i in 0..n {
            x[i] += alpha * p[i];
            r[i] -= alpha * ap[i];
        }
        let rs_new = dot(&r, &r);
        let beta = rs_new / rs;
        rs = rs_new;
        for i in 0..n {
            p[i] = r[i] + beta * p[i];
        }
    }
    (x, max_iter, rs.sqrt() / bnorm)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;
    use crate::util::propcheck;

    fn global() -> Csr {
        Csr::from_coo(&gen::poisson_2d_quad(16, 0.0, 13))
    }

    #[test]
    fn distributed_spmv_matches_global() {
        let g = global();
        let n = g.nrows;
        for nsub in [1, 2, 4, 7] {
            let mut dm = DistributedMatrix::from_global(&g, nsub);
            let x: Vec<f64> = (0..n).map(|i| (i as f64 * 0.3).sin()).collect();
            let (mut y1, mut y2) = (vec![0.0; n], vec![0.0; n]);
            g.spmv(&x, &mut y1);
            dm.spmv(&x, &mut y2);
            propcheck::assert_close(&y1, &y2, 1e-11, 1e-11)
                .unwrap_or_else(|e| panic!("nsub={nsub}: {e}"));
        }
    }

    #[test]
    fn engine_backed_spmv_matches_global() {
        let g = global();
        let n = g.nrows;
        for (nsub, kind) in [
            (2, EngineKind::LocalBuffers(crate::parallel::AccumMethod::Effective)),
            (4, EngineKind::Atomic),
            (3, EngineKind::Auto),
        ] {
            let mut dm = DistributedMatrix::from_global(&g, nsub);
            dm.attach_engines(kind, 2, &TrialBudget::smoke());
            let x: Vec<f64> = (0..n).map(|i| (i as f64 * 0.7).cos()).collect();
            let (mut y1, mut y2) = (vec![0.0; n], vec![0.0; n]);
            g.spmv(&x, &mut y1);
            dm.spmv(&x, &mut y2);
            propcheck::assert_close(&y1, &y2, 1e-11, 1e-11)
                .unwrap_or_else(|e| panic!("nsub={nsub} kind={kind:?}: {e}"));
        }
    }

    #[test]
    fn distributed_spmv_multi_matches_columns() {
        let g = global();
        let n = g.nrows;
        let mut dm = DistributedMatrix::from_global(&g, 4);
        dm.attach_engines(EngineKind::Atomic, 2, &TrialBudget::zero());
        let k = 4;
        let mut rng = crate::util::Rng::new(31);
        let x: Vec<f64> = (0..n * k).map(|_| rng.normal()).collect();
        let mut y = vec![0.0; n * k];
        dm.spmv_multi(&x, &mut y, k);
        for c in 0..k {
            let xc: Vec<f64> = (0..n).map(|j| x[j * k + c]).collect();
            let mut want = vec![0.0; n];
            g.spmv(&xc, &mut want);
            let got: Vec<f64> = (0..n).map(|i| y[i * k + c]).collect();
            propcheck::assert_close(&got, &want, 1e-11, 1e-11)
                .unwrap_or_else(|e| panic!("col {c}: {e}"));
        }
    }

    /// The satellite fix: scratch is allocated on first use and then
    /// reused — repeated products add no allocations; only widening the
    /// panel grows the buffers, and narrowing back is free.
    #[test]
    fn subdomain_scratch_grows_once() {
        let g = global();
        let n = g.nrows;
        let mut dm = DistributedMatrix::from_global(&g, 4);
        assert_eq!(dm.scratch_reallocs(), 0, "construction allocates no scratch");
        let x: Vec<f64> = (0..n).map(|i| (i as f64 * 0.1).sin()).collect();
        let mut y = vec![0.0; n];
        dm.spmv(&x, &mut y);
        let after_first = dm.scratch_reallocs();
        assert_eq!(after_first, 8, "first product: xl + yl per subdomain");
        for _ in 0..10 {
            dm.spmv(&x, &mut y);
        }
        assert_eq!(dm.scratch_reallocs(), after_first, "steady state allocates nothing");
        // Widening to a panel grows each buffer once more...
        let k = 4;
        let xp: Vec<f64> = (0..n * k).map(|i| (i as f64 * 0.2).cos()).collect();
        let mut yp = vec![0.0; n * k];
        dm.spmv_multi(&xp, &mut yp, k);
        let after_wide = dm.scratch_reallocs();
        assert_eq!(after_wide, 16);
        // ...and narrower products afterwards reuse the wide scratch.
        dm.spmv(&x, &mut y);
        dm.spmv_multi(&xp, &mut yp, k);
        assert_eq!(dm.scratch_reallocs(), after_wide);
    }

    #[test]
    fn halo_volume_grows_with_subdomains() {
        let g = global();
        let v2 = DistributedMatrix::from_global(&g, 2).halo_volume();
        let v8 = DistributedMatrix::from_global(&g, 8).halo_volume();
        assert!(v8 > v2, "more cuts -> more halo ({v2} vs {v8})");
        assert_eq!(DistributedMatrix::from_global(&g, 1).halo_volume(), 0);
    }

    #[test]
    fn distributed_cg_converges() {
        let g = global();
        let n = g.nrows;
        let mut dm = DistributedMatrix::from_global(&g, 4);
        let mut rng = crate::util::Rng::new(17);
        let xstar: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mut b = vec![0.0; n];
        g.spmv(&xstar, &mut b);
        let (x, its, res) = distributed_cg(&mut dm, &b, 1e-11, 5 * n);
        assert!(res < 1e-11, "residual {res}");
        assert!(its < 5 * n);
        for (got, want) in x.iter().zip(&xstar) {
            assert!((got - want).abs() < 1e-6);
        }
    }

    #[test]
    fn subdomain_shapes_are_consistent() {
        let g = global();
        let dm = DistributedMatrix::from_global(&g, 4);
        let mut total_rows = 0;
        for s in &dm.subs {
            assert_eq!(s.local.n(), s.rows.len());
            assert_eq!(s.local.m, s.rows.len() + s.ghosts.len());
            total_rows += s.rows.len();
        }
        assert_eq!(total_rows, g.nrows);
    }
}
