//! Distributed-memory layer (the paper's §2.1 / §5 context).
//!
//! The CSRC algorithms "are now part of a distributed-memory
//! implementation of the finite element method" using a
//! subdomain-by-subdomain approach with overlapping decomposition — the
//! very source of the rectangular matrices §2.1 extends CSRC for. This
//! module reproduces that substrate in-process: each subdomain owns the
//! rectangular local matrix (square CSRC part + CSR overlap couplings), a
//! ghost-exchange step plays the role of the MPI halo swap, and a
//! distributed CG couples the coarse (subdomain) and fine (thread)
//! parallelism — the paper's closing "currently, we conduct experiments
//! on the effect of coupling both coarse- and fine-grained parallelisms".

use crate::gen::decomp;
use crate::sparse::{Csr, CsrcRect};

/// One subdomain: local rectangular matrix + the global ids its ghost
/// columns refer to.
pub struct Subdomain {
    pub rank: usize,
    pub rows: std::ops::Range<usize>,
    pub local: CsrcRect,
    /// Global row ids of ghost columns (local columns n..m, in order).
    pub ghosts: Vec<usize>,
}

/// A process-group stand-in: all subdomains of one global matrix.
pub struct DistributedMatrix {
    pub n: usize,
    pub subs: Vec<Subdomain>,
}

impl DistributedMatrix {
    /// Overlapping decomposition of a global CSR into `nsub` subdomains.
    pub fn from_global(global: &Csr, nsub: usize) -> DistributedMatrix {
        assert!(global.is_structurally_symmetric());
        let n = global.nrows;
        let subs = (0..nsub)
            .map(|s| {
                let rows = decomp::slab(n, nsub, s);
                let coo = decomp::overlapping_local(global, nsub, s);
                let local = CsrcRect::from_coo(&coo)
                    .expect("overlap local must have a CSRC square part");
                // Ghost map in first-appearance order (same construction
                // as decomp::overlapping_local).
                let mut ghosts = Vec::new();
                let mut seen = std::collections::HashSet::new();
                for i in rows.clone() {
                    for k in global.row_range(i) {
                        let j = global.ja[k] as usize;
                        if !rows.contains(&j) && seen.insert(j) {
                            ghosts.push(j);
                        }
                    }
                }
                Subdomain { rank: s, rows, local, ghosts }
            })
            .collect();
        DistributedMatrix { n, subs }
    }

    /// The halo exchange: gather each subdomain's ghost values from the
    /// (conceptually remote) owners. In-process this is a gather from the
    /// global vector; the communication volume per rank is reported so
    /// benches can chart it.
    pub fn exchange_ghosts(&self, x: &[f64]) -> Vec<Vec<f64>> {
        self.subs
            .iter()
            .map(|s| s.ghosts.iter().map(|&g| x[g]).collect())
            .collect()
    }

    /// Distributed y = A x: per-subdomain rectangular CSRC products (the
    /// Fig. 2b kernel) + ghost exchange, scattered back to global ids.
    pub fn spmv(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.n);
        assert_eq!(y.len(), self.n);
        let halos = self.exchange_ghosts(x);
        for (s, halo) in self.subs.iter().zip(&halos) {
            let nl = s.rows.len();
            let mut xl = Vec::with_capacity(s.local.m);
            xl.extend(s.rows.clone().map(|i| x[i]));
            xl.extend_from_slice(halo);
            let mut yl = vec![0.0; nl];
            s.local.spmv(&xl, &mut yl);
            for (off, i) in s.rows.clone().enumerate() {
                y[i] = yl[off];
            }
        }
    }

    /// Total halo doubles moved per product (communication volume).
    pub fn halo_volume(&self) -> usize {
        self.subs.iter().map(|s| s.ghosts.len()).sum()
    }
}

/// Distributed (block-row) conjugate gradients on the subdomain matvec —
/// coarse-grained parallelism over subdomains with the CSRC kernel inside
/// each, exactly the paper's deployment shape. Returns (x, iterations,
/// relative residual).
pub fn distributed_cg(
    dm: &DistributedMatrix,
    b: &[f64],
    tol: f64,
    max_iter: usize,
) -> (Vec<f64>, usize, f64) {
    let n = dm.n;
    let mut x = vec![0.0; n];
    let mut r = b.to_vec();
    let mut p = r.clone();
    let mut ap = vec![0.0; n];
    let dot = |a: &[f64], b: &[f64]| a.iter().zip(b).map(|(u, v)| u * v).sum::<f64>();
    let bnorm = dot(b, b).sqrt().max(1e-300);
    let mut rs = dot(&r, &r);
    for it in 0..max_iter {
        if rs.sqrt() / bnorm < tol {
            return (x, it, rs.sqrt() / bnorm);
        }
        dm.spmv(&p, &mut ap);
        let alpha = rs / dot(&p, &ap);
        for i in 0..n {
            x[i] += alpha * p[i];
            r[i] -= alpha * ap[i];
        }
        let rs_new = dot(&r, &r);
        let beta = rs_new / rs;
        rs = rs_new;
        for i in 0..n {
            p[i] = r[i] + beta * p[i];
        }
    }
    (x, max_iter, rs.sqrt() / bnorm)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;
    use crate::util::propcheck;

    fn global() -> Csr {
        Csr::from_coo(&gen::poisson_2d_quad(16, 0.0, 13))
    }

    #[test]
    fn distributed_spmv_matches_global() {
        let g = global();
        let n = g.nrows;
        for nsub in [1, 2, 4, 7] {
            let dm = DistributedMatrix::from_global(&g, nsub);
            let x: Vec<f64> = (0..n).map(|i| (i as f64 * 0.3).sin()).collect();
            let (mut y1, mut y2) = (vec![0.0; n], vec![0.0; n]);
            g.spmv(&x, &mut y1);
            dm.spmv(&x, &mut y2);
            propcheck::assert_close(&y1, &y2, 1e-11, 1e-11)
                .unwrap_or_else(|e| panic!("nsub={nsub}: {e}"));
        }
    }

    #[test]
    fn halo_volume_grows_with_subdomains() {
        let g = global();
        let v2 = DistributedMatrix::from_global(&g, 2).halo_volume();
        let v8 = DistributedMatrix::from_global(&g, 8).halo_volume();
        assert!(v8 > v2, "more cuts -> more halo ({v2} vs {v8})");
        assert_eq!(DistributedMatrix::from_global(&g, 1).halo_volume(), 0);
    }

    #[test]
    fn distributed_cg_converges() {
        let g = global();
        let n = g.nrows;
        let dm = DistributedMatrix::from_global(&g, 4);
        let mut rng = crate::util::Rng::new(17);
        let xstar: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mut b = vec![0.0; n];
        g.spmv(&xstar, &mut b);
        let (x, its, res) = distributed_cg(&dm, &b, 1e-11, 5 * n);
        assert!(res < 1e-11, "residual {res}");
        assert!(its < 5 * n);
        for (got, want) in x.iter().zip(&xstar) {
            assert!((got - want).abs() < 1e-6);
        }
    }

    #[test]
    fn subdomain_shapes_are_consistent() {
        let g = global();
        let dm = DistributedMatrix::from_global(&g, 4);
        let mut total_rows = 0;
        for s in &dm.subs {
            assert_eq!(s.local.n(), s.rows.len());
            assert_eq!(s.local.m, s.rows.len() + s.ghosts.len());
            total_rows += s.rows.len();
        }
        assert_eq!(total_rows, g.nrows);
    }
}
