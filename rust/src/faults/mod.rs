//! Deterministic fault injection ("chaos") for the serving stack.
//!
//! Compiled in and gated on one atomic switch, exactly like `obs/`: with
//! chaos off — the default — every injection site costs a single relaxed
//! load and no lock is touched. Armed via [`configure`] +
//! [`set_chaos_enabled`] (surfaced as `csrc serve --chaos <spec>`), each
//! named [`InjectionPoint`] fires on a **deterministic error-diffusion
//! schedule** rather than a coin flip: a point with rate `r` keeps an
//! accumulator, adds `r` per check, and fires whenever it crosses 1
//! (subtracting 1 again). With the default `seed:0` the accumulator
//! starts at `1 - r`, so the *first* check of every armed point fires —
//! CI can assert `panics_caught > 0` without flakiness — and thereafter
//! every ~`1/r`-th check fires. A nonzero seed rotates each point's
//! starting phase reproducibly instead.
//!
//! Spec grammar — comma-separated `key:value` pairs:
//!
//! ```text
//! worker-panic:0.05,shard-stall:1,stall-ms:80,seed:7
//! ```
//!
//! Point keys (rate in `[0, 1]`): `worker-panic` (batch panics before
//! serving), `shard-stall` (worker sleeps `stall-ms` before the batch),
//! `queue-full` (the front treats the shard queue as full),
//! `deadline-blow` (the front treats the shard reply as past deadline),
//! `cache-io` (decision-cache reads fail / writes are dropped). Extras:
//! `stall-ms:<u64>` sleep per `shard-stall` fire (default 100),
//! `seed:<u64>` accumulator phase (default 0 = fire-first).

use std::sync::atomic::{AtomicBool, Ordering::Relaxed};
use std::sync::{Mutex, MutexGuard};
use std::time::Duration;

/// Named places in the serving stack where a fault can be injected.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InjectionPoint {
    /// Worker panics at the top of a batch (exercises catch_unwind +
    /// supervisor restart).
    WorkerPanic = 0,
    /// Worker sleeps [`stall_duration`] before serving a batch
    /// (exercises deadlines and circuit breakers).
    ShardStall = 1,
    /// The sharded front sees the shard queue as full (exercises
    /// retry-with-backoff and typed rejections).
    QueueFull = 2,
    /// The sharded front discards the shard reply as if the deadline
    /// passed (exercises breakers without waiting out a real stall).
    DeadlineBlow = 3,
    /// Decision-cache file reads fail and writes are dropped (exercises
    /// cache-less degradation).
    CacheIo = 4,
}

/// Number of injection points (array sizing).
pub const NPOINTS: usize = 5;

impl InjectionPoint {
    /// Every point, in index order.
    pub const ALL: [InjectionPoint; NPOINTS] = [
        InjectionPoint::WorkerPanic,
        InjectionPoint::ShardStall,
        InjectionPoint::QueueFull,
        InjectionPoint::DeadlineBlow,
        InjectionPoint::CacheIo,
    ];

    /// Spec-grammar key for this point.
    pub fn label(self) -> &'static str {
        match self {
            InjectionPoint::WorkerPanic => "worker-panic",
            InjectionPoint::ShardStall => "shard-stall",
            InjectionPoint::QueueFull => "queue-full",
            InjectionPoint::DeadlineBlow => "deadline-blow",
            InjectionPoint::CacheIo => "cache-io",
        }
    }

    /// Inverse of [`Self::label`].
    pub fn parse(key: &str) -> Option<InjectionPoint> {
        InjectionPoint::ALL.iter().copied().find(|p| p.label() == key)
    }
}

/// Error-diffusion firing schedule for one point: deterministic, seeded,
/// and independent of wall clock or thread interleaving at a given
/// check count.
#[derive(Clone, Copy, Debug)]
struct PointState {
    rate: f64,
    acc: f64,
    checks: u64,
    fired: u64,
}

impl PointState {
    const fn idle() -> PointState {
        PointState { rate: 0.0, acc: 0.0, checks: 0, fired: 0 }
    }

    fn arm(rate: f64, phase: f64) -> PointState {
        PointState { rate, acc: phase, checks: 0, fired: 0 }
    }

    fn check(&mut self) -> bool {
        self.checks += 1;
        if self.rate <= 0.0 {
            return false;
        }
        self.acc += self.rate;
        if self.acc >= 1.0 {
            self.acc -= 1.0;
            self.fired += 1;
            true
        } else {
            false
        }
    }
}

struct ChaosState {
    points: [PointState; NPOINTS],
    stall: Duration,
}

impl ChaosState {
    const fn idle() -> ChaosState {
        ChaosState { points: [PointState::idle(); NPOINTS], stall: Duration::from_millis(100) }
    }
}

static CHAOS_ON: AtomicBool = AtomicBool::new(false);
static STATE: Mutex<ChaosState> = Mutex::new(ChaosState::idle());

fn state() -> MutexGuard<'static, ChaosState> {
    // Chaos fires across panicking workers; recover rather than poison.
    STATE.lock().unwrap_or_else(|e| e.into_inner())
}

/// Parse `spec` and install it (accumulators reset). Does NOT flip the
/// enable switch — pair with [`set_chaos_enabled`].
pub fn configure(spec: &str) -> Result<(), String> {
    let mut next = ChaosState::idle();
    let mut rates = [0.0f64; NPOINTS];
    let mut seed = 0u64;
    for tok in spec.split(',') {
        let tok = tok.trim();
        if tok.is_empty() {
            continue;
        }
        let (key, val) = tok
            .split_once(':')
            .ok_or_else(|| format!("chaos spec entry {tok:?}: expected key:value"))?;
        if let Some(p) = InjectionPoint::parse(key) {
            let rate: f64 = val
                .parse()
                .map_err(|_| format!("chaos point {key}: bad rate {val:?}"))?;
            if !(0.0..=1.0).contains(&rate) {
                return Err(format!("chaos point {key}: rate {rate} outside [0, 1]"));
            }
            rates[p as usize] = rate;
        } else if key == "stall-ms" {
            let ms: u64 =
                val.parse().map_err(|_| format!("chaos stall-ms: bad value {val:?}"))?;
            next.stall = Duration::from_millis(ms);
        } else if key == "seed" {
            seed = val.parse().map_err(|_| format!("chaos seed: bad value {val:?}"))?;
        } else {
            return Err(format!(
                "chaos spec: unknown key {key:?} (points: {}, extras: stall-ms, seed)",
                InjectionPoint::ALL.map(|p| p.label()).join(", ")
            ));
        }
    }
    for p in InjectionPoint::ALL {
        let i = p as usize;
        if rates[i] <= 0.0 {
            continue;
        }
        let phase = if seed == 0 {
            // Fire-first: the very first check of an armed point fires.
            1.0 - rates[i]
        } else {
            crate::util::Rng::new(seed.wrapping_add(i as u64 + 1)).f64()
        };
        next.points[i] = PointState::arm(rates[i], phase);
    }
    *state() = next;
    Ok(())
}

/// Flip the global chaos switch. Injection sites are free when off.
pub fn set_chaos_enabled(on: bool) {
    CHAOS_ON.store(on, Relaxed);
}

/// Is the chaos switch on?
pub fn chaos_enabled() -> bool {
    CHAOS_ON.load(Relaxed)
}

/// Disable chaos and clear the installed spec and counters.
pub fn reset() {
    CHAOS_ON.store(false, Relaxed);
    *state() = ChaosState::idle();
}

/// Should the fault at `p` fire now? One relaxed load when chaos is off;
/// when armed, advances `p`'s deterministic schedule.
#[inline]
pub fn fire(p: InjectionPoint) -> bool {
    if !CHAOS_ON.load(Relaxed) {
        return false;
    }
    state().points[p as usize].check()
}

/// How long a fired [`InjectionPoint::ShardStall`] sleeps.
pub fn stall_duration() -> Duration {
    state().stall
}

/// (checks, fires) seen by point `p` since [`configure`]/[`reset`].
pub fn point_stats(p: InjectionPoint) -> (u64, u64) {
    let s = state();
    (s.points[p as usize].checks, s.points[p as usize].fired)
}

/// Total checks across all points — the ablation uses this to count how
/// many injection-site gates one product crosses.
pub fn checks_total() -> u64 {
    state().points.iter().map(|p| p.checks).sum()
}

/// Human summary of the armed points, for the serve banner.
pub fn describe() -> String {
    let s = state();
    let mut parts: Vec<String> = InjectionPoint::ALL
        .iter()
        .filter(|&&p| s.points[p as usize].rate > 0.0)
        .map(|&p| format!("{}:{}", p.label(), s.points[p as usize].rate))
        .collect();
    if parts.is_empty() {
        return "no points armed".to_string();
    }
    parts.push(format!("stall-ms:{}", s.stall.as_millis()));
    parts.join(",")
}

#[cfg(test)]
mod tests {
    use super::*;

    // These tests exercise the pure schedule and the parser only; the
    // process-global switch stays off so concurrently running service
    // tests never see injected faults (end-to-end chaos behaviour lives
    // in the serialized `rust/tests/chaos.rs` binary).

    #[test]
    fn fire_is_false_and_free_when_disabled() {
        assert!(!chaos_enabled());
        for p in InjectionPoint::ALL {
            assert!(!fire(p));
        }
    }

    #[test]
    fn error_diffusion_fires_first_then_every_nth() {
        let mut p = PointState::arm(0.25, 1.0 - 0.25);
        let fires: Vec<bool> = (0..12).map(|_| p.check()).collect();
        // Fire-first phase, then every 4th check.
        assert_eq!(
            fires,
            [true, false, false, false, true, false, false, false, true, false, false, false]
        );
        assert_eq!(p.checks, 12);
        assert_eq!(p.fired, 3);
    }

    #[test]
    fn rate_one_fires_every_check_and_rate_zero_never() {
        let mut always = PointState::arm(1.0, 0.0);
        assert!((0..50).all(|_| always.check()));
        let mut never = PointState::idle();
        assert!((0..50).all(|_| !never.check()));
        assert_eq!(never.checks, 50);
    }

    #[test]
    fn long_run_frequency_matches_rate() {
        for rate in [0.05, 0.1, 0.37, 0.5, 0.9] {
            let mut p = PointState::arm(rate, 1.0 - rate);
            let n = 10_000;
            let fired = (0..n).filter(|_| p.check()).count();
            let want = (rate * n as f64).round() as usize;
            assert!(
                fired.abs_diff(want) <= 1,
                "rate {rate}: fired {fired}, want ~{want}"
            );
        }
    }

    #[test]
    fn seeded_phase_is_reproducible_and_in_range() {
        for seed in [1u64, 7, 42, 0xDEADBEEF] {
            for i in 0..NPOINTS {
                let a = crate::util::Rng::new(seed.wrapping_add(i as u64 + 1)).f64();
                let b = crate::util::Rng::new(seed.wrapping_add(i as u64 + 1)).f64();
                assert_eq!(a, b);
                assert!((0.0..1.0).contains(&a));
            }
        }
    }

    #[test]
    fn spec_parser_accepts_the_grammar() {
        // Parse-only: build the state the way configure() would, without
        // touching the global registry.
        assert!(InjectionPoint::parse("worker-panic").is_some());
        assert!(InjectionPoint::parse("shard-stall").is_some());
        assert!(InjectionPoint::parse("queue-full").is_some());
        assert!(InjectionPoint::parse("deadline-blow").is_some());
        assert!(InjectionPoint::parse("cache-io").is_some());
        assert!(InjectionPoint::parse("bogus").is_none());
        for p in InjectionPoint::ALL {
            assert_eq!(InjectionPoint::parse(p.label()), Some(p));
        }
    }

    #[test]
    fn spec_parser_rejects_bad_entries() {
        for bad in [
            "worker-panic",          // no value
            "worker-panic:1.5",      // rate out of range
            "worker-panic:-0.1",     // negative
            "worker-panic:abc",      // not a number
            "stall-ms:xyz",          // bad extra
            "seed:-3",               // bad seed
            "unknown-point:0.5",     // unknown key
        ] {
            assert!(configure(bad).is_err(), "accepted {bad:?}");
        }
        // configure() on errors must not leave chaos enabled.
        assert!(!chaos_enabled());
        reset();
    }
}
