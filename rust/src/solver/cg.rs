//! Preconditioned conjugate gradients — the paper's canonical consumer
//! ("a thousand products ... reasonable for iterative solvers like the
//! preconditioned conjugate gradient method", §4).

use super::precond::Preconditioner;
use super::{axpy, dot, norm};
use crate::sparse::LinOp;

#[derive(Debug)]
pub struct CgResult {
    pub x: Vec<f64>,
    pub iterations: usize,
    pub residual: f64,
    pub converged: bool,
    /// Relative residual after every iteration (for loss-curve-style logs).
    pub history: Vec<f64>,
}

/// Solve A x = b for SPD A; `precond` of None = plain CG.
pub fn cg(
    a: &dyn LinOp,
    b: &[f64],
    precond: Option<&dyn Preconditioner>,
    tol: f64,
    max_iter: usize,
) -> CgResult {
    let n = a.dim();
    assert_eq!(b.len(), n);
    let bnorm = norm(b).max(1e-300);
    let mut x = vec![0.0; n];
    let mut r = b.to_vec();
    let mut z = vec![0.0; n];
    apply_precond(precond, &r, &mut z);
    let mut p = z.clone();
    let mut rz = dot(&r, &z);
    let mut ap = vec![0.0; n];
    let mut history = Vec::new();
    for it in 0..max_iter {
        let rel = norm(&r) / bnorm;
        history.push(rel);
        if rel < tol {
            return CgResult { x, iterations: it, residual: rel, converged: true, history };
        }
        a.apply(&p, &mut ap);
        let alpha = rz / dot(&p, &ap);
        axpy(&mut x, alpha, &p);
        axpy(&mut r, -alpha, &ap);
        apply_precond(precond, &r, &mut z);
        let rz_new = dot(&r, &z);
        let beta = rz_new / rz;
        rz = rz_new;
        for i in 0..n {
            p[i] = z[i] + beta * p[i];
        }
    }
    let rel = norm(&r) / bnorm;
    history.push(rel);
    CgResult { x, iterations: max_iter, residual: rel, converged: rel < tol, history }
}

fn apply_precond(precond: Option<&dyn Preconditioner>, r: &[f64], z: &mut [f64]) {
    match precond {
        Some(m) => m.apply(r, z),
        None => z.copy_from_slice(r),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::precond::Jacobi;
    use crate::sparse::{Coo, Csrc, LinOp};
    use crate::util::Rng;

    fn spd(n: usize, seed: u64) -> Csrc {
        let mut rng = Rng::new(seed);
        let coo = Coo::random_structurally_symmetric(n, 3, true, &mut rng);
        Csrc::from_coo(&coo).unwrap()
    }

    #[test]
    fn cg_solves_spd_system() {
        let a = spd(100, 92);
        let mut rng = Rng::new(1);
        let xstar: Vec<f64> = (0..100).map(|_| rng.normal()).collect();
        let mut b = vec![0.0; 100];
        a.apply(&xstar, &mut b);
        let r = cg(&a, &b, None, 1e-12, 1000);
        assert!(r.converged, "residual {}", r.residual);
        for (got, want) in r.x.iter().zip(&xstar) {
            assert!((got - want).abs() < 1e-7);
        }
    }

    #[test]
    fn jacobi_preconditioner_reduces_iterations() {
        // Badly scaled diagonal: Jacobi should pay off.
        let mut rng = Rng::new(93);
        let mut coo = Coo::random_structurally_symmetric(120, 3, true, &mut rng);
        for ((i, j), v) in coo.rows.iter().zip(&coo.cols).zip(coo.vals.iter_mut()) {
            if i == j {
                *v *= 1.0 + 100.0 * (*i as f64 / 120.0);
            }
        }
        let a = Csrc::from_coo(&coo).unwrap();
        let b: Vec<f64> = (0..120).map(|_| rng.normal()).collect();
        let plain = cg(&a, &b, None, 1e-10, 2000);
        let jac = Jacobi::new(&a).expect("CSRC exposes its diagonal");
        let pre = cg(&a, &b, Some(&jac), 1e-10, 2000);
        assert!(pre.converged && plain.converged);
        assert!(
            pre.iterations <= plain.iterations,
            "jacobi {} > plain {}",
            pre.iterations,
            plain.iterations
        );
    }

    #[test]
    fn history_is_monotone_enough() {
        let a = spd(60, 94);
        let b = vec![1.0; 60];
        let r = cg(&a, &b, None, 1e-12, 500);
        assert!(r.converged);
        assert!(r.history.first().unwrap() > r.history.last().unwrap());
    }
}
