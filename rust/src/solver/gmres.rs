//! Restarted GMRES(m) with Givens rotations — the paper's second named
//! consumer ("the generalized minimum residual method", §4); handles the
//! numerically non-symmetric CSRC matrices.

use super::{dot, norm};
use crate::sparse::LinOp;

#[derive(Debug)]
pub struct GmresResult {
    pub x: Vec<f64>,
    pub iterations: usize,
    pub residual: f64,
    pub converged: bool,
}

/// Solve A x = b with GMRES(m).
pub fn gmres(a: &dyn LinOp, b: &[f64], m: usize, tol: f64, max_outer: usize) -> GmresResult {
    let n = a.dim();
    let bnorm = norm(b).max(1e-300);
    let mut x = vec![0.0; n];
    let mut total_it = 0usize;
    let mut tmp = vec![0.0; n];

    for _outer in 0..max_outer {
        // r = b - A x
        a.apply(&x, &mut tmp);
        let mut r: Vec<f64> = b.iter().zip(&tmp).map(|(bi, ti)| bi - ti).collect();
        let beta = norm(&r);
        if beta / bnorm < tol {
            return GmresResult { x, iterations: total_it, residual: beta / bnorm, converged: true };
        }
        for ri in &mut r {
            *ri /= beta;
        }
        // Arnoldi basis V and Hessenberg H (column-major vecs).
        let mut v: Vec<Vec<f64>> = vec![r];
        let mut h: Vec<Vec<f64>> = Vec::new(); // h[j] has j+2 entries
        let mut cs: Vec<f64> = Vec::new();
        let mut sn: Vec<f64> = Vec::new();
        let mut g = vec![0.0; m + 1];
        g[0] = beta;
        let mut k_used = 0usize;
        for j in 0..m {
            total_it += 1;
            a.apply(&v[j], &mut tmp);
            let mut w = tmp.clone();
            let mut hj = vec![0.0; j + 2];
            // Modified Gram-Schmidt.
            for (i, vi) in v.iter().enumerate() {
                hj[i] = dot(&w, vi);
                for (wk, vk) in w.iter_mut().zip(vi) {
                    *wk -= hj[i] * vk;
                }
            }
            hj[j + 1] = norm(&w);
            // Apply accumulated rotations to the new column.
            for i in 0..j {
                let t = cs[i] * hj[i] + sn[i] * hj[i + 1];
                hj[i + 1] = -sn[i] * hj[i] + cs[i] * hj[i + 1];
                hj[i] = t;
            }
            // New rotation to annihilate hj[j+1].
            let (c, s) = givens(hj[j], hj[j + 1]);
            cs.push(c);
            sn.push(s);
            hj[j] = c * hj[j] + s * hj[j + 1];
            hj[j + 1] = 0.0;
            g[j + 1] = -s * g[j];
            g[j] *= c;
            let hjj = hj[j];
            h.push(hj);
            k_used = j + 1;
            let rel = g[j + 1].abs() / bnorm;
            if hjj.abs() < 1e-300 || rel < tol {
                break;
            }
            if j + 1 < m {
                let mut vnext = w;
                let wn = norm(&vnext);
                for vk in &mut vnext {
                    *vk /= wn.max(1e-300);
                }
                v.push(vnext);
            }
        }
        // Back-substitute y from H y = g.
        let k = k_used;
        let mut y = vec![0.0; k];
        for i in (0..k).rev() {
            let mut s = g[i];
            for (jj, yj) in y.iter().enumerate().skip(i + 1) {
                s -= h[jj][i] * yj;
            }
            y[i] = s / h[i][i];
        }
        for (j, yj) in y.iter().enumerate() {
            for (xi, vij) in x.iter_mut().zip(&v[j]) {
                *xi += yj * vij;
            }
        }
        // Convergence check next outer loop.
    }
    a.apply(&x, &mut tmp);
    let res: f64 = norm(&b.iter().zip(&tmp).map(|(bi, ti)| bi - ti).collect::<Vec<_>>()) / bnorm;
    GmresResult { x, iterations: total_it, residual: res, converged: res < tol }
}

fn givens(a: f64, b: f64) -> (f64, f64) {
    if b == 0.0 {
        (1.0, 0.0)
    } else if a.abs() < b.abs() {
        let t = a / b;
        let s = 1.0 / (1.0 + t * t).sqrt();
        (s * t, s)
    } else {
        let t = b / a;
        let c = 1.0 / (1.0 + t * t).sqrt();
        (c, c * t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::{Coo, Csrc, LinOp};
    use crate::util::Rng;

    #[test]
    fn gmres_solves_nonsymmetric_system() {
        let mut rng = Rng::new(95);
        let coo = Coo::random_structurally_symmetric(80, 3, false, &mut rng);
        let a = Csrc::from_coo(&coo).unwrap();
        let xstar: Vec<f64> = (0..80).map(|_| rng.normal()).collect();
        let mut b = vec![0.0; 80];
        a.apply(&xstar, &mut b);
        let r = gmres(&a, &b, 40, 1e-10, 50);
        assert!(r.converged, "residual {}", r.residual);
        for (got, want) in r.x.iter().zip(&xstar) {
            assert!((got - want).abs() < 1e-5, "{got} vs {want}");
        }
    }

    #[test]
    fn gmres_handles_restart() {
        let mut rng = Rng::new(96);
        let coo = Coo::random_structurally_symmetric(60, 2, false, &mut rng);
        let a = Csrc::from_coo(&coo).unwrap();
        let b: Vec<f64> = (0..60).map(|_| rng.normal()).collect();
        let r = gmres(&a, &b, 10, 1e-8, 200); // small m forces restarts
        assert!(r.converged, "residual {}", r.residual);
    }

    #[test]
    fn givens_rotations_are_orthonormal() {
        for (a, b) in [(3.0, 4.0), (0.0, 1.0), (1.0, 0.0), (-2.0, 5.0)] {
            let (c, s) = givens(a, b);
            assert!((c * c + s * s - 1.0).abs() < 1e-12);
            // The rotation annihilates the second component.
            assert!((-s * a + c * b).abs() < 1e-12);
        }
    }
}
