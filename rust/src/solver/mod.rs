//! Iterative solvers — the downstream consumers that motivate the paper
//! (§1: "performance of finite element codes using iterative solvers is
//! dominated by the matrix-vector multiplication"; §4: the 1000-product
//! benchmark models a PCG/GMRES solve).
//!
//! All solvers work on any [`crate::sparse::LinOp`], so they run on the
//! sequential formats *and* on the parallel engines via
//! [`ParallelLinOp`]. [`bicg`] exercises Aᵀx — the operation CSRC gets
//! for free (§5).

pub mod block_cg;
pub mod cg;
pub mod gmres;
pub mod precond;

pub use block_cg::{block_cg, BlockCgResult};
pub use cg::{cg, CgResult};
pub use gmres::{gmres, GmresResult};
pub use precond::{Jacobi, Preconditioner};

use crate::parallel::{build_engine, EngineKind, ParallelSpmv};
use crate::plan::SpmvPlan;
use crate::sparse::{LinOp, SpmvKernel};
use std::sync::Arc;

/// Adapter: any parallel engine is a LinOp (transpose unsupported).
pub struct ParallelLinOp<'a> {
    pub engine: std::sync::Mutex<&'a mut dyn ParallelSpmv>,
    pub n: usize,
}

impl<'a> ParallelLinOp<'a> {
    pub fn new(n: usize, engine: &'a mut dyn ParallelSpmv) -> Self {
        Self { engine: std::sync::Mutex::new(engine), n }
    }
}

impl LinOp for ParallelLinOp<'_> {
    fn dim(&self) -> usize {
        self.n
    }
    fn apply(&self, x: &[f64], y: &mut [f64]) {
        self.engine.lock().unwrap().spmv(x, y);
    }
    fn apply_multi(&self, x: &[f64], y: &mut [f64], k: usize) {
        self.engine.lock().unwrap().spmv_multi(x, y, k);
    }
}

/// Owning adapter: builds an executor from `(kind, kernel, plan)` — the
/// plan/executor path — and exposes it as a [`LinOp`], so a solver can
/// run on a coordinator-cached plan without borrowing an engine from the
/// caller.
pub struct EngineLinOp {
    engine: std::sync::Mutex<Box<dyn ParallelSpmv>>,
    n: usize,
}

impl EngineLinOp {
    pub fn new(kind: EngineKind, kernel: Arc<dyn SpmvKernel>, plan: Arc<SpmvPlan>) -> Self {
        let n = kernel.dim();
        Self { engine: std::sync::Mutex::new(build_engine(kind, kernel, plan)), n }
    }

    /// Analyze-and-build convenience (single-use plan).
    pub fn auto(kind: EngineKind, kernel: Arc<dyn SpmvKernel>, nthreads: usize) -> Self {
        let plan = SpmvPlan::for_engine(kind, kernel.as_ref(), nthreads);
        Self::new(kind, kernel, plan)
    }
}

impl LinOp for EngineLinOp {
    fn dim(&self) -> usize {
        self.n
    }
    fn apply(&self, x: &[f64], y: &mut [f64]) {
        self.engine.lock().unwrap().spmv(x, y);
    }
    fn apply_multi(&self, x: &[f64], y: &mut [f64], k: usize) {
        self.engine.lock().unwrap().spmv_multi(x, y, k);
    }
}

/// Adapter: a matrix registered with a
/// [`crate::coordinator::ShardedMatvecService`] is a
/// [`LinOp`] — every solver iteration scatters across the shards and
/// gathers back, so a CG/GMRES solve exercises the full sharded serving
/// path (the §5 "iterative solver on a decomposed domain" shape).
/// Serving errors (unknown key, back-pressure, deadline) panic: solvers
/// have no error channel for the operator, and a mid-solve rejection is
/// a deployment bug, not a numerical event.
pub struct ShardedLinOp<'a> {
    svc: &'a crate::coordinator::ShardedMatvecService,
    key: String,
    n: usize,
}

impl<'a> ShardedLinOp<'a> {
    pub fn new(svc: &'a crate::coordinator::ShardedMatvecService, key: &str, n: usize) -> Self {
        Self { svc, key: key.to_string(), n }
    }
}

impl LinOp for ShardedLinOp<'_> {
    fn dim(&self) -> usize {
        self.n
    }
    fn apply(&self, x: &[f64], y: &mut [f64]) {
        let r = self.svc.spmv(&self.key, x).expect("sharded spmv failed mid-solve");
        y.copy_from_slice(&r);
    }
    fn apply_multi(&self, x: &[f64], y: &mut [f64], k: usize) {
        let r = self.svc.spmv_multi(&self.key, x, k).expect("sharded spmv_multi failed mid-solve");
        y.copy_from_slice(&r);
    }
}

/// BiCG — an oblique-projection method needing both A·v and Aᵀ·v per
/// iteration: the workload where CSRC's free transpose pays (§5).
pub struct BicgResult {
    pub x: Vec<f64>,
    pub iterations: usize,
    pub residual: f64,
    pub converged: bool,
}

/// Errors with a message (rather than panicking) when the operator does
/// not support the transpose product BiCG needs each iteration.
pub fn bicg(a: &dyn LinOp, b: &[f64], tol: f64, max_iter: usize) -> Result<BicgResult, String> {
    let n = a.dim();
    let mut x = vec![0.0; n];
    let mut r = b.to_vec();
    let mut rt = b.to_vec();
    let mut p = r.clone();
    let mut pt = rt.clone();
    let mut rho = dot(&rt, &r);
    let bnorm = norm(b).max(1e-300);
    let mut ap = vec![0.0; n];
    let mut atpt = vec![0.0; n];
    for it in 0..max_iter {
        if norm(&r) / bnorm < tol {
            return Ok(BicgResult {
                x,
                iterations: it,
                residual: norm(&r) / bnorm,
                converged: true,
            });
        }
        a.apply(&p, &mut ap);
        a.apply_t(&pt, &mut atpt)
            .map_err(|e| format!("bicg requires a transpose product: {e}"))?;
        let alpha = rho / dot(&pt, &ap);
        axpy(&mut x, alpha, &p);
        axpy(&mut r, -alpha, &ap);
        axpy(&mut rt, -alpha, &atpt);
        let rho_new = dot(&rt, &r);
        let beta = rho_new / rho;
        rho = rho_new;
        for i in 0..n {
            p[i] = r[i] + beta * p[i];
            pt[i] = rt[i] + beta * pt[i];
        }
    }
    let res = norm(&r) / bnorm;
    Ok(BicgResult { x, iterations: max_iter, residual: res, converged: res < tol })
}

// --- tiny BLAS-1 helpers shared by the solvers -------------------------

#[inline]
pub(crate) fn dot(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

#[inline]
pub(crate) fn norm(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

#[inline]
pub(crate) fn axpy(y: &mut [f64], alpha: f64, x: &[f64]) {
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::{Coo, Csrc};
    use crate::util::Rng;

    #[test]
    fn bicg_solves_nonsymmetric_csrc_system() {
        let mut rng = Rng::new(90);
        let coo = Coo::random_structurally_symmetric(80, 3, false, &mut rng);
        let a = Csrc::from_coo(&coo).unwrap();
        let xstar: Vec<f64> = (0..80).map(|_| rng.normal()).collect();
        let mut b = vec![0.0; 80];
        a.spmv_into_zeroed(&xstar, &mut b);
        let r = bicg(&a, &b, 1e-10, 500).unwrap();
        assert!(r.converged, "residual {}", r.residual);
        for (got, want) in r.x.iter().zip(&xstar) {
            assert!((got - want).abs() < 1e-6, "{got} vs {want}");
        }
    }

    #[test]
    fn missing_capabilities_probe_without_panicking() {
        // Engine adapters expose neither Aᵀx nor a diagonal; probing must
        // return Err/None (the tuner's capability check), and bicg must
        // surface a clean error instead of aborting.
        use crate::parallel::{build_engine_auto, AccumMethod, EngineKind};
        let mut rng = Rng::new(93);
        let coo = Coo::random_structurally_symmetric(40, 3, false, &mut rng);
        let a = std::sync::Arc::new(Csrc::from_coo(&coo).unwrap());
        let mut engine =
            build_engine_auto(EngineKind::LocalBuffers(AccumMethod::Effective), a.clone(), 2);
        let op = ParallelLinOp::new(40, engine.as_mut());
        let ones = vec![1.0; 40];
        let mut y = vec![0.0; 40];
        assert!(op.apply_t(&ones, &mut y).is_err());
        assert!(op.diagonal().is_none());
        let err = bicg(&op, &ones, 1e-8, 10).unwrap_err();
        assert!(err.contains("transpose"), "{err}");
    }

    #[test]
    fn parallel_linop_adapts_engine() {
        use crate::parallel::{build_engine_auto, AccumMethod, EngineKind};
        let mut rng = Rng::new(91);
        let coo = Coo::random_structurally_symmetric(60, 3, true, &mut rng);
        let a = std::sync::Arc::new(Csrc::from_coo(&coo).unwrap());
        let mut engine =
            build_engine_auto(EngineKind::LocalBuffers(AccumMethod::Effective), a.clone(), 2);
        let op = ParallelLinOp::new(60, engine.as_mut());
        let x: Vec<f64> = (0..60).map(|_| rng.normal()).collect();
        let (mut y1, mut y2) = (vec![0.0; 60], vec![0.0; 60]);
        op.apply(&x, &mut y1);
        a.spmv_into_zeroed(&x, &mut y2);
        crate::util::propcheck::assert_close(&y1, &y2, 1e-11, 1e-11).unwrap();
    }

    #[test]
    fn sharded_linop_runs_cg_through_the_front() {
        use crate::coordinator::{ShardConfig, ShardedMatvecService};
        let mut rng = Rng::new(94);
        let coo = Coo::random_structurally_symmetric(90, 3, true, &mut rng);
        let a = std::sync::Arc::new(Csrc::from_coo(&coo).unwrap());
        let xstar: Vec<f64> = (0..90).map(|_| rng.normal()).collect();
        let mut b = vec![0.0; 90];
        a.spmv_into_zeroed(&xstar, &mut b);
        // Unsharded reference solve on the raw operator.
        let want = cg::cg(a.as_ref(), &b, None, 1e-10, 2000);
        assert!(want.converged, "reference residual {}", want.residual);
        for nshards in [2usize, 4] {
            let svc =
                ShardedMatvecService::start(ShardConfig { nshards, ..ShardConfig::default() });
            svc.register("a", a.clone());
            let op = ShardedLinOp::new(&svc, "a", 90);
            let r = cg::cg(&op, &b, None, 1e-10, 2000);
            assert!(r.converged, "nshards={nshards} residual {}", r.residual);
            crate::util::propcheck::assert_close(&r.x, &want.x, 1e-6, 1e-6).unwrap();
            svc.shutdown();
        }
    }

    #[test]
    fn engine_linop_runs_cg_on_shared_plan() {
        use crate::parallel::EngineKind;
        use crate::plan::PlanBuilder;
        let mut rng = Rng::new(92);
        let coo = Coo::random_structurally_symmetric(70, 3, true, &mut rng);
        let a = std::sync::Arc::new(Csrc::from_coo(&coo).unwrap());
        let plan = std::sync::Arc::new(PlanBuilder::all(2).build(a.as_ref()));
        let op = EngineLinOp::new(EngineKind::Colorful, a.clone(), plan);
        let xstar: Vec<f64> = (0..70).map(|_| rng.normal()).collect();
        let mut b = vec![0.0; 70];
        a.spmv_into_zeroed(&xstar, &mut b);
        let r = cg::cg(&op, &b, None, 1e-10, 2000);
        assert!(r.converged, "residual {}", r.residual);
    }
}
