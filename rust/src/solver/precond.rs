//! Preconditioners. Jacobi (diagonal) suffices to exercise the
//! preconditioned paths; CSRC's dense `ad` array makes it free to build.

use crate::sparse::LinOp;

pub trait Preconditioner {
    /// z = M⁻¹ r.
    fn apply(&self, r: &[f64], z: &mut [f64]);
}

/// Diagonal (Jacobi) preconditioner.
pub struct Jacobi {
    inv_diag: Vec<f64>,
}

impl Jacobi {
    /// `None` when the operator exposes no diagonal ([`LinOp::diagonal`]
    /// is a probe, not a panic) — callers fall back to unpreconditioned
    /// iterations.
    pub fn new(a: &dyn LinOp) -> Option<Jacobi> {
        let d = a.diagonal()?;
        Some(Jacobi {
            inv_diag: d
                .iter()
                .map(|&x| if x.abs() > 1e-300 { 1.0 / x } else { 1.0 })
                .collect(),
        })
    }
}

impl Preconditioner for Jacobi {
    fn apply(&self, r: &[f64], z: &mut [f64]) {
        for ((zi, ri), di) in z.iter_mut().zip(r).zip(&self.inv_diag) {
            *zi = ri * di;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::{Coo, Csrc};

    #[test]
    fn jacobi_inverts_diagonal() {
        let mut coo = Coo::new(3, 3);
        coo.push(0, 0, 2.0);
        coo.push(1, 1, 4.0);
        coo.push(2, 2, 8.0);
        let a = Csrc::from_coo(&coo).unwrap();
        let j = Jacobi::new(&a).expect("CSRC exposes its diagonal");
        let mut z = vec![0.0; 3];
        j.apply(&[2.0, 4.0, 8.0], &mut z);
        assert_eq!(z, vec![1.0, 1.0, 1.0]);
    }
}
