//! Multi-RHS conjugate gradients over blocked products.
//!
//! Solves A·X = B for an SPD operator and a row-major n×k right-hand
//! panel by running k *independent* CG recurrences in lockstep: the
//! per-column scalars (α, β, ρ) never couple, but every iteration's k
//! matrix products fuse into ONE [`crate::sparse::LinOp::apply_multi`]
//! call — the paper's amortization argument (one sweep of A serves k
//! vectors) applied to the solver layer. Converged columns freeze in
//! place while the rest keep iterating, so a panel with one hard column
//! costs the same products as solving that column alone.

use crate::sparse::LinOp;

#[derive(Debug)]
pub struct BlockCgResult {
    /// Solution panel, row-major n×k (`x[i*k + c]` = column c's x_i).
    pub x: Vec<f64>,
    /// Iterations until every column converged (or `max_iter`).
    pub iterations: usize,
    /// Final relative residual per column.
    pub residuals: Vec<f64>,
    /// Every column converged.
    pub converged: bool,
}

/// Dot product of column `c` of two row-major n×k panels.
#[inline]
fn col_dot(a: &[f64], b: &[f64], k: usize, c: usize) -> f64 {
    a.iter()
        .skip(c)
        .step_by(k)
        .zip(b.iter().skip(c).step_by(k))
        .map(|(x, y)| x * y)
        .sum()
}

/// Solve A X = B for SPD A; `b` is a row-major n×k panel. Plain CG
/// recurrences per column (no preconditioner), one blocked product per
/// iteration.
pub fn block_cg(a: &dyn LinOp, b: &[f64], k: usize, tol: f64, max_iter: usize) -> BlockCgResult {
    assert!(k >= 1, "block_cg needs at least one right-hand side");
    let n = a.dim();
    assert_eq!(b.len(), n * k, "b must be a row-major n×k panel");
    let mut x = vec![0.0; n * k];
    let mut r = b.to_vec();
    let mut p = r.clone();
    let mut ap = vec![0.0; n * k];
    let bnorm: Vec<f64> = (0..k).map(|c| col_dot(b, b, k, c).sqrt().max(1e-300)).collect();
    let mut rz: Vec<f64> = (0..k).map(|c| col_dot(&r, &r, k, c)).collect();
    let mut active: Vec<bool> = (0..k).map(|c| rz[c].sqrt() / bnorm[c] >= tol).collect();
    let mut iterations = 0;
    for it in 0..max_iter {
        if active.iter().all(|&live| !live) {
            iterations = it;
            break;
        }
        iterations = it + 1;
        // One blocked product serves every column — including frozen
        // ones, whose stale p columns are simply ignored below (the
        // panel sweep is one pass over A either way).
        a.apply_multi(&p, &mut ap, k);
        for c in 0..k {
            if !active[c] {
                continue;
            }
            let denom = col_dot(&p, &ap, k, c);
            if denom <= 0.0 {
                // Breakdown (non-SPD or exhausted Krylov space): freeze
                // the column at its current iterate.
                active[c] = false;
                continue;
            }
            let alpha = rz[c] / denom;
            for (xi, pi) in x.iter_mut().skip(c).step_by(k).zip(p.iter().skip(c).step_by(k)) {
                *xi += alpha * pi;
            }
            for (ri, api) in r.iter_mut().skip(c).step_by(k).zip(ap.iter().skip(c).step_by(k)) {
                *ri -= alpha * api;
            }
            let rz_new = col_dot(&r, &r, k, c);
            if rz_new.sqrt() / bnorm[c] < tol {
                active[c] = false;
                rz[c] = rz_new;
                continue;
            }
            let beta = rz_new / rz[c];
            rz[c] = rz_new;
            for (pi, ri) in p.iter_mut().skip(c).step_by(k).zip(r.iter().skip(c).step_by(k)) {
                *pi = ri + beta * *pi;
            }
        }
    }
    let residuals: Vec<f64> = (0..k).map(|c| col_dot(&r, &r, k, c).sqrt() / bnorm[c]).collect();
    let converged = residuals.iter().all(|&res| res < tol);
    BlockCgResult { x, iterations, residuals, converged }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::cg;
    use crate::sparse::{Coo, Csrc};
    use crate::util::Rng;

    fn spd(n: usize, seed: u64) -> Csrc {
        let mut rng = Rng::new(seed);
        let coo = Coo::random_structurally_symmetric(n, 3, true, &mut rng);
        Csrc::from_coo(&coo).unwrap()
    }

    /// Row-major panel whose column c is the vector `cols[c]`.
    fn pack(cols: &[Vec<f64>], n: usize) -> Vec<f64> {
        let k = cols.len();
        let mut panel = vec![0.0; n * k];
        for (c, col) in cols.iter().enumerate() {
            for (i, &v) in col.iter().enumerate() {
                panel[i * k + c] = v;
            }
        }
        panel
    }

    #[test]
    fn block_cg_matches_k_independent_cg_solves() {
        let n = 100;
        let a = spd(n, 110);
        let mut rng = Rng::new(2);
        let bs: Vec<Vec<f64>> = (0..3).map(|_| (0..n).map(|_| rng.normal()).collect()).collect();
        let panel = pack(&bs, n);
        let r = block_cg(&a, &panel, 3, 1e-10, 2000);
        assert!(r.converged, "residuals {:?}", r.residuals);
        for (c, b) in bs.iter().enumerate() {
            let single = cg::cg(&a, b, None, 1e-10, 2000);
            assert!(single.converged);
            for i in 0..n {
                let got = r.x[i * 3 + c];
                let want = single.x[i];
                assert!((got - want).abs() < 1e-6, "col {c} row {i}: {got} vs {want}");
            }
        }
    }

    #[test]
    fn block_cg_k1_equals_plain_cg() {
        let n = 80;
        let a = spd(n, 111);
        let b: Vec<f64> = (0..n).map(|i| ((i as f64) * 0.1).sin()).collect();
        let blocked = block_cg(&a, &b, 1, 1e-10, 2000);
        let plain = cg::cg(&a, &b, None, 1e-10, 2000);
        assert!(blocked.converged && plain.converged);
        for (got, want) in blocked.x.iter().zip(&plain.x) {
            assert!((got - want).abs() < 1e-8);
        }
    }

    #[test]
    fn converged_columns_freeze_while_others_iterate() {
        // Column 0 is already solved (b = 0 ⇒ x = 0 instantly); the
        // solver must keep iterating the hard column without disturbing
        // the frozen one.
        let n = 90;
        let a = spd(n, 112);
        let mut rng = Rng::new(3);
        let hard: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let panel = pack(&[vec![0.0; n], hard.clone()], n);
        let r = block_cg(&a, &panel, 2, 1e-10, 2000);
        assert!(r.converged, "residuals {:?}", r.residuals);
        for i in 0..n {
            assert_eq!(r.x[i * 2], 0.0, "the zero column must stay exactly zero");
        }
        let single = cg::cg(&a, &hard, None, 1e-10, 2000);
        for i in 0..n {
            assert!((r.x[i * 2 + 1] - single.x[i]).abs() < 1e-6);
        }
    }

    #[test]
    fn block_cg_runs_on_a_parallel_engine() {
        // End-to-end over the engine layer: every iteration's blocked
        // product goes through ParallelSpmv::spmv_multi.
        use crate::parallel::EngineKind;
        use crate::plan::PlanBuilder;
        use crate::solver::EngineLinOp;
        use std::sync::Arc;
        let n = 120;
        let a = Arc::new(spd(n, 113));
        let plan = Arc::new(PlanBuilder::all(2).build(a.as_ref()));
        let op = EngineLinOp::new(EngineKind::Colorful, a.clone(), plan);
        let mut rng = Rng::new(4);
        let bs: Vec<Vec<f64>> = (0..4).map(|_| (0..n).map(|_| rng.normal()).collect()).collect();
        let panel = pack(&bs, n);
        let r = block_cg(&op, &panel, 4, 1e-10, 3000);
        assert!(r.converged, "residuals {:?}", r.residuals);
        // Residual check against the sequential oracle.
        for (c, b) in bs.iter().enumerate() {
            let xc: Vec<f64> = (0..n).map(|i| r.x[i * 4 + c]).collect();
            let mut ax = vec![0.0; n];
            a.spmv_into_zeroed(&xc, &mut ax);
            let res: f64 = ax.iter().zip(b).map(|(p, q)| (p - q) * (p - q)).sum::<f64>().sqrt();
            let bn: f64 = b.iter().map(|v| v * v).sum::<f64>().sqrt();
            assert!(res / bn < 1e-8, "col {c}: residual {}", res / bn);
        }
    }
}
