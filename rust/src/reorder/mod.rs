//! Bandwidth-aware reordering — the analysis that makes the windowed
//! local buffers small.
//!
//! The paper ties SpMV performance to the band structure (§4.2: cage15
//! and F1 suffer from "the absence of a band structure") and both
//! Schubert–Hager–Fehske (arXiv:0910.4836) and RACE (arXiv:1907.06487)
//! show symmetric SpMV is bandwidth-bound: working-set bytes are the
//! lever. Reverse Cuthill–McKee clusters the symmetric pattern around
//! the diagonal, which
//!
//! * shrinks every thread's *effective range* (`SpmvPlan::eff`), so the
//!   windowed scatter buffers of
//!   [`crate::parallel::LocalBuffersEngine`] zero, sweep and accumulate
//!   fewer bytes per product,
//! * reduces the conflict-color count of the §3.2 colorful schedule,
//! * improves x/y locality of the sequential sweep itself.
//!
//! This module owns the mechanics: [`Permutation`] (a validated
//! new↔old index bijection with `apply`/`apply_inverse`/`inverse`),
//! [`rcm`] (BFS from a pseudo-peripheral vertex per component, minimum
//! degree tie-breaks, reversed), [`ReorderedLinOp`] (a solver-facing
//! operator that permutes x in and un-permutes y out, so `cg`, `gmres`,
//! `bicg` and `Jacobi` run transparently on reordered operators) and
//! [`ReorderedEngine`] (the same wrapper at the [`ParallelSpmv`] level,
//! used by the tuner's reordered candidates and the service workers).
//! The permuted matrices themselves are built by
//! [`crate::sparse::Csrc::permuted`] / [`crate::sparse::Csr::permuted`].

use crate::parallel::ParallelSpmv;
use crate::plan::SpmvPlan;
use crate::sparse::{LinOp, SpmvKernel};
use std::sync::Arc;

/// When the stack should reorder a matrix.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum ReorderPolicy {
    /// Run every matrix in its given ordering (the status quo).
    #[default]
    Never,
    /// Let the tuner measure reordered candidates next to the plain
    /// ones and keep whichever wins — reorder-on vs reorder-off is a
    /// per-matrix measurement, not folklore.
    Measure,
    /// Always execute through the RCM ordering (ablations, matrices
    /// known to be shuffled).
    Always,
}

impl ReorderPolicy {
    pub fn parse(s: &str) -> Option<ReorderPolicy> {
        match s.trim().to_ascii_lowercase().as_str() {
            "never" | "off" => Some(ReorderPolicy::Never),
            "measure" | "auto" => Some(ReorderPolicy::Measure),
            "always" | "on" => Some(ReorderPolicy::Always),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            ReorderPolicy::Never => "never",
            ReorderPolicy::Measure => "measure",
            ReorderPolicy::Always => "always",
        }
    }
}

/// A validated bijection between an *old* (given) and a *new*
/// (reordered) row/column numbering. Both directions are stored so
/// per-request permute/un-permute are straight gathers.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Permutation {
    /// `new_to_old[new] = old` — the order the rows are visited in.
    new_to_old: Vec<usize>,
    /// `old_to_new[old] = new`.
    old_to_new: Vec<usize>,
}

impl Permutation {
    pub fn identity(n: usize) -> Permutation {
        Permutation { new_to_old: (0..n).collect(), old_to_new: (0..n).collect() }
    }

    /// Build from a `perm[new] = old` vector, rejecting anything that is
    /// not a bijection on `0..len`.
    pub fn from_new_to_old(new_to_old: Vec<usize>) -> Result<Permutation, String> {
        let n = new_to_old.len();
        let mut old_to_new = vec![usize::MAX; n];
        for (new, &old) in new_to_old.iter().enumerate() {
            if old >= n {
                return Err(format!("index {old} out of range 0..{n}"));
            }
            if old_to_new[old] != usize::MAX {
                return Err(format!("index {old} appears twice"));
            }
            old_to_new[old] = new;
        }
        Ok(Permutation { new_to_old, old_to_new })
    }

    pub fn len(&self) -> usize {
        self.new_to_old.len()
    }

    pub fn is_empty(&self) -> bool {
        self.new_to_old.is_empty()
    }

    pub fn is_identity(&self) -> bool {
        self.new_to_old.iter().enumerate().all(|(new, &old)| new == old)
    }

    #[inline]
    pub fn old_of(&self, new: usize) -> usize {
        self.new_to_old[new]
    }

    #[inline]
    pub fn new_of(&self, old: usize) -> usize {
        self.old_to_new[old]
    }

    /// The `perm[new] = old` view (what [`rcm`] computed).
    pub fn as_new_to_old(&self) -> &[usize] {
        &self.new_to_old
    }

    /// Gather a vector into the *new* ordering: `out[new] = x[old]`.
    pub fn apply(&self, x: &[f64], out: &mut [f64]) {
        assert_eq!(x.len(), self.len());
        assert_eq!(out.len(), self.len());
        for (o, &old) in out.iter_mut().zip(&self.new_to_old) {
            *o = x[old];
        }
    }

    /// Scatter a reordered vector back: `out[old] = y[new]`.
    pub fn apply_inverse(&self, y: &[f64], out: &mut [f64]) {
        assert_eq!(y.len(), self.len());
        assert_eq!(out.len(), self.len());
        for (o, &new) in out.iter_mut().zip(&self.old_to_new) {
            *o = y[new];
        }
    }

    /// k-wide [`Permutation::apply`]: gather row-major n×k panels,
    /// `out[new·k + c] = x[old·k + c]` — k columns permuted per product
    /// with one pass over the index vector.
    pub fn apply_multi(&self, x: &[f64], out: &mut [f64], k: usize) {
        assert!(k >= 1);
        assert_eq!(x.len(), self.len() * k);
        assert_eq!(out.len(), self.len() * k);
        for (o, &old) in out.chunks_exact_mut(k).zip(&self.new_to_old) {
            o.copy_from_slice(&x[old * k..old * k + k]);
        }
    }

    /// k-wide [`Permutation::apply_inverse`]: `out[old·k + c] = y[new·k + c]`.
    pub fn apply_inverse_multi(&self, y: &[f64], out: &mut [f64], k: usize) {
        assert!(k >= 1);
        assert_eq!(y.len(), self.len() * k);
        assert_eq!(out.len(), self.len() * k);
        for (o, &new) in out.chunks_exact_mut(k).zip(&self.old_to_new) {
            o.copy_from_slice(&y[new * k..new * k + k]);
        }
    }

    /// The inverse bijection (swaps the two directions).
    pub fn inverse(&self) -> Permutation {
        Permutation { new_to_old: self.old_to_new.clone(), old_to_new: self.new_to_old.clone() }
    }
}

/// Symmetric adjacency of a kernel's scatter pattern (each unordered
/// pair mirrored both ways), CSR-shaped. The contract on
/// [`SpmvKernel::scatter_targets`] — each pair visited once across the
/// sweep — makes the mirroring exact.
fn symmetric_adjacency(a: &dyn SpmvKernel) -> (Vec<u32>, Vec<u32>) {
    let n = a.dim();
    let mut deg = vec![0u32; n];
    for i in 0..n {
        a.scatter_targets(i, &mut |j| {
            deg[i] += 1;
            deg[j] += 1;
        });
    }
    let mut xadj = vec![0u32; n + 1];
    for i in 0..n {
        xadj[i + 1] = xadj[i] + deg[i];
    }
    let mut cursor: Vec<u32> = xadj[..n].to_vec();
    let mut adj = vec![0u32; xadj[n] as usize];
    for i in 0..n {
        a.scatter_targets(i, &mut |j| {
            adj[cursor[i] as usize] = j as u32;
            cursor[i] += 1;
            adj[cursor[j] as usize] = i as u32;
            cursor[j] += 1;
        });
    }
    (xadj, adj)
}

/// The BFS level structure rooted at `seed`: (eccentricity, vertices of
/// the deepest level). `mark`/`epoch` implement O(level-structure-size)
/// visited tracking — the caller bumps `epoch` instead of clearing the
/// n-length array, so a graph of many components costs O(n + nnz)
/// total, not O(n × components).
fn level_structure(
    xadj: &[u32],
    adj: &[u32],
    seed: usize,
    mark: &mut [usize],
    epoch: usize,
) -> (usize, Vec<usize>) {
    mark[seed] = epoch;
    let mut frontier = vec![seed];
    let mut depth = 0usize;
    loop {
        let mut next: Vec<usize> = Vec::new();
        for &v in &frontier {
            for &u in &adj[xadj[v] as usize..xadj[v + 1] as usize] {
                let u = u as usize;
                if mark[u] != epoch {
                    mark[u] = epoch;
                    next.push(u);
                }
            }
        }
        if next.is_empty() {
            return (depth, frontier);
        }
        depth += 1;
        frontier = next;
    }
}

/// George–Liu pseudo-peripheral vertex: root a level structure at a
/// minimum-degree start, re-root at a minimum-degree vertex of the
/// deepest level while the eccentricity keeps growing. Strictly
/// increasing depth bounds the iteration by the graph diameter.
fn pseudo_peripheral(
    xadj: &[u32],
    adj: &[u32],
    start: usize,
    mark: &mut [usize],
    epoch: &mut usize,
) -> usize {
    let mut seed = start;
    *epoch += 1;
    let (mut depth, mut last) = level_structure(xadj, adj, seed, mark, *epoch);
    loop {
        let candidate = *last
            .iter()
            .min_by_key(|&&u| (xadj[u + 1] - xadj[u], u as u32))
            .unwrap_or(&seed);
        if candidate == seed {
            return seed;
        }
        *epoch += 1;
        let (d2, l2) = level_structure(xadj, adj, candidate, mark, *epoch);
        if d2 <= depth {
            return seed;
        }
        seed = candidate;
        depth = d2;
        last = l2;
    }
}

/// Reverse Cuthill–McKee over the kernel's symmetric scatter pattern:
/// per connected component, a Cuthill–McKee traversal from a
/// pseudo-peripheral vertex — each dequeued vertex appends its
/// unvisited neighbours in ascending-degree order (the per-*vertex*
/// queue discipline matters: it reproduces a full band's own ordering
/// exactly, which per-level batching does not) — then the whole order
/// reversed. Rows with no off-diagonal entries are bandwidth-neutral;
/// they are emitted adjacently (and end up reversed with everything
/// else — a scatter-free kernel maps to the full reversal, not the
/// identity).
pub fn rcm(a: &dyn SpmvKernel) -> Permutation {
    let _span = crate::obs::phase(crate::obs::Phase::Reorder);
    let n = a.dim();
    let (xadj, adj) = symmetric_adjacency(a);
    let mut visited = vec![false; n];
    let mut mark = vec![0usize; n];
    let mut epoch = 0usize;
    let mut order: Vec<usize> = Vec::with_capacity(n);
    // Components seeded smallest-degree-first; each is traversed from a
    // pseudo-peripheral vertex (long, thin level structure → small
    // bandwidth).
    let mut by_degree: Vec<usize> = (0..n).collect();
    by_degree.sort_by_key(|&v| (xadj[v + 1] - xadj[v], v as u32));
    for &cand in &by_degree {
        if visited[cand] {
            continue;
        }
        // Isolated vertices (every row of a scatter-free kernel) are
        // their own component and bandwidth-neutral: emit directly, no
        // pseudo-peripheral search.
        if xadj[cand + 1] == xadj[cand] {
            visited[cand] = true;
            order.push(cand);
            continue;
        }
        let seed = pseudo_peripheral(&xadj, &adj, cand, &mut mark, &mut epoch);
        let mut head = order.len();
        order.push(seed);
        visited[seed] = true;
        while head < order.len() {
            let v = order[head];
            head += 1;
            let mut nbrs: Vec<usize> = adj[xadj[v] as usize..xadj[v + 1] as usize]
                .iter()
                .map(|&u| u as usize)
                .filter(|&u| !visited[u])
                .collect();
            nbrs.sort_by_key(|&u| (xadj[u + 1] - xadj[u], u as u32));
            for u in nbrs {
                visited[u] = true;
                order.push(u);
            }
        }
    }
    order.reverse();
    Permutation::from_new_to_old(order).expect("the traversal visits every vertex exactly once")
}

/// Half-bandwidth of the kernel's symmetric pattern: max |i − j| over
/// scatter pairs (0 for scatter-free kernels).
pub fn pattern_half_bandwidth(a: &dyn SpmvKernel) -> usize {
    let n = a.dim();
    let mut bw = 0usize;
    for i in 0..n {
        a.scatter_targets(i, &mut |j| {
            bw = bw.max(if j > i { j - i } else { i - j });
        });
    }
    bw
}

/// The full reorder analysis for one kernel — the single implementation
/// behind the plan's reorder stage ([`crate::plan::PlanBuilder::reorder`])
/// and the tuner's reorder context: RCM permutation plus half-bandwidth
/// before/after, so both always agree on what reordering would buy.
pub fn analyze(kernel: &dyn SpmvKernel) -> crate::plan::ReorderPlan {
    let perm = rcm(kernel);
    let hbw_before = pattern_half_bandwidth(kernel);
    let hbw_after = permuted_half_bandwidth(kernel, &perm);
    crate::plan::ReorderPlan { perm: Arc::new(perm), hbw_before, hbw_after }
}

/// Half-bandwidth the pattern *would* have under `perm` — computed from
/// the scatter pairs alone, no permuted matrix needed (the plan's
/// reorder stage records before/after from this).
pub fn permuted_half_bandwidth(a: &dyn SpmvKernel, perm: &Permutation) -> usize {
    let n = a.dim();
    assert_eq!(perm.len(), n);
    let mut bw = 0usize;
    for i in 0..n {
        let pi = perm.new_of(i);
        a.scatter_targets(i, &mut |j| {
            let pj = perm.new_of(j);
            bw = bw.max(if pj > pi { pj - pi } else { pi - pj });
        });
    }
    bw
}

/// A solver-facing operator in the *original* numbering, executed
/// through a reordered inner operator `B = P A Pᵀ`: apply permutes x
/// in, runs B, and un-permutes y out. `apply_t` and `diagonal` forward
/// the same way, so `bicg` (needs Aᵀx) and `Jacobi::new` (needs the
/// diagonal) work transparently.
pub struct ReorderedLinOp<O: LinOp> {
    inner: O,
    perm: Permutation,
    /// Permute/un-permute scratch (px, py), reused across applies: the
    /// sandwich sits on the solver hot path (every cg/gmres/bicg
    /// iteration), so it must not allocate per call. Uncontended Mutex —
    /// same pattern as [`crate::solver::EngineLinOp`].
    scratch: std::sync::Mutex<(Vec<f64>, Vec<f64>)>,
}

impl<O: LinOp> ReorderedLinOp<O> {
    /// `inner` must act in the reordered numbering (e.g. the matrix from
    /// [`crate::sparse::Csrc::permuted`] with the same `perm`).
    pub fn new(inner: O, perm: Permutation) -> ReorderedLinOp<O> {
        assert_eq!(inner.dim(), perm.len(), "operator/permutation size mismatch");
        let n = perm.len();
        ReorderedLinOp {
            inner,
            perm,
            scratch: std::sync::Mutex::new((vec![0.0; n], vec![0.0; n])),
        }
    }

    pub fn permutation(&self) -> &Permutation {
        &self.perm
    }

    pub fn inner(&self) -> &O {
        &self.inner
    }
}

impl<O: LinOp> LinOp for ReorderedLinOp<O> {
    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn apply(&self, x: &[f64], y: &mut [f64]) {
        let mut s = self.scratch.lock().unwrap();
        let (px, py) = &mut *s;
        self.perm.apply(x, px);
        self.inner.apply(px, py);
        self.perm.apply_inverse(py, y);
    }

    fn apply_t(&self, x: &[f64], y: &mut [f64]) -> Result<(), String> {
        // (Pᵀ B P)ᵀ = Pᵀ Bᵀ P — the same permute/un-permute sandwich.
        let mut s = self.scratch.lock().unwrap();
        let (px, py) = &mut *s;
        self.perm.apply(x, px);
        self.inner.apply_t(px, py)?;
        self.perm.apply_inverse(py, y);
        Ok(())
    }

    fn diagonal(&self) -> Option<Vec<f64>> {
        // diag(A)[old] = diag(B)[new_of(old)].
        let d = self.inner.diagonal()?;
        let mut out = vec![0.0; d.len()];
        self.perm.apply_inverse(&d, &mut out);
        Some(out)
    }
}

/// The same sandwich one level down: a [`ParallelSpmv`] engine built
/// over the *permuted* kernel, exposed in the original numbering. The
/// permute/un-permute gathers are part of every product — the tuner's
/// reordered candidates are timed through this wrapper so the measured
/// rate is end-to-end honest, and the service workers serve through it.
pub struct ReorderedEngine {
    inner: Box<dyn ParallelSpmv>,
    perm: Arc<Permutation>,
    /// Permute/un-permute scratch, reused in place across products and
    /// grown (never shrunk) to `n·k` for the widest panel seen — the
    /// sandwich must not allocate fresh n-vectors per product.
    px: Vec<f64>,
    py: Vec<f64>,
    /// How many times the scratch pair (re)allocated — tests assert this
    /// stays at the grow-once minimum across repeated products.
    scratch_reallocs: usize,
}

impl ReorderedEngine {
    pub fn new(inner: Box<dyn ParallelSpmv>, perm: Arc<Permutation>) -> ReorderedEngine {
        let n = perm.len();
        ReorderedEngine {
            inner,
            perm,
            px: vec![0.0; n],
            py: vec![0.0; n],
            scratch_reallocs: 1,
        }
    }

    /// Allocation count of the permute scratch (1 after construction;
    /// +1 only when a wider panel forces a grow).
    pub fn scratch_reallocs(&self) -> usize {
        self.scratch_reallocs
    }

    fn ensure_scratch(&mut self, len: usize) {
        if self.px.len() < len {
            self.px = vec![0.0; len];
            self.py = vec![0.0; len];
            self.scratch_reallocs += 1;
        }
    }
}

impl ParallelSpmv for ReorderedEngine {
    fn spmv(&mut self, x: &[f64], y: &mut [f64]) {
        let n = self.perm.len();
        let gather = crate::obs::phase(crate::obs::Phase::PermuteScatter);
        self.perm.apply(x, &mut self.px[..n]);
        drop(gather);
        self.inner.spmv(&self.px[..n], &mut self.py[..n]);
        let _scatter = crate::obs::phase(crate::obs::Phase::PermuteScatter);
        self.perm.apply_inverse(&self.py[..n], y);
    }

    fn spmv_multi(&mut self, x: &[f64], y: &mut [f64], k: usize) {
        assert!(k >= 1);
        if k == 1 {
            return self.spmv(x, y);
        }
        let n = self.perm.len();
        self.ensure_scratch(n * k);
        // Split borrows: perm/inner are disjoint from px/py.
        let perm = self.perm.clone();
        let gather = crate::obs::phase(crate::obs::Phase::PermuteScatter);
        perm.apply_multi(x, &mut self.px[..n * k], k);
        drop(gather);
        self.inner.spmv_multi(&self.px[..n * k], &mut self.py[..n * k], k);
        let _scatter = crate::obs::phase(crate::obs::Phase::PermuteScatter);
        perm.apply_inverse_multi(&self.py[..n * k], y, k);
    }

    fn name(&self) -> String {
        format!("reordered/{}", self.inner.name())
    }

    fn nthreads(&self) -> usize {
        self.inner.nthreads()
    }

    fn plan(&self) -> Option<&Arc<SpmvPlan>> {
        self.inner.plan()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::parallel::{build_engine_auto, AccumMethod, EngineKind};
    use crate::solver::{self, Jacobi};
    use crate::sparse::{Coo, Csrc};
    use crate::util::{propcheck, Rng};

    fn random(n: usize, npr: usize, seed: u64) -> Csrc {
        let mut rng = Rng::new(seed);
        Csrc::from_coo(&Coo::random_structurally_symmetric(n, npr, false, &mut rng)).unwrap()
    }

    #[test]
    fn permutation_validates_and_inverts() {
        assert!(Permutation::from_new_to_old(vec![0, 2, 1]).is_ok());
        assert!(Permutation::from_new_to_old(vec![0, 0, 1]).is_err());
        assert!(Permutation::from_new_to_old(vec![0, 3, 1]).is_err());
        let p = Permutation::from_new_to_old(vec![2, 0, 1]).unwrap();
        assert_eq!(p.old_of(0), 2);
        assert_eq!(p.new_of(2), 0);
        let inv = p.inverse();
        for i in 0..3 {
            assert_eq!(inv.old_of(p.new_of(i)), i);
        }
        assert!(Permutation::identity(5).is_identity());
        assert!(!p.is_identity());
    }

    #[test]
    fn apply_then_inverse_roundtrips() {
        let mut rng = Rng::new(1);
        let p = Permutation::from_new_to_old(rng.permutation(40)).unwrap();
        let x: Vec<f64> = (0..40).map(|_| rng.normal()).collect();
        let mut px = vec![0.0; 40];
        let mut back = vec![0.0; 40];
        p.apply(&x, &mut px);
        p.apply_inverse(&px, &mut back);
        propcheck::assert_close(&back, &x, 0.0, 0.0).unwrap();
        // apply gathers: px[new] = x[old].
        for new in 0..40 {
            assert_eq!(px[new], x[p.old_of(new)]);
        }
    }

    #[test]
    fn apply_multi_matches_columnwise_apply() {
        let mut rng = Rng::new(21);
        let p = Permutation::from_new_to_old(rng.permutation(30)).unwrap();
        for k in [1usize, 2, 3, 8] {
            let x: Vec<f64> = (0..30 * k).map(|_| rng.normal()).collect();
            let mut panel = vec![0.0; 30 * k];
            p.apply_multi(&x, &mut panel, k);
            let mut back = vec![0.0; 30 * k];
            p.apply_inverse_multi(&panel, &mut back, k);
            propcheck::assert_close(&back, &x, 0.0, 0.0).unwrap();
            for c in 0..k {
                let xc: Vec<f64> = (0..30).map(|i| x[i * k + c]).collect();
                let mut want = vec![0.0; 30];
                p.apply(&xc, &mut want);
                for new in 0..30 {
                    assert_eq!(panel[new * k + c], want[new], "k={k} c={c}");
                }
            }
        }
    }

    /// Satellite: the reordered sandwich permutes through reused scratch
    /// — repeated products (including k-wide ones at a fixed k) must not
    /// allocate; only a wider panel may grow the pair, once.
    #[test]
    fn reordered_engine_scratch_grows_once() {
        let a = std::sync::Arc::new(random(80, 3, 22));
        let perm = Arc::new(rcm(a.as_ref()));
        let pa = std::sync::Arc::new(a.permuted(&perm));
        let inner = build_engine_auto(EngineKind::LocalBuffers(AccumMethod::Effective), pa, 2);
        let mut engine = ReorderedEngine::new(inner, perm);
        assert_eq!(engine.scratch_reallocs(), 1);
        let x: Vec<f64> = (0..80).map(|i| (i as f64).sin()).collect();
        let mut y = vec![0.0; 80];
        for _ in 0..3 {
            engine.spmv(&x, &mut y);
        }
        assert_eq!(engine.scratch_reallocs(), 1, "k=1 products must not allocate");
        let xp: Vec<f64> = (0..80 * 4).map(|i| (i as f64).cos()).collect();
        let mut yp = vec![0.0; 80 * 4];
        for _ in 0..3 {
            engine.spmv_multi(&xp, &mut yp, 4);
        }
        assert_eq!(engine.scratch_reallocs(), 2, "k=4 grows once, then reuses");
        let xp2: Vec<f64> = (0..80 * 2).map(|i| (i as f64 * 0.3).sin()).collect();
        let mut yp2 = vec![0.0; 80 * 2];
        engine.spmv_multi(&xp2, &mut yp2, 2);
        engine.spmv(&x, &mut y);
        assert_eq!(engine.scratch_reallocs(), 2, "narrower panels reuse the wide scratch");
    }

    #[test]
    fn rcm_is_a_permutation_on_random_patterns() {
        let a = random(90, 4, 2);
        let p = rcm(&a);
        let mut s = p.as_new_to_old().to_vec();
        s.sort_unstable();
        assert_eq!(s, (0..90).collect::<Vec<_>>());
    }

    /// Satellite: RCM must never *increase* the half-bandwidth of an
    /// already optimally ordered band matrix — the BFS from a
    /// pseudo-peripheral vertex of a full band walks it end to end.
    #[test]
    fn rcm_never_increases_bandwidth_on_banded() {
        propcheck::check(10, |rng| {
            let n = 30 + rng.below(120);
            let hbw = 1 + rng.below(4);
            let a = Csrc::from_coo(&Coo::banded(n, hbw, false, rng)).map_err(|e| e.to_string())?;
            let before = a.half_bandwidth();
            let p = rcm(&a);
            let after = permuted_half_bandwidth(&a, &p);
            if after > before {
                return Err(format!("RCM grew the band: {before} -> {after}"));
            }
            Ok(())
        });
    }

    #[test]
    fn rcm_recovers_band_from_shuffle() {
        let mut rng = Rng::new(3);
        let band = Csrc::from_coo(&Coo::banded(300, 2, true, &mut rng)).unwrap();
        let shuffle = Permutation::from_new_to_old(rng.permutation(300)).unwrap();
        let shuffled = band.permuted(&shuffle);
        assert!(shuffled.half_bandwidth() > 30, "shuffle must destroy the band");
        let p = rcm(&shuffled);
        let restored = shuffled.permuted(&p);
        assert!(
            restored.half_bandwidth() <= shuffled.half_bandwidth() / 4,
            "RCM {} vs shuffled {}",
            restored.half_bandwidth(),
            shuffled.half_bandwidth()
        );
        // The analytic half-bandwidth matches the built matrix.
        assert_eq!(permuted_half_bandwidth(&shuffled, &p), restored.half_bandwidth());
    }

    #[test]
    fn permuted_matrix_preserves_the_operator() {
        // (P A Pᵀ)(P x) == P (A x) ⇔ the reordered LinOp equals A.
        let a = random(70, 3, 4);
        let mut rng = Rng::new(5);
        let perm = Permutation::from_new_to_old(rng.permutation(70)).unwrap();
        let b = a.permuted(&perm);
        let op = ReorderedLinOp::new(b, perm);
        let x: Vec<f64> = (0..70).map(|_| rng.normal()).collect();
        let (mut y1, mut y2) = (vec![0.0; 70], vec![0.0; 70]);
        a.apply(&x, &mut y1);
        op.apply(&x, &mut y2);
        propcheck::assert_close(&y1, &y2, 1e-11, 1e-11).unwrap();
        // Transpose too (bicg's requirement).
        a.apply_t(&x, &mut y1).unwrap();
        op.apply_t(&x, &mut y2).unwrap();
        propcheck::assert_close(&y1, &y2, 1e-11, 1e-11).unwrap();
        // Diagonal comes back in the original numbering (Jacobi's
        // requirement).
        assert_eq!(op.diagonal().unwrap(), a.diagonal().unwrap());
    }

    #[test]
    fn solvers_run_transparently_on_reordered_operators() {
        let a = random(60, 3, 6);
        let perm = rcm(&a);
        let b = a.permuted(&perm);
        let op = ReorderedLinOp::new(b, perm);
        let mut rng = Rng::new(7);
        let xstar: Vec<f64> = (0..60).map(|_| rng.normal()).collect();
        let mut rhs = vec![0.0; 60];
        a.apply(&xstar, &mut rhs);
        // bicg exercises apply_t; Jacobi exercises diagonal.
        let r = solver::bicg(&op, &rhs, 1e-10, 600).unwrap();
        assert!(r.converged, "residual {}", r.residual);
        for (got, want) in r.x.iter().zip(&xstar) {
            assert!((got - want).abs() < 1e-6, "{got} vs {want}");
        }
        let jac = Jacobi::new(&op).expect("reordered operator exposes its diagonal");
        let g = solver::gmres(&op, &rhs, 30, 1e-10, 200);
        assert!(g.converged, "gmres residual {}", g.residual);
        let _ = jac;
    }

    #[test]
    fn reordered_engine_matches_plain_execution() {
        let a = std::sync::Arc::new(random(120, 4, 8));
        let perm = Arc::new(rcm(a.as_ref()));
        let pa = std::sync::Arc::new(a.permuted(&perm));
        let x: Vec<f64> = (0..120).map(|i| (i as f64 * 0.1).sin()).collect();
        let mut want = vec![0.0; 120];
        a.spmv_into_zeroed(&x, &mut want);
        for kind in [
            EngineKind::Sequential,
            EngineKind::LocalBuffers(AccumMethod::Effective),
            EngineKind::LocalBuffers(AccumMethod::Interval),
            EngineKind::Colorful,
            EngineKind::Atomic,
        ] {
            let inner = build_engine_auto(kind, pa.clone(), 3);
            let mut engine = ReorderedEngine::new(inner, perm.clone());
            assert!(engine.name().starts_with("reordered/"));
            let mut y = vec![f64::NAN; 120];
            engine.spmv(&x, &mut y);
            propcheck::assert_close(&y, &want, 1e-11, 1e-11)
                .unwrap_or_else(|e| panic!("{}: {e}", kind.label()));
        }
    }
}
