//! PJRT runtime — loads the AOT artifacts (`artifacts/*.hlo.txt` +
//! `manifest.json` produced by `python/compile/aot.py`) and executes them
//! on the XLA CPU client. This is the only place the jax-lowered L1/L2
//! compute runs; python is never on the request path.
//!
//! Interchange is HLO *text* (the image's xla_extension 0.5.1 rejects
//! jax ≥ 0.5 serialized protos — see /opt/xla-example/README.md).

use crate::sparse::Ell;
use crate::util::json::Json;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// One entry of `manifest.json`.
#[derive(Clone, Debug)]
pub struct ManifestEntry {
    pub name: String,
    pub file: String,
    pub n: usize,
    pub w: usize,
    pub batch: Option<usize>,
    pub params: Vec<(String, Vec<usize>, String)>,
    pub outputs: Vec<(String, Vec<usize>, String)>,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub entries: Vec<ManifestEntry>,
}

impl Manifest {
    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> anyhow::Result<Manifest> {
        let j = Json::parse(text).map_err(|e| anyhow::anyhow!("manifest: {e}"))?;
        let mut entries = Vec::new();
        for e in j
            .get("entries")
            .and_then(|x| x.as_arr())
            .ok_or_else(|| anyhow::anyhow!("manifest missing entries"))?
        {
            let shapes = |key: &str| -> Vec<(String, Vec<usize>, String)> {
                e.get(key)
                    .and_then(|x| x.as_arr())
                    .map(|ps| {
                        ps.iter()
                            .map(|p| {
                                (
                                    p.get("name").and_then(|x| x.as_str()).unwrap_or("").to_string(),
                                    p.get("shape")
                                        .and_then(|x| x.as_arr())
                                        .map(|s| s.iter().filter_map(|d| d.as_usize()).collect())
                                        .unwrap_or_default(),
                                    p.get("dtype").and_then(|x| x.as_str()).unwrap_or("f32").to_string(),
                                )
                            })
                            .collect()
                    })
                    .unwrap_or_default()
            };
            entries.push(ManifestEntry {
                name: e.get("name").and_then(|x| x.as_str()).unwrap_or("").to_string(),
                file: e.get("file").and_then(|x| x.as_str()).unwrap_or("").to_string(),
                n: e.get("n").and_then(|x| x.as_usize()).unwrap_or(0),
                w: e.get("w").and_then(|x| x.as_usize()).unwrap_or(0),
                batch: e.get("batch").and_then(|x| if x.is_null() { None } else { x.as_usize() }),
                params: shapes("params"),
                outputs: shapes("outputs"),
            });
        }
        Ok(Manifest { entries })
    }

    pub fn find(&self, name: &str) -> Option<&ManifestEntry> {
        self.entries.iter().find(|e| e.name == name)
    }
}

/// The live runtime: a PJRT CPU client plus lazily compiled executables.
pub struct XlaRuntime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl XlaRuntime {
    /// Open the artifact directory (compiles nothing yet).
    pub fn open(dir: &Path) -> anyhow::Result<XlaRuntime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(XlaRuntime { client, dir: dir.to_path_buf(), manifest, exes: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (once) and cache the named artifact.
    pub fn ensure_compiled(&mut self, name: &str) -> anyhow::Result<()> {
        if self.exes.contains_key(name) {
            return Ok(());
        }
        let entry = self
            .manifest
            .find(name)
            .ok_or_else(|| anyhow::anyhow!("artifact {name} not in manifest"))?;
        let path = self.dir.join(&entry.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        self.exes.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute an artifact with positional literal arguments; returns the
    /// flattened output tuple (aot.py lowers with return_tuple=True).
    pub fn execute(&mut self, name: &str, args: &[xla::Literal]) -> anyhow::Result<Vec<xla::Literal>> {
        self.ensure_compiled(name)?;
        let exe = self.exes.get(name).unwrap();
        let result = exe.execute::<xla::Literal>(args)?[0][0].to_literal_sync()?;
        let tuple = result.to_tuple()?;
        Ok(tuple)
    }

    /// y = A·x via the Pallas-lowered SpMV artifact for this (n, w) shape.
    pub fn spmv(&mut self, name: &str, ell: &Ell, x: &[f32]) -> anyhow::Result<Vec<f32>> {
        let entry = self
            .manifest
            .find(name)
            .ok_or_else(|| anyhow::anyhow!("artifact {name} not in manifest"))?
            .clone();
        anyhow::ensure!(
            entry.n == ell.n && entry.w == ell.w,
            "shape mismatch: artifact {}x{} vs ell {}x{}",
            entry.n,
            entry.w,
            ell.n,
            ell.w
        );
        anyhow::ensure!(x.len() == ell.n, "x length {} != n {}", x.len(), ell.n);
        let args = vec![
            xla::Literal::vec1(&ell.ad),
            xla::Literal::vec1(&ell.al).reshape(&[ell.n as i64, ell.w as i64])?,
            xla::Literal::vec1(&ell.au).reshape(&[ell.n as i64, ell.w as i64])?,
            xla::Literal::vec1(&ell.ja).reshape(&[ell.n as i64, ell.w as i64])?,
            xla::Literal::vec1(x),
        ];
        let out = self.execute(name, &args)?;
        anyhow::ensure!(!out.is_empty(), "empty output tuple");
        Ok(out[0].to_vec::<f32>()?)
    }

    /// Batched SpMV: xs is `batch` rows of length n, row-major.
    pub fn spmv_batch(
        &mut self,
        name: &str,
        ell: &Ell,
        xs: &[f32],
        batch: usize,
    ) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(xs.len() == batch * ell.n);
        let args = vec![
            xla::Literal::vec1(&ell.ad),
            xla::Literal::vec1(&ell.al).reshape(&[ell.n as i64, ell.w as i64])?,
            xla::Literal::vec1(&ell.au).reshape(&[ell.n as i64, ell.w as i64])?,
            xla::Literal::vec1(&ell.ja).reshape(&[ell.n as i64, ell.w as i64])?,
            xla::Literal::vec1(xs).reshape(&[batch as i64, ell.n as i64])?,
        ];
        let out = self.execute(name, &args)?;
        Ok(out[0].to_vec::<f32>()?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses_real_shape() {
        let text = r#"{
          "format": "hlo-text", "return_tuple": true,
          "entries": [
            {"name": "spmv_n256_w8", "file": "spmv_n256_w8.hlo.txt",
             "n": 256, "w": 8, "batch": null,
             "params": [{"name": "ad", "shape": [256], "dtype": "f32"},
                        {"name": "x", "shape": [256], "dtype": "f32"}],
             "outputs": [{"name": "y", "shape": [256], "dtype": "f32"}]}
          ]}"#;
        let m = Manifest::parse(text).unwrap();
        assert_eq!(m.entries.len(), 1);
        let e = m.find("spmv_n256_w8").unwrap();
        assert_eq!(e.n, 256);
        assert_eq!(e.w, 8);
        assert_eq!(e.batch, None);
        assert_eq!(e.params[0].0, "ad");
        assert_eq!(e.outputs[0].1, vec![256]);
    }

    #[test]
    fn manifest_batch_entry() {
        let text = r#"{"entries": [{"name": "b", "file": "b.hlo.txt",
            "n": 4, "w": 2, "batch": 8, "params": [], "outputs": []}]}"#;
        let m = Manifest::parse(text).unwrap();
        assert_eq!(m.find("b").unwrap().batch, Some(8));
    }

    #[test]
    fn missing_artifact_is_error() {
        let m = Manifest::parse(r#"{"entries": []}"#).unwrap();
        assert!(m.find("nope").is_none());
    }
}
