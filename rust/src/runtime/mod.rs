//! PJRT runtime — loads the AOT artifacts (`artifacts/*.hlo.txt` +
//! `manifest.json` produced by `python/compile/aot.py`) and executes them
//! on the XLA CPU client. This is the only place the jax-lowered L1/L2
//! compute runs; python is never on the request path.
//!
//! Interchange is HLO *text* (the image's xla_extension 0.5.1 rejects
//! jax ≥ 0.5 serialized protos — see /opt/xla-example/README.md).
//!
//! The PJRT client itself needs the `xla` crate and the `xla_extension`
//! shared library, which exist only in the image's offline vendor tree —
//! so everything touching them is gated behind the **`xla` cargo
//! feature** (off by default; see DESIGN.md §Runtime). Without the
//! feature, [`Manifest`] parsing still works and [`XlaRuntime::open`]
//! returns a clean error instead of failing to link.

use crate::util::error::{msg, Result};
use crate::util::json::Json;
use std::path::Path;

/// One entry of `manifest.json`.
#[derive(Clone, Debug)]
pub struct ManifestEntry {
    pub name: String,
    pub file: String,
    pub n: usize,
    pub w: usize,
    pub batch: Option<usize>,
    pub params: Vec<(String, Vec<usize>, String)>,
    pub outputs: Vec<(String, Vec<usize>, String)>,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub entries: Vec<ManifestEntry>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).map_err(|e| msg(format!("manifest: {e}")))?;
        let mut entries = Vec::new();
        for e in j
            .get("entries")
            .and_then(|x| x.as_arr())
            .ok_or_else(|| msg("manifest missing entries"))?
        {
            let shapes = |key: &str| -> Vec<(String, Vec<usize>, String)> {
                e.get(key)
                    .and_then(|x| x.as_arr())
                    .map(|ps| {
                        ps.iter()
                            .map(|p| {
                                (
                                    p.get("name").and_then(|x| x.as_str()).unwrap_or("").to_string(),
                                    p.get("shape")
                                        .and_then(|x| x.as_arr())
                                        .map(|s| s.iter().filter_map(|d| d.as_usize()).collect())
                                        .unwrap_or_default(),
                                    p.get("dtype").and_then(|x| x.as_str()).unwrap_or("f32").to_string(),
                                )
                            })
                            .collect()
                    })
                    .unwrap_or_default()
            };
            entries.push(ManifestEntry {
                name: e.get("name").and_then(|x| x.as_str()).unwrap_or("").to_string(),
                file: e.get("file").and_then(|x| x.as_str()).unwrap_or("").to_string(),
                n: e.get("n").and_then(|x| x.as_usize()).unwrap_or(0),
                w: e.get("w").and_then(|x| x.as_usize()).unwrap_or(0),
                batch: e.get("batch").and_then(|x| if x.is_null() { None } else { x.as_usize() }),
                params: shapes("params"),
                outputs: shapes("outputs"),
            });
        }
        Ok(Manifest { entries })
    }

    pub fn find(&self, name: &str) -> Option<&ManifestEntry> {
        self.entries.iter().find(|e| e.name == name)
    }
}

/// The live runtime: a PJRT CPU client plus lazily compiled executables.
/// Real implementation — only with the `xla` feature (needs the vendored
/// `xla` crate and the xla_extension shared library).
#[cfg(feature = "xla")]
pub struct XlaRuntime {
    client: xla::PjRtClient,
    dir: std::path::PathBuf,
    pub manifest: Manifest,
    exes: std::collections::HashMap<String, xla::PjRtLoadedExecutable>,
}

#[cfg(feature = "xla")]
impl XlaRuntime {
    /// Open the artifact directory (compiles nothing yet).
    pub fn open(dir: &Path) -> Result<XlaRuntime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(XlaRuntime {
            client,
            dir: dir.to_path_buf(),
            manifest,
            exes: std::collections::HashMap::new(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (once) and cache the named artifact.
    pub fn ensure_compiled(&mut self, name: &str) -> Result<()> {
        if self.exes.contains_key(name) {
            return Ok(());
        }
        let entry = self
            .manifest
            .find(name)
            .ok_or_else(|| msg(format!("artifact {name} not in manifest")))?;
        let path = self.dir.join(&entry.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| msg("non-utf8 path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        self.exes.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute an artifact with positional literal arguments; returns the
    /// flattened output tuple (aot.py lowers with return_tuple=True).
    pub fn execute(&mut self, name: &str, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        self.ensure_compiled(name)?;
        let exe = self.exes.get(name).unwrap();
        let result = exe.execute::<xla::Literal>(args)?[0][0].to_literal_sync()?;
        let tuple = result.to_tuple()?;
        Ok(tuple)
    }

    /// y = A·x via the Pallas-lowered SpMV artifact for this (n, w) shape.
    pub fn spmv(&mut self, name: &str, ell: &crate::sparse::Ell, x: &[f32]) -> Result<Vec<f32>> {
        let entry = self
            .manifest
            .find(name)
            .ok_or_else(|| msg(format!("artifact {name} not in manifest")))?
            .clone();
        if entry.n != ell.n || entry.w != ell.w {
            return Err(msg(format!(
                "shape mismatch: artifact {}x{} vs ell {}x{}",
                entry.n, entry.w, ell.n, ell.w
            )));
        }
        if x.len() != ell.n {
            return Err(msg(format!("x length {} != n {}", x.len(), ell.n)));
        }
        let args = vec![
            xla::Literal::vec1(&ell.ad),
            xla::Literal::vec1(&ell.al).reshape(&[ell.n as i64, ell.w as i64])?,
            xla::Literal::vec1(&ell.au).reshape(&[ell.n as i64, ell.w as i64])?,
            xla::Literal::vec1(&ell.ja).reshape(&[ell.n as i64, ell.w as i64])?,
            xla::Literal::vec1(x),
        ];
        let out = self.execute(name, &args)?;
        if out.is_empty() {
            return Err(msg("empty output tuple"));
        }
        Ok(out[0].to_vec::<f32>()?)
    }

    /// Batched SpMV: xs is `batch` rows of length n, row-major.
    pub fn spmv_batch(
        &mut self,
        name: &str,
        ell: &crate::sparse::Ell,
        xs: &[f32],
        batch: usize,
    ) -> Result<Vec<f32>> {
        if xs.len() != batch * ell.n {
            return Err(msg("xs length mismatch"));
        }
        let args = vec![
            xla::Literal::vec1(&ell.ad),
            xla::Literal::vec1(&ell.al).reshape(&[ell.n as i64, ell.w as i64])?,
            xla::Literal::vec1(&ell.au).reshape(&[ell.n as i64, ell.w as i64])?,
            xla::Literal::vec1(&ell.ja).reshape(&[ell.n as i64, ell.w as i64])?,
            xla::Literal::vec1(xs).reshape(&[batch as i64, ell.n as i64])?,
        ];
        let out = self.execute(name, &args)?;
        Ok(out[0].to_vec::<f32>()?)
    }
}

/// Feature-off stub: manifest parsing still works, but opening the
/// runtime reports the missing feature instead of failing to link
/// against xla_extension. Keeps `csrc xla` and the router compiling on
/// machines without the runtime.
#[cfg(not(feature = "xla"))]
pub struct XlaRuntime {
    pub manifest: Manifest,
}

#[cfg(not(feature = "xla"))]
impl XlaRuntime {
    const DISABLED: &'static str =
        "built without the `xla` feature; on an image providing the xla_extension \
         runtime, add the vendored `xla` crate to Cargo.toml and rebuild with \
         `--features xla` (see DESIGN.md §5)";

    pub fn open(_dir: &Path) -> Result<XlaRuntime> {
        Err(msg(Self::DISABLED))
    }

    pub fn platform(&self) -> String {
        "disabled".into()
    }

    pub fn spmv(&mut self, _name: &str, _ell: &crate::sparse::Ell, _x: &[f32]) -> Result<Vec<f32>> {
        Err(msg(Self::DISABLED))
    }

    pub fn spmv_batch(
        &mut self,
        _name: &str,
        _ell: &crate::sparse::Ell,
        _xs: &[f32],
        _batch: usize,
    ) -> Result<Vec<f32>> {
        Err(msg(Self::DISABLED))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses_real_shape() {
        let text = r#"{
          "format": "hlo-text", "return_tuple": true,
          "entries": [
            {"name": "spmv_n256_w8", "file": "spmv_n256_w8.hlo.txt",
             "n": 256, "w": 8, "batch": null,
             "params": [{"name": "ad", "shape": [256], "dtype": "f32"},
                        {"name": "x", "shape": [256], "dtype": "f32"}],
             "outputs": [{"name": "y", "shape": [256], "dtype": "f32"}]}
          ]}"#;
        let m = Manifest::parse(text).unwrap();
        assert_eq!(m.entries.len(), 1);
        let e = m.find("spmv_n256_w8").unwrap();
        assert_eq!(e.n, 256);
        assert_eq!(e.w, 8);
        assert_eq!(e.batch, None);
        assert_eq!(e.params[0].0, "ad");
        assert_eq!(e.outputs[0].1, vec![256]);
    }

    #[test]
    fn manifest_batch_entry() {
        let text = r#"{"entries": [{"name": "b", "file": "b.hlo.txt",
            "n": 4, "w": 2, "batch": 8, "params": [], "outputs": []}]}"#;
        let m = Manifest::parse(text).unwrap();
        assert_eq!(m.find("b").unwrap().batch, Some(8));
    }

    #[test]
    fn missing_artifact_is_error() {
        let m = Manifest::parse(r#"{"entries": []}"#).unwrap();
        assert!(m.find("nope").is_none());
    }
}
