//! Criterion-style micro-bench harness (criterion is not vendored).
//!
//! Usage from a `[[bench]] harness = false` target:
//!
//! ```no_run
//! use csrc_spmv::util::bench::Bench;
//! let mut b = Bench::new("fig5_sequential");
//! b.run("csr/poisson2d", || { /* one product */ });
//! b.finish();
//! ```
//!
//! Reports median / MAD over samples after warmup; honours
//! `CSRC_BENCH_FAST=1` for CI-speed runs.

use super::stats;
use std::time::Instant;

pub struct Bench {
    group: String,
    rows: Vec<(String, f64, f64, usize)>, // (name, median_s, mad_s, iters)
    samples: usize,
    min_iters: usize,
    target_sample_s: f64,
}

impl Bench {
    pub fn new(group: &str) -> Self {
        let fast = std::env::var("CSRC_BENCH_FAST").ok().as_deref() == Some("1");
        println!("== bench group: {group} ==");
        Self {
            group: group.to_string(),
            rows: Vec::new(),
            samples: if fast { 3 } else { 7 },
            min_iters: 1,
            target_sample_s: if fast { 0.02 } else { 0.15 },
        }
    }

    /// Time `f`, choosing an iteration count so one sample lasts
    /// ~target_sample_s, then record `samples` samples.
    pub fn run<F: FnMut()>(&mut self, name: &str, mut f: F) -> f64 {
        // Calibrate.
        let mut iters = self.min_iters;
        loop {
            let t = Instant::now();
            for _ in 0..iters {
                f();
            }
            let dt = t.elapsed().as_secs_f64();
            if dt >= self.target_sample_s || iters >= 1 << 24 {
                break;
            }
            let scale = (self.target_sample_s / dt.max(1e-9)).min(64.0);
            iters = ((iters as f64 * scale).ceil() as usize).max(iters + 1);
        }
        // Measure.
        let mut per_iter = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t = Instant::now();
            for _ in 0..iters {
                f();
            }
            per_iter.push(t.elapsed().as_secs_f64() / iters as f64);
        }
        let med = stats::median(&per_iter).expect("bench samples are never empty");
        let mad = stats::mad(&per_iter).expect("bench samples are never empty");
        println!(
            "{:<48} {:>12} / iter   (±{:.1}%, {} iters × {} samples)",
            name,
            fmt_time(med),
            if med > 0.0 { 100.0 * mad / med } else { 0.0 },
            iters,
            self.samples
        );
        self.rows.push((name.to_string(), med, mad, iters));
        med
    }

    /// Record an externally computed scalar (e.g. Mflop/s, speedup) so it
    /// appears in the bench report alongside timings.
    pub fn record(&mut self, name: &str, value: f64, unit: &str) {
        println!("{:<48} {:>12.3} {}", name, value, unit);
        self.rows.push((format!("{name} [{unit}]"), value, 0.0, 0));
    }

    pub fn finish(self) {
        println!("== {} done: {} entries ==\n", self.group, self.rows.len());
    }

    /// Like [`Bench::finish`], but also writes the rows as a JSON report
    /// (`{"group": .., "entries": [{name, median_s, mad_s, iters}, ..]}`)
    /// so ablation results are machine-readable alongside the stdout log.
    pub fn finish_json(self, path: &std::path::Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let mut s = String::from("{\n");
        s.push_str(&format!("  \"group\": \"{}\",\n", json_escape(&self.group)));
        s.push_str("  \"entries\": [\n");
        for (i, (name, med, mad, iters)) in self.rows.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"name\": \"{}\", \"median_s\": {:e}, \"mad_s\": {:e}, \"iters\": {}}}{}\n",
                json_escape(name),
                med,
                mad,
                iters,
                if i + 1 == self.rows.len() { "" } else { "," }
            ));
        }
        s.push_str("  ]\n}\n");
        std::fs::write(path, &s)?;
        println!("== {} done: {} entries -> {} ==\n", self.group, self.rows.len(), path.display());
        Ok(())
    }
}

fn json_escape(s: &str) -> String {
    s.chars()
        .flat_map(|c| match c {
            '"' => "\\\"".chars().collect::<Vec<_>>(),
            '\\' => "\\\\".chars().collect(),
            '\n' => "\\n".chars().collect(),
            c if (c as u32) < 0x20 => format!("\\u{:04x}", c as u32).chars().collect(),
            c => vec![c],
        })
        .collect()
}

fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        std::env::set_var("CSRC_BENCH_FAST", "1");
        let mut b = Bench::new("selftest");
        let mut acc = 0u64;
        let med = b.run("noop-ish", || {
            acc = acc.wrapping_add(std::hint::black_box(1));
        });
        assert!(med > 0.0 && med < 0.1);
        b.finish();
    }

    #[test]
    fn finish_json_writes_parseable_report() {
        std::env::set_var("CSRC_BENCH_FAST", "1");
        let mut b = Bench::new("jsontest");
        b.record("alpha/one", 1.5, "x");
        b.record("beta \"q\"", 2.0, "colors");
        let path = std::env::temp_dir().join("csrc_bench_test").join("out.json");
        b.finish_json(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let j = crate::util::json::Json::parse(&text).unwrap();
        assert_eq!(j.get("group").and_then(|g| g.as_str()), Some("jsontest"));
        assert_eq!(j.get("entries").and_then(|e| e.as_arr()).map(|a| a.len()), Some(2));
    }

    #[test]
    fn fmt_time_ranges() {
        assert!(fmt_time(2.0).ends_with(" s"));
        assert!(fmt_time(2e-3).ends_with("ms"));
        assert!(fmt_time(2e-6).ends_with("µs"));
        assert!(fmt_time(2e-9).ends_with("ns"));
    }
}
