//! Small std-only infrastructure: seeded RNG, statistics, timers, a JSON
//! reader for the AOT manifest, a CLI parser, a criterion-style bench
//! harness and a property-test runner.
//!
//! These exist because the build environment is offline: only the `xla`
//! crate's vendored dep tree is available, so `rand`, `clap`, `serde_json`,
//! `criterion` and `proptest` are replaced by the minimal in-tree versions
//! below. Each is deliberately tiny and fully unit-tested.

pub mod bench;
pub mod cli;
pub mod error;
pub mod json;
pub mod propcheck;
pub mod stats;

/// Lock a mutex, recovering from poisoning instead of propagating it.
/// The serving stack isolates worker panics with `catch_unwind`; a
/// panic while holding a shared lock must not take down every other
/// thread that touches it later. The guarded data is counters, caches,
/// and registries that stay internally consistent under panic (their
/// updates are single statements), so the poison flag carries no
/// information for us.
pub fn lock_unpoisoned<T>(m: &std::sync::Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Crash-safe file write shared by the decision cache and the cost-model
/// files: create the parent directory, write to a pid-suffixed temp file,
/// then rename into place — a crash mid-write can never leave a truncated
/// file that later readers silently degrade past.
pub fn write_atomic(path: &std::path::Path, contents: &str) -> std::io::Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let tmp = path.with_extension(format!("tmp.{}", std::process::id()));
    std::fs::write(&tmp, contents)?;
    std::fs::rename(&tmp, path)
}

/// xorshift64* — deterministic, seedable, good enough for workload
/// generation and property tests (not cryptographic).
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // Avoid the all-zero fixed point.
        Self { state: seed.wrapping_mul(0x9E3779B97F4A7C15).max(1) }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in [0, bound).
    #[inline]
    pub fn below(&mut self, bound: usize) -> usize {
        assert!(bound > 0);
        (self.next_u64() % bound as u64) as usize
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// k distinct values from [0, bound), sorted. O(bound) when k ~ bound,
    /// rejection sampling otherwise.
    pub fn distinct_below(&mut self, k: usize, bound: usize) -> Vec<usize> {
        assert!(k <= bound);
        if k * 3 >= bound {
            // Partial Fisher-Yates.
            let mut all: Vec<usize> = (0..bound).collect();
            for i in 0..k {
                let j = i + self.below(bound - i);
                all.swap(i, j);
            }
            let mut v = all[..k].to_vec();
            v.sort_unstable();
            v
        } else {
            let mut seen = std::collections::HashSet::with_capacity(k * 2);
            let mut v = Vec::with_capacity(k);
            while v.len() < k {
                let x = self.below(bound);
                if seen.insert(x) {
                    v.push(x);
                }
            }
            v.sort_unstable();
            v
        }
    }

    /// Random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut v: Vec<usize> = (0..n).collect();
        for i in (1..n).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
        v
    }
}

/// Monotonic wall-clock timer returning seconds.
pub struct Timer(std::time::Instant);

impl Timer {
    pub fn start() -> Self {
        Self(std::time::Instant::now())
    }
    pub fn elapsed_s(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn rng_below_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn rng_f64_unit_interval() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn distinct_below_properties() {
        let mut r = Rng::new(3);
        for k in [0usize, 1, 5, 20] {
            let v = r.distinct_below(k, 20);
            assert_eq!(v.len(), k);
            let mut sorted = v.clone();
            sorted.dedup();
            assert_eq!(sorted.len(), k, "duplicates in {v:?}");
            assert!(v.iter().all(|&x| x < 20));
            assert!(v.windows(2).all(|w| w[0] < w[1]));
        }
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Rng::new(5);
        let p = r.permutation(50);
        let mut s = p.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn normal_has_sane_moments() {
        let mut r = Rng::new(11);
        let n = 20000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }
}
