//! Minimal JSON reader/writer — enough to parse `artifacts/manifest.json`
//! written by `python/compile/aot.py` and to persist the autotuner's
//! decision cache (objects, arrays, strings, numbers, bools, null). No
//! serde in the offline vendor tree.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    /// Build an object from (key, value) pairs — the serializer-side
    /// convenience shared by the decision cache and the cost-model
    /// files, so their JSON shape comes from one place.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect::<BTreeMap<_, _>>())
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    /// Serialize to compact JSON text. Round-trips through
    /// [`Json::parse`] for everything the model represents, except
    /// non-finite numbers, which become `null` (JSON has no NaN/Inf).
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.dump_into(&mut out);
        out
    }

    fn dump_into(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if !x.is_finite() {
                    out.push_str("null");
                } else if *x == x.trunc() && x.abs() < 9e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x:e}"));
                }
            }
            Json::Str(s) => dump_str(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.dump_into(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    dump_str(k, out);
                    out.push(':');
                    v.dump_into(out);
                }
                out.push('}');
            }
        }
    }
}

fn dump_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(c) => {
                    // Collect a run of plain bytes for speed.
                    let start = self.i;
                    while self.i < self.b.len()
                        && self.b[self.i] != b'"'
                        && self.b[self.i] != b'\\'
                    {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                    let _ = c;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-2.5e1").unwrap(), Json::Num(-25.0));
        assert_eq!(Json::parse("\"hi\\nthere\"").unwrap(), Json::Str("hi\nthere".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("x")
        );
        assert!(j.get("c").unwrap().is_null());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn parses_manifest_shape() {
        let j = Json::parse(
            r#"{"format":"hlo-text","entries":[{"name":"spmv","n":256,"w":8,
                 "params":[{"name":"ad","shape":[256],"dtype":"f32"}]}]}"#,
        )
        .unwrap();
        let e = &j.get("entries").unwrap().as_arr().unwrap()[0];
        assert_eq!(e.get("n").unwrap().as_usize(), Some(256));
        assert_eq!(
            e.get("params").unwrap().as_arr().unwrap()[0]
                .get("shape")
                .unwrap()
                .as_arr()
                .unwrap()[0]
                .as_usize(),
            Some(256)
        );
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn dump_roundtrips() {
        let text = r#"{"a": [1, -2.5, {"b": "x\ny"}], "c": null, "d": true, "e": 0.125}"#;
        let j = Json::parse(text).unwrap();
        let back = Json::parse(&j.dump()).unwrap();
        assert_eq!(back, j);
        // Integral numbers stay readable; floats use exponent form.
        let dumped = Json::Num(42.0).dump();
        assert_eq!(dumped, "42");
        assert_eq!(Json::parse(&Json::Num(0.5).dump()).unwrap(), Json::Num(0.5));
        // Non-finite numbers degrade to null rather than invalid JSON.
        assert_eq!(Json::Num(f64::NAN).dump(), "null");
    }

    #[test]
    fn as_bool_accessor() {
        assert_eq!(Json::Bool(true).as_bool(), Some(true));
        assert_eq!(Json::Null.as_bool(), None);
    }
}
