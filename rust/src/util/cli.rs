//! Tiny CLI argument parser (clap is not in the offline vendor tree).
//!
//! Model: `csrc <subcommand> [positional...] [--flag] [--key value]`.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse raw argv-style tokens (after the subcommand).
    pub fn parse<I: IntoIterator<Item = String>>(items: I) -> Args {
        let mut out = Args::default();
        let mut it = items.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.options.insert(name.to_string(), v);
                } else {
                    out.flags.push(name.to_string());
                }
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    pub fn opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn opt_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.opt(key).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.opt(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.opt(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects a number, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(v: &[&str]) -> Vec<String> {
        v.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_mixed() {
        let a = Args::parse(s(&["pos1", "--k", "v", "--flag", "--x=3", "pos2"]));
        assert_eq!(a.positional, vec!["pos1", "pos2"]);
        assert_eq!(a.opt("k"), Some("v"));
        assert_eq!(a.opt("x"), Some("3"));
        assert!(a.has_flag("flag"));
    }

    #[test]
    fn typed_accessors() {
        let a = Args::parse(s(&["--n", "42", "--rho", "0.5"]));
        assert_eq!(a.usize_or("n", 0), 42);
        assert_eq!(a.f64_or("rho", 0.0), 0.5);
        assert_eq!(a.usize_or("missing", 7), 7);
    }

    #[test]
    fn trailing_flag() {
        let a = Args::parse(s(&["--verbose"]));
        assert!(a.has_flag("verbose"));
        assert!(a.opt("verbose").is_none());
    }
}
