//! Summary statistics used by the bench harness and the paper-figure
//! reports (the paper reports *medians over three runs*, §4).

/// Median of a slice (not in-place; handles even lengths by averaging).
pub fn median(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

pub fn mean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
}

/// Median absolute deviation — robust spread estimate for bench noise.
pub fn mad(xs: &[f64]) -> f64 {
    let m = median(xs);
    let devs: Vec<f64> = xs.iter().map(|x| (x - m).abs()).collect();
    median(&devs)
}

/// Geometric mean (used for "average speedup over the suite").
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let s: f64 = xs.iter().map(|x| x.max(1e-300).ln()).sum();
    (s / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
        assert_eq!(median(&[5.0]), 5.0);
    }

    #[test]
    fn mean_simple() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
    }

    #[test]
    fn minmax() {
        let xs = [2.0, -1.0, 7.0];
        assert_eq!(min(&xs), -1.0);
        assert_eq!(max(&xs), 7.0);
    }

    #[test]
    fn mad_robust_to_outlier() {
        let clean = [1.0, 1.1, 0.9, 1.0, 1.05];
        let noisy = [1.0, 1.1, 0.9, 1.0, 100.0];
        assert!(mad(&noisy) < 1.0, "mad should shrug off one outlier");
        assert!(mad(&clean) < 0.2);
    }

    #[test]
    fn geomean_of_speedups() {
        let g = geomean(&[2.0, 8.0]);
        assert!((g - 4.0).abs() < 1e-12);
    }
}
