//! Summary statistics used by the bench harness and the paper-figure
//! reports (the paper reports *medians over three runs*, §4).
//!
//! The location/spread estimators ([`median`], [`mean`], [`mad`],
//! [`geomean`]) return `None` for an empty slice — there is no honest
//! number to report — so the empty case is part of the signature instead
//! of a panic deep inside a measurement loop. [`min`]/[`max`] keep their
//! fold identities (±∞) for the empty slice, which every consumer treats
//! as "no data".

/// Median of a slice (not in-place; handles even lengths by averaging).
/// `None` when `xs` is empty.
pub fn median(xs: &[f64]) -> Option<f64> {
    if xs.is_empty() {
        return None;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    Some(if n % 2 == 1 { v[n / 2] } else { 0.5 * (v[n / 2 - 1] + v[n / 2]) })
}

/// Arithmetic mean; `None` when `xs` is empty.
pub fn mean(xs: &[f64]) -> Option<f64> {
    if xs.is_empty() {
        return None;
    }
    Some(xs.iter().sum::<f64>() / xs.len() as f64)
}

/// Minimum (`+∞` for an empty slice — the fold identity).
pub fn min(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::INFINITY, f64::min)
}

/// Maximum (`-∞` for an empty slice — the fold identity).
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
}

/// Median absolute deviation — robust spread estimate for bench noise.
/// `None` when `xs` is empty.
pub fn mad(xs: &[f64]) -> Option<f64> {
    let m = median(xs)?;
    let devs: Vec<f64> = xs.iter().map(|x| (x - m).abs()).collect();
    median(&devs)
}

/// Geometric mean (used for "average speedup over the suite").
/// `None` when `xs` is empty.
pub fn geomean(xs: &[f64]) -> Option<f64> {
    if xs.is_empty() {
        return None;
    }
    let s: f64 = xs.iter().map(|x| x.max(1e-300).ln()).sum();
    Some((s / xs.len() as f64).exp())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), Some(2.0));
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), Some(2.5));
        assert_eq!(median(&[5.0]), Some(5.0));
    }

    #[test]
    fn empty_slices_are_not_a_panic() {
        assert_eq!(median(&[]), None);
        assert_eq!(mean(&[]), None);
        assert_eq!(mad(&[]), None);
        assert_eq!(geomean(&[]), None);
        assert_eq!(min(&[]), f64::INFINITY);
        assert_eq!(max(&[]), f64::NEG_INFINITY);
    }

    #[test]
    fn mean_simple() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), Some(2.0));
    }

    #[test]
    fn minmax() {
        let xs = [2.0, -1.0, 7.0];
        assert_eq!(min(&xs), -1.0);
        assert_eq!(max(&xs), 7.0);
    }

    #[test]
    fn mad_robust_to_outlier() {
        let clean = [1.0, 1.1, 0.9, 1.0, 1.05];
        let noisy = [1.0, 1.1, 0.9, 1.0, 100.0];
        assert!(mad(&noisy).unwrap() < 1.0, "mad should shrug off one outlier");
        assert!(mad(&clean).unwrap() < 0.2);
    }

    #[test]
    fn geomean_of_speedups() {
        let g = geomean(&[2.0, 8.0]).unwrap();
        assert!((g - 4.0).abs() < 1e-12);
    }
}
