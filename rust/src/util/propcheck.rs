//! Minimal property-test runner (proptest is not vendored).
//!
//! `check(cases, |rng| { ... })` runs the closure over `cases` seeded RNGs
//! and panics with the *failing seed* so any failure is reproducible with
//! `check_seed(seed, ...)`. Closures return `Result<(), String>` so the
//! failure message travels with the seed.

use super::Rng;

/// Run `f` for seeds 0..cases (plus a few adversarial seeds); panic with
/// the failing seed and message on first failure.
pub fn check<F>(cases: u64, f: F)
where
    F: Fn(&mut Rng) -> Result<(), String>,
{
    let extra = [u64::MAX, 0xDEADBEEF, 1 << 63];
    for seed in (0..cases).chain(extra.iter().copied()) {
        let mut rng = Rng::new(seed);
        if let Err(msg) = f(&mut rng) {
            panic!("property failed at seed {seed}: {msg}");
        }
    }
}

/// Re-run a single seed (for debugging a failure printed by `check`).
pub fn check_seed<F>(seed: u64, f: F)
where
    F: Fn(&mut Rng) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    if let Err(msg) = f(&mut rng) {
        panic!("property failed at seed {seed}: {msg}");
    }
}

/// Assert two f64 slices are elementwise close (relative + absolute tol),
/// returning a property-friendly Result.
pub fn assert_close(a: &[f64], b: &[f64], rtol: f64, atol: f64) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch: {} vs {}", a.len(), b.len()));
    }
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let tol = atol + rtol * x.abs().max(y.abs());
        if (x - y).abs() > tol || x.is_nan() != y.is_nan() {
            return Err(format!("index {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_seeds() {
        let mut count = std::sync::atomic::AtomicU64::new(0);
        check(10, |_rng| {
            count.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            Ok(())
        });
        assert_eq!(*count.get_mut(), 13); // 10 + 3 adversarial
    }

    #[test]
    #[should_panic(expected = "property failed at seed")]
    fn failing_property_reports_seed() {
        check(5, |rng| {
            if rng.below(3) == 0 {
                Err("boom".into())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn assert_close_catches_mismatch() {
        assert!(assert_close(&[1.0], &[1.0 + 1e-12], 1e-9, 0.0).is_ok());
        assert!(assert_close(&[1.0], &[1.1], 1e-9, 0.0).is_err());
        assert!(assert_close(&[1.0], &[1.0, 2.0], 1e-9, 0.0).is_err());
    }
}
