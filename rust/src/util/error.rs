//! Minimal error plumbing for the binary and the runtime loader (anyhow
//! is not vendored — the build is offline and dependency-free).
//!
//! `Error` is a boxed `std::error::Error`, so `?` converts any std error
//! automatically; [`msg`] makes an ad-hoc message error the way
//! `anyhow::anyhow!` would.

/// Boxed dynamic error (Send + Sync so it crosses service threads).
pub type Error = Box<dyn std::error::Error + Send + Sync + 'static>;

/// Result alias used by `main.rs` and the runtime loader.
pub type Result<T> = std::result::Result<T, Error>;

/// An ad-hoc message error: `return Err(msg(format!("bad {x}")))`.
pub fn msg(m: impl std::fmt::Display) -> Error {
    m.to_string().into()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails_io() -> Result<String> {
        Ok(std::fs::read_to_string("/definitely/not/a/path")?)
    }

    #[test]
    fn msg_displays_and_io_converts() {
        let e = msg(format!("bad value {}", 7));
        assert_eq!(e.to_string(), "bad value 7");
        assert!(fails_io().is_err());
    }
}
