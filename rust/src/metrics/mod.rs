//! Measurement helpers: Mflop/s accounting (§4.1 flop formulas), the
//! paper's median-of-three protocol, and a tiny latency histogram used by
//! the coordinator.

use crate::util::stats;
use std::time::Instant;

/// Mflop/s given a flop count and elapsed seconds.
pub fn mflops(flops: usize, seconds: f64) -> f64 {
    flops as f64 / seconds.max(1e-12) / 1e6
}

/// Run `f`, returning its result and the elapsed wall-clock seconds —
/// used by the plan builder so per-phase analysis cost (partition,
/// ranges, intervals, coloring) lands in [`crate::plan::PlanStats`] and,
/// aggregated, in the coordinator's `ServiceStats::plan_build_seconds`.
#[inline]
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Instant::now();
    let out = f();
    (out, t.elapsed().as_secs_f64())
}

/// The paper's protocol: run `products` SpMVs per measurement, repeat
/// `runs` times, report the median (§4: 1000 products, median of 3).
/// See [`median_and_spread_of_runs`] for the zero-runs contract.
pub fn median_of_runs<F: FnMut()>(runs: usize, products: usize, one_product: F) -> f64 {
    median_and_spread_of_runs(runs, products, one_product).0
}

/// [`median_of_runs`] plus the MAD across runs — the tuner's trial
/// protocol records both so that noisy wins stay visible in reports.
///
/// An empty budget (`runs == 0` or `products == 0`) measures nothing and
/// returns `(+∞, 0.0)` — "infinitely slow, perfectly certain" — instead
/// of panicking inside [`stats::median`]'s non-empty contract. The +∞
/// turns into a 0 Mflop/s rate through [`mflops`], so an unmeasured
/// candidate can never be declared a winner by accident.
pub fn median_and_spread_of_runs<F: FnMut()>(
    runs: usize,
    products: usize,
    mut one_product: F,
) -> (f64, f64) {
    if runs == 0 || products == 0 {
        return (f64::INFINITY, 0.0);
    }
    let mut samples = Vec::with_capacity(runs);
    for _ in 0..runs {
        let t = Instant::now();
        for _ in 0..products {
            one_product();
        }
        samples.push(t.elapsed().as_secs_f64() / products as f64);
    }
    (
        stats::median(&samples).expect("runs > 0 was checked above"),
        stats::mad(&samples).expect("runs > 0 was checked above"),
    )
}

/// Fixed-bucket latency histogram (power-of-two microsecond buckets).
#[derive(Clone, Debug)]
pub struct LatencyHistogram {
    /// counts[i] = latencies in [2^i, 2^{i+1}) microseconds.
    counts: Vec<u64>,
    total: u64,
    sum_us: f64,
    max_us: f64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self { counts: vec![0; 32], total: 0, sum_us: 0.0, max_us: 0.0 }
    }

    /// Record one latency. Sub-microsecond latencies are clamped into
    /// the first bucket `[1, 2)µs` — `us.max(1.0)` keeps the `log2`
    /// defined — so no quantile can ever report below 2µs; `mean_us` and
    /// `max_us` keep the exact value.
    pub fn record(&mut self, seconds: f64) {
        let us = seconds * 1e6;
        let bucket = (us.max(1.0).log2() as usize).min(self.counts.len() - 1);
        self.counts[bucket] += 1;
        self.total += 1;
        self.sum_us += us;
        self.max_us = self.max_us.max(us);
    }

    /// Fold `other` into `self`. Every histogram shares the fixed
    /// power-of-two bucket layout, so bucket-wise addition is exact:
    /// merging per-worker histograms is indistinguishable (for counts,
    /// mean, max, and every quantile) from having recorded all samples
    /// into one histogram — the service-level distribution the
    /// coordinator snapshots instead of averaging workers wrongly.
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (mine, theirs) in self.counts.iter_mut().zip(other.counts.iter()) {
            *mine += *theirs;
        }
        self.total += other.total;
        self.sum_us += other.sum_us;
        self.max_us = self.max_us.max(other.max_us);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    /// Sum of all recorded latencies in µs (summary `_sum` exposition).
    pub fn sum_us(&self) -> f64 {
        self.sum_us
    }

    pub fn mean_us(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum_us / self.total as f64
        }
    }

    pub fn max_us(&self) -> f64 {
        self.max_us
    }

    /// Upper bound of the bucket containing quantile q (approximate).
    /// `q` is clamped into [0, 1], and the rank is clamped to at least
    /// one sample: `q = 0` therefore reports the smallest *recorded*
    /// bucket — with a plain `acc >= want` and `want = 0`, the first
    /// (possibly empty) bucket would satisfy the scan and the answer
    /// would be 2µs regardless of the data.
    pub fn quantile_us(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let want = ((q.clamp(0.0, 1.0) * self.total as f64).ceil() as u64).max(1);
        let mut acc = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= want {
                return (1u64 << (i + 1)) as f64;
            }
        }
        self.max_us
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mflops_basic() {
        assert_eq!(mflops(2_000_000, 1.0), 2.0);
        assert!(mflops(1, 0.0).is_finite());
    }

    #[test]
    fn timed_returns_result_and_duration() {
        let (v, s) = timed(|| 6 * 7);
        assert_eq!(v, 42);
        assert!(s >= 0.0);
    }

    #[test]
    fn median_of_runs_measures() {
        let mut calls = 0usize;
        let per = median_of_runs(3, 10, || {
            calls += 1;
            std::hint::black_box(calls);
        });
        assert_eq!(calls, 30);
        assert!(per >= 0.0 && per < 0.1);
    }

    #[test]
    fn median_and_spread_reports_both() {
        let (med, mad) = median_and_spread_of_runs(3, 5, || {
            std::hint::black_box(1u64);
        });
        assert!(med >= 0.0 && mad >= 0.0);
    }

    #[test]
    fn histogram_quantiles_ordered() {
        let mut h = LatencyHistogram::new();
        for i in 1..=1000 {
            h.record(i as f64 * 1e-6);
        }
        assert_eq!(h.count(), 1000);
        assert!(h.quantile_us(0.5) <= h.quantile_us(0.99));
        assert!(h.mean_us() > 0.0);
        assert!(h.max_us() >= 999.0);
    }

    #[test]
    fn quantile_zero_reports_the_smallest_recorded_bucket() {
        let mut h = LatencyHistogram::new();
        h.record(100e-6); // bucket [64, 128)µs
        h.record(200e-6); // bucket [128, 256)µs
        assert_eq!(
            h.quantile_us(0.0),
            128.0,
            "q=0 must skip empty leading buckets, not report their 2µs bound"
        );
        // Out-of-range q is clamped, not undefined.
        assert_eq!(h.quantile_us(-3.0), 128.0);
        assert_eq!(h.quantile_us(7.0), h.quantile_us(1.0));
        assert!(h.quantile_us(1.0) >= h.quantile_us(0.0));
        // Empty histogram still answers 0 for any q.
        assert_eq!(LatencyHistogram::new().quantile_us(0.0), 0.0);
    }

    #[test]
    fn merge_is_equivalent_to_recording_into_one_histogram() {
        use crate::util::propcheck;
        propcheck::check(25, |rng| {
            // Random samples split across a random number of "worker"
            // histograms, then merged, must match one histogram that
            // recorded every sample: counts, mean, max, and quantiles.
            let workers = 1 + rng.below(5);
            let mut parts: Vec<LatencyHistogram> =
                (0..workers).map(|_| LatencyHistogram::new()).collect();
            let mut whole = LatencyHistogram::new();
            for _ in 0..rng.below(200) {
                // Spread samples across ~9 decades, sub-µs to seconds.
                let seconds = 10f64.powf(rng.f64() * 9.0 - 7.0);
                parts[rng.below(workers)].record(seconds);
                whole.record(seconds);
            }
            let mut merged = LatencyHistogram::new();
            for p in &parts {
                merged.merge(p);
            }
            if merged.count() != whole.count() {
                return Err(format!("count {} vs {}", merged.count(), whole.count()));
            }
            if (merged.mean_us() - whole.mean_us()).abs() > 1e-9 * whole.mean_us().max(1.0) {
                return Err(format!("mean {} vs {}", merged.mean_us(), whole.mean_us()));
            }
            if merged.max_us() != whole.max_us() {
                return Err(format!("max {} vs {}", merged.max_us(), whole.max_us()));
            }
            for q in [0.0, 0.1, 0.5, 0.9, 0.99, 1.0] {
                if merged.quantile_us(q) != whole.quantile_us(q) {
                    return Err(format!(
                        "q{q}: {} vs {}",
                        merged.quantile_us(q),
                        whole.quantile_us(q)
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn merge_into_empty_copies_and_empty_merge_is_noop() {
        let mut h = LatencyHistogram::new();
        h.record(50e-6);
        let mut empty = LatencyHistogram::new();
        empty.merge(&h);
        assert_eq!(empty.count(), 1);
        assert_eq!(empty.max_us(), h.max_us());
        h.merge(&LatencyHistogram::new());
        assert_eq!(h.count(), 1);
        assert_eq!(h.sum_us(), 50.0);
    }

    #[test]
    fn empty_budgets_measure_as_infinitely_slow() {
        let (per, mad) = median_and_spread_of_runs(0, 5, || {});
        assert!(per.is_infinite() && mad == 0.0);
        let (per, _) = median_and_spread_of_runs(3, 0, || {});
        assert!(per.is_infinite());
        // The defined value flows into a 0 rate, never a winning one.
        assert_eq!(mflops(1_000_000, per), 0.0);
    }
}
