//! The 60-matrix evaluation suite (Table 1 substitute).
//!
//! Table 1's matrices come from the UF collection plus the authors'
//! in-house FEM models. We regenerate the same *spectrum* — n from ~1 K to
//! ~1 M, nnz/row from 2 to 1000, working sets from well-in-cache to far
//! out-of-cache, symmetric and structurally-symmetric-only, banded and
//! irregular, plus the `_o32`/`_n32` domain-decomposition variants — from
//! seeded generators. Real `.mtx` files can be dropped in via
//! `sparse::mmio` and the CLI.
//!
//! Sizes are scaled (DESIGN.md §2): the largest paper matrices (cage15,
//! audikw_1, cube2m) exceed this container's time budget at full size, so
//! they appear at reduced n with the same structure class. ws classes
//! relative to the simulated caches (6 MB / 8 MB) are preserved: the suite
//! spans ~0.2 MB to ~80 MB.

use crate::gen;
use crate::sparse::{Coo, Csr, Csrc};
use crate::util::Rng;

/// How a dataset entry is produced.
#[derive(Clone, Debug)]
pub enum MatrixKind {
    Dense { n: usize },
    Banded { n: usize, hbw: usize, sym: bool },
    RandomSym { n: usize, nnz_per_row: usize, sym: bool },
    Poisson2dTri { nx: usize, convection: f64 },
    Poisson2dQuad { nx: usize, convection: f64 },
    Poisson3dHex { nx: usize, convection: f64 },
    Elasticity2d { nx: usize },
    /// Overlapping DD local (rectangular) of a Poisson3d global — only its
    /// square part enters the square-matrix experiments.
    OverlapLocal { nx: usize, nsub: usize, s: usize },
    /// Non-overlapping DD local (square).
    NonoverlapLocal { nx: usize, nsub: usize, s: usize },
}

#[derive(Clone, Debug)]
pub struct DatasetEntry {
    pub name: &'static str,
    pub kind: MatrixKind,
    /// Numerically symmetric (Table 1 "Sym." column).
    pub sym: bool,
    pub seed: u64,
}

impl DatasetEntry {
    /// Materialize the COO (deterministic per seed).
    pub fn build_coo(&self) -> Coo {
        let mut rng = Rng::new(self.seed);
        match self.kind {
            MatrixKind::Dense { n } => Coo::dense_random(n, &mut rng),
            MatrixKind::Banded { n, hbw, sym } => Coo::banded(n, hbw, sym, &mut rng),
            MatrixKind::RandomSym { n, nnz_per_row, sym } => {
                Coo::random_structurally_symmetric(n, nnz_per_row, sym, &mut rng)
            }
            MatrixKind::Poisson2dTri { nx, convection } => {
                gen::poisson_2d_tri(nx, convection, self.seed)
            }
            MatrixKind::Poisson2dQuad { nx, convection } => {
                gen::poisson_2d_quad(nx, convection, self.seed)
            }
            MatrixKind::Poisson3dHex { nx, convection } => {
                gen::poisson_3d_hex(nx, convection, self.seed)
            }
            MatrixKind::Elasticity2d { nx } => gen::elasticity_2d(nx, self.seed),
            MatrixKind::OverlapLocal { nx, nsub, s } => {
                let g = Csr::from_coo(&gen::poisson_3d_hex(nx, 0.4, self.seed));
                gen::overlapping_local(&g, nsub, s)
            }
            MatrixKind::NonoverlapLocal { nx, nsub, s } => {
                let g = Csr::from_coo(&gen::poisson_3d_hex(nx, 0.0, self.seed));
                gen::nonoverlapping_local(&g, nsub, s)
            }
        }
    }

    /// Materialize as CSRC (square part for the overlap rectangles).
    pub fn build_csrc(&self) -> Csrc {
        let coo = self.build_coo();
        if coo.nrows == coo.ncols {
            Csrc::from_coo(&coo).expect("dataset entries must be structurally symmetric")
        } else {
            crate::sparse::CsrcRect::from_coo(&coo)
                .expect("overlap locals must have CSRC square parts")
                .square
        }
    }
}

/// The full 60-entry suite mirroring Table 1's spectrum.
pub fn full_suite() -> Vec<DatasetEntry> {
    use MatrixKind::*;
    let mut v = Vec::new();
    let mut seed = 1000u64;
    let mut push = |name: &'static str, kind: MatrixKind, sym: bool, v: &mut Vec<DatasetEntry>| {
        seed += 1;
        v.push(DatasetEntry { name, kind, sym, seed });
    };
    // --- small, in-cache (the paper's thermal .. k3plates region).
    push("thermal", Poisson2dQuad { nx: 58, convection: 0.3 }, false, &mut v);
    push("ex37", Poisson2dQuad { nx: 59, convection: 0.4 }, false, &mut v);
    push("flowmeter5", RandomSym { n: 9669, nnz_per_row: 3, sym: false }, false, &mut v);
    push("piston", RandomSym { n: 2025, nnz_per_row: 24, sym: false }, false, &mut v);
    push("SiNa", RandomSym { n: 5743, nnz_per_row: 8, sym: true }, true, &mut v);
    push("benzene", RandomSym { n: 8219, nnz_per_row: 7, sym: true }, true, &mut v);
    push("cage10", RandomSym { n: 11397, nnz_per_row: 6, sym: false }, false, &mut v);
    push("spmsrtls", Banded { n: 29995, hbw: 2, sym: true }, true, &mut v);
    push("torsion1", Banded { n: 40000, hbw: 1, sym: true }, true, &mut v);
    push("minsurfo", Banded { n: 40806, hbw: 1, sym: true }, true, &mut v);
    push("wang4", Poisson3dHex { nx: 29, convection: 0.5 }, false, &mut v);
    push("chem_master1", Banded { n: 40401, hbw: 2, sym: false }, false, &mut v);
    push("dixmaanl", Banded { n: 60000, hbw: 1, sym: true }, true, &mut v);
    push("chipcool1", Poisson2dTri { nx: 140, convection: 0.4 }, false, &mut v);
    push("t3dl", RandomSym { n: 20360, nnz_per_row: 6, sym: true }, true, &mut v);
    push("poisson3Da", Poisson3dHex { nx: 23, convection: 0.3 }, false, &mut v);
    push("k3plates", RandomSym { n: 11107, nnz_per_row: 17, sym: false }, false, &mut v);
    push("gridgena", Poisson2dQuad { nx: 220, convection: 0.0 }, true, &mut v);
    push("cbuckle", RandomSym { n: 13681, nnz_per_row: 12, sym: true }, true, &mut v);
    push("bcircuit", Banded { n: 68902, hbw: 2, sym: false }, false, &mut v);
    // --- the in-house FEM groups with DD variants (§4: angical, tracer,
    //     cube2m; "_o32"/"_n32" = overlapping / non-overlapping locals).
    push("angical_n32", NonoverlapLocal { nx: 40, nsub: 3, s: 1 }, true, &mut v);
    push("angical_o32", OverlapLocal { nx: 40, nsub: 3, s: 1 }, false, &mut v);
    push("tracer_n32", NonoverlapLocal { nx: 46, nsub: 3, s: 1 }, true, &mut v);
    push("tracer_o32", OverlapLocal { nx: 46, nsub: 3, s: 1 }, false, &mut v);
    push("crystk02", RandomSym { n: 13965, nnz_per_row: 17, sym: true }, true, &mut v);
    push("olafu", RandomSym { n: 16146, nnz_per_row: 15, sym: true }, true, &mut v);
    push("gyro", RandomSym { n: 17361, nnz_per_row: 14, sym: true }, true, &mut v);
    push("dawson5", RandomSym { n: 51537, nnz_per_row: 5, sym: true }, true, &mut v);
    push("ASIC_100ks", RandomSym { n: 99190, nnz_per_row: 2, sym: false }, false, &mut v);
    push("bcsstk35", RandomSym { n: 30237, nnz_per_row: 12, sym: true }, true, &mut v);
    // --- medium, near the cache boundary.
    push("dense_1000", Dense { n: 768 }, false, &mut v);
    push("sparsine", RandomSym { n: 50000, nnz_per_row: 7, sym: true }, true, &mut v);
    push("crystk03", RandomSym { n: 24696, nnz_per_row: 17, sym: true }, true, &mut v);
    push("ex11", RandomSym { n: 16614, nnz_per_row: 33, sym: false }, false, &mut v);
    push("2cubes_sphere", Poisson3dHex { nx: 46, convection: 0.0 }, true, &mut v);
    push("xenon1", RandomSym { n: 48600, nnz_per_row: 12, sym: false }, false, &mut v);
    push("raefsky3", RandomSym { n: 21200, nnz_per_row: 35, sym: false }, false, &mut v);
    push("cube2m_o32", OverlapLocal { nx: 57, nsub: 3, s: 1 }, false, &mut v);
    push("nasasrb", RandomSym { n: 54870, nnz_per_row: 12, sym: true }, true, &mut v);
    push("cube2m_n32", NonoverlapLocal { nx: 57, nsub: 3, s: 1 }, false, &mut v);
    push("venkat01", RandomSym { n: 62424, nnz_per_row: 13, sym: false }, false, &mut v);
    push("filter3D", RandomSym { n: 106437, nnz_per_row: 6, sym: true }, true, &mut v);
    push("appu", RandomSym { n: 14000, nnz_per_row: 66, sym: false }, false, &mut v);
    push("poisson3Db", Poisson3dHex { nx: 44, convection: 0.3 }, false, &mut v);
    push("thermomech_dK", RandomSym { n: 204316, nnz_per_row: 6, sym: false }, false, &mut v);
    push("Ga3As3H12", RandomSym { n: 61349, nnz_per_row: 24, sym: true }, true, &mut v);
    push("xenon2", RandomSym { n: 157464, nnz_per_row: 12, sym: false }, false, &mut v);
    push("tmt_sym", Banded { n: 320000, hbw: 1, sym: true }, true, &mut v);
    push("CO", RandomSym { n: 221119, nnz_per_row: 8, sym: true }, true, &mut v);
    push("tmt_unsym", Banded { n: 400000, hbw: 2, sym: false }, false, &mut v);
    // --- large, out-of-cache (scaled from the paper's giants).
    push("crankseg_1", RandomSym { n: 52804, nnz_per_row: 50, sym: true }, true, &mut v);
    push("SiO2", RandomSym { n: 155331, nnz_per_row: 18, sym: true }, true, &mut v);
    push("bmw3_2", RandomSym { n: 227362, nnz_per_row: 12, sym: true }, true, &mut v);
    push("af_0_k101", Poisson3dHex { nx: 63, convection: 0.0 }, true, &mut v);
    push("angical", Poisson3dHex { nx: 60, convection: 0.0 }, true, &mut v);
    push("F1", RandomSym { n: 343791, nnz_per_row: 19, sym: true }, true, &mut v);
    push("tracer", Poisson2dTri { nx: 700, convection: 0.0 }, true, &mut v);
    push("audikw_1", Elasticity2d { nx: 280 }, true, &mut v);
    push("cube2m", Poisson3dHex { nx: 70, convection: 0.4 }, false, &mut v);
    push("cage15", RandomSym { n: 515485, nnz_per_row: 9, sym: false }, false, &mut v);
    v
}

/// A curated subset that spans the ws spectrum quickly (default for the
/// figure harness and the benches; `--full` runs all 60).
pub fn quick_suite() -> Vec<DatasetEntry> {
    let pick = [
        "thermal", "piston", "torsion1", "minsurfo", "dixmaanl", "cage10",
        "angical_n32", "angical_o32", "dense_1000", "poisson3Da",
        "2cubes_sphere", "raefsky3", "venkat01", "appu", "tmt_sym",
        "crankseg_1", "SiO2", "cage15",
    ];
    full_suite().into_iter().filter(|e| pick.contains(&e.name)).collect()
}

/// A tiny subset for CI-speed smoke runs.
pub fn smoke_suite() -> Vec<DatasetEntry> {
    let pick = ["thermal", "torsion1", "dense_1000", "poisson3Da", "angical_o32"];
    full_suite().into_iter().filter(|e| pick.contains(&e.name)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_has_60_unique_entries() {
        let s = full_suite();
        assert_eq!(s.len(), 60);
        let mut names: Vec<&str> = s.iter().map(|e| e.name).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), 60, "duplicate names");
    }

    #[test]
    fn quick_suite_is_nonempty_subset() {
        let q = quick_suite();
        assert!(q.len() >= 12);
        assert!(q.len() < 60);
    }

    #[test]
    fn small_entries_build_as_csrc() {
        for e in smoke_suite() {
            let m = e.build_csrc();
            assert!(m.n > 0, "{}", e.name);
            if e.sym {
                assert!(m.numeric_symmetric, "{} should be numerically symmetric", e.name);
            }
        }
    }

    #[test]
    fn overlap_entry_is_rectangular() {
        let e = full_suite().into_iter().find(|e| e.name == "angical_o32").unwrap();
        let coo = e.build_coo();
        assert!(coo.ncols > coo.nrows, "{}x{}", coo.nrows, coo.ncols);
    }

    #[test]
    fn deterministic_rebuild() {
        let e = full_suite().into_iter().find(|e| e.name == "piston").unwrap();
        let a = e.build_coo();
        let b = e.build_coo();
        assert_eq!(a.vals, b.vals);
    }

    #[test]
    fn ws_spectrum_spans_cache_sizes() {
        // At least one entry well under 6MB and one well over 8MB.
        let mut under = false;
        let mut over = false;
        for e in quick_suite() {
            let m = e.build_csrc();
            let ws = m.working_set_bytes();
            under |= ws < 2 << 20;
            over |= ws > 16 << 20;
        }
        assert!(under && over, "suite does not span the cache boundary");
    }
}
