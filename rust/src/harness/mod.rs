//! Experiment harness: the 60-matrix dataset (Table 1 substitute) and one
//! regeneration routine per paper table/figure. The CLI (`csrc figures`)
//! and the criterion-style benches call into this module; results land in
//! `results/*.{md,csv}` and are summarized in EXPERIMENTS.md.

pub mod dataset;
pub mod figures;
pub mod report;

pub use dataset::{full_suite, quick_suite, smoke_suite, DatasetEntry, MatrixKind};
pub use report::Report;
