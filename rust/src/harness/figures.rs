//! Per-figure/table harnesses. Each regenerates one piece of the paper's
//! evaluation (the experiment index in DESIGN.md §4) over a dataset
//! suite, returning rows for the report writer.
//!
//! * Table 1 — suite statistics (n, nnz, nnz/n, ws),
//! * Fig. 4  — simulated % L2 / TLB misses, CSRC vs CSR (Wolfdale model),
//! * Fig. 5  — *measured* sequential Mflop/s, CSR vs CSRC (this host),
//! * Fig. 6  — colorful vs best local-buffers (simulated speedups),
//! * Fig. 7  — colorful speedups (Wolfdale 2t; Bloomfield 2t/4t),
//! * Fig. 8/9 — local-buffers speedups ×4 accumulation methods,
//! * Table 2 — avg max per-thread init+accumulate cycles by ws class.

use super::dataset::DatasetEntry;
use crate::coordinator::{ShardConfig, ShardedMatvecService};
use crate::faults;
use crate::graph::{greedy_coloring, ConflictGraph, Ordering as ColorOrdering};
use crate::metrics;
use crate::obs::{self, Phase};
use crate::parallel::{build_engine, AccumMethod, EngineKind, ParallelSpmv};
use crate::plan::{PlanBuilder, PlanCache};
use crate::simulator::{
    sim_colorful, sim_csr_sequential, sim_csrc_sequential, sim_local_buffers, MachineConfig,
    MachineSim,
};
use crate::sparse::SpmvKernel;
use crate::tuner::{self, TrialBudget};
use std::sync::Arc;

/// Products per measurement for Fig. 5: the paper uses 1000; we scale by
/// nnz so the full suite stays within the time budget while keeping ≥ 3.
pub fn products_for(nnz: usize) -> usize {
    (20_000_000 / nnz.max(1)).clamp(3, 1000)
}

pub struct FigureRow {
    pub cells: Vec<String>,
}

// ---------------------------------------------------------------- Table 1

pub fn table1(entries: &[DatasetEntry]) -> Vec<Vec<String>> {
    entries
        .iter()
        .map(|e| {
            let coo = e.build_coo();
            let (nnz, ws) = if coo.nrows == coo.ncols {
                let m = crate::sparse::Csrc::from_coo(&coo).expect(e.name);
                (m.nnz(), m.working_set_bytes())
            } else {
                let r = crate::sparse::CsrcRect::from_coo(&coo).expect(e.name);
                (r.nnz(), r.working_set_bytes())
            };
            vec![
                e.name.to_string(),
                if e.sym { "yes" } else { "no" }.into(),
                coo.nrows.to_string(),
                nnz.to_string(),
                (nnz / coo.nrows.max(1)).to_string(),
                format!("{}", ws / 1024),
            ]
        })
        .collect()
}

// ----------------------------------------------------------------- Fig. 4

pub fn fig4(entries: &[DatasetEntry]) -> Vec<Vec<String>> {
    entries
        .iter()
        .map(|e| {
            let m = e.build_csrc();
            let csr = m.to_csr();
            // Warm measurement: one cold product to populate the caches,
            // reset counters, then measure the steady-state product (the
            // paper's numbers come from 1000 back-to-back products).
            let mut sim_c = MachineSim::new(MachineConfig::wolfdale());
            sim_csrc_sequential(&mut sim_c, &m);
            sim_c.reset_counters();
            let rc = sim_csrc_sequential(&mut sim_c, &m);
            let mut sim_r = MachineSim::new(MachineConfig::wolfdale());
            sim_csr_sequential(&mut sim_r, &csr);
            sim_r.reset_counters();
            let rr = sim_csr_sequential(&mut sim_r, &csr);
            vec![
                e.name.to_string(),
                format!("{:.2}", rc.misses.outer_miss_pct()),
                format!("{:.2}", rr.misses.outer_miss_pct()),
                format!("{:.3}", rc.misses.tlb_miss_pct()),
                format!("{:.3}", rr.misses.tlb_miss_pct()),
            ]
        })
        .collect()
}

// ----------------------------------------------------------------- Fig. 5

pub fn fig5(entries: &[DatasetEntry]) -> Vec<Vec<String>> {
    entries
        .iter()
        .map(|e| {
            let m = e.build_csrc();
            let csr = m.to_csr();
            let n = m.n;
            let x: Vec<f64> = (0..n).map(|i| (i as f64 * 0.001).sin()).collect();
            let mut y = vec![0.0; n];
            let products = products_for(m.nnz());
            // CSRC (symmetric kernel when applicable, as the paper does).
            let csrc_s = if m.numeric_symmetric {
                metrics::median_of_runs(3, products, || {
                    y.fill(0.0);
                    m.spmv_sym(&x, &mut y);
                })
            } else {
                metrics::median_of_runs(3, products, || m.spmv_into_zeroed(&x, &mut y))
            };
            let csr_s = metrics::median_of_runs(3, products, || csr.spmv(&x, &mut y));
            vec![
                e.name.to_string(),
                format!("{:.1}", metrics::mflops(m.flops(), csrc_s)),
                format!("{:.1}", metrics::mflops(csr.flops(), csr_s)),
                format!("{:.3}", csr_s / csrc_s),
            ]
        })
        .collect()
}

// ------------------------------------------------- speedup helpers (sim)

/// Warm sequential baseline: one cold product to populate the caches,
/// then measure the steady-state product (the paper times 1000 warm
/// products; a cold product is dominated by compulsory misses that no
/// strategy can parallelize).
pub fn warm_seq_cycles(m: &crate::sparse::Csrc, cfg: &MachineConfig) -> f64 {
    let mut sim = MachineSim::new(cfg.clone());
    sim_csrc_sequential(&mut sim, m);
    sim.reset_counters();
    sim.reset_cycles();
    sim_csrc_sequential(&mut sim, m).cycles
}

fn sim_speedup_lb(m: &crate::sparse::Csrc, cfg: &MachineConfig, p: usize, meth: AccumMethod) -> f64 {
    let base = warm_seq_cycles(m, cfg);
    let mut par = MachineSim::new(cfg.clone());
    sim_local_buffers(&mut par, m, p, meth);
    par.reset_counters();
    par.reset_cycles();
    base / sim_local_buffers(&mut par, m, p, meth).cycles
}

fn sim_speedup_colorful(m: &crate::sparse::Csrc, cfg: &MachineConfig, p: usize) -> f64 {
    let g = ConflictGraph::build(m);
    let colors = greedy_coloring(&g, ColorOrdering::Natural);
    let base = warm_seq_cycles(m, cfg);
    let mut par = MachineSim::new(cfg.clone());
    sim_colorful(&mut par, m, p, &colors);
    par.reset_counters();
    par.reset_cycles();
    base / sim_colorful(&mut par, m, p, &colors).cycles
}

// ----------------------------------------------------------------- Fig. 6

pub fn fig6(entries: &[DatasetEntry]) -> Vec<Vec<String>> {
    let wolf = MachineConfig::wolfdale();
    let bloom = MachineConfig::bloomfield();
    entries
        .iter()
        .map(|e| {
            let m = e.build_csrc();
            let best_lb_w = AccumMethod::all()
                .iter()
                .map(|&meth| sim_speedup_lb(&m, &wolf, 2, meth))
                .fold(0.0, f64::max);
            let col_w = sim_speedup_colorful(&m, &wolf, 2);
            let best_lb_b = AccumMethod::all()
                .iter()
                .map(|&meth| sim_speedup_lb(&m, &bloom, 4, meth))
                .fold(0.0, f64::max);
            let col_b = sim_speedup_colorful(&m, &bloom, 4);
            vec![
                e.name.to_string(),
                format!("{col_w:.2}"),
                format!("{best_lb_w:.2}"),
                format!("{col_b:.2}"),
                format!("{best_lb_b:.2}"),
                (if col_w > best_lb_w { "colorful" } else { "local-buffers" }).into(),
            ]
        })
        .collect()
}

// ----------------------------------------------------------------- Fig. 7

pub fn fig7(entries: &[DatasetEntry]) -> Vec<Vec<String>> {
    let wolf = MachineConfig::wolfdale();
    let bloom = MachineConfig::bloomfield();
    entries
        .iter()
        .map(|e| {
            let m = e.build_csrc();
            let g = ConflictGraph::build(&m);
            let k = greedy_coloring(&g, ColorOrdering::Natural).num_colors();
            vec![
                e.name.to_string(),
                k.to_string(),
                format!("{:.2}", sim_speedup_colorful(&m, &wolf, 2)),
                format!("{:.2}", sim_speedup_colorful(&m, &bloom, 2)),
                format!("{:.2}", sim_speedup_colorful(&m, &bloom, 4)),
            ]
        })
        .collect()
}

// ------------------------------------------------------------- Figs. 8/9

/// machine = wolfdale (Fig. 8, 2 threads) or bloomfield (Fig. 9, 2 and 4).
pub fn fig89(entries: &[DatasetEntry], cfg: &MachineConfig) -> Vec<Vec<String>> {
    let threads: &[usize] = if cfg.cores >= 4 { &[2, 4] } else { &[2] };
    entries
        .iter()
        .map(|e| {
            let m = e.build_csrc();
            let mut cells = vec![e.name.to_string()];
            for &p in threads {
                for meth in AccumMethod::all() {
                    cells.push(format!("{:.2}", sim_speedup_lb(&m, cfg, p, meth)));
                }
            }
            cells
        })
        .collect()
}

pub fn fig89_headers(cfg: &MachineConfig) -> Vec<String> {
    let threads: &[usize] = if cfg.cores >= 4 { &[2, 4] } else { &[2] };
    let mut h = vec!["matrix".to_string()];
    for &p in threads {
        for meth in AccumMethod::all() {
            h.push(format!("{}({}t)", meth.label(), p));
        }
    }
    h
}

// ---------------------------------------------------------------- Table 2

/// Average (over matrices in each ws class) of the simulated max-thread
/// init+accumulation cycles, normalized to milliseconds at the machine's
/// nominal clock, mirroring Table 2's layout.
pub fn table2(entries: &[DatasetEntry]) -> Vec<Vec<String>> {
    let configs = [
        (MachineConfig::wolfdale(), 2.66e9, vec![2usize]),
        (MachineConfig::bloomfield(), 2.93e9, vec![2, 4]),
    ];
    let mut rows = Vec::new();
    for meth in AccumMethod::all() {
        let mut cells = vec![meth.label().to_string()];
        for (cfg, hz, threads) in &configs {
            for &p in threads {
                for in_cache in [true, false] {
                    let mut vals = Vec::new();
                    for e in entries {
                        let m = e.build_csrc();
                        let fits = m.working_set_bytes() < cfg.last_level_bytes();
                        if fits != in_cache {
                            continue;
                        }
                        // Overhead = warm parallel total minus the ideal
                        // compute share (warm sequential / p): what the
                        // init + accumulate steps and imbalance add.
                        let mut sim = MachineSim::new(cfg.clone());
                        sim_local_buffers(&mut sim, &m, p, meth);
                        sim.reset_counters();
                        sim.reset_cycles();
                        let total = sim_local_buffers(&mut sim, &m, p, meth).cycles;
                        let seq = warm_seq_cycles(&m, cfg);
                        let overhead = (total - seq / p as f64).max(0.0);
                        vals.push(overhead / hz * 1e3); // ms
                    }
                    let avg = if vals.is_empty() {
                        f64::NAN
                    } else {
                        vals.iter().sum::<f64>() / vals.len() as f64
                    };
                    cells.push(if avg.is_nan() {
                        "-".into()
                    } else {
                        format!("{avg:.4}")
                    });
                }
            }
        }
        rows.push(cells);
    }
    rows
}

// ------------------------------------------------------- Plan analysis

/// Beyond the paper: the shared-plan architecture made the §3 analysis a
/// first-class, reusable artifact — this table shows its cost and shape
/// per matrix (full plan at `p` threads), and cross-checks one product
/// per engine kind through the `build_engine(kind, kernel, plan)` path.
pub fn plan_overview(entries: &[DatasetEntry], p: usize) -> Vec<Vec<String>> {
    entries
        .iter()
        .map(|e| {
            let kernel: Arc<dyn SpmvKernel> = Arc::new(e.build_csrc());
            let n = kernel.dim();
            let plan = Arc::new(PlanBuilder::all(p).build(kernel.as_ref()));
            let eff_span: usize =
                plan.eff.as_ref().unwrap().iter().map(|r| r.end - r.start).sum();
            let x: Vec<f64> = (0..n).map(|i| (i as f64 * 0.001).sin()).collect();
            let mut want = vec![0.0; n];
            kernel.sweep_full(&x, &mut want);
            let mut ok = true;
            for kind in EngineKind::all() {
                let mut engine = build_engine(kind, kernel.clone(), plan.clone());
                let mut y = vec![f64::NAN; n];
                engine.spmv(&x, &mut y);
                ok &= crate::util::propcheck::assert_close(&y, &want, 1e-9, 1e-9).is_ok();
            }
            vec![
                e.name.to_string(),
                n.to_string(),
                plan.colors.as_ref().unwrap().num_colors().to_string(),
                plan.ints.as_ref().unwrap().len().to_string(),
                format!("{:.2}", eff_span as f64 / n as f64),
                format!("{:.3}", plan.stats.total_s * 1e3),
                if ok { "yes" } else { "NO" }.into(),
            ]
        })
        .collect()
}

pub fn plan_overview_headers() -> Vec<String> {
    ["matrix", "n", "colors", "intervals", "eff-span/n", "plan build (ms)", "engines agree"]
        .iter()
        .map(|s| s.to_string())
        .collect()
}

// ------------------------------------------------------------ Tune table

/// Beyond the paper: the §4 observation that no strategy wins everywhere,
/// made operational — the autotuner trials every candidate per matrix
/// and this table compares the measured winner against the fixed
/// `local-buffers/effective` default the router would otherwise pick.
pub fn tune_table(entries: &[DatasetEntry], p: usize, budget: &TrialBudget) -> Vec<Vec<String>> {
    entries
        .iter()
        .map(|e| {
            let m = Arc::new(e.build_csrc());
            let flops = m.flops();
            let kernel: Arc<dyn SpmvKernel> = m.clone();
            let plan = Arc::new(PlanBuilder::all(p).build(kernel.as_ref()));
            let d = tuner::tune(&kernel, &plan, budget);
            let seconds_of = |k: EngineKind| {
                d.trials.iter().find(|t| t.kind == k).map(|t| t.seconds_per_product)
            };
            let win_s = seconds_of(d.kind);
            let eff_s = seconds_of(EngineKind::LocalBuffers(AccumMethod::Effective));
            let mf = |s: Option<f64>| {
                s.map(|s| format!("{:.1}", metrics::mflops(flops, s)))
                    .unwrap_or_else(|| "-".into())
            };
            let ratio = match (win_s, eff_s) {
                (Some(w), Some(f)) if w > 0.0 => format!("{:.2}", f / w),
                _ => "-".into(),
            };
            vec![
                e.name.to_string(),
                d.features.n.to_string(),
                d.features.colors.to_string(),
                d.kind.label(),
                mf(win_s),
                mf(eff_s),
                ratio,
            ]
        })
        .collect()
}

pub fn tune_headers() -> Vec<String> {
    ["matrix", "n", "colors", "winner", "winner Mflop/s", "effective Mflop/s", "eff/winner time"]
        .iter()
        .map(|s| s.to_string())
        .collect()
}

// ----------------------------------------------------------- Sweep table

/// Beyond the paper's fixed-p tables: its §4 scalability observation —
/// the best thread count varies per matrix, several peak *below* the
/// core count — as a rate-vs-p surface (the Fig. 5/6 shape with p on the
/// x axis). One column per ladder rung (the best engine's Mflop/s at
/// that p), then the swept (engine × p) winner.
pub fn sweep_table(
    entries: &[DatasetEntry],
    max_threads: usize,
    budget: &TrialBudget,
) -> Vec<Vec<String>> {
    let ladder = tuner::thread_ladder(max_threads);
    entries
        .iter()
        .map(|e| {
            let m = Arc::new(e.build_csrc());
            let kernel: Arc<dyn SpmvKernel> = m.clone();
            let plans = PlanCache::new();
            let mut plan_for = tuner::cached_plan_provider(&plans, e.name, &kernel);
            let d = tuner::sweep(&kernel, &ladder, budget, &mut plan_for);
            let mut cells = vec![e.name.to_string()];
            for p in &ladder {
                let best = d
                    .sweep
                    .iter()
                    .find(|pt| pt.nthreads == *p)
                    .and_then(|pt| pt.best())
                    .map(|t| format!("{:.1}", t.mflops))
                    .unwrap_or_else(|| "-".into());
                cells.push(best);
            }
            cells.push(format!("{}@{}t", d.kind.label(), d.nthreads));
            cells.push(format!("{:.1}", d.mflops));
            cells
        })
        .collect()
}

pub fn sweep_headers(max_threads: usize) -> Vec<String> {
    let mut h = vec!["matrix".to_string()];
    for p in tuner::thread_ladder(max_threads) {
        h.push(format!("best Mflop/s @{p}t"));
    }
    h.push("winner".into());
    h.push("winner Mflop/s".into());
    h
}

// --------------------------------------------------------- Reorder table

/// Beyond the paper: its §4.2 observation that performance follows the
/// band structure, made actionable — RCM reordering + windowed local
/// buffers per suite matrix. Columns: half-bandwidth before/after, the
/// parallel working set (sequential ws + windowed buffers) before/after,
/// measured windowed `local-buffers/effective` Mflop/s before/after
/// (the reordered run pays its per-product permute/un-permute), and a
/// correctness check of the reordered path against the plain product.
pub fn reorder_table(entries: &[DatasetEntry], p: usize) -> Vec<Vec<String>> {
    entries
        .iter()
        .map(|e| {
            let m = Arc::new(e.build_csrc());
            let kernel: Arc<dyn SpmvKernel> = m.clone();
            let plan =
                Arc::new(PlanBuilder::new(p).ranges().reorder().build(kernel.as_ref()));
            let r = plan.reorder.clone().expect("reorder piece requested");
            let permuted = Arc::new(m.permuted(&r.perm));
            let pkernel: Arc<dyn SpmvKernel> = permuted.clone();
            let pplan = Arc::new(PlanBuilder::new(p).ranges().build(pkernel.as_ref()));
            let n = m.n;
            let x: Vec<f64> = (0..n).map(|i| (i as f64 * 0.001).sin()).collect();
            let mut y_plain = vec![0.0; n];
            let mut y_reord = vec![0.0; n];
            let products = products_for(m.nnz()).min(200);
            let kind = EngineKind::LocalBuffers(AccumMethod::Effective);
            let mut plain = build_engine(kind, kernel.clone(), plan.clone());
            let mut reord = crate::reorder::ReorderedEngine::new(
                build_engine(kind, pkernel.clone(), pplan.clone()),
                r.perm.clone(),
            );
            let t_plain =
                metrics::median_of_runs(2, products, || plain.spmv(&x, &mut y_plain));
            let t_reord =
                metrics::median_of_runs(2, products, || reord.spmv(&x, &mut y_reord));
            let ok = y_plain
                .iter()
                .zip(&y_reord)
                .all(|(a, b)| (a - b).abs() <= 1e-9 * (1.0 + a.abs()));
            vec![
                e.name.to_string(),
                r.hbw_before.to_string(),
                r.hbw_after.to_string(),
                format!("{}", m.working_set_bytes_parallel(&plan) / 1024),
                format!("{}", permuted.working_set_bytes_parallel(&pplan) / 1024),
                format!("{:.1}", metrics::mflops(m.flops(), t_plain)),
                format!("{:.1}", metrics::mflops(m.flops(), t_reord)),
                format!("{:.2}", t_plain / t_reord),
                if ok { "yes" } else { "NO" }.into(),
            ]
        })
        .collect()
}

pub fn reorder_headers() -> Vec<String> {
    [
        "matrix",
        "hbw",
        "hbw rcm",
        "ws par (KB)",
        "ws par rcm (KB)",
        "Mflop/s",
        "Mflop/s rcm",
        "speedup",
        "correct",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect()
}

// ------------------------------------------------------------- SpMM table

/// Block widths the SpMM table (and the tuner's block axis) measures.
pub const SPMM_WIDTHS: [usize; 3] = [2, 4, 8];

/// Beyond the paper: the multi-vector extension (DESIGN.md §11). Per
/// matrix, k serial `spmv` calls vs one blocked `spmv_multi` panel at
/// the same engine and thread count — the amortization a blocked sweep
/// buys (one pass over A serves all k vectors). Columns: the serial
/// per-vector Mflop/s baseline, then blocked per-vector Mflop/s and
/// speedup at each width, and a correctness check of every blocked
/// column against its independent product.
pub fn spmm_table(entries: &[DatasetEntry], p: usize) -> Vec<Vec<String>> {
    entries
        .iter()
        .map(|e| {
            let m = Arc::new(e.build_csrc());
            let kernel: Arc<dyn SpmvKernel> = m.clone();
            let plan = Arc::new(PlanBuilder::all(p).build(kernel.as_ref()));
            let kind = EngineKind::LocalBuffers(AccumMethod::Effective);
            let mut engine = build_engine(kind, kernel.clone(), plan);
            let n = m.n;
            let kmax = *SPMM_WIDTHS.last().unwrap();
            let xs: Vec<Vec<f64>> = (0..kmax)
                .map(|c| (0..n).map(|i| ((i + 7 * c) as f64 * 0.001).sin()).collect())
                .collect();
            let products = products_for(m.nnz()).min(100);
            let mut y = vec![0.0; n];
            let serial_s = metrics::median_of_runs(2, products, || engine.spmv(&xs[0], &mut y));
            let mut cells =
                vec![e.name.to_string(), format!("{:.1}", metrics::mflops(m.flops(), serial_s))];
            let mut ok = true;
            for &k in &SPMM_WIDTHS {
                let mut xp = vec![0.0; n * k];
                for (c, col) in xs.iter().take(k).enumerate() {
                    for (i, &v) in col.iter().enumerate() {
                        xp[i * k + c] = v;
                    }
                }
                let mut yp = vec![0.0; n * k];
                let panel_s =
                    metrics::median_of_runs(2, products, || engine.spmv_multi(&xp, &mut yp, k));
                let per_vec = panel_s / k as f64;
                cells.push(format!("{:.1}", metrics::mflops(m.flops(), per_vec)));
                cells.push(format!("{:.2}", serial_s / per_vec));
                for (c, col) in xs.iter().take(k).enumerate() {
                    let mut want = vec![0.0; n];
                    m.spmv_into_zeroed(col, &mut want);
                    ok &= (0..n)
                        .all(|i| (yp[i * k + c] - want[i]).abs() <= 1e-9 * (1.0 + want[i].abs()));
                }
            }
            cells.push(if ok { "yes" } else { "NO" }.into());
            cells
        })
        .collect()
}

pub fn spmm_headers() -> Vec<String> {
    let mut h = vec!["matrix".to_string(), "serial Mflop/s".to_string()];
    for k in SPMM_WIDTHS {
        h.push(format!("k={k} Mflop/s/vec"));
        h.push(format!("k={k} speedup"));
    }
    h.push("correct".into());
    h
}

// ------------------------------------------------------------ Model table

/// Beyond the paper: the learned cross-matrix cost model
/// ([`crate::tuner::model`]) judged per matrix — the measured winner
/// next to the model's and the heuristic's cold-start picks, with
/// *regret* = the % of measured rate each zero-trial pick leaves on the
/// table. With no pre-trained `model` supplied, each row trains
/// leave-one-out on the rest of the suite's measured decisions, so
/// every prediction is for a matrix the model never saw — the
/// cross-matrix claim, tested directly.
pub fn model_table(
    entries: &[DatasetEntry],
    p: usize,
    budget: &TrialBudget,
    model: Option<&tuner::CostModel>,
) -> Vec<Vec<String>> {
    let measured: Vec<(&str, tuner::Decision)> = entries
        .iter()
        .map(|e| {
            let m = Arc::new(e.build_csrc());
            let kernel: Arc<dyn SpmvKernel> = m.clone();
            let plan = Arc::new(PlanBuilder::all(p).build(kernel.as_ref()));
            (e.name, tuner::tune(&kernel, &plan, budget))
        })
        .collect();
    measured
        .iter()
        .enumerate()
        .map(|(i, (name, d))| {
            // Leave-one-out fallback: train on every *other* decision.
            let trained;
            let predictor = match model {
                Some(m) => Some(m),
                None => {
                    let held: Vec<tuner::Decision> = measured
                        .iter()
                        .enumerate()
                        .filter(|(j, _)| *j != i)
                        .map(|(_, (_, d))| d.clone())
                        .collect();
                    trained = tuner::CostModel::train(&tuner::model::rows_from_decisions(&held));
                    trained.as_ref()
                }
            };
            let heur_pick = tuner::cost_model(&d.features);
            // A declining model (e.g. one with no plain classes) shows
            // as "-", never as the heuristic's pick in disguise — the
            // whole point of the table is the model-vs-heuristic gap.
            let model_pick = predictor
                .and_then(|m| m.predict(&d.features, crate::reorder::ReorderPolicy::Never))
                .map(|pr| pr.kind);
            let best = d.mflops;
            let rate_of = |k: EngineKind| {
                d.trials.iter().find(|t| t.kind == k && !t.reordered).map(|t| t.mflops)
            };
            let regret = |k: EngineKind| match rate_of(k) {
                Some(r) if best > 0.0 => format!("{:.1}", (1.0 - r / best).max(0.0) * 100.0),
                _ => "-".into(),
            };
            vec![
                name.to_string(),
                d.kind.label(),
                model_pick.map_or_else(|| "-".into(), |k| k.label()),
                model_pick.map_or_else(|| "-".into(), &regret),
                heur_pick.label(),
                regret(heur_pick),
            ]
        })
        .collect()
}

pub fn model_headers() -> Vec<String> {
    [
        "matrix",
        "measured winner",
        "model pick",
        "model regret %",
        "heuristic pick",
        "heuristic regret %",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect()
}

pub fn table2_headers() -> Vec<String> {
    let mut h = vec!["method".to_string()];
    for (machine, threads) in [("wolfdale", vec![2]), ("bloomfield", vec![2, 4])] {
        for p in threads {
            for class in ["ws<cache", "ws>cache"] {
                h.push(format!("{machine}/{p}t/{class} (ms)"));
            }
        }
    }
    h
}

// -------------------------------------------------------------- Obs table

/// Phases an in-process product run exercises (DESIGN.md §12): plan
/// construction once, then zero/sweep/accumulate per product.
const OBS_PHASES: [Phase; 4] = [Phase::PlanBuild, Phase::Zero, Phase::Sweep, Phase::Accumulate];

/// Beyond the paper: the instrumentation cross-check. Per matrix, reset
/// the process-wide phase timers, build a plan and run a handful of
/// local-buffers products, then report where the instrumented time went
/// — absolute ms and share per phase, plus the grand total and span
/// count. The caller owns the global metrics switch
/// ([`obs::set_metrics_enabled`]); with instrumentation off every cell
/// legitimately reads zero, which the shape tests rely on.
pub fn obs_table(entries: &[DatasetEntry], p: usize) -> Vec<Vec<String>> {
    entries
        .iter()
        .map(|e| {
            obs::reset_phases();
            let m = Arc::new(e.build_csrc());
            let kernel: Arc<dyn SpmvKernel> = m.clone();
            let plan = Arc::new(PlanBuilder::all(p).build(kernel.as_ref()));
            let kind = EngineKind::LocalBuffers(AccumMethod::Effective);
            let mut engine = build_engine(kind, kernel.clone(), plan);
            let n = m.n;
            let x: Vec<f64> = (0..n).map(|i| (i as f64 * 0.001).sin()).collect();
            let mut y = vec![0.0; n];
            for _ in 0..products_for(m.nnz()).min(20) {
                engine.spmv(&x, &mut y);
            }
            let totals = obs::phase_totals();
            let grand_ns: u64 = totals.iter().map(|t| t.ns).sum();
            let spans: u64 = totals.iter().map(|t| t.calls).sum();
            let mut cells = vec![e.name.to_string()];
            for phase in OBS_PHASES {
                let t = totals.iter().find(|t| t.phase == phase).expect("phase in totals");
                cells.push(format!("{:.3}", t.ns as f64 / 1e6));
                cells.push(format!("{:.1}", t.ns as f64 * 100.0 / grand_ns.max(1) as f64));
            }
            cells.push(format!("{:.3}", grand_ns as f64 / 1e6));
            cells.push(spans.to_string());
            cells
        })
        .collect()
}

pub fn obs_headers() -> Vec<String> {
    let mut h = vec!["matrix".to_string()];
    for phase in OBS_PHASES {
        h.push(format!("{} ms", phase.label()));
        h.push(format!("{} %", phase.label()));
    }
    h.push("total ms".into());
    h.push("spans".into());
    h
}

// ------------------------------------------------------------ Shard table

/// Shard counts the sharded-serving table sweeps (matching the shard
/// equivalence tests: 1 = the unsharded baseline, 7 deliberately odd).
pub const SHARD_COUNTS: [usize; 4] = [1, 2, 4, 7];

/// Beyond the paper: sharded serving (DESIGN.md §13). Per matrix and
/// shard count, the served single-vector rate through the scatter/gather
/// front (includes routing, queueing, and coupling — an end-to-end
/// serving rate, not a kernel rate) and the halo volume the overlap
/// decomposition pays at that shard count, plus a correctness check of
/// every served product against the sequential kernel.
pub fn shard_table(entries: &[DatasetEntry]) -> Vec<Vec<String>> {
    entries
        .iter()
        .map(|e| {
            let m = Arc::new(e.build_csrc());
            let n = m.n;
            let x: Vec<f64> = (0..n).map(|i| (i as f64 * 0.001).sin()).collect();
            let mut want = vec![0.0; n];
            m.spmv_into_zeroed(&x, &mut want);
            let mut cells = vec![e.name.to_string()];
            let mut ok = true;
            let products = products_for(m.nnz()).min(10);
            for s in SHARD_COUNTS {
                let svc = ShardedMatvecService::start(ShardConfig {
                    nshards: s,
                    ..ShardConfig::default()
                });
                svc.register(e.name, m.clone());
                let mut y = Vec::new();
                let secs = metrics::median_of_runs(2, products, || {
                    y = svc.spmv(e.name, &x).expect("sharded product");
                });
                ok &= (0..n).all(|i| (y[i] - want[i]).abs() <= 1e-9 * (1.0 + want[i].abs()));
                cells.push(format!("{:.1}", metrics::mflops(m.flops(), secs)));
                cells.push(format!("{:.0}", svc.halo_doubles()));
                svc.shutdown();
            }
            cells.push(if ok { "yes" } else { "NO" }.into());
            cells
        })
        .collect()
}

pub fn shard_headers() -> Vec<String> {
    let mut h = vec!["matrix".to_string()];
    for s in SHARD_COUNTS {
        h.push(format!("s={s} Mflop/s"));
        h.push(format!("s={s} halo"));
    }
    h.push("correct".into());
    h
}

// ----------------------------------------------------------- Faults table

/// Default chaos spec for the faults table (`csrc figures faults`):
/// worker panics, brief shard stalls, and front-side queue-full
/// injections, on the seeded deterministic schedule.
pub const FAULTS_SPEC: &str = "worker-panic:0.2,shard-stall:0.3,stall-ms:2,queue-full:0.15,seed:42";

/// Products served per matrix by [`faults_table`].
pub const FAULTS_PRODUCTS: usize = 30;

/// Beyond the paper: fault-tolerant serving (DESIGN.md §14). Per matrix,
/// a 2-shard front serves [`FAULTS_PRODUCTS`] products with `spec`'s
/// faults armed; the row reports the front's accounting (completed /
/// rejected / degraded), the supervision counters (panics caught, worker
/// restarts), the lost-request count (must be 0: every product resolves
/// to completed or a typed rejection), and whether every completed
/// answer matched the sequential kernel.
///
/// Arms and clears the *process-wide* chaos switch — callers that share
/// the process with concurrent serving (tests) must serialize around it.
pub fn faults_table(entries: &[DatasetEntry], spec: &str) -> Vec<Vec<String>> {
    entries
        .iter()
        .map(|e| {
            let m = Arc::new(e.build_csrc());
            let n = m.n;
            let x: Vec<f64> = (0..n).map(|i| (i as f64 * 0.002).cos()).collect();
            let mut want = vec![0.0; n];
            m.spmv_into_zeroed(&x, &mut want);
            let svc = ShardedMatvecService::start(ShardConfig {
                nshards: 2,
                breaker_threshold: 2,
                breaker_cooldown: std::time::Duration::from_millis(50),
                deadline: std::time::Duration::from_millis(500),
                ..ShardConfig::default()
            });
            svc.register(e.name, m.clone());
            faults::configure(spec).expect("faults table spec");
            faults::set_chaos_enabled(true);
            let mut ok = true;
            for _ in 0..FAULTS_PRODUCTS {
                if let Ok(y) = svc.spmv(e.name, &x) {
                    ok &= (0..n).all(|i| (y[i] - want[i]).abs() <= 1e-9 * (1.0 + want[i].abs()));
                }
            }
            faults::reset();
            let f = svc.front_stats();
            let stats = svc.stats();
            let panics: u64 = stats.iter().map(|s| s.service.panics_caught).sum();
            let restarts: u64 = stats.iter().map(|s| s.service.worker_restarts).sum();
            let degraded: u64 = stats.iter().map(|s| s.degraded).sum();
            let lost = f.products - (f.completed + f.rejected);
            let row = vec![
                e.name.to_string(),
                f.products.to_string(),
                f.completed.to_string(),
                f.rejected.to_string(),
                degraded.to_string(),
                panics.to_string(),
                restarts.to_string(),
                lost.to_string(),
                if ok { "yes" } else { "NO" }.into(),
            ];
            svc.shutdown();
            row
        })
        .collect()
}

pub fn faults_headers() -> Vec<String> {
    let cols = [
        "matrix", "products", "completed", "rejected", "degraded", "panics", "restarts", "lost",
        "correct",
    ];
    cols.iter().map(|s| s.to_string()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harness::dataset::smoke_suite;

    #[test]
    fn products_scale_is_bounded() {
        assert_eq!(products_for(10), 1000);
        assert_eq!(products_for(20_000_000_000), 3);
    }

    #[test]
    fn table1_rows_have_six_columns() {
        let rows = table1(&smoke_suite());
        assert_eq!(rows.len(), smoke_suite().len());
        assert!(rows.iter().all(|r| r.len() == 6));
    }

    #[test]
    fn fig4_csrc_miss_pct_not_worse() {
        // The paper's Fig. 4 finding: CSRC does NOT increase L2 misses
        // (usually the converse). Check the average over a small subset.
        let rows = fig4(&smoke_suite()[..2]);
        let avg = |col: usize| {
            rows.iter().map(|r| r[col].parse::<f64>().unwrap()).sum::<f64>() / rows.len() as f64
        };
        let (csrc_l2, csr_l2) = (avg(1), avg(2));
        assert!(
            csrc_l2 <= csr_l2 * 1.15,
            "CSRC L2 miss% {csrc_l2:.2} should not exceed CSR {csr_l2:.2}"
        );
    }

    #[test]
    fn fig89_header_matches_row_width() {
        let cfg = MachineConfig::bloomfield();
        let rows = fig89(&smoke_suite()[..2], &cfg);
        assert_eq!(rows[0].len(), fig89_headers(&cfg).len());
    }

    #[test]
    fn table2_shape() {
        let rows = table2(&smoke_suite()[..1]);
        assert_eq!(rows.len(), 4);
        assert_eq!(rows[0].len(), table2_headers().len());
    }

    #[test]
    fn shard_table_matches_headers_and_serves_correctly() {
        let rows = shard_table(&smoke_suite()[..1]);
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].len(), shard_headers().len());
        assert_eq!(rows[0].last().unwrap(), "yes", "{rows:?}");
        // shards=1 pays no halo; every sharded count pays some.
        assert_eq!(rows[0][2], "0");
        assert_ne!(rows[0][4], "0");
    }

    #[test]
    fn plan_overview_checks_engines() {
        let rows = plan_overview(&smoke_suite()[..2], 3);
        assert_eq!(rows[0].len(), plan_overview_headers().len());
        for r in &rows {
            assert_eq!(r.last().unwrap(), "yes", "{r:?}");
        }
    }

    #[test]
    fn sweep_table_reports_each_ladder_rung() {
        let rows = sweep_table(&smoke_suite()[..2], 2, &TrialBudget::smoke());
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].len(), sweep_headers(2).len());
        for r in &rows {
            // Ladder [1, 2]: both rungs measured.
            assert_ne!(r[1], "-", "{r:?}");
            assert_ne!(r[2], "-", "{r:?}");
            let winner = &r[r.len() - 2];
            assert!(
                winner.ends_with("@1t") || winner.ends_with("@2t"),
                "winner must name its thread count: {winner}"
            );
            assert_ne!(r.last().unwrap().as_str(), "-", "{r:?}");
        }
    }

    #[test]
    fn model_table_reports_regret_per_matrix() {
        let rows = model_table(&smoke_suite()[..3], 2, &TrialBudget::smoke(), None);
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[0].len(), model_headers().len());
        for r in &rows {
            // Measured winner and the heuristic pick are concrete
            // engine labels; the model pick is one too unless the model
            // declined ("-", never the heuristic in disguise).
            for col in [1usize, 4] {
                assert!(EngineKind::parse(&r[col]).is_some(), "{r:?}");
                assert_ne!(r[col], "auto", "{r:?}");
            }
            if r[2] != "-" {
                assert!(EngineKind::parse(&r[2]).is_some(), "{r:?}");
            }
            // Regret parses and is non-negative whenever the pick was
            // among the measured trials.
            for col in [3usize, 5] {
                if r[col] != "-" {
                    assert!(r[col].parse::<f64>().unwrap() >= 0.0, "{r:?}");
                }
            }
        }
    }

    #[test]
    fn spmm_table_blocked_panels_match_serial_products() {
        let rows = spmm_table(&smoke_suite()[..2], 2);
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].len(), spmm_headers().len());
        for r in &rows {
            assert_eq!(r.last().unwrap(), "yes", "{r:?}");
            // Serial baseline and every blocked width produced a rate.
            for cell in &r[1..r.len() - 1] {
                assert!(cell.parse::<f64>().unwrap() > 0.0, "{r:?}");
            }
        }
    }

    #[test]
    fn obs_table_header_matches_row_width() {
        // Deliberately run WITHOUT toggling the global metrics switch:
        // other tests share the process, and the table's shape must not
        // depend on instrumentation being live (cells just read 0).
        let rows = obs_table(&smoke_suite()[..2], 2);
        assert_eq!(rows.len(), 2);
        let headers = obs_headers();
        for r in &rows {
            assert_eq!(r.len(), headers.len(), "{r:?}");
            // Every numeric cell parses; shares are percentages.
            for cell in &r[1..r.len() - 1] {
                assert!(cell.parse::<f64>().unwrap() >= 0.0, "{r:?}");
            }
            let _spans: u64 = r.last().unwrap().parse().unwrap();
        }
    }

    #[test]
    fn tune_table_picks_concrete_winners() {
        let rows = tune_table(&smoke_suite()[..2], 2, &TrialBudget::smoke());
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].len(), tune_headers().len());
        for r in &rows {
            let kind = EngineKind::parse(&r[3]).expect("winner label parses");
            assert_ne!(kind, EngineKind::Auto, "{r:?}");
            assert_ne!(r[4], "-", "measured budget must produce a rate: {r:?}");
        }
    }
}
