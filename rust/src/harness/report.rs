//! Report writer: every figure/table harness emits a markdown table (for
//! EXPERIMENTS.md) and a CSV (for plotting) under `results/`.

use std::io::Write;
use std::path::{Path, PathBuf};

pub struct Report {
    dir: Option<PathBuf>,
    pub echo: bool,
}

impl Report {
    /// Write files under `dir` (created if needed); `None` = stdout only.
    pub fn new(dir: Option<&Path>) -> std::io::Result<Report> {
        if let Some(d) = dir {
            std::fs::create_dir_all(d)?;
        }
        Ok(Report { dir: dir.map(|d| d.to_path_buf()), echo: true })
    }

    pub fn table(
        &self,
        name: &str,
        title: &str,
        headers: &[&str],
        rows: &[Vec<String>],
    ) -> std::io::Result<()> {
        let md = render_markdown(title, headers, rows);
        if self.echo {
            println!("{md}");
        }
        if let Some(dir) = &self.dir {
            std::fs::write(dir.join(format!("{name}.md")), &md)?;
            let mut csv = std::fs::File::create(dir.join(format!("{name}.csv")))?;
            writeln!(csv, "{}", headers.join(","))?;
            for row in rows {
                writeln!(csv, "{}", row.join(","))?;
            }
        }
        Ok(())
    }
}

pub fn render_markdown(title: &str, headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut s = format!("## {title}\n\n");
    s.push_str(&format!("| {} |\n", headers.join(" | ")));
    s.push_str(&format!("|{}\n", "---|".repeat(headers.len())));
    for row in rows {
        s.push_str(&format!("| {} |\n", row.join(" | ")));
    }
    s
}

/// Format helpers shared by the figure harnesses.
pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}

pub fn f1(x: f64) -> String {
    format!("{x:.1}")
}

pub fn f4(x: f64) -> String {
    format!("{x:.4}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_shape() {
        let md = render_markdown("T", &["a", "b"], &[vec!["1".into(), "2".into()]]);
        assert!(md.contains("## T"));
        assert!(md.contains("| a | b |"));
        assert!(md.contains("| 1 | 2 |"));
    }

    #[test]
    fn writes_files() {
        let dir = std::env::temp_dir().join("csrc_report_test");
        let _ = std::fs::remove_dir_all(&dir);
        let r = Report::new(Some(&dir)).unwrap();
        r.table("t1", "Title", &["x"], &[vec!["7".into()]]).unwrap();
        assert!(dir.join("t1.md").exists());
        let csv = std::fs::read_to_string(dir.join("t1.csv")).unwrap();
        assert_eq!(csv, "x\n7\n");
    }
}
