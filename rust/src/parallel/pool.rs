//! Persistent fork-join worker pool.
//!
//! The paper's OpenMP `parallel do` amortizes thread spawn cost across a
//! solver's thousand products; spawning per product would drown the
//! fine-grained kernel in overhead. This pool keeps `p` workers parked on
//! a condvar and runs closures of the shape `f(tid)` with a fork-join
//! barrier, plus an in-region [`Barrier`]-like `sync()` for the engines'
//! compute→accumulate phase boundary.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Barrier, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Arc<dyn Fn(usize) + Send + Sync>;

struct Shared {
    job: Mutex<Option<(u64, Job)>>, // (epoch, job)
    cv: Condvar,
    done: Mutex<u64>, // count of completed epochs × workers
    done_cv: Condvar,
    shutdown: std::sync::atomic::AtomicBool,
}

/// Fork-join pool with `p` *worker* threads; the caller participates as
/// thread 0, workers are 1..p (so `ThreadPool::new(1)` spawns nothing and
/// runs inline, matching the paper's "check the number of threads at
/// runtime" single-thread shortcut).
pub struct ThreadPool {
    p: usize,
    shared: Arc<Shared>,
    region_barrier: Arc<Barrier>,
    epoch: u64,
    handles: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(p: usize) -> ThreadPool {
        assert!(p >= 1);
        let shared = Arc::new(Shared {
            job: Mutex::new(None),
            cv: Condvar::new(),
            done: Mutex::new(0),
            done_cv: Condvar::new(),
            shutdown: std::sync::atomic::AtomicBool::new(false),
        });
        let region_barrier = Arc::new(Barrier::new(p));
        let handles = (1..p)
            .map(|tid| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("csrc-worker-{tid}"))
                    .spawn(move || worker_loop(tid, shared))
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { p, shared, region_barrier, epoch: 0, handles }
    }

    pub fn nthreads(&self) -> usize {
        self.p
    }

    /// Barrier usable *inside* a running region (all p threads must call).
    pub fn barrier(&self) -> Arc<Barrier> {
        self.region_barrier.clone()
    }

    /// Run `f(tid)` on all p threads (caller runs tid 0) and join.
    pub fn run<F>(&mut self, f: F)
    where
        F: Fn(usize) + Send + Sync,
    {
        if self.p == 1 {
            f(0);
            return;
        }
        self.epoch += 1;
        // SAFETY-free type erasure: extend the closure's lifetime for the
        // duration of this call; we block until every worker reports done,
        // so the borrow cannot escape. (The standard scoped-pool trick.)
        let job: Arc<dyn Fn(usize) + Send + Sync> = unsafe {
            std::mem::transmute::<Arc<dyn Fn(usize) + Send + Sync + '_>, Job>(
                Arc::new(f) as Arc<dyn Fn(usize) + Send + Sync + '_>
            )
        };
        {
            let mut slot = self.shared.job.lock().unwrap();
            *slot = Some((self.epoch, job.clone()));
            self.shared.cv.notify_all();
        }
        job(0);
        drop(job);
        // Wait until all workers finished this epoch.
        let mut done = self.shared.done.lock().unwrap();
        while *done < self.epoch * (self.p as u64 - 1) {
            done = self.shared.done_cv.wait(done).unwrap();
        }
    }
}

fn worker_loop(tid: usize, shared: Arc<Shared>) {
    let mut last_epoch = 0u64;
    loop {
        let job = {
            let mut slot = shared.job.lock().unwrap();
            loop {
                if shared.shutdown.load(Ordering::Acquire) {
                    return;
                }
                if let Some((epoch, job)) = slot.as_ref() {
                    if *epoch > last_epoch {
                        last_epoch = *epoch;
                        break job.clone();
                    }
                }
                slot = shared.cv.wait(slot).unwrap();
            }
        };
        job(tid);
        drop(job);
        let mut done = shared.done.lock().unwrap();
        *done += 1;
        shared.done_cv.notify_all();
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::Release);
        self.shared.cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// A tiny atomic work counter for dynamic scheduling experiments.
pub struct WorkCounter(AtomicUsize);

impl WorkCounter {
    pub fn new() -> Self {
        Self(AtomicUsize::new(0))
    }
    pub fn next(&self) -> usize {
        self.0.fetch_add(1, Ordering::Relaxed)
    }
    pub fn reset(&self) {
        self.0.store(0, Ordering::Relaxed);
    }
}

impl Default for WorkCounter {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pool_runs_all_tids() {
        let mut pool = ThreadPool::new(4);
        let hits: Vec<AtomicUsize> = (0..4).map(|_| AtomicUsize::new(0)).collect();
        pool.run(|tid| {
            hits[tid].fetch_add(1, Ordering::Relaxed);
        });
        for h in &hits {
            assert_eq!(h.load(Ordering::Relaxed), 1);
        }
    }

    #[test]
    fn pool_reusable_many_epochs() {
        let mut pool = ThreadPool::new(3);
        let count = AtomicUsize::new(0);
        for _ in 0..50 {
            pool.run(|_tid| {
                count.fetch_add(1, Ordering::Relaxed);
            });
        }
        assert_eq!(count.load(Ordering::Relaxed), 150);
    }

    #[test]
    fn single_thread_runs_inline() {
        let mut pool = ThreadPool::new(1);
        let mut touched = false;
        // Can borrow mutably because run with p=1 is inline.
        pool.run(|tid| {
            assert_eq!(tid, 0);
        });
        touched = true;
        assert!(touched);
    }

    #[test]
    fn in_region_barrier_synchronizes() {
        let mut pool = ThreadPool::new(4);
        let barrier = pool.barrier();
        let phase1 = AtomicUsize::new(0);
        let ok = AtomicUsize::new(0);
        pool.run(|_tid| {
            phase1.fetch_add(1, Ordering::SeqCst);
            barrier.wait();
            // After the barrier every thread must observe all phase-1 work.
            if phase1.load(Ordering::SeqCst) == 4 {
                ok.fetch_add(1, Ordering::SeqCst);
            }
        });
        assert_eq!(ok.load(Ordering::SeqCst), 4);
    }

    #[test]
    fn work_counter_is_dense() {
        let pool = WorkCounter::new();
        let mut seen: Vec<usize> = (0..100).map(|_| pool.next()).collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..100).collect::<Vec<_>>());
    }
}
