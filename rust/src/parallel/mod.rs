//! Parallel CSRC SpMV engines (§3 of the paper).
//!
//! The CSRC sweep scatters into `y[ja(k)]` while another thread may own
//! that row — the race the paper's two strategies avoid:
//!
//! * [`local_buffers::LocalBuffersEngine`] — per-thread private buffers
//!   merged in an accumulation step, with the four init/accumulation
//!   schemes of §3.1 ([`AccumMethod`]),
//! * [`colorful::ColorfulEngine`] — conflict-free color classes (§3.2),
//! * [`atomic::AtomicEngine`] — the atomics baseline the paper dismisses
//!   as too costly (kept as an ablation),
//! * [`pool::ThreadPool`] — the persistent fork-join worker pool all
//!   engines share.
//!
//! Every engine implements [`ParallelSpmv`] and is property-tested against
//! the sequential sweep.

pub mod atomic;
pub mod colorful;
pub mod local_buffers;
pub mod pool;

pub use atomic::AtomicEngine;
pub use colorful::ColorfulEngine;
pub use local_buffers::{AccumMethod, LocalBuffersEngine};
pub use pool::ThreadPool;

use crate::sparse::Csrc;

/// A parallel y = A·x engine over a fixed matrix + thread count.
pub trait ParallelSpmv {
    /// Compute y = A x (y fully overwritten).
    fn spmv(&mut self, x: &[f64], y: &mut [f64]);
    /// Engine name for reports.
    fn name(&self) -> String;
    fn nthreads(&self) -> usize;
}

/// Which engine to build — the CLI / harness selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    Sequential,
    LocalBuffers(AccumMethod),
    Colorful,
    Atomic,
}

impl EngineKind {
    pub fn all_local_buffers() -> [EngineKind; 4] {
        [
            EngineKind::LocalBuffers(AccumMethod::AllInOne),
            EngineKind::LocalBuffers(AccumMethod::PerBuffer),
            EngineKind::LocalBuffers(AccumMethod::Effective),
            EngineKind::LocalBuffers(AccumMethod::Interval),
        ]
    }

    pub fn parse(s: &str) -> Option<EngineKind> {
        Some(match s {
            "seq" | "sequential" => EngineKind::Sequential,
            "all-in-one" => EngineKind::LocalBuffers(AccumMethod::AllInOne),
            "per-buffer" => EngineKind::LocalBuffers(AccumMethod::PerBuffer),
            "effective" => EngineKind::LocalBuffers(AccumMethod::Effective),
            "interval" => EngineKind::LocalBuffers(AccumMethod::Interval),
            "colorful" => EngineKind::Colorful,
            "atomic" => EngineKind::Atomic,
            _ => return None,
        })
    }

    pub fn label(&self) -> String {
        match self {
            EngineKind::Sequential => "sequential".into(),
            EngineKind::LocalBuffers(m) => format!("local-buffers/{}", m.label()),
            EngineKind::Colorful => "colorful".into(),
            EngineKind::Atomic => "atomic".into(),
        }
    }
}

/// Sequential engine (the speedup baseline: the paper's speedups are
/// relative to the *pure sequential* CSRC sweep, not the 1-thread case).
pub struct SequentialEngine {
    a: std::sync::Arc<Csrc>,
}

impl SequentialEngine {
    pub fn new(a: std::sync::Arc<Csrc>) -> Self {
        Self { a }
    }
}

impl ParallelSpmv for SequentialEngine {
    fn spmv(&mut self, x: &[f64], y: &mut [f64]) {
        self.a.spmv_into_zeroed(x, y);
    }
    fn name(&self) -> String {
        "sequential".into()
    }
    fn nthreads(&self) -> usize {
        1
    }
}

/// Build any engine from its kind.
pub fn build_engine(
    kind: EngineKind,
    a: std::sync::Arc<Csrc>,
    nthreads: usize,
) -> Box<dyn ParallelSpmv> {
    match kind {
        EngineKind::Sequential => Box::new(SequentialEngine::new(a)),
        EngineKind::LocalBuffers(m) => Box::new(LocalBuffersEngine::new(a, nthreads, m)),
        EngineKind::Colorful => Box::new(ColorfulEngine::new(a, nthreads)),
        EngineKind::Atomic => Box::new(AtomicEngine::new(a, nthreads)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::Coo;
    use crate::util::{propcheck, Rng};
    use std::sync::Arc;

    /// Every engine × several thread counts must match the sequential
    /// sweep — the central correctness property of the whole paper.
    #[test]
    fn all_engines_match_sequential() {
        propcheck::check(8, |rng| {
            let n = 16 + rng.below(120);
            let npr = 1 + rng.below(6);
            let sym = rng.below(2) == 0;
            let coo = Coo::random_structurally_symmetric(n, npr, sym, rng);
            let a = Arc::new(crate::sparse::Csrc::from_coo(&coo).map_err(|e| e.to_string())?);
            let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let mut want = vec![0.0; n];
            a.spmv_into_zeroed(&x, &mut want);
            let kinds = [
                EngineKind::LocalBuffers(AccumMethod::AllInOne),
                EngineKind::LocalBuffers(AccumMethod::PerBuffer),
                EngineKind::LocalBuffers(AccumMethod::Effective),
                EngineKind::LocalBuffers(AccumMethod::Interval),
                EngineKind::Colorful,
                EngineKind::Atomic,
            ];
            for kind in kinds {
                for p in [1, 2, 3, 4] {
                    let mut engine = build_engine(kind, a.clone(), p);
                    let mut y = vec![f64::NAN; n]; // must be fully overwritten
                    engine.spmv(&x, &mut y);
                    propcheck::assert_close(&y, &want, 1e-11, 1e-11)
                        .map_err(|e| format!("{} p={p}: {e}", kind.label()))?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn engine_parse_labels_roundtrip() {
        for s in ["seq", "all-in-one", "per-buffer", "effective", "interval", "colorful", "atomic"]
        {
            assert!(EngineKind::parse(s).is_some(), "{s}");
        }
        assert!(EngineKind::parse("nope").is_none());
    }

    #[test]
    fn engines_are_reusable() {
        // Repeated calls must not accumulate stale buffer state.
        let mut rng = Rng::new(77);
        let coo = Coo::random_structurally_symmetric(50, 4, false, &mut rng);
        let a = Arc::new(crate::sparse::Csrc::from_coo(&coo).unwrap());
        let x: Vec<f64> = (0..50).map(|_| rng.normal()).collect();
        let mut want = vec![0.0; 50];
        a.spmv_into_zeroed(&x, &mut want);
        let mut engine =
            build_engine(EngineKind::LocalBuffers(AccumMethod::Effective), a.clone(), 3);
        for _ in 0..5 {
            let mut y = vec![0.0; 50];
            engine.spmv(&x, &mut y);
            propcheck::assert_close(&y, &want, 1e-11, 1e-11).unwrap();
        }
    }
}

pub mod share;
