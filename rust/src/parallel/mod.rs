//! Parallel SpMV executors (§3 of the paper).
//!
//! The row sweep of a scatter-producing kernel (CSRC writes `y[ja(k)]`
//! while another thread may own that row) races unless scheduled; the
//! paper's two strategies avoid the race with precomputed analysis. This
//! module holds only the *execution* half of that split: every engine is
//! a format-generic executor over a [`SpmvKernel`] that borrows a shared,
//! immutable [`SpmvPlan`] (see [`crate::plan`]) instead of computing its
//! own analysis in the constructor.
//!
//! * [`local_buffers::LocalBuffersEngine`] — per-thread private buffers
//!   merged in an accumulation step, with the four init/accumulation
//!   schemes of §3.1 ([`AccumMethod`]); consumes the plan's partition,
//!   effective ranges and interval decomposition,
//! * [`colorful::ColorfulEngine`] — conflict-free color classes (§3.2);
//!   consumes the plan's coloring and class shares,
//! * [`atomic::AtomicEngine`] — the atomics baseline the paper dismisses
//!   as too costly (kept as an ablation); consumes the partition,
//! * [`pool::ThreadPool`] — the persistent fork-join worker pool all
//!   engines share.
//!
//! Engines are built through [`build_engine`] from `(kind, kernel, plan)`
//! — the coordinator caches one plan per matrix × thread-count and every
//! worker / engine borrows it. [`build_engine_auto`] builds a fresh
//! single-use plan for callers without a cache. Every engine implements
//! [`ParallelSpmv`] and is property-tested against the sequential sweep
//! for both the CSRC and CSR kernels.

pub mod atomic;
pub mod colorful;
pub mod local_buffers;
pub mod pool;

pub use atomic::AtomicEngine;
pub use colorful::ColorfulEngine;
pub use local_buffers::{AccumMethod, LocalBuffersEngine};
pub use pool::ThreadPool;

use crate::plan::{PlanBuilder, SpmvPlan};
use crate::sparse::SpmvKernel;
use std::sync::Arc;

/// A parallel y = A·x engine over a fixed kernel + plan.
pub trait ParallelSpmv {
    /// Compute y = A x (y fully overwritten).
    fn spmv(&mut self, x: &[f64], y: &mut [f64]);
    /// Multi-vector product Y = A X over row-major n×k panels
    /// (`x[j*k + c]`, `y[i*k + c]`; `y` fully overwritten). The default
    /// de-interleaves into k serial products — correct for any engine;
    /// the concrete engines override it with blocked sweeps that read
    /// the matrix once for all k columns.
    fn spmv_multi(&mut self, x: &[f64], y: &mut [f64], k: usize) {
        assert!(k >= 1 && x.len() == y.len() && y.len() % k == 0);
        if k == 1 {
            return self.spmv(x, y);
        }
        let n = y.len() / k;
        let mut xc = vec![0.0; n];
        let mut yc = vec![0.0; n];
        for c in 0..k {
            for (s, panel) in xc.iter_mut().zip(x.chunks_exact(k)) {
                *s = panel[c];
            }
            self.spmv(&xc, &mut yc);
            for (v, panel) in yc.iter().zip(y.chunks_exact_mut(k)) {
                panel[c] = *v;
            }
        }
    }
    /// Engine name for reports.
    fn name(&self) -> String;
    fn nthreads(&self) -> usize;
    /// The plan this engine executes (None for the sequential baseline).
    fn plan(&self) -> Option<&Arc<SpmvPlan>> {
        None
    }
}

/// Which engine to build — the CLI / harness selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    Sequential,
    LocalBuffers(AccumMethod),
    Colorful,
    Atomic,
    /// Measurement-driven selection: resolved per matrix × thread-count
    /// by the autotuner ([`crate::tuner`]) into one of the concrete
    /// kinds above. `Auto` is a *routing* selector — it never reaches
    /// [`build_engine`] unresolved.
    Auto,
}

impl EngineKind {
    pub fn all_local_buffers() -> [EngineKind; 4] {
        [
            EngineKind::LocalBuffers(AccumMethod::AllInOne),
            EngineKind::LocalBuffers(AccumMethod::PerBuffer),
            EngineKind::LocalBuffers(AccumMethod::Effective),
            EngineKind::LocalBuffers(AccumMethod::Interval),
        ]
    }

    /// Every *concrete* kind (the order reports use). `Auto` is excluded
    /// on purpose: it is a selector, not an executor.
    pub fn all() -> [EngineKind; 7] {
        [
            EngineKind::Sequential,
            EngineKind::LocalBuffers(AccumMethod::AllInOne),
            EngineKind::LocalBuffers(AccumMethod::PerBuffer),
            EngineKind::LocalBuffers(AccumMethod::Effective),
            EngineKind::LocalBuffers(AccumMethod::Interval),
            EngineKind::Colorful,
            EngineKind::Atomic,
        ]
    }

    /// Parse a selector. Accepts both the short CLI spellings
    /// (`effective`) and every string [`EngineKind::label`] emits
    /// (`local-buffers/effective`), case-insensitively. The
    /// `local-buffers/` prefix is valid only for the four accumulation
    /// methods — `local-buffers/colorful` is rejected, not reinterpreted.
    pub fn parse(s: &str) -> Option<EngineKind> {
        let lower = s.trim().to_ascii_lowercase();
        if let Some(method) = lower.strip_prefix("local-buffers/") {
            return Some(EngineKind::LocalBuffers(match method {
                "all-in-one" => AccumMethod::AllInOne,
                "per-buffer" => AccumMethod::PerBuffer,
                "effective" => AccumMethod::Effective,
                "interval" => AccumMethod::Interval,
                _ => return None,
            }));
        }
        Some(match lower.as_str() {
            "seq" | "sequential" => EngineKind::Sequential,
            "all-in-one" => EngineKind::LocalBuffers(AccumMethod::AllInOne),
            "per-buffer" => EngineKind::LocalBuffers(AccumMethod::PerBuffer),
            "effective" => EngineKind::LocalBuffers(AccumMethod::Effective),
            "interval" => EngineKind::LocalBuffers(AccumMethod::Interval),
            "colorful" => EngineKind::Colorful,
            "atomic" => EngineKind::Atomic,
            "auto" => EngineKind::Auto,
            _ => return None,
        })
    }

    pub fn label(&self) -> String {
        match self {
            EngineKind::Sequential => "sequential".into(),
            EngineKind::LocalBuffers(m) => format!("local-buffers/{}", m.label()),
            EngineKind::Colorful => "colorful".into(),
            EngineKind::Atomic => "atomic".into(),
            EngineKind::Auto => "auto".into(),
        }
    }
}

/// Sequential engine (the speedup baseline: the paper's speedups are
/// relative to the *pure sequential* sweep, not the 1-thread case).
pub struct SequentialEngine {
    kernel: Arc<dyn SpmvKernel>,
}

impl SequentialEngine {
    pub fn new(kernel: Arc<dyn SpmvKernel>) -> Self {
        Self { kernel }
    }
}

impl ParallelSpmv for SequentialEngine {
    fn spmv(&mut self, x: &[f64], y: &mut [f64]) {
        self.kernel.sweep_full(x, y);
    }
    fn spmv_multi(&mut self, x: &[f64], y: &mut [f64], k: usize) {
        self.kernel.sweep_full_multi(x, y, k);
    }
    fn name(&self) -> String {
        "sequential".into()
    }
    fn nthreads(&self) -> usize {
        1
    }
}

/// Build an executor from its kind, the kernel it sweeps, and the shared
/// plan it borrows — the coordinator path, where one `Arc<SpmvPlan>` per
/// matrix × thread-count serves every worker and engine.
///
/// Panics if the plan lacks a piece the kind needs (build it with
/// [`PlanBuilder::for_kind`] or [`PlanBuilder::all`]).
pub fn build_engine(
    kind: EngineKind,
    kernel: Arc<dyn SpmvKernel>,
    plan: Arc<SpmvPlan>,
) -> Box<dyn ParallelSpmv> {
    assert!(
        kind != EngineKind::Auto,
        "EngineKind::Auto is a routing selector: resolve it to a concrete engine \
         first (crate::tuner::resolve or tuner::cost_model)"
    );
    assert!(
        plan.pieces.covers(crate::plan::PlanPieces::for_kind(kind)),
        "plan (pieces {:?}) cannot run {}",
        plan.pieces,
        kind.label()
    );
    match kind {
        EngineKind::Sequential => Box::new(SequentialEngine::new(kernel)),
        EngineKind::LocalBuffers(m) => Box::new(LocalBuffersEngine::with_plan(kernel, plan, m)),
        EngineKind::Colorful => Box::new(ColorfulEngine::with_plan(kernel, plan)),
        EngineKind::Atomic => Box::new(AtomicEngine::with_plan(kernel, plan)),
        EngineKind::Auto => unreachable!("rejected above"),
    }
}

/// Convenience for plan-less callers (examples, benches, one-shot CLI
/// runs): analyze the kernel for exactly this kind and build the engine.
pub fn build_engine_auto(
    kind: EngineKind,
    kernel: Arc<dyn SpmvKernel>,
    nthreads: usize,
) -> Box<dyn ParallelSpmv> {
    let plan = Arc::new(PlanBuilder::for_kind(nthreads, kind).build(kernel.as_ref()));
    build_engine(kind, kernel, plan)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::{Coo, Csr, Csrc};
    use crate::util::{propcheck, Rng};
    use std::sync::Arc;

    /// Every engine × kernel format × several thread counts must match
    /// the sequential sweep — the central correctness property of the
    /// whole paper, now format-generic: the same executors run the CSRC
    /// kernel (scattering) and the CSR kernel (scatter-free).
    #[test]
    fn all_engines_match_sequential() {
        propcheck::check(6, |rng| {
            let n = 16 + rng.below(120);
            let npr = 1 + rng.below(6);
            let sym = rng.below(2) == 0;
            let coo = Coo::random_structurally_symmetric(n, npr, sym, rng);
            let csrc = Csrc::from_coo(&coo).map_err(|e| e.to_string())?;
            let csr = Csr::from_coo(&coo);
            let kernels: [Arc<dyn crate::sparse::SpmvKernel>; 2] =
                [Arc::new(csrc), Arc::new(csr)];
            let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            for kernel in kernels {
                let mut want = vec![0.0; n];
                kernel.sweep_full(&x, &mut want);
                let kinds = [
                    EngineKind::LocalBuffers(AccumMethod::AllInOne),
                    EngineKind::LocalBuffers(AccumMethod::PerBuffer),
                    EngineKind::LocalBuffers(AccumMethod::Effective),
                    EngineKind::LocalBuffers(AccumMethod::Interval),
                    EngineKind::Colorful,
                    EngineKind::Atomic,
                ];
                for kind in kinds {
                    for p in [1, 2, 3, 4] {
                        let mut engine = build_engine_auto(kind, kernel.clone(), p);
                        let mut y = vec![f64::NAN; n]; // must be fully overwritten
                        engine.spmv(&x, &mut y);
                        propcheck::assert_close(&y, &want, 1e-11, 1e-11).map_err(|e| {
                            format!("{} [{}] p={p}: {e}", kind.label(), kernel.kernel_name())
                        })?;
                    }
                }
            }
            Ok(())
        });
    }

    /// Satellite: the blocked product is exactly k independent SpMVs —
    /// for every engine kind (all four accumulation methods included),
    /// k ∈ {1, 2, 3, 8}, on an RCM-permuted (banded) and on a shuffled
    /// matrix, through the plain engines and the reordered sandwich.
    #[test]
    fn property_spmv_multi_matches_k_serial_spmv() {
        propcheck::check(4, |rng| {
            let n = 16 + rng.below(90);
            let npr = 1 + rng.below(5);
            let coo = Coo::random_structurally_symmetric(n, npr, false, rng);
            let base = Csrc::from_coo(&coo).map_err(|e| e.to_string())?;
            // Two orderings: RCM-tightened and adversarially shuffled.
            let rcm_perm = crate::reorder::rcm(&base);
            let shuffle = crate::reorder::Permutation::from_new_to_old(rng.permutation(n))
                .map_err(|e| e.to_string())?;
            let mats = [base.permuted(&rcm_perm), base.permuted(&shuffle)];
            let kinds = [
                EngineKind::Sequential,
                EngineKind::LocalBuffers(AccumMethod::AllInOne),
                EngineKind::LocalBuffers(AccumMethod::PerBuffer),
                EngineKind::LocalBuffers(AccumMethod::Effective),
                EngineKind::LocalBuffers(AccumMethod::Interval),
                EngineKind::Colorful,
                EngineKind::Atomic,
            ];
            for (mi, m) in mats.into_iter().enumerate() {
                // Reordered-sandwich ingredients for this ordering:
                // engines on B = P A Pᵀ exposed in the original numbering.
                let sandwich_perm = Arc::new(crate::reorder::rcm(&m));
                let sandwich_kernel: Arc<dyn crate::sparse::SpmvKernel> =
                    Arc::new(m.permuted(sandwich_perm.as_ref()));
                let kernel: Arc<dyn crate::sparse::SpmvKernel> = Arc::new(m);
                for k in [1usize, 2, 3, 8] {
                    let x: Vec<f64> = (0..n * k).map(|_| rng.normal()).collect();
                    // Oracle: k serial SpMVs, column by column.
                    let mut want = vec![0.0; n * k];
                    let mut xc = vec![0.0; n];
                    let mut yc = vec![0.0; n];
                    for c in 0..k {
                        for (s, panel) in xc.iter_mut().zip(x.chunks_exact(k)) {
                            *s = panel[c];
                        }
                        yc.fill(0.0);
                        kernel.sweep_full(&xc, &mut yc);
                        for (v, panel) in yc.iter().zip(want.chunks_exact_mut(k)) {
                            panel[c] = *v;
                        }
                    }
                    for kind in kinds {
                        let p = 1 + rng.below(4);
                        let mut engine = build_engine_auto(kind, kernel.clone(), p);
                        let mut y = vec![f64::NAN; n * k];
                        engine.spmv_multi(&x, &mut y, k);
                        propcheck::assert_close(&y, &want, 1e-9, 1e-9).map_err(|e| {
                            format!("{} mat{mi} p={p} k={k}: {e}", kind.label())
                        })?;
                        let inner = build_engine_auto(kind, sandwich_kernel.clone(), p);
                        let mut re =
                            crate::reorder::ReorderedEngine::new(inner, sandwich_perm.clone());
                        let mut y2 = vec![f64::NAN; n * k];
                        re.spmv_multi(&x, &mut y2, k);
                        propcheck::assert_close(&y2, &want, 1e-9, 1e-9).map_err(|e| {
                            format!("reordered/{} mat{mi} p={p} k={k}: {e}", kind.label())
                        })?;
                    }
                }
            }
            Ok(())
        });
    }

    /// One shared full plan drives every engine kind — the coordinator's
    /// usage pattern.
    #[test]
    fn engines_share_one_plan() {
        let mut rng = Rng::new(7);
        let coo = Coo::random_structurally_symmetric(90, 4, false, &mut rng);
        let a: Arc<dyn crate::sparse::SpmvKernel> = Arc::new(Csrc::from_coo(&coo).unwrap());
        let plan = Arc::new(crate::plan::PlanBuilder::all(3).build(a.as_ref()));
        let x: Vec<f64> = (0..90).map(|_| rng.normal()).collect();
        let mut want = vec![0.0; 90];
        a.sweep_full(&x, &mut want);
        for kind in EngineKind::all() {
            let mut engine = build_engine(kind, a.clone(), plan.clone());
            if let Some(p) = engine.plan() {
                assert!(Arc::ptr_eq(p, &plan), "{} must borrow the shared plan", kind.label());
            }
            let mut y = vec![f64::NAN; 90];
            engine.spmv(&x, &mut y);
            propcheck::assert_close(&y, &want, 1e-9, 1e-9).unwrap();
        }
    }

    #[test]
    fn bcsr_kernel_runs_through_engines() {
        let mut rng = Rng::new(8);
        let coo = Coo::random_structurally_symmetric(64, 3, false, &mut rng);
        let csr = Csr::from_coo(&coo);
        let bcsr: Arc<dyn crate::sparse::SpmvKernel> =
            Arc::new(crate::sparse::Bcsr::from_csr(&csr, 2, 2));
        let x: Vec<f64> = (0..64).map(|_| rng.normal()).collect();
        let mut want = vec![0.0; 64];
        bcsr.sweep_full(&x, &mut want);
        for kind in [
            EngineKind::LocalBuffers(AccumMethod::Effective),
            EngineKind::Colorful,
            EngineKind::Atomic,
        ] {
            let mut engine = build_engine_auto(kind, bcsr.clone(), 3);
            let mut y = vec![f64::NAN; 64];
            engine.spmv(&x, &mut y);
            propcheck::assert_close(&y, &want, 1e-10, 1e-10)
                .unwrap_or_else(|e| panic!("{}: {e}", kind.label()));
        }
    }

    /// Satellite regression: `label()` emits `local-buffers/<method>`,
    /// which `parse()` must accept (it used to reject it) — round-trip
    /// every variant, case-insensitively.
    #[test]
    fn engine_label_parse_roundtrip() {
        for kind in EngineKind::all() {
            let label = kind.label();
            assert_eq!(EngineKind::parse(&label), Some(kind), "{label}");
            assert_eq!(EngineKind::parse(&label.to_ascii_uppercase()), Some(kind), "{label}");
        }
        for s in ["seq", "all-in-one", "per-buffer", "effective", "interval", "colorful", "atomic"]
        {
            assert!(EngineKind::parse(s).is_some(), "{s}");
        }
        // Auto round-trips as a selector but never appears in all().
        assert_eq!(EngineKind::parse("auto"), Some(EngineKind::Auto));
        assert_eq!(EngineKind::parse(&EngineKind::Auto.label()), Some(EngineKind::Auto));
        assert!(!EngineKind::all().contains(&EngineKind::Auto));
        assert!(EngineKind::parse("nope").is_none());
        assert!(EngineKind::parse("local-buffers/nope").is_none());
        // The prefix must not smuggle other engine families through.
        assert!(EngineKind::parse("local-buffers/colorful").is_none());
        assert!(EngineKind::parse("local-buffers/seq").is_none());
        assert!(EngineKind::parse("local-buffers/atomic").is_none());
    }

    #[test]
    fn engines_are_reusable() {
        // Repeated calls must not accumulate stale buffer state.
        let mut rng = Rng::new(77);
        let coo = Coo::random_structurally_symmetric(50, 4, false, &mut rng);
        let a: Arc<dyn crate::sparse::SpmvKernel> = Arc::new(Csrc::from_coo(&coo).unwrap());
        let x: Vec<f64> = (0..50).map(|_| rng.normal()).collect();
        let mut want = vec![0.0; 50];
        a.sweep_full(&x, &mut want);
        let mut engine =
            build_engine_auto(EngineKind::LocalBuffers(AccumMethod::Effective), a.clone(), 3);
        for _ in 0..5 {
            let mut y = vec![0.0; 50];
            engine.spmv(&x, &mut y);
            propcheck::assert_close(&y, &want, 1e-11, 1e-11).unwrap();
        }
    }

    #[test]
    #[should_panic(expected = "routing selector")]
    fn auto_kind_rejected_by_build_engine() {
        let mut rng = Rng::new(10);
        let coo = Coo::random_structurally_symmetric(30, 2, false, &mut rng);
        let a: Arc<dyn crate::sparse::SpmvKernel> = Arc::new(Csrc::from_coo(&coo).unwrap());
        let plan = Arc::new(crate::plan::PlanBuilder::all(2).build(a.as_ref()));
        let _ = build_engine(EngineKind::Auto, a, plan);
    }

    #[test]
    #[should_panic(expected = "cannot run")]
    fn partition_only_plan_rejects_colorful() {
        let mut rng = Rng::new(9);
        let coo = Coo::random_structurally_symmetric(30, 2, false, &mut rng);
        let a: Arc<dyn crate::sparse::SpmvKernel> = Arc::new(Csrc::from_coo(&coo).unwrap());
        let plan = Arc::new(crate::plan::PlanBuilder::new(2).build(a.as_ref()));
        let _ = build_engine(EngineKind::Colorful, a, plan);
    }
}

pub mod share;
