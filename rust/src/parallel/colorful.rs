//! Colorful executor (§3.2): rows are grouped into conflict-free color
//! classes (no direct or indirect conflicts inside a class), so inside a
//! class every thread may write y directly — no buffers, no atomics.
//! Classes run one after another with a team barrier in between; rows of
//! a class are split nnz-balanced among threads.
//!
//! The coloring and the per-class thread shares are analysis and live in
//! the borrowed [`SpmvPlan`]; this type holds only the thread pool and
//! sweeps rows through the [`SpmvKernel`] abstraction, so the same
//! executor serves CSRC (scattering) and scatter-free formats (which
//! collapse to a single color).

use super::pool::ThreadPool;
use super::share::SyncSlice;
use super::ParallelSpmv;
use crate::graph::ColorClasses;
use crate::obs::{self, Phase};
use crate::plan::{PlanBuilder, SpmvPlan};
use crate::sparse::SpmvKernel;
use std::sync::Arc;

pub struct ColorfulEngine {
    kernel: Arc<dyn SpmvKernel>,
    plan: Arc<SpmvPlan>,
    pool: ThreadPool,
}

impl ColorfulEngine {
    /// Analyze-and-build convenience (single-use plan). Shared-plan
    /// callers use [`ColorfulEngine::with_plan`] / [`super::build_engine`].
    pub fn new(kernel: Arc<dyn SpmvKernel>, p: usize) -> Self {
        let plan = Arc::new(
            PlanBuilder::for_kind(p, super::EngineKind::Colorful).build(kernel.as_ref()),
        );
        Self::with_plan(kernel, plan)
    }

    /// Build with a caller-provided coloring (used by the stride-capped
    /// ablation and by tests).
    pub fn with_coloring(kernel: Arc<dyn SpmvKernel>, p: usize, colors: ColorClasses) -> Self {
        let plan =
            Arc::new(PlanBuilder::new(p).build_with_coloring(kernel.as_ref(), colors));
        Self::with_plan(kernel, plan)
    }

    /// Build over a shared plan (must carry the coloring piece).
    pub fn with_plan(kernel: Arc<dyn SpmvKernel>, plan: Arc<SpmvPlan>) -> Self {
        assert_eq!(plan.n, kernel.dim(), "plan built for a different matrix");
        assert!(plan.colors.is_some(), "colorful engine needs plan coloring");
        let p = plan.nthreads;
        ColorfulEngine { kernel, plan, pool: ThreadPool::new(p) }
    }

    pub fn num_colors(&self) -> usize {
        self.coloring().num_colors()
    }

    pub fn coloring(&self) -> &ColorClasses {
        self.plan.colors.as_ref().unwrap()
    }
}

impl ParallelSpmv for ColorfulEngine {
    fn spmv(&mut self, x: &[f64], y: &mut [f64]) {
        let n = self.plan.n;
        debug_assert_eq!(x.len(), n);
        debug_assert_eq!(y.len(), n);
        let p = self.pool.nthreads();
        if p == 1 {
            let _sweep_span = obs::phase(Phase::Sweep);
            self.kernel.sweep_full(x, y);
            return;
        }
        let kernel = &*self.kernel;
        let colors = self.plan.colors.as_ref().unwrap();
        let shares = self.plan.color_shares.as_ref().unwrap();
        let barrier = self.pool.barrier();
        let yv = SyncSlice::new(y);

        self.pool.run(move |t| {
            // Phase 0: zero y cooperatively (disjoint chunks).
            let zero_span = obs::phase(Phase::Zero);
            let (lo, hi) = (t * n / p, (t + 1) * n / p);
            // SAFETY: disjoint per-thread chunks.
            unsafe { yv.slice_mut(lo..hi).fill(0.0) };
            drop(zero_span);
            barrier.wait();
            let _sweep_span = obs::phase(Phase::Sweep);
            // One color at a time; rows inside a class are conflict-free
            // — by the coloring invariant no other thread's row in this
            // phase writes any y position row i's sweep writes — so the
            // kernel may accumulate straight into the shared vector
            // (through a raw pointer: no `&mut` alias of y is ever
            // formed). Barrier between colors.
            for (class, share) in colors.classes.iter().zip(shares) {
                let (s, e) = share[t];
                for &row in &class[s..e] {
                    let i = row as usize;
                    // SAFETY: y has length n and row i's write set is
                    // disjoint from every other row of this class.
                    unsafe { kernel.sweep_row_shared(x, i, yv.as_mut_ptr()) };
                }
                barrier.wait();
            }
        });
    }

    /// k-wide product: identical schedule (zero cooperatively, one color
    /// class at a time), but every row sweep writes a k-slot panel. The
    /// coloring invariant is unchanged — row i's write set is `{i} ∪
    /// scatter targets`, and widening each target to k adjacent slots
    /// keeps distinct rows' panels disjoint.
    fn spmv_multi(&mut self, x: &[f64], y: &mut [f64], k: usize) {
        assert!(k >= 1);
        if k == 1 {
            return self.spmv(x, y);
        }
        let n = self.plan.n;
        debug_assert_eq!(x.len(), n * k);
        debug_assert_eq!(y.len(), n * k);
        let p = self.pool.nthreads();
        if p == 1 {
            let _sweep_span = obs::phase(Phase::Sweep);
            self.kernel.sweep_full_multi(x, y, k);
            return;
        }
        let kernel = &*self.kernel;
        let colors = self.plan.colors.as_ref().unwrap();
        let shares = self.plan.color_shares.as_ref().unwrap();
        let barrier = self.pool.barrier();
        let yv = SyncSlice::new(y);

        self.pool.run(move |t| {
            let zero_span = obs::phase(Phase::Zero);
            let (lo, hi) = (t * n / p, (t + 1) * n / p);
            // SAFETY: disjoint per-thread chunks (scaled by k).
            unsafe { yv.slice_mut(lo * k..hi * k).fill(0.0) };
            drop(zero_span);
            barrier.wait();
            let _sweep_span = obs::phase(Phase::Sweep);
            for (class, share) in colors.classes.iter().zip(shares) {
                let (s, e) = share[t];
                for &row in &class[s..e] {
                    let i = row as usize;
                    // SAFETY: same disjointness as spmv — the multi sweep
                    // writes only slots `idx·k..idx·k+k` for idx in row
                    // i's write set, disjoint within a color class.
                    unsafe { kernel.sweep_row_shared_multi(x, k, i, yv.as_mut_ptr()) };
                }
                barrier.wait();
            }
        });
    }

    fn name(&self) -> String {
        format!("colorful({} colors)", self.num_colors())
    }

    fn nthreads(&self) -> usize {
        self.pool.nthreads()
    }

    fn plan(&self) -> Option<&Arc<SpmvPlan>> {
        Some(&self.plan)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{stride_capped_coloring, ConflictGraph};
    use crate::sparse::{Coo, Csrc};
    use crate::util::{propcheck, Rng};

    fn mat(n: usize, npr: usize, seed: u64) -> Arc<Csrc> {
        let mut rng = Rng::new(seed);
        Arc::new(
            Csrc::from_coo(&Coo::random_structurally_symmetric(n, npr, false, &mut rng)).unwrap(),
        )
    }

    #[test]
    fn matches_sequential_various_threads() {
        let a = mat(130, 4, 60);
        let x: Vec<f64> = (0..130).map(|i| (i as f64 * 0.1).cos()).collect();
        let mut want = vec![0.0; 130];
        a.spmv_into_zeroed(&x, &mut want);
        for p in [2, 3, 4, 5] {
            let mut e = ColorfulEngine::new(a.clone(), p);
            let mut y = vec![f64::NAN; 130];
            e.spmv(&x, &mut y);
            propcheck::assert_close(&y, &want, 1e-11, 1e-11)
                .unwrap_or_else(|err| panic!("p={p}: {err}"));
        }
    }

    #[test]
    fn banded_matrix_few_colors() {
        let mut rng = Rng::new(61);
        let a = Arc::new(Csrc::from_coo(&Coo::banded(100, 1, true, &mut rng)).unwrap());
        let e = ColorfulEngine::new(a, 2);
        assert!(e.num_colors() <= 3);
    }

    #[test]
    fn stride_capped_coloring_also_correct() {
        let a = mat(90, 3, 62);
        let g = ConflictGraph::build(a.as_ref());
        let colors = stride_capped_coloring(&g, 8);
        let x: Vec<f64> = (0..90).map(|i| i as f64).collect();
        let mut want = vec![0.0; 90];
        a.spmv_into_zeroed(&x, &mut want);
        let mut e = ColorfulEngine::with_coloring(a, 3, colors);
        let mut y = vec![0.0; 90];
        e.spmv(&x, &mut y);
        propcheck::assert_close(&y, &want, 1e-11, 1e-11).unwrap();
    }

    #[test]
    fn class_shares_cover_class() {
        let a = mat(70, 3, 63);
        let e = ColorfulEngine::new(a, 4);
        let colors = e.coloring();
        let shares = e.plan.color_shares.as_ref().unwrap();
        for (class, share) in colors.classes.iter().zip(shares) {
            assert_eq!(share[0].0, 0);
            assert_eq!(share.last().unwrap().1, class.len());
            for w in share.windows(2) {
                assert_eq!(w[0].1, w[1].0, "gap in class share");
            }
        }
    }

    #[test]
    fn property_colorful_vs_sequential() {
        propcheck::check(8, |rng| {
            let n = 10 + rng.below(100);
            let coo = Coo::random_structurally_symmetric(n, 1 + rng.below(5), false, rng);
            let a = Arc::new(Csrc::from_coo(&coo).map_err(|e| e.to_string())?);
            let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let mut want = vec![0.0; n];
            a.spmv_into_zeroed(&x, &mut want);
            let mut e = ColorfulEngine::new(a, 1 + rng.below(5));
            let mut y = vec![0.0; n];
            e.spmv(&x, &mut y);
            propcheck::assert_close(&y, &want, 1e-11, 1e-11)
        });
    }
}
