//! Colorful strategy (§3.2): rows are grouped into conflict-free color
//! classes (no direct or indirect conflicts inside a class), so inside a
//! class every thread may write y directly — no buffers, no atomics.
//! Classes run one after another with a team barrier in between; rows of
//! a class are split nnz-balanced among threads.

use super::pool::ThreadPool;
use super::share::SyncSlice;
use super::ParallelSpmv;
use crate::graph::{greedy_coloring, ColorClasses, ConflictGraph, Ordering as ColorOrdering};
use crate::sparse::Csrc;
use std::sync::Arc;

pub struct ColorfulEngine {
    a: Arc<Csrc>,
    pool: ThreadPool,
    colors: ColorClasses,
    /// Per color, per thread: the slice [lo, hi) of the class row list the
    /// thread processes (nnz-balanced inside the class).
    shares: Vec<Vec<(usize, usize)>>,
}

impl ColorfulEngine {
    pub fn new(a: Arc<Csrc>, p: usize) -> Self {
        let g = ConflictGraph::build(&a);
        let colors = greedy_coloring(&g, ColorOrdering::Natural);
        Self::with_coloring(a, p, colors)
    }

    /// Build with a caller-provided coloring (used by the stride-capped
    /// ablation and by tests).
    pub fn with_coloring(a: Arc<Csrc>, p: usize, colors: ColorClasses) -> Self {
        let shares = colors
            .classes
            .iter()
            .map(|class| split_class_by_nnz(&a, class, p))
            .collect();
        ColorfulEngine { a, pool: ThreadPool::new(p), colors, shares }
    }

    pub fn num_colors(&self) -> usize {
        self.colors.num_colors()
    }

    pub fn coloring(&self) -> &ColorClasses {
        &self.colors
    }
}

/// Split a class's row list into p contiguous chunks balanced by the
/// per-row CSRC work (1 + 2·row_len).
fn split_class_by_nnz(a: &Csrc, class: &[u32], p: usize) -> Vec<(usize, usize)> {
    let work: Vec<usize> = class.iter().map(|&i| 1 + 2 * a.row_range(i as usize).len()).collect();
    let total: usize = work.iter().sum();
    let mut out = Vec::with_capacity(p);
    let mut pos = 0usize;
    let mut consumed = 0usize;
    for t in 0..p {
        let start = pos;
        if t + 1 == p {
            pos = class.len();
        } else {
            let target = (total - consumed) as f64 / (p - t) as f64;
            let mut blk = 0usize;
            while pos < class.len() {
                let w = work[pos];
                if blk > 0 && (blk + w) as f64 - target > target - blk as f64 {
                    break;
                }
                blk += w;
                pos += 1;
            }
            consumed += blk;
        }
        out.push((start, pos));
    }
    out
}

impl ParallelSpmv for ColorfulEngine {
    fn spmv(&mut self, x: &[f64], y: &mut [f64]) {
        let n = self.a.n;
        debug_assert_eq!(x.len(), n);
        debug_assert_eq!(y.len(), n);
        let p = self.pool.nthreads();
        if p == 1 {
            self.a.spmv_into_zeroed(x, y);
            return;
        }
        let a = &self.a;
        let colors = &self.colors;
        let shares = &self.shares;
        let barrier = self.pool.barrier();
        let yv = SyncSlice::new(y);

        self.pool.run(move |t| {
            // Phase 0: zero y cooperatively (disjoint chunks).
            let (lo, hi) = (t * n / p, (t + 1) * n / p);
            // SAFETY: disjoint per-thread chunks.
            unsafe { yv.slice_mut(lo..hi).fill(0.0) };
            barrier.wait();
            // One color at a time; rows inside a color are conflict-free,
            // so direct writes to y are safe. Barrier between colors.
            for (class, share) in colors.classes.iter().zip(shares) {
                let (s, e) = share[t];
                for &row in &class[s..e] {
                    let i = row as usize;
                    let xi = x[i];
                    let mut acc = a.ad[i] * xi;
                    for k in a.row_range(i) {
                        let j = a.ja[k] as usize;
                        acc += a.al[k] * x[j];
                        // SAFETY: j is a direct neighbour of i; no other
                        // row in this class conflicts with i, so no other
                        // thread touches y[j] in this phase.
                        unsafe {
                            let cur = *yv.slice_mut(j..j + 1).as_ptr();
                            yv.write(j, cur + a.au[k] * xi);
                        }
                    }
                    unsafe {
                        let cur = *yv.slice_mut(i..i + 1).as_ptr();
                        yv.write(i, cur + acc);
                    }
                }
                barrier.wait();
            }
        });
    }

    fn name(&self) -> String {
        format!("colorful({} colors)", self.num_colors())
    }

    fn nthreads(&self) -> usize {
        self.pool.nthreads()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::stride_capped_coloring;
    use crate::sparse::Coo;
    use crate::util::{propcheck, Rng};

    fn mat(n: usize, npr: usize, seed: u64) -> Arc<Csrc> {
        let mut rng = Rng::new(seed);
        Arc::new(
            Csrc::from_coo(&Coo::random_structurally_symmetric(n, npr, false, &mut rng)).unwrap(),
        )
    }

    #[test]
    fn matches_sequential_various_threads() {
        let a = mat(130, 4, 60);
        let x: Vec<f64> = (0..130).map(|i| (i as f64 * 0.1).cos()).collect();
        let mut want = vec![0.0; 130];
        a.spmv_into_zeroed(&x, &mut want);
        for p in [2, 3, 4, 5] {
            let mut e = ColorfulEngine::new(a.clone(), p);
            let mut y = vec![f64::NAN; 130];
            e.spmv(&x, &mut y);
            propcheck::assert_close(&y, &want, 1e-11, 1e-11)
                .unwrap_or_else(|err| panic!("p={p}: {err}"));
        }
    }

    #[test]
    fn banded_matrix_few_colors() {
        let mut rng = Rng::new(61);
        let a = Arc::new(Csrc::from_coo(&Coo::banded(100, 1, true, &mut rng)).unwrap());
        let e = ColorfulEngine::new(a, 2);
        assert!(e.num_colors() <= 3);
    }

    #[test]
    fn stride_capped_coloring_also_correct() {
        let a = mat(90, 3, 62);
        let g = ConflictGraph::build(&a);
        let colors = stride_capped_coloring(&g, 8);
        let x: Vec<f64> = (0..90).map(|i| i as f64).collect();
        let mut want = vec![0.0; 90];
        a.spmv_into_zeroed(&x, &mut want);
        let mut e = ColorfulEngine::with_coloring(a, 3, colors);
        let mut y = vec![0.0; 90];
        e.spmv(&x, &mut y);
        propcheck::assert_close(&y, &want, 1e-11, 1e-11).unwrap();
    }

    #[test]
    fn class_shares_cover_class() {
        let a = mat(70, 3, 63);
        let e = ColorfulEngine::new(a, 4);
        for (class, share) in e.colors.classes.iter().zip(&e.shares) {
            assert_eq!(share[0].0, 0);
            assert_eq!(share.last().unwrap().1, class.len());
            for w in share.windows(2) {
                assert_eq!(w[0].1, w[1].0, "gap in class share");
            }
        }
    }

    #[test]
    fn property_colorful_vs_sequential() {
        propcheck::check(8, |rng| {
            let n = 10 + rng.below(100);
            let coo = Coo::random_structurally_symmetric(n, 1 + rng.below(5), false, rng);
            let a = Arc::new(Csrc::from_coo(&coo).map_err(|e| e.to_string())?);
            let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let mut want = vec![0.0; n];
            a.spmv_into_zeroed(&x, &mut want);
            let mut e = ColorfulEngine::new(a, 1 + rng.below(5));
            let mut y = vec![0.0; n];
            e.spmv(&x, &mut y);
            propcheck::assert_close(&y, &want, 1e-11, 1e-11)
        });
    }
}
