//! Shared-mutability primitives for the engines.
//!
//! The engines' phases have *provably disjoint* write sets (own buffer in
//! the compute phase; owned rows / owned intervals in the accumulation
//! phase; conflict-free rows inside a color class). Rust cannot see that
//! through `&[f64]`, so these two wrappers carry the unsafety with the
//! invariants documented at each use site.

use std::cell::UnsafeCell;

/// A slice multiple threads may write, with caller-guaranteed disjoint
/// index sets per thread.
pub struct SyncSlice<'a> {
    ptr: *mut f64,
    len: usize,
    _marker: std::marker::PhantomData<&'a mut [f64]>,
}

unsafe impl Send for SyncSlice<'_> {}
unsafe impl Sync for SyncSlice<'_> {}

impl<'a> SyncSlice<'a> {
    pub fn new(s: &'a mut [f64]) -> Self {
        Self { ptr: s.as_mut_ptr(), len: s.len(), _marker: std::marker::PhantomData }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// # Safety
    /// Caller must guarantee no concurrent access to index `i`.
    #[inline]
    pub unsafe fn write(&self, i: usize, v: f64) {
        debug_assert!(i < self.len);
        *self.ptr.add(i) = v;
    }

    /// Raw base pointer — for per-element writes that must not form a
    /// `&mut` over the whole (shared) slice. Same contract as the other
    /// accessors: disjoint index sets per thread.
    #[inline]
    pub fn as_mut_ptr(&self) -> *mut f64 {
        self.ptr
    }

    /// # Safety
    /// Caller must guarantee the range is not concurrently accessed.
    #[inline]
    pub unsafe fn slice_mut(&self, range: std::ops::Range<usize>) -> &mut [f64] {
        debug_assert!(range.end <= self.len);
        std::slice::from_raw_parts_mut(self.ptr.add(range.start), range.len())
    }
}

/// One private f64 buffer per thread, readable by all threads after the
/// compute-phase barrier.
pub struct SharedBuffers {
    bufs: Vec<UnsafeCell<Vec<f64>>>,
}

unsafe impl Send for SharedBuffers {}
unsafe impl Sync for SharedBuffers {}

impl SharedBuffers {
    pub fn new(p: usize, len: usize) -> Self {
        Self { bufs: (0..p).map(|_| UnsafeCell::new(vec![0.0; len])).collect() }
    }

    /// One buffer per window, each sized to its window only — the
    /// windowed local-buffers engine's backing store. `windows[t]` is
    /// thread t's effective range; `buf[t][i]` holds `y[windows[t].start
    /// + i]`.
    pub fn windowed(windows: &[std::ops::Range<usize>]) -> Self {
        Self {
            bufs: windows.iter().map(|r| UnsafeCell::new(vec![0.0; r.len()])).collect(),
        }
    }

    pub fn count(&self) -> usize {
        self.bufs.len()
    }

    /// Length of buffer `t` (its window length).
    pub fn len_of(&self, t: usize) -> usize {
        // Safe: len() reads only the Vec header, and rebuilding buffers
        // never happens after construction.
        unsafe { (*self.bufs[t].get()).len() }
    }

    /// Total f64 slots across all buffers.
    pub fn total_len(&self) -> usize {
        (0..self.count()).map(|t| self.len_of(t)).sum()
    }

    /// # Safety
    /// Only thread `t` may hold this mutably, and no concurrent `read`
    /// of buffer `t` may exist (enforced by the engines' phase barriers).
    #[inline]
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn get_mut(&self, t: usize) -> &mut [f64] {
        (*self.bufs[t].get()).as_mut_slice()
    }

    /// # Safety
    /// No concurrent `get_mut` of buffer `t` may exist.
    #[inline]
    pub unsafe fn read(&self, t: usize) -> &[f64] {
        (*self.bufs[t].get()).as_slice()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn sync_slice_disjoint_parallel_writes() {
        let mut v = vec![0.0; 100];
        let s = SyncSlice::new(&mut v);
        std::thread::scope(|scope| {
            let s = &s;
            for t in 0..4usize {
                scope.spawn(move || {
                    for i in (t * 25)..((t + 1) * 25) {
                        unsafe { s.write(i, t as f64) };
                    }
                });
            }
        });
        drop(s);
        for t in 0..4 {
            assert!(v[t * 25..(t + 1) * 25].iter().all(|&x| x == t as f64));
        }
    }

    #[test]
    fn shared_buffers_isolated_then_readable() {
        let bufs = Arc::new(SharedBuffers::new(3, 10));
        let handles: Vec<_> = (0..3)
            .map(|t| {
                let b = bufs.clone();
                std::thread::spawn(move || {
                    let mine = unsafe { b.get_mut(t) };
                    mine.fill(t as f64 + 1.0);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        for t in 0..3 {
            assert!(unsafe { bufs.read(t) }.iter().all(|&x| x == t as f64 + 1.0));
        }
    }

    #[test]
    fn slice_mut_range_view() {
        let mut v = vec![1.0; 8];
        {
            let s = SyncSlice::new(&mut v);
            unsafe {
                s.slice_mut(2..5).fill(9.0);
            }
        }
        assert_eq!(v, vec![1.0, 1.0, 9.0, 9.0, 9.0, 1.0, 1.0, 1.0]);
    }
}
