//! Local-buffers strategy (§3.1): each thread scatters into a private
//! buffer; buffers are merged into y in an accumulation step. The four
//! init/accumulation schemes of the paper:
//!
//! | method     | init                                | accumulation                                   | span (paper) |
//! |------------|-------------------------------------|------------------------------------------------|--------------|
//! | all-in-one | whole team's buffers, in parallel   | y rows split evenly; sum all p buffers         | Θ(p + log n) |
//! | per-buffer | buffer-by-buffer, parallel within   | buffer-by-buffer, parallel within              | Θ(p log n)   |
//! | effective  | own buffer over own effective range | own *owned rows*, buffers covering them        | Θ(p log(n/p))|
//! | interval   | intervals of intersected eff ranges | intervals, assigned load-balanced              | Θ(p log(n/p))|
//!
//! Partitioning is nnz-guided (§3.1 last paragraph). With one thread the
//! engine bypasses buffers entirely (the paper's runtime check).

use super::pool::ThreadPool;
use super::share::{SharedBuffers, SyncSlice};
use super::ParallelSpmv;
use crate::partition::{self, Interval, RowPartition};
use crate::sparse::Csrc;
use std::ops::Range;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AccumMethod {
    AllInOne,
    PerBuffer,
    Effective,
    Interval,
}

impl AccumMethod {
    pub fn label(&self) -> &'static str {
        match self {
            AccumMethod::AllInOne => "all-in-one",
            AccumMethod::PerBuffer => "per-buffer",
            AccumMethod::Effective => "effective",
            AccumMethod::Interval => "interval",
        }
    }

    pub fn all() -> [AccumMethod; 4] {
        [
            AccumMethod::AllInOne,
            AccumMethod::PerBuffer,
            AccumMethod::Effective,
            AccumMethod::Interval,
        ]
    }
}

pub struct LocalBuffersEngine {
    a: Arc<Csrc>,
    pool: ThreadPool,
    method: AccumMethod,
    part: RowPartition,
    /// Effective range per thread (§3.1).
    eff: Vec<Range<usize>>,
    /// Interval decomposition + per-thread assignment (interval method).
    ints: Vec<Interval>,
    int_assign: Vec<Vec<usize>>,
    bufs: SharedBuffers,
    /// Buffers covering each owned block (effective method): for thread
    /// t's owned rows, which buffers' effective ranges intersect them.
    covering: Vec<Vec<usize>>,
    /// Nanoseconds of the slowest thread's init+accumulate work in the
    /// last call — the Table 2 measurement.
    pub last_overhead_ns: u64,
}

impl LocalBuffersEngine {
    pub fn new(a: Arc<Csrc>, p: usize, method: AccumMethod) -> Self {
        let part = partition::nnz_balanced(&a, p);
        let eff: Vec<Range<usize>> =
            (0..p).map(|t| partition::effective_range(&a, part.block(t))).collect();
        let ints = partition::intervals(&eff);
        let int_assign = partition::assign_intervals(&ints, p);
        let covering = (0..p)
            .map(|t| {
                let own = part.block(t);
                (0..p)
                    .filter(|&b| eff[b].start < own.end && own.start < eff[b].end)
                    .collect()
            })
            .collect();
        let bufs = SharedBuffers::new(p, a.n);
        LocalBuffersEngine {
            a,
            pool: ThreadPool::new(p),
            method,
            part,
            eff,
            ints,
            int_assign,
            bufs,
            covering,
            last_overhead_ns: 0,
        }
    }

    pub fn method(&self) -> AccumMethod {
        self.method
    }

    pub fn partition(&self) -> &RowPartition {
        &self.part
    }

    pub fn effective_ranges(&self) -> &[Range<usize>] {
        &self.eff
    }
}

impl ParallelSpmv for LocalBuffersEngine {
    fn spmv(&mut self, x: &[f64], y: &mut [f64]) {
        let p = self.pool.nthreads();
        let n = self.a.n;
        debug_assert_eq!(x.len(), n);
        debug_assert_eq!(y.len(), n);

        // Single-thread shortcut (§4.2): use the global vector directly.
        if p == 1 {
            self.a.spmv_into_zeroed(x, y);
            self.last_overhead_ns = 0;
            return;
        }

        let a = &self.a;
        let part = &self.part;
        let eff = &self.eff;
        let ints = &self.ints;
        let int_assign = &self.int_assign;
        let covering = &self.covering;
        let bufs = &self.bufs;
        let method = self.method;
        let barrier = self.pool.barrier();
        let yv = SyncSlice::new(y);
        let max_overhead = AtomicU64::new(0);
        let ov = &max_overhead;

        self.pool.run(move |t| {
            let mut overhead_ns = 0u64;

            // ---- init step -------------------------------------------
            let t0 = Instant::now();
            match method {
                AccumMethod::AllInOne => {
                    // The team's p buffers seen as one dense p*n array,
                    // split evenly among threads.
                    let total = p * n;
                    let (lo, hi) = (t * total / p, (t + 1) * total / p);
                    let mut i = lo;
                    while i < hi {
                        let b = i / n;
                        let off = i % n;
                        let run = (hi - i).min(n - off);
                        // SAFETY: [b][off..off+run] touched by this thread
                        // only — the flat split is disjoint.
                        unsafe { bufs.get_mut(b)[off..off + run].fill(0.0) };
                        i += run;
                    }
                }
                AccumMethod::PerBuffer => {
                    // Buffer-by-buffer, rows split among threads.
                    for b in 0..p {
                        let (lo, hi) = (t * n / p, (t + 1) * n / p);
                        unsafe { bufs.get_mut(b)[lo..hi].fill(0.0) };
                    }
                }
                AccumMethod::Effective => {
                    // Own buffer, own effective range only.
                    let r = eff[t].clone();
                    unsafe { bufs.get_mut(t)[r].fill(0.0) };
                }
                AccumMethod::Interval => {
                    // Assigned intervals, every covering buffer.
                    for &i in &int_assign[t] {
                        let int = &ints[i];
                        for &b in &int.covers {
                            unsafe { bufs.get_mut(b)[int.range.clone()].fill(0.0) };
                        }
                    }
                }
            }
            overhead_ns += t0.elapsed().as_nanos() as u64;
            barrier.wait();

            // ---- compute step: private buffer, no races ---------------
            let block = part.block(t);
            // SAFETY: buffer t is written by thread t only in this phase.
            let buf = unsafe { bufs.get_mut(t) };
            a.spmv_rows_into(x, block.start, block.end, buf, 0);
            barrier.wait();

            // ---- accumulation step ------------------------------------
            let t1 = Instant::now();
            match method {
                AccumMethod::AllInOne => {
                    // y rows split evenly; each thread sums all p buffers.
                    let (lo, hi) = (t * n / p, (t + 1) * n / p);
                    // SAFETY: [lo,hi) disjoint per thread.
                    let dst = unsafe { yv.slice_mut(lo..hi) };
                    dst.fill(0.0);
                    for b in 0..p {
                        let src = unsafe { bufs.read(b) };
                        for (d, s) in dst.iter_mut().zip(&src[lo..hi]) {
                            *d += *s;
                        }
                    }
                }
                AccumMethod::PerBuffer => {
                    let (lo, hi) = (t * n / p, (t + 1) * n / p);
                    let dst = unsafe { yv.slice_mut(lo..hi) };
                    dst.fill(0.0);
                    for b in 0..p {
                        let src = unsafe { bufs.read(b) };
                        for (d, s) in dst.iter_mut().zip(&src[lo..hi]) {
                            *d += *s;
                        }
                        // The paper's per-buffer scheme synchronizes the
                        // team between buffers (span Θ(p log n)).
                        barrier.wait();
                    }
                }
                AccumMethod::Effective => {
                    // Own block rows; only buffers whose effective range
                    // covers them contribute.
                    let own = part.block(t);
                    let dst = unsafe { yv.slice_mut(own.clone()) };
                    dst.fill(0.0);
                    for &b in &covering[t] {
                        let src = unsafe { bufs.read(b) };
                        let from = own.start.max(eff[b].start);
                        let to = own.end.min(eff[b].end);
                        for i in from..to {
                            dst[i - own.start] += src[i];
                        }
                    }
                }
                AccumMethod::Interval => {
                    for &idx in &int_assign[t] {
                        let int = &ints[idx];
                        let dst = unsafe { yv.slice_mut(int.range.clone()) };
                        dst.fill(0.0);
                        for &b in &int.covers {
                            let src = unsafe { bufs.read(b) };
                            for (d, s) in dst.iter_mut().zip(&src[int.range.clone()]) {
                                *d += *s;
                            }
                        }
                    }
                }
            }
            overhead_ns += t1.elapsed().as_nanos() as u64;
            ov.fetch_max(overhead_ns, Ordering::Relaxed);
        });

        self.last_overhead_ns = max_overhead.load(Ordering::Relaxed);
    }

    fn name(&self) -> String {
        format!("local-buffers/{}", self.method.label())
    }

    fn nthreads(&self) -> usize {
        self.pool.nthreads()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::Coo;
    use crate::util::{propcheck, Rng};

    fn mat(n: usize, npr: usize, seed: u64) -> Arc<Csrc> {
        let mut rng = Rng::new(seed);
        Arc::new(Csrc::from_coo(&Coo::random_structurally_symmetric(n, npr, false, &mut rng)).unwrap())
    }

    #[test]
    fn every_method_matches_sequential() {
        let a = mat(120, 5, 50);
        let x: Vec<f64> = (0..120).map(|i| (i as f64).sin()).collect();
        let mut want = vec![0.0; 120];
        a.spmv_into_zeroed(&x, &mut want);
        for method in AccumMethod::all() {
            for p in [2, 3, 4, 6] {
                let mut e = LocalBuffersEngine::new(a.clone(), p, method);
                let mut y = vec![f64::NAN; 120];
                e.spmv(&x, &mut y);
                propcheck::assert_close(&y, &want, 1e-11, 1e-11)
                    .unwrap_or_else(|err| panic!("{} p={p}: {err}", method.label()));
            }
        }
    }

    #[test]
    fn single_thread_shortcut_no_overhead() {
        let a = mat(40, 3, 51);
        let x = vec![1.0; 40];
        let mut e = LocalBuffersEngine::new(a.clone(), 1, AccumMethod::AllInOne);
        let mut y = vec![0.0; 40];
        e.spmv(&x, &mut y);
        assert_eq!(e.last_overhead_ns, 0);
    }

    #[test]
    fn overhead_is_recorded_for_multithread() {
        let a = mat(400, 6, 52);
        let x = vec![1.0; 400];
        let mut e = LocalBuffersEngine::new(a.clone(), 4, AccumMethod::AllInOne);
        let mut y = vec![0.0; 400];
        e.spmv(&x, &mut y);
        assert!(e.last_overhead_ns > 0);
    }

    #[test]
    fn effective_covering_is_complete() {
        // Whoever covers thread t's rows must include t itself.
        let a = mat(100, 4, 53);
        let e = LocalBuffersEngine::new(a, 4, AccumMethod::Effective);
        for t in 0..4 {
            assert!(e.covering[t].contains(&t));
        }
    }

    #[test]
    fn works_on_banded_and_dense_patterns() {
        let mut rng = Rng::new(54);
        for coo in [
            Coo::banded(90, 1, true, &mut rng),
            Coo::banded(90, 8, false, &mut rng),
            Coo::dense_random(48, &mut rng),
        ] {
            let a = Arc::new(Csrc::from_coo(&coo).unwrap());
            let n = a.n;
            let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let mut want = vec![0.0; n];
            a.spmv_into_zeroed(&x, &mut want);
            for method in AccumMethod::all() {
                let mut e = LocalBuffersEngine::new(a.clone(), 3, method);
                let mut y = vec![0.0; n];
                e.spmv(&x, &mut y);
                propcheck::assert_close(&y, &want, 1e-10, 1e-10).unwrap();
            }
        }
    }
}
