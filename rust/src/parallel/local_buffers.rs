//! Local-buffers executor (§3.1): each thread scatters into a private
//! buffer; buffers are merged into y in an accumulation step. The four
//! init/accumulation schemes of the paper:
//!
//! | method     | init                                | accumulation                                   | span (paper) |
//! |------------|-------------------------------------|------------------------------------------------|--------------|
//! | all-in-one | whole team's buffers, in parallel   | y rows split evenly; sum all p buffers         | Θ(p + log n) |
//! | per-buffer | buffer-by-buffer, parallel within   | buffer-by-buffer, parallel within              | Θ(p log n)   |
//! | effective  | own buffer over own effective range | own *owned rows*, buffers covering them        | Θ(p log(n/p))|
//! | interval   | intervals of intersected eff ranges | intervals, assigned load-balanced              | Θ(p log(n/p))|
//!
//! **Windowed buffers.** Thread t only ever writes
//! `[eff[t].start, block(t).end)` — its effective range — so its private
//! buffer is allocated over exactly that window (`buf[t][i]` holds
//! `y[win[t].start + i]`, plumbed through the kernel's `lo` offset)
//! instead of a full-length copy of y. Every init/accumulation path
//! indexes windowed buffers, so the bytes allocated, zeroed, swept and
//! summed shrink from `p·n` to `Σ_t |eff[t]|`. Symmetric SpMV is
//! bandwidth-bound (arXiv:0910.4836, arXiv:1907.06487): those bytes are
//! the cost of the local-buffers strategy, and RCM reordering
//! ([`crate::reorder`]) is what makes the windows tight — a banded
//! matrix has `Σ|eff| ≈ n + p·hbw ≪ p·n`. The full-length layout
//! survives behind [`LocalBuffersEngine::with_plan_windowed`] as the
//! ablation baseline (`benches/ablations.rs` windowed-vs-full).
//!
//! All analysis (nnz-guided partition, effective ranges, interval
//! decomposition) lives in the borrowed [`SpmvPlan`]; this type holds
//! only execution state — the thread pool and the scatter buffers — and
//! sweeps whatever [`SpmvKernel`] it was built over. With one thread the
//! engine bypasses buffers entirely (the paper's runtime check).

use super::pool::ThreadPool;
use super::share::{SharedBuffers, SyncSlice};
use super::ParallelSpmv;
use crate::obs::{self, Phase};
use crate::plan::{PlanBuilder, SpmvPlan};
use crate::sparse::SpmvKernel;
use std::ops::Range;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AccumMethod {
    AllInOne,
    PerBuffer,
    Effective,
    Interval,
}

impl AccumMethod {
    pub fn label(&self) -> &'static str {
        match self {
            AccumMethod::AllInOne => "all-in-one",
            AccumMethod::PerBuffer => "per-buffer",
            AccumMethod::Effective => "effective",
            AccumMethod::Interval => "interval",
        }
    }

    pub fn all() -> [AccumMethod; 4] {
        [
            AccumMethod::AllInOne,
            AccumMethod::PerBuffer,
            AccumMethod::Effective,
            AccumMethod::Interval,
        ]
    }
}

pub struct LocalBuffersEngine {
    kernel: Arc<dyn SpmvKernel>,
    plan: Arc<SpmvPlan>,
    pool: ThreadPool,
    method: AccumMethod,
    bufs: SharedBuffers,
    /// Per-thread buffer windows: `bufs[t][i]` holds `y[win[t].start + i]`.
    /// Windowed engines use the plan's effective ranges; the full-length
    /// baseline (and plans without the `ranges` piece) use `0..n`.
    win: Vec<Range<usize>>,
    /// Prefix sums of window lengths (`flat[t]` = slots before buffer t;
    /// `flat[p]` = total slots) — the all-in-one flat init split.
    flat: Vec<usize>,
    /// Lazily-built k-wide scatter buffers for the multi-vector path:
    /// the same windows widened to `|win[t]|·k` slots (`Σ|eff[t]|·k`
    /// total). Cached per k and rebuilt only when k changes, so a
    /// service coalescing at a steady block size allocates once.
    multi: Option<(usize, SharedBuffers)>,
    /// Nanoseconds of the slowest thread's init+accumulate work in the
    /// last call — the Table 2 measurement.
    pub last_overhead_ns: u64,
}

impl LocalBuffersEngine {
    /// Analyze-and-build convenience (single-use plan). Shared-plan
    /// callers use [`LocalBuffersEngine::with_plan`] /
    /// [`super::build_engine`].
    pub fn new(kernel: Arc<dyn SpmvKernel>, p: usize, method: AccumMethod) -> Self {
        let plan = Arc::new(
            PlanBuilder::for_kind(p, super::EngineKind::LocalBuffers(method))
                .build(kernel.as_ref()),
        );
        Self::with_plan(kernel, plan, method)
    }

    /// Build over a shared plan with windowed buffers (the default). The
    /// plan must carry the pieces `method` needs (`ranges` for
    /// effective, `intervals` for interval).
    pub fn with_plan(
        kernel: Arc<dyn SpmvKernel>,
        plan: Arc<SpmvPlan>,
        method: AccumMethod,
    ) -> Self {
        Self::with_plan_windowed(kernel, plan, method, true)
    }

    /// [`LocalBuffersEngine::with_plan`] with the buffer layout made
    /// explicit: `windowed = false` allocates the pre-windowing
    /// full-length buffers (one n-sized copy of y per thread) — kept as
    /// the measured baseline for the windowed-vs-full ablation.
    pub fn with_plan_windowed(
        kernel: Arc<dyn SpmvKernel>,
        plan: Arc<SpmvPlan>,
        method: AccumMethod,
        windowed: bool,
    ) -> Self {
        let n = kernel.dim();
        assert_eq!(plan.n, n, "plan built for a different matrix");
        match method {
            AccumMethod::Effective => {
                assert!(plan.eff.is_some(), "effective method needs plan ranges")
            }
            AccumMethod::Interval => {
                assert!(plan.ints.is_some(), "interval method needs plan intervals")
            }
            _ => {}
        }
        let p = plan.nthreads;
        // Window = effective range (eff[t].end == block(t).end by plan
        // invariant); plans without ranges fall back to full-length.
        let win: Vec<Range<usize>> = match (&plan.eff, windowed) {
            (Some(eff), true) => eff.clone(),
            _ => (0..p).map(|_| 0..n).collect(),
        };
        let mut flat = Vec::with_capacity(p + 1);
        let mut total = 0usize;
        flat.push(0usize);
        for r in &win {
            total += r.len();
            flat.push(total);
        }
        let bufs = SharedBuffers::windowed(&win);
        LocalBuffersEngine {
            kernel,
            plan,
            pool: ThreadPool::new(p),
            method,
            bufs,
            win,
            flat,
            multi: None,
            last_overhead_ns: 0,
        }
    }

    pub fn method(&self) -> AccumMethod {
        self.method
    }

    pub fn effective_ranges(&self) -> Option<&[Range<usize>]> {
        self.plan.eff.as_deref()
    }

    /// The per-thread buffer windows actually allocated.
    pub fn windows(&self) -> &[Range<usize>] {
        &self.win
    }

    /// Bytes of private scatter-buffer backing this engine. Windowed
    /// engines hold `Σ_t |win[t]| · 8`; the full-length baseline holds
    /// `p·n·8`.
    pub fn buffer_bytes(&self) -> usize {
        *self.flat.last().unwrap() * 8
    }

    /// What the pre-windowing layout would allocate: `p·n·8`.
    pub fn full_buffer_bytes(&self) -> usize {
        self.plan.nthreads * self.plan.n * 8
    }

    /// Bytes the k-wide multi-vector path backs: the same windows
    /// widened to `Σ_t |win[t]| · k · 8` (the windowed-buffer widening
    /// math of DESIGN.md §11).
    pub fn buffer_bytes_multi(&self, k: usize) -> usize {
        self.buffer_bytes() * k
    }

    /// Buffer bytes the init step zeroes per product under this
    /// engine's method and layout (the Table 2 cost the windows shrink).
    pub fn bytes_zeroed_per_product(&self) -> usize {
        if self.pool.nthreads() == 1 {
            return 0; // single-thread shortcut: no buffers at all
        }
        match self.method {
            // Whole buffers, so exactly the allocated slots.
            AccumMethod::AllInOne | AccumMethod::PerBuffer => self.buffer_bytes(),
            // Own effective range only (identical in both layouts).
            AccumMethod::Effective => self
                .plan
                .eff
                .as_ref()
                .map(|eff| eff.iter().map(|r| r.len()).sum::<usize>() * 8)
                .unwrap_or_else(|| self.buffer_bytes()),
            // Each interval zeroed once per covering buffer.
            AccumMethod::Interval => self
                .plan
                .ints
                .as_ref()
                .map(|ints| {
                    ints.iter().map(|i| i.range.len() * i.covers.len()).sum::<usize>() * 8
                })
                .unwrap_or_else(|| self.buffer_bytes()),
        }
    }

    /// Buffer bytes the accumulation step reads per product.
    pub fn bytes_accumulated_per_product(&self) -> usize {
        if self.pool.nthreads() == 1 {
            return 0;
        }
        match self.method {
            // Every buffer summed over its (window ∩ y-split) extent.
            AccumMethod::AllInOne | AccumMethod::PerBuffer => self.buffer_bytes(),
            // Covering buffers over owned rows / intervals: one read per
            // (row × covering buffer) = Σ |eff| either way.
            AccumMethod::Effective | AccumMethod::Interval => self
                .plan
                .eff
                .as_ref()
                .map(|eff| eff.iter().map(|r| r.len()).sum::<usize>() * 8)
                .unwrap_or_else(|| self.buffer_bytes()),
        }
    }
}

impl ParallelSpmv for LocalBuffersEngine {
    fn spmv(&mut self, x: &[f64], y: &mut [f64]) {
        let p = self.pool.nthreads();
        let n = self.plan.n;
        debug_assert_eq!(x.len(), n);
        debug_assert_eq!(y.len(), n);

        // Single-thread shortcut (§4.2): use the global vector directly.
        if p == 1 {
            let _sweep_span = obs::phase(Phase::Sweep);
            self.kernel.sweep_full(x, y);
            self.last_overhead_ns = 0;
            return;
        }

        let kernel = &*self.kernel;
        let plan = &*self.plan;
        let part = &plan.part;
        let eff: &[Range<usize>] = plan.eff.as_deref().unwrap_or(&[]);
        let covering: &[Vec<usize>] = plan.covering.as_deref().unwrap_or(&[]);
        let ints: &[crate::partition::Interval] = plan.ints.as_deref().unwrap_or(&[]);
        let int_assign: &[Vec<usize>] = plan.int_assign.as_deref().unwrap_or(&[]);
        let bufs = &self.bufs;
        let win: &[Range<usize>] = &self.win;
        let flat: &[usize] = &self.flat;
        let method = self.method;
        let barrier = self.pool.barrier();
        let yv = SyncSlice::new(y);
        let max_overhead = AtomicU64::new(0);
        let ov = &max_overhead;

        self.pool.run(move |t| {
            let mut overhead_ns = 0u64;

            // ---- init step -------------------------------------------
            let zero_span = obs::phase(Phase::Zero);
            let t0 = Instant::now();
            match method {
                AccumMethod::AllInOne => {
                    // The team's buffers seen as one dense flat array of
                    // `flat[p]` window slots, split evenly among threads.
                    let total = flat[p];
                    let (glo, ghi) = (t * total / p, (t + 1) * total / p);
                    for b in 0..p {
                        let (bs, be) = (flat[b], flat[b + 1]);
                        let lo = glo.max(bs);
                        let hi = ghi.min(be);
                        if lo < hi {
                            // SAFETY: the flat split is disjoint across
                            // threads, so [lo-bs, hi-bs) of buffer b is
                            // touched by this thread only.
                            unsafe { bufs.get_mut(b)[lo - bs..hi - bs].fill(0.0) };
                        }
                    }
                }
                AccumMethod::PerBuffer => {
                    // Buffer-by-buffer, each window split among threads.
                    for b in 0..p {
                        let len_b = win[b].len();
                        let (lo, hi) = (t * len_b / p, (t + 1) * len_b / p);
                        // SAFETY: [lo,hi) disjoint per thread within b.
                        unsafe { bufs.get_mut(b)[lo..hi].fill(0.0) };
                    }
                }
                AccumMethod::Effective => {
                    // Own buffer, own effective range only (the whole
                    // window when windowed).
                    let r = eff[t].clone();
                    let off = win[t].start;
                    // SAFETY: buffer t touched by thread t only here.
                    unsafe { bufs.get_mut(t)[r.start - off..r.end - off].fill(0.0) };
                }
                AccumMethod::Interval => {
                    // Assigned intervals, every covering buffer. An
                    // interval is ⊆ eff[b] ⊆ win[b] for each b it covers.
                    for &i in &int_assign[t] {
                        let int = &ints[i];
                        for &b in &int.covers {
                            let off = win[b].start;
                            // SAFETY: intervals are disjoint and each is
                            // assigned to exactly one thread.
                            unsafe {
                                bufs.get_mut(b)[int.range.start - off..int.range.end - off]
                                    .fill(0.0)
                            };
                        }
                    }
                }
            }
            overhead_ns += t0.elapsed().as_nanos() as u64;
            drop(zero_span);
            barrier.wait();

            // ---- compute step: private windowed buffer, no races ------
            let sweep_span = obs::phase(Phase::Sweep);
            let block = part.block(t);
            // SAFETY: buffer t is written by thread t only in this phase.
            let buf = unsafe { bufs.get_mut(t) };
            // The window offset is the kernel's `lo`: scatters land at
            // `buf[j - win[t].start]`, and every write of the block sits
            // in [eff[t].start, block.end) ⊆ win[t] by plan invariant.
            kernel.sweep_rows_into(x, block.start, block.end, buf, win[t].start);
            drop(sweep_span);
            barrier.wait();

            // ---- accumulation step ------------------------------------
            let accum_span = obs::phase(Phase::Accumulate);
            let t1 = Instant::now();
            match method {
                AccumMethod::AllInOne => {
                    // y rows split evenly; each thread sums the buffers
                    // whose window overlaps its rows.
                    let (lo, hi) = (t * n / p, (t + 1) * n / p);
                    // SAFETY: [lo,hi) disjoint per thread.
                    let dst = unsafe { yv.slice_mut(lo..hi) };
                    dst.fill(0.0);
                    for b in 0..p {
                        let from = lo.max(win[b].start);
                        let to = hi.min(win[b].end);
                        if from < to {
                            let src = unsafe { bufs.read(b) };
                            let off = win[b].start;
                            // Slice-zip keeps the loop bounds-check-free.
                            for (d, s) in
                                dst[from - lo..to - lo].iter_mut().zip(&src[from - off..to - off])
                            {
                                *d += *s;
                            }
                        }
                    }
                }
                AccumMethod::PerBuffer => {
                    let (lo, hi) = (t * n / p, (t + 1) * n / p);
                    let dst = unsafe { yv.slice_mut(lo..hi) };
                    dst.fill(0.0);
                    for b in 0..p {
                        let from = lo.max(win[b].start);
                        let to = hi.min(win[b].end);
                        if from < to {
                            let src = unsafe { bufs.read(b) };
                            let off = win[b].start;
                            for (d, s) in
                                dst[from - lo..to - lo].iter_mut().zip(&src[from - off..to - off])
                            {
                                *d += *s;
                            }
                        }
                        // The paper's per-buffer scheme synchronizes the
                        // team between buffers (span Θ(p log n)); the
                        // barrier count must match across threads, so it
                        // sits outside the overlap check.
                        barrier.wait();
                    }
                }
                AccumMethod::Effective => {
                    // Own block rows; only buffers whose effective range
                    // covers them contribute.
                    let own = part.block(t);
                    let dst = unsafe { yv.slice_mut(own.clone()) };
                    dst.fill(0.0);
                    for &b in &covering[t] {
                        let src = unsafe { bufs.read(b) };
                        let from = own.start.max(eff[b].start);
                        let to = own.end.min(eff[b].end);
                        let off = win[b].start;
                        for (d, s) in dst[from - own.start..to - own.start]
                            .iter_mut()
                            .zip(&src[from - off..to - off])
                        {
                            *d += *s;
                        }
                    }
                }
                AccumMethod::Interval => {
                    for &idx in &int_assign[t] {
                        let int = &ints[idx];
                        let dst = unsafe { yv.slice_mut(int.range.clone()) };
                        dst.fill(0.0);
                        for &b in &int.covers {
                            let src = unsafe { bufs.read(b) };
                            let off = win[b].start;
                            let s = &src[int.range.start - off..int.range.end - off];
                            for (d, v) in dst.iter_mut().zip(s) {
                                *d += *v;
                            }
                        }
                    }
                }
            }
            overhead_ns += t1.elapsed().as_nanos() as u64;
            drop(accum_span);
            ov.fetch_max(overhead_ns, Ordering::Relaxed);
        });

        self.last_overhead_ns = max_overhead.load(Ordering::Relaxed);
    }

    /// k-wide product through the same four init/compute/accumulate
    /// schemes, with every window boundary scaled by k: buffer b holds
    /// `|win[b]|·k` slots and slot `(j - win[b].start)·k + c` is column
    /// c of `y_j`. The buffers are rebuilt only when k changes.
    fn spmv_multi(&mut self, x: &[f64], y: &mut [f64], k: usize) {
        assert!(k >= 1);
        if k == 1 {
            return self.spmv(x, y);
        }
        let p = self.pool.nthreads();
        let n = self.plan.n;
        debug_assert_eq!(x.len(), n * k);
        debug_assert_eq!(y.len(), n * k);

        if p == 1 {
            let _sweep_span = obs::phase(Phase::Sweep);
            self.kernel.sweep_full_multi(x, y, k);
            self.last_overhead_ns = 0;
            return;
        }

        // Lazily (re)build the k-wide windowed buffers.
        if self.multi.as_ref().map(|(mk, _)| *mk) != Some(k) {
            let scaled: Vec<Range<usize>> =
                self.win.iter().map(|r| r.start * k..r.end * k).collect();
            self.multi = Some((k, SharedBuffers::windowed(&scaled)));
        }

        let kernel = &*self.kernel;
        let plan = &*self.plan;
        let part = &plan.part;
        let eff: &[Range<usize>] = plan.eff.as_deref().unwrap_or(&[]);
        let covering: &[Vec<usize>] = plan.covering.as_deref().unwrap_or(&[]);
        let ints: &[crate::partition::Interval] = plan.ints.as_deref().unwrap_or(&[]);
        let int_assign: &[Vec<usize>] = plan.int_assign.as_deref().unwrap_or(&[]);
        let bufs = &self.multi.as_ref().expect("built above").1;
        let win: &[Range<usize>] = &self.win;
        let flat: &[usize] = &self.flat;
        let method = self.method;
        let barrier = self.pool.barrier();
        let yv = SyncSlice::new(y);
        let max_overhead = AtomicU64::new(0);
        let ov = &max_overhead;

        self.pool.run(move |t| {
            let mut overhead_ns = 0u64;

            // ---- init step: same splits as spmv(), scaled by k --------
            let zero_span = obs::phase(Phase::Zero);
            let t0 = Instant::now();
            match method {
                AccumMethod::AllInOne => {
                    let total = flat[p] * k;
                    let (glo, ghi) = (t * total / p, (t + 1) * total / p);
                    for b in 0..p {
                        let (bs, be) = (flat[b] * k, flat[b + 1] * k);
                        let lo = glo.max(bs);
                        let hi = ghi.min(be);
                        if lo < hi {
                            // SAFETY: the flat split is disjoint across
                            // threads (see spmv).
                            unsafe { bufs.get_mut(b)[lo - bs..hi - bs].fill(0.0) };
                        }
                    }
                }
                AccumMethod::PerBuffer => {
                    for b in 0..p {
                        let len_b = win[b].len() * k;
                        let (lo, hi) = (t * len_b / p, (t + 1) * len_b / p);
                        // SAFETY: [lo,hi) disjoint per thread within b.
                        unsafe { bufs.get_mut(b)[lo..hi].fill(0.0) };
                    }
                }
                AccumMethod::Effective => {
                    let r = eff[t].clone();
                    let off = win[t].start;
                    // SAFETY: buffer t touched by thread t only here.
                    unsafe {
                        bufs.get_mut(t)[(r.start - off) * k..(r.end - off) * k].fill(0.0)
                    };
                }
                AccumMethod::Interval => {
                    for &i in &int_assign[t] {
                        let int = &ints[i];
                        for &b in &int.covers {
                            let off = win[b].start;
                            // SAFETY: intervals are disjoint and each is
                            // assigned to exactly one thread.
                            unsafe {
                                bufs.get_mut(b)
                                    [(int.range.start - off) * k..(int.range.end - off) * k]
                                    .fill(0.0)
                            };
                        }
                    }
                }
            }
            overhead_ns += t0.elapsed().as_nanos() as u64;
            drop(zero_span);
            barrier.wait();

            // ---- compute step: private k-wide windowed buffer ---------
            let sweep_span = obs::phase(Phase::Sweep);
            let block = part.block(t);
            // SAFETY: buffer t is written by thread t only in this phase.
            let buf = unsafe { bufs.get_mut(t) };
            kernel.sweep_rows_into_multi(x, k, block.start, block.end, buf, win[t].start);
            drop(sweep_span);
            barrier.wait();

            // ---- accumulation step: row windows scaled by k -----------
            let accum_span = obs::phase(Phase::Accumulate);
            let t1 = Instant::now();
            match method {
                AccumMethod::AllInOne => {
                    let (lo, hi) = (t * n / p, (t + 1) * n / p);
                    // SAFETY: row split [lo,hi) disjoint per thread.
                    let dst = unsafe { yv.slice_mut(lo * k..hi * k) };
                    dst.fill(0.0);
                    for b in 0..p {
                        let from = lo.max(win[b].start);
                        let to = hi.min(win[b].end);
                        if from < to {
                            let src = unsafe { bufs.read(b) };
                            let off = win[b].start;
                            for (d, s) in dst[(from - lo) * k..(to - lo) * k]
                                .iter_mut()
                                .zip(&src[(from - off) * k..(to - off) * k])
                            {
                                *d += *s;
                            }
                        }
                    }
                }
                AccumMethod::PerBuffer => {
                    let (lo, hi) = (t * n / p, (t + 1) * n / p);
                    let dst = unsafe { yv.slice_mut(lo * k..hi * k) };
                    dst.fill(0.0);
                    for b in 0..p {
                        let from = lo.max(win[b].start);
                        let to = hi.min(win[b].end);
                        if from < to {
                            let src = unsafe { bufs.read(b) };
                            let off = win[b].start;
                            for (d, s) in dst[(from - lo) * k..(to - lo) * k]
                                .iter_mut()
                                .zip(&src[(from - off) * k..(to - off) * k])
                            {
                                *d += *s;
                            }
                        }
                        barrier.wait();
                    }
                }
                AccumMethod::Effective => {
                    let own = part.block(t);
                    let dst = unsafe { yv.slice_mut(own.start * k..own.end * k) };
                    dst.fill(0.0);
                    for &b in &covering[t] {
                        let src = unsafe { bufs.read(b) };
                        let from = own.start.max(eff[b].start);
                        let to = own.end.min(eff[b].end);
                        let off = win[b].start;
                        for (d, s) in dst[(from - own.start) * k..(to - own.start) * k]
                            .iter_mut()
                            .zip(&src[(from - off) * k..(to - off) * k])
                        {
                            *d += *s;
                        }
                    }
                }
                AccumMethod::Interval => {
                    for &idx in &int_assign[t] {
                        let int = &ints[idx];
                        let dst =
                            unsafe { yv.slice_mut(int.range.start * k..int.range.end * k) };
                        dst.fill(0.0);
                        for &b in &int.covers {
                            let src = unsafe { bufs.read(b) };
                            let off = win[b].start;
                            let s = &src
                                [(int.range.start - off) * k..(int.range.end - off) * k];
                            for (d, v) in dst.iter_mut().zip(s) {
                                *d += *v;
                            }
                        }
                    }
                }
            }
            overhead_ns += t1.elapsed().as_nanos() as u64;
            drop(accum_span);
            ov.fetch_max(overhead_ns, Ordering::Relaxed);
        });

        self.last_overhead_ns = max_overhead.load(Ordering::Relaxed);
    }

    fn name(&self) -> String {
        format!("local-buffers/{}", self.method.label())
    }

    fn nthreads(&self) -> usize {
        self.pool.nthreads()
    }

    fn plan(&self) -> Option<&Arc<SpmvPlan>> {
        Some(&self.plan)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::{Coo, Csrc};
    use crate::util::{propcheck, Rng};

    fn mat(n: usize, npr: usize, seed: u64) -> Arc<Csrc> {
        let mut rng = Rng::new(seed);
        Arc::new(
            Csrc::from_coo(&Coo::random_structurally_symmetric(n, npr, false, &mut rng)).unwrap(),
        )
    }

    #[test]
    fn every_method_matches_sequential() {
        let a = mat(120, 5, 50);
        let x: Vec<f64> = (0..120).map(|i| (i as f64).sin()).collect();
        let mut want = vec![0.0; 120];
        a.spmv_into_zeroed(&x, &mut want);
        for method in AccumMethod::all() {
            for p in [2, 3, 4, 6] {
                let mut e = LocalBuffersEngine::new(a.clone(), p, method);
                let mut y = vec![f64::NAN; 120];
                e.spmv(&x, &mut y);
                propcheck::assert_close(&y, &want, 1e-11, 1e-11)
                    .unwrap_or_else(|err| panic!("{} p={p}: {err}", method.label()));
            }
        }
    }

    #[test]
    fn methods_share_one_full_plan() {
        let a = mat(100, 4, 55);
        let plan = Arc::new(PlanBuilder::all(4).build(a.as_ref()));
        let x: Vec<f64> = (0..100).map(|i| (i as f64 * 0.3).cos()).collect();
        let mut want = vec![0.0; 100];
        a.spmv_into_zeroed(&x, &mut want);
        for method in AccumMethod::all() {
            let mut e = LocalBuffersEngine::with_plan(a.clone(), plan.clone(), method);
            assert!(Arc::ptr_eq(e.plan().unwrap(), &plan));
            let mut y = vec![f64::NAN; 100];
            e.spmv(&x, &mut y);
            propcheck::assert_close(&y, &want, 1e-11, 1e-11).unwrap();
        }
    }

    /// The windowed layout and the full-length baseline must agree on
    /// every method (the windowed-vs-full ablation's correctness leg),
    /// while the windowed engine backs strictly fewer bytes on a banded
    /// matrix.
    #[test]
    fn windowed_matches_full_and_shrinks_bytes() {
        let mut rng = Rng::new(56);
        let a = Arc::new(Csrc::from_coo(&Coo::banded(240, 2, false, &mut rng)).unwrap());
        let plan = Arc::new(PlanBuilder::all(4).build(a.as_ref()));
        let x: Vec<f64> = (0..240).map(|i| (i as f64 * 0.05).sin()).collect();
        let mut want = vec![0.0; 240];
        a.spmv_into_zeroed(&x, &mut want);
        for method in AccumMethod::all() {
            let mut wdw = LocalBuffersEngine::with_plan(a.clone(), plan.clone(), method);
            let mut full =
                LocalBuffersEngine::with_plan_windowed(a.clone(), plan.clone(), method, false);
            let (mut y1, mut y2) = (vec![f64::NAN; 240], vec![f64::NAN; 240]);
            wdw.spmv(&x, &mut y1);
            full.spmv(&x, &mut y2);
            propcheck::assert_close(&y1, &want, 1e-11, 1e-11)
                .unwrap_or_else(|e| panic!("windowed {}: {e}", method.label()));
            propcheck::assert_close(&y2, &want, 1e-11, 1e-11)
                .unwrap_or_else(|e| panic!("full {}: {e}", method.label()));
            // A tight band keeps every effective range near its block:
            // the windows must be a small fraction of p·n.
            assert!(
                wdw.buffer_bytes() < full.buffer_bytes() / 2,
                "{}: windowed {} vs full {} bytes",
                method.label(),
                wdw.buffer_bytes(),
                full.buffer_bytes()
            );
            assert_eq!(full.buffer_bytes(), full.full_buffer_bytes());
            assert!(wdw.bytes_zeroed_per_product() <= full.bytes_zeroed_per_product());
            assert!(wdw.bytes_accumulated_per_product() <= full.bytes_accumulated_per_product());
            // All-in-one / per-buffer zero whole buffers: windowing must
            // strictly shrink what they touch.
            if matches!(method, AccumMethod::AllInOne | AccumMethod::PerBuffer) {
                assert!(wdw.bytes_zeroed_per_product() < full.bytes_zeroed_per_product());
            }
            // The windows are exactly the plan's effective ranges.
            assert_eq!(wdw.windows(), plan.eff.as_deref().unwrap());
        }
    }

    #[test]
    fn single_thread_shortcut_no_overhead() {
        let a = mat(40, 3, 51);
        let x = vec![1.0; 40];
        let mut e = LocalBuffersEngine::new(a.clone(), 1, AccumMethod::AllInOne);
        let mut y = vec![0.0; 40];
        e.spmv(&x, &mut y);
        assert_eq!(e.last_overhead_ns, 0);
        assert_eq!(e.bytes_zeroed_per_product(), 0);
    }

    #[test]
    fn overhead_is_recorded_for_multithread() {
        let a = mat(400, 6, 52);
        let x = vec![1.0; 400];
        let mut e = LocalBuffersEngine::new(a.clone(), 4, AccumMethod::AllInOne);
        let mut y = vec![0.0; 400];
        e.spmv(&x, &mut y);
        assert!(e.last_overhead_ns > 0);
    }

    #[test]
    fn effective_covering_is_complete() {
        // Whoever covers thread t's rows must include t itself.
        let a = mat(100, 4, 53);
        let e = LocalBuffersEngine::new(a, 4, AccumMethod::Effective);
        let covering = e.plan.covering.as_ref().unwrap();
        for t in 0..4 {
            assert!(covering[t].contains(&t));
        }
    }

    #[test]
    fn works_on_banded_and_dense_patterns() {
        let mut rng = Rng::new(54);
        for coo in [
            Coo::banded(90, 1, true, &mut rng),
            Coo::banded(90, 8, false, &mut rng),
            Coo::dense_random(48, &mut rng),
        ] {
            let a = Arc::new(Csrc::from_coo(&coo).unwrap());
            let n = a.n;
            let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let mut want = vec![0.0; n];
            a.spmv_into_zeroed(&x, &mut want);
            for method in AccumMethod::all() {
                let mut e = LocalBuffersEngine::new(a.clone(), 3, method);
                let mut y = vec![0.0; n];
                e.spmv(&x, &mut y);
                propcheck::assert_close(&y, &want, 1e-10, 1e-10).unwrap();
            }
        }
    }

    #[test]
    fn property_windowed_buffers_match_oracle() {
        // Random structurally-symmetric *and* banded patterns, every
        // method, random thread counts: the windowed engine must match
        // the sequential oracle bit-for-tolerance.
        propcheck::check(10, |rng| {
            let n = 16 + rng.below(120);
            let coo = if rng.below(2) == 0 {
                Coo::random_structurally_symmetric(n, 1 + rng.below(5), false, rng)
            } else {
                Coo::banded(n, 1 + rng.below(4), false, rng)
            };
            let a = Arc::new(Csrc::from_coo(&coo).map_err(|e| e.to_string())?);
            let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let mut want = vec![0.0; n];
            a.spmv_into_zeroed(&x, &mut want);
            let p = 2 + rng.below(5);
            for method in AccumMethod::all() {
                let mut e = LocalBuffersEngine::new(a.clone(), p, method);
                let mut y = vec![f64::NAN; n];
                e.spmv(&x, &mut y);
                propcheck::assert_close(&y, &want, 1e-10, 1e-10)
                    .map_err(|e| format!("{} p={p}: {e}", method.label()))?;
            }
            Ok(())
        });
    }
}
