//! Local-buffers executor (§3.1): each thread scatters into a private
//! buffer; buffers are merged into y in an accumulation step. The four
//! init/accumulation schemes of the paper:
//!
//! | method     | init                                | accumulation                                   | span (paper) |
//! |------------|-------------------------------------|------------------------------------------------|--------------|
//! | all-in-one | whole team's buffers, in parallel   | y rows split evenly; sum all p buffers         | Θ(p + log n) |
//! | per-buffer | buffer-by-buffer, parallel within   | buffer-by-buffer, parallel within              | Θ(p log n)   |
//! | effective  | own buffer over own effective range | own *owned rows*, buffers covering them        | Θ(p log(n/p))|
//! | interval   | intervals of intersected eff ranges | intervals, assigned load-balanced              | Θ(p log(n/p))|
//!
//! All analysis (nnz-guided partition, effective ranges, interval
//! decomposition) lives in the borrowed [`SpmvPlan`]; this type holds
//! only execution state — the thread pool and the scatter buffers — and
//! sweeps whatever [`SpmvKernel`] it was built over. With one thread the
//! engine bypasses buffers entirely (the paper's runtime check).

use super::pool::ThreadPool;
use super::share::{SharedBuffers, SyncSlice};
use super::ParallelSpmv;
use crate::plan::{PlanBuilder, SpmvPlan};
use crate::sparse::SpmvKernel;
use std::ops::Range;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AccumMethod {
    AllInOne,
    PerBuffer,
    Effective,
    Interval,
}

impl AccumMethod {
    pub fn label(&self) -> &'static str {
        match self {
            AccumMethod::AllInOne => "all-in-one",
            AccumMethod::PerBuffer => "per-buffer",
            AccumMethod::Effective => "effective",
            AccumMethod::Interval => "interval",
        }
    }

    pub fn all() -> [AccumMethod; 4] {
        [
            AccumMethod::AllInOne,
            AccumMethod::PerBuffer,
            AccumMethod::Effective,
            AccumMethod::Interval,
        ]
    }
}

pub struct LocalBuffersEngine {
    kernel: Arc<dyn SpmvKernel>,
    plan: Arc<SpmvPlan>,
    pool: ThreadPool,
    method: AccumMethod,
    bufs: SharedBuffers,
    /// Nanoseconds of the slowest thread's init+accumulate work in the
    /// last call — the Table 2 measurement.
    pub last_overhead_ns: u64,
}

impl LocalBuffersEngine {
    /// Analyze-and-build convenience (single-use plan). Shared-plan
    /// callers use [`LocalBuffersEngine::with_plan`] /
    /// [`super::build_engine`].
    pub fn new(kernel: Arc<dyn SpmvKernel>, p: usize, method: AccumMethod) -> Self {
        let plan = Arc::new(
            PlanBuilder::for_kind(p, super::EngineKind::LocalBuffers(method))
                .build(kernel.as_ref()),
        );
        Self::with_plan(kernel, plan, method)
    }

    /// Build over a shared plan. The plan must carry the pieces `method`
    /// needs (`ranges` for effective, `intervals` for interval).
    pub fn with_plan(
        kernel: Arc<dyn SpmvKernel>,
        plan: Arc<SpmvPlan>,
        method: AccumMethod,
    ) -> Self {
        let n = kernel.dim();
        assert_eq!(plan.n, n, "plan built for a different matrix");
        match method {
            AccumMethod::Effective => {
                assert!(plan.eff.is_some(), "effective method needs plan ranges")
            }
            AccumMethod::Interval => {
                assert!(plan.ints.is_some(), "interval method needs plan intervals")
            }
            _ => {}
        }
        let p = plan.nthreads;
        let bufs = SharedBuffers::new(p, n);
        LocalBuffersEngine {
            kernel,
            plan,
            pool: ThreadPool::new(p),
            method,
            bufs,
            last_overhead_ns: 0,
        }
    }

    pub fn method(&self) -> AccumMethod {
        self.method
    }

    pub fn effective_ranges(&self) -> Option<&[Range<usize>]> {
        self.plan.eff.as_deref()
    }
}

impl ParallelSpmv for LocalBuffersEngine {
    fn spmv(&mut self, x: &[f64], y: &mut [f64]) {
        let p = self.pool.nthreads();
        let n = self.plan.n;
        debug_assert_eq!(x.len(), n);
        debug_assert_eq!(y.len(), n);

        // Single-thread shortcut (§4.2): use the global vector directly.
        if p == 1 {
            self.kernel.sweep_full(x, y);
            self.last_overhead_ns = 0;
            return;
        }

        let kernel = &*self.kernel;
        let plan = &*self.plan;
        let part = &plan.part;
        let eff: &[Range<usize>] = plan.eff.as_deref().unwrap_or(&[]);
        let covering: &[Vec<usize>] = plan.covering.as_deref().unwrap_or(&[]);
        let ints: &[crate::partition::Interval] = plan.ints.as_deref().unwrap_or(&[]);
        let int_assign: &[Vec<usize>] = plan.int_assign.as_deref().unwrap_or(&[]);
        let bufs = &self.bufs;
        let method = self.method;
        let barrier = self.pool.barrier();
        let yv = SyncSlice::new(y);
        let max_overhead = AtomicU64::new(0);
        let ov = &max_overhead;

        self.pool.run(move |t| {
            let mut overhead_ns = 0u64;

            // ---- init step -------------------------------------------
            let t0 = Instant::now();
            match method {
                AccumMethod::AllInOne => {
                    // The team's p buffers seen as one dense p*n array,
                    // split evenly among threads.
                    let total = p * n;
                    let (lo, hi) = (t * total / p, (t + 1) * total / p);
                    let mut i = lo;
                    while i < hi {
                        let b = i / n;
                        let off = i % n;
                        let run = (hi - i).min(n - off);
                        // SAFETY: [b][off..off+run] touched by this thread
                        // only — the flat split is disjoint.
                        unsafe { bufs.get_mut(b)[off..off + run].fill(0.0) };
                        i += run;
                    }
                }
                AccumMethod::PerBuffer => {
                    // Buffer-by-buffer, rows split among threads.
                    for b in 0..p {
                        let (lo, hi) = (t * n / p, (t + 1) * n / p);
                        unsafe { bufs.get_mut(b)[lo..hi].fill(0.0) };
                    }
                }
                AccumMethod::Effective => {
                    // Own buffer, own effective range only.
                    let r = eff[t].clone();
                    unsafe { bufs.get_mut(t)[r].fill(0.0) };
                }
                AccumMethod::Interval => {
                    // Assigned intervals, every covering buffer.
                    for &i in &int_assign[t] {
                        let int = &ints[i];
                        for &b in &int.covers {
                            unsafe { bufs.get_mut(b)[int.range.clone()].fill(0.0) };
                        }
                    }
                }
            }
            overhead_ns += t0.elapsed().as_nanos() as u64;
            barrier.wait();

            // ---- compute step: private buffer, no races ---------------
            let block = part.block(t);
            // SAFETY: buffer t is written by thread t only in this phase.
            let buf = unsafe { bufs.get_mut(t) };
            kernel.sweep_rows_into(x, block.start, block.end, buf, 0);
            barrier.wait();

            // ---- accumulation step ------------------------------------
            let t1 = Instant::now();
            match method {
                AccumMethod::AllInOne => {
                    // y rows split evenly; each thread sums all p buffers.
                    let (lo, hi) = (t * n / p, (t + 1) * n / p);
                    // SAFETY: [lo,hi) disjoint per thread.
                    let dst = unsafe { yv.slice_mut(lo..hi) };
                    dst.fill(0.0);
                    for b in 0..p {
                        let src = unsafe { bufs.read(b) };
                        for (d, s) in dst.iter_mut().zip(&src[lo..hi]) {
                            *d += *s;
                        }
                    }
                }
                AccumMethod::PerBuffer => {
                    let (lo, hi) = (t * n / p, (t + 1) * n / p);
                    let dst = unsafe { yv.slice_mut(lo..hi) };
                    dst.fill(0.0);
                    for b in 0..p {
                        let src = unsafe { bufs.read(b) };
                        for (d, s) in dst.iter_mut().zip(&src[lo..hi]) {
                            *d += *s;
                        }
                        // The paper's per-buffer scheme synchronizes the
                        // team between buffers (span Θ(p log n)).
                        barrier.wait();
                    }
                }
                AccumMethod::Effective => {
                    // Own block rows; only buffers whose effective range
                    // covers them contribute.
                    let own = part.block(t);
                    let dst = unsafe { yv.slice_mut(own.clone()) };
                    dst.fill(0.0);
                    for &b in &covering[t] {
                        let src = unsafe { bufs.read(b) };
                        let from = own.start.max(eff[b].start);
                        let to = own.end.min(eff[b].end);
                        for i in from..to {
                            dst[i - own.start] += src[i];
                        }
                    }
                }
                AccumMethod::Interval => {
                    for &idx in &int_assign[t] {
                        let int = &ints[idx];
                        let dst = unsafe { yv.slice_mut(int.range.clone()) };
                        dst.fill(0.0);
                        for &b in &int.covers {
                            let src = unsafe { bufs.read(b) };
                            for (d, s) in dst.iter_mut().zip(&src[int.range.clone()]) {
                                *d += *s;
                            }
                        }
                    }
                }
            }
            overhead_ns += t1.elapsed().as_nanos() as u64;
            ov.fetch_max(overhead_ns, Ordering::Relaxed);
        });

        self.last_overhead_ns = max_overhead.load(Ordering::Relaxed);
    }

    fn name(&self) -> String {
        format!("local-buffers/{}", self.method.label())
    }

    fn nthreads(&self) -> usize {
        self.pool.nthreads()
    }

    fn plan(&self) -> Option<&Arc<SpmvPlan>> {
        Some(&self.plan)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::{Coo, Csrc};
    use crate::util::{propcheck, Rng};

    fn mat(n: usize, npr: usize, seed: u64) -> Arc<Csrc> {
        let mut rng = Rng::new(seed);
        Arc::new(
            Csrc::from_coo(&Coo::random_structurally_symmetric(n, npr, false, &mut rng)).unwrap(),
        )
    }

    #[test]
    fn every_method_matches_sequential() {
        let a = mat(120, 5, 50);
        let x: Vec<f64> = (0..120).map(|i| (i as f64).sin()).collect();
        let mut want = vec![0.0; 120];
        a.spmv_into_zeroed(&x, &mut want);
        for method in AccumMethod::all() {
            for p in [2, 3, 4, 6] {
                let mut e = LocalBuffersEngine::new(a.clone(), p, method);
                let mut y = vec![f64::NAN; 120];
                e.spmv(&x, &mut y);
                propcheck::assert_close(&y, &want, 1e-11, 1e-11)
                    .unwrap_or_else(|err| panic!("{} p={p}: {err}", method.label()));
            }
        }
    }

    #[test]
    fn methods_share_one_full_plan() {
        let a = mat(100, 4, 55);
        let plan = Arc::new(PlanBuilder::all(4).build(a.as_ref()));
        let x: Vec<f64> = (0..100).map(|i| (i as f64 * 0.3).cos()).collect();
        let mut want = vec![0.0; 100];
        a.spmv_into_zeroed(&x, &mut want);
        for method in AccumMethod::all() {
            let mut e = LocalBuffersEngine::with_plan(a.clone(), plan.clone(), method);
            assert!(Arc::ptr_eq(e.plan().unwrap(), &plan));
            let mut y = vec![f64::NAN; 100];
            e.spmv(&x, &mut y);
            propcheck::assert_close(&y, &want, 1e-11, 1e-11).unwrap();
        }
    }

    #[test]
    fn single_thread_shortcut_no_overhead() {
        let a = mat(40, 3, 51);
        let x = vec![1.0; 40];
        let mut e = LocalBuffersEngine::new(a.clone(), 1, AccumMethod::AllInOne);
        let mut y = vec![0.0; 40];
        e.spmv(&x, &mut y);
        assert_eq!(e.last_overhead_ns, 0);
    }

    #[test]
    fn overhead_is_recorded_for_multithread() {
        let a = mat(400, 6, 52);
        let x = vec![1.0; 400];
        let mut e = LocalBuffersEngine::new(a.clone(), 4, AccumMethod::AllInOne);
        let mut y = vec![0.0; 400];
        e.spmv(&x, &mut y);
        assert!(e.last_overhead_ns > 0);
    }

    #[test]
    fn effective_covering_is_complete() {
        // Whoever covers thread t's rows must include t itself.
        let a = mat(100, 4, 53);
        let e = LocalBuffersEngine::new(a, 4, AccumMethod::Effective);
        let covering = e.plan.covering.as_ref().unwrap();
        for t in 0..4 {
            assert!(covering[t].contains(&t));
        }
    }

    #[test]
    fn works_on_banded_and_dense_patterns() {
        let mut rng = Rng::new(54);
        for coo in [
            Coo::banded(90, 1, true, &mut rng),
            Coo::banded(90, 8, false, &mut rng),
            Coo::dense_random(48, &mut rng),
        ] {
            let a = Arc::new(Csrc::from_coo(&coo).unwrap());
            let n = a.n;
            let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let mut want = vec![0.0; n];
            a.spmv_into_zeroed(&x, &mut want);
            for method in AccumMethod::all() {
                let mut e = LocalBuffersEngine::new(a.clone(), 3, method);
                let mut y = vec![0.0; n];
                e.spmv(&x, &mut y);
                propcheck::assert_close(&y, &want, 1e-10, 1e-10).unwrap();
            }
        }
    }
}
