//! Atomics baseline (§3 intro): the paper notes that atomic primitives /
//! locks cost too much relative to the fine-grained y accesses. We keep a
//! CAS-loop f64 atomic-add engine as the ablation that quantifies that
//! claim (bench `ablations`). Like every executor it borrows its row
//! partition from the shared [`SpmvPlan`] and sweeps rows through the
//! [`SpmvKernel`] contribution stream.

use super::pool::ThreadPool;
use super::ParallelSpmv;
use crate::obs::{self, Phase};
use crate::plan::{PlanBuilder, SpmvPlan};
use crate::sparse::SpmvKernel;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

pub struct AtomicEngine {
    kernel: Arc<dyn SpmvKernel>,
    plan: Arc<SpmvPlan>,
    pool: ThreadPool,
    /// f64 bits behind AtomicU64 — lives across calls to avoid realloc.
    bits: Vec<AtomicU64>,
}

#[inline]
fn atomic_add(slot: &AtomicU64, v: f64) {
    let mut cur = slot.load(Ordering::Relaxed);
    loop {
        let new = (f64::from_bits(cur) + v).to_bits();
        match slot.compare_exchange_weak(cur, new, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return,
            Err(actual) => cur = actual,
        }
    }
}

impl AtomicEngine {
    /// Analyze-and-build convenience (single-use plan).
    pub fn new(kernel: Arc<dyn SpmvKernel>, p: usize) -> Self {
        let plan = Arc::new(
            PlanBuilder::for_kind(p, super::EngineKind::Atomic).build(kernel.as_ref()),
        );
        Self::with_plan(kernel, plan)
    }

    /// Build over a shared plan (only the row partition is consumed).
    pub fn with_plan(kernel: Arc<dyn SpmvKernel>, plan: Arc<SpmvPlan>) -> Self {
        let n = kernel.dim();
        assert_eq!(plan.n, n, "plan built for a different matrix");
        let bits = (0..n).map(|_| AtomicU64::new(0)).collect();
        let p = plan.nthreads;
        AtomicEngine { kernel, plan, pool: ThreadPool::new(p), bits }
    }
}

impl ParallelSpmv for AtomicEngine {
    fn spmv(&mut self, x: &[f64], y: &mut [f64]) {
        let n = self.plan.n;
        let p = self.pool.nthreads();
        if p == 1 {
            let _sweep_span = obs::phase(Phase::Sweep);
            self.kernel.sweep_full(x, y);
            return;
        }
        let kernel = &*self.kernel;
        let part = &self.plan.part;
        let bits = &self.bits;
        let barrier = self.pool.barrier();
        self.pool.run(move |t| {
            let zero_span = obs::phase(Phase::Zero);
            let (lo, hi) = (t * n / p, (t + 1) * n / p);
            for slot in &bits[lo..hi] {
                slot.store(0, Ordering::Relaxed);
            }
            drop(zero_span);
            barrier.wait();
            let _sweep_span = obs::phase(Phase::Sweep);
            let block = part.block(t);
            for i in block {
                kernel.sweep_row_contribs(x, i, &mut |idx, v| atomic_add(&bits[idx], v));
            }
        });
        let _accum_span = obs::phase(Phase::Accumulate);
        for (dst, slot) in y.iter_mut().zip(&self.bits) {
            *dst = f64::from_bits(slot.load(Ordering::Relaxed));
        }
    }

    /// k-wide product: the same contribution stream, with each target
    /// widened to a k-slot panel (`n·k` CAS slots, grown lazily and kept
    /// across calls).
    fn spmv_multi(&mut self, x: &[f64], y: &mut [f64], k: usize) {
        assert!(k >= 1);
        if k == 1 {
            return self.spmv(x, y);
        }
        let n = self.plan.n;
        debug_assert_eq!(x.len(), n * k);
        debug_assert_eq!(y.len(), n * k);
        let p = self.pool.nthreads();
        if p == 1 {
            let _sweep_span = obs::phase(Phase::Sweep);
            self.kernel.sweep_full_multi(x, y, k);
            return;
        }
        if self.bits.len() < n * k {
            let grow = n * k - self.bits.len();
            self.bits.extend((0..grow).map(|_| AtomicU64::new(0)));
        }
        let kernel = &*self.kernel;
        let part = &self.plan.part;
        let bits = &self.bits[..n * k];
        let barrier = self.pool.barrier();
        self.pool.run(move |t| {
            let zero_span = obs::phase(Phase::Zero);
            let (lo, hi) = (t * n / p, (t + 1) * n / p);
            for slot in &bits[lo * k..hi * k] {
                slot.store(0, Ordering::Relaxed);
            }
            drop(zero_span);
            barrier.wait();
            let _sweep_span = obs::phase(Phase::Sweep);
            let block = part.block(t);
            for i in block {
                kernel
                    .sweep_row_contribs_multi(x, k, i, &mut |idx, v| atomic_add(&bits[idx], v));
            }
        });
        let _accum_span = obs::phase(Phase::Accumulate);
        for (dst, slot) in y.iter_mut().zip(bits) {
            *dst = f64::from_bits(slot.load(Ordering::Relaxed));
        }
    }

    fn name(&self) -> String {
        "atomic".into()
    }

    fn nthreads(&self) -> usize {
        self.pool.nthreads()
    }

    fn plan(&self) -> Option<&Arc<SpmvPlan>> {
        Some(&self.plan)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::{Coo, Csrc};
    use crate::util::propcheck;

    #[test]
    fn atomic_add_accumulates_exactly() {
        let slot = AtomicU64::new(0);
        for _ in 0..100 {
            atomic_add(&slot, 0.5);
        }
        assert_eq!(f64::from_bits(slot.load(Ordering::Relaxed)), 50.0);
    }

    #[test]
    fn matches_sequential() {
        propcheck::check(6, |rng| {
            let n = 20 + rng.below(80);
            let coo = Coo::random_structurally_symmetric(n, 1 + rng.below(5), false, rng);
            let a = Arc::new(Csrc::from_coo(&coo).map_err(|e| e.to_string())?);
            let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let mut want = vec![0.0; n];
            a.spmv_into_zeroed(&x, &mut want);
            let mut e = AtomicEngine::new(a, 2 + rng.below(3));
            let mut y = vec![0.0; n];
            e.spmv(&x, &mut y);
            // Atomic adds reorder; f64 addition is not associative.
            propcheck::assert_close(&y, &want, 1e-9, 1e-9)
        });
    }
}
