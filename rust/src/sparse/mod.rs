//! Sparse matrix storage formats.
//!
//! The paper's contribution is the **CSRC** format ([`csrc::Csrc`]) — a CSR
//! specialization for structurally symmetric matrices that stores only half
//! of the off-diagonal connectivity (§2 of the paper). The other formats
//! here are the comparison baselines and substrates the evaluation needs:
//!
//! * [`coo::Coo`] — triplet builder every generator assembles into,
//! * [`csr::Csr`] / [`csc::Csc`] — the classical compressed formats (Fig. 5
//!   baseline),
//! * [`bcsr::Bcsr`] — block CSR, the blocking baseline discussed in §1.1,
//! * [`csrc_rect::CsrcRect`] — the §2.1 rectangular extension used by
//!   overlapping domain decomposition,
//! * [`dense`] — dense oracle used by tests,
//! * [`mmio`] — Matrix-Market I/O so real UF-collection files drop in.

pub mod bcsr;
pub mod coo;
pub mod csc;
pub mod csr;
pub mod csrc;
pub mod csrc_rect;
pub mod dense;
pub mod ell;
pub mod mmio;

pub use bcsr::Bcsr;
pub use coo::Coo;
pub use csc::Csc;
pub use csr::Csr;
pub use csrc::{Csrc, CsrcError};
pub use csrc_rect::CsrcRect;
pub use ell::Ell;

/// A row-sweep SpMV kernel: the format abstraction the parallel layer
/// executes against.
///
/// A *row sweep* of row `i` accumulates `y_i` and may additionally
/// scatter updates into other rows (CSRC scatters the mirrored upper
/// contributions `y_j += a_ji · x_i`, `j < i`; CSR and BCSR scatter
/// nothing). Everything the race-avoidance analysis in [`crate::plan`]
/// needs — per-row work for nnz-guided partitioning, write extents for
/// effective ranges, scatter targets for the conflict graph — is exposed
/// here, so one `SpmvPlan` and one set of executors serve every format.
///
/// Contract for implementors:
///
/// * The matrix is square; [`SpmvKernel::dim`] is its order `n`.
/// * [`SpmvKernel::sweep_rows_into`] *accumulates* (`+=`) into `buf`,
///   where `buf[j - lo]` holds `y_j`; sweeping all rows over a zeroed
///   full-length buffer must equal the sequential product.
/// * [`SpmvKernel::scatter_targets`] visits each off-diagonal scatter
///   target of row `i` (never `i` itself), each unordered `{i, j}` pair
///   at most once across the whole sweep — the conflict-graph builder
///   symmetrizes.
/// * [`SpmvKernel::row_write_lo`] is a lower bound ≤ every index row
///   `i`'s sweep writes (used for effective-range analysis); the sweep
///   never writes above `i`.
pub trait SpmvKernel: Send + Sync {
    /// Matrix order n (kernels are square operators).
    fn dim(&self) -> usize;

    /// Per-row work estimate for nnz-guided partitioning (flop-ish units;
    /// only ratios matter).
    fn row_work(&self, i: usize) -> usize;

    /// Lowest index written by row i's sweep (min over {i} ∪ scatter
    /// targets).
    fn row_write_lo(&self, i: usize) -> usize;

    /// Visit every off-diagonal scatter target of row i.
    fn scatter_targets(&self, i: usize, visit: &mut dyn FnMut(usize));

    /// Sweep rows [r0, r1), accumulating into `buf` offset by `lo`
    /// (`buf[j - lo]` holds y_j; `lo = 0` for a full-length buffer).
    fn sweep_rows_into(&self, x: &[f64], r0: usize, r1: usize, buf: &mut [f64], lo: usize);

    /// Sweep one row, accumulating into a *shared* full-length y through
    /// a raw pointer — the colorful executor's per-class primitive
    /// (threads of one class write disjoint index sets, so no `&mut`
    /// alias may be formed over the whole vector).
    ///
    /// # Safety
    /// `y` must point at a buffer of at least [`SpmvKernel::dim`]
    /// elements, and no other thread may concurrently access any index
    /// row `i`'s sweep writes.
    unsafe fn sweep_row_shared(&self, x: &[f64], i: usize, y: *mut f64);

    /// Visit every (index, value) contribution of row i's sweep,
    /// including the `y_i` accumulation itself — the atomics baseline
    /// feeds these straight into CAS adds.
    fn sweep_row_contribs(&self, x: &[f64], i: usize, emit: &mut dyn FnMut(usize, f64));

    /// Full sequential product, y fully overwritten (the baseline and
    /// the single-thread shortcut).
    fn sweep_full(&self, x: &[f64], y: &mut [f64]);

    /// Multi-vector (SpMM) variant of [`SpmvKernel::sweep_rows_into`]:
    /// sweep rows [r0, r1) of a k-wide product, accumulating into a
    /// row-major panel buffer where `buf[(j - lo)*k + c]` holds column
    /// `c` of `y_j`. `x` is the matching n×k row-major panel
    /// (`x[j*k + c]` = column c of x_j). The default runs k gathered
    /// single-vector sweeps — correct for any kernel; the concrete
    /// formats override it with fused panel sweeps that read the matrix
    /// (values *and* indices) once for all k columns, which is the whole
    /// point of blocking a bandwidth-bound product.
    fn sweep_rows_into_multi(
        &self,
        x: &[f64],
        k: usize,
        r0: usize,
        r1: usize,
        buf: &mut [f64],
        lo: usize,
    ) {
        assert!(k >= 1 && buf.len() % k == 0);
        let n = self.dim();
        debug_assert_eq!(x.len(), n * k);
        let mut xc = vec![0.0; n];
        let mut tmp = vec![0.0; buf.len() / k];
        for c in 0..k {
            for (s, panel) in xc.iter_mut().zip(x.chunks_exact(k)) {
                *s = panel[c];
            }
            for v in tmp.iter_mut() {
                *v = 0.0;
            }
            self.sweep_rows_into(&xc, r0, r1, &mut tmp, lo);
            for (v, panel) in tmp.iter().zip(buf.chunks_exact_mut(k)) {
                panel[c] += *v;
            }
        }
    }

    /// Multi-vector variant of [`SpmvKernel::sweep_full`]: `y` is an
    /// n×k row-major panel, fully overwritten. Default: zero + one
    /// accumulating panel sweep over all rows.
    fn sweep_full_multi(&self, x: &[f64], y: &mut [f64], k: usize) {
        y.fill(0.0);
        self.sweep_rows_into_multi(x, k, 0, self.dim(), y, 0);
    }

    /// Multi-vector variant of [`SpmvKernel::sweep_row_shared`]: one
    /// row's sweep of a k-wide product into a shared n×k row-major
    /// panel through a raw pointer (`y[j*k + c]`). The default gathers
    /// each column and replays the single-vector contributions — it
    /// writes exactly the indices the scalar sweep writes, so the
    /// colorful executor's disjointness guarantee carries over.
    ///
    /// # Safety
    /// `y` must point at a buffer of at least `dim() * k` elements, and
    /// no other thread may concurrently access any panel row that row
    /// `i`'s sweep writes.
    unsafe fn sweep_row_shared_multi(&self, x: &[f64], k: usize, i: usize, y: *mut f64) {
        let n = self.dim();
        let mut xc = vec![0.0; n];
        for c in 0..k {
            for (s, panel) in xc.iter_mut().zip(x.chunks_exact(k)) {
                *s = panel[c];
            }
            self.sweep_row_contribs(&xc, i, &mut |idx, v| *y.add(idx * k + c) += v);
        }
    }

    /// Multi-vector variant of [`SpmvKernel::sweep_row_contribs`]:
    /// visit every (flat panel slot, value) contribution of row i's
    /// k-wide sweep, where the slot is `idx * k + c`. Feeds the atomics
    /// baseline's n×k CAS table.
    fn sweep_row_contribs_multi(
        &self,
        x: &[f64],
        k: usize,
        i: usize,
        emit: &mut dyn FnMut(usize, f64),
    ) {
        let n = self.dim();
        let mut xc = vec![0.0; n];
        for c in 0..k {
            for (s, panel) in xc.iter_mut().zip(x.chunks_exact(k)) {
                *s = panel[c];
            }
            self.sweep_row_contribs(&xc, i, &mut |idx, v| emit(idx * k + c, v));
        }
    }

    /// Format name for reports ("csrc", "csr", "bcsr").
    fn kernel_name(&self) -> &'static str;

    /// The same matrix renumbered by `perm` (B = P A Pᵀ), as a fresh
    /// kernel — what the tuner's reordered candidates and the service's
    /// reorder policy execute against. Default `None`: formats without a
    /// symmetric permutation (or where it is not worth implementing)
    /// simply opt out of reordering.
    fn permuted(&self, perm: &crate::reorder::Permutation) -> Option<std::sync::Arc<dyn SpmvKernel>> {
        let _ = perm;
        None
    }
}

/// A square linear operator: the trait the solvers (`solver/`) and the
/// coordinator consume, implemented by every format and by the parallel
/// engines.
pub trait LinOp {
    fn dim(&self) -> usize;
    /// y = A x (y is fully overwritten).
    fn apply(&self, x: &[f64], y: &mut [f64]);
    /// y = Aᵀ x when the operator supports it. Default: `Err` — callers
    /// (solvers, the autotuner) probe capabilities by calling, never by
    /// catching a panic. CSRC overrides this for free (swap al/au, the
    /// paper's §5 point); CSR pays for a transpose pass.
    fn apply_t(&self, x: &[f64], y: &mut [f64]) -> Result<(), String> {
        let _ = (x, y);
        Err("transpose product not supported by this operator".into())
    }
    /// Diagonal extraction (for Jacobi preconditioning); `None` when the
    /// operator cannot expose one.
    fn diagonal(&self) -> Option<Vec<f64>> {
        None
    }
    /// Y = A X for a row-major n×k panel (`x[j*k + c]`, `y[i*k + c]`;
    /// `y` fully overwritten) — what the block solvers iterate on.
    /// Default: k gathered single-vector products; operators with a
    /// blocked kernel (CSRC, the parallel engines) override it.
    fn apply_multi(&self, x: &[f64], y: &mut [f64], k: usize) {
        assert!(k >= 1);
        let n = self.dim();
        debug_assert!(x.len() == n * k && y.len() == n * k);
        let mut xc = vec![0.0; n];
        let mut yc = vec![0.0; n];
        for c in 0..k {
            for (s, panel) in xc.iter_mut().zip(x.chunks_exact(k)) {
                *s = panel[c];
            }
            self.apply(&xc, &mut yc);
            for (v, panel) in yc.iter().zip(y.chunks_exact_mut(k)) {
                panel[c] = *v;
            }
        }
    }
}
