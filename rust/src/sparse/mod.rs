//! Sparse matrix storage formats.
//!
//! The paper's contribution is the **CSRC** format ([`csrc::Csrc`]) — a CSR
//! specialization for structurally symmetric matrices that stores only half
//! of the off-diagonal connectivity (§2 of the paper). The other formats
//! here are the comparison baselines and substrates the evaluation needs:
//!
//! * [`coo::Coo`] — triplet builder every generator assembles into,
//! * [`csr::Csr`] / [`csc::Csc`] — the classical compressed formats (Fig. 5
//!   baseline),
//! * [`bcsr::Bcsr`] — block CSR, the blocking baseline discussed in §1.1,
//! * [`csrc_rect::CsrcRect`] — the §2.1 rectangular extension used by
//!   overlapping domain decomposition,
//! * [`dense`] — dense oracle used by tests,
//! * [`mmio`] — Matrix-Market I/O so real UF-collection files drop in.

pub mod bcsr;
pub mod coo;
pub mod csc;
pub mod csr;
pub mod csrc;
pub mod csrc_rect;
pub mod dense;
pub mod ell;
pub mod mmio;

pub use bcsr::Bcsr;
pub use coo::Coo;
pub use csc::Csc;
pub use csr::Csr;
pub use csrc::{Csrc, CsrcError};
pub use csrc_rect::CsrcRect;
pub use ell::Ell;

/// A square linear operator: the trait the solvers (`solver/`) and the
/// coordinator consume, implemented by every format and by the parallel
/// engines.
pub trait LinOp {
    fn dim(&self) -> usize;
    /// y = A x (y is fully overwritten).
    fn apply(&self, x: &[f64], y: &mut [f64]);
    /// y = Aᵀ x. Default: unimplemented — CSRC overrides this for free
    /// (swap al/au, the paper's §5 point), CSR pays for a transpose pass.
    fn apply_t(&self, x: &[f64], y: &mut [f64]) {
        let _ = (x, y);
        unimplemented!("transpose product not supported by this operator");
    }
    /// Diagonal extraction (for Jacobi preconditioning); default panics.
    fn diagonal(&self) -> Vec<f64> {
        unimplemented!("diagonal not supported by this operator");
    }
}
